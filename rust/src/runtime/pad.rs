//! The padding contract between the bucket-laddered AOT artifacts and
//! live problem sizes:
//!
//! * **data rows** pad with zeros — RBF distances to a zero-padded
//!   *feature* dimension are unchanged, and zero *rows* produce garbage
//!   entries the caller slices away;
//! * **z weights** pad with `0` — padded coordinates contribute nothing
//!   to the rotation (kernel multiplies by `z`);
//! * **eigenvalues** pad with ascending sentinels far above any real
//!   spectrum (`SENTINEL + j`), keeping denominators `λⱼ − λ̃ᵢ` huge so
//!   padded columns stay finite and bounded before being sliced away.
//!
//! The `_into` forms write padded buckets straight from (possibly
//! strided) views into reusable staging buffers — a [`Staging`] bundle
//! per runtime, so steady-state dispatch re-pads without touching the
//! allocator. The allocating forms survive as thin shims over them.

use crate::linalg::{Mat, MatView};

/// Base value for sentinel eigenvalues. Real kernel eigenvalues in this
/// system are ≤ `n·max k(x,x)` ≲ 1e6; 1e12 keeps sentinel gaps dominant.
pub const SENTINEL: f64 = 1e12;

/// Reusable staging buffers for padded operands: one bundle per
/// runtime, each executable wrapper staging its operands into the named
/// slots before building device literals. Capacities only ever grow
/// (to the largest bucket dispatched), so re-dispatch at a warm bucket
/// size is allocation-free.
#[derive(Clone, Debug, Default)]
pub struct Staging {
    /// First padded matrix operand of a dispatch.
    pub mat_a: Vec<f64>,
    /// Second padded matrix operand of a dispatch.
    pub mat_b: Vec<f64>,
    /// First padded vector operand.
    pub vec_a: Vec<f64>,
    /// Second padded vector operand.
    pub vec_b: Vec<f64>,
    /// Third padded vector operand.
    pub vec_c: Vec<f64>,
}

impl Staging {
    pub fn new() -> Staging {
        Staging::default()
    }
}

/// Zero-pad a matrix view to `rows × cols`, row-major into `buf`
/// (resized to `rows·cols`; every cell is written — copied window,
/// zeroed gap columns and tail rows — so stale staging contents never
/// leak into a dispatch).
pub fn pad_mat_into(a: MatView<'_>, rows: usize, cols: usize, buf: &mut Vec<f64>) {
    assert!(rows >= a.rows() && cols >= a.cols(), "pad_mat_into: target smaller than source");
    buf.resize(rows * cols, 0.0);
    for i in 0..a.rows() {
        let src = a.row(i);
        let dst = &mut buf[i * cols..(i + 1) * cols];
        dst[..src.len()].copy_from_slice(src);
        dst[src.len()..].fill(0.0);
    }
    buf[a.rows() * cols..].fill(0.0);
}

/// Zero-pad a vector to `len` into `buf` (every cell written).
pub fn pad_zeros_into(v: &[f64], len: usize, buf: &mut Vec<f64>) {
    assert!(len >= v.len(), "pad_zeros_into: target smaller than source");
    buf.resize(len, 0.0);
    buf[..v.len()].copy_from_slice(v);
    buf[v.len()..].fill(0.0);
}

/// Pad eigenvalues with ascending sentinels into `buf` (`offset` shifts
/// the sentinel series so poles and roots never collide).
pub fn pad_sentinels_into(v: &[f64], len: usize, offset: f64, buf: &mut Vec<f64>) {
    assert!(len >= v.len(), "pad_sentinels_into: target smaller than source");
    buf.resize(len, 0.0);
    buf[..v.len()].copy_from_slice(v);
    for (j, slot) in buf.iter_mut().enumerate().skip(v.len()) {
        *slot = SENTINEL + j as f64 + offset;
    }
}

/// Zero-pad a matrix to `rows × cols` (allocating shim over
/// [`pad_mat_into`]).
pub fn pad_mat(a: &Mat, rows: usize, cols: usize) -> Mat {
    let mut buf = Vec::new();
    pad_mat_into(a.view(), rows, cols, &mut buf);
    Mat::from_vec(rows, cols, buf)
}

/// Zero-pad a vector to `len` (allocating shim over [`pad_zeros_into`]).
pub fn pad_zeros(v: &[f64], len: usize) -> Vec<f64> {
    let mut buf = Vec::new();
    pad_zeros_into(v, len, &mut buf);
    buf
}

/// Pad eigenvalues with ascending sentinels (allocating shim over
/// [`pad_sentinels_into`]).
pub fn pad_sentinels(v: &[f64], len: usize, offset: f64) -> Vec<f64> {
    let mut buf = Vec::new();
    pad_sentinels_into(v, len, offset, &mut buf);
    buf
}

/// Slice the leading `rows × cols` block out of a padded result.
pub fn unpad_mat(a: &Mat, rows: usize, cols: usize) -> Mat {
    a.submatrix(rows, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_roundtrip() {
        let a = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        let p = pad_mat(&a, 8, 8);
        assert_eq!(p[(2, 1)], 5.0);
        assert_eq!(p[(3, 0)], 0.0);
        assert!(unpad_mat(&p, 3, 2).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn sentinels_ascend_and_dont_collide() {
        let poles = pad_sentinels(&[1.0, 2.0], 6, 0.0);
        let roots = pad_sentinels(&[1.5, 2.5], 6, 0.5);
        for w in poles.windows(2) {
            assert!(w[0] < w[1]);
        }
        for (p, r) in poles.iter().zip(roots.iter()).skip(2) {
            assert!((p - r).abs() > 0.4);
        }
    }

    #[test]
    fn pad_zeros_length() {
        assert_eq!(pad_zeros(&[1.0], 3), vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn into_forms_overwrite_stale_staging() {
        // A reused staging buffer full of garbage must come out exactly
        // as if freshly allocated — the resize path retains stale cells,
        // so every pad writes the full target window.
        let mut buf = vec![f64::NAN; 64];
        let a = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64 + 1.0);
        pad_mat_into(a.view(), 4, 5, &mut buf);
        assert_eq!(buf.len(), 4 * 5);
        for i in 0..4 {
            for j in 0..5 {
                let want = if i < 2 && j < 3 { a[(i, j)] } else { 0.0 };
                assert_eq!(buf[i * 5 + j], want, "({i},{j})");
            }
        }
        // Strided source view: pad from a window without copying it out.
        let backing = Mat::from_fn(3, 7, |i, j| (i * 7 + j) as f64);
        let win = MatView::new(backing.as_slice(), 3, 2, 7);
        buf.iter_mut().for_each(|v| *v = f64::NAN);
        buf.resize(64, f64::NAN);
        pad_mat_into(win, 4, 4, &mut buf);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i < 3 && j < 2 { backing[(i, j)] } else { 0.0 };
                assert_eq!(buf[i * 4 + j], want, "strided ({i},{j})");
            }
        }
        let mut vbuf = vec![f64::NAN; 10];
        pad_zeros_into(&[7.0, 8.0], 5, &mut vbuf);
        assert_eq!(vbuf, vec![7.0, 8.0, 0.0, 0.0, 0.0]);
        let mut sbuf = vec![f64::NAN; 10];
        pad_sentinels_into(&[1.0], 4, 0.5, &mut sbuf);
        assert_eq!(sbuf.len(), 4);
        assert_eq!(sbuf[0], 1.0);
        for (j, &s) in sbuf.iter().enumerate().skip(1) {
            assert_eq!(s, SENTINEL + j as f64 + 0.5);
        }
    }

    #[test]
    fn shims_match_into_forms() {
        let a = Mat::from_fn(3, 2, |i, j| ((i * 2 + j) as f64).sin());
        let p = pad_mat(&a, 6, 4);
        let mut buf = Vec::new();
        pad_mat_into(a.view(), 6, 4, &mut buf);
        assert_eq!(p.as_slice(), &buf[..]);
        assert_eq!(pad_zeros(&[1.0, 2.0], 4), {
            let mut b = Vec::new();
            pad_zeros_into(&[1.0, 2.0], 4, &mut b);
            b
        });
        assert_eq!(pad_sentinels(&[1.0], 3, 0.0), {
            let mut b = Vec::new();
            pad_sentinels_into(&[1.0], 3, 0.0, &mut b);
            b
        });
    }
}
