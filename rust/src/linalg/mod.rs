//! Dense linear-algebra substrate, built from scratch: matrix type,
//! blocked/parallel BLAS-3, Householder tridiagonalization, implicit-QL
//! tridiagonal eigensolver, full symmetric `eigh`, Cholesky with rank-one
//! up/downdates, and the three norms the paper's figures report.

pub mod cholesky;
pub mod eigh;
pub mod gemm;
pub mod householder;
pub mod matrix;
pub mod norms;
pub mod tridiag;

pub use cholesky::Cholesky;
pub use eigh::{eigh, eigvalsh, Eigh};
pub use gemm::{gemv, gemv_t, matmul, matmul_nt, syrk};
pub use matrix::{dot, norm2, Mat};
pub use norms::{
    frobenius, orthogonality_defect, psd_norms, spectral_sym, sym_norms, trace_sym, Norms,
};
