//! T1 — the §3 efficiency comparison: measured per-step wall-clock of
//! the paper's algorithm (adjusted ≈8m³ / unadjusted ≈4m³) against
//! Chin & Suter (≈20m³ per the paper's accounting; also the lean ≈11m³
//! kernelized variant as an ablation), the Hoegaerts tracker, and batch
//! re-eigendecomposition (≈9m³ *per step*). The paper's claim: ours is
//! >2× cheaper than Chin–Suter; the crossover shape, not absolute
//! numbers, is the acceptance criterion.

use std::io::Write;
use std::time::Instant;

use crate::baselines::{ChinSuterKpca, HoegaertsTracker};
use crate::data::load;
use crate::kernels::{median_heuristic, Rbf};
use crate::kpca::{BatchKpca, IncrementalKpca};

use super::RunMode;

#[derive(Clone, Debug)]
pub struct FlopsConfig {
    /// Eigensystem sizes to measure at.
    pub sizes: Vec<usize>,
    /// Steps averaged per measurement.
    pub steps: usize,
    pub seed: u64,
}

impl FlopsConfig {
    pub fn new(mode: RunMode) -> Self {
        match mode {
            RunMode::Quick => FlopsConfig { sizes: vec![64, 128], steps: 4, seed: 42 },
            RunMode::Full => {
                FlopsConfig { sizes: vec![64, 128, 256, 512], steps: 8, seed: 42 }
            }
        }
    }
}

/// Measured per-step cost (seconds) for each method at one size.
#[derive(Clone, Copy, Debug)]
pub struct FlopsRow {
    pub m: usize,
    pub ours_adjusted: f64,
    pub ours_unadjusted: f64,
    pub chin_suter: f64,
    pub chin_suter_lean: f64,
    pub hoegaerts_full: f64,
    pub batch_eig: f64,
}

impl FlopsRow {
    /// The paper's headline ratio at this size.
    pub fn speedup_vs_chin_suter(&self) -> f64 {
        self.chin_suter / self.ours_adjusted
    }
}

pub fn run_flops(cfg: &FlopsConfig) -> Result<Vec<FlopsRow>, String> {
    let (mut csv, path) = super::csv_writer(
        "table_flops.csv",
        "m,ours_adjusted_s,ours_unadjusted_s,chin_suter_s,chin_suter_lean_s,hoegaerts_s,batch_eig_s",
    )
    .map_err(|e| e.to_string())?;
    let max_m = *cfg.sizes.iter().max().unwrap();
    let ds = {
        let mut d = load("magic", max_m + cfg.steps + 1, cfg.seed)?;
        d.standardize();
        d
    };
    let sigma = median_heuristic(&ds.x, 200);
    let kern = Rbf { sigma };

    let mut rows = Vec::new();
    for &m in &cfg.sizes {
        let seed_mat = ds.x.submatrix(m, ds.dim());

        // Ours, mean-adjusted (Algorithm 2).
        let mut inc = IncrementalKpca::from_batch(&kern, &seed_mat, true)?;
        let t0 = Instant::now();
        for s in 0..cfg.steps {
            inc.push(ds.x.row(m + s))?;
        }
        let ours_adjusted = t0.elapsed().as_secs_f64() / cfg.steps as f64;

        // Ours, unadjusted (Algorithm 1).
        let mut inc = IncrementalKpca::from_batch(&kern, &seed_mat, false)?;
        let t0 = Instant::now();
        for s in 0..cfg.steps {
            inc.push(ds.x.row(m + s))?;
        }
        let ours_unadjusted = t0.elapsed().as_secs_f64() / cfg.steps as f64;

        // Chin–Suter, faithful cost profile (≈20m³).
        let mut cs = ChinSuterKpca::from_batch(&kern, &seed_mat)?;
        cs.faithful_cost = true;
        let t0 = Instant::now();
        for s in 0..cfg.steps {
            cs.push(ds.x.row(m + s))?;
        }
        let chin_suter = t0.elapsed().as_secs_f64() / cfg.steps as f64;

        // Chin–Suter, lean kernelized variant (≈11m³) — ablation.
        let mut cs = ChinSuterKpca::from_batch(&kern, &seed_mat)?;
        cs.faithful_cost = false;
        let t0 = Instant::now();
        for s in 0..cfg.steps {
            cs.push(ds.x.row(m + s))?;
        }
        let chin_suter_lean = t0.elapsed().as_secs_f64() / cfg.steps as f64;

        // Hoegaerts with r = m (exact, unadjusted).
        let mut hg = HoegaertsTracker::from_batch(&kern, &seed_mat, m + cfg.steps + 1)?;
        let t0 = Instant::now();
        for s in 0..cfg.steps {
            hg.push(ds.x.row(m + s))?;
        }
        let hoegaerts_full = t0.elapsed().as_secs_f64() / cfg.steps as f64;

        // Batch re-decomposition per step.
        let t0 = Instant::now();
        for s in 0..cfg.steps {
            let x = ds.x.submatrix(m + s + 1, ds.dim());
            BatchKpca::fit(&kern, &x, true)?;
        }
        let batch_eig = t0.elapsed().as_secs_f64() / cfg.steps as f64;

        let row = FlopsRow {
            m,
            ours_adjusted,
            ours_unadjusted,
            chin_suter,
            chin_suter_lean,
            hoegaerts_full,
            batch_eig,
        };
        writeln!(
            csv,
            "{m},{ours_adjusted:.6e},{ours_unadjusted:.6e},{chin_suter:.6e},{chin_suter_lean:.6e},{hoegaerts_full:.6e},{batch_eig:.6e}"
        )
        .map_err(|e| e.to_string())?;
        rows.push(row);
    }

    println!("── T1: per-step wall-clock (s) ──");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "m", "ours-adj", "ours-unadj", "chin-suter", "cs-lean", "hoegaerts", "batch", "speedup"
    );
    for r in &rows {
        println!(
            "{:>6} {:>12.4e} {:>12.4e} {:>12.4e} {:>12.4e} {:>12.4e} {:>12.4e} {:>7.1}x",
            r.m,
            r.ours_adjusted,
            r.ours_unadjusted,
            r.chin_suter,
            r.chin_suter_lean,
            r.hoegaerts_full,
            r.batch_eig,
            r.speedup_vs_chin_suter()
        );
    }
    println!(
        "flop model: ours-adj 8m³ | ours-unadj 4m³ | chin-suter ≈20m³ | batch ≈9m³/step (paper §3)"
    );
    println!("flops: wrote {}", path.display());
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_table_shape_holds_small() {
        // m=96 is the smallest size where the O(m³) terms dominate the
        // per-step overheads enough for the ordering to be stable.
        let cfg = FlopsConfig { sizes: vec![96], steps: 3, seed: 1 };
        let rows = run_flops(&cfg).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        // Who-wins shape: the faithful Chin–Suter does strictly more
        // O(m³) work than ours (≈20m³ vs ≈8m³).
        assert!(r.ours_adjusted < r.chin_suter, "{r:?}");
        assert!(r.ours_unadjusted < r.ours_adjusted * 1.5, "{r:?}");
    }
}
