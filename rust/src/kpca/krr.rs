//! Incremental kernel ridge regression through the eigendecomposition —
//! the paper's §3 claim made concrete: "any incremental algorithm for
//! the eigendecomposition of the kernel matrix can be applied where the
//! explicit or implicit inverse of the same is required, such as kernel
//! regression". With `K = UΛUᵀ` maintained by Algorithm 1, the KRR
//! coefficients are `α = U (Λ + λI)⁻¹ Uᵀ y` — an `O(m²)` refresh per
//! ridge value, with the eigensystem update doing the `O(m³)` work once
//! per example regardless of how many ridges are evaluated (the standard
//! reason to prefer the eigendecomposition over one Cholesky per λ).
//!
//! Refits follow the cached discipline the projection path adopted in
//! the coordinator work: everything a refit needs — coefficients,
//! in-sample fits, effective degrees of freedom — is computed from the
//! *tracked* eigensystem (`K = UΛUᵀ` exactly, to update rounding), so
//! no Gram matrix is ever recomputed per refit. The pre-cache
//! Gram-recomputing path survives as [`IncrementalKrr::fitted_recomputed`],
//! the ≤1e-10 equivalence reference. Prediction evaluates its kernel
//! column over the state's flat retained data
//! ([`IncrementalKpca::data_flat`]) — no per-query matrix clone.

use crate::kernels::{kernel_column_into, Kernel};
use crate::linalg::{gemv_t, Mat};
use crate::rankone::Rotate;

use super::incremental::{BatchOutcome, IncrementalKpca};

/// Incremental KRR model: an (unadjusted) incremental eigensystem plus
/// the stored targets.
pub struct IncrementalKrr<'k> {
    pub kpca: IncrementalKpca<'k>,
    y: Vec<f64>,
    /// Ridge (regularization) parameter λ.
    pub ridge: f64,
}

impl<'k> IncrementalKrr<'k> {
    /// Seed from a batch fit over `(x0, y0)`.
    pub fn from_batch(
        kernel: &'k dyn Kernel,
        x0: &Mat,
        y0: &[f64],
        ridge: f64,
    ) -> Result<Self, String> {
        assert_eq!(x0.rows(), y0.len());
        assert!(ridge > 0.0, "ridge must be positive");
        let kpca = IncrementalKpca::from_batch(kernel, x0, false)?;
        Ok(IncrementalKrr { kpca, y: y0.to_vec(), ridge })
    }

    pub fn len(&self) -> usize {
        self.kpca.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kpca.is_empty()
    }

    /// Ingest one labelled example.
    pub fn push(&mut self, x: &[f64], y: f64) -> Result<bool, String> {
        self.push_with(x, y, &crate::rankone::NativeRotate)
    }

    pub fn push_with(&mut self, x: &[f64], y: f64, engine: &dyn Rotate) -> Result<bool, String> {
        let accepted = self.kpca.push_with(x, engine)?;
        if accepted {
            self.y.push(y);
        }
        Ok(accepted)
    }

    /// Ingest a labelled batch (`xs` is `b × dim` row-major, one target
    /// per point) through the eigensystem's blocked batch entry point;
    /// targets of excluded points are dropped to keep `y` aligned with
    /// the retained set.
    pub fn push_batch(&mut self, xs: &[f64], ys: &[f64]) -> Result<BatchOutcome, String> {
        self.push_batch_with(xs, ys, &crate::rankone::NativeRotate)
    }

    pub fn push_batch_with(
        &mut self,
        xs: &[f64],
        ys: &[f64],
        engine: &dyn Rotate,
    ) -> Result<BatchOutcome, String> {
        assert_eq!(
            xs.len(),
            ys.len() * self.kpca.dim(),
            "one target per batch point required"
        );
        let outcome = self.kpca.push_batch_with(xs, engine);
        // Sync targets with whatever prefix the eigensystem actually
        // accepted — on `Err` the accepted prefix remains applied (the
        // mask covers exactly the processed points), and `y` must not
        // fall out of step with the retained set.
        for (&yi, &ok) in ys.iter().zip(self.kpca.last_batch_mask()) {
            if ok {
                self.y.push(yi);
            }
        }
        outcome
    }

    /// Dual coefficients `α = U (Λ + λI)⁻¹ Uᵀ y` for the current ridge.
    pub fn coefficients(&self) -> Vec<f64> {
        self.coefficients_for(self.ridge)
    }

    /// Coefficients for an arbitrary ridge — `O(m²)`, no refactorization
    /// (the eigensystem amortizes across the whole regularization path).
    pub fn coefficients_for(&self, ridge: f64) -> Vec<f64> {
        let uty = gemv_t(&self.kpca.vecs, &self.y);
        let scaled: Vec<f64> = uty
            .iter()
            .zip(&self.kpca.vals)
            .map(|(c, l)| c / (l + ridge))
            .collect();
        crate::linalg::gemv(&self.kpca.vecs, &scaled)
    }

    /// Predict at a query point. The kernel column is evaluated over
    /// the state's flat retained data — `O(m·d)` kernel work, no
    /// per-query matrix clone.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut kq = Vec::with_capacity(self.len());
        kernel_column_into(
            self.kpca.kernel_ref(),
            self.kpca.data_flat(),
            self.kpca.dim(),
            self.len(),
            x,
            &mut kq,
        );
        crate::linalg::dot(&self.coefficients(), &kq)
    }

    /// In-sample predictions for the current ridge (see
    /// [`IncrementalKrr::fitted_for`]).
    pub fn fitted(&self) -> Vec<f64> {
        self.fitted_for(self.ridge)
    }

    /// In-sample predictions `K α = U Λ (Λ + λI)⁻¹ Uᵀ y` straight off
    /// the tracked eigensystem — the cached-centering discipline: a
    /// refit at any ridge is `O(m²)` with *zero* kernel evaluations (the
    /// incremental update already paid for `K = UΛUᵀ`). The
    /// Gram-recomputing path is kept as
    /// [`IncrementalKrr::fitted_recomputed`] and must agree to ≤1e-10.
    pub fn fitted_for(&self, ridge: f64) -> Vec<f64> {
        let uty = gemv_t(&self.kpca.vecs, &self.y);
        let scaled: Vec<f64> = uty
            .iter()
            .zip(&self.kpca.vals)
            .map(|(c, l)| c * l / (l + ridge))
            .collect();
        crate::linalg::gemv(&self.kpca.vecs, &scaled)
    }

    /// Reference in-sample predictions: recompute the full Gram and
    /// apply it to the coefficients (`O(m²)` kernel evaluations — the
    /// pre-cache behaviour, kept to validate [`IncrementalKrr::fitted`]
    /// against).
    pub fn fitted_recomputed(&self) -> Vec<f64> {
        let data = self.kpca.data();
        let k = crate::kernels::gram(self.kpca.kernel_ref(), &data);
        crate::linalg::gemv(&k, &self.coefficients())
    }

    /// Effective degrees of freedom `Σ λᵢ/(λᵢ+ridge)` — free given the
    /// eigenvalues, used for regularization-path selection.
    pub fn effective_dof(&self, ridge: f64) -> f64 {
        self.kpca.vals.iter().map(|l| l / (l + ridge)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;
    use crate::linalg::Cholesky;

    fn toy_problem(n: usize) -> (Mat, Vec<f64>) {
        let ds = yeast_like(n, 9);
        let y: Vec<f64> =
            (0..n).map(|i| ds.x[(i, 0)] * 2.0 - ds.x[(i, 1)] + 0.1 * (i as f64).sin()).collect();
        (ds.x, y)
    }

    #[test]
    fn matches_direct_solve() {
        let (x, y) = toy_problem(18);
        let kern = Rbf { sigma: 1.0 };
        let ridge = 0.1;
        let seed_n = 6;
        let mut krr =
            IncrementalKrr::from_batch(&kern, &x.submatrix(seed_n, x.cols()), &y[..seed_n], ridge)
                .unwrap();
        for i in seed_n..18 {
            krr.push(x.row(i), y[i]).unwrap();
        }
        // Direct: α = (K + λI)⁻¹ y via Cholesky.
        let mut k = crate::kernels::gram(&kern, &x);
        for i in 0..18 {
            k[(i, i)] += ridge;
        }
        let direct = Cholesky::new(&k).unwrap().solve(&y);
        let ours = krr.coefficients();
        for (a, b) in ours.iter().zip(direct.iter()) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn prediction_interpolates_with_tiny_ridge() {
        let (x, y) = toy_problem(12);
        let kern = Rbf { sigma: 1.0 };
        let mut krr =
            IncrementalKrr::from_batch(&kern, &x.submatrix(4, x.cols()), &y[..4], 1e-8).unwrap();
        for i in 4..12 {
            krr.push(x.row(i), y[i]).unwrap();
        }
        // Near-zero ridge: training predictions ≈ targets.
        for i in 0..12 {
            let p = krr.predict(x.row(i));
            assert!((p - y[i]).abs() < 1e-3, "{p} vs {}", y[i]);
        }
    }

    #[test]
    fn cached_refit_matches_recomputed_gram_path() {
        // The cached-centering discipline: fitted() refits off the
        // tracked eigensystem with zero kernel evaluations and must
        // agree with the Gram-recomputing reference to ≤ 1e-10 — at the
        // stored ridge and across a refit path.
        let (x, y) = toy_problem(16);
        let kern = Rbf { sigma: 1.0 };
        let mut krr =
            IncrementalKrr::from_batch(&kern, &x.submatrix(5, x.cols()), &y[..5], 0.2).unwrap();
        for i in 5..16 {
            krr.push(x.row(i), y[i]).unwrap();
        }
        let cached = krr.fitted();
        let recomputed = krr.fitted_recomputed();
        for (a, b) in cached.iter().zip(&recomputed) {
            assert!((a - b).abs() <= 1e-10, "cached {a} vs recomputed {b}");
        }
        // Refits at other ridges stay on the cached path too.
        for ridge in [0.01, 0.5, 2.0] {
            let f = krr.fitted_for(ridge);
            let mut k = crate::kernels::gram(&kern, &x);
            for i in 0..16 {
                k[(i, i)] += ridge;
            }
            let alpha = Cholesky::new(&k).unwrap().solve(&y);
            let k_plain = crate::kernels::gram(&kern, &x);
            let direct = crate::linalg::gemv(&k_plain, &alpha);
            for (a, b) in f.iter().zip(&direct) {
                assert!((a - b).abs() <= 1e-8, "ridge {ridge}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn labelled_batch_push_matches_sequential() {
        let (x, y) = toy_problem(15);
        let kern = Rbf { sigma: 1.0 };
        let mut seq =
            IncrementalKrr::from_batch(&kern, &x.submatrix(4, x.cols()), &y[..4], 0.1).unwrap();
        for i in 4..15 {
            seq.push(x.row(i), y[i]).unwrap();
        }
        let mut bat =
            IncrementalKrr::from_batch(&kern, &x.submatrix(4, x.cols()), &y[..4], 0.1).unwrap();
        let dim = x.cols();
        let flat = x.as_slice();
        let out = bat.push_batch(&flat[4 * dim..9 * dim], &y[4..9]).unwrap();
        assert_eq!(out.accepted, 5);
        let out = bat.push_batch(&flat[9 * dim..15 * dim], &y[9..15]).unwrap();
        assert_eq!(out.accepted, 6);
        assert_eq!(bat.len(), 15);
        for (a, b) in seq.coefficients().iter().zip(bat.coefficients().iter()) {
            assert!((a - b).abs() <= 1e-10, "{a} vs {b}");
        }
        let p_seq = seq.predict(x.row(2));
        let p_bat = bat.predict(x.row(2));
        assert!((p_seq - p_bat).abs() <= 1e-10);
    }

    #[test]
    fn ridge_path_without_refactorization() {
        let (x, y) = toy_problem(14);
        let kern = Rbf { sigma: 1.0 };
        let mut krr =
            IncrementalKrr::from_batch(&kern, &x.submatrix(5, x.cols()), &y[..5], 0.5).unwrap();
        for i in 5..14 {
            krr.push(x.row(i), y[i]).unwrap();
        }
        // dof decreases monotonically with ridge — the path is coherent.
        let d1 = krr.effective_dof(0.01);
        let d2 = krr.effective_dof(0.1);
        let d3 = krr.effective_dof(1.0);
        assert!(d1 > d2 && d2 > d3);
        // Coefficients for each ridge match the direct solve.
        for ridge in [0.01, 0.1, 1.0] {
            let mut k = crate::kernels::gram(&kern, &x);
            for i in 0..14 {
                k[(i, i)] += ridge;
            }
            let direct = Cholesky::new(&k).unwrap().solve(&y);
            for (a, b) in krr.coefficients_for(ridge).iter().zip(direct.iter()) {
                assert!((a - b).abs() < 1e-8);
            }
        }
    }
}
