//! Full symmetric eigendecomposition `A = V Λ Vᵀ` — the batch baseline
//! the paper's incremental algorithm is measured against (§2.2), built
//! from `householder::tridiagonalize` + `tridiag::tridiag_eig`.

use super::householder::tridiagonalize;
use super::matrix::Mat;
use super::tridiag::{sort_eigenpairs, tridiag_eig};

/// Eigendecomposition result: `values` ascending, `vectors` columns are
/// the corresponding orthonormal eigenvectors.
#[derive(Clone, Debug)]
pub struct Eigh {
    pub values: Vec<f64>,
    pub vectors: Mat,
}

impl Eigh {
    /// Reconstruct `V Λ Vᵀ`.
    pub fn reconstruct(&self) -> Mat {
        let n = self.values.len();
        let mut vl = self.vectors.clone();
        for i in 0..n {
            for j in 0..n {
                vl[(i, j)] *= self.values[j];
            }
        }
        super::gemm::matmul_nt(&vl, &self.vectors)
    }
}

/// Compute all eigenvalues and eigenvectors of symmetric `a`.
/// Eigenvalues are returned in ascending order.
pub fn eigh(a: &Mat) -> Result<Eigh, String> {
    assert!(a.is_square(), "eigh needs a square matrix");
    let mut t = tridiagonalize(a);
    tridiag_eig(&mut t.d, &mut t.e, &mut t.q)?;
    sort_eigenpairs(&mut t.d, &mut t.q);
    Ok(Eigh { values: t.d, vectors: t.q })
}

/// Eigenvalues only (still O(n³) here since we reuse the same kernel,
/// but skips the final sort-permute of a separate vector matrix).
pub fn eigvalsh(a: &Mat) -> Result<Vec<f64>, String> {
    let mut t = tridiagonalize(a);
    // Accumulating into a 0-row matrix skips all eigenvector work inside
    // the QL sweep (the rotation loop runs over z.rows() == 0).
    let mut z = Mat::zeros(0, 0);
    tridiag_eig(&mut t.d, &mut t.e, &mut z)?;
    let mut vals = t.d;
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;

    fn rand_sym(n: usize, seed: u64) -> Mat {
        // xorshift-based deterministic pseudo-random symmetric matrix.
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 11) as f64 / (1u64 << 53) as f64 - 0.5
        };
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = next();
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    #[test]
    fn reconstructs_random_matrices() {
        for (n, seed) in [(3, 1u64), (7, 2), (16, 3), (33, 4)] {
            let a = rand_sym(n, seed);
            let eg = eigh(&a).unwrap();
            assert!(
                eg.reconstruct().max_abs_diff(&a) < 1e-10,
                "n={n} reconstruction failed"
            );
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = rand_sym(20, 7);
        let eg = eigh(&a).unwrap();
        let vtv = matmul(&eg.vectors.transpose(), &eg.vectors);
        assert!(vtv.max_abs_diff(&Mat::eye(20)) < 1e-11);
    }

    #[test]
    fn values_ascending() {
        let a = rand_sym(15, 11);
        let eg = eigh(&a).unwrap();
        for w in eg.values.windows(2) {
            assert!(w[0] <= w[1] + 1e-14);
        }
    }

    #[test]
    fn known_eigenvalues_projection() {
        // Rank-one projector vvᵀ with ‖v‖=1 has eigenvalues {0,…,0,1}.
        let n = 6;
        let v: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).sqrt()).collect();
        let norm = crate::linalg::matrix::norm2(&v);
        let v: Vec<f64> = v.iter().map(|x| x / norm).collect();
        let mut a = Mat::zeros(n, n);
        a.syr(1.0, &v);
        let eg = eigh(&a).unwrap();
        assert!((eg.values[n - 1] - 1.0).abs() < 1e-12);
        for k in 0..n - 1 {
            assert!(eg.values[k].abs() < 1e-12);
        }
    }

    #[test]
    fn eigvalsh_matches_eigh() {
        let a = rand_sym(12, 21);
        let vals = eigvalsh(&a).unwrap();
        let eg = eigh(&a).unwrap();
        for (u, v) in vals.iter().zip(eg.values.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_matrix_psd() {
        // AAᵀ is PSD: all eigenvalues ≥ -tol.
        let x = Mat::from_fn(9, 4, |i, j| ((i * j) as f64).sin());
        let g = crate::linalg::gemm::syrk(&x);
        let vals = eigvalsh(&g).unwrap();
        assert!(vals[0] > -1e-10);
    }
}
