//! Deterministic pseudo-random number generation (xoshiro256**) — the
//! substrate behind synthetic datasets, random subset orders in the
//! Nyström experiments and the in-tree property-test driver. No external
//! RNG crates are available offline, and determinism is a feature here:
//! every experiment in EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256** by Blackman & Vigna (public domain reference).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that similar seeds diverge immediately.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = move || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-space mean and standard deviation.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_mean_and_variance() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.05);
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(3);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
