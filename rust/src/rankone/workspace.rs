//! The reusable scratch arena for rank-one eigensystem updates. One
//! workspace per stream: every buffer a [`super::rank_one_update_ws`]
//! step needs — the projected weight vector `z`, the deflation
//! partition, the secular roots, the stabilized weights, the `W`
//! eigenvector factor and the rotated-`U` double buffer — lives here
//! and is reused across updates, so the steady-state hot path performs
//! no heap allocation (verified by the realloc counter and the
//! `tests/workspace.rs` suite; the parallel GEMM still spawns scoped
//! threads above its flop threshold).

use crate::secular::{Deflation, SecularRoot};

/// Scratch buffers for the rank-one update hot path. Construct once per
/// stream and thread through every update; capacities are retained and
/// only ever grow (doubling with the eigensystem).
#[derive(Clone, Debug, Default)]
pub struct UpdateWorkspace {
    /// `z = Uᵀv` — perturbation in the eigenbasis (length n).
    pub(crate) z: Vec<f64>,
    /// Gu–Eisenstat stabilized weights over the active set (length k).
    pub(crate) zhat: Vec<f64>,
    /// The `k × k` inner eigenvector factor `W`.
    pub(crate) w: Vec<f64>,
    /// One column of `W` during assembly (length k).
    pub(crate) col: Vec<f64>,
    /// Gathered `m × k` active eigenvector panel (deflation path only).
    pub(crate) u_active: Vec<f64>,
    /// Rotation output; doubles as the eigenbasis swap buffer on the
    /// no-deflation fast path.
    pub(crate) rotated: Vec<f64>,
    /// Row scratch for in-place column permutation (length n).
    pub(crate) scratch: Vec<f64>,
    /// Eigenvalue scratch for the sort (length n).
    pub(crate) vals_tmp: Vec<f64>,
    /// Sort permutation (length n).
    pub(crate) perm: Vec<usize>,
    /// Reusable deflation partition.
    pub(crate) def: Deflation,
    /// Reusable secular roots.
    pub(crate) roots: Vec<SecularRoot>,
    /// Buffer-growth events across all members (zero once warm).
    pub(crate) reallocs: u64,
}

impl UpdateWorkspace {
    pub fn new() -> Self {
        UpdateWorkspace::default()
    }

    /// Pre-size every buffer for eigensystems up to `m` rows × `n`
    /// eigenpairs, *without* counting toward the realloc counter — the
    /// warm-up entry point for latency-critical streams.
    pub fn reserve(&mut self, m: usize, n: usize) {
        fn grow<T>(v: &mut Vec<T>, cap: usize) {
            if v.capacity() < cap {
                v.reserve(cap - v.len());
            }
        }
        grow(&mut self.z, n);
        grow(&mut self.zhat, n);
        grow(&mut self.w, n * n);
        grow(&mut self.col, n);
        grow(&mut self.u_active, m * n);
        grow(&mut self.rotated, m * n);
        grow(&mut self.scratch, n);
        grow(&mut self.vals_tmp, n);
        grow(&mut self.perm, n);
        grow(&mut self.roots, n);
        grow(&mut self.def.active, n);
        grow(&mut self.def.deflated, n);
        grow(&mut self.def.d_active, n);
        grow(&mut self.def.z_active, n);
    }

    /// Buffer-growth events since construction. Constant across updates
    /// once the workspace is warm — the zero-allocation guarantee the
    /// steady-state test pins down.
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }

    /// Bytes currently held across all scratch buffers.
    pub fn bytes_resident(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let u = std::mem::size_of::<usize>();
        let r = std::mem::size_of::<SecularRoot>();
        f * (self.z.capacity()
            + self.zhat.capacity()
            + self.w.capacity()
            + self.col.capacity()
            + self.u_active.capacity()
            + self.rotated.capacity()
            + self.scratch.capacity()
            + self.vals_tmp.capacity()
            + self.def.d_active.capacity()
            + self.def.z_active.capacity())
            + u * (self.perm.capacity()
                + self.def.active.capacity()
                + self.def.deflated.capacity())
            + r * self.roots.capacity()
    }
}

/// Resize `buf` to `len`, counting a realloc only when capacity grows.
/// Retained elements keep their previous (stale) values — every
/// consumer fully overwrites its window, so no full-buffer memset is
/// paid on the hot path; only growth zero-fills the tail.
pub(crate) fn ensure_f64(buf: &mut Vec<f64>, len: usize, reallocs: &mut u64) {
    if len > buf.capacity() {
        *reallocs += 1;
    }
    buf.resize(len, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_counts_only_capacity_growth() {
        let mut buf = Vec::new();
        let mut r = 0u64;
        ensure_f64(&mut buf, 8, &mut r);
        assert_eq!(r, 1);
        assert_eq!(buf.len(), 8);
        ensure_f64(&mut buf, 4, &mut r);
        ensure_f64(&mut buf, 8, &mut r);
        assert_eq!(r, 1, "shrink/regrow within capacity must be free");
        ensure_f64(&mut buf, 16, &mut r);
        assert_eq!(r, 2);
    }

    #[test]
    fn reserve_is_invisible_to_the_counter() {
        let mut ws = UpdateWorkspace::new();
        ws.reserve(32, 32);
        assert_eq!(ws.reallocs(), 0);
        assert!(ws.bytes_resident() > 0);
        let mut r = ws.reallocs;
        ensure_f64(&mut ws.z, 32, &mut r);
        assert_eq!(r, 0, "reserved buffer must absorb ensure() without realloc");
    }
}
