//! Long-stream drift study (the live version of Fig. 1): run the
//! mean-adjusted Algorithm 2 in both numerical variants — the paper's
//! literal re-centering split and our norm-balanced + Gu–Eisenstat
//! stabilized default — alongside the unadjusted Algorithm 1, and
//! report reconstruction drift and eigenvector orthogonality.
//!
//! The paper's §5.1 observation (mean-adjusted drifts visibly more, four
//! updates per step) reproduces with `naive_recenter_split = true`; the
//! stabilized default removes the gap entirely (EXPERIMENTS.md §F1).
//!
//!     cargo run --release --example drift_monitor

use inkpca::data::load;
use inkpca::kernels::{median_heuristic, Rbf};
use inkpca::kpca::IncrementalKpca;
use inkpca::linalg::{orthogonality_defect, sym_norms};

fn main() -> Result<(), String> {
    let mut ds = load("magic", 240, 3)?;
    ds.standardize();
    let sigma = median_heuristic(&ds.x, 200);
    let kern = Rbf { sigma };
    let seed = ds.x.submatrix(20, ds.dim());

    let mut stabilized = IncrementalKpca::from_batch(&kern, &seed, true)?;
    let mut paper_split = IncrementalKpca::from_batch(&kern, &seed, true)?;
    paper_split.naive_recenter_split = true;
    let mut unadjusted = IncrementalKpca::from_batch(&kern, &seed, false)?;

    println!(
        "{:>5} | {:>12} {:>12} | {:>12} {:>12} | {:>12}",
        "m", "adj-stab fro", "‖UUᵀ−I‖", "adj-paper fro", "‖UUᵀ−I‖", "unadj fro"
    );
    for i in 20..ds.n() {
        stabilized.push(ds.x.row(i))?;
        paper_split.push(ds.x.row(i))?;
        unadjusted.push(ds.x.row(i))?;
        if (i + 1) % 40 == 0 {
            let dstab = sym_norms(&stabilized.reconstruct().sub(&stabilized.batch_reference()));
            let dpap = sym_norms(&paper_split.reconstruct().sub(&paper_split.batch_reference()));
            let dun = sym_norms(&unadjusted.reconstruct().sub(&unadjusted.batch_reference()));
            println!(
                "{:>5} | {:>12.3e} {:>12.3e} | {:>12.3e} {:>12.3e} | {:>12.3e}",
                i + 1,
                dstab.frobenius,
                orthogonality_defect(&stabilized.vecs),
                dpap.frobenius,
                orthogonality_defect(&paper_split.vecs),
                dun.frobenius,
            );
        }
    }
    let dstab = sym_norms(&stabilized.reconstruct().sub(&stabilized.batch_reference()));
    let dpap = sym_norms(&paper_split.reconstruct().sub(&paper_split.batch_reference()));
    let dun = sym_norms(&unadjusted.reconstruct().sub(&unadjusted.batch_reference()));
    println!(
        "\nfinal drift: stabilized {:.3e} | paper-split {:.3e} | unadjusted {:.3e}",
        dstab.frobenius, dpap.frobenius, dun.frobenius
    );
    println!(
        "excluded examples: stabilized {} paper-split {} unadjusted {}",
        stabilized.stats.excluded, paper_split.stats.excluded, unadjusted.stats.excluded
    );
    // Acceptance: the paper-split reproduces the paper's §5.1 drift gap;
    // the stabilized default keeps the adjusted drift at unadjusted
    // levels or better.
    assert!(dun.frobenius < 1e-9, "unadjusted drift out of range");
    assert!(dstab.frobenius < 1e-10, "stabilized drift out of range");
    assert!(dpap.frobenius > dstab.frobenius, "paper split should drift more");
    println!("drift_monitor OK");
    Ok(())
}
