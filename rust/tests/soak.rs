//! Long-stream soak: a bounded stream at fixed m must be genuinely
//! bounded — 10⁵ points through a capped landmark set with ZERO
//! hot-path reallocations and flat resident bytes once warm, while the
//! eigensystem keeps tracking its batch ground truth. `#[ignore]`d: run
//! in release via `cargo test --release --test soak -- --ignored`
//! (CI's soak job does).

mod common;

use common::oracle;
use inkpca::kernels::Rbf;
use inkpca::kpca::{EvictionPolicy, IncrementalKpca};

#[test]
#[ignore = "long-stream soak: ~10⁵ points, run in release with --ignored"]
fn bounded_stream_soak_zero_realloc_flat_memory() {
    const N: usize = 100_000;
    const CAP: usize = 64;
    const PROTECTED: usize = 8;
    const BATCH: usize = 32;
    const WARM: usize = 2_048; // past the cap, policy + scratch all hot

    let ds = oracle::std_stream(N, 7001);
    let dim = ds.dim();
    let flat = ds.x.as_slice();
    let kern = Rbf { sigma: 2.0 };
    let seed = ds.x.submatrix(PROTECTED, dim);
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
    // The production default for a capped stream: leverage-score
    // victims. m transiently reaches CAP+1 before each eviction lands,
    // so the fixed-size reservation is one row wider than the cap.
    inc.set_bound(CAP, EvictionPolicy::LeverageScore, PROTECTED);
    inc.reserve(CAP + 1, BATCH);

    // Warm-up: fill to the cap and push well past it so every buffer —
    // workspace, basis, batch scratch, leverage scratch — has seen its
    // steady-state shape.
    let mut i = PROTECTED;
    while i < WARM {
        let end = (i + BATCH).min(WARM);
        inc.push_batch(&flat[i * dim..end * dim]).unwrap();
        i = end;
    }
    assert_eq!(inc.len(), CAP, "warm-up must fill the cap");
    assert!(inc.evictions() > 0, "warm-up must already be evicting");

    let ws_reallocs0 = inc.hot_path_reallocs();
    let batch_reallocs0 = inc.batch_reallocs();
    let bytes0 = inc.hot_path_bytes();
    let evictions0 = inc.evictions();

    // The soak: ~98k more points at fixed m. Every accepted point
    // evicts exactly one landmark; nothing may grow.
    let mut accepted = 0usize;
    while i < N {
        let end = (i + BATCH).min(N);
        let out = inc.push_batch(&flat[i * dim..end * dim]).unwrap();
        accepted += out.accepted;
        assert!(inc.len() <= CAP, "cap breached at point {i}");
        i = end;
    }

    assert_eq!(inc.len(), CAP);
    assert_eq!(
        inc.hot_path_reallocs(),
        ws_reallocs0,
        "workspace/basis reallocated during the soak"
    );
    assert_eq!(
        inc.batch_reallocs(),
        batch_reallocs0,
        "batch scratch reallocated during the soak"
    );
    assert_eq!(
        inc.hot_path_bytes(),
        bytes0,
        "resident hot-path bytes must stay flat at fixed m"
    );
    assert_eq!(
        inc.evictions(),
        evictions0 + accepted,
        "one eviction per over-cap accept"
    );

    // Protected seed prefix survived 10⁵ points of churn.
    for p in 0..PROTECTED {
        assert_eq!(inc.row(p), ds.x.row(p), "protected row {p} evicted");
    }

    // The eigensystem still tracks a from-scratch batch recompute over
    // the surviving landmarks. The bar is a loose backstop — ~10⁵
    // down-dates accumulate rounding — but it rules out systematic
    // divergence (tracked values are O(1) for RBF).
    let gap = oracle::kpca_oracle_gap(&kern, &inc);
    assert!(gap < 1e-3, "soak drifted from batch ground truth: {gap}");
    let s = inc.sufficiency_gap();
    assert!((0.0..=1.0).contains(&s), "sufficiency gauge {s}");
}
