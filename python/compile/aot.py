"""AOT lowering: jax (L2+L1) -> HLO text -> artifacts/ for the rust
runtime.

HLO *text* is the interchange format, not serialized protos: jax >= 0.5
emits 64-bit instruction ids that the xla crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Each function is lowered at every size of the bucket ladder; the rust
runtime picks the smallest bucket >= the live problem size and pads
(runtime::pad contract). A TSV manifest indexes the artifacts (the
offline image has no JSON crate on the rust side).

Usage: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Bucket ladder for the eigensystem order m (and Gram size n). Chosen to
# cover the paper's experiment range (m0=20 ... ~1000) with <= 2x padding
# waste at any size.
BUCKETS = [64, 128, 256, 512, 1024]
# Feature dimension is padded to a single bucket: zero-padded features
# leave RBF distances unchanged.
DIM = 16
DTYPE = jnp.float64


def to_hlo_text(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, DTYPE)


def artifact_set():
    """(name, kind, m, path-suffix, fn, arg specs) for every artifact."""
    out = []
    for m in BUCKETS:
        out.append(
            (
                f"kernel_column_{m}",
                "kernel_column",
                m,
                lambda m=m: (model.kernel_column, [spec((m, DIM)), spec((DIM,)), spec(())]),
            )
        )
        out.append(
            (
                f"eigvec_update_{m}",
                "eigvec_update",
                m,
                lambda m=m: (
                    model.eigvec_update,
                    [spec((m, m)), spec((m,)), spec((m,)), spec((m,))],
                ),
            )
        )
        out.append(
            (
                f"gram_{m}",
                "gram",
                m,
                lambda m=m: (model.gram, [spec((m, DIM)), spec(())]),
            )
        )
        out.append(
            (
                f"nystrom_reconstruct_{m}",
                "nystrom_reconstruct",
                m,
                # n is fixed at the largest bucket; m varies.
                lambda m=m: (
                    model.nystrom_reconstruct,
                    [spec((BUCKETS[-1], m)), spec((m, m)), spec((m,))],
                ),
            )
        )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default=None,
        help="comma-separated bucket override (smoke tests use e.g. 64,128)",
    )
    args = ap.parse_args()
    global BUCKETS
    if args.buckets:
        BUCKETS = [int(b) for b in args.buckets.split(",")]
    os.makedirs(args.out, exist_ok=True)
    manifest_rows = []
    for name, kind, m, build in artifact_set():
        fn, specs = build()
        text = to_hlo_text(fn, *specs)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out, path), "w") as f:
            f.write(text)
        manifest_rows.append(f"{name}\t{kind}\t{m}\t{DIM}\t{path}")
        print(f"lowered {name:<28} {len(text):>9} chars")
    with open(os.path.join(args.out, "manifest.tsv"), "w") as f:
        f.write("# name\tkind\tm\tdim\tpath\n")
        f.write("\n".join(manifest_rows) + "\n")
    # manifest.json is the Makefile's freshness stamp; keep both names.
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        rows = ",\n".join(
            '  {"name": "%s", "kind": "%s", "m": %s, "dim": %s, "path": "%s"}'
            % tuple(r.split("\t"))
            for r in manifest_rows
        )
        f.write("[\n" + rows + "\n]\n")
    print(f"wrote {len(manifest_rows)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
