"""L1 Pallas kernels for RBF kernel evaluation.

TPU mapping (DESIGN.md §Hardware-Adaptation): the pairwise term is
expressed through the inner-product form ||x||^2 + ||y||^2 - 2<x, y> so
the dominant work is a matmul that lands on the MXU; tiles are sized so
one (BM, D) panel of x plus the (BM, BN) output block sit comfortably in
VMEM. On this CPU image the kernels run under interpret=True (the CPU
PJRT plugin cannot execute Mosaic custom-calls), so tiling here encodes
the *schedule*, not measured wall-clock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height for the column kernel; multiples of the 8-lane sublane
# work well on both the interpreter and real hardware.
BLOCK_M = 128
# Tile edge for the Gram kernel.
BLOCK_G = 128


def _rbf_column_kernel(x_ref, y_ref, sig_ref, o_ref):
    """One (BLOCK_M, d) row-panel: squared distance to y, then exp."""
    x = x_ref[...]
    y = y_ref[...]
    diff = x - y[None, :]
    d2 = jnp.sum(diff * diff, axis=1)
    o_ref[...] = jnp.exp(-d2 / sig_ref[0])


@functools.partial(jax.jit, static_argnames=("block_m",))
def rbf_column(x, y, sigma, block_m=BLOCK_M):
    """Pallas RBF column: a[i] = exp(-||x_i - y||^2 / sigma).

    `x.shape[0]` must be a multiple of `block_m` (the AOT bucket ladder
    guarantees this; callers pad with zero rows and slice the result).
    """
    m, d = x.shape
    block_m = min(block_m, m)
    assert m % block_m == 0, f"m={m} not a multiple of block_m={block_m}"
    sig = jnp.asarray(sigma, x.dtype).reshape((1,))
    return pl.pallas_call(
        _rbf_column_kernel,
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(x, y, sig)


def _rbf_gram_kernel(xi_ref, xj_ref, sig_ref, o_ref):
    """One (BG, BG) Gram tile via the MXU-friendly inner-product form."""
    xi = xi_ref[...]
    xj = xj_ref[...]
    sq_i = jnp.sum(xi * xi, axis=1)
    sq_j = jnp.sum(xj * xj, axis=1)
    cross = jnp.dot(xi, xj.T)  # the MXU matmul
    d2 = jnp.maximum(sq_i[:, None] + sq_j[None, :] - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-d2 / sig_ref[0])


@functools.partial(jax.jit, static_argnames=("block",))
def rbf_gram(x, sigma, block=BLOCK_G):
    """Pallas tiled RBF Gram matrix over the rows of x.

    `x.shape[0]` must be a multiple of `block`.
    """
    n, d = x.shape
    block = min(block, n)
    assert n % block == 0, f"n={n} not a multiple of block={block}"
    sig = jnp.asarray(sigma, x.dtype).reshape((1,))
    return pl.pallas_call(
        _rbf_gram_kernel,
        grid=(n // block, n // block),
        in_specs=[
            pl.BlockSpec((block, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), x.dtype),
        interpret=True,
    )(x, x, sig)
