//! # inkpca — Incremental kernel PCA and the Nyström method
//!
//! A three-layer Rust + JAX + Pallas reproduction of Hallgren &
//! Northrop, *"Incremental kernel PCA and the Nyström method"*
//! (stat.ML 2018), grown toward a production streaming system.
//!
//! **`ARCHITECTURE.md` at the repository root is the companion map**:
//! paper section → module, the data flow of one batched ingest through
//! the shard pool, and the blocked rank-b rotation decision rule. Start
//! there when orienting; the module docs below carry the details.
//!
//! ## Layers
//!
//! - **Layer 3** ([`coordinator`]) — a *sharded multi-stream* engine:
//!   a [`coordinator::ShardPool`] of worker threads, each owning
//!   slot-indexed per-stream state (incremental eigensystem + update
//!   workspace + eigenbasis + drift monitor + metrics), fronted by a
//!   stream-keyed [`coordinator::StreamRouter`] over per-shard bounded
//!   channels. Streams are placed on a consistent-hash ring
//!   ([`coordinator::HashRing`]: FNV-1a keyed, deterministic across
//!   processes), resolved *once* at `open_stream` into a cheap
//!   [`coordinator::StreamHandle`] (shard + integer slot + generation)
//!   — the ingest path carries no `String` and does no map lookup. The
//!   topology is *elastic*: `add_shard`/`remove_shard`/`rebalance`
//!   migrate live streams between workers (the entry is `Send`) behind
//!   a queue-drain barrier, under bumped generations, with stale
//!   handles re-routed through a redirect table — the pool grows and
//!   shrinks under load without restarting a stream.
//!   Three ingest shapes share the per-shard queues: rendezvous
//!   `ingest`, fire-and-forget `ingest_async` (errors deferred to a
//!   per-stream counter, drained by `sync`), and batched `ingest_many`
//!   (one command per batch; the worker computes the batch's kernel
//!   rows as one blocked GEMM through
//!   [`kpca::IncrementalKpca::push_batch_with`]). Backpressure and
//!   queue contention stay per shard; each shard shares one rotation
//!   engine (and one PJRT runtime) across its streams, and the pool
//!   rolls per-stream metrics up into a
//!   [`coordinator::PoolSnapshot`]. Reads take a *lock-free* path:
//!   each worker publishes an immutable
//!   [`coordinator::ProjectionSnapshot`] per stream through an
//!   epoch-swapped [`coordinator::SnapshotCell`], and
//!   `project_snapshot`/`project_many` serve projections (the b×m
//!   kernel block + one GEMM against the snapshot basis, zero-alloc
//!   with a per-reader [`coordinator::ProjectScratch`]) without
//!   enqueueing a single shard command — read throughput scales with
//!   reader cores, not shard count. The historical single-stream
//!   [`coordinator::Coordinator`] survives as a thin wrapper over a
//!   1-shard pool.
//! - **Layer 2/1** — JAX model + Pallas kernels (build-time Python),
//!   AOT-lowered to HLO text and executed from Rust via PJRT
//!   ([`runtime`]; compiled under `--cfg pjrt_runtime`, with a clean
//!   native fallback stub otherwise).
//! - The paper's algorithms live in [`kpca`] (Algorithms 1 & 2),
//!   [`rankone`]/[`secular`] (the Golub-73 / Bunch–Nielsen–Sorensen-78
//!   rank-one eigen update) and [`nystrom`] (§4 incremental Nyström —
//!   both the eigen path and the Rudi-15 Cholesky baseline now grow by
//!   amortized appends, never re-layouting per added point), with
//!   baselines in [`baselines`] and all dense linear algebra built from
//!   scratch in [`linalg`].
//!
//! ## Multi-stream ownership
//!
//! Per-stream state owns its kernel through an
//! `Arc` ([`kpca::IncrementalKpca::from_batch_shared`]) — closing a
//! stream frees everything it held; nothing is leaked per stream.
//! Mean-adjusted projection reuses the incrementally maintained
//! centering sums (`Σₘ`, `Kₘ𝟙`), making scoring `O(m·r)` per query
//! with no Gram recomputation.
//!
//! ## The zero-allocation streaming hot path
//!
//! The point of rank-one updates is that streaming is cheaper than
//! re-solving — so the steady-state update loop must not pay the
//! allocator either. Three pieces make the hot path allocation-free
//! once warm:
//!
//! - **Views** ([`linalg::MatView`]/[`linalg::MatViewMut`]): shape +
//!   row-stride windows over borrowed `&[f64]`. Every BLAS kernel has a
//!   `*_into` variant (`matmul_into`, `gemv_t_into`, …) writing into
//!   caller-owned, possibly strided buffers; the allocating entry
//!   points are thin wrappers accepting anything viewable (`&Mat`,
//!   `MatView`, `&EigenBasis`).
//! - **[`rankone::EigenBasis`]**: capacity-doubling eigenvector storage
//!   (rows kept at a fixed stride inside a `row_cap × stride` buffer).
//!   The per-example expansion by one row + one column is an in-place
//!   `O(m)` write instead of a full `O(m²)` re-layout; reallocation is
//!   amortized `O(1)` by doubling.
//! - **[`rankone::UpdateWorkspace`]**: one scratch arena per stream
//!   owning every intermediate a rank-one step needs — `z = Uᵀv`, the
//!   deflation partition, secular roots, stabilized weights, the `W`
//!   factor, and the rotated-`U` double buffer that commits the
//!   no-deflation fast path by an `O(1)` buffer swap. A realloc counter
//!   proves steady-state silence (`tests/workspace.rs`), and the
//!   coordinator surfaces bytes-resident / reallocs-per-update gauges
//!   per stream.
//!
//! The workspace threads from [`linalg`] through [`rankone`] (the
//! [`rankone::Rotate`] engines now rotate *into* caller buffers, fused
//! or W-form), [`kpca::IncrementalKpca`] (2 updates per example
//! unadjusted, 4 adjusted — one shared workspace), the top-`r` trackers
//! and [`baselines`], [`nystrom::IncrementalNystrom`] (whose cross-Gram
//! appends rows in amortized `O(n)`) and the packed
//! [`linalg::PackedCholesky`] factor under
//! [`nystrom::CholeskyNystrom`], up to [`coordinator::shard`] (one
//! workspace per stream entry; per-stream gauges and pool rollups in
//! [`coordinator::metrics`]). Because the steady state is
//! allocation-free, N streams on one shard contend only on the shard's
//! queue — which is what makes the shard pool scale.
//!
//! ## Batched ingest
//!
//! The rank-one update makes each ingest cheap, so at modest `m` the
//! *per-point* costs around the update — channel rendezvous, command
//! allocation, the `m`-long scalar kernel loop — rival the math.
//! Batching removes them without changing the math: a batch of `b`
//! points computes its `b × m` kernel rows (plus the `b × b`
//! intra-batch block) as one blocked GEMM for dot-product-family
//! kernels ([`kernels::kernel_rows_into`]; RBF goes through the
//! row-norm identity `‖x−y‖² = ‖x‖² − 2⟨x,y⟩ + ‖y‖²`, anything else
//! falls back to scalar evaluation), then applies the `b` rank-one
//! update sequences back to back — the identical update algorithm,
//! with batched ≡ sequential equivalence ≤1e-10 pinned by
//! `tests/batching.rs`. The same
//! entry point serves [`nystrom::IncrementalNystrom::add_points`] (the
//! `K_{m,n}` rows of all accepted points are one `b × n` block) and the
//! labelled [`kpca::IncrementalKrr::push_batch`]; KRR refits follow the
//! cached discipline too — `fitted` is `U Λ (Λ+λI)⁻¹ Uᵀ y` off the
//! tracked eigensystem, zero kernel evaluations per refit.
//!
//! ## The blocked rank-b eigen-update
//!
//! Batching the kernel evaluation left one per-point cost: each
//! rank-one update still paid its own `2m³` back-rotation GEMM. The
//! blocked path ([`rankone::rank_one_update_fused_ws`]) removes it: a
//! clean update's rotation factor `W` depends only on the spectrum and
//! on `z = Uᵀv`, so a batch's factors fold into one pending product
//! `Q = W₁·…·W_j` in workspace scratch (eigenvalues advance per update;
//! the next `z` is `Qᵀ(Uᵀv)`; expansions embed as `diag(Q, 1)` plus a
//! column permutation of `Q`), and [`rankone::flush_rotation_ws`]
//! applies `U ← U·Q` as **one** engine GEMM per batch. Updates that
//! would deflate — screened in `O(n)` by [`secular::is_clean`] — flush
//! and run sequentially, so fused ≡ sequential to rounding; the
//! [`kpca::BatchRotation`] strategy (auto: fused for `b ≥ 2`) selects
//! per batch, and `UpdateWorkspace::engine_gemms` / the coordinator's
//! `engine_gemms` gauges expose the amortization (the `e2e_shards`
//! bench carries a forced fused-vs-sequential series).

// The numeric kernels are written index-style on purpose (they mirror
// the paper's equations and the blocked-GEMM literature); clippy's
// iterator-style suggestions hurt readability there. `Mat::add`/`sub`
// are deliberate inherent methods (operator impls would force owned
// receivers or double-reference noise everywhere).
#![allow(clippy::needless_range_loop, clippy::should_implement_trait)]

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kernels;
pub mod kpca;
pub mod linalg;
pub mod nystrom;
pub mod rankone;
pub mod rff;
pub mod runtime;
pub mod secular;
pub mod util;
