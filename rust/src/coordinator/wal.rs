//! Write-ahead ingest log: the replay half of the durability story.
//!
//! Each shard worker owns one append-only log file (`wal-<shard>.log`).
//! Every state-changing command that passes validation — stream open,
//! accepted single/batched ingest, stream close — is framed and
//! appended *before* it is applied, so after a crash the pool can be
//! rebuilt as "latest checkpoint + replay of the WAL suffix" (see
//! [`super::persist`] for checkpoints and
//! [`super::shard::StreamRouter::restore_pool`] for the recovery
//! ladder).
//!
//! Frame format (all integers little-endian):
//!
//! ```text
//! file   := MAGIC(8) frame*
//! frame  := len:u32  crc:u32  payload[len]      crc = CRC32(payload)
//! ```
//!
//! The reader validates frames in order and stops at the first bad one
//! (short header, impossible length, CRC mismatch): a torn tail — the
//! expected artifact of crashing mid-append — costs only the torn
//! record, never the file. [`WalWriter::open`] repairs the tail the
//! same way (truncate to the valid prefix) before appending, so a
//! recovered log never grows records *behind* a tear.
//!
//! Durability is tunable per deployment via [`FsyncPolicy`]: fsync
//! every N appends, on a wall-clock interval, or never (leave it to the
//! OS). Append failures never take the stream down: a bounded
//! retry-with-backoff runs first, and only then does the writer drop to
//! *degraded* mode — appends are skipped (the stream stays live
//! in-memory, `wal_errors` visible in the pool snapshot) until the next
//! checkpoint rotation re-arms the log.
//!
//! The append path is allocation-free in steady state: one reusable
//! frame buffer, with its own realloc counter so the zero-allocation
//! claim is testable rather than aspirational.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Leading bytes of every WAL file (name + format version).
pub const WAL_MAGIC: &[u8; 8] = b"IKWAL001";

// ---------------------------------------------------------------------
// CRC32 (IEEE reflected polynomial), table built at compile time — no
// external crates are available offline.
// ---------------------------------------------------------------------

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = build_crc_table();

/// CRC32 (IEEE) of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// Little-endian byte codec helpers, shared with the checkpoint codec in
// `super::persist`.
// ---------------------------------------------------------------------

pub(crate) fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// `u32` length prefix + UTF-8 bytes.
pub(crate) fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// `u64` element count + raw little-endian doubles.
pub(crate) fn put_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    put_u64(buf, xs.len() as u64);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounded cursor over a decoded payload. Every `take_*` checks the
/// remaining length and returns `Err` instead of panicking — the
/// property the corruption corpus pins.
pub(crate) struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Cur { buf, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!("short payload: need {n} bytes, have {}", self.remaining()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub(crate) fn take_u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn take_u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn take_u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn take_f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn take_str(&mut self) -> Result<String, String> {
        let n = self.take_u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| format!("bad utf8: {e}"))
    }

    pub(crate) fn take_bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.take_u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    pub(crate) fn take_f64s(&mut self) -> Result<Vec<f64>, String> {
        let n = self.take_u64()? as usize;
        // Guard before allocating: a corrupt count must not trigger an
        // absurd reservation.
        if self.remaining() < n.saturating_mul(8) {
            return Err(format!("short f64 run: need {n} values, have {} bytes", self.remaining()));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.take_f64()?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------

/// One logged event. `cfg` in `Open` is the opaque
/// [`StreamConfig`](super::shard::StreamConfig) encoding produced by
/// `super::persist` — the WAL layer frames bytes, it does not interpret
/// stream configuration.
#[derive(Clone, Debug, PartialEq)]
pub enum WalRecord {
    /// A stream was opened (before any checkpoint could exist) — lets
    /// recovery rebuild streams that died mid-seed.
    Open { id: String, dim: u32, cfg: Vec<u8> },
    /// Accepted ingest command: one or more `dim`-dimensional points,
    /// stamped with the stream's monotonic per-record sequence number
    /// (travels with the entry across migrations, so replay order is
    /// well defined even when a stream's records span shard logs).
    Ingest { id: String, seq: u64, dim: u32, points: Vec<f64> },
    /// The stream was closed — recovery must not resurrect it.
    Close { id: String },
}

const KIND_OPEN: u8 = 1;
const KIND_INGEST: u8 = 2;
const KIND_CLOSE: u8 = 3;

impl WalRecord {
    pub fn stream_id(&self) -> &str {
        match self {
            WalRecord::Open { id, .. }
            | WalRecord::Ingest { id, .. }
            | WalRecord::Close { id } => id,
        }
    }

    /// Encode the record payload (no frame header) into `buf`.
    pub fn encode_into(&self, buf: &mut Vec<u8>) {
        match self {
            WalRecord::Open { id, dim, cfg } => {
                put_u8(buf, KIND_OPEN);
                put_str(buf, id);
                put_u32(buf, *dim);
                put_u32(buf, cfg.len() as u32);
                buf.extend_from_slice(cfg);
            }
            WalRecord::Ingest { id, seq, dim, points } => {
                put_u8(buf, KIND_INGEST);
                put_str(buf, id);
                put_u64(buf, *seq);
                put_u32(buf, *dim);
                put_f64s(buf, points);
            }
            WalRecord::Close { id } => {
                put_u8(buf, KIND_CLOSE);
                put_str(buf, id);
            }
        }
    }

    /// Decode a record payload. Never panics on malformed input.
    pub fn decode(payload: &[u8]) -> Result<WalRecord, String> {
        let mut c = Cur::new(payload);
        let rec = match c.take_u8()? {
            KIND_OPEN => WalRecord::Open {
                id: c.take_str()?,
                dim: c.take_u32()?,
                cfg: c.take_bytes()?,
            },
            KIND_INGEST => WalRecord::Ingest {
                id: c.take_str()?,
                seq: c.take_u64()?,
                dim: c.take_u32()?,
                points: c.take_f64s()?,
            },
            KIND_CLOSE => WalRecord::Close { id: c.take_str()? },
            k => return Err(format!("unknown WAL record kind {k}")),
        };
        if c.remaining() != 0 {
            return Err(format!("{} trailing bytes after record", c.remaining()));
        }
        Ok(rec)
    }
}

// ---------------------------------------------------------------------
// Fsync policy
// ---------------------------------------------------------------------

/// When the writer flushes appended frames to stable storage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// Fsync after every `n` appends (`n = 1` is sync-every-append).
    EveryN(u64),
    /// Fsync when at least this much wall time has passed since the
    /// last flush (checked on append — an idle log does not wake up).
    Interval(Duration),
    /// Never fsync explicitly; the page cache decides. One crash's
    /// worth of tail may be lost, which recovery already tolerates.
    #[default]
    Off,
}

impl FsyncPolicy {
    /// Parse the CLI form: `off`, `every=N`, or `interval_ms=M`.
    pub fn parse(s: &str) -> Result<FsyncPolicy, String> {
        if s == "off" {
            return Ok(FsyncPolicy::Off);
        }
        if let Some(n) = s.strip_prefix("every=") {
            let n: u64 = n.parse().map_err(|_| format!("bad fsync count '{n}'"))?;
            if n == 0 {
                return Err("fsync every=0 is meaningless; use 'off'".into());
            }
            return Ok(FsyncPolicy::EveryN(n));
        }
        if let Some(ms) = s.strip_prefix("interval_ms=") {
            let ms: u64 = ms.parse().map_err(|_| format!("bad fsync interval '{ms}'"))?;
            return Ok(FsyncPolicy::Interval(Duration::from_millis(ms)));
        }
        Err(format!("unknown fsync policy '{s}' (expected off | every=N | interval_ms=M)"))
    }
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

/// Append attempts before the writer gives up and degrades.
const APPEND_TRIES: u32 = 3;
/// Backoff between retries (bounded — an ingest worker must not stall
/// behind a dead disk for long).
const RETRY_BACKOFF: [Duration; 2] = [Duration::from_millis(1), Duration::from_millis(5)];

/// Appending half of the log. One per shard worker; not thread-safe by
/// design (the owning worker is the only writer).
#[derive(Debug)]
pub struct WalWriter {
    path: PathBuf,
    file: Option<File>,
    policy: FsyncPolicy,
    /// Reusable frame buffer: `[len|crc|payload]` assembled in place.
    frame: Vec<u8>,
    reallocs: u64,
    appends: u64,
    bytes: u64,
    errors: u64,
    since_sync: u64,
    last_sync: Instant,
    degraded: bool,
}

impl WalWriter {
    /// Open (or create) the log at `path`. An existing file is scanned
    /// and truncated to its valid frame prefix first — appending after
    /// a torn tail would hide every later record from the reader.
    pub fn open(path: PathBuf, policy: FsyncPolicy) -> std::io::Result<WalWriter> {
        let file = match OpenOptions::new().read(true).write(true).open(&path) {
            Ok(mut f) => {
                let mut bytes = Vec::new();
                f.read_to_end(&mut bytes)?;
                let valid = scan_valid_len(&bytes);
                if valid < WAL_MAGIC.len() as u64 {
                    // Missing/garbled header: start the file over.
                    drop(f);
                    Self::create_fresh(&path)?
                } else {
                    f.set_len(valid)?;
                    f.seek(SeekFrom::End(0))?;
                    f
                }
            }
            Err(_) => Self::create_fresh(&path)?,
        };
        Ok(WalWriter {
            path,
            file: Some(file),
            policy,
            frame: Vec::new(),
            reallocs: 0,
            appends: 0,
            bytes: 0,
            errors: 0,
            since_sync: 0,
            last_sync: Instant::now(),
            degraded: false,
        })
    }

    fn create_fresh(path: &Path) -> std::io::Result<File> {
        let mut f =
            OpenOptions::new().read(true).write(true).create(true).truncate(true).open(path)?;
        f.write_all(WAL_MAGIC)?;
        Ok(f)
    }

    /// Append one record. Returns the framed byte count on success,
    /// `None` when the record was not persisted (degraded mode, or all
    /// retries failed — the caller's stream stays live in-memory
    /// either way). The frame buffer is retained across calls; steady
    /// state appends allocate nothing.
    pub fn append(&mut self, rec: &WalRecord) -> Option<u64> {
        if self.degraded {
            return None;
        }
        let cap = self.frame.capacity();
        self.frame.clear();
        // Reserve the 8-byte frame header, encode the payload behind
        // it, then patch len/crc — one buffer, one write syscall.
        self.frame.extend_from_slice(&[0u8; 8]);
        rec.encode_into(&mut self.frame);
        let payload_len = (self.frame.len() - 8) as u32;
        let crc = crc32(&self.frame[8..]);
        self.frame[0..4].copy_from_slice(&payload_len.to_le_bytes());
        self.frame[4..8].copy_from_slice(&crc.to_le_bytes());
        if self.frame.capacity() > cap {
            self.reallocs += 1;
        }

        for attempt in 0..APPEND_TRIES {
            let ok = match self.file.as_mut() {
                Some(f) => f.write_all(&self.frame).is_ok(),
                None => false,
            };
            if ok {
                self.appends += 1;
                self.bytes += self.frame.len() as u64;
                self.since_sync += 1;
                self.maybe_sync();
                return Some(self.frame.len() as u64);
            }
            self.errors += 1;
            if (attempt as usize) < RETRY_BACKOFF.len() {
                std::thread::sleep(RETRY_BACKOFF[attempt as usize]);
            }
        }
        // Every retry failed: degrade. The stream keeps serving from
        // memory; the log re-arms at the next checkpoint rotation.
        self.degraded = true;
        None
    }

    fn maybe_sync(&mut self) {
        let due = match self.policy {
            FsyncPolicy::EveryN(n) => self.since_sync >= n,
            FsyncPolicy::Interval(d) => self.last_sync.elapsed() >= d,
            FsyncPolicy::Off => false,
        };
        if due {
            self.sync();
        }
    }

    /// Force a flush to stable storage.
    pub fn sync(&mut self) {
        if let Some(f) = self.file.as_mut() {
            if f.sync_data().is_err() {
                self.errors += 1;
            }
        }
        self.since_sync = 0;
        self.last_sync = Instant::now();
    }

    /// Truncate the log back to the bare header — called right after a
    /// whole-shard checkpoint makes the logged suffix redundant. Also
    /// re-arms a degraded writer (the rotation is its recovery retry).
    pub fn rotate(&mut self) -> std::io::Result<()> {
        self.file = None;
        let f = Self::create_fresh(&self.path)?;
        self.file = Some(f);
        self.since_sync = 0;
        self.last_sync = Instant::now();
        self.degraded = false;
        self.sync();
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Successful appends since open.
    pub fn appends(&self) -> u64 {
        self.appends
    }

    /// Framed bytes written since open.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Failed write/sync attempts since open.
    pub fn errors(&self) -> u64 {
        self.errors
    }

    /// Frame-buffer growth events (zero in steady state).
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }

    /// Whether the writer has dropped to degraded (non-logging) mode.
    pub fn degraded(&self) -> bool {
        self.degraded
    }
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

/// Outcome of scanning one WAL file.
#[derive(Debug, Default)]
pub struct WalReadResult {
    /// Records decoded from the valid prefix, in append order.
    pub records: Vec<WalRecord>,
    /// True when the file ended in a torn or corrupt tail (everything
    /// before the tear is still in `records`).
    pub torn: bool,
    /// Byte length of the valid prefix (header + whole good frames).
    pub valid_len: u64,
}

/// Byte length of the valid prefix: the magic header plus every leading
/// frame whose length fits and whose CRC matches.
pub fn scan_valid_len(bytes: &[u8]) -> u64 {
    if bytes.len() < WAL_MAGIC.len() || &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        return 0;
    }
    let mut pos = WAL_MAGIC.len();
    loop {
        if bytes.len() - pos < 8 {
            return pos as u64;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if bytes.len() - pos - 8 < len {
            return pos as u64;
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            return pos as u64;
        }
        pos += 8 + len;
    }
}

/// Read a WAL file, tolerating a torn tail. A missing file reads as
/// empty (a shard that never logged anything has nothing to replay).
pub fn read_wal(path: &Path) -> std::io::Result<WalReadResult> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(WalReadResult::default())
        }
        Err(e) => return Err(e),
    };
    Ok(decode_wal_bytes(&bytes))
}

/// Decode in-memory WAL bytes (the reader body, file-free for tests and
/// the corruption corpus).
pub fn decode_wal_bytes(bytes: &[u8]) -> WalReadResult {
    let mut out = WalReadResult::default();
    if bytes.len() < WAL_MAGIC.len() || &bytes[..WAL_MAGIC.len()] != WAL_MAGIC {
        out.torn = !bytes.is_empty();
        return out;
    }
    let mut pos = WAL_MAGIC.len();
    loop {
        if bytes.len() - pos < 8 {
            out.torn |= bytes.len() - pos != 0;
            break;
        }
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap());
        if bytes.len() - pos - 8 < len {
            out.torn = true;
            break;
        }
        let payload = &bytes[pos + 8..pos + 8 + len];
        if crc32(payload) != crc {
            out.torn = true;
            break;
        }
        match WalRecord::decode(payload) {
            Ok(rec) => out.records.push(rec),
            Err(_) => {
                // Framed correctly but semantically bad (e.g. written
                // by a future version): stop here, keep the prefix.
                out.torn = true;
                break;
            }
        }
        pos += 8 + len;
    }
    out.valid_len = pos as u64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, default_cases, ensure};
    use crate::util::rng::Rng;

    fn temp_path(tag: &str) -> PathBuf {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "inkpca_wal_{tag}_{}_{n}.log",
            std::process::id()
        ))
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord::Open { id: "s0".into(), dim: 3, cfg: vec![1, 2, 3, 4] },
            WalRecord::Ingest { id: "s0".into(), seq: 1, dim: 3, points: vec![0.5, -1.25, 3.0] },
            WalRecord::Ingest {
                id: "s0".into(),
                seq: 2,
                dim: 3,
                points: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            },
            WalRecord::Close { id: "s0".into() },
        ]
    }

    fn random_record(rng: &mut Rng) -> WalRecord {
        let id = format!("stream-{}", rng.below(1000));
        match rng.below(3) {
            0 => {
                let cfg: Vec<u8> = (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
                WalRecord::Open { id, dim: rng.below(32) as u32 + 1, cfg }
            }
            1 => {
                let dim = rng.below(8) + 1;
                let n = rng.below(5) + 1;
                let points: Vec<f64> = (0..dim * n).map(|_| rng.normal()).collect();
                WalRecord::Ingest { id, seq: rng.next_u64(), dim: dim as u32, points }
            }
            _ => WalRecord::Close { id },
        }
    }

    /// Encode records into full file bytes (header + frames).
    fn encode_file(records: &[WalRecord]) -> Vec<u8> {
        let mut bytes = WAL_MAGIC.to_vec();
        for rec in records {
            let mut payload = Vec::new();
            rec.encode_into(&mut payload);
            bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
            bytes.extend_from_slice(&payload);
        }
        bytes
    }

    #[test]
    fn crc32_known_vector() {
        // The standard IEEE CRC32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn record_roundtrip() {
        for rec in sample_records() {
            let mut payload = Vec::new();
            rec.encode_into(&mut payload);
            assert_eq!(WalRecord::decode(&payload).unwrap(), rec);
        }
    }

    #[test]
    fn fsync_policy_parses() {
        assert_eq!(FsyncPolicy::parse("off").unwrap(), FsyncPolicy::Off);
        assert_eq!(FsyncPolicy::parse("every=8").unwrap(), FsyncPolicy::EveryN(8));
        assert_eq!(
            FsyncPolicy::parse("interval_ms=250").unwrap(),
            FsyncPolicy::Interval(Duration::from_millis(250))
        );
        assert!(FsyncPolicy::parse("every=0").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
    }

    #[test]
    fn writer_reader_file_roundtrip() {
        let path = temp_path("roundtrip");
        let mut w = WalWriter::open(path.clone(), FsyncPolicy::EveryN(2)).unwrap();
        let records = sample_records();
        for rec in &records {
            assert!(w.append(rec).is_some());
        }
        assert_eq!(w.appends(), records.len() as u64);
        assert!(w.bytes() > 0);
        assert_eq!(w.errors(), 0);
        assert!(!w.degraded());
        w.sync();
        let read = read_wal(&path).unwrap();
        assert!(!read.torn);
        assert_eq!(read.records, records);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn steady_state_append_is_allocation_free() {
        let path = temp_path("zeroalloc");
        let mut w = WalWriter::open(path.clone(), FsyncPolicy::Off).unwrap();
        let rec = WalRecord::Ingest { id: "s".into(), seq: 0, dim: 4, points: vec![1.0; 4] };
        w.append(&rec).unwrap();
        let warm = w.reallocs();
        for seq in 1..200u64 {
            let rec = WalRecord::Ingest { id: "s".into(), seq, dim: 4, points: vec![1.0; 4] };
            w.append(&rec).unwrap();
        }
        assert_eq!(w.reallocs(), warm, "frame buffer must not grow after warm-up");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rotate_truncates_and_rearms() {
        let path = temp_path("rotate");
        let mut w = WalWriter::open(path.clone(), FsyncPolicy::Off).unwrap();
        for rec in sample_records() {
            w.append(&rec);
        }
        w.rotate().unwrap();
        let read = read_wal(&path).unwrap();
        assert!(read.records.is_empty());
        assert!(!read.torn);
        // Appends after rotation land in the fresh file.
        w.append(&WalRecord::Close { id: "x".into() });
        let read = read_wal(&path).unwrap();
        assert_eq!(read.records.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn open_repairs_torn_tail_before_appending() {
        let path = temp_path("repair");
        let records = sample_records();
        let mut bytes = encode_file(&records);
        // Tear mid-way through the final frame.
        let cut = bytes.len() - 3;
        bytes.truncate(cut);
        std::fs::write(&path, &bytes).unwrap();
        let mut w = WalWriter::open(path.clone(), FsyncPolicy::Off).unwrap();
        w.append(&WalRecord::Close { id: "post".into() }).unwrap();
        w.sync();
        let read = read_wal(&path).unwrap();
        assert!(!read.torn, "tail must be repaired at open");
        assert_eq!(read.records.len(), records.len()); // 3 survivors + 1 new
        assert_eq!(read.records.last().unwrap(), &WalRecord::Close { id: "post".into() });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_reads_empty() {
        let res = read_wal(Path::new("/nonexistent/inkpca/never.log")).unwrap();
        assert!(res.records.is_empty());
        assert!(!res.torn);
    }

    #[test]
    fn torn_tail_keeps_prefix() {
        let records = sample_records();
        let bytes = encode_file(&records);
        let res = decode_wal_bytes(&bytes[..bytes.len() - 1]);
        assert!(res.torn);
        assert_eq!(res.records.len(), records.len() - 1);
        assert_eq!(res.records, records[..records.len() - 1]);
    }

    #[test]
    fn prop_record_roundtrip() {
        check("wal record roundtrip", default_cases(), |rng| {
            let rec = random_record(rng);
            let mut payload = Vec::new();
            rec.encode_into(&mut payload);
            let back = WalRecord::decode(&payload)?;
            ensure(back == rec, || format!("roundtrip mismatch: {rec:?} vs {back:?}"))
        });
    }

    #[test]
    fn prop_bitflip_never_panics_and_keeps_only_valid_prefix() {
        check("wal bit-flip corpus", default_cases(), |rng| {
            let records: Vec<WalRecord> =
                (0..rng.below(6) + 1).map(|_| random_record(rng)).collect();
            let mut bytes = encode_file(&records);
            let bit = rng.below(bytes.len() * 8);
            bytes[bit / 8] ^= 1 << (bit % 8);
            // Must not panic; every decoded record must be one we wrote
            // (a single bit flip cannot pass CRC32, so the decoded list
            // is a strict prefix of the original).
            let res = decode_wal_bytes(&bytes);
            ensure(res.records.len() < records.len() || res.records == records, || {
                "bit flip produced a non-prefix decode".into()
            })?;
            ensure(
                res.records.iter().zip(&records).all(|(a, b)| a == b),
                || "decoded prefix diverged from original".into(),
            )
        });
    }

    #[test]
    fn prop_truncation_never_panics() {
        check("wal truncation corpus", default_cases(), |rng| {
            let records: Vec<WalRecord> =
                (0..rng.below(6) + 1).map(|_| random_record(rng)).collect();
            let bytes = encode_file(&records);
            let cut = rng.below(bytes.len() + 1);
            let res = decode_wal_bytes(&bytes[..cut]);
            ensure(res.records.len() <= records.len(), || "over-long decode".into())?;
            ensure(
                res.records.iter().zip(&records).all(|(a, b)| a == b),
                || "truncated decode diverged from original prefix".into(),
            )
        });
    }

    #[test]
    fn scan_valid_len_matches_decode() {
        let records = sample_records();
        let bytes = encode_file(&records);
        assert_eq!(scan_valid_len(&bytes), bytes.len() as u64);
        let cut = &bytes[..bytes.len() - 2];
        assert_eq!(scan_valid_len(cut), decode_wal_bytes(cut).valid_len);
        assert_eq!(scan_valid_len(b"garbage"), 0);
    }
}
