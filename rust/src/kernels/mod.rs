//! Kernel functions (§2.1) and the Gram-matrix helpers the algorithms
//! consume. The paper's experiments use the RBF kernel with the median
//! heuristic (§5); linear, polynomial, Laplacian and sigmoid kernels are
//! provided so the incremental machinery is exercised beyond the
//! constant-diagonal case (`k(x,x) = 1`) the paper's Algorithm 1 note
//! discusses.

use crate::linalg::Mat;
use crate::util::par;

/// A symmetric positive (semi-)definite kernel over ℝᵈ rows.
pub trait Kernel: Sync + Send {
    /// Evaluate `k(x, y)`.
    fn eval(&self, x: &[f64], y: &[f64]) -> f64;

    /// Human-readable name for logs / experiment reports.
    fn name(&self) -> String;

    /// Whether `k(x, x)` is the same for every `x` (true for RBF and
    /// Laplacian) — enables the simplification noted after Algorithm 1.
    fn constant_diagonal(&self) -> bool {
        false
    }
}

/// Radial basis function kernel `exp(−‖x−y‖² / σ)` — note the paper
/// parameterizes with `σ` directly dividing the squared distance.
#[derive(Clone, Copy, Debug)]
pub struct Rbf {
    pub sigma: f64,
}

impl Kernel for Rbf {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        (-sqdist(x, y) / self.sigma).exp()
    }
    fn name(&self) -> String {
        format!("rbf(sigma={:.4})", self.sigma)
    }
    fn constant_diagonal(&self) -> bool {
        true
    }
}

/// Linear kernel `⟨x, y⟩`.
#[derive(Clone, Copy, Debug)]
pub struct Linear;

impl Kernel for Linear {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        crate::linalg::dot(x, y)
    }
    fn name(&self) -> String {
        "linear".into()
    }
}

/// Polynomial kernel `(⟨x, y⟩ + c)^p`.
#[derive(Clone, Copy, Debug)]
pub struct Polynomial {
    pub degree: u32,
    pub offset: f64,
}

impl Kernel for Polynomial {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        (crate::linalg::dot(x, y) + self.offset).powi(self.degree as i32)
    }
    fn name(&self) -> String {
        format!("poly(d={}, c={})", self.degree, self.offset)
    }
}

/// Laplacian kernel `exp(−‖x−y‖₁ / σ)`.
#[derive(Clone, Copy, Debug)]
pub struct Laplacian {
    pub sigma: f64,
}

impl Kernel for Laplacian {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        let l1: f64 = x.iter().zip(y).map(|(a, b)| (a - b).abs()).sum();
        (-l1 / self.sigma).exp()
    }
    fn name(&self) -> String {
        format!("laplacian(sigma={:.4})", self.sigma)
    }
    fn constant_diagonal(&self) -> bool {
        true
    }
}

/// Sigmoid (tanh) kernel `tanh(a⟨x,y⟩ + b)` — not PSD in general; kept
/// for robustness testing of the deflation path.
#[derive(Clone, Copy, Debug)]
pub struct Sigmoid {
    pub alpha: f64,
    pub beta: f64,
}

impl Kernel for Sigmoid {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        (self.alpha * crate::linalg::dot(x, y) + self.beta).tanh()
    }
    fn name(&self) -> String {
        format!("sigmoid(a={}, b={})", self.alpha, self.beta)
    }
}

/// Squared Euclidean distance.
#[inline]
pub fn sqdist(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// The paper's bandwidth heuristic (§5): the median of pairwise squared
/// distances over (a subset of) the data. Uses at most `max_points`
/// rows to bound the O(n²) scan.
pub fn median_heuristic(x: &Mat, max_points: usize) -> f64 {
    let n = x.rows().min(max_points);
    let mut dists = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            dists.push(sqdist(x.row(i), x.row(j)));
        }
    }
    if dists.is_empty() {
        return 1.0;
    }
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let m = dists.len();
    let med = if m % 2 == 1 { dists[m / 2] } else { 0.5 * (dists[m / 2 - 1] + dists[m / 2]) };
    if med > 0.0 {
        med
    } else {
        1.0
    }
}

/// Full Gram matrix `K[i,j] = k(xᵢ, xⱼ)` over the rows of `x`: only the
/// upper triangle is evaluated (kernel evals dominate the cold-start
/// cost and the matrix is symmetric) and mirrored into place. The
/// parallel split pairs row `t` with row `n−1−t`, so every task carries
/// the same `n+1` evaluations — the bare upper-triangle row split would
/// front-load long rows onto the first workers.
pub fn gram(kernel: &dyn Kernel, x: &Mat) -> Mat {
    let n = x.rows();
    let mut k = Mat::zeros(n, n);
    if n == 0 {
        return k;
    }
    let half = n - n / 2; // ceil(n/2) row pairs
    let pairs: Vec<(Vec<f64>, Vec<f64>)> = par::par_map(half, 4, |t| {
        let i = t;
        let j = n - 1 - t;
        let row_i: Vec<f64> = (i..n).map(|c| kernel.eval(x.row(i), x.row(c))).collect();
        let row_j: Vec<f64> = if j > i {
            (j..n).map(|c| kernel.eval(x.row(j), x.row(c))).collect()
        } else {
            Vec::new()
        };
        (row_i, row_j)
    });
    for (t, (row_i, row_j)) in pairs.into_iter().enumerate() {
        let i = t;
        for (off, v) in row_i.into_iter().enumerate() {
            k[(i, i + off)] = v;
            k[(i + off, i)] = v;
        }
        let j = n - 1 - t;
        for (off, v) in row_j.into_iter().enumerate() {
            k[(j, j + off)] = v;
            k[(j + off, j)] = v;
        }
    }
    k
}

/// Kernel column `a = [k(x₁, y) … k(xₘ, y)]ᵀ` against the first `m` rows
/// of `x` — the per-step quantity of Algorithms 1–2 (allocating form of
/// [`kernel_column_into`]).
pub fn kernel_column(kernel: &dyn Kernel, x: &Mat, m: usize, y: &[f64]) -> Vec<f64> {
    assert!(m <= x.rows());
    let mut out = Vec::new();
    kernel_column_into(kernel, x.as_slice(), x.cols(), m, y, &mut out);
    out
}

/// [`kernel_column`] over flat row-major data into a caller-owned,
/// capacity-retaining buffer — the zero-allocation streaming form (the
/// incremental states keep their retained examples as a flat `Vec`, so
/// no per-push matrix clone is needed either).
pub fn kernel_column_into(
    kernel: &dyn Kernel,
    x: &[f64],
    dim: usize,
    m: usize,
    y: &[f64],
    out: &mut Vec<f64>,
) {
    assert!(x.len() >= m * dim, "kernel_column_into: data shorter than m rows");
    assert_eq!(y.len(), dim, "kernel_column_into: query dimension mismatch");
    out.clear();
    out.resize(m, 0.0);
    let row = |i: usize| &x[i * dim..(i + 1) * dim];
    if m >= 64 {
        const CHUNK: usize = 16;
        par::par_chunks_mut(out, CHUNK, |ci, chunk| {
            let base = ci * CHUNK;
            for (off, slot) in chunk.iter_mut().enumerate() {
                *slot = kernel.eval(row(base + off), y);
            }
        });
    } else {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = kernel.eval(row(i), y);
        }
    }
}

/// Rectangular cross-Gram `K[i,j] = k(aᵢ, bⱼ)` between row sets.
pub fn cross_gram(kernel: &dyn Kernel, a: &Mat, b: &Mat) -> Mat {
    let (na, nb) = (a.rows(), b.rows());
    let rows: Vec<Vec<f64>> = par::par_map(na, 4, |i| {
        (0..nb).map(|j| kernel.eval(a.row(i), b.row(j))).collect()
    });
    let mut k = Mat::zeros(na, nb);
    for (i, vals) in rows.into_iter().enumerate() {
        k.row_mut(i).copy_from_slice(&vals);
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigvalsh;

    fn toy_data() -> Mat {
        Mat::from_fn(8, 3, |i, j| ((i * 3 + j) as f64 * 0.37).sin())
    }

    #[test]
    fn rbf_unit_diagonal_and_symmetry() {
        let k = Rbf { sigma: 2.0 };
        let x = toy_data();
        let g = gram(&k, &x);
        for i in 0..8 {
            assert!((g[(i, i)] - 1.0).abs() < 1e-15);
            for j in 0..8 {
                assert_eq!(g[(i, j)], g[(j, i)]);
                assert!(g[(i, j)] > 0.0 && g[(i, j)] <= 1.0);
            }
        }
    }

    #[test]
    fn rbf_gram_is_psd() {
        let k = Rbf { sigma: 1.0 };
        let g = gram(&k, &toy_data());
        let vals = eigvalsh(&g).unwrap();
        assert!(vals[0] > -1e-10);
    }

    #[test]
    fn linear_kernel_matches_dot() {
        let x = [1.0, 2.0];
        let y = [3.0, -1.0];
        assert_eq!(Linear.eval(&x, &y), 1.0);
    }

    #[test]
    fn polynomial_kernel_closed_form() {
        let k = Polynomial { degree: 2, offset: 1.0 };
        assert_eq!(k.eval(&[1.0], &[2.0]), 9.0);
    }

    #[test]
    fn laplacian_constant_diagonal() {
        let k = Laplacian { sigma: 1.5 };
        assert!((k.eval(&[0.3, 0.4], &[0.3, 0.4]) - 1.0).abs() < 1e-15);
        assert!(k.constant_diagonal());
    }

    #[test]
    fn median_heuristic_positive_and_scale_covariant() {
        let x = toy_data();
        let s1 = median_heuristic(&x, 100);
        assert!(s1 > 0.0);
        // Doubling the data scale quadruples squared distances.
        let mut x2 = x.clone();
        x2.scale(2.0);
        let s2 = median_heuristic(&x2, 100);
        assert!((s2 / s1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn kernel_column_matches_gram_column() {
        let k = Rbf { sigma: 0.7 };
        let x = toy_data();
        let g = gram(&k, &x);
        let col = kernel_column(&k, &x, 8, x.row(5));
        for i in 0..8 {
            assert!((col[i] - g[(i, 5)]).abs() < 1e-15);
        }
    }

    #[test]
    fn gram_matches_brute_force_odd_and_even() {
        // The paired-row upper-triangle fill must cover every entry for
        // both parities of n (middle row is unpaired when n is odd).
        let k = Rbf { sigma: 1.3 };
        for n in [1usize, 2, 5, 8, 9] {
            let x = Mat::from_fn(n, 3, |i, j| ((i * 3 + j) as f64 * 0.29).cos());
            let g = gram(&k, &x);
            for i in 0..n {
                for j in 0..n {
                    let expect = k.eval(x.row(i), x.row(j));
                    assert!((g[(i, j)] - expect).abs() < 1e-15, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn kernel_column_into_reuses_buffer() {
        let k = Rbf { sigma: 0.9 };
        let x = toy_data();
        let mut buf = Vec::new();
        kernel_column_into(&k, x.as_slice(), x.cols(), 8, x.row(2), &mut buf);
        assert_eq!(buf.len(), 8);
        let cap = buf.capacity();
        kernel_column_into(&k, x.as_slice(), x.cols(), 5, x.row(1), &mut buf);
        assert_eq!(buf.len(), 5);
        assert_eq!(buf.capacity(), cap, "buffer must be reused, not reallocated");
        assert!((buf[1] - k.eval(x.row(1), x.row(1))).abs() < 1e-15);
    }

    #[test]
    fn cross_gram_consistent_with_gram() {
        let k = Rbf { sigma: 0.7 };
        let x = toy_data();
        let c = cross_gram(&k, &x, &x);
        assert!(c.max_abs_diff(&gram(&k, &x)) < 1e-15);
    }
}
