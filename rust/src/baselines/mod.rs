//! Prior-work baselines the paper compares against (§2.3, §3):
//! Chin & Suter (2007) — exact incremental KPCA with mean adjustment via
//! incremental SVD in feature space (≈20m³ flops/step per the paper's
//! accounting) — and Hoegaerts et al. (2007) — dominant-subspace
//! tracking of the unadjusted kernel matrix.

pub mod chin_suter;
pub mod hoegaerts;

pub use chin_suter::ChinSuterKpca;
pub use hoegaerts::HoegaertsTracker;
