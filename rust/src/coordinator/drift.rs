//! Online drift monitor — the live version of the paper's Fig. 1
//! measurement: every `every` accepted examples, reconstruct `UΛUᵀ`,
//! recompute the batch (adjusted) kernel matrix, and record the three
//! norms of the difference. `O(m³)` per measurement, so it is sampled,
//! not per-step. Each stream entry in the shard pool owns one monitor;
//! its latest Frobenius norm surfaces as the per-stream `drift` gauge
//! in the pool snapshot.

use crate::kpca::IncrementalKpca;
use crate::linalg::{sym_norms, Norms};

/// One drift measurement.
#[derive(Clone, Copy, Debug)]
pub struct DriftPoint {
    /// Number of points in the eigensystem at measurement time.
    pub m: usize,
    pub norms: Norms,
    /// `‖UUᵀ − I‖_F` (§5.1 orthogonality diagnostic).
    pub orthogonality: f64,
}

/// One Fig.-1-style measurement of an exact eigensystem: reconstruct
/// `UΛUᵀ`, recompute the batch reference kernel, difference norms +
/// the §5.1 orthogonality defect. Free function so the engine seam
/// ([`super::engine::StreamState::measure_drift`]) can measure without
/// holding a monitor — the monitor's cadence bookkeeping stays with
/// the stream entry.
pub fn measure_point(state: &IncrementalKpca<'_>) -> DriftPoint {
    let diff = state.reconstruct().sub(&state.batch_reference());
    DriftPoint {
        m: state.len(),
        norms: sym_norms(&diff),
        orthogonality: crate::linalg::orthogonality_defect(&state.vecs),
    }
}

/// Periodic drift monitor.
#[derive(Debug)]
pub struct DriftMonitor {
    /// Measure every this many accepted examples (0 disables).
    pub every: usize,
    accepted_since: usize,
    history: Vec<DriftPoint>,
}

impl DriftMonitor {
    pub fn new(every: usize) -> Self {
        DriftMonitor { every, accepted_since: 0, history: Vec::new() }
    }

    /// Notify of an accepted example; measures when due.
    pub fn on_accept(&mut self, state: &IncrementalKpca<'_>) -> Option<DriftPoint> {
        if self.every == 0 {
            return None;
        }
        self.accepted_since += 1;
        if self.accepted_since < self.every {
            return None;
        }
        self.accepted_since = 0;
        Some(self.measure(state))
    }

    /// Notify of `n` accepted examples at once (batched ingest).
    /// Measures at most once — at the batch boundary — even when `n`
    /// spans several cadence periods: drift is a sampled diagnostic and
    /// the intermediate eigensystems no longer exist to be measured.
    pub fn on_accept_many(
        &mut self,
        n: usize,
        state: &IncrementalKpca<'_>,
    ) -> Option<DriftPoint> {
        if self.every == 0 || n == 0 {
            return None;
        }
        self.accepted_since += n;
        if self.accepted_since < self.every {
            return None;
        }
        self.accepted_since = 0;
        Some(self.measure(state))
    }

    /// Notify of `n` accepted examples without measuring; returns
    /// whether a measurement is due (and resets the cadence phase when
    /// it is). The engine-seam path: the caller measures through
    /// [`super::engine::StreamState::measure_drift`] — which may fail
    /// on tiers with nothing to reconstruct — and feeds the point back
    /// via [`DriftMonitor::record`].
    pub fn note(&mut self, n: usize) -> bool {
        if self.every == 0 || n == 0 {
            return false;
        }
        self.accepted_since += n;
        if self.accepted_since < self.every {
            return false;
        }
        self.accepted_since = 0;
        true
    }

    /// Append a measurement produced outside the monitor (the
    /// engine-seam and eviction-audit paths).
    pub fn record(&mut self, point: DriftPoint) {
        self.history.push(point);
    }

    /// Unconditional measurement.
    pub fn measure(&mut self, state: &IncrementalKpca<'_>) -> DriftPoint {
        let point = measure_point(state);
        self.history.push(point);
        point
    }

    pub fn history(&self) -> &[DriftPoint] {
        &self.history
    }

    pub fn latest(&self) -> Option<&DriftPoint> {
        self.history.last()
    }

    /// Accepted examples since the last measurement — serialized by the
    /// checkpoint codec so a restored monitor keeps its cadence phase.
    pub fn accepted_since(&self) -> usize {
        self.accepted_since
    }

    /// Rebuild a monitor from checkpointed parts (cadence, phase, and
    /// the measurement history) — the restore inverse of
    /// [`DriftMonitor::accepted_since`] / [`DriftMonitor::history`].
    pub fn from_parts(every: usize, accepted_since: usize, history: Vec<DriftPoint>) -> Self {
        DriftMonitor { every, accepted_since, history }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;

    #[test]
    fn measures_every_n_accepts() {
        let ds = yeast_like(16, 1);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        let mut mon = DriftMonitor::new(3);
        let mut measured = 0;
        for i in 4..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
            if mon.on_accept(&inc).is_some() {
                measured += 1;
            }
        }
        assert_eq!(measured, 12 / 3);
        assert_eq!(mon.history().len(), measured);
        // Exact algorithm: drift stays tiny.
        for p in mon.history() {
            assert!(p.norms.frobenius < 1e-8, "drift {:?}", p.norms);
            assert!(p.orthogonality < 1e-9);
        }
    }

    #[test]
    fn disabled_monitor_never_fires() {
        let ds = yeast_like(8, 2);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, false).unwrap();
        let mut mon = DriftMonitor::new(0);
        for i in 4..8 {
            inc.push(ds.x.row(i)).unwrap();
            assert!(mon.on_accept(&inc).is_none());
        }
        assert!(mon.history().is_empty());
    }

    #[test]
    fn drift_monotone_in_m_is_not_required_but_small() {
        // Sanity: measurements carry increasing m.
        let ds = yeast_like(12, 3);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        let mut mon = DriftMonitor::new(2);
        for i in 4..12 {
            inc.push(ds.x.row(i)).unwrap();
            mon.on_accept(&inc);
        }
        let ms: Vec<usize> = mon.history().iter().map(|p| p.m).collect();
        for w in ms.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
