//! Capacity-doubling eigenvector storage. The streaming algorithms grow
//! the eigensystem by one row *and* one column per accepted example;
//! with a plain contiguous matrix that is a full `O(mn)` re-layout per
//! step. `EigenBasis` keeps rows at a fixed `stride ≥ cols` inside a
//! `row_cap × stride` buffer, so expansion is `O(m)` writes (zeroing the
//! newly exposed row/column) and reallocation is amortized `O(1)` via
//! doubling — the same trade `Vec` makes, lifted to two dimensions.
//!
//! Only the leading `rows × cols` window is meaningful; slack capacity
//! holds stale values by design (every consumer goes through
//! [`EigenBasis::view`], which exposes exactly the window).

use std::ops::{Index, IndexMut};

use crate::linalg::{Mat, MatView, MatViewMut};

/// Growable eigenvector matrix (`rows × cols` window, one eigenvector
/// per column) with stride/capacity slack for in-place expansion.
#[derive(Clone, Debug, Default)]
pub struct EigenBasis {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
    /// Allocated elements per row (`>= cols`).
    stride: usize,
    /// Allocated rows (`>= rows`).
    row_cap: usize,
    reallocs: u64,
}

impl EigenBasis {
    /// Empty basis (grows on first [`EigenBasis::expand`]).
    pub fn new() -> Self {
        EigenBasis::default()
    }

    /// Take over a dense matrix without copying (stride = cols).
    pub fn from_mat(m: Mat) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        EigenBasis { data: m.into_vec(), rows, cols, stride: cols, row_cap: rows, reallocs: 0 }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Buffer-growth events since construction (zero in steady state).
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }

    /// Bytes held by the backing buffer.
    pub fn bytes_resident(&self) -> usize {
        self.data.capacity() * std::mem::size_of::<f64>()
    }

    /// Length of the backing buffer in elements (`row_cap × stride`).
    pub(crate) fn data_len(&self) -> usize {
        self.data.len()
    }

    /// Row stride of the backing buffer.
    pub(crate) fn stride(&self) -> usize {
        self.stride
    }

    /// Swap the backing buffer with an equally-sized external one — the
    /// `O(1)` commit of the rotated-eigenvector double buffer.
    pub(crate) fn swap_data(&mut self, other: &mut Vec<f64>) {
        debug_assert_eq!(other.len(), self.data.len(), "double buffer length mismatch");
        std::mem::swap(&mut self.data, other);
    }

    /// View of the valid `rows × cols` window.
    pub fn view(&self) -> MatView<'_> {
        MatView::new(&self.data, self.rows, self.cols, self.stride.max(self.cols))
    }

    /// Mutable view of the valid window.
    pub fn view_mut(&mut self) -> MatViewMut<'_> {
        let stride = self.stride.max(self.cols);
        MatViewMut::new(&mut self.data, self.rows, self.cols, stride)
    }

    /// Row `i` of the window.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// Mutable row `i` of the window.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// Column `j` copied into a new vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Copy the window out into a dense matrix.
    pub fn to_mat(&self) -> Mat {
        self.view().to_mat()
    }

    /// Consume into a dense matrix (`O(1)` when the storage is exactly
    /// contiguous, one compaction copy otherwise).
    pub fn into_mat(self) -> Mat {
        if self.stride == self.cols && self.data.len() == self.rows * self.cols {
            Mat::from_vec(self.rows, self.cols, self.data)
        } else {
            self.to_mat()
        }
    }

    /// Grow the window by one row and one column. Within capacity this
    /// is `O(rows + cols)` (zero the newly exposed lane pair); beyond it
    /// the buffer doubles in the overflowing dimension(s).
    pub fn expand(&mut self) {
        let (m, n) = (self.rows, self.cols);
        if n + 1 > self.stride || m + 1 > self.row_cap {
            let new_stride =
                if n + 1 > self.stride { (n + 1).max(2 * self.stride) } else { self.stride };
            let new_row_cap =
                if m + 1 > self.row_cap { (m + 1).max(2 * self.row_cap) } else { self.row_cap };
            let mut data = vec![0.0; new_row_cap * new_stride];
            for i in 0..m {
                data[i * new_stride..i * new_stride + n]
                    .copy_from_slice(&self.data[i * self.stride..i * self.stride + n]);
            }
            self.data = data;
            self.stride = new_stride;
            self.row_cap = new_row_cap;
            self.reallocs += 1;
        } else {
            // Clear the stale lane pair the window is about to expose.
            for i in 0..m {
                self.data[i * self.stride + n] = 0.0;
            }
            let base = m * self.stride;
            self.data[base..base + n + 1].fill(0.0);
        }
        self.rows = m + 1;
        self.cols = n + 1;
    }

    /// Pre-size the backing buffer for windows up to `rows × cols`
    /// *without* counting toward the realloc counter — the warm-up
    /// entry point matching [`super::UpdateWorkspace::reserve`]. All
    /// subsequent in-capacity [`EigenBasis::expand`] calls are then
    /// allocation-free up to that size.
    pub fn reserve(&mut self, rows: usize, cols: usize) {
        if rows <= self.row_cap && cols <= self.stride {
            return;
        }
        let new_stride = self.stride.max(cols);
        let new_row_cap = self.row_cap.max(rows);
        let mut data = vec![0.0; new_row_cap * new_stride];
        for i in 0..self.rows {
            data[i * new_stride..i * new_stride + self.cols]
                .copy_from_slice(&self.data[i * self.stride..i * self.stride + self.cols]);
        }
        self.data = data;
        self.stride = new_stride;
        self.row_cap = new_row_cap;
    }

    /// Drop column `j`, shifting later columns left in place (no
    /// reallocation; used by the top-`r` truncating trackers).
    pub fn remove_col(&mut self, j: usize) {
        assert!(j < self.cols, "remove_col out of range");
        for i in 0..self.rows {
            let base = i * self.stride;
            self.data.copy_within(base + j + 1..base + self.cols, base + j);
        }
        self.cols -= 1;
    }

    /// Drop row `i`, shifting later rows up in place (no reallocation;
    /// the landmark-eviction down-date removes the evicted point's
    /// coordinate from every eigenvector this way). Removing a basis
    /// *row* commutes with any pending right-rotation `U·Q`, so this is
    /// safe while a blocked-batch product is pending.
    pub fn remove_row(&mut self, i: usize) {
        assert!(i < self.rows, "remove_row out of range");
        let s = self.stride.max(self.cols);
        if i + 1 < self.rows {
            self.data.copy_within((i + 1) * s..(self.rows - 1) * s + self.cols, i * s);
        }
        self.rows -= 1;
    }

    /// Shrink the column window to `new_cols` without moving data — the
    /// commit step of a *rectangular* pending-rotation flush, where
    /// `U (m × q_rows) · Q (q_rows × q_dim)` lands in a buffer laid out
    /// at the old stride and only the leading `q_dim` columns are
    /// meaningful. Slack columns go stale by design (see module docs);
    /// [`EigenBasis::expand`] re-zeroes a lane before exposing it.
    pub(crate) fn shrink_cols(&mut self, new_cols: usize) {
        assert!(new_cols <= self.cols, "shrink_cols must not grow the window");
        self.cols = new_cols;
    }

    /// Max absolute difference to a dense matrix (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows(), other.cols()));
        let mut m = 0.0_f64;
        for i in 0..self.rows {
            for (a, b) in self.row(i).iter().zip(other.row(i)) {
                m = m.max((a - b).abs());
            }
        }
        m
    }
}

impl Index<(usize, usize)> for EigenBasis {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.stride + j]
    }
}

impl IndexMut<(usize, usize)> for EigenBasis {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.stride + j]
    }
}

impl<'a> From<&'a EigenBasis> for MatView<'a> {
    fn from(b: &'a EigenBasis) -> MatView<'a> {
        b.view()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_mat_roundtrip_is_lossless() {
        let m = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let b = EigenBasis::from_mat(m.clone());
        assert_eq!(b.rows(), 4);
        assert_eq!(b.cols(), 3);
        assert_eq!(b.max_abs_diff(&m), 0.0);
        assert_eq!(b.into_mat().max_abs_diff(&m), 0.0);
    }

    #[test]
    fn expand_zeroes_new_lane_pair() {
        let mut b = EigenBasis::from_mat(Mat::from_fn(2, 2, |_, _| 7.0));
        b.expand();
        assert_eq!(b.rows(), 3);
        assert_eq!(b.cols(), 3);
        for i in 0..3 {
            assert_eq!(b[(i, 2)], 0.0);
            assert_eq!(b[(2, i)], 0.0);
        }
        assert_eq!(b[(1, 1)], 7.0);
    }

    #[test]
    fn expansion_reallocs_are_amortized() {
        let mut b = EigenBasis::new();
        for _ in 0..64 {
            b.expand();
        }
        assert_eq!(b.rows(), 64);
        // Doubling growth: far fewer reallocations than expansions.
        assert!(b.reallocs() <= 8, "reallocs {}", b.reallocs());
    }

    #[test]
    fn in_capacity_expand_does_not_realloc() {
        let mut b = EigenBasis::new();
        for _ in 0..20 {
            b.expand();
        }
        // Shrink the window, then regrow within the existing capacity.
        let before = b.reallocs();
        b.remove_col(0);
        // Stale column beyond the window must come back as zeros.
        for i in 0..b.rows() {
            b.row_mut(i).fill(3.0);
        }
        b.expand();
        assert_eq!(b.reallocs(), before);
        for i in 0..b.rows() {
            assert_eq!(b[(i, b.cols() - 1)], 0.0, "stale column leaked at row {i}");
        }
    }

    #[test]
    fn reserve_preserves_window_and_silences_growth() {
        let m = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let mut b = EigenBasis::from_mat(m.clone());
        b.reserve(16, 16);
        assert_eq!(b.reallocs(), 0, "reserve must not count as a realloc");
        assert_eq!(b.max_abs_diff(&m), 0.0);
        for _ in 3..16 {
            b.expand();
        }
        assert_eq!(b.rows(), 16);
        assert_eq!(b.reallocs(), 0, "expansion within reserved capacity is free");
        // The original window survived the growth.
        assert_eq!(b[(2, 2)], 8.0);
    }

    #[test]
    fn remove_col_shifts_left() {
        let m = Mat::from_fn(3, 4, |i, j| (10 * i + j) as f64);
        let mut b = EigenBasis::from_mat(m);
        b.remove_col(1);
        assert_eq!(b.cols(), 3);
        assert_eq!(b[(0, 0)], 0.0);
        assert_eq!(b[(0, 1)], 2.0);
        assert_eq!(b[(2, 2)], 23.0);
    }

    #[test]
    fn remove_row_shifts_up() {
        let m = Mat::from_fn(4, 3, |i, j| (10 * i + j) as f64);
        let mut b = EigenBasis::from_mat(m);
        b.remove_row(1);
        assert_eq!(b.rows(), 3);
        assert_eq!(b[(0, 0)], 0.0);
        assert_eq!(b[(1, 0)], 20.0);
        assert_eq!(b[(2, 2)], 32.0);
        // Removing the (new) last row needs no data motion.
        b.remove_row(2);
        assert_eq!(b.rows(), 2);
        assert_eq!(b[(1, 1)], 21.0);
    }

    #[test]
    fn remove_row_respects_stride_slack() {
        // Grow past the initial capacity so stride > cols, then remove a
        // row and check the window stays consistent.
        let mut b = EigenBasis::new();
        for _ in 0..5 {
            b.expand();
        }
        for i in 0..5 {
            for j in 0..5 {
                b[(i, j)] = (10 * i + j) as f64;
            }
        }
        b.remove_row(2);
        assert_eq!(b.rows(), 4);
        assert_eq!(b[(2, 0)], 30.0);
        assert_eq!(b[(3, 4)], 44.0);
        assert_eq!(b[(1, 1)], 11.0);
    }

    #[test]
    fn shrink_cols_then_expand_re_zeroes() {
        let mut b = EigenBasis::from_mat(Mat::from_fn(3, 3, |_, _| 5.0));
        b.shrink_cols(2);
        assert_eq!(b.cols(), 2);
        b.expand();
        assert_eq!(b.cols(), 3);
        for i in 0..b.rows() {
            assert_eq!(b[(i, 2)], 0.0, "stale column leaked at row {i}");
        }
    }

    #[test]
    fn view_matches_indexing_after_growth() {
        let mut b = EigenBasis::from_mat(Mat::from_fn(2, 2, |i, j| (i + j) as f64));
        b.expand();
        b[(2, 2)] = 1.0;
        let v = b.view();
        assert_eq!(v.rows(), 3);
        assert_eq!(v[(2, 2)], 1.0);
        assert_eq!(v[(0, 1)], 1.0);
        let m = b.to_mat();
        assert_eq!(m[(2, 2)], 1.0);
    }

    #[test]
    fn swap_data_exchanges_storage() {
        let mut b = EigenBasis::from_mat(Mat::from_fn(2, 2, |i, j| (i * 2 + j) as f64));
        let mut buf = vec![9.0; b.data_len()];
        b.swap_data(&mut buf);
        assert_eq!(b[(0, 0)], 9.0);
        assert_eq!(buf[3], 3.0);
    }
}
