"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here;
pytest sweeps shapes/dtypes (hypothesis) asserting allclose between the
two. The references are also what the rust test-suite numerics were
derived from.
"""

import jax.numpy as jnp


def rbf_column_ref(x, y, sigma):
    """RBF kernel column a[i] = exp(-||x_i - y||^2 / sigma).

    Args:
      x: (m, d) data rows.
      y: (d,) query point.
      sigma: scalar bandwidth (the paper's parameterization divides the
        squared distance by sigma directly).
    Returns: (m,) kernel column.
    """
    d2 = jnp.sum((x - y[None, :]) ** 2, axis=1)
    return jnp.exp(-d2 / sigma)


def rbf_gram_ref(x, sigma):
    """Full RBF Gram matrix K[i, j] = exp(-||x_i - x_j||^2 / sigma)."""
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    d2 = jnp.maximum(d2, 0.0)
    return jnp.exp(-d2 / sigma)


def eigvec_weights_ref(z, lam, lam_new):
    """Unnormalized BNS78 inner eigenvectors W[j, i] = z_j / (lam_j - lam_new_i)."""
    return z[:, None] / (lam[:, None] - lam_new[None, :])


def eigvec_update_ref(u, z, lam, lam_new, eps=1e-300):
    """Rotated eigenvector matrix U @ (W / ||W||_cols)  (paper eq. 6).

    Args:
      u: (m, k) current eigenvectors.
      z: (k,) projected perturbation U^T v.
      lam: (k,) current eigenvalues (poles).
      lam_new: (k,) updated eigenvalues (secular roots).
    Returns: (m, k) updated eigenvectors.
    """
    w = eigvec_weights_ref(z, lam, lam_new)
    norms = jnp.sqrt(jnp.sum(w * w, axis=0))
    inv = 1.0 / jnp.maximum(norms, eps)
    return u @ (w * inv[None, :])
