//! Minimal data-parallel substrate built on `std::thread::scope` — the
//! offline environment has no rayon, so the blocked GEMM and the
//! experiment sweeps parallelize through this module instead.
//!
//! The design is deliberately simple: static chunking over an index
//! range with one OS thread per chunk. The kernels this crate runs are
//! regular (uniform per-index cost), so static chunking is within a few
//! percent of work stealing while having zero dependency cost.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

thread_local! {
    /// Set while the current thread is a par worker — nested parallel
    /// calls run serially instead of oversubscribing the machine.
    static IN_PAR: Cell<bool> = const { Cell::new(false) };
}

fn in_par() -> bool {
    IN_PAR.with(|f| f.get())
}

fn enter_par<R>(f: impl FnOnce() -> R) -> R {
    IN_PAR.with(|flag| flag.set(true));
    let r = f();
    IN_PAR.with(|flag| flag.set(false));
    r
}

/// Number of worker threads to use; `INKPCA_THREADS` overrides, default
/// is the number of available cores.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("INKPCA_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Run `f(i)` for every `i in 0..n`, distributing indices over worker
/// threads in contiguous chunks. Falls back to the serial loop when the
/// range is small or only one thread is configured.
pub fn par_for(n: usize, min_per_thread: usize, f: impl Fn(usize) + Sync) {
    let threads = num_threads().min(n / min_per_thread.max(1)).max(1);
    if threads <= 1 || in_par() {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    // Dynamic chunks of size `chunk`: cheap work stealing via an atomic
    // cursor, which keeps tail imbalance bounded without a deque.
    let chunk = (n / (threads * 4)).max(min_per_thread.max(1));
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                enter_par(|| loop {
                    let start = counter.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    let end = (start + chunk).min(n);
                    for i in start..end {
                        f(i);
                    }
                })
            });
        }
    });
}

/// Raw-pointer wrapper that lets disjoint-index writers share a buffer
/// across scoped threads.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Parallel map over `0..n` collecting results in index order.
pub fn par_map<T: Send>(n: usize, min_per_thread: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<std::mem::MaybeUninit<T>> = Vec::with_capacity(n);
    // SAFETY: set_len over MaybeUninit is fine; every slot is written
    // exactly once below before being read.
    unsafe { out.set_len(n) };
    let ptr = SendPtr(out.as_mut_ptr());
    let ptr_ref = &ptr; // capture the Sync wrapper, not the raw field
    par_for(n, min_per_thread, |i| {
        // SAFETY: par_for hands each index to exactly one worker, so
        // writes are disjoint; the buffer outlives the scoped threads.
        unsafe { (*ptr_ref.0.add(i)).write(f(i)) };
    });
    // SAFETY: all n slots initialized above.
    unsafe { std::mem::transmute::<Vec<std::mem::MaybeUninit<T>>, Vec<T>>(out) }
}

/// Split a mutable slice into `chunks` of `chunk_len` and run `f(chunk
/// index, chunk)` in parallel — the pattern the blocked GEMM needs for
/// disjoint row-panels of the output.
pub fn par_chunks_mut<T: Send>(
    data: &mut [T],
    chunk_len: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_len).enumerate().collect();
    let n = chunks.len();
    if n <= 1 || num_threads() <= 1 || in_par() {
        for (i, c) in chunks {
            f(i, c);
        }
        return;
    }
    let shared: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> =
        chunks.into_iter().map(|c| std::sync::Mutex::new(Some(c))).collect();
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..num_threads().min(n) {
            s.spawn(|| {
                enter_par(|| loop {
                    let idx = counter.fetch_add(1, Ordering::Relaxed);
                    if idx >= n {
                        break;
                    }
                    let (i, c) = shared[idx].lock().unwrap().take().expect("chunk taken twice");
                    f(i, c);
                })
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_visits_every_index_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for(n, 1, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let v = par_map(257, 1, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn par_chunks_mut_disjoint_writes() {
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci + 1;
            }
        });
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i / 10 + 1);
        }
    }

    #[test]
    fn empty_and_tiny_ranges() {
        par_for(0, 1, |_| panic!("should not run"));
        let v = par_map(1, 64, |i| i + 5);
        assert_eq!(v, vec![5]);
    }
}
