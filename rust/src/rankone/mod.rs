//! Rank-one modification of the symmetric eigenproblem
//! (Bunch–Nielsen–Sorensen 1978), the engine under both of the paper's
//! incremental algorithms (§3.2):
//!
//! given `A = U Λ Uᵀ`, compute the eigendecomposition of `A + σ v vᵀ` as
//! `U Ũ Λ̃ Ũᵀ Uᵀ` where `Λ̃` solves the secular equation over `z = Uᵀv`
//! and the columns of `Ũ` are `Dᵢ⁻¹z / ‖Dᵢ⁻¹z‖`, `Dᵢ = Λ − λ̃ᵢI`
//! (paper eq. 6).
//!
//! The `2n³`-flop back-rotation `U · Ũ` dominates and is delegated to a
//! pluggable [`Rotate`] engine: the native blocked GEMM, or a PJRT
//! executable AOT-compiled from the Pallas kernel (see `runtime`).
//!
//! The streaming entry points are the `*_ws` forms: eigenvectors live in
//! an [`EigenBasis`] (capacity-doubling storage, expanded in place) and
//! every scratch buffer comes from an [`UpdateWorkspace`], so a warm
//! steady-state update touches the allocator zero times. On the
//! no-deflation fast path the rotation writes into the workspace's
//! double buffer and commits by an `O(1)` buffer swap. The `Mat`-based
//! functions remain as allocating compatibility wrappers (and as the
//! baseline the `benches/micro_linalg.rs` comparison measures against).
//!
//! **Blocked rank-b updates.** A batch of `b` accepted points triggers
//! `2b` (unadjusted) or `4b` (adjusted) rank-one updates; applying each
//! back-rotation eagerly costs one engine GEMM per update. The fused
//! path ([`rank_one_update_fused_ws`]) instead *defers* the rotation:
//! each clean (no-deflation) update solves its secular system against
//! the current spectrum, builds its `W` factor, and folds it into a
//! pending product `Q ← Q·W` held in workspace scratch; deferred
//! expansions embed as `diag(Q, 1)` plus a column permutation. One
//! [`flush_rotation_ws`] then applies `U ← U·Q` as a single engine
//! GEMM for the whole batch. Updates that would deflate (tiny weight or
//! repeated eigenvalues — the cases that must rotate or permute `U`
//! itself) flush and fall back to the sequential path, so blocked and
//! sequential runs are numerically interchangeable. The
//! [`UpdateWorkspace::engine_gemms`] counter exposes the amortization.
//!
//! **Down-dates.** The inverse operation — removing a point from the
//! tracked eigensystem — is two rank-one updates that decouple the
//! point's eigenpair, followed by [`remove_eigenpair_ws`], which drops
//! the decoupled eigenvalue, its effective eigenvector column, and the
//! point's basis row. Both halves are deferred-aware: the decoupling
//! updates fuse into a pending product like any other clean update, and
//! the column removal drops a column of `Q` instead of forcing a flush
//! (the product goes rectangular, `q_rows × q_dim` with
//! `q_rows > q_dim`, and collapses at the next [`flush_rotation_ws`]).
//! This is what keeps landmark eviction off the engine-GEMM budget of
//! the batch it lands in (see `kpca::IncrementalKpca::remove_point`).

mod basis;
mod blocked;
mod workspace;

pub use basis::EigenBasis;
pub use blocked::{
    effective_row_into, flush_rotation_ws, rank_one_update_fused_tol_ws,
    rank_one_update_fused_ws, remove_eigenpair_ws,
};
pub use workspace::UpdateWorkspace;

pub(crate) use workspace::ensure_f64;

use crate::linalg::{norm2, Mat, MatView, MatViewMut};
use crate::secular::{deflate_into, solve_all_into, SecularRoot};

/// Engine for the `U_active · W` product — the hot `2n³` path.
pub trait Rotate {
    /// `out ← u · w` where `u` is `m × k` and `w` is `k × k`. All three
    /// operands may be strided views; `out` must not alias `u`/`w`.
    fn rotate_into(&self, u: MatView<'_>, w: MatView<'_>, out: MatViewMut<'_>);

    /// [`Rotate::rotate_into`] with caller-owned GEMM packing scratch.
    /// Engines that pack (the native path) override this to keep the
    /// streaming steady state zero-realloc; engines with their own
    /// memory discipline (PJRT device buffers) ignore the scratch and
    /// fall through to [`Rotate::rotate_into`].
    fn rotate_into_buf(
        &self,
        u: MatView<'_>,
        w: MatView<'_>,
        out: MatViewMut<'_>,
        _bufs: &mut crate::linalg::PackBuffers,
    ) {
        self.rotate_into(u, w, out);
    }

    /// Fused path: given the raw secular quantities, build the
    /// normalized `W` internally, write `U·W` into `out` and return
    /// `true` — the shape the AOT Pallas artifact implements
    /// (runtime::PjrtRotate). Returning `false` (default) makes
    /// `rank_one_update` build `W` in pole-relative precision and call
    /// [`Rotate::rotate_into`].
    fn rotate_fused_into(
        &self,
        _u: MatView<'_>,
        _z: &[f64],
        _d: &[f64],
        _roots: &[SecularRoot],
        _out: MatViewMut<'_>,
    ) -> bool {
        false
    }

    /// Short engine label for metrics/logs.
    fn name(&self) -> &'static str {
        "unnamed"
    }

    /// Allocating convenience form of [`Rotate::rotate_into`].
    fn rotate(&self, u: &Mat, w: &Mat) -> Mat {
        let mut out = Mat::zeros(u.rows(), w.cols());
        self.rotate_into(MatView::from(u), MatView::from(w), MatViewMut::from(&mut out));
        out
    }
}

/// Native engine: the in-tree blocked, parallel GEMM.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeRotate;

impl Rotate for NativeRotate {
    fn rotate_into(&self, u: MatView<'_>, w: MatView<'_>, mut out: MatViewMut<'_>) {
        crate::linalg::matmul_into(u, w, &mut out);
    }
    fn rotate_into_buf(
        &self,
        u: MatView<'_>,
        w: MatView<'_>,
        mut out: MatViewMut<'_>,
        bufs: &mut crate::linalg::PackBuffers,
    ) {
        crate::linalg::matmul_into_buf(u, w, &mut out, bufs);
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Diagnostics accumulated across updates (reported by §5.1-style
/// experiments and the coordinator's metrics endpoint).
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    /// Eigenpairs that passed through unchanged (tiny weight).
    pub deflated: usize,
    /// Givens rotations applied for (near-)repeated eigenvalues.
    pub rotations: usize,
    /// Secular roots solved.
    pub solved: usize,
}

/// Relative deflation tolerance (on `|z|/‖z‖` and eigenvalue gaps).
pub const DEFAULT_DEFLATE_TOL: f64 = 1e-14;

/// Allocating compatibility form of [`rank_one_update_ws`]: a fresh
/// workspace per call (the pre-workspace behaviour, kept for tests,
/// cold paths, and as the bench baseline).
pub fn rank_one_update(
    vals: &mut Vec<f64>,
    vecs: &mut Mat,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
) -> Result<UpdateStats, String> {
    rank_one_update_tol(vals, vecs, sigma, v, engine, DEFAULT_DEFLATE_TOL)
}

/// [`rank_one_update`] with an explicit deflation tolerance.
pub fn rank_one_update_tol(
    vals: &mut Vec<f64>,
    vecs: &mut Mat,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
    tol: f64,
) -> Result<UpdateStats, String> {
    let mut ws = UpdateWorkspace::new();
    let mut basis = EigenBasis::from_mat(std::mem::replace(vecs, Mat::zeros(0, 0)));
    let result = rank_one_update_tol_ws(vals, &mut basis, sigma, v, engine, tol, &mut ws);
    *vecs = basis.into_mat();
    result
}

/// Update the eigendecomposition `(vals ascending, vecs columns)` of a
/// symmetric matrix under the perturbation `+ σ v vᵀ`, in place, using
/// caller-owned scratch — the zero-allocation streaming form.
///
/// `vecs` is `m × n` with one column per eigenpair (for full
/// decompositions `m == n`; the Hoegaerts/top-k trackers use `n < m`).
pub fn rank_one_update_ws(
    vals: &mut Vec<f64>,
    vecs: &mut EigenBasis,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
    ws: &mut UpdateWorkspace,
) -> Result<UpdateStats, String> {
    rank_one_update_tol_ws(vals, vecs, sigma, v, engine, DEFAULT_DEFLATE_TOL, ws)
}

/// [`rank_one_update_ws`] with an explicit deflation tolerance.
pub fn rank_one_update_tol_ws(
    vals: &mut Vec<f64>,
    vecs: &mut EigenBasis,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
    tol: f64,
    ws: &mut UpdateWorkspace,
) -> Result<UpdateStats, String> {
    // A pending blocked-batch rotation must be materialized before the
    // sequential path reads or mutates `vecs` directly.
    flush_rotation_ws(vecs, engine, ws);

    let n = vals.len();
    assert_eq!(vecs.cols(), n, "one eigenvector column per eigenvalue");
    assert_eq!(vecs.rows(), v.len(), "v must live in the row space of vecs");
    if n == 0 || sigma == 0.0 {
        return Ok(UpdateStats::default());
    }
    debug_assert!(
        vals.windows(2).all(|w| w[0] <= w[1]),
        "eigenvalues must be ascending"
    );

    let UpdateWorkspace {
        z,
        zhat,
        w,
        col,
        u_active,
        rotated,
        scratch,
        vals_tmp,
        perm,
        def,
        roots,
        reallocs,
        engine_gemms,
        pack,
        ..
    } = ws;

    // z = Uᵀ v — project the perturbation into the eigenbasis.
    ensure_f64(z, n, reallocs);
    crate::linalg::gemv_t_into(vecs.view(), v, z);

    // Deflate tiny weights / repeated eigenvalues (rotating U with z).
    deflate_into(vals, z, Some(vecs.view_mut()), tol, def, reallocs);
    let k = def.active.len();
    let stats = UpdateStats { deflated: def.deflated.len(), rotations: def.rotations, solved: k };
    if k == 0 {
        return Ok(stats);
    }

    // Secular solve on the active sub-problem.
    solve_all_into(&def.d_active, &def.z_active, sigma, roots, reallocs)?;

    // Gu–Eisenstat (1994) stabilization: recompute the weight vector ẑ
    // from the solved roots via the characteristic-polynomial identity,
    // so the eigenvector formula below is *exactly* consistent with the
    // computed eigenvalues. Without this, clustered poles (fast-decaying
    // kernel spectra) lose eigenvector orthogonality — the instability
    // the paper's §3 cites Gu & Eisenstat for.
    ensure_f64(zhat, k, reallocs);
    stabilized_weights_into(&def.d_active, &def.z_active, sigma, roots, zhat);

    let m = vecs.rows();
    // Fast path: with nothing deflated the active set is the whole
    // basis — rotate `vecs` directly into the double buffer and commit
    // by an O(1) swap, skipping both O(mk) copies (measured ~15% of the
    // update at m=256, §Perf).
    let full = def.deflated.is_empty() && k == vecs.cols();
    let (out_rows, out_cols, out_stride, out_len) = if full {
        (m, k, vecs.stride(), vecs.data_len())
    } else {
        (m, k, k, m * k)
    };
    ensure_f64(rotated, out_len, reallocs);

    // Gather U_active (m × k) for the deflation path; the full path
    // reads the basis in place.
    let u_view: MatView<'_> = if full {
        vecs.view()
    } else {
        ensure_f64(u_active, m * k, reallocs);
        for (c, &idx) in def.active.iter().enumerate() {
            for r in 0..m {
                u_active[r * k + c] = vecs[(r, idx)];
            }
        }
        MatView::new(u_active, m, k, k)
    };

    // Back-rotation: either the engine's fused path (AOT Pallas kernel
    // building W on-device) or the native path, which assembles W here
    // in pole-relative precision — eigenvectors of the inner problem are
    // Ũ[:,i] = D̃ᵢ⁻¹ z / ‖·‖ over active coordinates (paper eq. 6) —
    // and issues one engine GEMM for the 2mk² product.
    let out_view = MatViewMut::new(rotated, out_rows, out_cols, out_stride);
    let fused = engine.rotate_fused_into(u_view, zhat, &def.d_active, roots, out_view);
    if !fused {
        assemble_w_into(zhat, &def.d_active, roots, w, col, reallocs)?;
        let w_view = MatView::new(w, k, k, k);
        let out_view = MatViewMut::new(rotated, out_rows, out_cols, out_stride);
        engine.rotate_into_buf(u_view, w_view, out_view, pack);
    }
    *engine_gemms += 1;

    if full {
        // Commit: the rotated panel becomes the eigenvector storage.
        vecs.swap_data(rotated);
        for (c, root) in roots.iter().enumerate() {
            // Roots are already ascending and cover every position.
            vals[c] = root.value;
        }
        return Ok(stats);
    }

    // Deflation path: scatter the rotated panel back into the active
    // columns, then restore the ascending invariant (deflated values may
    // now be out of order relative to moved roots).
    for (c, &idx) in def.active.iter().enumerate() {
        vals[idx] = roots[c].value;
        for r in 0..m {
            vecs[(r, idx)] = rotated[r * k + c];
        }
    }
    sort_pairs_impl(vals, vecs, perm, vals_tmp, scratch, reallocs);
    Ok(stats)
}

/// Assemble the normalized inner eigenvector factor `W` (`k × k`,
/// column `i` is `D̃ᵢ⁻¹ ẑ / ‖·‖` over the active coordinates — paper
/// eq. 6) into workspace scratch, in pole-relative precision. Shared by
/// the sequential back-rotation and the blocked accumulation path.
fn assemble_w_into(
    zhat: &[f64],
    d: &[f64],
    roots: &[SecularRoot],
    w: &mut Vec<f64>,
    col: &mut Vec<f64>,
    reallocs: &mut u64,
) -> Result<(), String> {
    let k = roots.len();
    debug_assert_eq!(zhat.len(), k);
    ensure_f64(w, k * k, reallocs);
    ensure_f64(col, k, reallocs);
    for (i, root) in roots.iter().enumerate() {
        for j in 0..k {
            col[j] = zhat[j] / root.diff(d, j);
        }
        let nrm = norm2(col);
        if nrm == 0.0 || !nrm.is_finite() {
            return Err(format!("rank_one_update: degenerate eigenvector at root {i}"));
        }
        for j in 0..k {
            w[j * k + i] = col[j] / nrm;
        }
    }
    Ok(())
}

/// Gu–Eisenstat weight recomputation: given sorted poles `d`, original
/// weights `z` (signs only), strength `sigma` and the solved roots,
/// fill `zhat` with `ẑⱼ² = ∏ᵢ(λ̃ᵢ − dⱼ) / (σ ∏_{i≠j}(dᵢ − dⱼ))`,
/// evaluated in interlacing-paired form so every factor is an `O(1)`
/// ratio (no overflow for large `n`). All differences `λ̃ᵢ − dⱼ` are
/// formed pole-relatively through [`SecularRoot::diff`].
fn stabilized_weights_into(
    d: &[f64],
    z: &[f64],
    sigma: f64,
    roots: &[SecularRoot],
    zhat: &mut [f64],
) {
    let n = d.len();
    debug_assert_eq!(zhat.len(), n);
    for j in 0..n {
        let mut prod: f64;
        if sigma > 0.0 {
            // Interlacing: dᵢ < λ̃ᵢ < dᵢ₊₁, λ̃ₙ₋₁ < dₙ₋₁ + σ‖z‖².
            prod = -roots[n - 1].diff(d, j); // λ̃ₙ₋₁ − dⱼ > 0
            for i in 0..j {
                prod *= roots[i].diff(d, j) / (d[j] - d[i]); // (dⱼ−λ̃ᵢ)/(dⱼ−dᵢ)
            }
            for i in j..n - 1 {
                prod *= -roots[i].diff(d, j) / (d[i + 1] - d[j]); // (λ̃ᵢ−dⱼ)/(dᵢ₊₁−dⱼ)
            }
            prod /= sigma;
        } else {
            // Interlacing: dᵢ₋₁ < λ̃ᵢ < dᵢ, λ̃₀ > d₀ + σ‖z‖².
            prod = roots[0].diff(d, j); // dⱼ − λ̃₀ > 0
            for i in 1..=j {
                prod *= roots[i].diff(d, j) / (d[j] - d[i - 1]); // (dⱼ−λ̃ᵢ)/(dⱼ−dᵢ₋₁)
            }
            for i in (j + 1)..n {
                prod *= -roots[i].diff(d, j) / (d[i] - d[j]); // (λ̃ᵢ−dⱼ)/(dᵢ−dⱼ)
            }
            prod /= -sigma;
        }
        // Rounding can push a should-be-nonnegative product slightly
        // negative near exact deflation; clamp and fall back to the
        // original weight magnitude when degenerate.
        if prod.is_finite() && prod > 0.0 {
            zhat[j] = prod.sqrt().copysign(z[j]);
        } else {
            zhat[j] = z[j];
        }
    }
}

/// Expand an eigensystem with a new decoupled eigenpair
/// `(new_val, eₘ₊₁)` — the paper's expansion step before the two
/// rank-one updates (Algorithm 1 lines 1–2 / Algorithm 2 lines 13–14),
/// then restore ascending order as eq. (5)'s note requires.
/// Allocating compatibility form; see [`expand_eigensystem_ws`].
pub fn expand_eigensystem(vals: &mut Vec<f64>, vecs: &mut Mat, new_val: f64) {
    let mut ws = UpdateWorkspace::new();
    let mut basis = EigenBasis::from_mat(std::mem::replace(vecs, Mat::zeros(0, 0)));
    expand_eigensystem_ws(vals, &mut basis, new_val, &mut ws);
    *vecs = basis.into_mat();
}

/// [`expand_eigensystem`] on capacity-doubling storage: the basis grows
/// in place (amortized O(1) reallocation, O(m) writes) instead of the
/// full-copy-per-step a dense matrix forces.
///
/// While a blocked-batch rotation is pending (see
/// [`rank_one_update_fused_ws`]), the expansion is *deferred-aware*: the
/// basis still gains its identity row/column, but the sorted-order
/// column permutation is applied to the pending product `Q` (extended
/// as `diag(Q, 1)`) instead of to `U` — only `U·Q` is meaningful until
/// the flush, and this keeps the expansion from forcing one.
pub fn expand_eigensystem_ws(
    vals: &mut Vec<f64>,
    vecs: &mut EigenBasis,
    new_val: f64,
    ws: &mut UpdateWorkspace,
) {
    let (m, n) = (vecs.rows(), vecs.cols());
    debug_assert_eq!(vals.len(), n);
    vecs.expand();
    vecs[(m, n)] = 1.0;
    vals.push(new_val);
    if ws.q_dim > 0 {
        blocked::expand_pending_rotation(vals, ws);
    } else {
        sort_pairs_ws(vals, vecs, ws);
    }
}

/// Sort eigenpairs ascending, permuting columns alongside values
/// (allocating compatibility form of [`sort_pairs_ws`]).
pub fn sort_pairs(vals: &mut [f64], vecs: &mut Mat) {
    let n = vals.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
    if idx.iter().enumerate().all(|(i, &j)| i == j) {
        return;
    }
    let vals_old = vals.to_vec();
    let vecs_old = vecs.clone();
    for (newj, &oldj) in idx.iter().enumerate() {
        vals[newj] = vals_old[oldj];
        for i in 0..vecs.rows() {
            vecs[(i, newj)] = vecs_old[(i, oldj)];
        }
    }
}

/// Sort eigenpairs ascending using workspace scratch — no allocation
/// once the workspace is warm.
pub fn sort_pairs_ws(vals: &mut [f64], vecs: &mut EigenBasis, ws: &mut UpdateWorkspace) {
    let UpdateWorkspace { scratch, vals_tmp, perm, reallocs, .. } = ws;
    sort_pairs_impl(vals, vecs, perm, vals_tmp, scratch, reallocs);
}

fn sort_pairs_impl(
    vals: &mut [f64],
    vecs: &mut EigenBasis,
    perm: &mut Vec<usize>,
    vals_tmp: &mut Vec<f64>,
    scratch: &mut Vec<f64>,
    reallocs: &mut u64,
) {
    let n = vals.len();
    debug_assert_eq!(vecs.cols(), n);
    if vals.windows(2).all(|w| w[0] <= w[1]) {
        return;
    }
    if perm.capacity() < n {
        *reallocs += 1;
        perm.reserve(n);
    }
    perm.clear();
    perm.extend(0..n);
    // sort_unstable: no allocation (stable sort buffers internally).
    perm.sort_unstable_by(|&a, &b| vals[a].partial_cmp(&vals[b]).unwrap());
    ensure_f64(vals_tmp, n, reallocs);
    vals_tmp.copy_from_slice(vals);
    for (j, &p) in perm.iter().enumerate() {
        vals[j] = vals_tmp[p];
    }
    ensure_f64(scratch, n, reallocs);
    for i in 0..vecs.rows() {
        let row = vecs.row_mut(i);
        for (j, &p) in perm.iter().enumerate() {
            scratch[j] = row[p];
        }
        row.copy_from_slice(&scratch[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigh, orthogonality_defect};
    use crate::util::Rng;

    fn rand_sym(n: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.range(-1.0, 1.0);
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    fn check_update(n: usize, sigma: f64, seed: u64, tol: f64) {
        let mut rng = Rng::new(seed);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values.clone();
        let mut vecs = eg.vectors.clone();
        let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate).unwrap();
        // Reference: dense eigendecomposition of A + σvvᵀ.
        let mut b = a.clone();
        b.syr(sigma, &v);
        let expect = eigh(&b).unwrap();
        for (u, w) in vals.iter().zip(expect.values.iter()) {
            assert!((u - w).abs() < tol, "n={n} sigma={sigma}: {u} vs {w}");
        }
        // Reconstruction check (eigenvector quality).
        let rec = {
            let mut vl = vecs.clone();
            for i in 0..n {
                for j in 0..n {
                    vl[(i, j)] *= vals[j];
                }
            }
            crate::linalg::matmul_nt(&vl, &vecs)
        };
        assert!(rec.max_abs_diff(&b) < tol * 10.0, "reconstruction n={n}");
        assert!(orthogonality_defect(&vecs) < 1e-10);
    }

    #[test]
    fn update_matches_dense_small() {
        check_update(4, 1.0, 1, 1e-9);
        check_update(4, -0.5, 2, 1e-9);
    }

    #[test]
    fn update_matches_dense_medium() {
        check_update(24, 2.0, 3, 1e-8);
        check_update(24, -1.3, 4, 1e-8);
    }

    #[test]
    fn repeated_updates_stay_orthogonal() {
        let n = 16;
        let mut rng = Rng::new(9);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let (mut vals, mut vecs) = (eg.values, eg.vectors);
        for _ in 0..50 {
            let v: Vec<f64> = (0..n).map(|_| rng.range(-0.5, 0.5)).collect();
            let sigma = rng.range(0.2, 1.0);
            rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate).unwrap();
        }
        assert!(orthogonality_defect(&vecs) < 1e-8);
        for w in vals.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn workspace_updates_stay_orthogonal_and_sorted() {
        let n = 16;
        let mut rng = Rng::new(29);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values;
        let mut basis = EigenBasis::from_mat(eg.vectors);
        let mut ws = UpdateWorkspace::new();
        for _ in 0..50 {
            let v: Vec<f64> = (0..n).map(|_| rng.range(-0.5, 0.5)).collect();
            let sigma = rng.range(0.2, 1.0);
            rank_one_update_ws(&mut vals, &mut basis, sigma, &v, &NativeRotate, &mut ws)
                .unwrap();
        }
        assert!(orthogonality_defect(&basis) < 1e-8);
        for w in vals.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn deflation_fires_on_aligned_perturbation() {
        // v equal to an existing eigenvector: z has one nonzero entry →
        // n−1 deflations, eigenvalue shifts by exactly σ.
        let n = 6;
        let mut rng = Rng::new(5);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let (mut vals, mut vecs) = (eg.values.clone(), eg.vectors.clone());
        let v = eg.vectors.col(2);
        let stats = rank_one_update(&mut vals, &mut vecs, 0.7, &v, &NativeRotate).unwrap();
        assert_eq!(stats.deflated, n - 1);
        let mut expect = eg.values.clone();
        expect[2] += 0.7;
        expect.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (u, w) in vals.iter().zip(expect.iter()) {
            assert!((u - w).abs() < 1e-12);
        }
    }

    #[test]
    fn expand_inserts_sorted() {
        let mut vals = vec![1.0, 3.0];
        let mut vecs = Mat::eye(2);
        expand_eigensystem(&mut vals, &mut vecs, 2.0);
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        assert_eq!(vecs.rows(), 3);
        // The new eigenvector e₃ must sit at the sorted position (col 1).
        assert_eq!(vecs[(2, 1)], 1.0);
        assert!(orthogonality_defect(&vecs) < 1e-15);
    }

    #[test]
    fn expand_ws_matches_compat_expand() {
        let mut vals_a = vec![1.0, 3.0];
        let mut vecs_a = Mat::eye(2);
        expand_eigensystem(&mut vals_a, &mut vecs_a, 2.0);

        let mut vals_b = vec![1.0, 3.0];
        let mut basis = EigenBasis::from_mat(Mat::eye(2));
        let mut ws = UpdateWorkspace::new();
        expand_eigensystem_ws(&mut vals_b, &mut basis, 2.0, &mut ws);
        assert_eq!(vals_a, vals_b);
        assert_eq!(basis.max_abs_diff(&vecs_a), 0.0);
    }

    #[test]
    fn zero_sigma_is_noop() {
        let mut vals = vec![1.0, 2.0];
        let mut vecs = Mat::eye(2);
        let before = vecs.clone();
        rank_one_update(&mut vals, &mut vecs, 0.0, &[0.3, 0.4], &NativeRotate).unwrap();
        assert_eq!(vals, vec![1.0, 2.0]);
        assert_eq!(vecs.max_abs_diff(&before), 0.0);
    }

    #[test]
    fn property_random_updates_match_dense() {
        crate::util::prop::check("rankone-matches-dense", 16, |rng| {
            let n = 2 + rng.below(12);
            let a = rand_sym(n, rng);
            let eg = eigh(&a).map_err(|e| e.to_string())?;
            let (mut vals, mut vecs) = (eg.values, eg.vectors);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
            let sigma = rng.range(-2.0, 2.0);
            rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate)
                .map_err(|e| e.to_string())?;
            let mut b = a.clone();
            b.syr(sigma, &v);
            let expect = eigh(&b).map_err(|e| e.to_string())?;
            for (u, w) in vals.iter().zip(expect.values.iter()) {
                crate::util::prop::close("eigenvalue", *u, *w, 1e-7)?;
            }
            crate::util::prop::ensure(orthogonality_defect(&vecs) < 1e-8, || {
                format!("orthogonality defect {}", orthogonality_defect(&vecs))
            })
        });
    }

    #[test]
    fn interlacing_property_after_update() {
        crate::util::prop::check("rankone-interlacing", 12, |rng| {
            let n = 3 + rng.below(8);
            let a = rand_sym(n, rng);
            let eg = eigh(&a).map_err(|e| e.to_string())?;
            let old = eg.values.clone();
            let (mut vals, mut vecs) = (eg.values, eg.vectors);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
            let sigma = rng.range(0.1, 2.0);
            rank_one_update(&mut vals, &mut vecs, sigma, &v, &NativeRotate)
                .map_err(|e| e.to_string())?;
            // λᵢ ≤ λ̃ᵢ ≤ λᵢ₊₁ for σ > 0 (paper eq. 5).
            for i in 0..n {
                crate::util::prop::ensure(vals[i] >= old[i] - 1e-9, || {
                    format!("lower interlace violated at {i}")
                })?;
                if i + 1 < n {
                    crate::util::prop::ensure(vals[i] <= old[i + 1] + 1e-9, || {
                        format!("upper interlace violated at {i}")
                    })?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rotate_engine_receives_gathered_panels() {
        struct Spy(std::sync::atomic::AtomicUsize);
        impl Rotate for Spy {
            fn rotate_into(&self, u: MatView<'_>, w: MatView<'_>, out: MatViewMut<'_>) {
                self.0.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                NativeRotate.rotate_into(u, w, out);
            }
        }
        let spy = Spy(std::sync::atomic::AtomicUsize::new(0));
        let mut rng = Rng::new(31);
        let a = rand_sym(8, &mut rng);
        let eg = eigh(&a).unwrap();
        let (mut vals, mut vecs) = (eg.values, eg.vectors);
        let v: Vec<f64> = (0..8).map(|_| rng.range(-1.0, 1.0)).collect();
        rank_one_update(&mut vals, &mut vecs, 1.0, &v, &spy).unwrap();
        assert_eq!(spy.0.load(std::sync::atomic::Ordering::Relaxed), 1);
    }
}
