"""L1 Pallas kernel for the BNS78 eigenvector back-rotation — the 2n^3
hot spot of the paper's rank-one update (eq. 6):

    U_new[:, i] = U @ w_i / ||w_i||,   w_i[j] = z_j / (lam_j - lam~_i).

The kernel fuses construction of the (normalized) inner-eigenvector
matrix W into the matmul's K-loop: each (BK, BN) tile of W is built
on-VMEM from three vectors (z, lam, lam_new) instead of being read from
HBM, saving the K*K matrix round-trip entirely. Column norms arrive as a
precomputed inverse-norm vector (an O(K^2) side computation done by the
L2 wrapper).

TPU mapping: the W-tile build is VPU elementwise work; the dot is an
MXU contraction; accumulation runs over the innermost grid axis with a
VMEM accumulator, the standard Pallas matmul schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _rotate_kernel(u_ref, z_ref, lam_ref, lamn_ref, inv_ref, o_ref):
    """Grid (i, j, k): o[i, j] += u[i, k] @ W[k, j] with W built in-tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    z = z_ref[...]          # (BK,)
    lam = lam_ref[...]      # (BK,)
    lamn = lamn_ref[...]    # (BN,)
    inv = inv_ref[...]      # (BN,)
    # W tile: z_j / (lam_j - lam~_i), normalized per output column.
    w = (z[:, None] / (lam[:, None] - lamn[None, :])) * inv[None, :]
    o_ref[...] += jnp.dot(u_ref[...], w)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def rotate(u, z, lam, lam_new, inv_norms, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Pallas fused rotation: returns U @ normalize_cols(W).

    All of m, k must be multiples of the block sizes (the AOT bucket
    ladder guarantees this; callers pad — zero rows of U and zero z
    entries are absorbed, padded lam/lam_new values must be distinct and
    far from real eigenvalues, see runtime::pad contract).
    """
    m, k = u.shape
    assert k == z.shape[0] == lam.shape[0] == lam_new.shape[0] == inv_norms.shape[0]
    bm = min(bm, m)
    bn = min(bn, k)
    bk = min(bk, k)
    assert m % bm == 0 and k % bn == 0 and k % bk == 0
    return pl.pallas_call(
        _rotate_kernel,
        grid=(m // bm, k // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk,), lambda i, j, kk: (kk,)),
            pl.BlockSpec((bk,), lambda i, j, kk: (kk,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), u.dtype),
        interpret=True,
    )(u, z, lam, lam_new, inv_norms)
