//! Dense row-major `f64` matrix — the base type for every substrate in
//! this crate. Deliberately minimal: storage, indexing, views over rows,
//! transpose, symmetry helpers. Heavy numerics live in sibling modules
//! (`gemm`, `eigh`, …).

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix of shape `rows × cols`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Build from a flat row-major vector. Panics if length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec length mismatch");
        Mat { rows, cols, data }
    }

    /// Diagonal matrix from a slice.
    pub fn from_diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    /// Flat row-major data slice.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` copied into a new vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        debug_assert!(j < self.cols);
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Set column `j` from a slice.
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Append one row (amortized `O(cols)` — `Vec` growth doubles, so
    /// streaming appenders like the Nyström cross-Gram never re-layout).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols, "push_row width mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Remove row `i` in place (`O(rows · cols)` shift, no
    /// reallocation — the inverse of [`Mat::push_row`], used by the
    /// bounded-memory Nyström layer when a landmark is evicted).
    pub fn remove_row(&mut self, i: usize) {
        assert!(i < self.rows, "remove_row out of range");
        if i + 1 < self.rows {
            self.data.copy_within((i + 1) * self.cols.., i * self.cols);
        }
        self.data.truncate((self.rows - 1) * self.cols);
        self.rows -= 1;
    }

    /// Consume into the flat row-major backing vector.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Elementwise `self + s * other`.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// `self - other` as a new matrix.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// `self + other` as a new matrix.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Leading `r × c` sub-matrix copied out.
    pub fn submatrix(&self, r: usize, c: usize) -> Mat {
        assert!(r <= self.rows && c <= self.cols);
        Mat::from_fn(r, c, |i, j| self[(i, j)])
    }

    /// Symmetric rank-one update `self += sigma * v vᵀ` (square only).
    pub fn syr(&mut self, sigma: f64, v: &[f64]) {
        assert!(self.is_square() && v.len() == self.rows);
        for i in 0..self.rows {
            let vi = sigma * v[i];
            let row = self.row_mut(i);
            for j in 0..v.len() {
                row[j] += vi * v[j];
            }
        }
    }

    /// Max absolute elementwise difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Enforce exact symmetry by averaging with the transpose (in place).
    pub fn symmetrize(&mut self) {
        assert!(self.is_square());
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let avg = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = avg;
                self[(j, i)] = avg;
            }
        }
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, v| m.max(v.abs()))
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// Dot product of two slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm of a slice.
#[inline]
pub fn norm2(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_eye_indexing() {
        let z = Mat::zeros(3, 4);
        assert_eq!(z.rows(), 3);
        assert_eq!(z.cols(), 4);
        assert_eq!(z[(2, 3)], 0.0);
        let e = Mat::eye(3);
        assert_eq!(e[(1, 1)], 1.0);
        assert_eq!(e[(0, 1)], 0.0);
    }

    #[test]
    fn from_fn_and_transpose() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        let t = m.transpose();
        assert_eq!(t.rows(), 3);
        for i in 0..2 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], t[(j, i)]);
            }
        }
    }

    #[test]
    fn syr_matches_dense() {
        let mut m = Mat::eye(3);
        let v = [1.0, 2.0, 3.0];
        m.syr(0.5, &v);
        for i in 0..3 {
            for j in 0..3 {
                let expect = if i == j { 1.0 } else { 0.0 } + 0.5 * v[i] * v[j];
                assert!((m[(i, j)] - expect).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn row_col_access() {
        let m = Mat::from_fn(3, 3, |i, j| (i + 10 * j) as f64);
        assert_eq!(m.row(1), &[1.0, 11.0, 21.0]);
        assert_eq!(m.col(2), vec![20.0, 21.0, 22.0]);
    }

    #[test]
    fn submatrix_copies_leading_block() {
        let m = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let s = m.submatrix(2, 3);
        assert_eq!(s.rows(), 2);
        assert_eq!(s.cols(), 3);
        assert_eq!(s[(1, 2)], m[(1, 2)]);
    }

    #[test]
    fn symmetrize_forces_symmetry() {
        let mut m = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        m.symmetrize();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
    }

    #[test]
    fn remove_row_shifts_and_preserves() {
        let mut m = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let cap = m.as_slice().len();
        m.remove_row(1);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(0), &[0.0, 1.0, 2.0]);
        assert_eq!(m.row(1), &[6.0, 7.0, 8.0]);
        assert_eq!(m.row(2), &[9.0, 10.0, 11.0]);
        assert_eq!(m.as_slice().len(), cap - 3);
        // Removing the last row is a pure truncate.
        m.remove_row(2);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[6.0, 7.0, 8.0]);
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }
}
