//! Eviction oracle suite: the bounded-memory down-date path against the
//! batch-recompute ground truth. A landmark eviction is two rank-one
//! updates (the exact reverse of the eq. 2 expansion) plus a drop of the
//! decoupled pair, so a state that evicts and re-adds must land on
//! *exactly* the eigensystem a from-scratch build over its retained rows
//! yields (≤ 1e-10) — across kernel families, both mean-adjust modes,
//! mid-batch evictions, and evictions deferred into a fused pending Q.
//! Plus the ridge-leverage property layer: scores are non-negative, sum
//! to the effective rank, and the argmin victim never comes from the
//! protected seed prefix.

mod common;

use common::oracle;
use inkpca::data::Dataset;
use inkpca::kernels::{Kernel, Linear, Polynomial, Rbf};
use inkpca::kpca::{BatchRotation, EvictionPolicy, IncrementalKpca};
use inkpca::rankone::NativeRotate;
use inkpca::util::prop::{check, default_cases, ensure};
use inkpca::util::Rng;

fn kernels() -> Vec<Box<dyn Kernel>> {
    vec![
        Box::new(Rbf { sigma: 1.5 }),
        Box::new(Linear),
        Box::new(Polynomial { degree: 2, offset: 1.0 }),
    ]
}

/// Seed an incremental state from the first `seed_n` rows of `ds`.
fn seeded<'k>(
    kern: &'k dyn Kernel,
    ds: &Dataset,
    seed_n: usize,
    mean_adjust: bool,
) -> IncrementalKpca<'k> {
    let seed = ds.x.submatrix(seed_n, ds.dim());
    IncrementalKpca::from_batch(kern, &seed, mean_adjust).unwrap()
}

/// The acceptance bar: evict + re-add ≡ full batch recompute over the
/// retained rows, ≤ 1e-10, for every kernel family × adjust mode.
/// Evictions hit the interior, the first unprotected slot and the last
/// slot, so the row/eigenpair shifts are exercised at both ends.
#[test]
fn evict_and_readd_matches_batch_recompute_all_kernels() {
    for kern in kernels() {
        for mean_adjust in [false, true] {
            let ds = oracle::std_stream(18, 4301);
            let mut inc = seeded(kern.as_ref(), &ds, 8, mean_adjust);
            for i in 8..14 {
                inc.push(ds.x.row(i)).unwrap();
            }
            let m0 = inc.len();
            // Evict: an interior row, then (post-shift) the first and
            // the last retained row.
            inc.remove_point(5, &NativeRotate).unwrap();
            inc.remove_point(0, &NativeRotate).unwrap();
            inc.remove_point(inc.len() - 1, &NativeRotate).unwrap();
            assert_eq!(inc.evictions(), 3);
            assert_eq!(inc.len(), m0 - 3);
            // Re-add fresh points on the downdated state.
            for i in 14..ds.n() {
                inc.push(ds.x.row(i)).unwrap();
            }
            let gap = oracle::kpca_oracle_gap(kern.as_ref(), &inc);
            assert!(
                gap <= 1e-10,
                "{} adjust={mean_adjust}: evict+re-add vs batch recompute gap {gap}",
                kern.name()
            );
        }
    }
}

/// A mean-adjusted down-date re-centers over the survivors, which needs
/// m ≥ 3; below that the removal must refuse, not corrupt.
#[test]
fn mean_adjusted_removal_needs_three_points() {
    let ds = oracle::std_stream(4, 4305);
    let kern = Rbf { sigma: 1.5 };
    let mut inc = seeded(&kern, &ds, 2, true);
    assert!(inc.remove_point(0, &NativeRotate).is_err());
    // Untouched: the failed removal left the state usable.
    assert_eq!(inc.len(), 2);
    inc.push(ds.x.row(2)).unwrap();
    assert!(inc.remove_point(0, &NativeRotate).is_ok());
    let gap = oracle::kpca_oracle_gap(&kern, &inc);
    assert!(gap <= 1e-10, "post-refusal state drifted: {gap}");
}

/// Bounded sequential stream: the cap holds at fixed m, the protected
/// seed prefix survives verbatim, the eviction counter advances once
/// per over-cap accept, and the long-run state still tracks its batch
/// ground truth (drift bar, ~30 evictions deep).
#[test]
fn bounded_stream_pins_cap_and_tracks_oracle() {
    for policy in [EvictionPolicy::Uniform, EvictionPolicy::LeverageScore] {
        for mean_adjust in [false, true] {
            let ds = oracle::std_stream(40, 4302);
            let kern = Rbf { sigma: 1.5 };
            let (cap, protected) = (12, 6);
            let mut inc = seeded(&kern, &ds, protected, mean_adjust);
            inc.set_bound(cap, policy, protected);
            let mut accepted = protected;
            for i in protected..ds.n() {
                if inc.push(ds.x.row(i)).unwrap() {
                    accepted += 1;
                }
                assert!(inc.len() <= cap, "{policy:?}: cap breached at point {i}");
            }
            assert_eq!(inc.len(), cap, "{policy:?}: enough accepts to fill the cap");
            assert_eq!(inc.evictions(), accepted - cap, "{policy:?}");
            // The seed prefix is never a victim.
            for i in 0..protected {
                assert_eq!(inc.row(i), ds.x.row(i), "{policy:?}: protected row {i} evicted");
            }
            let gap = oracle::kpca_oracle_gap(&kern, &inc);
            assert!(gap < 1e-7, "{policy:?} adjust={mean_adjust}: long-run gap {gap}");
            let s = inc.sufficiency_gap();
            assert!((0.0..=1.0).contains(&s), "{policy:?}: sufficiency gauge {s}");
        }
    }
}

/// Mid-batch evictions under the fused strategy: the down-date defers
/// into the accumulating pending Q instead of forcing a flush, and the
/// batched bounded run lands exactly (≤ 1e-10) on the sequential
/// bounded run's eigensystem. Uniform policy — its victim sequence is a
/// pure function of the eviction counter, so both runs evict the same
/// rows. The batch size straddles several enforcement points, so every
/// eviction after the first lands on a non-empty pending product.
#[test]
fn mid_batch_eviction_defers_into_pending_q() {
    for mean_adjust in [false, true] {
        let ds = oracle::std_stream(36, 4303);
        let kern = Rbf { sigma: 1.2 };
        let (cap, protected) = (10, 6);
        let dim = ds.dim();
        let flat = ds.x.as_slice();

        let mut seq = seeded(&kern, &ds, protected, mean_adjust);
        seq.set_bound(cap, EvictionPolicy::Uniform, protected);
        for i in protected..ds.n() {
            seq.push(ds.x.row(i)).unwrap();
        }

        let mut fus = seeded(&kern, &ds, protected, mean_adjust);
        fus.set_bound(cap, EvictionPolicy::Uniform, protected);
        fus.batch_rotation = Some(BatchRotation::Fused);
        let mut i = protected;
        while i < ds.n() {
            let end = (i + 8).min(ds.n());
            fus.push_batch(&flat[i * dim..end * dim]).unwrap();
            assert!(
                !fus.workspace().pending_rotation(),
                "no pending rotation may survive a batch boundary"
            );
            i = end;
        }

        // The deferral actually happened: rotations folded, evictions
        // landed, and strictly fewer engine GEMMs than eager rotation.
        assert!(fus.workspace().fused_updates() > 0);
        assert!(fus.evictions() > 0);
        assert_eq!(fus.evictions(), seq.evictions(), "adjust={mean_adjust}");
        assert!(
            fus.engine_gemms() < seq.engine_gemms(),
            "adjust={mean_adjust}: fused {} vs sequential {} engine GEMMs",
            fus.engine_gemms(),
            seq.engine_gemms()
        );

        assert_eq!(fus.len(), seq.len());
        for (a, b) in fus.vals.iter().zip(&seq.vals) {
            assert!(
                (a - b).abs() <= 1e-10,
                "adjust={mean_adjust}: eigenvalue {a} vs {b}"
            );
        }
        let diff = fus.reconstruct().max_abs_diff(&seq.reconstruct());
        assert!(diff <= 1e-10, "adjust={mean_adjust}: fused vs sequential diff {diff}");
        let gap = oracle::kpca_oracle_gap(&kern, &fus);
        assert!(gap < 1e-7, "adjust={mean_adjust}: batched bounded gap {gap}");
    }
}

/// An eviction straddling a *live* pending Q: fold a fused batch whose
/// bound enforcement fires while earlier updates of the same batch are
/// still pending, then keep streaming single points. The downdated pair
/// removal is read through the pending product (deferred column drop),
/// so the continuation must stay exact.
#[test]
fn eviction_straddling_fused_pending_q_stays_exact() {
    let ds = oracle::std_stream(30, 4304);
    let kern = Rbf { sigma: 1.0 };
    let (cap, protected) = (9, 5);
    let dim = ds.dim();
    let flat = ds.x.as_slice();
    let mut inc = seeded(&kern, &ds, protected, false);
    inc.set_bound(cap, EvictionPolicy::Uniform, protected);
    inc.batch_rotation = Some(BatchRotation::Fused);
    // One big batch: the first few accepts fill the cap with rotations
    // pending, every later accept evicts against that pending product.
    inc.push_batch(&flat[protected * dim..20 * dim]).unwrap();
    assert!(inc.evictions() > 0);
    // Continue sequentially on the flushed state.
    for i in 20..ds.n() {
        inc.push(ds.x.row(i)).unwrap();
    }
    let gap = oracle::kpca_oracle_gap(&kern, &inc);
    assert!(gap <= 1e-7, "straddled eviction gap {gap}");
}

/// Ridge-leverage property layer (in-tree driver): over random kernels,
/// sizes and streams — scores are non-negative, their sum is the
/// effective rank `Σ_c λ⁺_c/(λ⁺_c + μ)` at ridge `μ = trace⁺/m` (an
/// orthonormality identity), and the bounded argmin victim is never a
/// protected row.
#[test]
fn prop_leverage_scores_sum_to_effective_rank() {
    check("leverage-scores", default_cases().min(12), |rng| {
        let n = 10 + rng.below(12);
        let seed_n = 3 + rng.below(3);
        let kern: Box<dyn Kernel> = match rng.below(3) {
            0 => Box::new(Rbf { sigma: rng.range(0.5, 3.0) }),
            1 => Box::new(Linear),
            _ => Box::new(Polynomial { degree: 2, offset: rng.range(0.5, 2.0) }),
        };
        let adjust = rng.uniform() < 0.5;
        let ds = oracle::std_stream(n, rng.next_u64());
        let mut inc = seeded(kern.as_ref(), &ds, seed_n, adjust);
        for i in seed_n..n {
            inc.push(ds.x.row(i)).map_err(|e| e.to_string())?;
        }
        let mut lev = Vec::new();
        inc.leverage_scores(&NativeRotate, &mut lev);
        ensure(lev.len() == inc.len(), || "one score per landmark".to_string())?;
        for (i, &l) in lev.iter().enumerate() {
            ensure(l >= -1e-12, || format!("negative leverage {l} at {i}"))?;
            ensure(l <= 1.0 + 1e-9, || format!("leverage {l} > 1 at {i}"))?;
        }
        let trace_pos: f64 = inc.vals.iter().map(|l| l.max(0.0)).sum();
        if trace_pos > 0.0 {
            let mu = trace_pos / inc.len() as f64;
            let effective_rank: f64 =
                inc.vals.iter().map(|&l| l.max(0.0) / (l.max(0.0) + mu)).sum();
            let sum: f64 = lev.iter().sum();
            ensure((sum - effective_rank).abs() <= 1e-8 * effective_rank.max(1.0), || {
                format!("Σℓ = {sum} vs effective rank {effective_rank}")
            })?;
        }
        Ok(())
    });
}

/// The leverage policy's victim is always an unprotected row, for every
/// protected-prefix size the bound allows — random streams, random
/// caps.
#[test]
fn prop_leverage_eviction_never_hits_protected_prefix() {
    check("protected-prefix", default_cases().min(10), |rng| {
        let n = 16 + rng.below(12);
        let protected = 3 + rng.below(4);
        let cap = protected + 2 + rng.below(4);
        let kern = Rbf { sigma: rng.range(0.8, 2.5) };
        let ds = oracle::std_stream(n, rng.next_u64());
        let mut inc = seeded(&kern, &ds, protected, rng.uniform() < 0.5);
        inc.set_bound(cap, EvictionPolicy::LeverageScore, protected);
        for i in protected..n {
            inc.push(ds.x.row(i)).map_err(|e| e.to_string())?;
            ensure(inc.len() <= cap, || format!("cap {cap} breached"))?;
            for p in 0..protected {
                ensure(inc.row(p) == ds.x.row(p), || {
                    format!("protected row {p} evicted (cap {cap}, protected {protected})")
                })?;
            }
        }
        ensure(inc.evictions() > 0, || "stream never reached the cap".to_string())
    });
}
