//! Experiment harnesses regenerating every evaluation artifact in the
//! paper (DESIGN.md §2): Figure 1 (incremental-KPCA drift), Figure 2
//! (incremental Nyström accuracy), the §3 flop/table comparison, and
//! the §5.1 orthogonality diagnostic (a Fig. 1 column). Each harness
//! prints a human-readable summary and writes CSV rows under
//! `results/` for plotting.

pub mod fig1;
pub mod fig2;
pub mod flops;

pub use fig1::{run_fig1, Fig1Config};
pub use fig2::{run_fig2, Fig2Config};
pub use flops::{run_flops, FlopsConfig};

use std::path::PathBuf;

/// Create `results/` and open a CSV file with a header.
pub fn csv_writer(name: &str, header: &str) -> std::io::Result<(std::fs::File, PathBuf)> {
    use std::io::Write;
    std::fs::create_dir_all("results")?;
    let path = PathBuf::from("results").join(name);
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    Ok((f, path))
}

/// Shared run-mode flag: quick (CI-sized) vs full (paper-sized).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RunMode {
    #[default]
    Quick,
    Full,
}

impl RunMode {
    pub fn from_args(args: &[String]) -> RunMode {
        if args.iter().any(|a| a == "--full") {
            RunMode::Full
        } else {
            RunMode::Quick
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writer_creates_file() {
        let (mut f, path) = csv_writer("test_tmp.csv", "a,b").unwrap();
        use std::io::Write;
        writeln!(f, "1,2").unwrap();
        drop(f);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n1,2"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn run_mode_parsing() {
        assert_eq!(RunMode::from_args(&[]), RunMode::Quick);
        assert_eq!(RunMode::from_args(&["--full".into()]), RunMode::Full);
    }
}
