//! End-to-end coordinator bench: streaming throughput through the L3
//! server (channel + worker + incremental update) vs driving the
//! algorithm directly — the coordinator overhead target in DESIGN.md
//! §Perf is <5% at m≈256. Also compares native vs PJRT engines when
//! artifacts are present.

use inkpca::coordinator::{Config, Coordinator, EngineConfig, EnginePolicy, KernelConfig};
use inkpca::data::load;
use inkpca::kernels::{median_heuristic, Rbf};
use inkpca::kpca::IncrementalKpca;
use inkpca::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let n = if std::env::var("INKPCA_BENCH_FAST").is_ok() { 120 } else { 240 };
    let mut ds = load("yeast", n, 42).unwrap();
    ds.standardize();
    let dim = ds.dim();
    let sigma = median_heuristic(&ds.x, 200);

    // Direct drive: algorithm without the coordinator.
    b.case(&format!("e2e/direct/n{n}"), || {
        let kern = Rbf { sigma };
        let seed = ds.x.submatrix(20, dim);
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 20..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        inc.len()
    });

    // Through the coordinator (native engine).
    b.case(&format!("e2e/coordinator_native/n{n}"), || {
        let coord = Coordinator::spawn(
            Config {
                kernel: KernelConfig::Rbf { sigma },
                mean_adjust: true,
                engine: EngineConfig::Native,
                queue: 64,
                seed_points: 20,
                drift_every: 0,
                ..Config::default()
            },
            dim,
        );
        for i in 0..ds.n() {
            coord.ingest(ds.x.row(i).to_vec()).unwrap();
        }
        coord.shutdown().accepted
    });

    // Through the coordinator (PJRT engine), if artifacts exist. Capped
    // at 120 points: the interpret-lowered Pallas path costs ~10-100 ms
    // per rotation on CPU (see EXPERIMENTS.md §Perf).
    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        let np = 120.min(ds.n());
        b.case(&format!("e2e/coordinator_pjrt/n{np}"), || {
            let coord = Coordinator::spawn(
                Config {
                    kernel: KernelConfig::Rbf { sigma },
                    mean_adjust: true,
                    engine: EngineConfig::Pjrt {
                        dir: "artifacts".into(),
                        policy: EnginePolicy::Pjrt,
                    },
                    queue: 64,
                    seed_points: 20,
                    drift_every: 0,
                    ..Config::default()
                },
                dim,
            );
            for i in 0..np {
                coord.ingest(ds.x.row(i).to_vec()).unwrap();
            }
            coord.shutdown().accepted
        });
    }
    b.finish();
    if let Err(e) = b.write_json("BENCH_e2e.json") {
        eprintln!("warning: could not write BENCH_e2e.json: {e}");
    } else {
        println!("wrote BENCH_e2e.json");
    }
}
