//! Quickstart: fit incremental kernel PCA on a small stream, verify it
//! reproduces batch KPCA exactly, and project new points.
//!
//!     cargo run --release --example quickstart

use inkpca::data::load;
use inkpca::kernels::{median_heuristic, Rbf};
use inkpca::kpca::{BatchKpca, IncrementalKpca};

fn main() -> Result<(), String> {
    // 1. Data: yeast-like synthetic (or data/yeast.data if present).
    let mut ds = load("yeast", 100, 7)?;
    ds.standardize();
    println!("dataset: {} ({} × {})", ds.name, ds.n(), ds.dim());

    // 2. Kernel with the paper's median heuristic.
    let sigma = median_heuristic(&ds.x, 200);
    let kern = Rbf { sigma };
    println!("rbf sigma (median heuristic): {sigma:.4}");

    // 3. Seed from the first 20 points, stream the rest (Algorithm 2).
    let seed = ds.x.submatrix(20, ds.dim());
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, true)?;
    for i in 20..ds.n() {
        inc.push(ds.x.row(i))?;
    }
    println!(
        "streamed {} points: {} rank-one updates, {} deflations",
        inc.len(),
        inc.stats.updates,
        inc.stats.deflated
    );

    // 4. Exactness: incremental == batch (up to numerical drift).
    let batch = BatchKpca::fit(&kern, &ds.x, true)?;
    let drift = inc.reconstruct().max_abs_diff(&batch.k_used);
    println!("drift vs batch K': {drift:.3e}");
    // Drift grows slowly with the number of rank-one updates (Fig. 1);
    // after 80 streamed points it sits well below 1e-5.
    assert!(drift < 1e-5, "incremental diverged from batch");

    // 5. Top principal components and a projection.
    let top: Vec<f64> = inc.vals.iter().rev().take(5).copied().collect();
    println!("top-5 eigenvalues: {top:?}");
    let probe = vec![0.5; ds.dim()];
    let scores = inc.project(&probe, 3);
    println!("projection of probe point on top-3 components: {scores:?}");
    println!("quickstart OK");
    Ok(())
}
