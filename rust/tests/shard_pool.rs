//! Shard-pool integration tests: concurrent multi-stream ingest with
//! stream isolation (every stream's eigensystem must match its
//! single-stream reference run), per-stream metrics attribution, the
//! steady-state allocation gauge, and clean close/shutdown semantics —
//! all through the resolved [`StreamHandle`] front-end.

mod common;

use common::oracle;
use inkpca::coordinator::{EngineConfig, KernelConfig, PoolConfig, ShardPool, StreamConfig};
use inkpca::data::synthetic::yeast_like;
use inkpca::data::Dataset;
use inkpca::kpca::IncrementalKpca;

fn stream_cfg(sigma: f64, seed_points: usize) -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma },
        mean_adjust: true,
        seed_points,
        ..StreamConfig::default()
    }
}

fn pool_cfg(shards: usize) -> PoolConfig {
    PoolConfig { shards, queue: 8, engine: EngineConfig::Native, ..PoolConfig::default() }
}

/// Reference: the same stream driven directly, single-threaded, through
/// the identical engine type the shard workers use.
fn reference_run(ds: &Dataset, sigma: f64, seed_points: usize) -> IncrementalKpca<'static> {
    oracle::reference_run(ds, ds.n(), sigma, seed_points)
}

#[test]
fn concurrent_streams_across_shards_stay_isolated() {
    const STREAMS: usize = 4;
    const N: usize = 26;
    const SEED_POINTS: usize = 6;
    let datasets: Vec<Dataset> = (0..STREAMS)
        .map(|s| {
            let ds = oracle::std_stream(N, 700 + s as u64);
            ds
        })
        .collect();
    let sigmas: Vec<f64> = (0..STREAMS).map(|s| 1.0 + 0.4 * s as f64).collect();

    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    // One producer thread per stream, all ingesting interleaved.
    let handles: Vec<_> = std::thread::scope(|scope| {
        let joins: Vec<_> = (0..STREAMS)
            .map(|si| {
                let r = router.clone();
                let ds = &datasets[si];
                let sigma = sigmas[si];
                scope.spawn(move || {
                    let id = format!("stream-{si}");
                    let h = r.open_stream(&id, ds.dim(), stream_cfg(sigma, SEED_POINTS)).unwrap();
                    for i in 0..ds.n() {
                        let reply = r.ingest(&h, ds.x.row(i).to_vec()).unwrap();
                        assert!(reply.accepted);
                    }
                    h
                })
            })
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    // Both shards must actually own streams (4 ids, 2 shards).
    let owned: std::collections::HashSet<usize> = handles.iter().map(|h| h.shard()).collect();
    assert_eq!(owned.len(), 2, "4 streams should spread over both shards");

    // Every stream's final eigensystem matches its isolated reference.
    for (si, h) in handles.iter().enumerate() {
        assert_eq!(h.id(), format!("stream-{si}"));
        let reference = reference_run(&datasets[si], sigmas[si], SEED_POINTS);
        let snap = router.snapshot(h).unwrap();
        assert_eq!(snap.m, N, "{}", h.id());
        let top_ref: Vec<f64> = reference.vals.iter().rev().take(10).copied().collect();
        assert_eq!(snap.top_values.len(), top_ref.len());
        for (got, want) in snap.top_values.iter().zip(&top_ref) {
            assert!(
                (got - want).abs() <= 1e-10,
                "{}: eigenvalue {got} vs reference {want}",
                h.id()
            );
        }
        // Projections (which exercise eigenvectors + centering sums)
        // agree too — magnitudes, since eigenvector sign is arbitrary.
        let probe = vec![0.25; datasets[si].dim()];
        let got = router.project(h, probe.clone(), 4).unwrap();
        let want = reference.project(&probe, 4);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g.abs() - w.abs()).abs() <= 1e-10,
                "{}: projection {g} vs reference {w}",
                h.id()
            );
        }
        // And the tracked eigensystem is still exact wrt batch.
        let drift = router.measure_drift(h).unwrap();
        assert!(drift.norms.frobenius < 1e-7, "{}: drift {:?}", h.id(), drift.norms);
    }
    pool.shutdown();
}

#[test]
fn per_stream_metrics_attribution_and_allocation_gauge() {
    let big = oracle::std_stream(40, 801);
    let small = oracle::std_stream(18, 802);

    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let hb = router.open_stream("big", big.dim(), stream_cfg(1.5, 5)).unwrap();
    let hs = router.open_stream("small", small.dim(), stream_cfg(1.5, 5)).unwrap();
    for i in 0..big.n() {
        router.ingest(&hb, big.x.row(i).to_vec()).unwrap();
    }
    for i in 0..small.n() {
        router.ingest(&hs, small.x.row(i).to_vec()).unwrap();
    }
    // One dimension-mismatch error attributed to `small` only.
    assert!(router.ingest(&hs, vec![0.0; small.dim() + 1]).is_err());

    let mb = router.metrics(&hb).unwrap();
    let ms = router.metrics(&hs).unwrap();
    assert_eq!(mb.accepted, (40 - 5) as u64);
    assert_eq!(ms.accepted, (18 - 5) as u64);
    assert_eq!(mb.errors, 0);
    assert_eq!(ms.errors, 1);
    // The acceptance gauge: steady-state per-stream ingest stays
    // allocation-free — growth events per update pinned below 1.
    assert!(mb.reallocs_per_update < 1.0, "big: {mb}");
    assert!(ms.reallocs_per_update < 1.0, "small: {ms}");
    assert!(mb.ws_bytes_resident > ms.ws_bytes_resident, "bigger stream, more resident");

    // Pool rollup sums the counters and attributes gauges per stream.
    let snap = router.pool_snapshot().unwrap();
    assert_eq!(snap.streams, 2);
    assert_eq!(snap.accepted, mb.accepted + ms.accepted);
    assert_eq!(snap.errors, 1);
    assert_eq!(snap.total_ws_bytes, mb.ws_bytes_resident + ms.ws_bytes_resident);
    assert_eq!(snap.ingest_count, (40 + 18 + 1) as u64);
    assert_eq!(snap.per_stream.len(), 2);
    let gb = snap.per_stream.iter().find(|g| g.stream == "big").unwrap();
    let gs = snap.per_stream.iter().find(|g| g.stream == "small").unwrap();
    assert_eq!(gb.m, 40);
    assert_eq!(gs.m, 18);
    assert!(gb.reallocs_per_update < 1.0 && gs.reallocs_per_update < 1.0);
    assert_eq!(gb.shard, hb.shard());
    assert_eq!(gs.shard, hs.shard());
    assert_eq!(hb.shard(), router.shard_of("big"));
    assert_eq!(hs.shard(), router.shard_of("small"));
    pool.shutdown();
}

#[test]
fn close_stream_frees_state_and_returns_stats() {
    let ds = yeast_like(20, 803);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let handles: Vec<_> = ["a", "b", "c"]
        .iter()
        .map(|id| {
            let h = router.open_stream(id, ds.dim(), stream_cfg(1.0, 5)).unwrap();
            for i in 0..ds.n() {
                router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
            }
            h
        })
        .collect();
    let stats = router.close_stream(&handles[1]).unwrap();
    assert_eq!(stats.accepted, 20);
    // The closed handle is stale; the others keep serving.
    assert!(router.ingest(&handles[1], ds.x.row(0).to_vec()).is_err());
    assert!(router.snapshot(&handles[1]).is_err());
    assert_eq!(router.snapshot(&handles[0]).unwrap().m, 20);
    assert!(router.project(&handles[2], vec![0.1; ds.dim()], 2).is_ok());
    let snap = router.pool_snapshot().unwrap();
    assert_eq!(snap.streams, 2);
    // Pool counters are monotonic under churn: the closed stream's
    // accepts/latency stay in the lifetime totals.
    assert_eq!(snap.accepted, 3 * (20 - 5) as u64);
    assert_eq!(snap.ingest_count, 3 * 20);
    // The id can be reopened fresh after close (possibly reusing the
    // slot — under a new generation).
    let hb2 = router.open_stream("b", ds.dim(), stream_cfg(1.0, 5)).unwrap();
    assert_eq!(router.snapshot(&hb2).unwrap().m, 0);
    assert!(router.snapshot(&handles[1]).is_err(), "old handle must stay stale");
    pool.shutdown();
}

#[test]
fn drop_with_open_streams_does_not_hang() {
    let ds = yeast_like(12, 804);
    let pool = ShardPool::spawn(pool_cfg(4));
    let router = pool.router();
    let mut handles = Vec::new();
    for si in 0..6 {
        let id = format!("s{si}");
        let h = router.open_stream(&id, ds.dim(), stream_cfg(1.0, 4)).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        handles.push(h);
    }
    drop(pool); // joins all 4 workers with streams still open
    // Surviving router clones fail cleanly instead of hanging.
    assert!(router.ingest(&handles[0], ds.x.row(0).to_vec()).is_err());
    assert!(router.ingest_async(&handles[0], ds.x.row(0).to_vec()).is_err());
    assert!(router.pool_snapshot().is_err());
}

#[test]
fn concurrent_producers_on_one_stream_keep_m_consistent() {
    // Multiple producers feeding the SAME stream (each holding a clone
    // of its handle) serialize through its pinned shard: every reply
    // carries a consistent, growing m.
    let ds = oracle::std_stream(48, 805);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("shared", ds.dim(), stream_cfg(2.0, 4)).unwrap();
    std::thread::scope(|scope| {
        for half in 0..2 {
            let r = router.clone();
            let hc = h.clone();
            let ds = &ds;
            scope.spawn(move || {
                for i in (half..ds.n()).step_by(2) {
                    r.ingest(&hc, ds.x.row(i).to_vec()).unwrap();
                }
            });
        }
    });
    let snap = router.snapshot(&h).unwrap();
    assert_eq!(snap.m, 48);
    let drift = router.measure_drift(&h).unwrap();
    assert!(drift.norms.frobenius < 1e-6);
    pool.shutdown();
}

#[test]
fn mixed_batch_and_async_producers_stay_isolated() {
    // One stream fed by ingest_many batches, one by fire-and-forget,
    // concurrently on the same pool: both end at the reference state.
    let ds = oracle::std_stream(32, 806);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let hb = router.open_stream("batched", ds.dim(), stream_cfg(1.5, 6)).unwrap();
    let ha = router.open_stream("async", ds.dim(), stream_cfg(1.5, 6)).unwrap();
    std::thread::scope(|scope| {
        {
            let r = router.clone();
            let h = hb.clone();
            let ds = &ds;
            scope.spawn(move || {
                let dim = ds.dim();
                let flat = ds.x.as_slice();
                let mut i = 0;
                while i < ds.n() {
                    let end = (i + 8).min(ds.n());
                    r.ingest_many(&h, flat[i * dim..end * dim].to_vec()).unwrap();
                    i = end;
                }
            });
        }
        {
            let r = router.clone();
            let h = ha.clone();
            let ds = &ds;
            scope.spawn(move || {
                for i in 0..ds.n() {
                    r.ingest_async(&h, ds.x.row(i).to_vec()).unwrap();
                }
                assert_eq!(r.sync(&h).unwrap(), 0);
            });
        }
    });
    let reference = reference_run(&ds, 1.5, 6);
    for h in [&hb, &ha] {
        let snap = router.snapshot(h).unwrap();
        assert_eq!(snap.m, 32, "{}", h.id());
        let top_ref: Vec<f64> = reference.vals.iter().rev().take(10).copied().collect();
        for (got, want) in snap.top_values.iter().zip(&top_ref) {
            assert!(
                (got - want).abs() <= 1e-10,
                "{}: eigenvalue {got} vs reference {want}",
                h.id()
            );
        }
        let drift = router.measure_drift(h).unwrap();
        assert!(drift.norms.frobenius < 1e-7, "{}: {:?}", h.id(), drift.norms);
    }
    pool.shutdown();
}
