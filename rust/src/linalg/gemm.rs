//! Packed, cache-blocked, parallel matrix multiplication and the small
//! BLAS-2 kernels the rest of the crate needs — all expressed over
//! [`MatView`]/[`MatViewMut`] so the streaming hot path can run into
//! caller-owned buffers without allocating.
//!
//! All three GEMM orientations (`matmul_into`, `matmul_nt_into`,
//! `matmul_tn_into`) route through one packed path: operands are
//! copied per depth block into tile-ordered panels ([`pack`]) and the
//! product bottoms out in the single fixed-shape `MR × NR`
//! microkernel. The packer absorbs transposes, which is what makes
//! the `NT`/`TN` variants free. The `_buf` forms take a caller-owned
//! [`PackBuffers`] so streaming steady state packs into pre-reserved
//! scratch; the plain forms fall back to a thread-local pack buffer.
//! The legacy unpacked kernels survive as `*_unpacked` — they are the
//! baseline the `micro_linalg` packed-vs-unpacked series measures
//! against (EXPERIMENTS.md §Perf).
//!
//! The allocating entry points (`matmul`, `gemv`, …) are thin wrappers
//! and accept anything convertible to a view (`&Mat`, `MatView`,
//! `&rankone::EigenBasis`). The same products can also be routed to an
//! AOT PJRT executable via `runtime`/`coordinator::router`.

use std::cell::RefCell;

use super::matrix::Mat;
use super::pack::{self, PackBuffers, Src, KC, MC, MR, NC, NR};
use super::view::{MatView, MatViewMut};
use crate::util::par;

/// Parallelism threshold: below this many flops, threads cost more than
/// they save.
const PAR_FLOPS: usize = 1 << 20;

/// Row-panel height of the legacy unpacked kernel (kept only as the
/// measured baseline for the packed path).
const UNPACKED_MC: usize = 64;
/// Depth blocking factor of the legacy unpacked kernel.
const UNPACKED_KC: usize = 256;

thread_local! {
    /// Fallback pack scratch for the plain (non-`_buf`) entry points.
    /// One per thread: reused across calls, so even the allocating
    /// call sites stop paying per-call pack growth after the first
    /// product at a given shape.
    static TL_PACK: RefCell<PackBuffers> = RefCell::new(PackBuffers::new());
}

/// Run `f` with the thread-local pack scratch. If the scratch is
/// already borrowed (a re-entrant matmul from inside a parallel
/// worker's closure), fall back to a fresh local buffer rather than
/// panicking — correctness first, reuse when possible.
fn with_tl_pack<R>(f: impl FnOnce(&mut PackBuffers) -> R) -> R {
    TL_PACK.with(|cell| match cell.try_borrow_mut() {
        Ok(mut bufs) => f(&mut bufs),
        Err(_) => f(&mut PackBuffers::new()),
    })
}

/// The one packed GEMM driver: `C = op(A) · op(B)` where the `Src`
/// orientation of each operand is absorbed by the packers. `m/k/n` are
/// the *logical* product dimensions (after any transpose). The output
/// window is zeroed first; gap columns and capacity rows of a wider
/// backing buffer are never touched.
///
/// Loop nest (BLIS order): `j0` over `NC`-wide column slices, `kk`
/// over `KC`-deep depth blocks — pack `B` once per `(j0, kk)` and `A`
/// once per `kk` — then row blocks of `C` run the microkernel over the
/// shared packed panels. When the flop count warrants it the row
/// blocks run in parallel: the packing stays serial and single-copy,
/// each worker consumes its own `MC`-row slice of the packed `A` (per
/// -thread A panels over shared packed `B`), so no worker ever
/// allocates (the per-call scoped threads in `util::par` would turn
/// per-worker pack buffers into per-call reallocs).
fn gemm_packed(
    a: Src<'_>,
    b: Src<'_>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut MatViewMut<'_>,
    bufs: &mut PackBuffers,
) {
    out.fill_zero();
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let sc = out.stride();
    let parallel = 2 * m * k * n >= PAR_FLOPS && par::num_threads() > 1;
    for j0 in (0..n).step_by(NC) {
        let nc = NC.min(n - j0);
        for kk in (0..k).step_by(KC) {
            let kc = KC.min(k - kk);
            bufs.ensure(m, kc, nc);
            pack::pack_b(b, kk, kc, j0, nc, &mut bufs.b);
            pack::pack_a(a, 0, m, kk, kc, &mut bufs.a);
            let (pa, pb) = (&bufs.a[..], &bufs.b[..]);
            if parallel {
                par::par_chunks_mut(out.raw_mut(), MC * sc, |blk, c_panel| {
                    let i0 = blk * MC;
                    if i0 >= m {
                        return; // capacity rows beyond the viewed window
                    }
                    let i1 = (i0 + MC).min(m);
                    block_rows(pa, pb, i0, i1, kc, nc, j0, c_panel, sc);
                });
            } else {
                block_rows(pa, pb, 0, m, kc, nc, j0, out.raw_mut(), sc);
            }
        }
    }
}

/// Accumulate rows `i0..i1` of `C` from the packed panels of one
/// `(j0, kk)` block. `c_panel` starts at row `i0`; `i0` must be
/// `MR`-aligned (guaranteed: parallel chunks start at multiples of
/// `MC`, and `MC % MR == 0`). Panel order: `B` panels outer, `A`
/// strips inner — one `kc × NR` B panel stays hot in L1 while the
/// strips of the `MC`-row A block stream past it from L2.
#[allow(clippy::too_many_arguments)]
fn block_rows(
    pa: &[f64],
    pb: &[f64],
    i0: usize,
    i1: usize,
    kc: usize,
    nc: usize,
    j0: usize,
    c_panel: &mut [f64],
    sc: usize,
) {
    debug_assert_eq!(i0 % MR, 0);
    let panels = nc.div_ceil(NR);
    let mut ib = i0;
    while ib < i1 {
        let ie = (ib + MC).min(i1);
        for t in 0..panels {
            let nv = NR.min(nc - t * NR);
            let bpanel = &pb[t * NR * kc..(t + 1) * NR * kc];
            let mut i = ib;
            while i < ie {
                let mv = MR.min(ie - i);
                let astrip = &pa[(i / MR) * MR * kc..(i / MR + 1) * MR * kc];
                let coff = (i - i0) * sc + j0 + t * NR;
                pack::microkernel(kc, astrip, bpanel, &mut c_panel[coff..], sc, mv, nv);
                i += MR;
            }
        }
        ib = ie;
    }
}

/// `C = A · B` into a caller-owned view (zeroed first), packing into
/// caller-owned scratch — the zero-realloc form for the streaming hot
/// path. All three operands may be strided.
pub fn matmul_into_buf(
    a: MatView<'_>,
    b: MatView<'_>,
    out: &mut MatViewMut<'_>,
    bufs: &mut PackBuffers,
) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul out rows mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul out cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let asrc = Src::Normal {
        data: a.raw(),
        stride: a.stride(),
    };
    let bsrc = Src::Normal {
        data: b.raw(),
        stride: b.stride(),
    };
    gemm_packed(asrc, bsrc, m, k, n, out, bufs);
}

/// `C = A · B` into a caller-owned view (zeroed first); packs into the
/// thread-local scratch.
pub fn matmul_into(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    with_tl_pack(|bufs| matmul_into_buf(a, b, out, bufs));
}

/// `C = A · B`.
pub fn matmul<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.cols());
    let mut cv = c.view_mut();
    matmul_into(a, b, &mut cv);
    c
}

/// `C = A · Bᵀ` into caller-owned view and pack scratch — the packer
/// walks `B` transposed (contiguous along each source row), so no
/// transpose is ever materialized and the kernel is identical to the
/// `NN` case.
pub fn matmul_nt_into_buf(
    a: MatView<'_>,
    b: MatView<'_>,
    out: &mut MatViewMut<'_>,
    bufs: &mut PackBuffers,
) {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul_nt out rows mismatch");
    assert_eq!(out.cols(), b.rows(), "matmul_nt out cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let asrc = Src::Normal {
        data: a.raw(),
        stride: a.stride(),
    };
    let bsrc = Src::Trans {
        data: b.raw(),
        stride: b.stride(),
    };
    gemm_packed(asrc, bsrc, m, k, n, out, bufs);
}

/// `C = A · Bᵀ` into a caller-owned view; packs into the thread-local
/// scratch.
pub fn matmul_nt_into(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    with_tl_pack(|bufs| matmul_nt_into_buf(a, b, out, bufs));
}

/// `C = A · Bᵀ` without materializing the transpose.
pub fn matmul_nt<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    let mut c = Mat::zeros(a.rows(), b.rows());
    let mut cv = c.view_mut();
    matmul_nt_into(a, b, &mut cv);
    c
}

/// `C = Aᵀ · B` into caller-owned view and pack scratch — the packer
/// walks `A` transposed (contiguous along each source row), same
/// kernel as the `NN` case.
pub fn matmul_tn_into_buf(
    a: MatView<'_>,
    b: MatView<'_>,
    out: &mut MatViewMut<'_>,
    bufs: &mut PackBuffers,
) {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    assert_eq!(out.rows(), a.cols(), "matmul_tn out rows mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul_tn out cols mismatch");
    let (m, k, n) = (a.cols(), a.rows(), b.cols());
    let asrc = Src::Trans {
        data: a.raw(),
        stride: a.stride(),
    };
    let bsrc = Src::Normal {
        data: b.raw(),
        stride: b.stride(),
    };
    gemm_packed(asrc, bsrc, m, k, n, out, bufs);
}

/// `C = Aᵀ · B` into a caller-owned view; packs into the thread-local
/// scratch.
pub fn matmul_tn_into(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    with_tl_pack(|bufs| matmul_tn_into_buf(a, b, out, bufs));
}

/// `C = A · B` with the legacy unpacked kernel (strided source reads,
/// 4-row register-blocked axpy). Benchmark baseline only — production
/// call sites use the packed path.
pub fn matmul_into_unpacked(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul out rows mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul out cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    out.fill_zero();
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let (sa, sb, sc) = (a.stride(), b.stride(), out.stride());
    let a_data = a.raw();
    let b_data = b.raw();
    if 2 * m * k * n < PAR_FLOPS {
        let c_data = out.raw_mut();
        for kk in (0..k).step_by(UNPACKED_KC) {
            let kend = (kk + UNPACKED_KC).min(k);
            gemm_panel(a_data, sa, b_data, sb, c_data, sc, 0, m, n, kk, kend);
        }
    } else {
        par::par_chunks_mut(out.raw_mut(), UNPACKED_MC * sc, |blk, c_panel| {
            let i0 = blk * UNPACKED_MC;
            if i0 >= m {
                return; // capacity rows beyond the viewed window
            }
            let i1 = (i0 + UNPACKED_MC).min(m);
            for kk in (0..k).step_by(UNPACKED_KC) {
                let kend = (kk + UNPACKED_KC).min(k);
                gemm_panel(a_data, sa, b_data, sb, c_panel, sc, i0, i1, n, kk, kend);
            }
        });
    }
}

/// Inner kernel of the legacy unpacked path: accumulate rows `i0..i1`
/// of `C` over the `kk..kend` depth slice with 4-row register
/// blocking — each `brow` load feeds four FMAs. `c_panel` starts at
/// row `i0`; `sa`/`sb`/`sc` are the row strides of the three operands.
#[allow(clippy::too_many_arguments)]
#[inline]
fn gemm_panel(
    a_data: &[f64],
    sa: usize,
    b_data: &[f64],
    sb: usize,
    c_panel: &mut [f64],
    sc: usize,
    i0: usize,
    i1: usize,
    n: usize,
    kk: usize,
    kend: usize,
) {
    let mut i = i0;
    while i + 4 <= i1 {
        // Split the 4 destination rows without aliasing.
        let base = (i - i0) * sc;
        let (r0, rest) = c_panel[base..].split_at_mut(sc);
        let (r1, rest) = rest.split_at_mut(sc);
        let (r2, rest) = rest.split_at_mut(sc);
        let r0 = &mut r0[..n];
        let r1 = &mut r1[..n];
        let r2 = &mut r2[..n];
        let r3 = &mut rest[..n];
        for p in kk..kend {
            let a0 = a_data[i * sa + p];
            let a1 = a_data[(i + 1) * sa + p];
            let a2 = a_data[(i + 2) * sa + p];
            let a3 = a_data[(i + 3) * sa + p];
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let brow = &b_data[p * sb..p * sb + n];
            for j in 0..n {
                let bj = brow[j];
                r0[j] += a0 * bj;
                r1[j] += a1 * bj;
                r2[j] += a2 * bj;
                r3[j] += a3 * bj;
            }
        }
        i += 4;
    }
    while i < i1 {
        let base = (i - i0) * sc;
        let crow = &mut c_panel[base..base + n];
        for p in kk..kend {
            let aip = a_data[i * sa + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b_data[p * sb..p * sb + n];
            for j in 0..n {
                crow[j] += aip * brow[j];
            }
        }
        i += 1;
    }
}

/// `C = A · Bᵀ` with the legacy per-row dot-product kernel. Benchmark
/// baseline only.
pub fn matmul_nt_into_unpacked(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    assert_eq!(out.rows(), a.rows(), "matmul_nt out rows mismatch");
    assert_eq!(out.cols(), b.rows(), "matmul_nt out cols mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    out.fill_zero();
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let sc = out.stride();
    let do_row = |i: usize, crow: &mut [f64]| {
        let arow = a.row(i);
        for j in 0..n {
            let brow = b.row(j);
            let mut s = 0.0;
            for p in 0..k {
                s += arow[p] * brow[p];
            }
            crow[j] = s;
        }
    };
    if 2 * m * k * n < PAR_FLOPS {
        let c_data = out.raw_mut();
        for i in 0..m {
            do_row(i, &mut c_data[i * sc..i * sc + n]);
        }
    } else {
        par::par_chunks_mut(out.raw_mut(), sc, |i, crow| {
            if i < m {
                do_row(i, &mut crow[..n]);
            }
        });
    }
}

/// `C = Aᵀ · B` with the legacy rank-one outer-product accumulation.
/// Benchmark baseline only.
pub fn matmul_tn_into_unpacked(a: MatView<'_>, b: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    assert_eq!(out.rows(), a.cols(), "matmul_tn out rows mismatch");
    assert_eq!(out.cols(), b.cols(), "matmul_tn out cols mismatch");
    let (m, r, n) = (a.rows(), a.cols(), b.cols());
    out.fill_zero();
    if m == 0 || r == 0 || n == 0 {
        return;
    }
    let sc = out.stride();
    let (sa, sb) = (a.stride(), b.stride());
    let a_data = a.raw();
    let b_data = b.raw();
    if 2 * m * r * n < PAR_FLOPS {
        let c_data = out.raw_mut();
        for p in 0..m {
            let arow = a.row(p);
            let brow = b.row(p);
            for (i, &aip) in arow.iter().enumerate() {
                if aip == 0.0 {
                    continue;
                }
                let crow = &mut c_data[i * sc..i * sc + n];
                for j in 0..n {
                    crow[j] += aip * brow[j];
                }
            }
        }
    } else {
        par::par_chunks_mut(out.raw_mut(), sc, |i, crow| {
            if i >= r {
                return;
            }
            let crow = &mut crow[..n];
            for p in 0..m {
                let aip = a_data[p * sa + i];
                if aip == 0.0 {
                    continue;
                }
                let brow = &b_data[p * sb..p * sb + n];
                for j in 0..n {
                    crow[j] += aip * brow[j];
                }
            }
        });
    }
}

/// `T = Aᵀ` into a caller-owned view.
pub fn transpose_into(a: MatView<'_>, out: &mut MatViewMut<'_>) {
    assert_eq!(out.rows(), a.cols(), "transpose out rows mismatch");
    assert_eq!(out.cols(), a.rows(), "transpose out cols mismatch");
    for i in 0..a.rows() {
        let arow = a.row(i);
        for (j, &v) in arow.iter().enumerate() {
            out[(j, i)] = v;
        }
    }
}

/// `y = A · x` into a caller-owned slice.
pub fn gemv_into(a: MatView<'_>, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.cols(), x.len(), "gemv shape mismatch");
    assert_eq!(a.rows(), y.len(), "gemv out length mismatch");
    for (i, yi) in y.iter_mut().enumerate() {
        *yi = super::matrix::dot(a.row(i), x);
    }
}

/// `y = A · x`.
pub fn gemv<'a>(a: impl Into<MatView<'a>>, x: &[f64]) -> Vec<f64> {
    let a = a.into();
    let mut y = vec![0.0; a.rows()];
    gemv_into(a, x, &mut y);
    y
}

/// `y = Aᵀ · x` into a caller-owned slice.
pub fn gemv_t_into(a: MatView<'_>, x: &[f64], y: &mut [f64]) {
    assert_eq!(a.rows(), x.len(), "gemv_t shape mismatch");
    assert_eq!(a.cols(), y.len(), "gemv_t out length mismatch");
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = a.row(i);
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += xi * row[j];
        }
    }
}

/// `y = Aᵀ · x`.
pub fn gemv_t<'a>(a: impl Into<MatView<'a>>, x: &[f64]) -> Vec<f64> {
    let a = a.into();
    let mut y = vec![0.0; a.cols()];
    gemv_t_into(a, x, &mut y);
    y
}

/// Gram matrix `A · Aᵀ` (symmetric; computes the upper triangle once).
pub fn syrk(a: &Mat) -> Mat {
    let (m, k) = (a.rows(), a.cols());
    let mut c = Mat::zeros(m, m);
    let a_data = a.as_slice();
    let upper_row = |i: usize| -> Vec<f64> {
        let ai = &a_data[i * k..(i + 1) * k];
        (i..m)
            .map(|j| {
                let aj = &a_data[j * k..(j + 1) * k];
                super::matrix::dot(ai, aj)
            })
            .collect()
    };
    let results: Vec<Vec<f64>> = if 2 * m * m * k >= PAR_FLOPS {
        par::par_map(m, 1, upper_row)
    } else {
        (0..m).map(upper_row).collect()
    };
    for (i, rowvals) in results.into_iter().enumerate() {
        for (off, v) in rowvals.into_iter().enumerate() {
            let j = i + off;
            c[(i, j)] = v;
            c[(j, i)] = v;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        Mat::from_fn(a.rows(), b.cols(), |i, j| {
            (0..a.cols()).map(|p| a[(i, p)] * b[(p, j)]).sum()
        })
    }

    #[test]
    fn matmul_matches_naive_small() {
        let a = Mat::from_fn(5, 7, |i, j| (i as f64 - j as f64) * 0.3);
        let b = Mat::from_fn(7, 4, |i, j| (i * j) as f64 * 0.1 + 1.0);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive_blocked_sizes() {
        // Exercise the KC blocking boundary and parallel path. k > KC
        // changes the per-element summation order (one partial sum per
        // depth block), hence 1e-9 instead of the single-block 1e-12.
        let a = Mat::from_fn(70, 300, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Mat::from_fn(300, 65, |i, j| ((i * 3 + j * 17) % 13) as f64 * 0.25);
        let c = matmul(&a, &b);
        assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-9);
    }

    #[test]
    fn nt_tn_match_naive_across_kc_boundary() {
        // Same k > KC shape through the transposed-operand packers.
        let a = Mat::from_fn(70, 300, |i, j| ((i * 7 + j * 13) % 11) as f64 - 5.0);
        let b = Mat::from_fn(300, 65, |i, j| ((i * 3 + j * 17) % 13) as f64 * 0.25);
        let expect = naive(&a, &b);
        let bt = b.transpose();
        let mut c = Mat::zeros(70, 65);
        {
            let mut cv = c.view_mut();
            matmul_nt_into(a.view(), bt.view(), &mut cv);
        }
        assert!(c.max_abs_diff(&expect) < 1e-9);
        let at = a.transpose();
        let mut c2 = Mat::zeros(70, 65);
        {
            let mut cv = c2.view_mut();
            matmul_tn_into(at.view(), b.view(), &mut cv);
        }
        assert!(c2.max_abs_diff(&expect) < 1e-9);
    }

    #[test]
    fn all_variants_match_naive_across_tail_shapes() {
        // Every residue class mod the tile sizes for m and n, k across
        // 0 and 1..MR·2+1 — all single-depth-block, so the packed path
        // reproduces the naive summation order exactly (≤1e-12 is
        // conservative; it is essentially bitwise).
        let ms = [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 13];
        let ns = [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17];
        let ks = [0usize, 1, 2, 3, 5, 7, 8, 9];
        for &m in &ms {
            for &n in &ns {
                for &k in &ks {
                    let a = Mat::from_fn(m, k, |i, j| ((i * 31 + j * 17) % 23) as f64 * 0.25 - 2.0);
                    let b = Mat::from_fn(k, n, |i, j| ((i * 13 + j * 7) % 19) as f64 * 0.5 - 4.0);
                    let expect = naive(&a, &b);
                    let c = matmul(&a, &b);
                    assert!(c.max_abs_diff(&expect) < 1e-12, "NN m={m} n={n} k={k}");
                    let bt = b.transpose();
                    let mut cnt = Mat::zeros(m, n);
                    {
                        let mut cv = cnt.view_mut();
                        matmul_nt_into(a.view(), bt.view(), &mut cv);
                    }
                    assert!(cnt.max_abs_diff(&expect) < 1e-12, "NT m={m} n={n} k={k}");
                    let at = a.transpose();
                    let mut ctn = Mat::zeros(m, n);
                    {
                        let mut cv = ctn.view_mut();
                        matmul_tn_into(at.view(), b.view(), &mut cv);
                    }
                    assert!(ctn.max_abs_diff(&expect) < 1e-12, "TN m={m} n={n} k={k}");
                }
            }
        }
    }

    #[test]
    fn degenerate_shapes_zero_the_window() {
        // n = 0 and k = 0 through every variant: output window must be
        // all zeros (k = 0 is an empty sum, n = 0 an empty window).
        let a = Mat::from_fn(4, 0, |_, _| f64::NAN);
        let b = Mat::from_fn(0, 3, |_, _| f64::NAN);
        let c = matmul(&a, &b);
        assert_eq!((c.rows(), c.cols()), (4, 3));
        assert!(c.as_slice().iter().all(|&v| v == 0.0));
        let a2 = Mat::from_fn(4, 5, |i, j| (i + j) as f64);
        let b2 = Mat::zeros(0, 5); // b2ᵀ is 5×0 → n = 0
        let mut cnt = Mat::zeros(4, 0);
        {
            let mut cv = cnt.view_mut();
            matmul_nt_into(a2.view(), b2.view(), &mut cv);
        }
        assert_eq!((cnt.rows(), cnt.cols()), (4, 0));
        let a3 = Mat::zeros(0, 4); // a3ᵀ is 4×0 → k = 0
        let b3 = Mat::zeros(0, 3);
        let mut ctn = Mat::zeros(4, 3);
        {
            let mut cv = ctn.view_mut();
            matmul_tn_into(a3.view(), b3.view(), &mut cv);
        }
        assert!(ctn.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn matmul_into_strided_out_matches() {
        // The output lives in a wider capacity buffer (stride > cols),
        // exactly how the workspace's rotated panel is laid out.
        let a = Mat::from_fn(9, 6, |i, j| ((i * 5 + j) % 7) as f64 - 3.0);
        let b = Mat::from_fn(6, 5, |i, j| ((i + 2 * j) % 5) as f64 * 0.5);
        let stride = 8;
        let mut buf = vec![f64::NAN; 12 * stride];
        {
            let mut out = MatViewMut::new(&mut buf, 9, 5, stride);
            matmul_into(a.view(), b.view(), &mut out);
        }
        let expect = naive(&a, &b);
        for i in 0..9 {
            for j in 0..5 {
                assert!((buf[i * stride + j] - expect[(i, j)]).abs() < 1e-12);
            }
        }
        // Gap columns and capacity rows untouched.
        assert!(buf[5].is_nan());
        assert!(buf[9 * stride].is_nan());
    }

    #[test]
    fn nt_tn_strided_views_and_capacity_rows_match() {
        // Operands are windows of wider buffers, outputs have both gap
        // columns and capacity rows — the layouts the workspace and
        // snapshot scratch actually use.
        let full_a = Mat::from_fn(7, 11, |i, j| ((i * 9 + j) % 13) as f64 * 0.3 - 1.0);
        let full_b = Mat::from_fn(9, 11, |i, j| ((i * 4 + j * 5) % 17) as f64 * 0.2);
        let av = MatView::new(full_a.as_slice(), 7, 6, 11); // 7×6 window
        let a_win = av.to_mat();
        // NT: B window 5×6 viewed out of 9×11 backing → C is 7×5.
        let bv = MatView::new(full_b.as_slice(), 5, 6, 11);
        let b_win = bv.to_mat();
        let stride = 9;
        let mut buf = vec![f64::NAN; 10 * stride];
        {
            let mut out = MatViewMut::new(&mut buf, 7, 5, stride);
            matmul_nt_into(av, bv, &mut out);
        }
        let expect = naive(&a_win, &b_win.transpose());
        for i in 0..7 {
            for j in 0..5 {
                assert!((buf[i * stride + j] - expect[(i, j)]).abs() < 1e-12, "NT ({i},{j})");
            }
        }
        assert!(buf[5].is_nan(), "NT gap column clobbered");
        assert!(buf[7 * stride].is_nan(), "NT capacity row clobbered");
        // TN: A window read transposed (6×7 logical), B window 7×8 out
        // of the 9×11 backing → C is 6×8.
        let bv2 = MatView::new(full_b.as_slice(), 7, 8, 11);
        let b2_win = bv2.to_mat();
        let av2 = MatView::new(full_a.as_slice(), 7, 6, 11);
        let mut buf2 = vec![f64::NAN; 8 * stride];
        {
            let mut out = MatViewMut::new(&mut buf2, 6, 8, stride);
            matmul_tn_into(av2, bv2, &mut out);
        }
        let expect2 = naive(&a_win.transpose(), &b2_win);
        for i in 0..6 {
            for j in 0..8 {
                assert!((buf2[i * stride + j] - expect2[(i, j)]).abs() < 1e-12, "TN ({i},{j})");
            }
        }
        assert!(buf2[8].is_nan(), "TN gap column clobbered");
        assert!(buf2[6 * stride].is_nan(), "TN capacity row clobbered");
    }

    #[test]
    fn matmul_strided_inputs_match() {
        // a and b viewed as windows of wider buffers.
        let full_a = Mat::from_fn(4, 9, |i, j| (i * 9 + j) as f64 * 0.1);
        let full_b = Mat::from_fn(3, 7, |i, j| (i * 7 + j) as f64 * 0.2 - 1.0);
        let av = MatView::new(full_a.as_slice(), 4, 3, 9);
        let bv = MatView::new(full_b.as_slice(), 3, 4, 7);
        let c = matmul(av, bv);
        let a_win = av.to_mat();
        let b_win = bv.to_mat();
        assert!(c.max_abs_diff(&naive(&a_win, &b_win)) < 1e-12);
    }

    #[test]
    fn parallel_path_matches_with_capacity_rows() {
        // Big enough to cross PAR_FLOPS; k ≤ KC keeps the summation
        // order identical to naive, so 1e-12 holds even in parallel.
        let (m, k, n) = (160, 60, 60);
        let a = Mat::from_fn(m, k, |i, j| ((i * 3 + j * 11) % 29) as f64 * 0.125 - 1.5);
        let b = Mat::from_fn(k, n, |i, j| ((i * 5 + j * 2) % 31) as f64 * 0.0625);
        let stride = n + 4;
        let mut buf = vec![f64::NAN; (m + 30) * stride];
        {
            let mut out = MatViewMut::new(&mut buf, m, n, stride);
            matmul_into(a.view(), b.view(), &mut out);
        }
        let expect = naive(&a, &b);
        for i in 0..m {
            for j in 0..n {
                assert!((buf[i * stride + j] - expect[(i, j)]).abs() < 1e-12);
            }
        }
        assert!(buf[n].is_nan(), "gap column clobbered");
        assert!(buf[m * stride].is_nan(), "capacity row clobbered");
    }

    #[test]
    fn unpacked_baselines_match_packed() {
        // The *_unpacked benchmark baselines must agree with the packed
        // production path (shared shape: one KC block, so ≤1e-12).
        let a = Mat::from_fn(33, 40, |i, j| ((i * 7 + j * 3) % 13) as f64 * 0.5 - 3.0);
        let b = Mat::from_fn(40, 21, |i, j| ((i + 5 * j) % 11) as f64 * 0.25);
        let packed = matmul(&a, &b);
        let mut up = Mat::zeros(33, 21);
        {
            let mut cv = up.view_mut();
            matmul_into_unpacked(a.view(), b.view(), &mut cv);
        }
        assert!(packed.max_abs_diff(&up) < 1e-12);
        let bt = b.transpose();
        let mut nt_p = Mat::zeros(33, 21);
        let mut nt_u = Mat::zeros(33, 21);
        {
            let mut cv = nt_p.view_mut();
            matmul_nt_into(a.view(), bt.view(), &mut cv);
            let mut cv = nt_u.view_mut();
            matmul_nt_into_unpacked(a.view(), bt.view(), &mut cv);
        }
        assert!(nt_p.max_abs_diff(&nt_u) < 1e-12);
        let at = a.transpose();
        let mut tn_p = Mat::zeros(33, 21);
        let mut tn_u = Mat::zeros(33, 21);
        {
            let mut cv = tn_p.view_mut();
            matmul_tn_into(at.view(), b.view(), &mut cv);
            let mut cv = tn_u.view_mut();
            matmul_tn_into_unpacked(at.view(), b.view(), &mut cv);
        }
        assert!(tn_p.max_abs_diff(&tn_u) < 1e-12);
    }

    #[test]
    fn packed_gemm_is_zero_realloc_after_reserve() {
        // A PackBuffers reserved for the largest shape must absorb 100
        // products (including smaller ones) without growing.
        let a = Mat::from_fn(70, 300, |i, j| ((i + j) % 9) as f64 - 4.0);
        let b = Mat::from_fn(300, 65, |i, j| ((i * 2 + j) % 7) as f64 * 0.5);
        let small_a = Mat::from_fn(16, 16, |i, j| (i * 16 + j) as f64 * 0.01);
        let mut bufs = PackBuffers::new();
        bufs.reserve(70, 300, 65);
        let mut c = Mat::zeros(70, 65);
        let mut cs = Mat::zeros(16, 16);
        for _ in 0..100 {
            let mut cv = c.view_mut();
            matmul_into_buf(a.view(), b.view(), &mut cv, &mut bufs);
            let mut cv = cs.view_mut();
            matmul_into_buf(small_a.view(), small_a.view(), &mut cv, &mut bufs);
        }
        assert_eq!(bufs.reallocs(), 0, "reserved pack buffers must never grow");
    }

    #[test]
    fn matmul_nt_matches() {
        let a = Mat::from_fn(6, 9, |i, j| (i + j) as f64 * 0.5);
        let b = Mat::from_fn(8, 9, |i, j| i as f64 * 1.5 - j as f64);
        let c = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        assert!(c.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn matmul_tn_matches() {
        let a = Mat::from_fn(7, 4, |i, j| ((i * 3 + j) as f64).sin());
        let b = Mat::from_fn(7, 5, |i, j| ((i + 2 * j) as f64).cos());
        let mut c = Mat::zeros(4, 5);
        {
            let mut cv = c.view_mut();
            matmul_tn_into(a.view(), b.view(), &mut cv);
        }
        let expect = matmul(&a.transpose(), &b);
        assert!(c.max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn transpose_into_matches() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        let mut t = Mat::zeros(5, 3);
        {
            let mut tv = t.view_mut();
            transpose_into(a.view(), &mut tv);
        }
        assert!(t.max_abs_diff(&a.transpose()) < 1e-15);
    }

    #[test]
    fn gemv_matches() {
        let a = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let x = vec![1.0, -1.0, 2.0];
        let y = gemv(&a, &x);
        for i in 0..4 {
            let expect: f64 = (0..3).map(|j| a[(i, j)] * x[j]).sum();
            assert!((y[i] - expect).abs() < 1e-13);
        }
    }

    #[test]
    fn gemv_t_matches() {
        let a = Mat::from_fn(4, 3, |i, j| ((i * 3 + j) as f64).sin());
        let x = vec![0.5, 1.5, -2.0, 3.0];
        let y = gemv_t(&a, &x);
        let yt = gemv(&a.transpose(), &x);
        for (u, v) in y.iter().zip(yt.iter()) {
            assert!((u - v).abs() < 1e-13);
        }
    }

    #[test]
    fn syrk_matches_matmul() {
        let a = Mat::from_fn(10, 6, |i, j| ((i + 2 * j) as f64).cos());
        let c = syrk(&a);
        let c2 = matmul_nt(&a, &a);
        assert!(c.max_abs_diff(&c2) < 1e-12);
    }

    #[test]
    fn empty_shapes() {
        let a = Mat::zeros(0, 3);
        let b = Mat::zeros(3, 2);
        let c = matmul(&a, &b);
        assert_eq!(c.rows(), 0);
        assert_eq!(c.cols(), 2);
    }
}
