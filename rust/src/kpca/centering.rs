//! Feature-space mean adjustment (paper eq. 1):
//! `K' = K − 𝟙K − K𝟙 + 𝟙K𝟙` with `(𝟙)ᵢⱼ = 1/n`.
//! Plain, batch formulas — the incremental algorithm reproduces these
//! through rank-one updates, and the drift experiments (Fig. 1) compare
//! against this module's output as ground truth.

use crate::linalg::Mat;

/// Center a Gram matrix in feature space: `K → K'` per eq. (1).
pub fn center_gram(k: &Mat) -> Mat {
    assert!(k.is_square());
    let n = k.rows();
    if n == 0 {
        return k.clone();
    }
    let nf = n as f64;
    // Row sums / n (equals column sums by symmetry) and total / n².
    let row_means: Vec<f64> = (0..n).map(|i| k.row(i).iter().sum::<f64>() / nf).collect();
    let total_mean: f64 = row_means.iter().sum::<f64>() / nf;
    Mat::from_fn(n, n, |i, j| k[(i, j)] - row_means[i] - row_means[j] + total_mean)
}

/// Centered kernel column for a *new* point `y` against training data
/// whose uncentered Gram is `k` and uncentered column is `ky`
/// (`ky[i] = k(xᵢ, y)`): the column of the centered feature map
/// `⟨φ(xᵢ) − φ̄, φ(y) − φ̄⟩`.
pub fn center_column(k: &Mat, ky: &[f64]) -> Vec<f64> {
    let n = k.rows();
    assert_eq!(ky.len(), n);
    let nf = n as f64;
    let ky_mean: f64 = ky.iter().sum::<f64>() / nf;
    let row_means: Vec<f64> = (0..n).map(|i| k.row(i).iter().sum::<f64>() / nf).collect();
    let total_mean: f64 = row_means.iter().sum::<f64>() / nf;
    (0..n).map(|i| ky[i] - row_means[i] - ky_mean + total_mean).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{gram, Rbf};
    use crate::linalg::{eigvalsh, matmul};

    fn toy_gram(n: usize) -> Mat {
        let x = Mat::from_fn(n, 3, |i, j| ((i * 2 + j) as f64 * 0.41).cos());
        gram(&Rbf { sigma: 1.0 }, &x)
    }

    #[test]
    fn centered_rows_sum_to_zero() {
        let kc = center_gram(&toy_gram(7));
        for i in 0..7 {
            let s: f64 = kc.row(i).iter().sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn matches_projector_formula() {
        // K' = (I − 𝟙) K (I − 𝟙) with (𝟙)ᵢⱼ = 1/n.
        let n = 6;
        let k = toy_gram(n);
        let c = Mat::from_fn(n, n, |i, j| {
            (if i == j { 1.0 } else { 0.0 }) - 1.0 / n as f64
        });
        let expect = matmul(&matmul(&c, &k), &c);
        assert!(center_gram(&k).max_abs_diff(&expect) < 1e-12);
    }

    #[test]
    fn centering_is_idempotent() {
        let kc = center_gram(&toy_gram(5));
        assert!(center_gram(&kc).max_abs_diff(&kc) < 1e-12);
    }

    #[test]
    fn centered_gram_stays_psd() {
        let kc = center_gram(&toy_gram(8));
        let vals = eigvalsh(&kc).unwrap();
        assert!(vals[0] > -1e-10);
    }

    #[test]
    fn center_column_consistent_with_center_gram() {
        // Append y as the last training point: the centered column of y
        // against the first n−1 points must match what a (n−1)-sized
        // center_column computes from uncentered quantities.
        let n = 6;
        let x = Mat::from_fn(n, 3, |i, j| ((i + j) as f64 * 0.3).sin());
        let k_full = gram(&Rbf { sigma: 1.0 }, &x);
        let k_sub = k_full.submatrix(n - 1, n - 1);
        let ky: Vec<f64> = (0..n - 1).map(|i| k_full[(i, n - 1)]).collect();
        let col = center_column(&k_sub, &ky);
        // Reference: explicit centered feature inner products via the
        // projector formula on the (n−1)-point training set.
        let m = n - 1;
        let mf = m as f64;
        let row_means: Vec<f64> =
            (0..m).map(|i| k_sub.row(i).iter().sum::<f64>() / mf).collect();
        let total: f64 = row_means.iter().sum::<f64>() / mf;
        let ky_mean: f64 = ky.iter().sum::<f64>() / mf;
        for i in 0..m {
            let expect = ky[i] - row_means[i] - ky_mean + total;
            assert!((col[i] - expect).abs() < 1e-14);
        }
    }
}
