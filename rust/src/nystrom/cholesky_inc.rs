//! Rudi, Camoriano & Rosasco (2015)-style incremental Nyström via
//! rank-one *Cholesky* updates — the prior work the paper generalizes
//! (§4). Maintains `K_{m,m} = L Lᵀ` through bordered expansion and
//! computes `K̃ = (L⁻¹K_{m,n})ᵀ(L⁻¹K_{m,n})` by triangular solves,
//! without ever forming an eigendecomposition. Serves as the comparison
//! baseline for the ablation bench (which decomposition to update).

use crate::kernels::{kernel_column, Kernel};
use crate::linalg::{Cholesky, Mat, Norms};

/// Incrementally grown Cholesky-based Nyström approximation.
pub struct CholeskyNystrom<'k> {
    kernel: &'k dyn Kernel,
    x: Mat,
    /// Cholesky factor of the subset Gram (plus jitter).
    chol: Option<Cholesky>,
    /// `n × m` cross-Gram.
    pub knm: Mat,
    pub subset: Vec<usize>,
    /// Diagonal jitter guaranteeing positive-definite expansion.
    pub jitter: f64,
    /// Points rejected because expansion lost positive definiteness.
    pub rejected: usize,
}

impl<'k> CholeskyNystrom<'k> {
    pub fn new(kernel: &'k dyn Kernel, x: Mat) -> Self {
        let n = x.rows();
        CholeskyNystrom {
            kernel,
            x,
            chol: None,
            knm: Mat::zeros(n, 0),
            subset: Vec::new(),
            jitter: 1e-10,
            rejected: 0,
        }
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn m(&self) -> usize {
        self.subset.len()
    }

    /// Add evaluation point `idx` to the subset. Returns `false` when
    /// the bordered Cholesky expansion fails (rank-degenerate point).
    pub fn add_point(&mut self, idx: usize) -> Result<bool, String> {
        let xi = self.x.row(idx).to_vec();
        let m = self.m();
        // Kernel column against the current subset + self-similarity.
        let sub = Mat::from_fn(m, self.x.cols(), |i, j| self.x[(self.subset[i], j)]);
        let col: Vec<f64> = (0..m).map(|i| self.kernel.eval(sub.row(i), &xi)).collect();
        let kself = self.kernel.eval(&xi, &xi) + self.jitter;
        match self.chol.as_mut() {
            None => {
                if kself <= 0.0 {
                    self.rejected += 1;
                    return Ok(false);
                }
                self.chol = Some(Cholesky::new(&Mat::from_vec(1, 1, vec![kself]))?);
            }
            Some(ch) => {
                if ch.expand(&col, kself).is_err() {
                    self.rejected += 1;
                    return Ok(false);
                }
            }
        }
        // Append the K_{n,m} column.
        let full_col = kernel_column(self.kernel, &self.x, self.n(), &xi);
        let n = self.n();
        let mut grown = Mat::zeros(n, m + 1);
        for i in 0..n {
            for j in 0..m {
                grown[(i, j)] = self.knm[(i, j)];
            }
            grown[(i, m)] = full_col[i];
        }
        self.knm = grown;
        self.subset.push(idx);
        Ok(true)
    }

    /// The approximation `K̃ = K_{n,m} (LLᵀ)⁻¹ K_{m,n}` via triangular
    /// solves: `B = L⁻¹ K_{m,n}` then `K̃ = Bᵀ B`.
    pub fn approx_gram(&self) -> Mat {
        let m = self.m();
        let n = self.n();
        if m == 0 {
            return Mat::zeros(n, n);
        }
        let ch = self.chol.as_ref().unwrap();
        // Solve L b = K_{m,n} column-wise (columns of K_{m,n} are rows
        // of knm).
        let mut b = Mat::zeros(m, n);
        for j in 0..n {
            let rhs: Vec<f64> = (0..m).map(|i| self.knm[(j, i)]).collect();
            let y = ch.solve_lower(&rhs);
            for i in 0..m {
                b[(i, j)] = y[i];
            }
        }
        crate::linalg::matmul(&b.transpose(), &b)
    }

    /// Fig. 2-style error norms against the full Gram.
    pub fn error_norms(&self, k_full: &Mat) -> Norms {
        crate::linalg::sym_norms(&k_full.sub(&self.approx_gram()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::{gram, Rbf};
    use crate::nystrom::IncrementalNystrom;

    #[test]
    fn agrees_with_eigen_based_incremental() {
        let ds = yeast_like(20, 1);
        let kern = Rbf { sigma: 1.0 };
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        let mut eig = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..8 {
            assert!(chol.add_point(m).unwrap());
            assert!(eig.add_point(m).unwrap());
        }
        let diff = chol.approx_gram().max_abs_diff(&eig.approx_gram());
        assert!(diff < 1e-5, "cholesky vs eigen Nyström diff {diff}");
    }

    #[test]
    fn duplicate_point_rejected() {
        let ds = yeast_like(10, 2);
        let kern = Rbf { sigma: 1.0 };
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        chol.jitter = 0.0; // make degeneracy exact
        assert!(chol.add_point(3).unwrap());
        assert!(!chol.add_point(3).unwrap());
        assert_eq!(chol.rejected, 1);
        assert_eq!(chol.m(), 1);
    }

    #[test]
    fn empty_subset_zero_approximation() {
        let ds = yeast_like(6, 3);
        let kern = Rbf { sigma: 1.0 };
        let chol = CholeskyNystrom::new(&kern, ds.x.clone());
        assert_eq!(chol.approx_gram().max_abs(), 0.0);
        let k = gram(&kern, &ds.x);
        let norms = chol.error_norms(&k);
        assert!((norms.frobenius - crate::linalg::frobenius(&k)).abs() < 1e-12);
    }
}
