//! Sharded multi-stream coordinator: a [`ShardPool`] of worker threads,
//! each owning slot-indexed per-stream state, fronted by a stream-keyed
//! [`StreamRouter`] that hands out resolved [`StreamHandle`]s.
//!
//! # Design
//!
//! **Placement.** Every stream id is placed on a consistent-hash ring
//! ([`super::ring::HashRing`]: FNV-1a keyed, splitmix-finalized,
//! `PoolConfig::vnodes` virtual nodes per shard — deterministic within
//! and across processes, like the PR 2 `hash % shards` pinning it
//! replaces). All commands for a stream serialize through one worker —
//! per-stream state needs no locks, and the paper's rank-one hot path
//! (workspace + eigenbasis, allocation-free once warm, PR 1) runs
//! untouched inside the shard. Unlike modulo pinning, the ring makes
//! the topology *elastic*: [`StreamRouter::add_shard`] /
//! [`StreamRouter::remove_shard`] change the member set and migrate
//! only the streams whose ring arc moved (≈ `1/(k+1)` of them on a
//! grow) instead of restarting the pool.
//!
//! **Live migration.** The boxed [`StreamState`] engine is `Send`
//! (whatever its tier), so a stream's whole entry (engine + drift
//! monitor + metrics) can be handed between workers without
//! recomputation. A
//! migration is driven by the *source* worker (command `Migrate`):
//! because commands serialize through the shard queue, every ingest
//! enqueued before the migration drains first — the queue itself is
//! the barrier. The source then extracts the entry, ships it to the
//! target worker (`Install`), which re-homes it in a fresh slot under
//! a bumped generation, and leaves a `Moved` tombstone in the old
//! slot. Commands that still arrive at the old address — stale handles
//! in flight — are re-addressed and forwarded by the tombstone, so no
//! fire-and-forget ingest is lost (forwards never block the worker: a
//! full target queue parks them in a worker-local retry buffer, which
//! makes cross-shard forwarding cycles deadlock-free); the router
//! additionally keeps a redirect table so subsequent sends skip the
//! detour entirely, and holds the pool-wide stream-id registry — a
//! migrated stream sits away from its ring shard, so duplicate-open
//! checks can no longer live in the per-worker name maps alone.
//! Handles therefore survive re-pinning unchanged. The per-stream
//! counters and latency histograms travel *inside* the entry, so pool
//! rollups stay monotonic across a move for the same reason they stay
//! monotonic across a close (nothing is dropped; tombstone orphans and
//! migration counts fold into per-shard totals like closed-stream
//! totals do). Caveat: a producer whose redirect lookup races the
//! migration commit can have its in-window commands arrive via the
//! forwarding detour, which can reorder them against commands sent
//! just after the commit; `sync` before migrating when strict order
//! across the move matters.
//!
//! **Resolved handles.** [`StreamRouter::open_stream`] resolves the
//! stream→shard placement and the shard-local storage slot *once* and
//! returns a cheap [`StreamHandle`] (shard index + integer slot +
//! generation + `Arc<str>` id). Every subsequent command addresses the
//! stream by slot — no per-command `String` allocation and no
//! `HashMap` lookup on the ingest path. The worker keeps its streams
//! in a slot-indexed vector; the name map exists only for open
//! (duplicate check) and close (removal). Slots are reused after close
//! with a bumped generation, so a stale handle can never address a
//! stream that replaced the one it named; `Moved` tombstones are never
//! recycled, so pre-migration handles stay forwardable for the pool's
//! life.
//!
//! **Backpressure.** Each shard has its own *bounded* command channel
//! (`PoolConfig::queue` deep). Producers of a hot shard block on that
//! shard's queue without slowing streams pinned elsewhere. Three ingest
//! shapes share it: rendezvous [`StreamRouter::ingest`] (one reply per
//! point), fire-and-forget [`StreamRouter::ingest_async`] (reply-less;
//! errors land in a per-stream counter and the *first* deferred error
//! message is surfaced by the next [`StreamRouter::sync`]), and batched
//! [`StreamRouter::ingest_many`] (one command and one reply per batch —
//! the per-point channel round-trip amortizes across the batch, the
//! worker computes the batch's kernel rows as one blocked GEMM via
//! [`crate::kpca::IncrementalKpca::push_batch_with`] on the exact
//! tier, and the batch's rank-one
//! back-rotations fold into a single fused engine GEMM — the blocked
//! rank-b update, whose per-stream `engine_gemms` gauge the pool
//! snapshot rolls up). Streams opened with
//! [`StreamConfig::expected_m`]/[`StreamConfig::expected_batch`] are
//! pre-sized once at initialization, so their whole streamed life is
//! allocation-silent.
//!
//! **Shared immutable resources.** One [`RoutedEngine`] (and, when
//! configured, one PJRT runtime — it is not `Send`, so it must be built
//! inside the worker thread) exists *per shard*, not per stream: the
//! engine is stateless apart from its dispatch counters, so all streams
//! of a shard share it. Per-stream state owns its kernel through an
//! `Arc` handed to [`crate::kpca::IncrementalKpca::from_batch_shared`]
//! — closing a
//! stream frees its kernel, and migrating one moves the `Arc` with it.
//!
//! **Metrics aggregation.** Each stream entry keeps its own
//! [`Metrics`] (latency histograms + counters + hot-path gauges).
//! [`StreamRouter::pool_snapshot`] asks every shard for a rollup —
//! counters summed, histograms merged bucket-wise, engine dispatch
//! counts added, migration/forward counts folded — and returns one
//! [`PoolSnapshot`] with the per-stream [`StreamGauges`] and per-shard
//! [`ShardOccupancy`] attached for attribution.
//!
//! **Lock-free reads.** Projection is the served quantity at production
//! read/write ratios, and routing every read through the worker FIFO
//! serializes reads against ingests. Instead, the worker publishes an
//! immutable [`super::snapshot::ProjectionSnapshot`] per stream into
//! the [`super::snapshot::SnapshotCell`] embedded in every
//! [`StreamHandle`] (on seed completion, every `ingest_many` flush,
//! every [`StreamConfig::publish_every`] accepted points, and every
//! `sync`); [`StreamRouter::project_snapshot`] /
//! [`StreamRouter::project_many`] read it without enqueueing anything —
//! see the snapshot module for the arc-swap and the freshness contract.
//! The topology itself is published the same way: an epoch-swapped
//! immutable `Arc<Topology>` (writers rebuild + swap under the reshard
//! lock; readers cache the `Arc` per thread, keyed by epoch), so the
//! data-path verbs stop paying a `RwLock` read per command.
//!
//! **Durability.** With [`PoolConfig::persist`] set, every worker
//! write-ahead logs well-formed ingest commands to its own
//! [`super::wal`] file *before* applying them, and the pool cuts
//! per-stream [`super::persist`] checkpoints on demand
//! ([`StreamRouter::checkpoint_stream`] /
//! [`StreamRouter::checkpoint_all`] — the shard queue doubles as the
//! consistent-cut barrier, exactly like migration). After a crash,
//! [`StreamRouter::restore_pool`] reloads the checkpoints (corrupt
//! files are quarantined, not fatal), replays each stream's
//! torn-tail-tolerant WAL suffix through the normal ingest path, and
//! hands back live handles. Log-append failures degrade, never block:
//! bounded retries, then the stream keeps serving from memory with its
//! `wal_errors` counter ticking — durability is not allowed to take
//! the write path down.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::kernels::{median_heuristic, Kernel};
use crate::kpca::{BatchRotation, EvictionPolicy, KpcaStats};
use crate::linalg::Mat;

use super::drift::{DriftMonitor, DriftPoint};
use super::engine::{self, StreamState, StreamTier, TierParts};
use super::metrics::{
    LatencyHistogram, Metrics, MetricsReport, PoolSnapshot, ShardOccupancy, StreamGauges,
};
use super::persist::{self, CheckpointData, PersistConfig, PersistedCounters};
use super::ring::HashRing;
use super::router::RoutedEngine;
use super::server::{BatchReply, EngineConfig, IngestReply, KernelConfig, Snapshot};
use super::snapshot::{ProjectScratch, ProjectionSnapshot, SnapshotCell};
use super::wal::{WalRecord, WalWriter};

/// Per-stream configuration (what used to be the per-coordinator
/// `Config`, minus the pool-level engine/queue knobs).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub kernel: KernelConfig,
    pub mean_adjust: bool,
    /// Seed examples accumulated before the batch initialization.
    pub seed_points: usize,
    /// Drift measurement cadence (accepted points; 0 = off).
    pub drift_every: usize,
    /// Expected steady-state eigensystem size. When > 0 (or
    /// `expected_batch` > 0) the worker calls
    /// [`crate::kpca::IncrementalKpca::reserve`] the moment the stream's eigensystem
    /// is built — every hot-path buffer is pre-sized once, instead of
    /// growing across the first batches.
    pub expected_m: usize,
    /// Expected ingest batch size for the same reserve call.
    pub expected_batch: usize,
    /// Batched back-rotation strategy for this stream's `ingest_many`
    /// commands; `None` keeps the library's auto rule (fused for real
    /// batches). Forcing [`BatchRotation::Sequential`] is how the
    /// fused-vs-sequential bench series isolates the amortization.
    pub batch_rotation: Option<BatchRotation>,
    /// Accepted points between automatic snapshot publications on the
    /// sequential ingest path (0 disables the cadence). Seed
    /// completion, every `ingest_many` flush and every `sync` publish
    /// regardless, so the snapshot read path can never lag a batched
    /// or synced stream by more than one command.
    pub publish_every: usize,
    /// Top components captured per published snapshot (0 = the full
    /// basis). Serving deployments that only ever read a handful of
    /// components can cap the per-publish copy at `O(m·r)`.
    pub snapshot_r: usize,
    /// Wall-clock snapshot publish deadline for the sequential ingest
    /// path: if at least one accepted point is waiting and this much
    /// time has passed since the last publish, the next accepted point
    /// publishes regardless of [`StreamConfig::publish_every`]. Bounds
    /// snapshot staleness on trickle streams (a stream accepting one
    /// point a minute would otherwise sit `publish_every` points — i.e.
    /// an hour — behind). `None` keeps the count-only cadence.
    pub publish_after: Option<Duration>,
    /// Landmark cap (0 = unbounded). Once the eigensystem reaches this
    /// size, every accepted point triggers one eviction chosen by
    /// `eviction`, so the stream's memory footprint stays fixed no
    /// matter how long it runs. Seed points are protected from
    /// eviction. See [`crate::kpca::IncrementalKpca::set_bound`].
    pub max_landmarks: usize,
    /// Which landmark goes when the cap is hit. Ignored while
    /// `max_landmarks` is 0.
    pub eviction: EvictionPolicy,
    /// Which engine runs this stream (see [`super::engine`]): the
    /// paper-exact eigensystem, the fixed-memory RFF sketch, or a
    /// shadow pairing of both that reports projection divergence.
    pub tier: StreamTier,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            kernel: KernelConfig::RbfMedian,
            mean_adjust: true,
            seed_points: 20,
            drift_every: 0,
            expected_m: 0,
            expected_batch: 0,
            batch_rotation: None,
            publish_every: 64,
            snapshot_r: 0,
            publish_after: None,
            max_landmarks: 0,
            eviction: EvictionPolicy::Off,
            tier: StreamTier::Exact,
        }
    }
}

/// Pool-level configuration: shard/queue topology and the (per-shard)
/// rotation engine.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Worker threads; streams are placed by consistent hash.
    pub shards: usize,
    /// Bounded command-queue depth *per shard* (ingest backpressure).
    pub queue: usize,
    /// Rotation engine, instantiated once per shard worker.
    pub engine: EngineConfig,
    /// Virtual nodes per shard on the placement ring. More vnodes give
    /// a more even stream spread (≥ 128 keeps the per-shard share
    /// within ~2× — pinned by the ring's property tests) at O(vnodes)
    /// memory per shard.
    pub vnodes: usize,
    /// Durability: snapshot directory + fsync policy. `None` (the
    /// default) runs the pool purely in memory — no WAL, and the
    /// checkpoint/restore verbs error. See [`super::persist`] and
    /// [`super::wal`] for the on-disk formats.
    pub persist: Option<PersistConfig>,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            shards: 1,
            queue: 64,
            engine: EngineConfig::Native,
            vnodes: 128,
            persist: None,
        }
    }
}

/// Resolved address of an open stream: pinned shard, storage slot in
/// that shard's worker, the slot generation (guards against reuse after
/// close), and the shared id for attribution. Cheap to clone
/// (`Arc<str>` bump); commands built from a handle carry two integers
/// instead of an owned `String`.
///
/// Handles survive re-pinning: after a migration the router's redirect
/// table (and, for in-flight commands, the source worker's forwarding
/// tombstone) re-routes a stale handle to the stream's new home, so a
/// producer never has to re-open.
#[derive(Clone, Debug)]
pub struct StreamHandle {
    shard: usize,
    slot: u32,
    gen: u32,
    id: Arc<str>,
    /// The stream's snapshot publication cell — shared with the worker
    /// entry (it migrates with the stream), read lock-free by
    /// [`StreamRouter::project_snapshot`]/[`StreamRouter::project_many`].
    cell: Arc<SnapshotCell>,
}

impl StreamHandle {
    /// The stream id this handle was opened with.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// The shard the stream was pinned to *when this handle was
    /// resolved*. A later migration may have moved the stream; the
    /// handle keeps working regardless (redirect table + tombstone
    /// forwarding), and [`PoolSnapshot::per_stream`] attributes the
    /// stream to its current shard.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// The stream's snapshot cell (epoch, lock-free read counter).
    pub fn snapshot_cell(&self) -> &Arc<SnapshotCell> {
        &self.cell
    }
}

/// Fully-resolved (shard, slot, generation) coordinate — the key of the
/// router's redirect table and the payload of a `Moved` tombstone.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct StreamAddr {
    shard: usize,
    slot: u32,
    gen: u32,
}

/// Reply of `Install`: the entry's new (slot, gen) on the target, or
/// the entry handed back with the reason so the source can reinstate.
type InstallReply = Result<(u32, u32), (Box<StreamEntry>, String)>;

/// Reply of `ListStreams`: (id, slot, gen) of every live stream.
type StreamListing = Vec<(Arc<str>, u32, u32)>;

enum ShardCommand {
    Open {
        stream: Arc<str>,
        dim: usize,
        cfg: StreamConfig,
        /// Router-created snapshot cell, shared with the handle — the
        /// worker publishes through it for the stream's whole life.
        cell: Arc<SnapshotCell>,
        reply: SyncSender<Result<(u32, u32), String>>,
    },
    Ingest {
        slot: u32,
        gen: u32,
        x: Vec<f64>,
        reply: SyncSender<Result<IngestReply, String>>,
    },
    /// Fire-and-forget ingest: no reply channel. Failures increment the
    /// stream's error counters; the first deferred message surfaces on
    /// the next `Sync`.
    IngestAsync {
        slot: u32,
        gen: u32,
        x: Vec<f64>,
    },
    /// One command per batch: `xs` is `b × dim` row-major. The reply
    /// hands the batch buffer back so chunked feeders
    /// ([`StreamRouter::ingest_all`]) can reuse one allocation for the
    /// whole feed instead of copying every chunk into a fresh `Vec`.
    IngestMany {
        slot: u32,
        gen: u32,
        xs: Vec<f64>,
        reply: SyncSender<(Result<BatchReply, String>, Vec<f64>)>,
    },
    /// Barrier + deferred-error drain for async ingest.
    Sync {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<u64, String>>,
    },
    Project {
        slot: u32,
        gen: u32,
        x: Vec<f64>,
        r: usize,
        reply: SyncSender<Result<Vec<f64>, String>>,
    },
    MeasureDrift {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<DriftPoint, String>>,
    },
    Snapshot {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<Snapshot, String>>,
    },
    Metrics {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<MetricsReport, String>>,
    },
    Close {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<KpcaStats, String>>,
    },
    /// Move the stream at (slot, gen) to `to_shard`: executed by the
    /// *source* worker (so the shard queue doubles as the drain
    /// barrier), replies with the stream's new (slot, gen) on the
    /// target.
    Migrate {
        slot: u32,
        gen: u32,
        to_shard: usize,
        reply: SyncSender<Result<(u32, u32), String>>,
    },
    /// Re-home a migrated (or, during recovery, restored) entry. The
    /// entry rides the channel — `StreamEntry` is `Send` because the
    /// eigensystem is. On failure the entry comes back so the source
    /// can reinstate it. `from_migration` keeps restore installs out of
    /// the migration counters.
    Install {
        entry: Box<StreamEntry>,
        from_migration: bool,
        reply: SyncSender<InstallReply>,
    },
    /// Write one stream's checkpoint to the pool's snapshot directory.
    /// Slot-addressed, so the shard queue drains ahead of it — the
    /// captured state reflects every previously enqueued command (the
    /// same consistent-cut barrier migration uses). Replies with the
    /// checkpoint's encoded byte length.
    Checkpoint {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<u64, String>>,
    },
    /// Checkpoint every live stream on this shard, then rotate the
    /// shard's WAL (every logged suffix is captured, so the old log is
    /// redundant). The WAL is only rotated when *all* checkpoints
    /// succeeded — a stream whose checkpoint failed still needs its
    /// suffix. Replies with the number of streams checkpointed.
    CheckpointAll {
        reply: SyncSender<Result<usize, String>>,
    },
    /// Live streams of this shard, as (id, slot, gen) — the rebalance
    /// work list.
    ListStreams {
        reply: SyncSender<StreamListing>,
    },
    Rollup {
        reply: SyncSender<ShardRollup>,
    },
    Shutdown,
}

/// The (slot, gen) a command addresses, if it addresses one — the
/// forwarding hook for `Moved` tombstones.
fn cmd_addr(cmd: &ShardCommand) -> Option<(u32, u32)> {
    match cmd {
        ShardCommand::Ingest { slot, gen, .. }
        | ShardCommand::IngestAsync { slot, gen, .. }
        | ShardCommand::IngestMany { slot, gen, .. }
        | ShardCommand::Sync { slot, gen, .. }
        | ShardCommand::Project { slot, gen, .. }
        | ShardCommand::MeasureDrift { slot, gen, .. }
        | ShardCommand::Snapshot { slot, gen, .. }
        | ShardCommand::Metrics { slot, gen, .. }
        | ShardCommand::Close { slot, gen, .. }
        | ShardCommand::Migrate { slot, gen, .. }
        | ShardCommand::Checkpoint { slot, gen, .. } => Some((*slot, *gen)),
        ShardCommand::Open { .. }
        | ShardCommand::Install { .. }
        | ShardCommand::CheckpointAll { .. }
        | ShardCommand::ListStreams { .. }
        | ShardCommand::Rollup { .. }
        | ShardCommand::Shutdown => None,
    }
}

/// Rebuild a command under the migrated stream's new (slot, gen) so it
/// can be forwarded to the target shard verbatim (reply channels ride
/// along — the eventual answer goes straight back to the producer).
fn readdress(cmd: ShardCommand, to: StreamAddr) -> ShardCommand {
    let (slot, gen) = (to.slot, to.gen);
    match cmd {
        ShardCommand::Ingest { x, reply, .. } => ShardCommand::Ingest { slot, gen, x, reply },
        ShardCommand::IngestAsync { x, .. } => ShardCommand::IngestAsync { slot, gen, x },
        ShardCommand::IngestMany { xs, reply, .. } => {
            ShardCommand::IngestMany { slot, gen, xs, reply }
        }
        ShardCommand::Sync { reply, .. } => ShardCommand::Sync { slot, gen, reply },
        ShardCommand::Project { x, r, reply, .. } => {
            ShardCommand::Project { slot, gen, x, r, reply }
        }
        ShardCommand::MeasureDrift { reply, .. } => {
            ShardCommand::MeasureDrift { slot, gen, reply }
        }
        ShardCommand::Snapshot { reply, .. } => ShardCommand::Snapshot { slot, gen, reply },
        ShardCommand::Metrics { reply, .. } => ShardCommand::Metrics { slot, gen, reply },
        ShardCommand::Close { reply, .. } => ShardCommand::Close { slot, gen, reply },
        ShardCommand::Migrate { to_shard, reply, .. } => {
            ShardCommand::Migrate { slot, gen, to_shard, reply }
        }
        ShardCommand::Checkpoint { reply, .. } => ShardCommand::Checkpoint { slot, gen, reply },
        other => other,
    }
}

/// Per-shard aggregation answered to `Rollup` (internal wire format;
/// the router folds these into one [`PoolSnapshot`]).
struct ShardRollup {
    streams: usize,
    accepted: u64,
    excluded: u64,
    errors: u64,
    evictions: u64,
    total_ws_bytes: u64,
    ws_engine_gemms: u64,
    migrated_in: u64,
    migrated_out: u64,
    forwarded: u64,
    snapshot_reads: u64,
    worker_reads: u64,
    checkpoints: u64,
    wal_appends: u64,
    wal_bytes: u64,
    wal_errors: u64,
    restored: usize,
    ingest: LatencyHistogram,
    project: LatencyHistogram,
    engine_calls: (u64, u64),
    gauges: Vec<StreamGauges>,
}

/// Lifetime totals of streams already closed on this shard: folded into
/// every rollup so pool-level counters stay *monotonic* across stream
/// churn (closing a stream must not erase its history from the pool).
/// Residency gauges are deliberately not kept — closed streams hold no
/// bytes. `orphans` counts commands addressed to dead slots (stale
/// handles); with no live entry to attribute them to, they live here.
///
/// Migrated-away streams do NOT fold here: their counters travel to the
/// target inside the entry's own [`Metrics`], which preserves the pool
/// total without double counting — only the per-shard migration event
/// counts ([`MigrationStats`]) stay behind, folded the same way these
/// totals are.
#[derive(Default)]
struct ClosedTotals {
    accepted: u64,
    excluded: u64,
    errors: u64,
    evictions: u64,
    orphans: u64,
    engine_gemms: u64,
    /// Worker-path projections served by streams closed since spawn.
    worker_reads: u64,
    /// Snapshot-path reads served by closed streams' cells (absorbed
    /// from the cell at close, since the cell lives outside `Metrics`).
    snapshot_reads: u64,
    checkpoints: u64,
    wal_appends: u64,
    wal_bytes: u64,
    wal_errors: u64,
    ingest: LatencyHistogram,
    project: LatencyHistogram,
}

impl ClosedTotals {
    fn absorb(&mut self, m: &Metrics) {
        self.accepted += m.accepted;
        self.excluded += m.excluded;
        self.errors += m.errors;
        self.evictions += m.evictions;
        self.engine_gemms += m.engine_gemms;
        self.worker_reads += m.worker_reads;
        self.checkpoints += m.checkpoints;
        self.wal_appends += m.wal_appends;
        self.wal_bytes += m.wal_bytes;
        self.wal_errors += m.wal_errors;
        self.ingest.merge(&m.ingest_latency);
        self.project.merge(&m.project_latency);
    }
}

/// Per-shard migration event counters, reported in every rollup.
#[derive(Default)]
struct MigrationStats {
    migrated_in: u64,
    migrated_out: u64,
    forwarded: u64,
}

/// Build the kernel a stream entry owns (shared ownership — freed with
/// the stream, never leaked).
fn build_kernel(cfg: &KernelConfig, seed: &Mat) -> Arc<dyn Kernel> {
    match cfg {
        KernelConfig::Rbf { sigma } => Arc::new(crate::kernels::Rbf { sigma: *sigma }),
        KernelConfig::RbfMedian => {
            let sigma = median_heuristic(seed, 500);
            Arc::new(crate::kernels::Rbf { sigma })
        }
        KernelConfig::Linear => Arc::new(crate::kernels::Linear),
        KernelConfig::Polynomial { degree, offset } => {
            Arc::new(crate::kernels::Polynomial { degree: *degree, offset: *offset })
        }
        KernelConfig::Laplacian { sigma } => {
            Arc::new(crate::kernels::Laplacian { sigma: *sigma })
        }
    }
}

/// Build the shard's shared rotation engine. The PJRT runtime is not
/// `Send`, so this runs inside the worker thread — one runtime per
/// worker, shared by all streams pinned to it.
fn build_engine(cfg: &EngineConfig) -> RoutedEngine {
    match cfg {
        EngineConfig::Native => RoutedEngine::native_only(),
        EngineConfig::Pjrt { dir, policy } => {
            match crate::runtime::Runtime::new(std::path::Path::new(dir)) {
                Ok(rt) => RoutedEngine::with_pjrt(
                    crate::runtime::PjrtRotate::new(std::sync::Arc::new(rt)),
                    policy.clone(),
                ),
                Err(e) => {
                    eprintln!("shard: pjrt unavailable ({e}); using native engine");
                    RoutedEngine::native_only()
                }
            }
        }
    }
}

/// All state of one stream, owned by exactly one shard worker at a
/// time: the incremental eigensystem (which itself owns the kernel, the
/// update workspace and the eigenbasis), the drift monitor, and the
/// per-stream metrics. Stored in its shard's slot vector; `gen` must
/// match the addressing handle's generation. Everything inside is
/// `Send`, so a migration ships the whole entry over the target
/// shard's channel — counters and histograms travel with it.
struct StreamEntry {
    id: Arc<str>,
    gen: u32,
    cfg: StreamConfig,
    dim: usize,
    seed_buf: Vec<f64>,
    seeded: usize,
    /// The stream's engine behind the tier seam — chosen by
    /// [`StreamConfig::tier`] at seed completion (see
    /// [`super::engine::seed_state`]). Boxed and `Send`, so migration
    /// ships it like any other field.
    state: Option<Box<dyn StreamState>>,
    drift: DriftMonitor,
    metrics: Metrics,
    /// First error deferred by fire-and-forget ingest, surfaced (and
    /// cleared) by the next `Sync`.
    pending_error: Option<String>,
    /// The stream's published-snapshot cell, shared with every clone of
    /// the stream's handle. It travels with the entry across
    /// migrations, so the epoch stays monotonic over the stream's whole
    /// life and readers never observe a reset.
    cell: Arc<SnapshotCell>,
    /// Accepted points applied since the last snapshot publish — the
    /// staleness gauge surfaced as `points_since_publish`.
    since_publish: u64,
    /// Next WAL sequence number to assign. Travels with the entry
    /// across migrations, so a stream's records stay totally ordered
    /// even when they span several shard logs; the checkpoint stores it
    /// so recovery replays exactly the post-cut suffix.
    ingest_seq: u64,
    /// When the last snapshot was published — the reference point of
    /// the [`StreamConfig::publish_after`] deadline.
    last_publish: Instant,
    /// Whether this entry was rebuilt by crash recovery (surfaced in
    /// the stream's gauges; counted pool-wide as `recovered_streams`).
    restored: bool,
    /// Evictions since the last eviction-triggered drift audit. When a
    /// bounded stream evicts, every `drift_every` evictions force a
    /// spot measurement into the live monitor — a misbehaving eviction
    /// policy is caught in production gauges, not only by the oracle
    /// test suite. Transient cadence state, deliberately not
    /// checkpointed.
    evictions_since_audit: u64,
}

impl StreamEntry {
    fn new(
        id: Arc<str>,
        gen: u32,
        dim: usize,
        cfg: StreamConfig,
        cell: Arc<SnapshotCell>,
    ) -> StreamEntry {
        let drift = DriftMonitor::new(cfg.drift_every);
        StreamEntry {
            id,
            gen,
            cfg,
            dim,
            seed_buf: Vec::new(),
            seeded: 0,
            state: None,
            drift,
            metrics: Metrics::default(),
            pending_error: None,
            cell,
            since_publish: 0,
            ingest_seq: 0,
            last_publish: Instant::now(),
            restored: false,
            evictions_since_audit: 0,
        }
    }

    fn min_seed(&self) -> usize {
        if self.cfg.mean_adjust {
            self.cfg.seed_points.max(2)
        } else {
            self.cfg.seed_points.max(1)
        }
    }

    /// Buffer one point toward the seed batch; initializes the
    /// eigensystem when the seed quota is reached.
    fn seed_point(&mut self, x: &[f64]) -> Result<IngestReply, String> {
        self.seed_buf.extend_from_slice(x);
        self.seeded += 1;
        if self.seeded < self.min_seed() {
            return Ok(IngestReply { accepted: true, m: self.seeded, seeding: true });
        }
        let seed = Mat::from_vec(self.seeded, self.dim, self.seed_buf.clone());
        let kernel = build_kernel(&self.cfg.kernel, &seed);
        match engine::seed_state(&self.cfg, kernel, &seed, &self.id) {
            Ok(mut st) => {
                // Warm the entry per the open-time expectations: one
                // reserve here replaces incremental growth across the
                // stream's first batches (ROADMAP "per-stream reserve
                // through the coordinator").
                if self.cfg.expected_m > 0 || self.cfg.expected_batch > 0 {
                    st.reserve(
                        self.cfg.expected_m.max(self.seeded),
                        self.cfg.expected_batch,
                    );
                }
                // Bounded-memory streams: cap the landmark set, protect
                // the seed prefix. `m` transiently reaches cap+1 before
                // the eviction lands, so reserve that extra row too.
                // (No-op on tiers without a landmark set.)
                if self.cfg.max_landmarks > 0 {
                    st.set_bound(self.cfg.max_landmarks, self.cfg.eviction, self.seeded);
                    st.reserve(
                        (self.cfg.max_landmarks + 1).max(self.seeded),
                        self.cfg.expected_batch,
                    );
                }
                // The batch init allocated the full eigensystem +
                // workspace — publish the residency gauges now, not
                // only after the first post-seed push.
                self.state = Some(st);
                self.refresh_gauges();
                // First publish: the moment the eigensystem exists,
                // snapshot readers stop erroring with "still seeding".
                self.publish_snapshot();
                Ok(IngestReply { accepted: true, m: self.seeded, seeding: false })
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    /// Refresh the per-stream hot-path gauges from the eigensystem:
    /// update count, resident bytes and growth events across the
    /// rank-one workspace, the eigenbasis *and* the batched-ingest
    /// scratch — batched streams' kernel-block memory must be visible
    /// to the pool rollup too.
    fn refresh_gauges(&mut self) {
        let st = self.state.as_ref().expect("gauges need an initialized stream");
        self.metrics.updates = st.stats().updates as u64;
        self.metrics.ws_bytes_resident = st.bytes_resident() as u64;
        self.metrics.ws_reallocs = st.reallocs();
        self.metrics.engine_gemms = st.engine_gemms();
        self.metrics.evictions = st.stats().evictions as u64;
        self.metrics.sufficiency_gap = st.sufficiency_gap();
        self.metrics.divergence = st.divergence();
    }

    /// Capture and publish a fresh projection snapshot (no-op while
    /// seeding). Publish points: seed completion, every
    /// [`StreamConfig::publish_every`] accepted points, the end of
    /// every batch command, and `sync` — the read-your-writes point.
    fn publish_snapshot(&mut self) {
        if let Some(st) = &mut self.state {
            if let Some(snap) = st.capture(self.cfg.snapshot_r) {
                self.cell.publish(snap);
                self.since_publish = 0;
                self.last_publish = Instant::now();
                // Divergence is measured per publish window: readers of
                // the fresh snapshot start a fresh max.
                st.reset_divergence();
            }
        }
    }

    /// Whether the sequential-path auto-publish cadence is due: the
    /// accepted-point counter ([`StreamConfig::publish_every`]) or the
    /// wall-clock deadline ([`StreamConfig::publish_after`]), whichever
    /// fires first. The deadline only fires with unpublished points
    /// waiting — an idle stream republishes nothing.
    fn publish_due(&self) -> bool {
        if self.cfg.publish_every > 0 && self.since_publish >= self.cfg.publish_every as u64 {
            return true;
        }
        match self.cfg.publish_after {
            Some(d) => self.since_publish > 0 && self.last_publish.elapsed() >= d,
            None => false,
        }
    }

    /// Eviction-triggered spot audit: bounded streams rewrite their
    /// retained set in place, so every [`StreamConfig::drift_every`]
    /// *evictions* (not accepted points) force one drift measurement
    /// into the live monitor — down-date bugs surface at the next pool
    /// snapshot instead of waiting out the accept cadence. The counter
    /// is transient (deliberately not checkpointed): an audit cadence,
    /// not replayable state.
    fn spot_audit(&mut self, evictions: u64) {
        if self.cfg.drift_every == 0 {
            return;
        }
        self.evictions_since_audit += evictions;
        if self.evictions_since_audit < self.cfg.drift_every as u64 {
            return;
        }
        self.evictions_since_audit = 0;
        if let Some(st) = &mut self.state {
            // Tiers without a Gram matrix decline; the cadence still
            // reset — the audit is best-effort per window.
            if let Ok(p) = st.measure_drift() {
                self.drift.record(p);
            }
        }
    }

    fn ingest(&mut self, x: &[f64], engine: &RoutedEngine) -> Result<IngestReply, String> {
        if x.len() != self.dim {
            self.metrics.errors += 1;
            return Err(format!("dimension mismatch: got {}, want {}", x.len(), self.dim));
        }
        if self.state.is_none() {
            return self.seed_point(x);
        }
        let st = self.state.as_mut().unwrap();
        let evictions_before = st.stats().evictions;
        match st.push_with(x, engine) {
            Ok(accepted) => {
                if accepted {
                    self.metrics.accepted += 1;
                    if self.drift.note(1) {
                        // Tiers without a Gram matrix (rff) decline the
                        // measurement; the cadence phase still advanced.
                        if let Ok(p) = st.measure_drift() {
                            self.drift.record(p);
                        }
                    }
                } else {
                    self.metrics.excluded += 1;
                }
                let m = st.len();
                let evictions_after = st.stats().evictions;
                let evicted = evictions_after > evictions_before;
                if evicted {
                    self.spot_audit((evictions_after - evictions_before) as u64);
                }
                self.refresh_gauges();
                if accepted {
                    self.since_publish += 1;
                    // An eviction rewrites the retained set in place —
                    // published projections referencing the old set are
                    // stale, so the epoch bumps immediately instead of
                    // waiting out the publish cadence.
                    if evicted || self.publish_due() {
                        self.publish_snapshot();
                    }
                }
                Ok(IngestReply { accepted, m, seeding: false })
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    /// Batched ingest: points still owed to the seed buffer are
    /// consumed one by one (they are cheap copies); the remainder goes
    /// through the eigensystem's blocked batch entry point in one call.
    /// On `Err`, points before the failure remain applied.
    fn ingest_many(&mut self, xs: &[f64], engine: &RoutedEngine) -> Result<BatchReply, String> {
        if self.dim == 0 || xs.len() % self.dim != 0 {
            self.metrics.errors += 1;
            return Err(format!(
                "batch length {} is not a multiple of dim {}",
                xs.len(),
                self.dim
            ));
        }
        let b = xs.len() / self.dim;
        let mut reply = BatchReply::default();
        let mut off = 0;
        while self.state.is_none() && off < b {
            self.seed_point(&xs[off * self.dim..(off + 1) * self.dim])?;
            reply.seeded += 1;
            off += 1;
        }
        if off < b {
            let st = self.state.as_mut().unwrap();
            let evictions_before = st.stats().evictions;
            let result = st.push_batch_with(&xs[off * self.dim..], engine);
            // The accepted prefix stays applied even on `Err` (the mask
            // covers exactly the processed points) — counters, drift
            // cadence and gauges must track it either way, or `m` would
            // permanently outrun the accounting after one bad batch.
            let accepted = st.last_batch_mask().iter().filter(|&&ok| ok).count();
            let excluded = st.last_batch_mask().len() - accepted;
            self.metrics.accepted += accepted as u64;
            self.metrics.excluded += excluded as u64;
            if self.drift.note(accepted) {
                if let Ok(p) = st.measure_drift() {
                    self.drift.record(p);
                }
            }
            let evictions_after = st.stats().evictions;
            if evictions_after > evictions_before {
                self.spot_audit((evictions_after - evictions_before) as u64);
            }
            self.refresh_gauges();
            // Batch flush = publish point, even for a partial batch:
            // the applied prefix is real state and readers may see it.
            self.publish_snapshot();
            match result {
                Ok(_) => {
                    reply.accepted = accepted;
                    reply.excluded = excluded;
                }
                Err(e) => {
                    self.metrics.errors += 1;
                    return Err(e);
                }
            }
        }
        reply.m = self.state.as_ref().map(|s| s.len()).unwrap_or(self.seeded);
        Ok(reply)
    }

    /// Write-ahead: frame and append an ingest command's points
    /// *before* they are applied, so replaying the log through the
    /// normal ingest path after a crash reproduces exactly the applied
    /// prefix. Only commands that pass the shape check are logged —
    /// malformed ones error identically live and on replay, except they
    /// never reach the log. `single` mirrors the stricter length check
    /// of the one-point path (a multiple-of-dim vector that is not
    /// exactly one point must not be replayed as a batch).
    ///
    /// `scratch` is the worker's one reusable record: refilled in place
    /// per append, so the steady-state logging path allocates nothing
    /// once its buffers are warm. Append failures degrade, never block:
    /// the stream stays live in memory and the failure lands in the
    /// per-stream `wal_errors` counter.
    fn wal_log_ingest(
        &mut self,
        wal: &mut Option<WalWriter>,
        scratch: &mut WalRecord,
        pts: &[f64],
        single: bool,
    ) {
        let Some(w) = wal.as_mut() else { return };
        let shape_ok = if single {
            pts.len() == self.dim
        } else {
            self.dim > 0 && !pts.is_empty() && pts.len() % self.dim == 0
        };
        if !shape_ok {
            return;
        }
        {
            let WalRecord::Ingest { id, seq, dim, points } = &mut *scratch else {
                unreachable!("worker scratch is always an Ingest record")
            };
            id.clear();
            id.push_str(&self.id);
            *seq = self.ingest_seq;
            *dim = self.dim as u32;
            points.clear();
            points.extend_from_slice(pts);
        }
        // The sequence number advances whether or not the append lands:
        // a degraded log gets gaps, never ambiguous reuse.
        self.ingest_seq += 1;
        let errors_before = w.errors();
        if let Some(n) = w.append(scratch) {
            self.metrics.wal_appends += 1;
            self.metrics.wal_bytes += n;
        }
        self.metrics.wal_errors += w.errors() - errors_before;
    }

    fn project(&mut self, x: &[f64], r: usize) -> Result<Vec<f64>, String> {
        let dim = self.dim;
        match (&mut self.state, x.len() == dim) {
            (Some(st), true) => st.project(x, r),
            (Some(_), false) => Err("dimension mismatch".to_string()),
            (None, _) => Err("not initialized (still seeding)".to_string()),
        }
    }

    fn measure_drift(&mut self) -> Result<DriftPoint, String> {
        match &mut self.state {
            Some(st) => {
                let p = st.measure_drift()?;
                self.drift.record(p);
                Ok(p)
            }
            None => Err("not initialized".to_string()),
        }
    }

    fn kernel_name(&self) -> &'static str {
        match &self.state {
            Some(st) => st.kernel_name(),
            None => self.cfg.kernel.name(),
        }
    }

    fn snapshot(&self, engine_calls: (u64, u64)) -> Snapshot {
        match &self.state {
            Some(st) => Snapshot {
                m: st.len(),
                dim: self.dim,
                kernel: st.kernel_name(),
                tier: st.tier_name(),
                top_values: st.top_values(10),
                stats: st.stats(),
                drift: self.drift.latest().copied(),
                engine_calls,
            },
            None => Snapshot {
                m: self.seeded,
                dim: self.dim,
                kernel: self.kernel_name(),
                tier: self.cfg.tier.name(),
                top_values: Vec::new(),
                stats: KpcaStats::default(),
                drift: None,
                engine_calls,
            },
        }
    }

    fn gauges(&self, shard: usize) -> StreamGauges {
        StreamGauges {
            stream: self.id.to_string(),
            shard,
            m: self.state.as_ref().map(|s| s.len()).unwrap_or(self.seeded),
            ws_bytes_resident: self.metrics.ws_bytes_resident,
            ws_reallocs: self.metrics.ws_reallocs,
            reallocs_per_update: self.metrics.reallocs_per_update(),
            engine_gemms: self.metrics.engine_gemms,
            evictions: self.metrics.evictions,
            sufficiency_gap: self.metrics.sufficiency_gap,
            divergence: self.metrics.divergence,
            drift_frobenius: self.drift.latest().map(|d| d.norms.frobenius),
            snapshot_epoch: self.cell.epoch(),
            snapshot_reads: self.cell.reads(),
            worker_reads: self.metrics.worker_reads,
            points_since_publish: self.since_publish,
            checkpoints: self.metrics.checkpoints,
            restored: self.restored,
        }
    }

    /// Per-stream metrics report with the snapshot gauges filled in —
    /// the cell and the staleness counter live on the entry, next to
    /// the handle, not inside [`Metrics`].
    fn report(&self) -> MetricsReport {
        let mut r = self.metrics.report();
        r.snapshot_epoch = self.cell.epoch();
        r.snapshot_reads = self.cell.reads();
        r.points_since_publish = self.since_publish;
        r
    }

    fn final_stats(self) -> KpcaStats {
        self.state.map(|s| s.stats()).unwrap_or_default()
    }

    /// Serialize everything this stream needs to come back after a
    /// crash. Runs between commands on the owning worker, so the cut is
    /// consistent: every command enqueued ahead of the checkpoint has
    /// fully applied (the queue-drain barrier migration uses).
    fn to_checkpoint(&self) -> CheckpointData {
        let state = self.state.as_ref().map(|st| st.to_parts());
        CheckpointData {
            id: self.id.to_string(),
            dim: self.dim,
            cfg: self.cfg.clone(),
            seeded: self.seeded,
            seed_buf: self.seed_buf.clone(),
            state,
            drift_every: self.drift.every,
            drift_accepted_since: self.drift.accepted_since(),
            drift_history: self.drift.history().to_vec(),
            counters: PersistedCounters {
                accepted: self.metrics.accepted,
                excluded: self.metrics.excluded,
                errors: self.metrics.errors,
                async_errors: self.metrics.async_errors,
                worker_reads: self.metrics.worker_reads,
                checkpoints: self.metrics.checkpoints,
                wal_appends: self.metrics.wal_appends,
                wal_bytes: self.metrics.wal_bytes,
                wal_errors: self.metrics.wal_errors,
            },
            since_publish: self.since_publish,
            ingest_seq: self.ingest_seq,
        }
    }

    /// Write this stream's checkpoint (atomic temp + rename; see
    /// [`super::persist::write_checkpoint`]). Counts into the stream's
    /// `checkpoints` gauge on success, its `errors` counter on failure.
    fn checkpoint_to(&mut self, dir: &Path) -> Result<u64, String> {
        let data = self.to_checkpoint();
        match persist::write_checkpoint(dir, &data) {
            Ok(n) => {
                self.metrics.checkpoints += 1;
                Ok(n)
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(format!("checkpoint of '{}' failed: {e}", self.id))
            }
        }
    }

    /// Rebuild an entry from checkpointed parts (generation 0 — the
    /// installing worker assigns the real slot and generation). The
    /// kernel is reconstructed from its `describe()` string; an
    /// unparseable or shape-inconsistent checkpoint is an `Err`, which
    /// recovery reports without aborting the pool. Latency histograms
    /// and snapshot epochs restart fresh — they are process-lifetime
    /// observability, deliberately not persisted.
    fn from_checkpoint(
        data: CheckpointData,
        cell: Arc<SnapshotCell>,
    ) -> Result<Box<StreamEntry>, String> {
        let state = match data.state {
            None => None,
            Some(parts) => {
                let mut st = engine::state_from_parts(parts)?;
                if data.cfg.expected_m > 0 || data.cfg.expected_batch > 0 {
                    st.reserve(data.cfg.expected_m.max(st.len()), data.cfg.expected_batch);
                }
                // The bound is configuration, not serialized state:
                // re-apply it from the checkpointed StreamConfig (the
                // Uniform round-robin cursor rides in `stats.evictions`,
                // which `from_parts` already restored). No-op on tiers
                // without a landmark set.
                if data.cfg.max_landmarks > 0 {
                    st.set_bound(data.cfg.max_landmarks, data.cfg.eviction, data.seeded);
                    st.reserve(
                        (data.cfg.max_landmarks + 1).max(st.len()),
                        data.cfg.expected_batch,
                    );
                }
                Some(st)
            }
        };
        let mut metrics = Metrics::default();
        let c = data.counters;
        metrics.accepted = c.accepted;
        metrics.excluded = c.excluded;
        metrics.errors = c.errors;
        metrics.async_errors = c.async_errors;
        metrics.worker_reads = c.worker_reads;
        metrics.checkpoints = c.checkpoints;
        metrics.wal_appends = c.wal_appends;
        metrics.wal_bytes = c.wal_bytes;
        metrics.wal_errors = c.wal_errors;
        let mut entry = Box::new(StreamEntry {
            id: Arc::from(data.id.as_str()),
            gen: 0,
            cfg: data.cfg,
            dim: data.dim,
            seed_buf: data.seed_buf,
            seeded: data.seeded,
            state,
            drift: DriftMonitor::from_parts(
                data.drift_every,
                data.drift_accepted_since,
                data.drift_history,
            ),
            metrics,
            pending_error: None,
            cell,
            since_publish: data.since_publish,
            ingest_seq: data.ingest_seq,
            last_publish: Instant::now(),
            restored: true,
            evictions_since_audit: 0,
        });
        if entry.state.is_some() {
            entry.refresh_gauges();
            // The restored eigensystem is current state: publish it so
            // snapshot readers serve immediately (which also zeroes the
            // staleness gauge — correctly, the snapshot is fresh).
            entry.publish_snapshot();
        }
        Ok(entry)
    }
}

/// One storage slot of a shard worker. Entries are boxed: the slot
/// vector stays dense for the integer-indexed lookup, migration moves
/// a pointer instead of memcpy-ing the whole eigensystem holder, and
/// the enum's variants stay size-balanced.
enum Slot {
    /// Recyclable (on the free list, or never used).
    Empty,
    /// An open stream owned by this worker.
    Live(Box<StreamEntry>),
    /// Tombstone of a migrated-away stream: commands addressed at
    /// (this slot, `gen`) are re-addressed and forwarded to `to`.
    /// Never recycled — a handle resolved before the move must stay
    /// forwardable for the pool's life (the price is one enum variant
    /// per migration, not the entry itself).
    Moved { gen: u32, to: StreamAddr },
}

/// Shard-local stream storage: slot-indexed entries (the ingest path
/// addresses by integer), a name map used only at open/close, and the
/// free list for slot reuse.
#[derive(Default)]
struct SlotTable {
    slots: Vec<Slot>,
    names: HashMap<Arc<str>, u32>,
    free: Vec<u32>,
    next_gen: u32,
}

impl SlotTable {
    fn alloc_slot(&mut self) -> u32 {
        self.free.pop().unwrap_or_else(|| {
            self.slots.push(Slot::Empty);
            (self.slots.len() - 1) as u32
        })
    }

    fn open(
        &mut self,
        stream: Arc<str>,
        dim: usize,
        cfg: StreamConfig,
        cell: Arc<SnapshotCell>,
    ) -> Result<(u32, u32), String> {
        if self.names.contains_key(stream.as_ref()) {
            return Err(format!("stream '{stream}' already open"));
        }
        let slot = self.alloc_slot();
        let gen = self.next_gen;
        self.next_gen = self.next_gen.wrapping_add(1);
        self.slots[slot as usize] =
            Slot::Live(Box::new(StreamEntry::new(stream.clone(), gen, dim, cfg, cell)));
        self.names.insert(stream, slot);
        Ok((slot, gen))
    }

    /// The live entry a (slot, gen) pair addresses, if any.
    fn get_mut(&mut self, slot: u32, gen: u32) -> Result<&mut StreamEntry, String> {
        match self.slots.get_mut(slot as usize) {
            Some(Slot::Live(e)) if e.gen == gen => Ok(e.as_mut()),
            _ => Err("unknown or closed stream".to_string()),
        }
    }

    fn get(&self, slot: u32, gen: u32) -> Result<&StreamEntry, String> {
        match self.slots.get(slot as usize) {
            Some(Slot::Live(e)) if e.gen == gen => Ok(e.as_ref()),
            _ => Err("unknown or closed stream".to_string()),
        }
    }

    /// Forwarding target if (slot, gen) is a migration tombstone.
    fn moved_to(&self, slot: u32, gen: u32) -> Option<StreamAddr> {
        match self.slots.get(slot as usize) {
            Some(Slot::Moved { gen: g, to }) if *g == gen => Some(*to),
            _ => None,
        }
    }

    fn close(&mut self, slot: u32, gen: u32) -> Result<Box<StreamEntry>, String> {
        match self.slots.get_mut(slot as usize) {
            Some(s) if matches!(s, Slot::Live(e) if e.gen == gen) => {
                let Slot::Live(entry) = std::mem::replace(s, Slot::Empty) else {
                    unreachable!("matched Live above")
                };
                self.names.remove(entry.id.as_ref());
                self.free.push(slot);
                Ok(entry)
            }
            _ => Err("unknown or closed stream".to_string()),
        }
    }

    /// Take the entry out for migration (name unregistered, slot left
    /// `Empty` until the caller installs the tombstone or reinstates).
    /// Only the owning worker calls this, and it resolves the slot to a
    /// tombstone or a reinstated entry before processing any further
    /// command, so the intermediate `Empty` is never observable. The
    /// slot is NOT pushed to the free list here — a successful
    /// migration turns it into a tombstone, a failed one reinstates.
    fn extract(&mut self, slot: u32, gen: u32) -> Result<Box<StreamEntry>, String> {
        match self.slots.get_mut(slot as usize) {
            Some(s) if matches!(s, Slot::Live(e) if e.gen == gen) => {
                let Slot::Live(entry) = std::mem::replace(s, Slot::Empty) else {
                    unreachable!("matched Live above")
                };
                self.names.remove(entry.id.as_ref());
                Ok(entry)
            }
            _ => Err("unknown or closed stream".to_string()),
        }
    }

    /// Undo a failed migration: put the extracted entry back into its
    /// original slot (generation unchanged — the handle stays valid).
    fn reinstate(&mut self, slot: u32, entry: Box<StreamEntry>) {
        self.names.insert(entry.id.clone(), slot);
        self.slots[slot as usize] = Slot::Live(entry);
    }

    /// Commit a migration: leave the forwarding tombstone. The slot is
    /// deliberately NOT returned to the free list.
    fn set_moved(&mut self, slot: u32, gen: u32, to: StreamAddr) {
        self.slots[slot as usize] = Slot::Moved { gen, to };
    }

    /// Recycle a slot vacated by `extract` whose entry will not come
    /// back (lost migration). Reuse is safe — generations are never
    /// reissued.
    fn free_slot(&mut self, slot: u32) {
        self.slots[slot as usize] = Slot::Empty;
        self.free.push(slot);
    }

    /// Re-home a migrated entry under a fresh local slot + generation.
    fn install(&mut self, mut entry: Box<StreamEntry>) -> InstallReply {
        if self.names.contains_key(entry.id.as_ref()) {
            let msg = format!("stream '{}' already open on target shard", entry.id);
            return Err((entry, msg));
        }
        let slot = self.alloc_slot();
        let gen = self.next_gen;
        self.next_gen = self.next_gen.wrapping_add(1);
        entry.gen = gen;
        self.names.insert(entry.id.clone(), slot);
        self.slots[slot as usize] = Slot::Live(entry);
        Ok((slot, gen))
    }

    fn live(&self) -> impl Iterator<Item = &StreamEntry> {
        self.slots.iter().filter_map(|s| match s {
            Slot::Live(e) => Some(e.as_ref()),
            _ => None,
        })
    }

    /// Mutable sweep over the live entries — the `CheckpointAll` walk.
    fn live_mut(&mut self) -> impl Iterator<Item = &mut StreamEntry> {
        self.slots.iter_mut().filter_map(|s| match s {
            Slot::Live(e) => Some(e.as_mut()),
            _ => None,
        })
    }

    /// Live streams as the rebalance work list.
    fn list(&self) -> Vec<(Arc<str>, u32, u32)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| match s {
                Slot::Live(e) => Some((e.id.clone(), i as u32, e.gen)),
                _ => None,
            })
            .collect()
    }

    fn live_count(&self) -> usize {
        self.names.len()
    }
}

/// The routing state every worker and router clone shares: per-shard
/// command senders (index = shard id; senders are never removed, so
/// retired workers keep receiving forwards and rollups) and the
/// placement ring (membership decides where opens land). Immutable
/// once published — topology changes build a fresh value and swap it
/// into the [`TopologyCell`].
#[derive(Clone)]
struct Topology {
    senders: Vec<SyncSender<ShardCommand>>,
    ring: HashRing,
}

/// Epoch-swapped immutable topology (the deferred PR 5 follow-on):
/// data-path readers revalidate a per-thread cached `Arc<Topology>`
/// with one atomic load per verb — no lock, no reference-count traffic
/// — while writers clone-mutate-swap under the router's reshard lock.
/// Same arc-swap shape as [`SnapshotCell`].
struct TopologyCell {
    /// Bumped on every swap; readers revalidate against it (`Acquire`).
    /// Starts at 1 so a zeroed thread-local cache can never match.
    epoch: AtomicU64,
    /// Write-rarely slot holding the current immutable topology.
    current: RwLock<Arc<Topology>>,
}

impl TopologyCell {
    fn new(topo: Topology) -> TopologyCell {
        TopologyCell {
            epoch: AtomicU64::new(1),
            current: RwLock::new(Arc::new(topo)),
        }
    }

    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// The current topology (read lock + `Arc` clone). Data-path verbs
    /// go through [`topo_of`], which caches per thread.
    fn load(&self) -> Arc<Topology> {
        self.current.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Publish a rebuilt topology. The value is stored before the epoch
    /// bump, both under the write lock, so a reader that observes the
    /// new epoch always loads a value at least that new (worst case it
    /// reloads once more — never serves a stale one as current).
    fn swap(&self, topo: Topology) {
        let mut slot = self.current.write().unwrap_or_else(|e| e.into_inner());
        *slot = Arc::new(topo);
        self.epoch.fetch_add(1, Ordering::Release);
    }
}

type SharedTopology = Arc<TopologyCell>;

thread_local! {
    /// Per-thread topology cache: (which cell, the epoch when cached,
    /// the cached value). The cell-identity check keeps multiple pools
    /// in one process from aliasing each other's slot; holding the
    /// `Arc<TopologyCell>` pins the allocation, so `ptr_eq` cannot be
    /// fooled by reuse.
    static TOPO_TLS: RefCell<Option<(Arc<TopologyCell>, u64, Arc<Topology>)>> =
        const { RefCell::new(None) };
}

/// The current topology, served from the calling thread's cache while
/// the cell's epoch still matches — the steady-state read is one
/// `Acquire` load plus a local `Arc` clone.
fn topo_of(cell: &SharedTopology) -> Arc<Topology> {
    let epoch = cell.epoch();
    TOPO_TLS.with(|tls| {
        let mut tls = tls.borrow_mut();
        if let Some((c, e, t)) = tls.as_ref() {
            if *e == epoch && Arc::ptr_eq(c, cell) {
                return t.clone();
            }
        }
        let t = cell.load();
        *tls = Some((cell.clone(), epoch, t.clone()));
        t
    })
}

/// Clone shard `shard`'s sender; the (possibly blocking) send that
/// follows happens against the clone, never against shared state.
fn sender_of(topo: &SharedTopology, shard: usize) -> Option<SyncSender<ShardCommand>> {
    topo_of(topo).senders.get(shard).cloned()
}

/// Source-side migration: extract the entry, ship it to the target
/// worker, commit the forwarding tombstone. Runs inside the source
/// worker's command loop, so every command enqueued before the
/// `Migrate` has already been applied — the queue is the drain barrier.
fn migrate_entry(
    shard: usize,
    table: &mut SlotTable,
    topo: &SharedTopology,
    stats: &mut MigrationStats,
    slot: u32,
    gen: u32,
    to_shard: usize,
) -> Result<(u32, u32), String> {
    if to_shard == shard {
        // Already home — nothing to move, the handle stays as is.
        table.get(slot, gen)?;
        return Ok((slot, gen));
    }
    let Some(tx) = sender_of(topo, to_shard) else {
        return Err(format!("unknown target shard {to_shard}"));
    };
    let entry = table.extract(slot, gen)?;
    let (rtx, rrx) = sync_channel(1);
    let install = ShardCommand::Install { entry, from_migration: true, reply: rtx };
    if let Err(send_err) = tx.send(install) {
        // Target worker gone (pool shutting down): put the stream back.
        if let ShardCommand::Install { entry, .. } = send_err.0 {
            table.reinstate(slot, entry);
        }
        return Err("target shard down".to_string());
    }
    match rrx.recv() {
        Ok(Ok((new_slot, new_gen))) => {
            table.set_moved(
                slot,
                gen,
                StreamAddr { shard: to_shard, slot: new_slot, gen: new_gen },
            );
            stats.migrated_out += 1;
            Ok((new_slot, new_gen))
        }
        Ok(Err((entry, e))) => {
            table.reinstate(slot, entry);
            Err(e)
        }
        Err(_) => {
            // Target died mid-install (worker panic / pool teardown):
            // the entry rode the channel and is unrecoverable. Leave
            // the retired address answering "unknown or closed" and
            // recycle the slot — a future occupant gets a fresh
            // generation, so the lost stream's handles can never alias
            // it. (Its router-side name reservation stays held; a pool
            // in this state has lost a worker thread and is already
            // degraded.)
            table.free_slot(slot);
            Err(format!("target shard {to_shard} dropped during migration; stream lost"))
        }
    }
}

/// Push buffered forwards toward their targets without ever blocking:
/// stop at the first still-full target queue (order within the buffer
/// is preserved — later forwards queue behind the head), drop forwards
/// whose target receiver is gone (pool shutting down; the producer's
/// reply channel drops and it sees "shard dropped reply").
fn flush_forwards(topo: &SharedTopology, pending: &mut VecDeque<(usize, ShardCommand)>) {
    while let Some((shard, cmd)) = pending.pop_front() {
        let Some(tx) = sender_of(topo, shard) else {
            continue;
        };
        match tx.try_send(cmd) {
            Ok(()) => {}
            Err(TrySendError::Full(cmd)) => {
                pending.push_front((shard, cmd));
                return;
            }
            Err(TrySendError::Disconnected(_)) => {}
        }
    }
}

fn shard_worker(
    shard: usize,
    engine_cfg: EngineConfig,
    rx: Receiver<ShardCommand>,
    topo: SharedTopology,
    persist: Option<PersistConfig>,
) {
    let engine = build_engine(&engine_cfg);
    let mut table = SlotTable::default();
    let mut closed = ClosedTotals::default();
    let mut migration = MigrationStats::default();
    // Durability: one write-ahead log per worker, opened (with torn-
    // tail repair) before the first command. An unopenable log is a
    // degraded start, not a dead shard — the pool keeps serving from
    // memory, like a runtime append failure would leave it.
    let mut wal: Option<WalWriter> = persist.as_ref().and_then(|p| {
        if let Err(e) = std::fs::create_dir_all(&p.dir) {
            eprintln!("shard {shard}: snapshot dir unavailable ({e}); running without a log");
            return None;
        }
        match WalWriter::open(p.wal_path(shard), p.fsync) {
            Ok(w) => Some(w),
            Err(e) => {
                eprintln!("shard {shard}: WAL unavailable ({e}); running without a log");
                None
            }
        }
    });
    // The one reusable record the ingest arms refill in place — the
    // zero-allocation half of the steady-state append path (the frame
    // buffer inside `WalWriter` is the other half).
    let mut wal_scratch =
        WalRecord::Ingest { id: String::new(), seq: 0, dim: 0, points: Vec::new() };
    // Forwards waiting for room in their target's bounded queue. The
    // worker NEVER blocks sending to another worker: a full target is
    // retried between commands (`try_send` + this buffer), so a
    // cross-shard forwarding cycle (tombstones pointing both ways with
    // both queues full) cannot deadlock — every worker always returns
    // to draining its own queue.
    let mut pending: VecDeque<(usize, ShardCommand)> = VecDeque::new();
    loop {
        flush_forwards(&topo, &mut pending);
        let cmd = if pending.is_empty() {
            match rx.recv() {
                Ok(cmd) => cmd,
                Err(_) => break,
            }
        } else {
            // Keep retrying the buffered forwards while serving our own
            // queue; the 1 ms tick bounds the retry latency without
            // spinning.
            match rx.recv_timeout(Duration::from_millis(1)) {
                Ok(cmd) => cmd,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        };
        // Commands addressed at a migrated slot are re-addressed under
        // the stream's new generation and forwarded to its new shard —
        // this is what makes in-flight traffic (sent before the
        // router's redirect table caught up) survive a move. A
        // forwarded reply channel rides along, so the producer's
        // rendezvous completes transparently from the target. Always
        // appended behind any already-buffered forward, so forwarded
        // traffic stays in order.
        if let Some((slot, gen)) = cmd_addr(&cmd) {
            if let Some(to) = table.moved_to(slot, gen) {
                migration.forwarded += 1;
                pending.push_back((to.shard, readdress(cmd, to)));
                continue;
            }
        }
        match cmd {
            ShardCommand::Open { stream, dim, cfg, cell, reply } => {
                let res = table.open(stream.clone(), dim, cfg.clone(), cell);
                if let Ok(&(slot, gen)) = res.as_ref() {
                    if let Some(w) = wal.as_mut() {
                        // Opens are rare — allocating the record here
                        // is fine; only the per-point path must stay
                        // allocation-silent.
                        let mut cfg_bytes = Vec::new();
                        persist::encode_stream_config(&mut cfg_bytes, &cfg);
                        let rec = WalRecord::Open {
                            id: stream.to_string(),
                            dim: dim as u32,
                            cfg: cfg_bytes,
                        };
                        let errors_before = w.errors();
                        let appended = w.append(&rec);
                        if let Ok(entry) = table.get_mut(slot, gen) {
                            if let Some(n) = appended {
                                entry.metrics.wal_appends += 1;
                                entry.metrics.wal_bytes += n;
                            }
                            entry.metrics.wal_errors += w.errors() - errors_before;
                        }
                    }
                }
                let _ = reply.send(res);
            }
            ShardCommand::Ingest { slot, gen, x, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => {
                        let t0 = Instant::now();
                        entry.wal_log_ingest(&mut wal, &mut wal_scratch, &x, true);
                        let r = entry.ingest(&x, &engine);
                        entry.metrics.ingest_latency.record(t0.elapsed());
                        r
                    }
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::IngestAsync { slot, gen, x } => match table.get_mut(slot, gen) {
                Ok(entry) => {
                    let t0 = Instant::now();
                    entry.wal_log_ingest(&mut wal, &mut wal_scratch, &x, true);
                    if let Err(e) = entry.ingest(&x, &engine) {
                        entry.metrics.async_errors += 1;
                        if entry.pending_error.is_none() {
                            entry.pending_error = Some(e);
                        }
                    }
                    entry.metrics.ingest_latency.record(t0.elapsed());
                }
                Err(_) => closed.orphans += 1,
            },
            ShardCommand::IngestMany { slot, gen, xs, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => {
                        let t0 = Instant::now();
                        // One record per batch command: replay applies
                        // it through the same batched entry point, so
                        // even a partially applied batch (Err after a
                        // prefix) reproduces the identical prefix.
                        entry.wal_log_ingest(&mut wal, &mut wal_scratch, &xs, false);
                        let r = entry.ingest_many(&xs, &engine);
                        // One latency sample per batch command — the
                        // amortization the batch exists for.
                        entry.metrics.ingest_latency.record(t0.elapsed());
                        r
                    }
                    Err(e) => Err(e),
                };
                // The chunk buffer rides the reply back so
                // `ingest_all` refills one allocation for the whole
                // feed instead of `to_vec()`-ing every chunk.
                let _ = reply.send((res, xs));
            }
            ShardCommand::Sync { slot, gen, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => {
                        // `sync` is the read-your-writes publish point:
                        // once this reply lands, snapshot readers see
                        // every previously applied ingest.
                        entry.publish_snapshot();
                        match entry.pending_error.take() {
                            Some(e) => Err(e),
                            None => Ok(entry.metrics.async_errors),
                        }
                    }
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Project { slot, gen, x, r, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => {
                        let t0 = Instant::now();
                        let out = entry.project(&x, r);
                        entry.metrics.project_latency.record(t0.elapsed());
                        entry.metrics.worker_reads += 1;
                        out
                    }
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::MeasureDrift { slot, gen, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => entry.measure_drift(),
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Snapshot { slot, gen, reply } => {
                let res = table.get(slot, gen).map(|entry| entry.snapshot(engine.counts()));
                let _ = reply.send(res);
            }
            ShardCommand::Metrics { slot, gen, reply } => {
                let res = table.get(slot, gen).map(|entry| entry.report());
                let _ = reply.send(res);
            }
            ShardCommand::Close { slot, gen, reply } => {
                let res = table.close(slot, gen).map(|entry| {
                    // A closed stream must stay closed across a crash:
                    // log the close and drop the checkpoint (both
                    // best-effort — worst case recovery resurrects a
                    // stream the caller meant to retire, never the
                    // reverse kind of loss).
                    if let Some(w) = wal.as_mut() {
                        let _ = w.append(&WalRecord::Close { id: entry.id.to_string() });
                    }
                    if let Some(p) = persist.as_ref() {
                        persist::remove_checkpoint(&p.dir, &entry.id);
                    }
                    // Keep the stream's lifetime counters/latency in
                    // the shard totals — pool counters stay monotonic.
                    closed.absorb(&entry.metrics);
                    closed.snapshot_reads += entry.cell.reads();
                    // Flip in-flight snapshot readers to a clean
                    // "unknown or closed stream" error and free the
                    // retained basis/landmark copy.
                    entry.cell.mark_closed();
                    entry.final_stats()
                });
                let _ = reply.send(res);
            }
            ShardCommand::Migrate { slot, gen, to_shard, reply } => {
                let res =
                    migrate_entry(shard, &mut table, &topo, &mut migration, slot, gen, to_shard);
                let _ = reply.send(res);
            }
            ShardCommand::Install { entry, from_migration, reply } => {
                let res = table.install(entry);
                if res.is_ok() && from_migration {
                    migration.migrated_in += 1;
                }
                let _ = reply.send(res);
            }
            ShardCommand::Checkpoint { slot, gen, reply } => {
                let res = match (table.get_mut(slot, gen), persist.as_ref()) {
                    (Ok(entry), Some(p)) => entry.checkpoint_to(&p.dir),
                    (Ok(_), None) => {
                        Err("durability not configured (no snapshot dir)".to_string())
                    }
                    (Err(e), _) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::CheckpointAll { reply } => {
                let res = match persist.as_ref() {
                    None => Err("durability not configured (no snapshot dir)".to_string()),
                    Some(p) => {
                        let mut count = 0usize;
                        let mut first_err: Option<String> = None;
                        for entry in table.live_mut() {
                            match entry.checkpoint_to(&p.dir) {
                                Ok(_) => count += 1,
                                Err(e) => {
                                    first_err.get_or_insert(e);
                                }
                            }
                        }
                        match first_err {
                            None => {
                                // Every live stream is captured — the
                                // logged suffix is redundant. Rotation
                                // also re-arms a degraded writer.
                                if let Some(w) = wal.as_mut() {
                                    if let Err(e) = w.rotate() {
                                        eprintln!("shard {shard}: WAL rotation failed ({e})");
                                    }
                                }
                                Ok(count)
                            }
                            Some(e) => {
                                Err(format!("checkpointed {count} stream(s), then: {e}"))
                            }
                        }
                    }
                };
                let _ = reply.send(res);
            }
            ShardCommand::ListStreams { reply } => {
                let _ = reply.send(table.list());
            }
            ShardCommand::Rollup { reply } => {
                let mut rollup = ShardRollup {
                    streams: table.live_count(),
                    accepted: closed.accepted,
                    excluded: closed.excluded,
                    errors: closed.errors + closed.orphans,
                    evictions: closed.evictions,
                    total_ws_bytes: 0,
                    ws_engine_gemms: closed.engine_gemms,
                    migrated_in: migration.migrated_in,
                    migrated_out: migration.migrated_out,
                    forwarded: migration.forwarded,
                    snapshot_reads: closed.snapshot_reads,
                    worker_reads: closed.worker_reads,
                    checkpoints: closed.checkpoints,
                    wal_appends: closed.wal_appends,
                    wal_bytes: closed.wal_bytes,
                    wal_errors: closed.wal_errors,
                    restored: 0,
                    ingest: closed.ingest.clone(),
                    project: closed.project.clone(),
                    engine_calls: engine.counts(),
                    gauges: Vec::with_capacity(table.live_count()),
                };
                for entry in table.live() {
                    rollup.accepted += entry.metrics.accepted;
                    rollup.excluded += entry.metrics.excluded;
                    rollup.errors += entry.metrics.errors;
                    rollup.evictions += entry.metrics.evictions;
                    rollup.total_ws_bytes += entry.metrics.ws_bytes_resident;
                    rollup.ws_engine_gemms += entry.metrics.engine_gemms;
                    rollup.snapshot_reads += entry.cell.reads();
                    rollup.worker_reads += entry.metrics.worker_reads;
                    rollup.checkpoints += entry.metrics.checkpoints;
                    rollup.wal_appends += entry.metrics.wal_appends;
                    rollup.wal_bytes += entry.metrics.wal_bytes;
                    rollup.wal_errors += entry.metrics.wal_errors;
                    rollup.restored += entry.restored as usize;
                    rollup.ingest.merge(&entry.metrics.ingest_latency);
                    rollup.project.merge(&entry.metrics.project_latency);
                    rollup.gauges.push(entry.gauges(shard));
                }
                let _ = reply.send(rollup);
            }
            ShardCommand::Shutdown => break,
        }
    }
}

/// Cloneable, thread-safe routing front-end over the per-shard command
/// channels. [`StreamRouter::open_stream`] resolves a stream id to a
/// [`StreamHandle`] once; all data-path verbs then address by handle —
/// producers on different shards never touch the same queue, and the
/// ingest path carries no string. The router also owns the *elastic*
/// verbs: [`StreamRouter::add_shard`], [`StreamRouter::remove_shard`],
/// [`StreamRouter::rebalance`] and [`StreamRouter::migrate_stream`]
/// change the topology live, migrating open streams without
/// restarting them.
#[derive(Clone)]
pub struct StreamRouter {
    topo: SharedTopology,
    /// old (shard, slot, gen) → current, updated after every
    /// migration. Data-path verbs resolve through here first, so a
    /// stale handle goes straight to the stream's new home instead of
    /// taking the tombstone-forwarding detour. Path-compressed on
    /// insert: chains stay one hop long no matter how often a stream
    /// moves.
    redirects: Arc<RwLock<HashMap<StreamAddr, StreamAddr>>>,
    /// Lock-free fast path for [`StreamRouter::resolve`]: set while
    /// the redirect table is non-empty. Every data-path verb skips the
    /// redirect read lock while it is clear — a pool that never
    /// reshapes pays (almost) nothing for elasticity, and one whose
    /// redirected streams have all since closed gets the fast path
    /// back (see [`StreamRouter::close_stream`]'s redirect GC). Only
    /// ever flipped inside the redirect table's write critical
    /// section, so the flag can never contradict the map it guards.
    redirected: Arc<AtomicBool>,
    /// Pool-wide open-stream ids. Worker name maps are per shard and
    /// used to be a sufficient duplicate-open check (placement was
    /// immutable, so a duplicate always hashed to the shard already
    /// holding the name); a migrated stream sits AWAY from its ring
    /// shard, so uniqueness must be enforced here, at the router.
    names: Arc<RwLock<HashSet<Arc<str>>>>,
    /// Serializes topology changes and migrations. Concurrent
    /// migrations in opposite directions could block on each other's
    /// bounded queues; one at a time costs nothing (topology changes
    /// are rare) and makes that impossible.
    reshard: Arc<Mutex<()>>,
    /// Worker join handles (shared with the pool, which joins them on
    /// drop; `add_shard` pushes new ones here).
    joins: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// Queue depth for workers spawned by `add_shard`.
    queue: usize,
    /// Engine config for workers spawned by `add_shard`.
    engine: EngineConfig,
    /// Durability config, shared with every worker (each opens its own
    /// WAL). `None` = in-memory pool; the checkpoint/restore verbs
    /// error.
    persist: Option<PersistConfig>,
}

impl StreamRouter {
    /// Number of shard workers behind this router — including retired
    /// ones (a removed shard's worker stays parked to serve stale
    /// forwards; see [`StreamRouter::remove_shard`]). The placement-
    /// eligible count is [`StreamRouter::active_shards`].
    pub fn shards(&self) -> usize {
        topo_of(&self.topo).senders.len()
    }

    /// Number of ring members — shards eligible to own streams.
    pub fn active_shards(&self) -> usize {
        topo_of(&self.topo).ring.len()
    }

    /// Ring-member shard ids, ascending.
    pub fn active_shard_ids(&self) -> Vec<usize> {
        topo_of(&self.topo).ring.shards()
    }

    /// The shard a stream id is currently placed on (stable until the
    /// ring membership changes).
    pub fn shard_of(&self, stream: &str) -> usize {
        topo_of(&self.topo).ring.shard_of(stream)
    }

    /// A handle's current address: its resolved coordinates, chased
    /// through the redirect table if the stream has migrated since.
    fn resolve(&self, h: &StreamHandle) -> StreamAddr {
        let mut addr = StreamAddr { shard: h.shard, slot: h.slot, gen: h.gen };
        // Until the first migration there is nothing to resolve — skip
        // even the read lock. (A racing first migration is harmless:
        // the command lands on the old shard and the tombstone
        // forwards it.)
        if !self.redirected.load(Ordering::Acquire) {
            return addr;
        }
        let map = self.redirects.read().unwrap_or_else(|e| e.into_inner());
        // Path compression keeps chains one hop long; the bound is
        // belt-and-braces against a (non-existent) cycle.
        let mut hops = 0;
        while let Some(next) = map.get(&addr) {
            addr = *next;
            hops += 1;
            if hops > map.len() {
                break;
            }
        }
        addr
    }

    /// Record `old → new` after a migration, re-pointing any existing
    /// redirect that targeted `old` (so every chain stays one hop).
    /// The fast-path flag is raised inside the write critical section:
    /// a concurrent GC's re-arm can then never interleave between the
    /// insert and the store and leave the flag down with a non-empty
    /// table.
    fn redirect(&self, old: StreamAddr, new: StreamAddr) {
        let mut map = self.redirects.write().unwrap_or_else(|e| e.into_inner());
        for v in map.values_mut() {
            if *v == old {
                *v = new;
            }
        }
        map.insert(old, new);
        self.redirected.store(true, Ordering::Release);
    }

    /// Redirect GC: drop every entry that resolves to `dead` (a closed
    /// stream's final address — any command through those entries now
    /// errors identically with or without the hop, so they are pure
    /// dead weight). When the table drains, the fast-path flag is
    /// re-armed — [`StreamRouter::resolve`] skips the read lock again,
    /// as if no migration had ever happened. Tombstones are untouched:
    /// they are the correctness layer, this table only an optimization.
    fn gc_redirects_to(&self, dead: StreamAddr) {
        let mut map = self.redirects.write().unwrap_or_else(|e| e.into_inner());
        map.retain(|_, v| *v != dead);
        if map.is_empty() {
            self.redirected.store(false, Ordering::Release);
        }
    }

    /// Current redirect-table size (observability; drops back to zero
    /// as migrated streams close — see the GC in
    /// [`StreamRouter::close_stream`]).
    pub fn redirect_entries(&self) -> usize {
        self.redirects.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    /// One rendezvous round-trip to shard `shard`: build the command
    /// around a fresh reply channel, send, await the answer. Every
    /// replying router verb goes through here so the error discipline
    /// cannot diverge between commands. The sender is cloned out of
    /// the topology lock before the (possibly blocking) send.
    fn rpc<T>(
        &self,
        shard: usize,
        make: impl FnOnce(SyncSender<T>) -> ShardCommand,
    ) -> Result<T, String> {
        let tx = sender_of(&self.topo, shard).ok_or_else(|| "shard pool down".to_string())?;
        let (rtx, rrx) = sync_channel(1);
        tx.send(make(rtx)).map_err(|_| "shard pool down".to_string())?;
        rrx.recv().map_err(|_| "shard dropped reply".to_string())
    }

    /// Open a stream on its ring shard and resolve it to a cheap
    /// [`StreamHandle`]. Fails if the id is in use.
    ///
    /// Setting [`StreamConfig::expected_m`]/
    /// [`StreamConfig::expected_batch`] makes the worker pre-size every
    /// hot-path buffer when the stream's eigensystem is built, so the
    /// whole streamed life of the entry is allocation-silent.
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::coordinator::{KernelConfig, PoolConfig, ShardPool, StreamConfig};
    ///
    /// let pool = ShardPool::spawn(PoolConfig::default());
    /// let router = pool.router();
    /// let cfg = StreamConfig {
    ///     kernel: KernelConfig::Rbf { sigma: 1.0 },
    ///     mean_adjust: false,
    ///     seed_points: 2,
    ///     expected_m: 64,      // reserve for 64 points …
    ///     expected_batch: 16,  // … fed in batches of up to 16
    ///     ..StreamConfig::default()
    /// };
    /// let h = router.open_stream("sensor-7", 3, cfg)?;
    /// assert_eq!(h.id(), "sensor-7");
    /// assert_eq!(h.shard(), router.shard_of("sensor-7"));
    /// # pool.shutdown();
    /// # Ok::<(), String>(())
    /// ```
    pub fn open_stream(
        &self,
        stream: &str,
        dim: usize,
        cfg: StreamConfig,
    ) -> Result<StreamHandle, String> {
        let shard = self.shard_of(stream);
        let id: Arc<str> = Arc::from(stream);
        // Reserve the id pool-wide first: the worker's own name map
        // only covers streams currently ON that shard, and a migrated
        // homonym lives elsewhere.
        {
            let mut names = self.names.write().unwrap_or_else(|e| e.into_inner());
            if !names.insert(id.clone()) {
                return Err(format!("stream '{stream}' already open"));
            }
        }
        let cmd_id = id.clone();
        // The snapshot cell is born with the stream: one allocation
        // shared between the handle (reader side) and the worker's
        // entry (publisher side).
        let cell = Arc::new(SnapshotCell::new());
        let cmd_cell = cell.clone();
        let res = self.rpc(shard, move |reply| ShardCommand::Open {
            stream: cmd_id,
            dim,
            cfg,
            cell: cmd_cell,
            reply,
        });
        match res {
            Ok(Ok((slot, gen))) => Ok(StreamHandle { shard, slot, gen, id, cell }),
            Ok(Err(e)) | Err(e) => {
                // Failed open: release the reservation.
                self.names.write().unwrap_or_else(|p| p.into_inner()).remove(&id);
                Err(e)
            }
        }
    }

    /// Ingest one example (blocks under backpressure of the stream's
    /// shard only; one rendezvous round-trip per point).
    pub fn ingest(&self, h: &StreamHandle, x: Vec<f64>) -> Result<IngestReply, String> {
        let a = self.resolve(h);
        self.rpc(a.shard, |reply| ShardCommand::Ingest { slot: a.slot, gen: a.gen, x, reply })?
    }

    /// Fire-and-forget ingest: enqueue and return. Still blocks when
    /// the shard's bounded queue is full (backpressure is preserved);
    /// per-point failures are deferred — they bump the stream's
    /// `async_errors` counter and the first message is returned by the
    /// next [`StreamRouter::sync`]. `Err` here only means the pool is
    /// down.
    pub fn ingest_async(&self, h: &StreamHandle, x: Vec<f64>) -> Result<(), String> {
        let a = self.resolve(h);
        let tx = sender_of(&self.topo, a.shard).ok_or_else(|| "shard pool down".to_string())?;
        tx.send(ShardCommand::IngestAsync { slot: a.slot, gen: a.gen, x })
            .map_err(|_| "shard pool down".to_string())
    }

    /// Ingest a whole batch (`xs` is `b × dim` row-major) as one
    /// command and one reply: the channel round-trip amortizes over the
    /// batch, the worker computes the batch's kernel rows as one
    /// blocked GEMM, and the batch's rank-one back-rotations fold into
    /// one fused engine GEMM (the blocked rank-b update — override per
    /// stream via [`StreamConfig::batch_rotation`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::coordinator::{KernelConfig, PoolConfig, ShardPool, StreamConfig};
    ///
    /// let pool = ShardPool::spawn(PoolConfig::default());
    /// let router = pool.router();
    /// let cfg = StreamConfig {
    ///     kernel: KernelConfig::Rbf { sigma: 1.0 },
    ///     mean_adjust: false,
    ///     seed_points: 2,
    ///     ..StreamConfig::default()
    /// };
    /// let h = router.open_stream("s", 2, cfg)?;
    /// // Six 2-d points in one command: two consumed by seeding, four
    /// // through the blocked batch path.
    /// let pts: Vec<f64> = (0..12).map(|i| (i as f64 * 0.31).cos()).collect();
    /// let reply = router.ingest_many(&h, pts)?;
    /// assert_eq!(reply.seeded, 2);
    /// assert_eq!(reply.accepted + reply.excluded, 4);
    /// assert_eq!(reply.m, 6 - reply.excluded);
    /// # pool.shutdown();
    /// # Ok::<(), String>(())
    /// ```
    pub fn ingest_many(&self, h: &StreamHandle, xs: Vec<f64>) -> Result<BatchReply, String> {
        self.ingest_many_rpc(h, xs).0
    }

    /// The batched-ingest rendezvous with the chunk buffer handed back:
    /// the worker moves the buffer into the reply, so a chunking caller
    /// ([`StreamRouter::ingest_all`]) refills one allocation for the
    /// whole feed. On a transport error the buffer is gone (it rode the
    /// channel) and an empty `Vec` comes back.
    fn ingest_many_rpc(
        &self,
        h: &StreamHandle,
        xs: Vec<f64>,
    ) -> (Result<BatchReply, String>, Vec<f64>) {
        let a = self.resolve(h);
        match self.rpc(a.shard, |reply| ShardCommand::IngestMany {
            slot: a.slot,
            gen: a.gen,
            xs,
            reply,
        }) {
            Ok((res, buf)) => (res, buf),
            Err(e) => (Err(e), Vec::new()),
        }
    }

    /// Drive a whole flat `n × dim` row-major feed through
    /// [`StreamRouter::ingest_many`] in `batch`-sized commands
    /// (`batch ≤ 1` means one-point batches) and return the aggregated
    /// counts — the one chunking loop the CLI, benches and tests all
    /// share, so the accounting cannot diverge between them.
    ///
    /// A malformed feed (`flat.len()` not a multiple of `dim`, or a
    /// zero `dim`) is an `Err`, matching the worker-side batch check —
    /// a serving front-end must not panic on a bad feed.
    pub fn ingest_all(
        &self,
        h: &StreamHandle,
        flat: &[f64],
        dim: usize,
        batch: usize,
    ) -> Result<BatchReply, String> {
        if dim == 0 || flat.len() % dim != 0 {
            return Err(format!(
                "feed length {} is not a multiple of dim {dim}",
                flat.len()
            ));
        }
        let n = flat.len() / dim;
        let batch = batch.max(1);
        if n <= batch {
            // The whole feed fits one command: a single copy (the
            // worker needs owned data), no chunking loop at all.
            return self.ingest_many(h, flat.to_vec());
        }
        let mut total = BatchReply::default();
        // One reusable chunk buffer round-trips through the worker —
        // refilled per chunk instead of `to_vec()`-allocated per chunk.
        let mut buf: Vec<f64> = Vec::with_capacity(batch * dim);
        let mut i = 0;
        while i < n {
            let end = (i + batch).min(n);
            buf.clear();
            buf.extend_from_slice(&flat[i * dim..end * dim]);
            let (res, back) = self.ingest_many_rpc(h, std::mem::take(&mut buf));
            buf = back;
            let r = res?;
            total.accepted += r.accepted;
            total.excluded += r.excluded;
            total.seeded += r.seeded;
            total.m = r.m;
            i = end;
        }
        Ok(total)
    }

    /// Barrier for fire-and-forget ingest: when this returns, every
    /// previously enqueued `ingest_async` for the stream has been
    /// applied (commands serialize through the shard). Returns the
    /// stream's cumulative async-error count, or `Err` with the first
    /// deferred error message since the last sync (clearing it).
    pub fn sync(&self, h: &StreamHandle) -> Result<u64, String> {
        let a = self.resolve(h);
        self.rpc(a.shard, |reply| ShardCommand::Sync { slot: a.slot, gen: a.gen, reply })?
    }

    /// Project a point onto a stream's current top-`r` components
    /// through the worker — one rendezvous round-trip, serialized
    /// behind the stream's ingests. This is the fully-fresh fallback;
    /// the serving path is [`StreamRouter::project_snapshot`] /
    /// [`StreamRouter::project_many`].
    pub fn project(&self, h: &StreamHandle, x: Vec<f64>, r: usize) -> Result<Vec<f64>, String> {
        let a = self.resolve(h);
        self.rpc(a.shard, |reply| ShardCommand::Project {
            slot: a.slot,
            gen: a.gen,
            x,
            r,
            reply,
        })?
    }

    /// Project one point through the stream's published snapshot —
    /// never enqueues a shard command, so readers scale with cores
    /// instead of queueing behind ingests. Borrowed input: no per-call
    /// `Vec` handoff (the `Vec`-moving RPC stays on the worker path
    /// only). Errors until the stream finishes seeding and publishes
    /// its first snapshot, and after close.
    ///
    /// Freshness: the snapshot may lag the worker by up to
    /// [`StreamConfig::publish_every`] accepted points;
    /// [`StreamRouter::sync`] publishes, so `sync` + snapshot read is
    /// read-your-writes.
    pub fn project_snapshot(
        &self,
        h: &StreamHandle,
        y: &[f64],
        r: usize,
    ) -> Result<Vec<f64>, String> {
        h.cell.load()?.project(y, r)
    }

    /// Batched snapshot projection: `ys` is `b × dim` row-major, the
    /// result is `b × r_eff` scores row-major. Allocating convenience
    /// wrapper over [`StreamRouter::project_many_into`].
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::coordinator::{KernelConfig, PoolConfig, ShardPool, StreamConfig};
    ///
    /// let pool = ShardPool::spawn(PoolConfig::default());
    /// let router = pool.router();
    /// let cfg = StreamConfig {
    ///     kernel: KernelConfig::Rbf { sigma: 1.0 },
    ///     mean_adjust: false,
    ///     seed_points: 2,
    ///     ..StreamConfig::default()
    /// };
    /// let h = router.open_stream("s", 2, cfg)?;
    /// let pts: Vec<f64> = (0..12).map(|i| (i as f64 * 0.31).cos()).collect();
    /// router.ingest_many(&h, pts)?;
    /// router.sync(&h)?; // publish: read-your-writes from here on
    /// let queries = [0.1, 0.2, 0.3, 0.4]; // two 2-d points
    /// let scores = router.project_many(&h, &queries, 2)?;
    /// assert_eq!(scores.len() % 2, 0);
    /// # pool.shutdown();
    /// # Ok::<(), String>(())
    /// ```
    pub fn project_many(
        &self,
        h: &StreamHandle,
        ys: &[f64],
        r: usize,
    ) -> Result<Vec<f64>, String> {
        let mut scratch = ProjectScratch::new();
        let mut out = Vec::new();
        self.project_many_into(h, ys, r, &mut scratch, &mut out)?;
        Ok(out)
    }

    /// Batched snapshot projection into caller-owned scratch + output —
    /// the zero-alloc steady-state read path: the b×m kernel block goes
    /// through `kernels::kernel_rows_into` and one GEMM against the
    /// snapshot basis, every buffer reused across calls. Returns the
    /// number of components per row actually produced
    /// (`min(r, published components)`); `out` holds `b × r_eff`
    /// scores row-major.
    pub fn project_many_into(
        &self,
        h: &StreamHandle,
        ys: &[f64],
        r: usize,
        scratch: &mut ProjectScratch,
        out: &mut Vec<f64>,
    ) -> Result<usize, String> {
        let snap = h.cell.load_cached(scratch)?;
        snap.project_many_into(ys, r, scratch, out)
    }

    /// The stream's current published-snapshot epoch (0 until the first
    /// publish). Monotonically non-decreasing for the stream's life,
    /// including across migrations — the cell travels with the entry.
    pub fn snapshot_epoch(&self, h: &StreamHandle) -> u64 {
        h.cell.epoch()
    }

    /// Force an immediate drift measurement on a stream.
    pub fn measure_drift(&self, h: &StreamHandle) -> Result<DriftPoint, String> {
        let a = self.resolve(h);
        self.rpc(a.shard, |reply| ShardCommand::MeasureDrift {
            slot: a.slot,
            gen: a.gen,
            reply,
        })?
    }

    /// Point-in-time view of one stream.
    pub fn snapshot(&self, h: &StreamHandle) -> Result<Snapshot, String> {
        let a = self.resolve(h);
        self.rpc(a.shard, |reply| ShardCommand::Snapshot { slot: a.slot, gen: a.gen, reply })?
    }

    /// Per-stream metrics report.
    pub fn metrics(&self, h: &StreamHandle) -> Result<MetricsReport, String> {
        let a = self.resolve(h);
        self.rpc(a.shard, |reply| ShardCommand::Metrics { slot: a.slot, gen: a.gen, reply })?
    }

    /// Close a stream, freeing its state (and its kernel), returning
    /// the stream's final stats. The stream's counters stay in the
    /// shard's lifetime totals, so pool counters remain monotonic; the
    /// slot is recycled under a new generation, so this (and any clone
    /// of this) handle goes stale rather than aliasing a successor.
    pub fn close_stream(&self, h: &StreamHandle) -> Result<KpcaStats, String> {
        let a = self.resolve(h);
        let stats =
            self.rpc(a.shard, |reply| ShardCommand::Close { slot: a.slot, gen: a.gen, reply })??;
        // The id is free to reuse only once the worker has actually
        // dropped the entry (a failed close — stale handle — must not
        // release someone else's reservation).
        self.names.write().unwrap_or_else(|e| e.into_inner()).remove(&h.id);
        // Redirect entries pointing at the closed address are dead
        // weight now — collect them (and re-arm the lock-free resolve
        // fast path if the table drains).
        self.gc_redirects_to(a);
        Ok(stats)
    }

    /// Grow the pool by one shard and rebalance: a retired worker is
    /// revived if one exists, otherwise a fresh worker thread (with its
    /// own queue and engine) is spawned; the new member joins the ring
    /// and exactly the streams whose ring arc it took over are
    /// migrated onto it (≈ `1/(k+1)` of them — the consistent-hashing
    /// guarantee, pinned by the ring's property tests). Returns the new
    /// shard's id. Open handles keep working throughout.
    pub fn add_shard(&self) -> Result<usize, String> {
        let _g = self.reshard.lock().unwrap_or_else(|e| e.into_inner());
        // Writers rebuild and swap: clone the current topology, mutate
        // the private copy, publish it atomically. Readers in flight
        // keep their (still valid) old `Arc` — senders are never
        // removed, so an old topology routes correctly forever.
        let (shard, rx) = {
            let mut topo = (*self.topo.load()).clone();
            // Prefer reviving a retired worker (shrunk earlier): its
            // thread is parked on an empty queue and rejoins for free.
            let retired = (0..topo.senders.len()).find(|s| !topo.ring.contains(*s));
            let picked = match retired {
                Some(s) => {
                    topo.ring.add_shard(s);
                    (s, None)
                }
                None => {
                    let (tx, rx) = sync_channel(self.queue.max(1));
                    let s = topo.senders.len();
                    topo.senders.push(tx);
                    topo.ring.add_shard(s);
                    (s, Some(rx))
                }
            };
            self.topo.swap(topo);
            picked
        };
        if let Some(rx) = rx {
            let engine_cfg = self.engine.clone();
            let topo = self.topo.clone();
            let persist = self.persist.clone();
            self.joins.lock().unwrap_or_else(|e| e.into_inner()).push(std::thread::spawn(
                move || shard_worker(shard, engine_cfg, rx, topo, persist),
            ));
        }
        self.rebalance_locked()?;
        Ok(shard)
    }

    /// Shrink the pool: take `shard` out of the ring and migrate every
    /// stream it owns to the remaining members (only *its* streams
    /// move). The worker thread stays parked on its (now idle) queue so
    /// pre-migration handles remain forwardable and its lifetime
    /// counters stay in the pool rollup; a later
    /// [`StreamRouter::add_shard`] revives it instead of spawning.
    /// Returns the number of streams migrated off.
    ///
    /// The ring change commits before the migration sweep: on `Err`
    /// the shard is already retired from placement and some streams
    /// may still sit on it — re-run [`StreamRouter::rebalance`] to
    /// converge (or [`StreamRouter::add_shard`] to re-admit the
    /// shard).
    pub fn remove_shard(&self, shard: usize) -> Result<usize, String> {
        let _g = self.reshard.lock().unwrap_or_else(|e| e.into_inner());
        {
            let mut topo = (*self.topo.load()).clone();
            if !topo.ring.contains(shard) {
                return Err(format!("shard {shard} is not in the ring"));
            }
            if topo.ring.len() <= 1 {
                return Err("cannot remove the last shard".to_string());
            }
            topo.ring.remove_shard(shard);
            self.topo.swap(topo);
        }
        self.rebalance_locked()
    }

    /// Migrate every stream that is not on its ring shard to where the
    /// ring places it (normally a no-op — `add_shard`/`remove_shard`
    /// rebalance themselves; useful after manual
    /// [`StreamRouter::migrate_stream`] placements). Returns the number
    /// of streams moved.
    pub fn rebalance(&self) -> Result<usize, String> {
        let _g = self.reshard.lock().unwrap_or_else(|e| e.into_inner());
        self.rebalance_locked()
    }

    /// Manually migrate one stream to `to_shard` (which may be any
    /// worker, ring member or not — note a later rebalance moves the
    /// stream back to its ring shard). The stream's queue drains to the
    /// migration barrier, its entry ships to the target under a bumped
    /// generation, and this (and every clone of this) handle keeps
    /// working through the router's redirect table.
    pub fn migrate_stream(&self, h: &StreamHandle, to_shard: usize) -> Result<(), String> {
        let _g = self.reshard.lock().unwrap_or_else(|e| e.into_inner());
        if to_shard >= self.shards() {
            return Err(format!("unknown target shard {to_shard}"));
        }
        let from = self.resolve(h);
        if from.shard == to_shard {
            return Ok(());
        }
        let (slot, gen) = self.rpc(from.shard, |reply| ShardCommand::Migrate {
            slot: from.slot,
            gen: from.gen,
            to_shard,
            reply,
        })??;
        self.redirect(from, StreamAddr { shard: to_shard, slot, gen });
        Ok(())
    }

    /// The migration sweep (caller holds the reshard lock): ask every
    /// worker for its live streams, move the ones whose ring placement
    /// differs from where they sit.
    /// Best-effort: a failing stream does not abort the sweep (the
    /// rest still migrate), and because the sweep is convergent —
    /// every pass moves only streams still off their ring shard —
    /// re-running `rebalance()` after an `Err` finishes the job.
    fn rebalance_locked(&self) -> Result<usize, String> {
        let workers = self.shards();
        let mut moved = 0usize;
        let mut first_err: Option<String> = None;
        for shard in 0..workers {
            let list = match self.rpc(shard, |reply| ShardCommand::ListStreams { reply }) {
                Ok(list) => list,
                Err(e) => {
                    first_err.get_or_insert(e);
                    continue;
                }
            };
            for (id, slot, gen) in list {
                let target = self.shard_of(&id);
                if target == shard {
                    continue;
                }
                let res = self.rpc(shard, |reply| ShardCommand::Migrate {
                    slot,
                    gen,
                    to_shard: target,
                    reply,
                });
                match res {
                    Ok(Ok((new_slot, new_gen))) => {
                        self.redirect(
                            StreamAddr { shard, slot, gen },
                            StreamAddr { shard: target, slot: new_slot, gen: new_gen },
                        );
                        moved += 1;
                    }
                    Ok(Err(e)) | Err(e) => {
                        first_err.get_or_insert(e);
                    }
                }
            }
        }
        match first_err {
            None => Ok(moved),
            Some(e) => Err(format!(
                "rebalance moved {moved} stream(s), then: {e} — re-run rebalance() to converge"
            )),
        }
    }

    /// Pool-level rollup: per-shard counters summed (including streams
    /// closed since spawn — counters are monotonic under churn, and a
    /// migrated stream's counters travel with it, so they are monotonic
    /// across moves too), latency histograms merged, engine dispatches
    /// aggregated, per-stream gauges attached for the currently open
    /// streams, per-shard occupancy (including retired workers, marked
    /// inactive) listed for attribution.
    pub fn pool_snapshot(&self) -> Result<PoolSnapshot, String> {
        let (workers, active_ids) = {
            let topo = topo_of(&self.topo);
            (topo.senders.len(), topo.ring.shards())
        };
        let mut snap = PoolSnapshot {
            shards: workers,
            active_shards: active_ids.len(),
            ..Default::default()
        };
        let mut ingest = LatencyHistogram::default();
        let mut project = LatencyHistogram::default();
        for shard in 0..workers {
            let rollup = self.rpc(shard, |reply| ShardCommand::Rollup { reply })?;
            snap.streams += rollup.streams;
            snap.accepted += rollup.accepted;
            snap.excluded += rollup.excluded;
            snap.errors += rollup.errors;
            snap.evictions += rollup.evictions;
            snap.total_ws_bytes += rollup.total_ws_bytes;
            snap.ws_engine_gemms += rollup.ws_engine_gemms;
            snap.migrations += rollup.migrated_in;
            snap.forwards += rollup.forwarded;
            snap.engine_calls.0 += rollup.engine_calls.0;
            snap.engine_calls.1 += rollup.engine_calls.1;
            snap.snapshot_reads += rollup.snapshot_reads;
            snap.worker_reads += rollup.worker_reads;
            snap.checkpoints += rollup.checkpoints;
            snap.wal_appends += rollup.wal_appends;
            snap.wal_bytes += rollup.wal_bytes;
            snap.wal_errors += rollup.wal_errors;
            snap.recovered_streams += rollup.restored;
            ingest.merge(&rollup.ingest);
            project.merge(&rollup.project);
            snap.per_shard.push(ShardOccupancy {
                shard,
                active: active_ids.contains(&shard),
                streams: rollup.streams,
                ws_bytes_resident: rollup.total_ws_bytes,
                migrated_in: rollup.migrated_in,
                migrated_out: rollup.migrated_out,
            });
            snap.per_stream.extend(rollup.gauges);
        }
        // Shadow-tier divergence rolls up as a pool-wide max: one bad
        // sketch anywhere is what the gauge exists to surface.
        snap.max_divergence = snap
            .per_stream
            .iter()
            .filter_map(|g| g.divergence)
            .fold(None, |acc: Option<f64>, d| Some(acc.map_or(d, |a| a.max(d))));
        snap.ingest_p50_us = ingest.percentile_ns(0.50) / 1e3;
        snap.ingest_p99_us = ingest.percentile_ns(0.99) / 1e3;
        snap.ingest_mean_us = ingest.mean_ns() / 1e3;
        snap.ingest_count = ingest.count();
        snap.project_mean_us = project.mean_ns() / 1e3;
        snap.per_stream.sort_by(|a, b| a.stream.cmp(&b.stream));
        Ok(snap)
    }

    /// Checkpoint one stream to the pool's snapshot directory. The
    /// command is slot-addressed, so the stream's shard queue drains
    /// ahead of it — the captured state reflects every command sent
    /// before this call (the same consistent-cut barrier migration
    /// uses). Returns the checkpoint's encoded byte length. Errors if
    /// the pool was spawned without [`PoolConfig::persist`].
    pub fn checkpoint_stream(&self, h: &StreamHandle) -> Result<u64, String> {
        let a = self.resolve(h);
        self.rpc(a.shard, |reply| ShardCommand::Checkpoint { slot: a.slot, gen: a.gen, reply })?
    }

    /// Checkpoint every live stream on every worker (including retired
    /// ones — migrated-off shards may still hold strays), rotating each
    /// shard's WAL once all of its streams are captured. Returns the
    /// number of streams checkpointed.
    ///
    /// Each *stream's* cut is consistent (its worker's queue drains to
    /// the command); the pool-wide cut is per-stream, not a global
    /// barrier — which is exactly what recovery needs, since restore is
    /// per-stream too: checkpoint plus seq-filtered log replay.
    pub fn checkpoint_all(&self) -> Result<usize, String> {
        let mut total = 0usize;
        for shard in 0..self.shards() {
            total += self.rpc(shard, |reply| ShardCommand::CheckpointAll { reply })??;
        }
        Ok(total)
    }

    /// Rebuild the pool's streams from the snapshot directory: load
    /// every readable checkpoint (corrupt ones are quarantined —
    /// renamed `.corrupt` — not fatal), read every shard WAL
    /// (torn tails tolerated: the log is truncated at the first bad
    /// frame), then per stream install the checkpointed entry on its
    /// ring shard and replay the WAL suffix (`seq ≥` the checkpoint's
    /// cursor, deduplicated) through the normal ingest path. Streams
    /// with an `Open` record but no checkpoint yet (crashed mid-seed)
    /// are re-opened and replayed from scratch; streams whose log
    /// records a close are skipped — close-then-reopen between
    /// checkpoints resolves conservatively in favor of the close.
    ///
    /// Finishes with a [`StreamRouter::checkpoint_all`] (best-effort,
    /// reported as `compacted`) so a second crash recovers from fresh
    /// checkpoints instead of re-replaying.
    ///
    /// Call on an idle pool right after spawn; errors if durability is
    /// not configured. Per-stream rebuild failures land in
    /// [`RestoreReport::failed`] without aborting the pool.
    pub fn restore_pool(&self) -> Result<RestoreReport, String> {
        let Some(pcfg) = self.persist.clone() else {
            return Err("durability not configured (no snapshot dir)".to_string());
        };
        // Serialize against topology changes: placement must not move
        // under the install/replay sweep.
        let _g = self.reshard.lock().unwrap_or_else(|e| e.into_inner());
        let loaded = persist::load_checkpoints(&pcfg.dir).map_err(|e| e.to_string())?;
        let wals = persist::load_wals(&pcfg.dir).map_err(|e| e.to_string())?;
        let mut report = RestoreReport {
            quarantined: loaded.quarantined,
            torn_logs: wals.torn_logs,
            ..Default::default()
        };
        let mut ckpts: HashMap<String, CheckpointData> = HashMap::new();
        for data in loaded.checkpoints {
            ckpts.insert(data.id.clone(), data);
        }
        // Group the logs per stream. Only the FIRST Open counts (a
        // re-logged Open from an earlier recovery is a duplicate);
        // any Close wins (see the conservative close-reopen rule).
        let mut opens: HashMap<String, (u32, Vec<u8>)> = HashMap::new();
        let mut ingests: HashMap<String, Vec<(u64, Vec<f64>)>> = HashMap::new();
        let mut closed_ids: HashSet<String> = HashSet::new();
        for rec in wals.records {
            match rec {
                WalRecord::Open { id, dim, cfg } => {
                    opens.entry(id).or_insert((dim, cfg));
                }
                WalRecord::Ingest { id, seq, points, .. } => {
                    ingests.entry(id).or_default().push((seq, points));
                }
                WalRecord::Close { id } => {
                    closed_ids.insert(id);
                }
            }
        }
        let mut ids: Vec<String> = ckpts.keys().chain(opens.keys()).cloned().collect();
        ids.sort();
        ids.dedup();
        for id in ids {
            if closed_ids.contains(&id) {
                report.skipped_closed += 1;
                continue;
            }
            // Rebuild the entry: from its checkpoint when one exists,
            // else a fresh stream from the logged open (mid-seed crash).
            let (handle, replay_from) = if let Some(data) = ckpts.remove(&id) {
                let next_seq = data.ingest_seq;
                match self.install_restored(data) {
                    Ok(h) => {
                        report.restored += 1;
                        (h, next_seq)
                    }
                    Err(e) => {
                        report.failed.push(format!("{id}: {e}"));
                        continue;
                    }
                }
            } else {
                let (dim, cfg_bytes) = opens.remove(&id).expect("id came from a map key");
                let cfg = match persist::decode_stream_config_bytes(&cfg_bytes) {
                    Ok(cfg) => cfg,
                    Err(e) => {
                        report.failed.push(format!("{id}: open record: {e}"));
                        continue;
                    }
                };
                // The normal open path: fresh entry, fresh Open record
                // in the new log (harmless duplicate — first one wins).
                match self.open_stream(&id, dim as usize, cfg) {
                    Ok(h) => {
                        report.from_wal_only += 1;
                        (h, 0)
                    }
                    Err(e) => {
                        report.failed.push(format!("{id}: {e}"));
                        continue;
                    }
                }
            };
            // Replay the suffix in sequence order through the normal
            // ingest path, dropping duplicate sequence numbers (a crash
            // during a previous recovery's replay re-logs records).
            if let Some(mut recs) = ingests.remove(&id) {
                recs.sort_by_key(|r| r.0);
                recs.dedup_by_key(|r| r.0);
                let dim = match self.snapshot(&handle) {
                    Ok(s) => s.dim,
                    Err(_) => 0,
                };
                for (seq, points) in recs {
                    if seq < replay_from {
                        continue;
                    }
                    // One-point records go through the one-point path,
                    // batch records through the batch path — replay
                    // retraces the original command shapes.
                    let res = if dim > 0 && points.len() == dim {
                        self.ingest(&handle, points).map(|_| ())
                    } else {
                        self.ingest_many(&handle, points).map(|_| ())
                    };
                    match res {
                        Ok(()) => report.replayed += 1,
                        Err(_) => report.replay_errors += 1,
                    }
                }
            }
            report.handles.push(handle);
        }
        // Compact: capture the restored state and rotate every WAL, so
        // a second crash recovers from the fresh checkpoints instead of
        // re-replaying (and so replay-time re-logging is retired).
        // (`checkpoint_all` takes no lock, so holding the reshard guard
        // here is fine.)
        report.compacted = self.checkpoint_all().is_ok();
        report.handles.sort_by(|a, b| a.id.cmp(&b.id));
        Ok(report)
    }

    /// Install one checkpointed entry on its ring shard: reserve the
    /// pool-wide name, rebuild the entry, ship it via `Install` (not
    /// counted as a migration), and resolve the handle.
    fn install_restored(&self, data: CheckpointData) -> Result<StreamHandle, String> {
        let id: Arc<str> = Arc::from(data.id.as_str());
        {
            let mut names = self.names.write().unwrap_or_else(|e| e.into_inner());
            if !names.insert(id.clone()) {
                return Err(format!("stream '{id}' already open"));
            }
        }
        let shard = self.shard_of(&id);
        let cell = Arc::new(SnapshotCell::new());
        let installed = StreamEntry::from_checkpoint(data, cell.clone()).and_then(|entry| {
            self.rpc(shard, |reply| ShardCommand::Install {
                entry,
                from_migration: false,
                reply,
            })?
            .map_err(|(_, e)| e)
        });
        match installed {
            Ok((slot, gen)) => Ok(StreamHandle { shard, slot, gen, id, cell }),
            Err(e) => {
                // Failed install: release the reservation.
                self.names.write().unwrap_or_else(|p| p.into_inner()).remove(&id);
                Err(e)
            }
        }
    }
}

/// What a [`StreamRouter::restore_pool`] recovery pass found and did.
#[derive(Debug, Default)]
pub struct RestoreReport {
    /// Streams rebuilt from a checkpoint file.
    pub restored: usize,
    /// Streams rebuilt from WAL `Open` records alone (crashed mid-seed,
    /// before their first checkpoint).
    pub from_wal_only: usize,
    /// WAL ingest records replayed through the normal ingest path.
    pub replayed: u64,
    /// Replayed records that errored (counted, not fatal — e.g. a
    /// record logged just before a rejected command).
    pub replay_errors: u64,
    /// Stream ids skipped because the log records their close.
    pub skipped_closed: usize,
    /// Checkpoint files quarantined (renamed `.corrupt`) as unreadable.
    pub quarantined: Vec<PathBuf>,
    /// Shard WALs whose tail was torn (tolerated: truncated at the
    /// first bad frame).
    pub torn_logs: usize,
    /// Per-stream rebuild failures (`id: reason`) — reported, never
    /// fatal to the pool.
    pub failed: Vec<String>,
    /// Whether the post-restore compaction checkpoint succeeded.
    pub compacted: bool,
    /// Handles of every recovered stream, sorted by id.
    pub handles: Vec<StreamHandle>,
}

/// Owner of the shard worker threads. Dropping (or calling
/// [`ShardPool::shutdown`]) stops every worker and joins it; router
/// clones held elsewhere then fail cleanly with "shard pool down".
pub struct ShardPool {
    router: StreamRouter,
}

impl ShardPool {
    /// Spawn `cfg.shards` worker threads (at least one), each with its
    /// own bounded command queue and rotation engine, placed on a
    /// `cfg.vnodes`-per-shard consistent-hash ring.
    pub fn spawn(cfg: PoolConfig) -> ShardPool {
        let n = cfg.shards.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = sync_channel(cfg.queue.max(1));
            txs.push(tx);
            rxs.push(rx);
        }
        let topo: SharedTopology = Arc::new(TopologyCell::new(Topology {
            senders: txs,
            ring: HashRing::with_shards(n, cfg.vnodes),
        }));
        let mut joins = Vec::with_capacity(n);
        for (shard, rx) in rxs.into_iter().enumerate() {
            let engine_cfg = cfg.engine.clone();
            let topo = topo.clone();
            let persist = cfg.persist.clone();
            joins.push(std::thread::spawn(move || {
                shard_worker(shard, engine_cfg, rx, topo, persist)
            }));
        }
        let router = StreamRouter {
            topo,
            redirects: Arc::new(RwLock::new(HashMap::new())),
            redirected: Arc::new(AtomicBool::new(false)),
            names: Arc::new(RwLock::new(HashSet::new())),
            reshard: Arc::new(Mutex::new(())),
            joins: Arc::new(Mutex::new(joins)),
            queue: cfg.queue.max(1),
            engine: cfg.engine,
            persist: cfg.persist,
        };
        ShardPool { router }
    }

    /// Number of shard workers (including retired ones after a shrink).
    pub fn shards(&self) -> usize {
        self.router.shards()
    }

    /// A cloneable routing handle (safe to share across producer
    /// threads).
    pub fn router(&self) -> StreamRouter {
        self.router.clone()
    }

    /// Grow by one shard — see [`StreamRouter::add_shard`].
    pub fn add_shard(&self) -> Result<usize, String> {
        self.router.add_shard()
    }

    /// Shrink by one shard — see [`StreamRouter::remove_shard`].
    pub fn remove_shard(&self, shard: usize) -> Result<usize, String> {
        self.router.remove_shard(shard)
    }

    /// Stop all workers and join them (open streams are dropped; close
    /// streams first if their final stats matter).
    pub fn shutdown(self) {
        // Drop runs the shutdown/join sequence.
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // Clone the senders out of the shared topology: Shutdown sends
        // can block on full queues, and workers still load the
        // topology to forward while draining.
        let senders: Vec<SyncSender<ShardCommand>> =
            self.router.topo.load().senders.to_vec();
        for tx in senders {
            let _ = tx.send(ShardCommand::Shutdown);
        }
        let joins: Vec<JoinHandle<()>> = self
            .router
            .joins
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        for join in joins {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;

    fn small_cfg() -> StreamConfig {
        StreamConfig {
            kernel: KernelConfig::Rbf { sigma: 1.0 },
            mean_adjust: true,
            seed_points: 5,
            ..StreamConfig::default()
        }
    }

    #[test]
    fn pinning_is_deterministic_and_spreads() {
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let mut hit = [false; 2];
        for i in 0..16 {
            let id = format!("stream-{i}");
            let s = router.shard_of(&id);
            assert_eq!(s, router.shard_of(&id), "pinning must be stable");
            assert!(s < 2);
            hit[s] = true;
        }
        assert!(hit[0] && hit[1], "16 ids should land on both shards");
        pool.shutdown();
    }

    #[test]
    fn open_twice_rejected_and_handles_expose_identity() {
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        let h = router.open_stream("a", 3, small_cfg()).unwrap();
        assert_eq!(h.id(), "a");
        assert_eq!(h.shard(), router.shard_of("a"));
        assert!(router.open_stream("a", 3, small_cfg()).is_err());
        pool.shutdown();
    }

    #[test]
    fn stale_handle_after_close_is_rejected() {
        let ds = yeast_like(8, 20);
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        let h = router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        router.close_stream(&h).unwrap();
        // The slot may be reused by a new stream; the old handle's
        // generation must not alias it.
        let h2 = router.open_stream("s2", ds.dim(), small_cfg()).unwrap();
        assert!(router.ingest(&h, ds.x.row(0).to_vec()).is_err());
        assert!(router.snapshot(&h).is_err());
        assert!(router.close_stream(&h).is_err());
        // Async ingest through a stale handle is counted, not lost.
        router.ingest_async(&h, ds.x.row(0).to_vec()).unwrap();
        router.ingest(&h2, ds.x.row(0).to_vec()).unwrap(); // barrier
        let snap = router.pool_snapshot().unwrap();
        assert_eq!(snap.errors, 1, "orphaned async command must surface in pool errors");
        pool.shutdown();
    }

    #[test]
    fn single_stream_through_pool_matches_reference() {
        let ds = yeast_like(24, 21);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let h = router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        let snap = router.snapshot(&h).unwrap();
        assert_eq!(snap.m, 24);
        assert_eq!(snap.kernel, "rbf");
        let d = router.measure_drift(&h).unwrap();
        assert!(d.norms.frobenius < 1e-7, "pool stream drift {:?}", d.norms);
        let stats = router.close_stream(&h).unwrap();
        assert_eq!(stats.accepted, 24);
        pool.shutdown();
    }

    #[test]
    fn batched_and_async_ingest_reach_the_same_state() {
        let ds = yeast_like(21, 22);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let hs = router.open_stream("seq", ds.dim(), small_cfg()).unwrap();
        let hb = router.open_stream("bat", ds.dim(), small_cfg()).unwrap();
        let ha = router.open_stream("asy", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&hs, ds.x.row(i).to_vec()).unwrap();
            router.ingest_async(&ha, ds.x.row(i).to_vec()).unwrap();
        }
        // Batched: all 21 points in chunks of 8 (seed phase included).
        let dim = ds.dim();
        let flat = ds.x.as_slice();
        let mut i = 0;
        while i < ds.n() {
            let end = (i + 8).min(ds.n());
            let reply = router.ingest_many(&hb, flat[i * dim..end * dim].to_vec()).unwrap();
            assert_eq!(reply.seeded + reply.accepted + reply.excluded, end - i);
            i = end;
        }
        assert_eq!(router.sync(&ha).unwrap(), 0, "clean async stream has no errors");
        for h in [&hs, &hb, &ha] {
            let snap = router.snapshot(h).unwrap();
            assert_eq!(snap.m, 21, "{}", h.id());
        }
        // All three eigensystems agree (same data, same kernel).
        let s0 = router.snapshot(&hs).unwrap();
        for h in [&hb, &ha] {
            let s = router.snapshot(h).unwrap();
            for (a, b) in s0.top_values.iter().zip(&s.top_values) {
                assert!((a - b).abs() < 1e-10, "{}: {a} vs {b}", h.id());
            }
        }
        pool.shutdown();
    }

    #[test]
    fn async_errors_surface_on_next_sync() {
        let ds = yeast_like(8, 23);
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        let h = router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest_async(&h, ds.x.row(i).to_vec()).unwrap();
        }
        // A wrong-dimension point: accepted by the queue, deferred as a
        // per-stream error.
        router.ingest_async(&h, vec![0.0; ds.dim() + 1]).unwrap();
        let err = router.sync(&h).unwrap_err();
        assert!(err.contains("dimension mismatch"), "deferred error: {err}");
        // Error cleared; the counter remembers.
        assert_eq!(router.sync(&h).unwrap(), 1);
        let m = router.metrics(&h).unwrap();
        assert_eq!(m.errors, 1);
        assert_eq!(m.async_errors, 1);
        pool.shutdown();
    }

    #[test]
    fn pool_snapshot_rolls_up_across_shards() {
        let ds = yeast_like(16, 22);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        for sid in ["alpha", "beta", "gamma"] {
            let h = router.open_stream(sid, ds.dim(), small_cfg()).unwrap();
            for i in 0..ds.n() {
                router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
            }
        }
        let snap = router.pool_snapshot().unwrap();
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.active_shards, 2);
        assert_eq!(snap.streams, 3);
        assert_eq!(snap.accepted, 3 * (16 - 5) as u64);
        assert_eq!(snap.ingest_count, 3 * 16);
        assert!(snap.total_ws_bytes > 0);
        assert_eq!(snap.per_stream.len(), 3);
        assert_eq!(snap.migrations, 0);
        // Per-shard occupancy covers both members and sums to the pool.
        assert_eq!(snap.per_shard.len(), 2);
        assert!(snap.per_shard.iter().all(|o| o.active));
        assert_eq!(snap.per_shard.iter().map(|o| o.streams).sum::<usize>(), 3);
        assert_eq!(
            snap.per_shard.iter().map(|o| o.ws_bytes_resident).sum::<u64>(),
            snap.total_ws_bytes
        );
        // Sorted by stream id, each attributed to its pinned shard.
        assert_eq!(snap.per_stream[0].stream, "alpha");
        for g in &snap.per_stream {
            assert_eq!(g.shard, router.shard_of(&g.stream));
            assert_eq!(g.m, 16);
        }
        pool.shutdown();
    }

    #[test]
    fn tombstone_forwards_stale_traffic_after_migration() {
        let ds = yeast_like(20, 25);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let h = router.open_stream("fwd", ds.dim(), small_cfg()).unwrap();
        for i in 0..10 {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        // Migrate via the raw command, deliberately bypassing the
        // router's redirect bookkeeping: every subsequent verb through
        // the (now stale) handle models in-flight traffic that raced a
        // redirect update, and must reach the stream via the source
        // worker's forwarding tombstone instead.
        let target = (h.shard() + 1) % 2;
        let from = router.resolve(&h);
        router
            .rpc(from.shard, |reply| ShardCommand::Migrate {
                slot: from.slot,
                gen: from.gen,
                to_shard: target,
                reply,
            })
            .unwrap()
            .unwrap();
        for i in 10..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        router.ingest_async(&h, ds.x.row(3).to_vec()).unwrap();
        assert_eq!(router.sync(&h).unwrap(), 0, "forwarded async must not be lost");
        let snap = router.snapshot(&h).unwrap();
        assert!(snap.m >= ds.n(), "every forwarded ingest reached the stream");
        let ps = router.pool_snapshot().unwrap();
        assert_eq!(ps.migrations, 1);
        assert_eq!(ps.errors, 0, "forwarded commands must not orphan");
        // 10 rendezvous ingests + 1 async + 1 sync + 1 snapshot, all
        // re-addressed at the tombstone.
        assert!(ps.forwards >= 13, "stale verbs must be forwarded, got {}", ps.forwards);
        let g = ps.per_stream.iter().find(|g| g.stream == "fwd").unwrap();
        assert_eq!(g.shard, target, "gauges attribute the stream to its new home");
        pool.shutdown();
    }

    #[test]
    fn redirect_gc_rearms_fast_path_after_close() {
        let ds = yeast_like(12, 26);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let h = router.open_stream("gc", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        assert!(!router.redirected.load(Ordering::Acquire));
        assert_eq!(router.redirect_entries(), 0);
        let target = (h.shard() + 1) % 2;
        router.migrate_stream(&h, target).unwrap();
        assert!(
            router.redirected.load(Ordering::Acquire),
            "migration must arm the redirect path"
        );
        assert_eq!(router.redirect_entries(), 1);
        // The redirected handle still works before the close.
        router.ingest(&h, ds.x.row(0).to_vec()).unwrap();
        router.close_stream(&h).unwrap();
        // GC: the closed stream's redirect entry is dead weight, and
        // with the table drained the lock-free fast path re-arms.
        assert_eq!(router.redirect_entries(), 0);
        assert!(
            !router.redirected.load(Ordering::Acquire),
            "drained redirect table must re-arm the fast path"
        );
        // Re-arming is not one-way: a later migration raises the flag
        // and redirects correctly again.
        let h2 = router.open_stream("gc2", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h2, ds.x.row(i).to_vec()).unwrap();
        }
        router.migrate_stream(&h2, (h2.shard() + 1) % 2).unwrap();
        assert!(router.redirected.load(Ordering::Acquire));
        assert_eq!(router.redirect_entries(), 1);
        assert_eq!(router.snapshot(&h2).unwrap().m, ds.n());
        pool.shutdown();
    }

    #[test]
    fn publish_after_deadline_bounds_snapshot_staleness() {
        let ds = yeast_like(8, 27);
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        // Count cadence effectively off; a zero deadline means every
        // accepted point with the deadline elapsed publishes — the
        // deterministic way to observe the time-based path.
        let deadline = StreamConfig {
            publish_every: 1_000_000,
            publish_after: Some(Duration::from_millis(0)),
            ..small_cfg()
        };
        let count_only = StreamConfig { publish_every: 1_000_000, ..small_cfg() };
        let hd = router.open_stream("deadline", ds.dim(), deadline).unwrap();
        let hc = router.open_stream("count", ds.dim(), count_only).unwrap();
        for i in 0..5 {
            router.ingest(&hd, ds.x.row(i).to_vec()).unwrap();
            router.ingest(&hc, ds.x.row(i).to_vec()).unwrap();
        }
        // Both published once at seed completion.
        let ed = router.snapshot_epoch(&hd);
        let ec = router.snapshot_epoch(&hc);
        assert!(ed >= 1 && ec >= 1);
        router.ingest(&hd, ds.x.row(5).to_vec()).unwrap();
        router.ingest(&hc, ds.x.row(5).to_vec()).unwrap();
        assert!(
            router.snapshot_epoch(&hd) > ed,
            "elapsed deadline must publish on the next accepted point"
        );
        assert_eq!(
            router.snapshot_epoch(&hc),
            ec,
            "count-only stream is still waiting for its cadence"
        );
        pool.shutdown();
    }

    #[test]
    fn ingest_all_rejects_malformed_feed_without_panicking() {
        let ds = yeast_like(12, 24);
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        let h = router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        let flat = ds.x.as_slice();
        // Truncated feed: not a whole number of rows.
        let err = router.ingest_all(&h, &flat[..flat.len() - 1], ds.dim(), 4).unwrap_err();
        assert!(err.contains("not a multiple"), "{err}");
        // Zero dim is malformed, not a divide-by-zero panic.
        assert!(router.ingest_all(&h, flat, 0, 4).is_err());
        // The stream is untouched and still usable.
        let reply = router.ingest_all(&h, flat, ds.dim(), 4).unwrap();
        assert_eq!(reply.seeded + reply.accepted + reply.excluded, ds.n());
        pool.shutdown();
    }
}
