//! Shard-pool scaling bench: aggregate ingest throughput of a fixed
//! multi-stream workload (one producer thread per stream) as the shard
//! count grows 1 → 2 → 4. Streams are pinned by id hash, so with more
//! shards the same producers contend on fewer shared queues and the
//! per-shard update loops run on separate cores. Emits
//! `BENCH_shards.json` for the perf trajectory.

use inkpca::coordinator::{EngineConfig, KernelConfig, PoolConfig, ShardPool, StreamConfig};
use inkpca::data::{load, Dataset};
use inkpca::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let fast = std::env::var("INKPCA_BENCH_FAST").is_ok();
    let n_per_stream = if fast { 60 } else { 160 };
    let n_streams = 4usize;

    // One dataset per stream (distinct seeds — independent eigensystems).
    let datasets: Vec<Dataset> = (0..n_streams)
        .map(|s| {
            let mut ds = load("yeast", n_per_stream, 100 + s as u64).unwrap();
            ds.standardize();
            ds
        })
        .collect();

    for shards in [1usize, 2, 4] {
        b.case(&format!("shards/ingest_4streams/shards{shards}"), || {
            let pool = ShardPool::spawn(PoolConfig {
                shards,
                queue: 64,
                engine: EngineConfig::Native,
            });
            let router = pool.router();
            std::thread::scope(|scope| {
                for (si, ds) in datasets.iter().enumerate() {
                    let r = router.clone();
                    scope.spawn(move || {
                        let id = format!("stream-{si}");
                        r.open_stream(
                            &id,
                            ds.dim(),
                            StreamConfig {
                                kernel: KernelConfig::Rbf { sigma: 2.0 },
                                mean_adjust: true,
                                seed_points: 10,
                                drift_every: 0,
                            },
                        )
                        .unwrap();
                        for i in 0..ds.n() {
                            r.ingest(&id, ds.x.row(i).to_vec()).unwrap();
                        }
                    });
                }
            });
            let snap = router.pool_snapshot().unwrap();
            pool.shutdown();
            snap.accepted
        });
    }

    b.finish();
    if let Err(e) = b.write_json("BENCH_shards.json") {
        eprintln!("warning: could not write BENCH_shards.json: {e}");
    } else {
        println!("wrote BENCH_shards.json");
    }
}
