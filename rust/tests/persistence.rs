//! Durability integration tests: checkpoint/restore round-trips, WAL
//! replay after simulated crashes, torn-tail and corrupt-checkpoint
//! tolerance, close semantics across restarts, and the durability
//! counters in the pool rollup.
//!
//! A "crash" here is a pool shutdown WITHOUT closing the streams: the
//! write-ahead log already holds every accepted command (append happens
//! before apply), so dropping the workers mid-stream loses exactly the
//! state a real kill would lose. The exactness bar matches the
//! migration suite: a restored stream must reproduce an uninterrupted
//! single-threaded reference to ≤ 1e-10 — recovery replays history, it
//! never approximates it.

mod common;

use std::path::PathBuf;

use common::oracle;
use inkpca::coordinator::{
    EngineConfig, KernelConfig, PersistConfig, PoolConfig, ShardPool, StreamConfig,
    StreamHandle, StreamRouter,
};
use inkpca::data::Dataset;
use inkpca::kpca::IncrementalKpca;

const SEED_POINTS: usize = 6;
const SIGMA: f64 = 1.5;

fn temp_dir(tag: &str) -> PathBuf {
    oracle::temp_dir(tag)
}

fn stream_cfg() -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma: SIGMA },
        mean_adjust: true,
        seed_points: SEED_POINTS,
        ..StreamConfig::default()
    }
}

fn durable_pool(dir: &PathBuf) -> (ShardPool, StreamRouter) {
    let pool = ShardPool::spawn(PoolConfig {
        shards: 2,
        queue: 64,
        engine: EngineConfig::Native,
        persist: Some(PersistConfig::new(dir.clone())),
        ..PoolConfig::default()
    });
    let router = pool.router();
    (pool, router)
}

/// Uninterrupted reference: the same feed driven directly through the
/// engine type the shard workers use.
fn reference_run(ds: &Dataset, n: usize) -> IncrementalKpca<'static> {
    oracle::reference_run(ds, n, SIGMA, SEED_POINTS)
}

fn assert_matches_reference(
    router: &StreamRouter,
    h: &StreamHandle,
    ds: &Dataset,
    reference: &IncrementalKpca<'static>,
) {
    oracle::assert_matches_reference(router, h, ds, reference);
}

fn feed(router: &StreamRouter, h: &StreamHandle, ds: &Dataset, range: std::ops::Range<usize>) {
    for i in range {
        router.ingest(h, ds.x.row(i).to_vec()).unwrap();
    }
}

/// The torture matrix: kill the pool at a mid-seed, just-seeded and
/// mid-feed cut (never checkpointed — the WAL alone must carry the
/// stream), restore, finish the feed, and demand the uninterrupted
/// reference. Then crash AGAIN after the full feed and restore once
/// more: the second recovery replays a log that already contains
/// replayed (re-logged) records, so it also proves replay idempotence
/// under sequence-number dedup.
#[test]
fn crash_without_checkpoint_recovers_from_wal_alone() {
    let ds = oracle::std_stream(24, 1101);
    let reference = reference_run(&ds, ds.n());
    for cut in [2, SEED_POINTS + 1, 16] {
        let dir = temp_dir("walonly");
        let (pool, router) = durable_pool(&dir);
        let h = router.open_stream("t", ds.dim(), stream_cfg()).unwrap();
        feed(&router, &h, &ds, 0..cut);
        drop(h);
        pool.shutdown(); // crash: no close, no checkpoint

        let (pool2, router2) = durable_pool(&dir);
        let report = router2.restore_pool().unwrap();
        assert_eq!(report.restored, 0, "cut {cut}: nothing was checkpointed");
        assert_eq!(report.from_wal_only, 1, "cut {cut}");
        assert_eq!(report.replayed, cut as u64, "cut {cut}");
        assert_eq!(report.replay_errors, 0, "cut {cut}");
        assert!(report.failed.is_empty(), "cut {cut}: {:?}", report.failed);
        assert!(report.compacted, "cut {cut}: restore ends with a compaction checkpoint");
        let h = report.handles[0].clone();
        assert_eq!(h.id(), "t");
        feed(&router2, &h, &ds, cut..ds.n());
        assert_matches_reference(&router2, &h, &ds, &reference);
        drop(h);
        pool2.shutdown(); // crash again, now with a checkpoint + WAL suffix

        let (pool3, router3) = durable_pool(&dir);
        let report = router3.restore_pool().unwrap();
        assert_eq!(report.restored, 1, "cut {cut}: compaction checkpoint found");
        assert_eq!(report.from_wal_only, 0, "cut {cut}");
        assert!(report.failed.is_empty(), "cut {cut}: {:?}", report.failed);
        assert_matches_reference(&router3, &report.handles[0], &ds, &reference);
        pool3.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Checkpoint mid-feed, keep feeding, crash: restore must load the
/// checkpoint and replay exactly the post-checkpoint WAL suffix.
#[test]
fn crash_after_checkpoint_replays_only_the_suffix() {
    let ds = oracle::std_stream(28, 1102);
    let dir = temp_dir("suffix");
    let (pool, router) = durable_pool(&dir);
    let h = router.open_stream("s", ds.dim(), stream_cfg()).unwrap();
    feed(&router, &h, &ds, 0..14);
    let bytes = router.checkpoint_stream(&h).unwrap();
    assert!(bytes > 0);
    feed(&router, &h, &ds, 14..ds.n());
    drop(h);
    pool.shutdown(); // crash

    let (pool2, router2) = durable_pool(&dir);
    let report = router2.restore_pool().unwrap();
    assert_eq!(report.restored, 1);
    assert_eq!(report.from_wal_only, 0);
    assert_eq!(
        report.replayed,
        (ds.n() - 14) as u64,
        "only the post-checkpoint suffix replays"
    );
    assert_eq!(report.replay_errors, 0);
    let reference = reference_run(&ds, ds.n());
    assert_matches_reference(&router2, &report.handles[0], &ds, &reference);

    // Restored counters continue, never reset: the checkpoint carried
    // them and the replayed suffix re-accumulated on top.
    let m = router2.metrics(&report.handles[0]).unwrap();
    assert_eq!(m.accepted, (ds.n() - SEED_POINTS) as u64);
    let snap = router2.pool_snapshot().unwrap();
    assert_eq!(snap.recovered_streams, 1);
    assert!(snap.checkpoints >= 1);
    pool2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Garbage appended to a WAL (a torn final write) must be truncated at
/// open, not poison recovery; chopping bytes off the tail loses exactly
/// the last record and nothing else.
#[test]
fn torn_wal_tail_is_truncated_not_fatal() {
    let ds = oracle::std_stream(20, 1103);
    let dir = temp_dir("torn");
    let (pool, router) = durable_pool(&dir);
    let h = router.open_stream("torn", ds.dim(), stream_cfg()).unwrap();
    feed(&router, &h, &ds, 0..ds.n());
    drop(h);
    pool.shutdown(); // crash

    // Tear the tail of whichever shard WAL holds the stream: first add
    // garbage (a frame that never finished writing its payload)…
    let wal: Vec<PathBuf> = (0..2)
        .map(|s| dir.join(format!("wal-{s}.log")))
        .filter(|p| p.metadata().map(|m| m.len() > 8).unwrap_or(false))
        .collect();
    assert_eq!(wal.len(), 1, "one shard owns the stream's WAL");
    let len = wal[0].metadata().unwrap().len();
    {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().append(true).open(&wal[0]).unwrap();
        f.write_all(&[0x55; 11]).unwrap();
    }
    let (pool2, router2) = durable_pool(&dir);
    let report = router2.restore_pool().unwrap();
    // The repair happens at the earliest open: the respawned worker's
    // `WalWriter::open` truncates the garbage before `restore_pool`
    // reads the log, so the reader sees a clean file (`torn_logs`
    // counts tears the *reader* had to skip — e.g. logs left by a
    // larger former topology that no current worker owns).
    assert_eq!(report.torn_logs, 0, "writer-side repair beat the reader to it");
    assert_eq!(report.replayed, ds.n() as u64, "no valid record is lost to the tear");
    let reference = reference_run(&ds, ds.n());
    assert_matches_reference(&router2, &report.handles[0], &ds, &reference);
    pool2.shutdown();

    // …then rebuild the pre-compaction log shape by hand: truncate a
    // fresh copy mid-frame and recover from it. The final record is
    // gone; every earlier one survives.
    let dir2 = temp_dir("torn2");
    let (pool3, router3) = durable_pool(&dir2);
    let h = router3.open_stream("torn", ds.dim(), stream_cfg()).unwrap();
    feed(&router3, &h, &ds, 0..ds.n());
    drop(h);
    pool3.shutdown();
    let wal2: Vec<PathBuf> = (0..2)
        .map(|s| dir2.join(format!("wal-{s}.log")))
        .filter(|p| p.metadata().map(|m| m.len() > 8).unwrap_or(false))
        .collect();
    let f = std::fs::OpenOptions::new().write(true).open(&wal2[0]).unwrap();
    f.set_len(len - 3).unwrap();
    drop(f);
    let (pool4, router4) = durable_pool(&dir2);
    let report = router4.restore_pool().unwrap();
    assert_eq!(report.replayed, (ds.n() - 1) as u64, "exactly the torn record is lost");
    let reference = reference_run(&ds, ds.n() - 1);
    assert_matches_reference(&router4, &report.handles[0], &ds, &reference);
    pool4.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// A corrupt checkpoint is quarantined (renamed, never deleted) and the
/// stream falls back to full WAL replay — the pool must come up serving
/// with zero aborted restores.
#[test]
fn corrupt_checkpoint_quarantined_wal_rescues() {
    let ds = oracle::std_stream(22, 1104);
    let dir = temp_dir("quarantine");
    let (pool, router) = durable_pool(&dir);
    let h = router.open_stream("q", ds.dim(), stream_cfg()).unwrap();
    feed(&router, &h, &ds, 0..12);
    // Single-stream checkpoint: does NOT rotate the WAL, so the full
    // log remains as the fallback the corruption test needs.
    router.checkpoint_stream(&h).unwrap();
    feed(&router, &h, &ds, 12..ds.n());
    drop(h);
    pool.shutdown(); // crash

    // Flip one payload byte in the only checkpoint file.
    let ckpt: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| Some(e.ok()?.path()))
        .filter(|p| p.extension().map(|x| x == "ckpt").unwrap_or(false))
        .collect();
    assert_eq!(ckpt.len(), 1);
    let mut bytes = std::fs::read(&ckpt[0]).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x80;
    std::fs::write(&ckpt[0], &bytes).unwrap();

    let (pool2, router2) = durable_pool(&dir);
    let report = router2.restore_pool().unwrap();
    assert_eq!(report.quarantined.len(), 1, "bad checkpoint set aside, not deleted");
    assert!(report.quarantined[0].to_string_lossy().ends_with(".corrupt"));
    assert!(report.quarantined[0].exists(), "quarantined bytes survive for forensics");
    assert_eq!(report.restored, 0);
    assert_eq!(report.from_wal_only, 1, "the WAL carries the stream instead");
    assert_eq!(report.replayed, ds.n() as u64);
    assert!(report.failed.is_empty(), "{:?}", report.failed);
    let reference = reference_run(&ds, ds.n());
    assert_matches_reference(&router2, &report.handles[0], &ds, &reference);
    pool2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Close is durable: a stream closed before the crash must NOT
/// resurrect, and its id is free for a fresh open after restore.
#[test]
fn closed_streams_stay_closed_after_restore() {
    let ds = oracle::std_stream(18, 1105);
    let dir = temp_dir("closed");
    let (pool, router) = durable_pool(&dir);
    let keep = router.open_stream("keep", ds.dim(), stream_cfg()).unwrap();
    let gone = router.open_stream("gone", ds.dim(), stream_cfg()).unwrap();
    feed(&router, &keep, &ds, 0..ds.n());
    feed(&router, &gone, &ds, 0..ds.n());
    // Per-stream checkpoint only: no WAL rotation, so "gone"'s Open and
    // Close records are still in the log for restore to reconcile.
    router.checkpoint_stream(&keep).unwrap();
    let stats = router.close_stream(&gone).unwrap();
    assert_eq!(stats.accepted, ds.n() as u64);
    drop((keep, gone));
    pool.shutdown(); // crash

    let (pool2, router2) = durable_pool(&dir);
    let report = router2.restore_pool().unwrap();
    assert_eq!(report.skipped_closed, 1, "the closed stream is not resurrected");
    assert_eq!(report.restored, 1);
    assert_eq!(report.handles.len(), 1);
    assert_eq!(report.handles[0].id(), "keep");
    let reference = reference_run(&ds, ds.n());
    assert_matches_reference(&router2, &report.handles[0], &ds, &reference);
    // The closed id is free again and starts from scratch.
    let fresh = router2.open_stream("gone", ds.dim(), stream_cfg()).unwrap();
    assert_eq!(router2.snapshot(&fresh).unwrap().m, 0);
    pool2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Durability counters thread end to end: every accepted command is
/// write-ahead logged, checkpoints are counted per stream and rolled
/// up, and the WAL never errors on the happy path.
#[test]
fn durability_counters_roll_up() {
    let ds = oracle::std_stream(20, 1106);
    let dir = temp_dir("counters");
    let (pool, router) = durable_pool(&dir);
    let h = router.open_stream("c", ds.dim(), stream_cfg()).unwrap();
    feed(&router, &h, &ds, 0..ds.n());
    // Batched ingest logs ONE record per command, not per point.
    let tail: Vec<f64> =
        (0..4).flat_map(|i| ds.x.row(i).iter().copied()).collect();
    router.ingest_many(&h, tail).unwrap();
    router.checkpoint_stream(&h).unwrap();
    router.checkpoint_stream(&h).unwrap();

    let snap = router.pool_snapshot().unwrap();
    assert_eq!(
        snap.wal_appends,
        ds.n() as u64 + 2,
        "1 open + n single ingests + 1 batch record"
    );
    assert!(snap.wal_bytes > 0);
    assert_eq!(snap.wal_errors, 0);
    assert_eq!(snap.checkpoints, 2);
    assert_eq!(snap.recovered_streams, 0, "nothing restored in this life");
    let m = router.metrics(&h).unwrap();
    assert_eq!(m.checkpoints, 2);
    assert_eq!(m.wal_appends, snap.wal_appends);
    assert_eq!(m.wal_errors, 0);
    pool.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Restoring from an empty (or absent) snapshot directory is a clean
/// no-op fresh start, so restore-then-serve needs no first-boot branch.
#[test]
fn restore_from_empty_dir_is_fresh_start() {
    let dir = temp_dir("fresh");
    let (pool, router) = durable_pool(&dir);
    let report = router.restore_pool().unwrap();
    assert_eq!(report.restored + report.from_wal_only, 0);
    assert_eq!(report.replayed, 0);
    assert!(report.handles.is_empty());
    // And the pool is fully usable afterwards.
    let ds = oracle::std_stream(10, 1107);
    let h = router.open_stream("f", ds.dim(), stream_cfg()).unwrap();
    feed(&router, &h, &ds, 0..ds.n());
    assert_eq!(router.snapshot(&h).unwrap().m, ds.n());
    pool.shutdown();
    std::fs::remove_dir_all(&dir).ok();

    // A pool with no persist config reports restore as unconfigured.
    let pool = ShardPool::spawn(PoolConfig {
        shards: 1,
        queue: 8,
        engine: EngineConfig::Native,
        ..PoolConfig::default()
    });
    let router = pool.router();
    assert!(router.restore_pool().is_err());
    assert!(router.checkpoint_all().is_err());
    pool.shutdown();
}

/// Format-compatibility pin: an `IKCKPT02` checkpoint (the previous
/// on-disk format, written before the engine-tier seam existed) must
/// restore as the `Exact` tier with full fidelity. A fresh `IKCKPT03`
/// file of an exact-tier stream differs from the v02 layout by exactly
/// the magic and the one-byte tier tag at the end of the config block
/// (the `Exact` state block is byte-identical), so the test rewrites a
/// real checkpoint into the legacy layout on disk, deletes the WALs so
/// the file alone must carry the stream, and restores from it.
#[test]
fn v02_checkpoint_restores_as_exact_tier() {
    let ds = oracle::std_stream(20, 1109);
    let dir = temp_dir("v02");
    let (pool, router) = durable_pool(&dir);
    let h = router.open_stream("legacy", ds.dim(), stream_cfg()).unwrap();
    feed(&router, &h, &ds, 0..ds.n());
    router.checkpoint_stream(&h).unwrap();
    drop(h);
    pool.shutdown(); // crash after checkpoint

    let ckpt: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| Some(e.ok()?.path()))
        .filter(|p| p.extension().map(|x| x == "ckpt").unwrap_or(false))
        .collect();
    assert_eq!(ckpt.len(), 1);
    let bytes = std::fs::read(&ckpt[0]).unwrap();
    assert_eq!(&bytes[..8], b"IKCKPT03");
    // Payload offset of the config tier tag for this test's stream:
    // str("legacy") + dim:u64 + RBF kernel (tag+sigma) + mean_adjust +
    // 4 cadence/capacity u64s + rotation + publish_every + snapshot_r +
    // publish_after(None) + max_landmarks + eviction
    // = (4+6) + 8 + 9 + 1 + 32 + 1 + 8 + 8 + 1 + 8 + 1 = 87.
    let payload = &bytes[16..];
    let off = 87;
    assert_eq!(payload[off], 0, "exact tier tag where the layout says");
    let mut v2_payload = payload.to_vec();
    v2_payload.remove(off);
    let mut v2 = b"IKCKPT02".to_vec();
    v2.extend_from_slice(&(v2_payload.len() as u32).to_le_bytes());
    v2.extend_from_slice(&inkpca::coordinator::wal::crc32(&v2_payload).to_le_bytes());
    v2.extend_from_slice(&v2_payload);
    std::fs::write(&ckpt[0], &v2).unwrap();
    for s in 0..2 {
        std::fs::remove_file(dir.join(format!("wal-{s}.log"))).ok();
    }

    let (pool2, router2) = durable_pool(&dir);
    let report = router2.restore_pool().unwrap();
    assert!(report.quarantined.is_empty(), "v02 must decode, not quarantine");
    assert_eq!(report.restored, 1);
    assert_eq!(report.replayed, 0, "no WAL left — the v02 file alone carried it");
    let h = report.handles[0].clone();
    assert_eq!(router2.snapshot(&h).unwrap().tier, "exact");
    let reference = reference_run(&ds, ds.n());
    assert_matches_reference(&router2, &h, &ds, &reference);
    // And the restored stream keeps serving.
    feed(&router2, &h, &ds, 0..2);
    assert_eq!(router2.snapshot(&h).unwrap().m, ds.n() + 2);
    pool2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The single-stream `Coordinator` wrapper: restore-or-spawn, feed,
/// checkpoint, crash, restore — the default stream comes back with its
/// state and keeps serving.
#[test]
fn coordinator_restore_roundtrip() {
    use inkpca::coordinator::{Config, Coordinator};
    let ds = oracle::std_stream(16, 1108);
    let dir = temp_dir("coord");
    let cfg = Config {
        kernel: KernelConfig::Rbf { sigma: SIGMA },
        seed_points: SEED_POINTS,
        persist: Some(PersistConfig::new(dir.clone())),
        ..Config::default()
    };
    // First boot: empty dir, restore falls through to a fresh stream.
    let (coord, report) = Coordinator::restore(cfg.clone(), ds.dim()).unwrap();
    assert_eq!(report.restored + report.from_wal_only, 0);
    for i in 0..ds.n() {
        coord.ingest(ds.x.row(i).to_vec()).unwrap();
    }
    assert_eq!(coord.checkpoint_all().unwrap(), 1);
    drop(coord); // crash after checkpoint (shutdown() would close cleanly)

    let (coord, report) = Coordinator::restore(cfg, ds.dim()).unwrap();
    assert_eq!(report.restored, 1);
    let snap = coord.snapshot().unwrap();
    assert_eq!(snap.m, ds.n());
    // The restored default stream is reference-exact…
    let reference = reference_run(&ds, ds.n());
    let probe = vec![0.25; ds.dim()];
    let got = coord.project(probe.clone(), 4).unwrap();
    for (g, w) in got.iter().zip(&reference.project(&probe, 4)) {
        assert!((g.abs() - w.abs()).abs() <= 1e-10, "projection {g} vs reference {w}");
    }
    // …and keeps serving: more points land on the restored eigensystem.
    coord.ingest(ds.x.row(0).to_vec()).unwrap();
    assert_eq!(coord.snapshot().unwrap().m, ds.n() + 1);
    coord.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
