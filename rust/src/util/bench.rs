//! Criterion-style micro-bench harness (criterion itself is not
//! available offline): warm-up, timed samples, robust summary stats, and
//! a stable one-line report format the bench binaries and
//! EXPERIMENTS.md share.

use std::time::{Duration, Instant};

/// Summary statistics over the collected samples.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub samples: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    fn from_samples(mut ns: Vec<f64>) -> Stats {
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            samples: n,
            mean_ns: mean,
            median_ns: if n % 2 == 1 {
                ns[n / 2]
            } else {
                0.5 * (ns[n / 2 - 1] + ns[n / 2])
            },
            stddev_ns: var.sqrt(),
            min_ns: ns[0],
            max_ns: ns[n - 1],
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner for one binary; prints one line per case.
pub struct Bench {
    /// Minimum wall time to spend sampling each case.
    pub min_time: Duration,
    /// Hard cap on the number of samples.
    pub max_samples: usize,
    /// Warm-up invocations before timing.
    pub warmup: usize,
    results: Vec<(String, Stats)>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // `INKPCA_BENCH_FAST=1` shrinks budgets so `cargo bench` in CI
        // finishes quickly; full runs drop the variable.
        let fast = std::env::var("INKPCA_BENCH_FAST").is_ok();
        Bench {
            min_time: if fast { Duration::from_millis(200) } else { Duration::from_secs(2) },
            max_samples: if fast { 10 } else { 100 },
            warmup: if fast { 1 } else { 3 },
            results: Vec::new(),
        }
    }

    /// Time `f` and print + record the summary under `name`.
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.max_samples
            && (samples.len() < 5 || start.elapsed() < self.min_time)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = Stats::from_samples(samples);
        println!(
            "bench {name:<48} median {:>12}  mean {:>12}  ±{:>10}  (n={})",
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.stddev_ns),
            stats.samples
        );
        self.results.push((name.to_string(), stats));
        stats
    }

    /// All recorded results.
    pub fn results(&self) -> &[(String, Stats)] {
        &self.results
    }

    /// Write the recorded results as a JSON array (hand-rolled — no
    /// serde offline). The perf-trajectory files (`BENCH_*.json`) the
    /// bench binaries emit go through here.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "[")?;
        for (i, (name, s)) in self.results.iter().enumerate() {
            let comma = if i + 1 < self.results.len() { "," } else { "" };
            writeln!(
                f,
                "  {{\"name\": \"{}\", \"median_ns\": {:.0}, \"mean_ns\": {:.0}, \"stddev_ns\": {:.0}, \"min_ns\": {:.0}, \"max_ns\": {:.0}, \"samples\": {}}}{}",
                name.replace('"', "'"),
                s.median_ns,
                s.mean_ns,
                s.stddev_ns,
                s.min_ns,
                s.max_ns,
                s.samples,
                comma
            )?;
        }
        writeln!(f, "]")?;
        Ok(())
    }

    /// Final machine-readable TSV block (consumed by EXPERIMENTS.md
    /// tooling and by `inkpca bench-report`).
    pub fn finish(&self) {
        println!("== bench-tsv ==");
        println!("name\tmedian_ns\tmean_ns\tstddev_ns\tsamples");
        for (name, s) in &self.results {
            println!(
                "{name}\t{:.0}\t{:.0}\t{:.0}\t{}",
                s.median_ns, s.mean_ns, s.stddev_ns, s.samples
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples(vec![10.0; 8]);
        assert_eq!(s.mean_ns, 10.0);
        assert_eq!(s.median_ns, 10.0);
        assert_eq!(s.stddev_ns, 0.0);
        assert_eq!(s.min_ns, 10.0);
        assert_eq!(s.max_ns, 10.0);
    }

    #[test]
    fn stats_median_even_odd() {
        let s = Stats::from_samples(vec![1.0, 3.0, 2.0]);
        assert_eq!(s.median_ns, 2.0);
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median_ns, 2.5);
    }

    #[test]
    fn write_json_emits_valid_rows() {
        let mut b = Bench::new();
        b.min_time = Duration::from_millis(1);
        b.max_samples = 5;
        b.warmup = 0;
        b.case("alpha", || 1);
        b.case("beta/gamma", || 2);
        let path = std::env::temp_dir().join("inkpca_bench_test.json");
        let path = path.to_str().unwrap().to_string();
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.trim_start().starts_with('['));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"name\": \"alpha\""));
        assert!(text.contains("\"name\": \"beta/gamma\""));
        assert_eq!(text.matches("median_ns").count(), 2);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn case_runs_and_records() {
        std::env::set_var("INKPCA_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.min_time = Duration::from_millis(1);
        b.max_samples = 6;
        b.warmup = 0;
        let s = b.case("noop", || 1 + 1);
        assert!(s.samples >= 5);
        assert_eq!(b.results().len(), 1);
    }
}
