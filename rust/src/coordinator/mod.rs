//! Layer-3 streaming coordinator: a sharded multi-stream engine with an
//! *elastic* topology. [`shard`] owns the machinery — a [`ShardPool`]
//! of worker threads (each holding slot-indexed per-stream eigenstate,
//! a shared rotation engine, and per-stream metrics) fronted by a
//! stream-keyed [`StreamRouter`] over per-shard bounded channels
//! (backpressure is per shard). Streams are placed on a consistent-hash
//! ring ([`ring`], FNV-1a keyed, deterministic across processes);
//! [`StreamRouter::add_shard`] / [`StreamRouter::remove_shard`] /
//! [`StreamRouter::rebalance`] change the shard count *live*, migrating
//! only the streams whose ring arc moved — each stream's eigensystem
//! ships between workers (it is `Send`) behind a queue-drain barrier,
//! under a bumped slot generation, with stale handles re-routed through
//! a redirect table plus worker-side forwarding tombstones.
//! [`StreamRouter::open_stream`] resolves a stream id to a cheap
//! [`StreamHandle`] once; the data-path verbs — rendezvous `ingest`,
//! fire-and-forget `ingest_async` (+ `sync` error drain), and batched
//! `ingest_many` — then address by slot with no per-command string.
//! [`server`] keeps the historical single-stream [`Coordinator`] API as
//! a thin wrapper over a 1-shard pool. [`drift`] measures live
//! reconstruction error; [`metrics`] holds the per-stream
//! histograms/gauges and the pool-level rollup (now with per-shard
//! occupancy and migration counters); [`router`] routes each rank-one
//! back-rotation to the native GEMM or the AOT PJRT engine.
//! [`snapshot`] is the lock-free read path: the worker publishes an
//! immutable [`ProjectionSnapshot`] per stream through an epoch-swapped
//! [`SnapshotCell`], and [`StreamRouter::project_snapshot`] /
//! [`StreamRouter::project_many`] serve projections from it without
//! enqueueing a single shard command.
//! [`engine`] is the stream-engine seam: every per-stream verb behind
//! the object-safe [`StreamState`] trait, with the engine chosen per
//! stream by [`StreamTier`] — the paper-exact eigensystem, the
//! fixed-memory RFF + frequent-directions sketch ([`crate::rff`]), or
//! a shadow pairing of both that reports projection divergence.
//! [`wal`] and [`persist`] are the durability layer: per-shard
//! CRC-framed write-ahead ingest logs plus per-stream checkpoints cut
//! at the same queue-drain barrier migration uses —
//! [`StreamRouter::checkpoint_all`] captures the pool,
//! [`StreamRouter::restore_pool`] brings it back after a crash
//! (torn log tails truncated, corrupt checkpoints quarantined, the
//! WAL suffix replayed through the normal ingest path).

pub mod drift;
pub mod engine;
pub mod metrics;
pub mod persist;
pub mod ring;
pub mod router;
pub mod server;
pub mod shard;
pub mod snapshot;
pub mod wal;

pub use drift::{DriftMonitor, DriftPoint};
pub use engine::{StreamState, StreamTier, TierParts};
pub use metrics::{
    LatencyHistogram, Metrics, MetricsReport, PoolSnapshot, ShardOccupancy, StreamGauges,
};
pub use persist::PersistConfig;
pub use ring::HashRing;
pub use router::{EnginePolicy, RoutedEngine};
pub use server::{
    BatchReply, Config, Coordinator, EngineConfig, IngestReply, KernelConfig, Snapshot,
};
pub use shard::{
    PoolConfig, RestoreReport, ShardPool, StreamConfig, StreamHandle, StreamRouter,
};
pub use snapshot::{ProjectScratch, ProjectionSnapshot, SnapshotCell};
pub use wal::{FsyncPolicy, WalRecord};
