//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once per (kind, bucket) on the
//! CPU PJRT client, and exposes typed, padded execution wrappers.
//!
//! The execution path needs the `xla` crate, which the offline image
//! does not carry; it is compiled only under `--cfg pjrt_runtime` (with
//! a vendored `xla` checkout patched in). Without the cfg, the `stub`
//! module provides the same `Runtime`/`PjrtRotate` surface: construction fails
//! cleanly, so the coordinator falls back to the native engine, and
//! `PjrtRotate` routes every rotation to the native blocked GEMM. The
//! artifact manifest and padding contract are pure Rust and always
//! compiled (they are exercised by tests and the build-time tooling).

pub mod artifact;
pub mod pad;

pub use artifact::{ArtifactMeta, Manifest};

#[cfg(pjrt_runtime)]
mod pjrt;
#[cfg(pjrt_runtime)]
pub use pjrt::{PjrtRotate, Runtime};

#[cfg(not(pjrt_runtime))]
mod stub;
#[cfg(not(pjrt_runtime))]
pub use stub::{PjrtRotate, Runtime};
