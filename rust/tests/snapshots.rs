//! Lock-free read-path integration tests: snapshot projections must
//! match the worker's (read-your-writes after `sync`), must never
//! enqueue a shard command (`worker_reads` flat while `snapshot_reads`
//! grows — the acceptance signature), must stay zero-alloc in steady
//! state through a reused [`ProjectScratch`], and must keep serving —
//! with monotonically non-decreasing epochs — while the stream migrates
//! and the pool reshards underneath the readers.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};

use common::oracle;
use inkpca::coordinator::{
    EngineConfig, KernelConfig, PoolConfig, ProjectScratch, ShardPool, StreamConfig,
};
use inkpca::data::synthetic::yeast_like;

fn stream_cfg(sigma: f64, seed_points: usize) -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma },
        mean_adjust: true,
        seed_points,
        ..StreamConfig::default()
    }
}

fn pool_cfg(shards: usize) -> PoolConfig {
    PoolConfig { shards, queue: 8, engine: EngineConfig::Native, ..PoolConfig::default() }
}

#[test]
fn snapshot_projection_matches_worker_after_sync() {
    // `sync` publishes before replying, so a snapshot read issued after
    // `sync` returns sees exactly the worker's state: same basis, same
    // centering sums, same signs — compare directly, no |abs| slack.
    for mean_adjust in [false, true] {
        let ds = oracle::std_stream(30, 901);
        let pool = ShardPool::spawn(pool_cfg(2));
        let router = pool.router();
        let cfg = StreamConfig { mean_adjust, ..stream_cfg(1.5, 6) };
        let h = router.open_stream("s", ds.dim(), cfg).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        router.sync(&h).unwrap();

        let probes: Vec<Vec<f64>> =
            (0..4).map(|i| ds.x.row(i * 5).to_vec()).collect();
        let flat: Vec<f64> = probes.iter().flatten().copied().collect();
        let batched = router.project_many(&h, &flat, 5).unwrap();
        assert_eq!(batched.len(), probes.len() * 5);
        for (b, probe) in probes.iter().enumerate() {
            let want = router.project(&h, probe.clone(), 5).unwrap();
            let snap = router.project_snapshot(&h, probe, 5).unwrap();
            assert_eq!(want.len(), snap.len());
            for (c, (w, s)) in want.iter().zip(&snap).enumerate() {
                assert!(
                    (w - s).abs() <= 1e-12,
                    "adjust={mean_adjust} probe {b} comp {c}: worker {w} vs snapshot {s}"
                );
                let m = batched[b * 5 + c];
                assert!(
                    (w - m).abs() <= 1e-12,
                    "adjust={mean_adjust} probe {b} comp {c}: worker {w} vs batched {m}"
                );
            }
        }
        pool.shutdown();
    }
}

#[test]
fn snapshot_reads_never_touch_the_worker() {
    // The ISSUE acceptance signature: snapshot-path projections must
    // not enqueue a shard command — `worker_reads` stays flat while
    // `snapshot_reads` grows.
    let ds = oracle::std_stream(24, 902);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("reads", ds.dim(), stream_cfg(1.5, 6)).unwrap();
    router.ingest_many(&h, ds.x.as_slice().to_vec()).unwrap();
    router.sync(&h).unwrap();

    let before = router.metrics(&h).unwrap();
    assert_eq!(before.worker_reads, 0);
    assert!(before.snapshot_epoch >= 1, "ingest_many + sync must have published");

    let probe = ds.x.row(0).to_vec();
    const READS: u64 = 40;
    for i in 0..READS {
        if i % 2 == 0 {
            router.project_snapshot(&h, &probe, 3).unwrap();
        } else {
            router.project_many(&h, &probe, 3).unwrap();
        }
    }
    let after = router.metrics(&h).unwrap();
    assert_eq!(after.worker_reads, 0, "snapshot reads must not reach the worker");
    assert_eq!(after.snapshot_reads, before.snapshot_reads + READS);
    assert_eq!(after.snapshot_epoch, before.snapshot_epoch, "no ingest, no new publish");
    assert_eq!(after.points_since_publish, 0, "sync captured everything");

    // One worker-path read for contrast, then the pool rollup carries
    // both counters.
    router.project(&h, probe.clone(), 3).unwrap();
    let snap = router.pool_snapshot().unwrap();
    assert_eq!(snap.worker_reads, 1);
    assert_eq!(snap.snapshot_reads, after.snapshot_reads);
    let g = snap.per_stream.iter().find(|g| g.stream == "reads").unwrap();
    assert_eq!(g.worker_reads, 1);
    assert_eq!(g.snapshot_reads, after.snapshot_reads);
    assert_eq!(g.snapshot_epoch, after.snapshot_epoch);

    // Close folds the stream's read counters into the lifetime totals.
    router.close_stream(&h).unwrap();
    let closed = router.pool_snapshot().unwrap();
    assert_eq!(closed.snapshot_reads, after.snapshot_reads);
    assert_eq!(closed.worker_reads, 1);
    pool.shutdown();
}

#[test]
fn steady_state_snapshot_reads_are_zero_realloc() {
    let ds = oracle::std_stream(28, 903);
    let pool = ShardPool::spawn(pool_cfg(1));
    let router = pool.router();
    let h = router.open_stream("warm", ds.dim(), stream_cfg(1.2, 6)).unwrap();
    router.ingest_many(&h, ds.x.as_slice().to_vec()).unwrap();
    router.sync(&h).unwrap();

    let queries: Vec<f64> = ds.x.as_slice()[..8 * ds.dim()].to_vec();
    let mut scratch = ProjectScratch::new();
    let mut out = Vec::new();
    // Warm-up sizes every buffer (kernel block, GEMM packing panels,
    // row norms, output).
    router.project_many_into(&h, &queries, 4, &mut scratch, &mut out).unwrap();
    let warm = scratch.reallocs();
    for _ in 0..100 {
        let r_eff = router.project_many_into(&h, &queries, 4, &mut scratch, &mut out).unwrap();
        assert_eq!(r_eff, 4);
    }
    assert_eq!(
        scratch.reallocs(),
        warm,
        "steady-state snapshot reads must not grow any buffer"
    );
    pool.shutdown();
}

#[test]
fn reads_error_before_first_publish_and_after_close() {
    let ds = yeast_like(12, 904);
    let pool = ShardPool::spawn(pool_cfg(1));
    let router = pool.router();
    let h = router.open_stream("gate", ds.dim(), stream_cfg(1.0, 5)).unwrap();

    // Still seeding: nothing published yet, reads fail fast.
    for i in 0..4 {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    assert_eq!(router.snapshot_epoch(&h), 0);
    let err = router.project_snapshot(&h, ds.x.row(0), 2).unwrap_err();
    assert!(err.contains("no snapshot"), "unexpected error: {err}");

    // Seed completion publishes — the read path opens.
    router.ingest(&h, ds.x.row(4).to_vec()).unwrap();
    assert!(router.snapshot_epoch(&h) >= 1);
    assert!(router.project_snapshot(&h, ds.x.row(0), 2).is_ok());

    // Malformed queries error without panicking.
    let bad = vec![0.0; ds.dim() + 1];
    assert!(router.project_snapshot(&h, &bad, 2).is_err());
    assert!(router.project_many(&h, &bad, 2).is_err());

    // Close marks the cell: stale handles get the worker's own wording.
    router.close_stream(&h).unwrap();
    let err = router.project_snapshot(&h, ds.x.row(0), 2).unwrap_err();
    assert!(err.contains("unknown or closed stream"), "unexpected error: {err}");
    assert!(router.project_many(&h, ds.x.row(0), 2).is_err());
    pool.shutdown();
}

#[test]
fn concurrent_readers_survive_migration_and_reshard() {
    // Readers hammer the snapshot path while the stream is manually
    // migrated between shards, the pool grows by a shard (ring
    // reshard + rebalance sweep), and a writer keeps batching points
    // in. Invariants: once the first snapshot is published, every read
    // succeeds, and the epoch observed by each reader never decreases
    // (the cell travels with the entry across migrations).
    let ds = oracle::std_stream(60, 905);
    let dim = ds.dim();
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("moving", dim, stream_cfg(1.5, 6)).unwrap();
    // Seed + publish before the readers start.
    router.ingest_many(&h, ds.x.as_slice()[..10 * dim].to_vec()).unwrap();
    router.sync(&h).unwrap();
    assert!(router.snapshot_epoch(&h) >= 1);

    let stop = AtomicBool::new(false);
    let probe: Vec<f64> = ds.x.row(0).to_vec();
    std::thread::scope(|scope| {
        let mut readers = Vec::new();
        for _ in 0..4 {
            let r = router.clone();
            let hc = h.clone();
            let stop = &stop;
            let probe = &probe;
            readers.push(scope.spawn(move || {
                let mut scratch = ProjectScratch::new();
                let mut out = Vec::new();
                let mut last_epoch = 0u64;
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let e = r.snapshot_epoch(&hc);
                    assert!(e >= last_epoch, "epoch went backwards: {last_epoch} -> {e}");
                    last_epoch = e;
                    r.project_many_into(&hc, probe, 3, &mut scratch, &mut out)
                        .unwrap_or_else(|err| panic!("read failed mid-reshard: {err}"));
                    reads += 1;
                }
                reads
            }));
        }

        // Writer + topology churn on the main thread.
        let mut next = 10;
        let grown = router.add_shard().unwrap();
        assert_eq!(grown, 2, "fresh pool of 2 grows into shard id 2");
        let mut step = 0;
        while next < ds.n() {
            let end = (next + 5).min(ds.n());
            router
                .ingest_many(&h, ds.x.as_slice()[next * dim..end * dim].to_vec())
                .unwrap();
            // Cycle the stream over every worker; landing on its
            // current shard is a documented no-op, the rest are real
            // drain-barrier migrations under the readers.
            router.migrate_stream(&h, step % router.shards()).unwrap();
            step += 1;
            next = end;
        }
        router.rebalance().unwrap();
        router.sync(&h).unwrap();
        stop.store(true, Ordering::Relaxed);
        let total: u64 = readers.into_iter().map(|j| j.join().unwrap()).sum();
        assert!(total > 0, "readers never got a read in");
    });

    // After the dust settles the snapshot still matches the worker.
    router.sync(&h).unwrap();
    let want = router.project(&h, probe.clone(), 3).unwrap();
    let got = router.project_snapshot(&h, &probe, 3).unwrap();
    for (w, g) in want.iter().zip(&got) {
        assert!((w - g).abs() <= 1e-12, "post-reshard: worker {w} vs snapshot {g}");
    }
    let snap = router.pool_snapshot().unwrap();
    assert!(snap.migrations > 0, "the stream should actually have moved");
    assert!(snap.snapshot_reads > 0);
    pool.shutdown();
}
