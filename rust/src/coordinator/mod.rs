//! Layer-3 streaming coordinator: a sharded multi-stream engine.
//! [`shard`] owns the machinery — a [`ShardPool`] of worker threads
//! (each holding slot-indexed per-stream eigenstate, a shared rotation
//! engine, and per-stream metrics) fronted by a stream-keyed
//! [`StreamRouter`] over per-shard bounded channels (backpressure is
//! per shard). [`StreamRouter::open_stream`] resolves a stream id to a
//! cheap [`StreamHandle`] once; the data-path verbs — rendezvous
//! `ingest`, fire-and-forget `ingest_async` (+ `sync` error drain), and
//! batched `ingest_many` — then address by slot with no per-command
//! string. [`server`] keeps the historical single-stream
//! [`Coordinator`] API as a thin wrapper over a 1-shard pool. [`drift`]
//! measures live reconstruction error; [`metrics`] holds the per-stream
//! histograms/gauges and the pool-level rollup; [`router`] routes each
//! rank-one back-rotation to the native GEMM or the AOT PJRT engine.

pub mod drift;
pub mod metrics;
pub mod router;
pub mod server;
pub mod shard;

pub use drift::{DriftMonitor, DriftPoint};
pub use metrics::{
    LatencyHistogram, Metrics, MetricsReport, PoolSnapshot, StreamGauges,
};
pub use router::{EnginePolicy, RoutedEngine};
pub use server::{
    BatchReply, Config, Coordinator, EngineConfig, IngestReply, KernelConfig, Snapshot,
};
pub use shard::{PoolConfig, ShardPool, StreamConfig, StreamHandle, StreamRouter};
