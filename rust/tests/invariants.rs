//! Property-based invariant suite (in-tree driver, DESIGN.md §7): the
//! algebraic guarantees the paper's algorithms rest on, checked over
//! randomized streams, kernels and sizes.

use inkpca::data::synthetic::{magic_like, yeast_like};
use inkpca::kernels::{Kernel, Laplacian, Polynomial, Rbf};
use inkpca::kpca::IncrementalKpca;
use inkpca::linalg::{eigvalsh, orthogonality_defect, Mat};
use inkpca::nystrom::IncrementalNystrom;
use inkpca::util::prop::{check, close, default_cases, ensure};
use inkpca::util::Rng;

fn random_kernel(rng: &mut Rng) -> Box<dyn Kernel> {
    match rng.below(3) {
        0 => Box::new(Rbf { sigma: rng.range(0.5, 4.0) }),
        1 => Box::new(Laplacian { sigma: rng.range(0.5, 4.0) }),
        _ => Box::new(Polynomial { degree: 2, offset: rng.range(0.5, 2.0) }),
    }
}

fn random_dataset(rng: &mut Rng, n: usize) -> Mat {
    let mut ds = if rng.uniform() < 0.5 { yeast_like(n, rng.next_u64()) } else {
        magic_like(n, rng.next_u64())
    };
    ds.standardize();
    ds.x
}

#[test]
fn prop_incremental_reproduces_batch_any_kernel_any_order() {
    check("incremental==batch", default_cases().min(16), |rng| {
        let n = 8 + rng.below(14);
        let seed_n = 2 + rng.below(4);
        let x = random_dataset(rng, n);
        let kern = random_kernel(rng);
        let adjust = rng.uniform() < 0.5;
        let seed = x.submatrix(seed_n, x.cols());
        let mut inc = IncrementalKpca::from_batch(kern.as_ref(), &seed, adjust)
            .map_err(|e| e.to_string())?;
        for i in seed_n..n {
            inc.push(x.row(i)).map_err(|e| e.to_string())?;
        }
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        ensure(drift < 1e-6, || format!("kernel {} drift {drift}", kern.name()))
    });
}

#[test]
fn prop_eigenvalues_sorted_nonnegative_psd_kernels() {
    check("psd-spectrum", 12, |rng| {
        let n = 6 + rng.below(10);
        let x = random_dataset(rng, n);
        let kern = Rbf { sigma: rng.range(0.5, 3.0) };
        let seed = x.submatrix(3, x.cols());
        let mut inc =
            IncrementalKpca::from_batch(&kern, &seed, true).map_err(|e| e.to_string())?;
        for i in 3..n {
            inc.push(x.row(i)).map_err(|e| e.to_string())?;
            for w in inc.vals.windows(2) {
                ensure(w[0] <= w[1] + 1e-12, || "unsorted eigenvalues".to_string())?;
            }
            // PSD up to method drift: the centered Gram has an exact
            // zero eigenvalue; sequential rank-one updates resolve it to
            // within the drift the paper's Fig. 1 measures (~1e-6
            // relative on pathological clustered spectra, e.g. a
            // near-identity kernel matrix from an unsuited bandwidth).
            let scale = inc.vals.last().copied().unwrap_or(1.0).max(1.0);
            ensure(inc.vals[0] > -1e-4 * scale, || {
                format!("negative eigenvalue {} (scale {scale})", inc.vals[0])
            })?;
        }
        Ok(())
    });
}

#[test]
fn prop_orthogonality_bounded_over_long_streams() {
    check("orthogonality", 6, |rng| {
        let n = 30 + rng.below(20);
        let x = random_dataset(rng, n);
        let kern = Rbf { sigma: rng.range(1.0, 3.0) };
        let seed = x.submatrix(10, x.cols());
        let mut inc =
            IncrementalKpca::from_batch(&kern, &seed, rng.uniform() < 0.5)
                .map_err(|e| e.to_string())?;
        for i in 10..n {
            inc.push(x.row(i)).map_err(|e| e.to_string())?;
        }
        let defect = orthogonality_defect(&inc.vecs);
        ensure(defect < 1e-7, || format!("orthogonality defect {defect}"))
    });
}

#[test]
fn prop_nystrom_incremental_equals_batch_every_m() {
    check("nystrom==batch", 8, |rng| {
        let n = 15 + rng.below(15);
        let x = random_dataset(rng, n);
        let kern = Rbf { sigma: rng.range(0.5, 3.0) };
        let mut inys =
            IncrementalNystrom::new(&kern, x.clone()).map_err(|e| e.to_string())?;
        let order = rng.permutation(n);
        let m_max = 4 + rng.below(6);
        for &idx in order.iter().take(m_max) {
            if !inys.add_point(idx).map_err(|e| e.to_string())? {
                continue;
            }
            let batch = inkpca::nystrom::BatchNystrom::fit(&kern, &x, &inys.subset)
                .map_err(|e| e.to_string())?;
            let diff = inys.approx_gram().max_abs_diff(&batch.approx_gram());
            ensure(diff < 1e-6, || format!("m={} diff {diff}", inys.m()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_nystrom_residual_psd() {
    // K − K̃ is a Schur complement: eigenvalues ≥ −tol at any m.
    check("nystrom-residual-psd", 8, |rng| {
        let n = 12 + rng.below(10);
        let x = random_dataset(rng, n);
        let kern = Rbf { sigma: rng.range(0.5, 3.0) };
        let k = inkpca::kernels::gram(&kern, &x);
        let mut inys =
            IncrementalNystrom::new(&kern, x.clone()).map_err(|e| e.to_string())?;
        for i in 0..4 + rng.below(4) {
            inys.add_point(i).map_err(|e| e.to_string())?;
        }
        let diff = k.sub(&inys.approx_gram());
        let vals = eigvalsh(&diff).map_err(|e| e.to_string())?;
        ensure(vals[0] > -1e-7, || format!("residual not PSD: λmin {}", vals[0]))
    });
}

#[test]
fn prop_trace_identity_after_updates() {
    // trace(K') is preserved exactly by the eigensystem: Σλ = tr(K').
    check("trace-identity", 10, |rng| {
        let n = 8 + rng.below(10);
        let x = random_dataset(rng, n);
        let kern = Rbf { sigma: rng.range(0.5, 3.0) };
        let seed = x.submatrix(4, x.cols());
        let mut inc =
            IncrementalKpca::from_batch(&kern, &seed, true).map_err(|e| e.to_string())?;
        for i in 4..n {
            inc.push(x.row(i)).map_err(|e| e.to_string())?;
        }
        let tr_eig: f64 = inc.vals.iter().sum();
        let kref = inc.batch_reference();
        let tr_mat: f64 = (0..kref.rows()).map(|i| kref[(i, i)]).sum();
        close("trace", tr_eig, tr_mat, 1e-9)
    });
}

#[test]
fn prop_projection_isometry_on_training_points() {
    // Σᵢ score(xⱼ, i)² over all components = K'(j,j) (Parseval in the
    // feature space spanned by the data).
    check("projection-parseval", 6, |rng| {
        let n = 8 + rng.below(6);
        let x = random_dataset(rng, n);
        let kern = Rbf { sigma: rng.range(1.0, 3.0) };
        let batch = inkpca::kpca::BatchKpca::fit(&kern, &x, true).map_err(|e| e.to_string())?;
        let k = inkpca::kernels::gram(&kern, &x);
        let j = rng.below(n);
        let scores = inkpca::kpca::project_point(
            &kern,
            &x,
            &batch.values,
            &batch.vectors,
            Some(&k),
            x.row(j),
            n,
        );
        let sum_sq: f64 = scores.iter().map(|s| s * s).sum();
        close("parseval", sum_sq, batch.k_used[(j, j)], 1e-7)
    });
}
