//! Projection of new points onto kernel principal components (§2.2):
//! the feature-space eigenvector is `vᵢ = Φᵀuᵢ/√λᵢ`, so the score of a
//! point `y` on component `i` is `⟨φ(y), vᵢ⟩ = (uᵢᵀ k_y)/√λᵢ` with
//! `k_y[j] = k(xⱼ, y)` (centered consistently when the model is
//! mean-adjusted).

use crate::kernels::{kernel_column, Kernel};
use crate::linalg::{Mat, MatView};

use super::centering::center_column;
use super::incremental::IncrementalKpca;

/// Project `y` onto the top `r` principal components of a fitted
/// eigensystem over training data `x` with (adjusted) eigenpairs
/// `(vals ascending, vecs)` — `vecs` is anything viewable as a matrix
/// (`&Mat`, a batch model's vectors, or an incremental state's
/// `EigenBasis`). `k` is the *uncentered* training Gram matrix, needed
/// for centering the new column; pass `None` when the model is
/// unadjusted.
pub fn project_point<'v>(
    kernel: &dyn Kernel,
    x: &Mat,
    vals: &[f64],
    vecs: impl Into<MatView<'v>>,
    k_uncentered: Option<&Mat>,
    y: &[f64],
    r: usize,
) -> Vec<f64> {
    let vecs = vecs.into();
    let m = x.rows();
    let ky = kernel_column(kernel, x, m, y);
    let col = match k_uncentered {
        Some(k) => center_column(k, &ky),
        None => ky,
    };
    score_top_r(vals, vecs, &col, r)
}

/// Scores of a (centered) kernel column on the top `r` components:
/// `(uᵢᵀ col)/√λᵢ`, top components at the END of the ascending order.
fn score_top_r(vals: &[f64], vecs: MatView<'_>, col: &[f64], r: usize) -> Vec<f64> {
    let n = vals.len();
    let m = col.len();
    let r = r.min(n);
    let mut scores = Vec::with_capacity(r);
    for c in 0..r {
        let idx = n - 1 - c;
        let lam = vals[idx];
        if lam <= 1e-12 {
            scores.push(0.0);
            continue;
        }
        let mut dot = 0.0;
        for j in 0..m {
            dot += vecs[(j, idx)] * col[j];
        }
        scores.push(dot / lam.sqrt());
    }
    scores
}

impl<'k> IncrementalKpca<'k> {
    /// Project a new point onto the current top-`r` components in
    /// `O(m·(d + r))`: the mean-adjusted centering reuses the
    /// incrementally maintained sums `Σₘ`/`Kₘ𝟙` (`centering_sums`)
    /// instead of recomputing the `O(m²)` uncentered Gram per query —
    /// the centered column is `k_y − Kₘ𝟙/m − mean(k_y)·𝟙 + Σₘ/m²·𝟙`.
    pub fn project(&self, y: &[f64], r: usize) -> Vec<f64> {
        assert_eq!(y.len(), self.dim(), "project: query dimension mismatch");
        let m = self.len();
        let kernel = self.kernel_ref();
        let mut col: Vec<f64> = (0..m).map(|i| kernel.eval(self.row(i), y)).collect();
        if self.mean_adjust && m > 0 {
            let (s, k1) = self.centering_sums();
            let mf = m as f64;
            let ky_mean = col.iter().sum::<f64>() / mf;
            let total_mean = s / (mf * mf);
            for (c, k1i) in col.iter_mut().zip(k1) {
                *c += total_mean - k1i / mf - ky_mean;
            }
        }
        score_top_r(&self.vals, self.vecs.view(), &col, r)
    }

    /// Reference scoring path: recompute the uncentered Gram and center
    /// the query column against it (`O(m²)` kernel evaluations) — the
    /// pre-cache behaviour, kept to validate [`IncrementalKpca::project`]
    /// against (the two must agree to ~1e-12).
    pub fn project_recomputed(&self, y: &[f64], r: usize) -> Vec<f64> {
        let x = self.data();
        let k = if self.mean_adjust {
            Some(crate::kernels::gram(self.kernel_ref(), &x))
        } else {
            None
        };
        project_point(self.kernel_ref(), &x, &self.vals, &self.vecs, k.as_ref(), y, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::{gram, Rbf};
    use crate::kpca::batch::BatchKpca;

    /// Projections of training points must reproduce the eigen-scores:
    /// projecting xⱼ on component i gives √λᵢ · uᵢⱼ.
    #[test]
    fn training_point_projection_consistency() {
        let ds = yeast_like(12, 1);
        let kern = Rbf { sigma: 1.0 };
        let model = BatchKpca::fit(&kern, &ds.x, false).unwrap();
        let n = ds.n();
        let y = ds.x.row(4);
        let scores = project_point(&kern, &ds.x, &model.values, &model.vectors, None, y, 3);
        for c in 0..3 {
            let idx = n - 1 - c;
            let expect = model.values[idx].sqrt() * model.vectors[(4, idx)];
            assert!(
                (scores[c] - expect).abs() < 1e-9,
                "component {c}: {} vs {expect}",
                scores[c]
            );
        }
    }

    #[test]
    fn centered_projection_consistency() {
        let ds = yeast_like(10, 2);
        let kern = Rbf { sigma: 1.0 };
        let model = BatchKpca::fit(&kern, &ds.x, true).unwrap();
        let k = gram(&kern, &ds.x);
        let y = ds.x.row(7);
        let scores =
            project_point(&kern, &ds.x, &model.values, &model.vectors, Some(&k), y, 2);
        let n = ds.n();
        for c in 0..2 {
            let idx = n - 1 - c;
            let expect = model.values[idx].sqrt() * model.vectors[(7, idx)];
            assert!((scores[c] - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn incremental_projection_matches_batch() {
        let ds = yeast_like(14, 3);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut inc =
            crate::kpca::IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 6..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        let batch = BatchKpca::fit(&kern, &ds.x, true).unwrap();
        let k = gram(&kern, &ds.x);
        let probe = vec![0.4; ds.dim()];
        let si = inc.project(&probe, 3);
        let sb =
            project_point(&kern, &ds.x, &batch.values, &batch.vectors, Some(&k), &probe, 3);
        for (a, b) in si.iter().zip(sb.iter()) {
            // Eigenvector sign is arbitrary — compare magnitudes.
            assert!((a.abs() - b.abs()).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn cached_centering_matches_recomputed_path() {
        // The O(m·r) path (incrementally maintained Σₘ/Kₘ𝟙) must agree
        // with the O(m²) recompute-the-Gram path to ≤1e-12, both
        // adjusted and unadjusted, on seeded + streamed states.
        for adjust in [true, false] {
            let ds = yeast_like(22, 11);
            let kern = Rbf { sigma: 1.3 };
            let seed = ds.x.submatrix(6, ds.dim());
            let mut inc =
                crate::kpca::IncrementalKpca::from_batch(&kern, &seed, adjust).unwrap();
            for i in 6..ds.n() {
                inc.push(ds.x.row(i)).unwrap();
            }
            for probe_seed in 0..3 {
                let probe: Vec<f64> =
                    (0..ds.dim()).map(|j| 0.2 * ((j + probe_seed) as f64).sin()).collect();
                let fast = inc.project(&probe, 5);
                let slow = inc.project_recomputed(&probe, 5);
                assert_eq!(fast.len(), slow.len());
                for (a, b) in fast.iter().zip(slow.iter()) {
                    assert!(
                        (a - b).abs() < 1e-12,
                        "adjust={adjust}: cached {a} vs recomputed {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_eigenvalue_components_score_zero() {
        let ds = yeast_like(6, 4);
        let kern = Rbf { sigma: 1.0 };
        let model = BatchKpca::fit(&kern, &ds.x, true).unwrap();
        let k = gram(&kern, &ds.x);
        let scores = project_point(
            &kern,
            &ds.x,
            &model.values,
            &model.vectors,
            Some(&k),
            ds.x.row(0),
            6,
        );
        // The centered Gram has rank ≤ n−1: the last component is null.
        assert_eq!(scores.len(), 6);
        assert_eq!(scores[5], 0.0);
    }
}
