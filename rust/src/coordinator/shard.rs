//! Sharded multi-stream coordinator: a [`ShardPool`] of worker threads,
//! each owning slot-indexed per-stream state, fronted by a stream-keyed
//! [`StreamRouter`] that hands out resolved [`StreamHandle`]s.
//!
//! # Design
//!
//! **Pinning.** Every stream id is hashed (FNV-1a, deterministic within
//! and across processes) and pinned to `hash % shards` for its whole
//! life. All commands for a stream therefore serialize through one
//! worker — per-stream state needs no locks, and the paper's rank-one
//! hot path (workspace + eigenbasis, allocation-free once warm, PR 1)
//! runs untouched inside the shard. Streams only ever contend with the
//! *other streams of their own shard*.
//!
//! **Resolved handles.** [`StreamRouter::open_stream`] resolves the
//! stream→shard hash and the shard-local storage slot *once* and
//! returns a cheap [`StreamHandle`] (shard index + integer slot +
//! generation + `Arc<str>` id). Every subsequent command addresses the
//! stream by slot — no per-command `String` allocation and no
//! `HashMap` lookup on the ingest path. The worker keeps its streams in
//! a slot-indexed `Vec<Option<StreamEntry>>`; the name map exists only
//! for open (duplicate check) and close (removal). Slots are reused
//! after close with a bumped generation, so a stale handle can never
//! address a stream that replaced the one it named.
//!
//! **Backpressure.** Each shard has its own *bounded* command channel
//! (`PoolConfig::queue` deep). Producers of a hot shard block on that
//! shard's queue without slowing streams pinned elsewhere. Three ingest
//! shapes share it: rendezvous [`StreamRouter::ingest`] (one reply per
//! point), fire-and-forget [`StreamRouter::ingest_async`] (reply-less;
//! errors land in a per-stream counter and the *first* deferred error
//! message is surfaced by the next [`StreamRouter::sync`]), and batched
//! [`StreamRouter::ingest_many`] (one command and one reply per batch —
//! the per-point channel round-trip amortizes across the batch, the
//! worker computes the batch's kernel rows as one blocked GEMM via
//! [`IncrementalKpca::push_batch_with`], and the batch's rank-one
//! back-rotations fold into a single fused engine GEMM — the blocked
//! rank-b update, whose per-stream `engine_gemms` gauge the pool
//! snapshot rolls up). Streams opened with
//! [`StreamConfig::expected_m`]/[`StreamConfig::expected_batch`] are
//! pre-sized once at initialization, so their whole streamed life is
//! allocation-silent.
//!
//! **Shared immutable resources.** One [`RoutedEngine`] (and, when
//! configured, one PJRT runtime — it is not `Send`, so it must be built
//! inside the worker thread) exists *per shard*, not per stream: the
//! engine is stateless apart from its dispatch counters, so all streams
//! of a shard share it. Per-stream state owns its kernel through an
//! `Arc` handed to [`IncrementalKpca::from_batch_shared`] — closing a
//! stream frees its kernel.
//!
//! **Metrics aggregation.** Each stream entry keeps its own
//! [`Metrics`] (latency histograms + counters + hot-path gauges).
//! [`StreamRouter::pool_snapshot`] asks every shard for a rollup —
//! counters summed, histograms merged bucket-wise, engine dispatch
//! counts added — and returns one [`PoolSnapshot`] with the per-stream
//! [`StreamGauges`] attached for attribution.

use std::collections::HashMap;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::kernels::{median_heuristic, Kernel};
use crate::kpca::{BatchRotation, IncrementalKpca, KpcaStats};
use crate::linalg::Mat;

use super::drift::{DriftMonitor, DriftPoint};
use super::metrics::{LatencyHistogram, Metrics, MetricsReport, PoolSnapshot, StreamGauges};
use super::router::RoutedEngine;
use super::server::{BatchReply, EngineConfig, IngestReply, KernelConfig, Snapshot};

/// Per-stream configuration (what used to be the per-coordinator
/// `Config`, minus the pool-level engine/queue knobs).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub kernel: KernelConfig,
    pub mean_adjust: bool,
    /// Seed examples accumulated before the batch initialization.
    pub seed_points: usize,
    /// Drift measurement cadence (accepted points; 0 = off).
    pub drift_every: usize,
    /// Expected steady-state eigensystem size. When > 0 (or
    /// `expected_batch` > 0) the worker calls
    /// [`IncrementalKpca::reserve`] the moment the stream's eigensystem
    /// is built — every hot-path buffer is pre-sized once, instead of
    /// growing across the first batches.
    pub expected_m: usize,
    /// Expected ingest batch size for the same reserve call.
    pub expected_batch: usize,
    /// Batched back-rotation strategy for this stream's `ingest_many`
    /// commands; `None` keeps the library's auto rule (fused for real
    /// batches). Forcing [`BatchRotation::Sequential`] is how the
    /// fused-vs-sequential bench series isolates the amortization.
    pub batch_rotation: Option<BatchRotation>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            kernel: KernelConfig::RbfMedian,
            mean_adjust: true,
            seed_points: 20,
            drift_every: 0,
            expected_m: 0,
            expected_batch: 0,
            batch_rotation: None,
        }
    }
}

/// Pool-level configuration: shard/queue topology and the (per-shard)
/// rotation engine.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Worker threads; streams are pinned by stream-id hash.
    pub shards: usize,
    /// Bounded command-queue depth *per shard* (ingest backpressure).
    pub queue: usize,
    /// Rotation engine, instantiated once per shard worker.
    pub engine: EngineConfig,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { shards: 1, queue: 64, engine: EngineConfig::Native }
    }
}

/// Resolved address of an open stream: pinned shard, storage slot in
/// that shard's worker, the slot generation (guards against reuse after
/// close), and the shared id for attribution. Cheap to clone
/// (`Arc<str>` bump); commands built from a handle carry two integers
/// instead of an owned `String`.
#[derive(Clone, Debug)]
pub struct StreamHandle {
    shard: usize,
    slot: u32,
    gen: u32,
    id: Arc<str>,
}

impl StreamHandle {
    /// The stream id this handle was opened with.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// The shard the stream is pinned to.
    pub fn shard(&self) -> usize {
        self.shard
    }
}

enum ShardCommand {
    Open {
        stream: Arc<str>,
        dim: usize,
        cfg: StreamConfig,
        reply: SyncSender<Result<(u32, u32), String>>,
    },
    Ingest {
        slot: u32,
        gen: u32,
        x: Vec<f64>,
        reply: SyncSender<Result<IngestReply, String>>,
    },
    /// Fire-and-forget ingest: no reply channel. Failures increment the
    /// stream's error counters; the first deferred message surfaces on
    /// the next `Sync`.
    IngestAsync {
        slot: u32,
        gen: u32,
        x: Vec<f64>,
    },
    /// One command per batch: `xs` is `b × dim` row-major.
    IngestMany {
        slot: u32,
        gen: u32,
        xs: Vec<f64>,
        reply: SyncSender<Result<BatchReply, String>>,
    },
    /// Barrier + deferred-error drain for async ingest.
    Sync {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<u64, String>>,
    },
    Project {
        slot: u32,
        gen: u32,
        x: Vec<f64>,
        r: usize,
        reply: SyncSender<Result<Vec<f64>, String>>,
    },
    MeasureDrift {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<DriftPoint, String>>,
    },
    Snapshot {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<Snapshot, String>>,
    },
    Metrics {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<MetricsReport, String>>,
    },
    Close {
        slot: u32,
        gen: u32,
        reply: SyncSender<Result<KpcaStats, String>>,
    },
    Rollup {
        reply: SyncSender<ShardRollup>,
    },
    Shutdown,
}

/// Per-shard aggregation answered to `Rollup` (internal wire format;
/// the router folds these into one [`PoolSnapshot`]).
struct ShardRollup {
    streams: usize,
    accepted: u64,
    excluded: u64,
    errors: u64,
    total_ws_bytes: u64,
    ws_engine_gemms: u64,
    ingest: LatencyHistogram,
    project: LatencyHistogram,
    engine_calls: (u64, u64),
    gauges: Vec<StreamGauges>,
}

/// Lifetime totals of streams already closed on this shard: folded into
/// every rollup so pool-level counters stay *monotonic* across stream
/// churn (closing a stream must not erase its history from the pool).
/// Residency gauges are deliberately not kept — closed streams hold no
/// bytes. `orphans` counts commands addressed to dead slots (stale
/// handles); with no live entry to attribute them to, they live here.
#[derive(Default)]
struct ClosedTotals {
    accepted: u64,
    excluded: u64,
    errors: u64,
    orphans: u64,
    engine_gemms: u64,
    ingest: LatencyHistogram,
    project: LatencyHistogram,
}

impl ClosedTotals {
    fn absorb(&mut self, m: &Metrics) {
        self.accepted += m.accepted;
        self.excluded += m.excluded;
        self.errors += m.errors;
        self.engine_gemms += m.engine_gemms;
        self.ingest.merge(&m.ingest_latency);
        self.project.merge(&m.project_latency);
    }
}

/// Build the kernel a stream entry owns (shared ownership — freed with
/// the stream, never leaked).
fn build_kernel(cfg: &KernelConfig, seed: &Mat) -> Arc<dyn Kernel> {
    match cfg {
        KernelConfig::Rbf { sigma } => Arc::new(crate::kernels::Rbf { sigma: *sigma }),
        KernelConfig::RbfMedian => {
            let sigma = median_heuristic(seed, 500);
            Arc::new(crate::kernels::Rbf { sigma })
        }
        KernelConfig::Linear => Arc::new(crate::kernels::Linear),
        KernelConfig::Polynomial { degree, offset } => {
            Arc::new(crate::kernels::Polynomial { degree: *degree, offset: *offset })
        }
        KernelConfig::Laplacian { sigma } => {
            Arc::new(crate::kernels::Laplacian { sigma: *sigma })
        }
    }
}

/// Build the shard's shared rotation engine. The PJRT runtime is not
/// `Send`, so this runs inside the worker thread — one runtime per
/// worker, shared by all streams pinned to it.
fn build_engine(cfg: &EngineConfig) -> RoutedEngine {
    match cfg {
        EngineConfig::Native => RoutedEngine::native_only(),
        EngineConfig::Pjrt { dir, policy } => {
            match crate::runtime::Runtime::new(std::path::Path::new(dir)) {
                Ok(rt) => RoutedEngine::with_pjrt(
                    crate::runtime::PjrtRotate::new(std::sync::Arc::new(rt)),
                    policy.clone(),
                ),
                Err(e) => {
                    eprintln!("shard: pjrt unavailable ({e}); using native engine");
                    RoutedEngine::native_only()
                }
            }
        }
    }
}

/// All state of one stream, owned by exactly one shard worker:
/// the incremental eigensystem (which itself owns the kernel, the
/// update workspace and the eigenbasis), the drift monitor, and the
/// per-stream metrics. Stored in its shard's slot vector; `gen` must
/// match the addressing handle's generation.
struct StreamEntry {
    id: Arc<str>,
    gen: u32,
    cfg: StreamConfig,
    dim: usize,
    seed_buf: Vec<f64>,
    seeded: usize,
    state: Option<IncrementalKpca<'static>>,
    drift: DriftMonitor,
    metrics: Metrics,
    /// First error deferred by fire-and-forget ingest, surfaced (and
    /// cleared) by the next `Sync`.
    pending_error: Option<String>,
}

impl StreamEntry {
    fn new(id: Arc<str>, gen: u32, dim: usize, cfg: StreamConfig) -> StreamEntry {
        let drift = DriftMonitor::new(cfg.drift_every);
        StreamEntry {
            id,
            gen,
            cfg,
            dim,
            seed_buf: Vec::new(),
            seeded: 0,
            state: None,
            drift,
            metrics: Metrics::default(),
            pending_error: None,
        }
    }

    fn min_seed(&self) -> usize {
        if self.cfg.mean_adjust {
            self.cfg.seed_points.max(2)
        } else {
            self.cfg.seed_points.max(1)
        }
    }

    /// Buffer one point toward the seed batch; initializes the
    /// eigensystem when the seed quota is reached.
    fn seed_point(&mut self, x: &[f64]) -> Result<IngestReply, String> {
        self.seed_buf.extend_from_slice(x);
        self.seeded += 1;
        if self.seeded < self.min_seed() {
            return Ok(IngestReply { accepted: true, m: self.seeded, seeding: true });
        }
        let seed = Mat::from_vec(self.seeded, self.dim, self.seed_buf.clone());
        let kernel = build_kernel(&self.cfg.kernel, &seed);
        match IncrementalKpca::from_batch_shared(kernel, &seed, self.cfg.mean_adjust) {
            Ok(mut st) => {
                st.batch_rotation = self.cfg.batch_rotation;
                // Warm the entry per the open-time expectations: one
                // reserve here replaces incremental growth across the
                // stream's first batches (ROADMAP "per-stream reserve
                // through the coordinator").
                if self.cfg.expected_m > 0 || self.cfg.expected_batch > 0 {
                    st.reserve(
                        self.cfg.expected_m.max(self.seeded),
                        self.cfg.expected_batch,
                    );
                }
                // The batch init allocated the full eigensystem +
                // workspace — publish the residency gauges now, not
                // only after the first post-seed push.
                self.state = Some(st);
                self.refresh_gauges();
                Ok(IngestReply { accepted: true, m: self.seeded, seeding: false })
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    /// Refresh the per-stream hot-path gauges from the eigensystem:
    /// update count, resident bytes and growth events across the
    /// rank-one workspace, the eigenbasis *and* the batched-ingest
    /// scratch — batched streams' kernel-block memory must be visible
    /// to the pool rollup too.
    fn refresh_gauges(&mut self) {
        let st = self.state.as_ref().expect("gauges need an initialized stream");
        self.metrics.updates = st.stats.updates as u64;
        self.metrics.ws_bytes_resident =
            (st.hot_path_bytes() + st.batch_bytes_resident()) as u64;
        self.metrics.ws_reallocs = st.hot_path_reallocs() + st.batch_reallocs();
        self.metrics.engine_gemms = st.engine_gemms();
    }

    fn ingest(&mut self, x: &[f64], engine: &RoutedEngine) -> Result<IngestReply, String> {
        if x.len() != self.dim {
            self.metrics.errors += 1;
            return Err(format!("dimension mismatch: got {}, want {}", x.len(), self.dim));
        }
        if self.state.is_none() {
            return self.seed_point(x);
        }
        let st = self.state.as_mut().unwrap();
        match st.push_with(x, engine) {
            Ok(accepted) => {
                if accepted {
                    self.metrics.accepted += 1;
                    self.drift.on_accept(st);
                } else {
                    self.metrics.excluded += 1;
                }
                let m = st.len();
                self.refresh_gauges();
                Ok(IngestReply { accepted, m, seeding: false })
            }
            Err(e) => {
                self.metrics.errors += 1;
                Err(e)
            }
        }
    }

    /// Batched ingest: points still owed to the seed buffer are
    /// consumed one by one (they are cheap copies); the remainder goes
    /// through the eigensystem's blocked batch entry point in one call.
    /// On `Err`, points before the failure remain applied.
    fn ingest_many(&mut self, xs: &[f64], engine: &RoutedEngine) -> Result<BatchReply, String> {
        if self.dim == 0 || xs.len() % self.dim != 0 {
            self.metrics.errors += 1;
            return Err(format!(
                "batch length {} is not a multiple of dim {}",
                xs.len(),
                self.dim
            ));
        }
        let b = xs.len() / self.dim;
        let mut reply = BatchReply::default();
        let mut off = 0;
        while self.state.is_none() && off < b {
            self.seed_point(&xs[off * self.dim..(off + 1) * self.dim])?;
            reply.seeded += 1;
            off += 1;
        }
        if off < b {
            let st = self.state.as_mut().unwrap();
            let result = st.push_batch_with(&xs[off * self.dim..], engine);
            // The accepted prefix stays applied even on `Err` (the mask
            // covers exactly the processed points) — counters, drift
            // cadence and gauges must track it either way, or `m` would
            // permanently outrun the accounting after one bad batch.
            let accepted = st.last_batch_mask().iter().filter(|&&ok| ok).count();
            let excluded = st.last_batch_mask().len() - accepted;
            self.metrics.accepted += accepted as u64;
            self.metrics.excluded += excluded as u64;
            self.drift.on_accept_many(accepted, st);
            self.refresh_gauges();
            match result {
                Ok(_) => {
                    reply.accepted = accepted;
                    reply.excluded = excluded;
                }
                Err(e) => {
                    self.metrics.errors += 1;
                    return Err(e);
                }
            }
        }
        reply.m = self.state.as_ref().map(|s| s.len()).unwrap_or(self.seeded);
        Ok(reply)
    }

    fn project(&self, x: &[f64], r: usize) -> Result<Vec<f64>, String> {
        match (&self.state, x.len() == self.dim) {
            (Some(st), true) => Ok(st.project(x, r)),
            (Some(_), false) => Err("dimension mismatch".to_string()),
            (None, _) => Err("not initialized (still seeding)".to_string()),
        }
    }

    fn measure_drift(&mut self) -> Result<DriftPoint, String> {
        match &self.state {
            Some(st) => Ok(self.drift.measure(st)),
            None => Err("not initialized".to_string()),
        }
    }

    fn kernel_name(&self) -> &'static str {
        match &self.state {
            Some(st) => st.kernel_ref().name(),
            None => self.cfg.kernel.name(),
        }
    }

    fn snapshot(&self, engine_calls: (u64, u64)) -> Snapshot {
        match &self.state {
            Some(st) => Snapshot {
                m: st.len(),
                dim: self.dim,
                kernel: self.kernel_name(),
                top_values: st.vals.iter().rev().take(10).copied().collect(),
                stats: st.stats,
                drift: self.drift.latest().copied(),
                engine_calls,
            },
            None => Snapshot {
                m: self.seeded,
                dim: self.dim,
                kernel: self.kernel_name(),
                top_values: Vec::new(),
                stats: KpcaStats::default(),
                drift: None,
                engine_calls,
            },
        }
    }

    fn gauges(&self, shard: usize) -> StreamGauges {
        StreamGauges {
            stream: self.id.to_string(),
            shard,
            m: self.state.as_ref().map(|s| s.len()).unwrap_or(self.seeded),
            ws_bytes_resident: self.metrics.ws_bytes_resident,
            ws_reallocs: self.metrics.ws_reallocs,
            reallocs_per_update: self.metrics.reallocs_per_update(),
            engine_gemms: self.metrics.engine_gemms,
            drift_frobenius: self.drift.latest().map(|d| d.norms.frobenius),
        }
    }

    fn final_stats(self) -> KpcaStats {
        self.state.map(|s| s.stats).unwrap_or_default()
    }
}

/// Shard-local stream storage: slot-indexed entries (the ingest path
/// addresses by integer), a name map used only at open/close, and the
/// free list for slot reuse.
#[derive(Default)]
struct SlotTable {
    slots: Vec<Option<StreamEntry>>,
    names: HashMap<Arc<str>, u32>,
    free: Vec<u32>,
    next_gen: u32,
}

impl SlotTable {
    fn open(
        &mut self,
        stream: Arc<str>,
        dim: usize,
        cfg: StreamConfig,
    ) -> Result<(u32, u32), String> {
        if self.names.contains_key(stream.as_ref()) {
            return Err(format!("stream '{stream}' already open"));
        }
        let slot = self.free.pop().unwrap_or_else(|| {
            self.slots.push(None);
            (self.slots.len() - 1) as u32
        });
        let gen = self.next_gen;
        self.next_gen = self.next_gen.wrapping_add(1);
        self.slots[slot as usize] = Some(StreamEntry::new(stream.clone(), gen, dim, cfg));
        self.names.insert(stream, slot);
        Ok((slot, gen))
    }

    /// The live entry a (slot, gen) pair addresses, if any.
    fn get_mut(&mut self, slot: u32, gen: u32) -> Result<&mut StreamEntry, String> {
        match self.slots.get_mut(slot as usize) {
            Some(Some(e)) if e.gen == gen => Ok(e),
            _ => Err("unknown or closed stream".to_string()),
        }
    }

    fn get(&self, slot: u32, gen: u32) -> Result<&StreamEntry, String> {
        match self.slots.get(slot as usize) {
            Some(Some(e)) if e.gen == gen => Ok(e),
            _ => Err("unknown or closed stream".to_string()),
        }
    }

    fn close(&mut self, slot: u32, gen: u32) -> Result<StreamEntry, String> {
        match self.slots.get_mut(slot as usize) {
            Some(s) if s.as_ref().map(|e| e.gen) == Some(gen) => {
                let entry = s.take().unwrap();
                self.names.remove(entry.id.as_ref());
                self.free.push(slot);
                Ok(entry)
            }
            _ => Err("unknown or closed stream".to_string()),
        }
    }

    fn live(&self) -> impl Iterator<Item = &StreamEntry> {
        self.slots.iter().flatten()
    }

    fn live_count(&self) -> usize {
        self.names.len()
    }
}

fn shard_worker(shard: usize, engine_cfg: EngineConfig, rx: Receiver<ShardCommand>) {
    let engine = build_engine(&engine_cfg);
    let mut table = SlotTable::default();
    let mut closed = ClosedTotals::default();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            ShardCommand::Open { stream, dim, cfg, reply } => {
                let _ = reply.send(table.open(stream, dim, cfg));
            }
            ShardCommand::Ingest { slot, gen, x, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => {
                        let t0 = Instant::now();
                        let r = entry.ingest(&x, &engine);
                        entry.metrics.ingest_latency.record(t0.elapsed());
                        r
                    }
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::IngestAsync { slot, gen, x } => match table.get_mut(slot, gen) {
                Ok(entry) => {
                    let t0 = Instant::now();
                    if let Err(e) = entry.ingest(&x, &engine) {
                        entry.metrics.async_errors += 1;
                        if entry.pending_error.is_none() {
                            entry.pending_error = Some(e);
                        }
                    }
                    entry.metrics.ingest_latency.record(t0.elapsed());
                }
                Err(_) => closed.orphans += 1,
            },
            ShardCommand::IngestMany { slot, gen, xs, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => {
                        let t0 = Instant::now();
                        let r = entry.ingest_many(&xs, &engine);
                        // One latency sample per batch command — the
                        // amortization the batch exists for.
                        entry.metrics.ingest_latency.record(t0.elapsed());
                        r
                    }
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Sync { slot, gen, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => match entry.pending_error.take() {
                        Some(e) => Err(e),
                        None => Ok(entry.metrics.async_errors),
                    },
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Project { slot, gen, x, r, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => {
                        let t0 = Instant::now();
                        let out = entry.project(&x, r);
                        entry.metrics.project_latency.record(t0.elapsed());
                        out
                    }
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::MeasureDrift { slot, gen, reply } => {
                let res = match table.get_mut(slot, gen) {
                    Ok(entry) => entry.measure_drift(),
                    Err(e) => Err(e),
                };
                let _ = reply.send(res);
            }
            ShardCommand::Snapshot { slot, gen, reply } => {
                let res = table.get(slot, gen).map(|entry| entry.snapshot(engine.counts()));
                let _ = reply.send(res);
            }
            ShardCommand::Metrics { slot, gen, reply } => {
                let res = table.get(slot, gen).map(|entry| entry.metrics.report());
                let _ = reply.send(res);
            }
            ShardCommand::Close { slot, gen, reply } => {
                let res = table.close(slot, gen).map(|entry| {
                    // Keep the stream's lifetime counters/latency in
                    // the shard totals — pool counters stay monotonic.
                    closed.absorb(&entry.metrics);
                    entry.final_stats()
                });
                let _ = reply.send(res);
            }
            ShardCommand::Rollup { reply } => {
                let mut rollup = ShardRollup {
                    streams: table.live_count(),
                    accepted: closed.accepted,
                    excluded: closed.excluded,
                    errors: closed.errors + closed.orphans,
                    total_ws_bytes: 0,
                    ws_engine_gemms: closed.engine_gemms,
                    ingest: closed.ingest.clone(),
                    project: closed.project.clone(),
                    engine_calls: engine.counts(),
                    gauges: Vec::with_capacity(table.live_count()),
                };
                for entry in table.live() {
                    rollup.accepted += entry.metrics.accepted;
                    rollup.excluded += entry.metrics.excluded;
                    rollup.errors += entry.metrics.errors;
                    rollup.total_ws_bytes += entry.metrics.ws_bytes_resident;
                    rollup.ws_engine_gemms += entry.metrics.engine_gemms;
                    rollup.ingest.merge(&entry.metrics.ingest_latency);
                    rollup.project.merge(&entry.metrics.project_latency);
                    rollup.gauges.push(entry.gauges(shard));
                }
                let _ = reply.send(rollup);
            }
            ShardCommand::Shutdown => break,
        }
    }
}

/// FNV-1a — deterministic stream→shard pinning (the std hasher is
/// randomly seeded per process, which would break cross-run
/// attribution in logs and tests).
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Cloneable, thread-safe routing front-end over the per-shard command
/// channels. [`StreamRouter::open_stream`] resolves a stream id to a
/// [`StreamHandle`] once; all data-path verbs then address by handle —
/// producers on different shards never touch the same queue, and the
/// ingest path carries no string.
#[derive(Clone)]
pub struct StreamRouter {
    shards: Arc<Vec<SyncSender<ShardCommand>>>,
}

impl StreamRouter {
    /// Number of shards behind this router.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard a stream id is pinned to (stable for the pool's life).
    pub fn shard_of(&self, stream: &str) -> usize {
        (fnv1a(stream) % self.shards.len() as u64) as usize
    }

    /// One rendezvous round-trip to shard `shard`: build the command
    /// around a fresh reply channel, send, await the answer. Every
    /// replying router verb goes through here so the error discipline
    /// cannot diverge between commands.
    fn rpc<T>(
        &self,
        shard: usize,
        make: impl FnOnce(SyncSender<T>) -> ShardCommand,
    ) -> Result<T, String> {
        let (rtx, rrx) = sync_channel(1);
        self.shards[shard].send(make(rtx)).map_err(|_| "shard pool down".to_string())?;
        rrx.recv().map_err(|_| "shard dropped reply".to_string())
    }

    /// Open a stream on its pinned shard and resolve it to a cheap
    /// [`StreamHandle`]. Fails if the id is in use.
    ///
    /// Setting [`StreamConfig::expected_m`]/
    /// [`StreamConfig::expected_batch`] makes the worker pre-size every
    /// hot-path buffer when the stream's eigensystem is built, so the
    /// whole streamed life of the entry is allocation-silent.
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::coordinator::{KernelConfig, PoolConfig, ShardPool, StreamConfig};
    ///
    /// let pool = ShardPool::spawn(PoolConfig::default());
    /// let router = pool.router();
    /// let cfg = StreamConfig {
    ///     kernel: KernelConfig::Rbf { sigma: 1.0 },
    ///     mean_adjust: false,
    ///     seed_points: 2,
    ///     expected_m: 64,      // reserve for 64 points …
    ///     expected_batch: 16,  // … fed in batches of up to 16
    ///     ..StreamConfig::default()
    /// };
    /// let h = router.open_stream("sensor-7", 3, cfg)?;
    /// assert_eq!(h.id(), "sensor-7");
    /// assert_eq!(h.shard(), router.shard_of("sensor-7"));
    /// # pool.shutdown();
    /// # Ok::<(), String>(())
    /// ```
    pub fn open_stream(
        &self,
        stream: &str,
        dim: usize,
        cfg: StreamConfig,
    ) -> Result<StreamHandle, String> {
        let shard = self.shard_of(stream);
        let id: Arc<str> = Arc::from(stream);
        let cmd_id = id.clone();
        let (slot, gen) =
            self.rpc(shard, move |reply| ShardCommand::Open { stream: cmd_id, dim, cfg, reply })??;
        Ok(StreamHandle { shard, slot, gen, id })
    }

    /// Ingest one example (blocks under backpressure of the stream's
    /// shard only; one rendezvous round-trip per point).
    pub fn ingest(&self, h: &StreamHandle, x: Vec<f64>) -> Result<IngestReply, String> {
        self.rpc(h.shard, |reply| ShardCommand::Ingest { slot: h.slot, gen: h.gen, x, reply })?
    }

    /// Fire-and-forget ingest: enqueue and return. Still blocks when
    /// the shard's bounded queue is full (backpressure is preserved);
    /// per-point failures are deferred — they bump the stream's
    /// `async_errors` counter and the first message is returned by the
    /// next [`StreamRouter::sync`]. `Err` here only means the pool is
    /// down.
    pub fn ingest_async(&self, h: &StreamHandle, x: Vec<f64>) -> Result<(), String> {
        self.shards[h.shard]
            .send(ShardCommand::IngestAsync { slot: h.slot, gen: h.gen, x })
            .map_err(|_| "shard pool down".to_string())
    }

    /// Ingest a whole batch (`xs` is `b × dim` row-major) as one
    /// command and one reply: the channel round-trip amortizes over the
    /// batch, the worker computes the batch's kernel rows as one
    /// blocked GEMM, and the batch's rank-one back-rotations fold into
    /// one fused engine GEMM (the blocked rank-b update — override per
    /// stream via [`StreamConfig::batch_rotation`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::coordinator::{KernelConfig, PoolConfig, ShardPool, StreamConfig};
    ///
    /// let pool = ShardPool::spawn(PoolConfig::default());
    /// let router = pool.router();
    /// let cfg = StreamConfig {
    ///     kernel: KernelConfig::Rbf { sigma: 1.0 },
    ///     mean_adjust: false,
    ///     seed_points: 2,
    ///     ..StreamConfig::default()
    /// };
    /// let h = router.open_stream("s", 2, cfg)?;
    /// // Six 2-d points in one command: two consumed by seeding, four
    /// // through the blocked batch path.
    /// let pts: Vec<f64> = (0..12).map(|i| (i as f64 * 0.31).cos()).collect();
    /// let reply = router.ingest_many(&h, pts)?;
    /// assert_eq!(reply.seeded, 2);
    /// assert_eq!(reply.accepted + reply.excluded, 4);
    /// assert_eq!(reply.m, 6 - reply.excluded);
    /// # pool.shutdown();
    /// # Ok::<(), String>(())
    /// ```
    pub fn ingest_many(&self, h: &StreamHandle, xs: Vec<f64>) -> Result<BatchReply, String> {
        self.rpc(h.shard, |reply| ShardCommand::IngestMany {
            slot: h.slot,
            gen: h.gen,
            xs,
            reply,
        })?
    }

    /// Drive a whole flat `n × dim` row-major feed through
    /// [`StreamRouter::ingest_many`] in `batch`-sized commands
    /// (`batch ≤ 1` means one-point batches) and return the aggregated
    /// counts — the one chunking loop the CLI, benches and tests all
    /// share, so the accounting cannot diverge between them.
    pub fn ingest_all(
        &self,
        h: &StreamHandle,
        flat: &[f64],
        dim: usize,
        batch: usize,
    ) -> Result<BatchReply, String> {
        assert!(dim > 0 && flat.len() % dim == 0, "feed must be n × dim row-major");
        let n = flat.len() / dim;
        let batch = batch.max(1);
        let mut total = BatchReply::default();
        let mut i = 0;
        while i < n {
            let end = (i + batch).min(n);
            let r = self.ingest_many(h, flat[i * dim..end * dim].to_vec())?;
            total.accepted += r.accepted;
            total.excluded += r.excluded;
            total.seeded += r.seeded;
            total.m = r.m;
            i = end;
        }
        Ok(total)
    }

    /// Barrier for fire-and-forget ingest: when this returns, every
    /// previously enqueued `ingest_async` for the stream has been
    /// applied (commands serialize through the shard). Returns the
    /// stream's cumulative async-error count, or `Err` with the first
    /// deferred error message since the last sync (clearing it).
    pub fn sync(&self, h: &StreamHandle) -> Result<u64, String> {
        self.rpc(h.shard, |reply| ShardCommand::Sync { slot: h.slot, gen: h.gen, reply })?
    }

    /// Project a point onto a stream's current top-`r` components.
    pub fn project(&self, h: &StreamHandle, x: Vec<f64>, r: usize) -> Result<Vec<f64>, String> {
        self.rpc(h.shard, |reply| ShardCommand::Project {
            slot: h.slot,
            gen: h.gen,
            x,
            r,
            reply,
        })?
    }

    /// Force an immediate drift measurement on a stream.
    pub fn measure_drift(&self, h: &StreamHandle) -> Result<DriftPoint, String> {
        self.rpc(h.shard, |reply| ShardCommand::MeasureDrift {
            slot: h.slot,
            gen: h.gen,
            reply,
        })?
    }

    /// Point-in-time view of one stream.
    pub fn snapshot(&self, h: &StreamHandle) -> Result<Snapshot, String> {
        self.rpc(h.shard, |reply| ShardCommand::Snapshot { slot: h.slot, gen: h.gen, reply })?
    }

    /// Per-stream metrics report.
    pub fn metrics(&self, h: &StreamHandle) -> Result<MetricsReport, String> {
        self.rpc(h.shard, |reply| ShardCommand::Metrics { slot: h.slot, gen: h.gen, reply })?
    }

    /// Close a stream, freeing its state (and its kernel), returning
    /// the stream's final stats. The stream's counters stay in the
    /// shard's lifetime totals, so pool counters remain monotonic; the
    /// slot is recycled under a new generation, so this (and any clone
    /// of this) handle goes stale rather than aliasing a successor.
    pub fn close_stream(&self, h: &StreamHandle) -> Result<KpcaStats, String> {
        self.rpc(h.shard, |reply| ShardCommand::Close { slot: h.slot, gen: h.gen, reply })?
    }

    /// Pool-level rollup: per-shard counters summed (including streams
    /// closed since spawn — counters are monotonic under churn), latency
    /// histograms merged, engine dispatches aggregated, per-stream
    /// gauges attached for the currently open streams.
    pub fn pool_snapshot(&self) -> Result<PoolSnapshot, String> {
        let mut snap = PoolSnapshot { shards: self.shards.len(), ..Default::default() };
        let mut ingest = LatencyHistogram::default();
        let mut project = LatencyHistogram::default();
        for shard in 0..self.shards.len() {
            let rollup = self.rpc(shard, |reply| ShardCommand::Rollup { reply })?;
            snap.streams += rollup.streams;
            snap.accepted += rollup.accepted;
            snap.excluded += rollup.excluded;
            snap.errors += rollup.errors;
            snap.total_ws_bytes += rollup.total_ws_bytes;
            snap.ws_engine_gemms += rollup.ws_engine_gemms;
            snap.engine_calls.0 += rollup.engine_calls.0;
            snap.engine_calls.1 += rollup.engine_calls.1;
            ingest.merge(&rollup.ingest);
            project.merge(&rollup.project);
            snap.per_stream.extend(rollup.gauges);
        }
        snap.ingest_p50_us = ingest.percentile_ns(0.50) / 1e3;
        snap.ingest_p99_us = ingest.percentile_ns(0.99) / 1e3;
        snap.ingest_mean_us = ingest.mean_ns() / 1e3;
        snap.ingest_count = ingest.count();
        snap.project_mean_us = project.mean_ns() / 1e3;
        snap.per_stream.sort_by(|a, b| a.stream.cmp(&b.stream));
        Ok(snap)
    }
}

/// Owner of the shard worker threads. Dropping (or calling
/// [`ShardPool::shutdown`]) stops every worker and joins it; router
/// clones held elsewhere then fail cleanly with "shard pool down".
pub struct ShardPool {
    router: StreamRouter,
    joins: Vec<JoinHandle<()>>,
}

impl ShardPool {
    /// Spawn `cfg.shards` worker threads (at least one), each with its
    /// own bounded command queue and rotation engine.
    pub fn spawn(cfg: PoolConfig) -> ShardPool {
        let n = cfg.shards.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut joins = Vec::with_capacity(n);
        for shard in 0..n {
            let (tx, rx) = sync_channel(cfg.queue.max(1));
            let engine_cfg = cfg.engine.clone();
            joins.push(std::thread::spawn(move || shard_worker(shard, engine_cfg, rx)));
            txs.push(tx);
        }
        ShardPool { router: StreamRouter { shards: Arc::new(txs) }, joins }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.router.shards()
    }

    /// A cloneable routing handle (safe to share across producer
    /// threads).
    pub fn router(&self) -> StreamRouter {
        self.router.clone()
    }

    /// Stop all workers and join them (open streams are dropped; close
    /// streams first if their final stats matter).
    pub fn shutdown(self) {
        // Drop runs the shutdown/join sequence.
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for tx in self.router.shards.iter() {
            let _ = tx.send(ShardCommand::Shutdown);
        }
        for join in self.joins.drain(..) {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;

    fn small_cfg() -> StreamConfig {
        StreamConfig {
            kernel: KernelConfig::Rbf { sigma: 1.0 },
            mean_adjust: true,
            seed_points: 5,
            ..StreamConfig::default()
        }
    }

    #[test]
    fn pinning_is_deterministic_and_spreads() {
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let mut hit = [false; 2];
        for i in 0..16 {
            let id = format!("stream-{i}");
            let s = router.shard_of(&id);
            assert_eq!(s, router.shard_of(&id), "pinning must be stable");
            assert!(s < 2);
            hit[s] = true;
        }
        assert!(hit[0] && hit[1], "16 ids should land on both shards");
        pool.shutdown();
    }

    #[test]
    fn open_twice_rejected_and_handles_expose_identity() {
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        let h = router.open_stream("a", 3, small_cfg()).unwrap();
        assert_eq!(h.id(), "a");
        assert_eq!(h.shard(), router.shard_of("a"));
        assert!(router.open_stream("a", 3, small_cfg()).is_err());
        pool.shutdown();
    }

    #[test]
    fn stale_handle_after_close_is_rejected() {
        let ds = yeast_like(8, 20);
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        let h = router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        router.close_stream(&h).unwrap();
        // The slot may be reused by a new stream; the old handle's
        // generation must not alias it.
        let h2 = router.open_stream("s2", ds.dim(), small_cfg()).unwrap();
        assert!(router.ingest(&h, ds.x.row(0).to_vec()).is_err());
        assert!(router.snapshot(&h).is_err());
        assert!(router.close_stream(&h).is_err());
        // Async ingest through a stale handle is counted, not lost.
        router.ingest_async(&h, ds.x.row(0).to_vec()).unwrap();
        router.ingest(&h2, ds.x.row(0).to_vec()).unwrap(); // barrier
        let snap = router.pool_snapshot().unwrap();
        assert_eq!(snap.errors, 1, "orphaned async command must surface in pool errors");
        pool.shutdown();
    }

    #[test]
    fn single_stream_through_pool_matches_reference() {
        let ds = yeast_like(24, 21);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let h = router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        let snap = router.snapshot(&h).unwrap();
        assert_eq!(snap.m, 24);
        assert_eq!(snap.kernel, "rbf");
        let d = router.measure_drift(&h).unwrap();
        assert!(d.norms.frobenius < 1e-7, "pool stream drift {:?}", d.norms);
        let stats = router.close_stream(&h).unwrap();
        assert_eq!(stats.accepted, 24);
        pool.shutdown();
    }

    #[test]
    fn batched_and_async_ingest_reach_the_same_state() {
        let ds = yeast_like(21, 22);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        let hs = router.open_stream("seq", ds.dim(), small_cfg()).unwrap();
        let hb = router.open_stream("bat", ds.dim(), small_cfg()).unwrap();
        let ha = router.open_stream("asy", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest(&hs, ds.x.row(i).to_vec()).unwrap();
            router.ingest_async(&ha, ds.x.row(i).to_vec()).unwrap();
        }
        // Batched: all 21 points in chunks of 8 (seed phase included).
        let dim = ds.dim();
        let flat = ds.x.as_slice();
        let mut i = 0;
        while i < ds.n() {
            let end = (i + 8).min(ds.n());
            let reply = router.ingest_many(&hb, flat[i * dim..end * dim].to_vec()).unwrap();
            assert_eq!(reply.seeded + reply.accepted + reply.excluded, end - i);
            i = end;
        }
        assert_eq!(router.sync(&ha).unwrap(), 0, "clean async stream has no errors");
        for h in [&hs, &hb, &ha] {
            let snap = router.snapshot(h).unwrap();
            assert_eq!(snap.m, 21, "{}", h.id());
        }
        // All three eigensystems agree (same data, same kernel).
        let s0 = router.snapshot(&hs).unwrap();
        for h in [&hb, &ha] {
            let s = router.snapshot(h).unwrap();
            for (a, b) in s0.top_values.iter().zip(&s.top_values) {
                assert!((a - b).abs() < 1e-10, "{}: {a} vs {b}", h.id());
            }
        }
        pool.shutdown();
    }

    #[test]
    fn async_errors_surface_on_next_sync() {
        let ds = yeast_like(8, 23);
        let pool = ShardPool::spawn(PoolConfig::default());
        let router = pool.router();
        let h = router.open_stream("s", ds.dim(), small_cfg()).unwrap();
        for i in 0..ds.n() {
            router.ingest_async(&h, ds.x.row(i).to_vec()).unwrap();
        }
        // A wrong-dimension point: accepted by the queue, deferred as a
        // per-stream error.
        router.ingest_async(&h, vec![0.0; ds.dim() + 1]).unwrap();
        let err = router.sync(&h).unwrap_err();
        assert!(err.contains("dimension mismatch"), "deferred error: {err}");
        // Error cleared; the counter remembers.
        assert_eq!(router.sync(&h).unwrap(), 1);
        let m = router.metrics(&h).unwrap();
        assert_eq!(m.errors, 1);
        assert_eq!(m.async_errors, 1);
        pool.shutdown();
    }

    #[test]
    fn pool_snapshot_rolls_up_across_shards() {
        let ds = yeast_like(16, 22);
        let pool = ShardPool::spawn(PoolConfig { shards: 2, ..Default::default() });
        let router = pool.router();
        for sid in ["alpha", "beta", "gamma"] {
            let h = router.open_stream(sid, ds.dim(), small_cfg()).unwrap();
            for i in 0..ds.n() {
                router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
            }
        }
        let snap = router.pool_snapshot().unwrap();
        assert_eq!(snap.shards, 2);
        assert_eq!(snap.streams, 3);
        assert_eq!(snap.accepted, 3 * (16 - 5) as u64);
        assert_eq!(snap.ingest_count, 3 * 16);
        assert!(snap.total_ws_bytes > 0);
        assert_eq!(snap.per_stream.len(), 3);
        // Sorted by stream id, each attributed to its pinned shard.
        assert_eq!(snap.per_stream[0].stream, "alpha");
        for g in &snap.per_stream {
            assert_eq!(g.shard, router.shard_of(&g.stream));
            assert_eq!(g.m, 16);
        }
        pool.shutdown();
    }
}
