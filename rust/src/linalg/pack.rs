//! Packed-panel substrate for the native GEMM (BLIS-style): cache
//! blocking constants, the reusable [`PackBuffers`] scratch, the
//! `pack_a`/`pack_b` panel writers and the fixed-shape `MR × NR`
//! microkernel every `matmul_*` variant bottoms out in.
//!
//! # Why packing
//!
//! The unpacked kernel reads `A` and `B` straight out of (possibly
//! strided) source buffers, so a `2mkn`-flop product pays a TLB walk
//! per `B` row and never presents the compiler with a fixed-width
//! inner loop it can keep in vector registers. Packing copies the
//! operands once per `KC`-deep slice into two contiguous, tile-ordered
//! buffers:
//!
//! * **A panels** — `MR`-row strips, depth-major: strip `s` holds rows
//!   `s·MR..s·MR+MR`, laid out `[p·MR + r]` so the microkernel loads
//!   one `MR`-wide column of `A` per depth step with a single
//!   contiguous read. Row tails zero-pad.
//! * **B panels** — `NR`-column panels, depth-major: panel `t` holds
//!   columns `t·NR..t·NR+NR`, laid out `[p·NR + c]` so each depth step
//!   is one `NR`-wide contiguous load. Column tails zero-pad.
//!
//! The packers absorb the operand orientation (`Src::Trans` walks
//! the source transposed), which is exactly what makes the `NT`/`TN`
//! GEMM variants free: the microkernel always sees the same two panel
//! layouts. Zero-padded tail lanes multiply against zeros and add
//! nothing, so every tile — full or edge — runs the same full-width
//! accumulate loop; only the write-back is bounded.
//!
//! # Blocking constants
//!
//! `MR×NR = 4×8` gives a 32-accumulator register tile (fits the 16
//! AVX2 `ymm` registers as 8 × 4-lane vectors with room for the `A`
//! broadcast and `B` loads). `KC = 256` puts one `A` strip (`4·256·8 =
//! 8 KiB`) plus one `B` panel (`8·256·8 = 16 KiB`) comfortably in a
//! 32 KiB L1d; `MC = 128` keeps the active `MC×KC` `A` block
//! (256 KiB) in L2; `NC = 4096` bounds the packed `B` slice (8 MiB
//! worst case) to an L3 share. Derivation and measurements:
//! EXPERIMENTS.md §Perf.

/// Microkernel tile rows (register blocking over `C` rows).
pub const MR: usize = 4;
/// Microkernel tile columns (one AVX2/AVX-512-friendly vector span).
pub const NR: usize = 8;
/// Row-panel height: the `MC × KC` packed `A` block targets L2.
pub const MC: usize = 128;
/// Depth blocking factor: one `A` strip + one `B` panel target L1d.
pub const KC: usize = 256;
/// Column blocking factor: bounds the packed `B` slice per pass.
pub const NC: usize = 4096;

/// Resize `buf` to `len`, counting a realloc only when capacity grows.
/// Retained elements keep their previous (stale) values — every
/// consumer fully overwrites its window, so no full-buffer memset is
/// paid on the hot path; only growth zero-fills the tail.
///
/// The one shared definition (workspace, kernel-block and pack-buffer
/// accounting all route here) so realloc counters can never diverge in
/// semantics across subsystems.
pub(crate) fn ensure_f64(buf: &mut Vec<f64>, len: usize, reallocs: &mut u64) {
    if len > buf.capacity() {
        *reallocs += 1;
    }
    buf.resize(len, 0.0);
}

/// Reusable packing scratch: one buffer for the tile-ordered `A`
/// panels, one for the `B` panels, and a growth counter so the
/// streaming steady state can assert the packed GEMM allocates
/// nothing. Owned thread-locally by the allocating `matmul_*` entry
/// points and cached inside `UpdateWorkspace` / `ProjectScratch` /
/// `KernelBlockScratch` for the `_buf` forms.
#[derive(Clone, Debug, Default)]
pub struct PackBuffers {
    /// Packed `A`: `div_ceil(m, MR)·MR × kc`, MR-strip layout.
    pub(super) a: Vec<f64>,
    /// Packed `B`: `kc × div_ceil(nc, NR)·NR`, NR-panel layout.
    pub(super) b: Vec<f64>,
    reallocs: u64,
}

impl PackBuffers {
    pub fn new() -> PackBuffers {
        PackBuffers::default()
    }

    /// Size both panels for one `(m, kc, nc)` blocking pass, counting
    /// capacity growth (the hot-path entry — zero once warm).
    pub(super) fn ensure(&mut self, m: usize, kc: usize, nc: usize) {
        let alen = m.div_ceil(MR) * MR * kc;
        let blen = nc.div_ceil(NR) * NR * kc;
        ensure_f64(&mut self.a, alen, &mut self.reallocs);
        ensure_f64(&mut self.b, blen, &mut self.reallocs);
    }

    /// Pre-size for products up to `m × k · k × n` without counting
    /// toward the realloc counter. Monotone in every argument: a
    /// reservation for `(m, k, n)` covers every smaller product.
    pub fn reserve(&mut self, m: usize, k: usize, n: usize) {
        let kc = k.min(KC);
        let alen = m.div_ceil(MR) * MR * kc;
        let blen = n.min(NC).div_ceil(NR) * NR * kc;
        if self.a.capacity() < alen {
            self.a.reserve(alen - self.a.len());
        }
        if self.b.capacity() < blen {
            self.b.reserve(blen - self.b.len());
        }
    }

    /// Capacity-growth events since construction (zero once warm).
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }

    /// Bytes currently held by the two panel buffers.
    pub fn bytes_resident(&self) -> usize {
        std::mem::size_of::<f64>() * (self.a.capacity() + self.b.capacity())
    }
}

/// Operand descriptor for the packers: a row-major backing slice plus
/// the orientation the packer should walk it in. `Trans` is how the
/// `NT`/`TN` variants reach the one packed path — the transpose is
/// absorbed here, never materialized.
#[derive(Clone, Copy)]
pub(super) enum Src<'a> {
    /// Element `(i, j)` is `data[i * stride + j]`.
    Normal { data: &'a [f64], stride: usize },
    /// Element `(i, j)` is `data[j * stride + i]` (logical transpose).
    Trans { data: &'a [f64], stride: usize },
}

/// Pack rows `i0..i1` of the left operand's `kk..kk+kc` depth slice
/// into MR-strips (`buf[s·MR·kc + p·MR + r]`), zero-padding the last
/// strip's missing rows. `i0` must be `MR`-aligned.
pub(super) fn pack_a(src: Src<'_>, i0: usize, i1: usize, kk: usize, kc: usize, buf: &mut [f64]) {
    debug_assert_eq!(i0 % MR, 0);
    let rows = i1 - i0;
    let strips = rows.div_ceil(MR);
    match src {
        Src::Normal { data, stride } => {
            for s in 0..strips {
                let dst = &mut buf[s * MR * kc..(s + 1) * MR * kc];
                let base = i0 + s * MR;
                let mv = MR.min(rows - s * MR);
                for r in 0..mv {
                    let off = (base + r) * stride + kk;
                    let row = &data[off..off + kc];
                    for (p, &v) in row.iter().enumerate() {
                        dst[p * MR + r] = v;
                    }
                }
                if mv < MR {
                    for p in 0..kc {
                        for r in mv..MR {
                            dst[p * MR + r] = 0.0;
                        }
                    }
                }
            }
        }
        Src::Trans { data, stride } => {
            // Element (i, p) lives at data[p·stride + i]: walking the
            // strip rows innermost reads the source contiguously.
            for s in 0..strips {
                let dst = &mut buf[s * MR * kc..(s + 1) * MR * kc];
                let base = i0 + s * MR;
                let mv = MR.min(rows - s * MR);
                for p in 0..kc {
                    let srow = &data[(kk + p) * stride + base..];
                    let d = &mut dst[p * MR..(p + 1) * MR];
                    for (r, slot) in d.iter_mut().take(mv).enumerate() {
                        *slot = srow[r];
                    }
                    for slot in d.iter_mut().skip(mv) {
                        *slot = 0.0;
                    }
                }
            }
        }
    }
}

/// Pack the right operand's `kk..kk+kc × j0..j0+nc` block into
/// NR-panels (`buf[t·NR·kc + p·NR + c]`), zero-padding the last
/// panel's missing columns.
pub(super) fn pack_b(src: Src<'_>, kk: usize, kc: usize, j0: usize, nc: usize, buf: &mut [f64]) {
    let panels = nc.div_ceil(NR);
    match src {
        Src::Normal { data, stride } => {
            for p in 0..kc {
                let row = &data[(kk + p) * stride + j0..];
                for t in 0..panels {
                    let nv = NR.min(nc - t * NR);
                    let d = &mut buf[t * NR * kc + p * NR..t * NR * kc + (p + 1) * NR];
                    d[..nv].copy_from_slice(&row[t * NR..t * NR + nv]);
                    for slot in d.iter_mut().skip(nv) {
                        *slot = 0.0;
                    }
                }
            }
        }
        Src::Trans { data, stride } => {
            // Element (p, j) lives at data[j·stride + p]: per column
            // the depth walk is contiguous.
            for t in 0..panels {
                let nv = NR.min(nc - t * NR);
                let pb = t * NR * kc;
                for c in 0..nv {
                    let col = &data[(j0 + t * NR + c) * stride + kk..];
                    for (p, &v) in col[..kc].iter().enumerate() {
                        buf[pb + p * NR + c] = v;
                    }
                }
                for c in nv..NR {
                    for p in 0..kc {
                        buf[pb + p * NR + c] = 0.0;
                    }
                }
            }
        }
    }
}

/// The one microkernel: accumulate a `kc`-deep `MR × NR` tile from one
/// packed `A` strip and one packed `B` panel into a register block,
/// then add it into `C`. `c` starts at the tile's top-left element;
/// `sc` is the output row stride; `mv × nv` bounds the write-back for
/// edge tiles (the accumulate itself always runs full width — padded
/// lanes hold zeros and contribute nothing, which keeps the inner loop
/// branch-free and lets rustc vectorize it; with `-C target-cpu=native`
/// the `a·b + acc` chains compile to FMA).
///
/// Per output element the depth sum runs `p` ascending within a block
/// and blocks in ascending `kk` order — for `k ≤ KC` that is exactly
/// the naive triple-loop summation order, which is what the ≤1e-12
/// packed≡naive equivalence tests pin down.
#[inline]
pub(super) fn microkernel(
    kc: usize,
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    sc: usize,
    mv: usize,
    nv: usize,
) {
    let mut acc = [[0.0f64; NR]; MR];
    let a = &a[..kc * MR];
    let b = &b[..kc * NR];
    for (ap, bp) in a.chunks_exact(MR).zip(b.chunks_exact(NR)) {
        let ap: &[f64; MR] = ap.try_into().unwrap();
        let bp: &[f64; NR] = bp.try_into().unwrap();
        for i in 0..MR {
            let ai = ap[i];
            for j in 0..NR {
                acc[i][j] += ai * bp[j];
            }
        }
    }
    if mv == MR && nv == NR {
        for (i, arow) in acc.iter().enumerate() {
            let crow = &mut c[i * sc..i * sc + NR];
            for j in 0..NR {
                crow[j] += arow[j];
            }
        }
    } else {
        for (i, arow) in acc.iter().enumerate().take(mv) {
            let crow = &mut c[i * sc..i * sc + nv];
            for (j, slot) in crow.iter_mut().enumerate() {
                *slot += arow[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_counts_only_capacity_growth() {
        let mut buf = Vec::new();
        let mut r = 0u64;
        ensure_f64(&mut buf, 8, &mut r);
        assert_eq!(r, 1);
        assert_eq!(buf.len(), 8);
        ensure_f64(&mut buf, 4, &mut r);
        ensure_f64(&mut buf, 8, &mut r);
        assert_eq!(r, 1, "shrink/regrow within capacity must be free");
        ensure_f64(&mut buf, 16, &mut r);
        assert_eq!(r, 2);
    }

    #[test]
    fn reserve_covers_every_smaller_ensure() {
        let mut bufs = PackBuffers::new();
        bufs.reserve(70, 300, 33);
        assert_eq!(bufs.reallocs(), 0, "reserve must not count as growth");
        // Every blocking pass of every sub-shape must fit what reserve
        // sized (monotonicity of the panel-length formulas).
        for (m, k, n) in [(70, 300, 33), (1, 1, 1), (70, 256, 33), (64, 44, 32), (3, 300, 5)] {
            for kk in (0..k).step_by(KC) {
                let kc = KC.min(k - kk);
                for j0 in (0..n).step_by(NC) {
                    let nc = NC.min(n - j0);
                    bufs.ensure(m, kc, nc);
                }
            }
        }
        assert_eq!(bufs.reallocs(), 0, "warm ensure within a reservation must be free");
    }

    #[test]
    fn pack_roundtrip_normal_and_trans() {
        // A 5×7 strided window; packing Normal then reading strips back
        // must reproduce the window, Trans must reproduce its transpose.
        let (rows, cols, stride) = (5usize, 7usize, 9usize);
        let data: Vec<f64> = (0..rows * stride).map(|i| i as f64 * 0.25 - 3.0).collect();
        let at = |i: usize, j: usize| data[i * stride + j];
        let normal = Src::Normal {
            data: &data,
            stride,
        };
        let trans = Src::Trans {
            data: &data,
            stride,
        };
        let kc = cols;
        let mut buf = vec![f64::NAN; rows.div_ceil(MR) * MR * kc];
        pack_a(normal, 0, rows, 0, kc, &mut buf);
        for i in 0..rows.div_ceil(MR) * MR {
            for p in 0..kc {
                let got = buf[(i / MR) * MR * kc + p * MR + (i % MR)];
                let want = if i < rows { at(i, p) } else { 0.0 };
                assert_eq!(got, want, "A pack ({i},{p})");
            }
        }
        // Trans: left operand is the 7×5 transpose of the same window.
        let (tm, tk) = (cols, rows);
        let mut tbuf = vec![f64::NAN; tm.div_ceil(MR) * MR * tk];
        pack_a(trans, 0, tm, 0, tk, &mut tbuf);
        for i in 0..tm.div_ceil(MR) * MR {
            for p in 0..tk {
                let got = tbuf[(i / MR) * MR * tk + p * MR + (i % MR)];
                let want = if i < tm { at(p, i) } else { 0.0 };
                assert_eq!(got, want, "Aᵀ pack ({i},{p})");
            }
        }
        // B: same window as the right operand, both orientations.
        let nc = cols;
        let mut bbuf = vec![f64::NAN; nc.div_ceil(NR) * NR * rows];
        pack_b(normal, 0, rows, 0, nc, &mut bbuf);
        for p in 0..rows {
            for j in 0..nc.div_ceil(NR) * NR {
                let got = bbuf[(j / NR) * NR * rows + p * NR + (j % NR)];
                let want = if j < nc { at(p, j) } else { 0.0 };
                assert_eq!(got, want, "B pack ({p},{j})");
            }
        }
        let (bk, bn) = (cols, rows); // Bᵀ is 7×5
        let mut btbuf = vec![f64::NAN; bn.div_ceil(NR) * NR * bk];
        pack_b(trans, 0, bk, 0, bn, &mut btbuf);
        for p in 0..bk {
            for j in 0..bn.div_ceil(NR) * NR {
                let got = btbuf[(j / NR) * NR * bk + p * NR + (j % NR)];
                let want = if j < bn { at(j, p) } else { 0.0 };
                assert_eq!(got, want, "Bᵀ pack ({p},{j})");
            }
        }
    }

    #[test]
    fn microkernel_matches_scalar_tile() {
        // One packed strip × one packed panel, every edge bound.
        let kc = 11;
        let a: Vec<f64> = (0..kc * MR).map(|i| (i as f64 * 0.37).sin()).collect();
        let b: Vec<f64> = (0..kc * NR).map(|i| (i as f64 * 0.21).cos()).collect();
        for mv in 1..=MR {
            for nv in 1..=NR {
                let sc = NR + 3;
                let mut c = vec![0.5; MR * sc];
                let keep = c.clone();
                microkernel(kc, &a, &b, &mut c, sc, mv, nv);
                for i in 0..MR {
                    for j in 0..sc {
                        let mut want = keep[i * sc + j];
                        if i < mv && j < nv {
                            for p in 0..kc {
                                want += a[p * MR + i] * b[p * NR + j];
                            }
                        }
                        let got = c[i * sc + j];
                        assert!((got - want).abs() < 1e-12, "tile mv={mv} nv={nv}");
                    }
                }
            }
        }
    }
}
