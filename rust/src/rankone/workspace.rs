//! The reusable scratch arena for rank-one eigensystem updates. One
//! workspace per stream: every buffer a [`super::rank_one_update_ws`]
//! step needs — the projected weight vector `z`, the deflation
//! partition, the secular roots, the stabilized weights, the `W`
//! eigenvector factor and the rotated-`U` double buffer — lives here
//! and is reused across updates, so the steady-state hot path performs
//! no heap allocation (verified by the realloc counter and the
//! `tests/workspace.rs` suite; the parallel GEMM still spawns scoped
//! threads above its flop threshold).
//!
//! The workspace also hosts the **blocked rank-b state**: the pending
//! rotation product `Q = Q₁·…·Q_j` accumulated by
//! [`super::rank_one_update_fused_ws`] across a batch's updates, its
//! double buffer, and the counters ([`UpdateWorkspace::engine_gemms`],
//! [`UpdateWorkspace::fused_updates`], …) that let tests and the
//! coordinator's metrics observe how many `U`-sized back-rotation GEMMs
//! actually reached the [`super::Rotate`] engine — the quantity the
//! fused path exists to amortize.

use crate::secular::{Deflation, SecularRoot};

/// Scratch buffers for the rank-one update hot path. Construct once per
/// stream and thread through every update; capacities are retained and
/// only ever grow (doubling with the eigensystem).
#[derive(Clone, Debug, Default)]
pub struct UpdateWorkspace {
    /// `z = Uᵀv` — perturbation in the eigenbasis (length n).
    pub(crate) z: Vec<f64>,
    /// Gu–Eisenstat stabilized weights over the active set (length k).
    pub(crate) zhat: Vec<f64>,
    /// The `k × k` inner eigenvector factor `W`.
    pub(crate) w: Vec<f64>,
    /// One column of `W` during assembly (length k).
    pub(crate) col: Vec<f64>,
    /// Gathered `m × k` active eigenvector panel (deflation path only).
    pub(crate) u_active: Vec<f64>,
    /// Rotation output; doubles as the eigenbasis swap buffer on the
    /// no-deflation fast path.
    pub(crate) rotated: Vec<f64>,
    /// Row scratch for in-place column permutation (length n).
    pub(crate) scratch: Vec<f64>,
    /// Eigenvalue scratch for the sort (length n).
    pub(crate) vals_tmp: Vec<f64>,
    /// Sort permutation (length n).
    pub(crate) perm: Vec<usize>,
    /// Reusable deflation partition.
    pub(crate) def: Deflation,
    /// Reusable secular roots.
    pub(crate) roots: Vec<SecularRoot>,
    /// Pending accumulated rotation `Q = Q₁·…·Q_j` of the blocked
    /// rank-b path, row-major `q_rows × q_dim` (square after pure
    /// updates/expansions; one column narrower per deferred eigenpair
    /// removal). While `q_dim > 0` the true eigenvectors are `U·Q`, not
    /// `U` — every read of the basis must go through
    /// [`super::flush_rotation_ws`] first.
    pub(crate) q: Vec<f64>,
    /// Double buffer for the `Q ← Q·W` accumulation GEMM and the
    /// `diag(Q, 1)` / column-removal re-layouts.
    pub(crate) q_next: Vec<f64>,
    /// Columns of the pending rotation (0 = none pending). Always equal
    /// to the eigenvalue count while pending.
    pub(crate) q_dim: usize,
    /// Rows of the pending rotation — the (stale) basis column count.
    /// Equals `q_dim` until a deferred removal drops a `Q` column;
    /// invariant `q_rows >= q_dim` and `q_rows == vecs.cols()` while
    /// pending.
    pub(crate) q_rows: usize,
    /// Scratch for `Uᵀv` before the `Qᵀ` re-projection (length n).
    pub(crate) zq: Vec<f64>,
    /// Buffer-growth events across all members (zero once warm).
    pub(crate) reallocs: u64,
    /// `U`-sized back-rotation GEMMs dispatched to the engine — one per
    /// sequential rank-one update, one per blocked-batch flush.
    pub(crate) engine_gemms: u64,
    /// Small `Q·W` accumulation products (native, never the engine).
    pub(crate) accum_gemms: u64,
    /// Rank-one updates absorbed into the pending product.
    pub(crate) fused_updates: u64,
    /// Fused attempts that had to fall back to the sequential path
    /// (deflation / repeated eigenvalues made folding unsound).
    pub(crate) fused_fallbacks: u64,
    /// Pending products materialized into `U` (one engine GEMM each).
    pub(crate) flushes: u64,
    /// GEMM packing scratch: the sequential back-rotation, the `Q·W`
    /// accumulation and the blocked flush all pack into these panels,
    /// so the packed GEMM stays zero-realloc once the stream is warm.
    pub(crate) pack: crate::linalg::PackBuffers,
}

impl UpdateWorkspace {
    pub fn new() -> Self {
        UpdateWorkspace::default()
    }

    /// Pre-size every buffer for eigensystems up to `m` rows × `n`
    /// eigenpairs, *without* counting toward the realloc counter — the
    /// warm-up entry point for latency-critical streams.
    pub fn reserve(&mut self, m: usize, n: usize) {
        fn grow<T>(v: &mut Vec<T>, cap: usize) {
            if v.capacity() < cap {
                v.reserve(cap - v.len());
            }
        }
        grow(&mut self.z, n);
        grow(&mut self.zhat, n);
        grow(&mut self.w, n * n);
        grow(&mut self.col, n);
        grow(&mut self.u_active, m * n);
        grow(&mut self.rotated, m * n);
        grow(&mut self.scratch, n);
        grow(&mut self.vals_tmp, n);
        grow(&mut self.perm, n);
        grow(&mut self.roots, n);
        grow(&mut self.def.active, n);
        grow(&mut self.def.deflated, n);
        grow(&mut self.def.d_active, n);
        grow(&mut self.def.z_active, n);
        // Largest GEMM the workspace ever packs for: the m × n basis
        // against an n × n rotation factor (covers the n × n accum
        // product too, by monotonicity of the panel-length formulas).
        self.pack.reserve(m, n, n);
    }

    /// Pre-size the blocked rank-b scratch (the pending product, its
    /// double buffer and the `Uᵀv` projection buffer) for eigensystems
    /// up to `n` eigenpairs — a further `2n² + n` floats on top of
    /// [`UpdateWorkspace::reserve`], so it is split out: only streams
    /// that can actually take the fused path should pay for it (the
    /// fused entry point grows these lazily otherwise).
    pub fn reserve_blocked(&mut self, n: usize) {
        fn grow<T>(v: &mut Vec<T>, cap: usize) {
            if v.capacity() < cap {
                v.reserve(cap - v.len());
            }
        }
        grow(&mut self.q, n * n);
        grow(&mut self.q_next, n * n);
        grow(&mut self.zq, n);
    }

    /// Buffer-growth events since construction (including the GEMM
    /// packing scratch). Constant across updates once the workspace is
    /// warm — the zero-allocation guarantee the steady-state test pins
    /// down.
    pub fn reallocs(&self) -> u64 {
        self.reallocs + self.pack.reallocs()
    }

    /// Whether a blocked-batch rotation product is pending (the basis is
    /// stale until [`super::flush_rotation_ws`] materializes `U·Q`).
    pub fn pending_rotation(&self) -> bool {
        self.q_dim > 0
    }

    /// `U`-sized back-rotation GEMMs dispatched to the [`super::Rotate`]
    /// engine since construction: one per sequential rank-one update,
    /// one per blocked-batch flush. The gap between this and
    /// [`UpdateWorkspace::fused_updates`] is the amortization the
    /// blocked rank-b path buys.
    pub fn engine_gemms(&self) -> u64 {
        self.engine_gemms
    }

    /// Rank-one updates absorbed into a pending rotation product
    /// instead of dispatching their own engine GEMM.
    pub fn fused_updates(&self) -> u64 {
        self.fused_updates
    }

    /// Fused update attempts that fell back to the sequential path
    /// because deflation (tiny weight or repeated eigenvalues) made
    /// folding the rotation unsound.
    pub fn fused_fallbacks(&self) -> u64 {
        self.fused_fallbacks
    }

    /// Pending rotation products materialized into the basis.
    pub fn rotation_flushes(&self) -> u64 {
        self.flushes
    }

    /// Small `Q·W` accumulation GEMMs (native scratch products — never
    /// the engine; reported for the flop-tradeoff accounting).
    pub fn accum_gemms(&self) -> u64 {
        self.accum_gemms
    }

    /// Bytes currently held across all scratch buffers.
    pub fn bytes_resident(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        let u = std::mem::size_of::<usize>();
        let r = std::mem::size_of::<SecularRoot>();
        f * (self.z.capacity()
            + self.zhat.capacity()
            + self.w.capacity()
            + self.col.capacity()
            + self.u_active.capacity()
            + self.rotated.capacity()
            + self.scratch.capacity()
            + self.vals_tmp.capacity()
            + self.q.capacity()
            + self.q_next.capacity()
            + self.zq.capacity()
            + self.def.d_active.capacity()
            + self.def.z_active.capacity())
            + u * (self.perm.capacity()
                + self.def.active.capacity()
                + self.def.deflated.capacity())
            + r * self.roots.capacity()
            + self.pack.bytes_resident()
    }
}

// The canonical counting-resize helper moved next to the pack buffers
// it also guards; re-exported here so existing `rankone::ensure_f64`
// users keep compiling unchanged.
pub(crate) use crate::linalg::pack::ensure_f64;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_counts_only_capacity_growth() {
        let mut buf = Vec::new();
        let mut r = 0u64;
        ensure_f64(&mut buf, 8, &mut r);
        assert_eq!(r, 1);
        assert_eq!(buf.len(), 8);
        ensure_f64(&mut buf, 4, &mut r);
        ensure_f64(&mut buf, 8, &mut r);
        assert_eq!(r, 1, "shrink/regrow within capacity must be free");
        ensure_f64(&mut buf, 16, &mut r);
        assert_eq!(r, 2);
    }

    #[test]
    fn reserve_is_invisible_to_the_counter() {
        let mut ws = UpdateWorkspace::new();
        ws.reserve(32, 32);
        assert_eq!(ws.reallocs(), 0);
        assert!(ws.bytes_resident() > 0);
        let mut r = ws.reallocs;
        ensure_f64(&mut ws.z, 32, &mut r);
        assert_eq!(r, 0, "reserved buffer must absorb ensure() without realloc");
    }
}
