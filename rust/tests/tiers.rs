//! Engine-tier integration tests: the `StreamTier` seam end to end.
//!
//! Four contracts, each through the public router verbs only:
//!
//! 1. **Determinism** — an `rff` stream is a pure function of
//!    (stream id, config, feed order): a locally driven
//!    [`inkpca::rff::RffKpca`] with the same FNV-seeded map must
//!    reproduce the routed stream's projections to ~1e-9, including
//!    across checkpoint/restore and live migration (both ship state,
//!    they never recompute it).
//! 2. **Sketch quality** — the routed `rff` stream tracks the
//!    batch-recompute oracle (exact PCA of the full feature matrix on
//!    the same seeded map) within the frequent-directions guarantee:
//!    `λₖ(BᵀB) ≤ λₖ(ZᵀZ)` and `λ₁(BᵀB) ≥ λ₁(ZᵀZ) − ‖Z‖²F/r`
//!    exactly, and top-subspace projection energy within the
//!    documented [`SKETCH_REL_TOL`].
//! 3. **Shadow gauge** — the shadow tier's projection-divergence gauge
//!    populates on probe cadence, grows monotonically within a publish
//!    window, resets at the publish point (`sync`), and rolls up as
//!    the pool-wide `max_divergence`.
//! 4. **Tier plumbing** — `Snapshot::tier` reports the serving engine
//!    everywhere (live, restored, migrated), and the sketched tiers
//!    reject non-RBF kernels with a clean error instead of seeding.

mod common;

use std::path::PathBuf;

use common::oracle;
use inkpca::coordinator::ring::fnv1a;
use inkpca::coordinator::{
    EngineConfig, KernelConfig, PersistConfig, PoolConfig, ShardPool, StreamConfig,
    StreamHandle, StreamRouter, StreamTier,
};
use inkpca::data::Dataset;
use inkpca::linalg::{eigh, Mat};
use inkpca::rff::{RffKpca, RffMap};

const SEED_POINTS: usize = 6;
const SIGMA: f64 = 1.5;
const FEATURES: usize = 64;
const SKETCH_R: usize = 16;

/// The documented sketch tolerance: relative error allowed between the
/// routed sketch's top-subspace projection energy and the batch
/// feature-PCA oracle's. Generous — the bound covers RFF map variance
/// plus the frequent-directions shrink, and the test pins "tracks the
/// subspace", not bit-equality (that's what the determinism tests
/// are for).
const SKETCH_REL_TOL: f64 = 0.5;

fn tier_cfg(tier: StreamTier, mean_adjust: bool, sigma: f64) -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma },
        mean_adjust,
        seed_points: SEED_POINTS,
        // Keep auto-publish off the feed cadence so the divergence
        // window under test is controlled purely by explicit `sync`.
        publish_every: 100_000,
        tier,
        ..StreamConfig::default()
    }
}

fn pool_cfg(shards: usize) -> PoolConfig {
    PoolConfig { shards, queue: 64, engine: EngineConfig::Native, ..PoolConfig::default() }
}

fn durable_pool(dir: &PathBuf) -> (ShardPool, StreamRouter) {
    let pool = ShardPool::spawn(PoolConfig {
        persist: Some(PersistConfig::new(dir.clone())),
        ..pool_cfg(2)
    });
    let router = pool.router();
    (pool, router)
}

fn feed(router: &StreamRouter, h: &StreamHandle, ds: &Dataset, range: std::ops::Range<usize>) {
    for i in range {
        router.ingest(h, ds.x.row(i).to_vec()).unwrap();
    }
}

/// The routed stream's uninterrupted local twin: the same seeded map
/// (the engine derives the map seed as `fnv1a(stream id)`), the same
/// feed order, driven directly.
fn rff_replica(id: &str, ds: &Dataset, n: usize, mean_adjust: bool, sigma: f64) -> RffKpca {
    let mut st =
        RffKpca::new(ds.dim(), FEATURES, SKETCH_R, sigma, fnv1a(id), mean_adjust).unwrap();
    for i in 0..n {
        st.push(ds.x.row(i)).unwrap();
    }
    st
}

/// Routed projections must match the replica's to ~1e-9: same map,
/// same sketch arithmetic, same order — the router adds routing, not
/// recomputation.
fn assert_matches_replica(
    router: &StreamRouter,
    h: &StreamHandle,
    ds: &Dataset,
    replica: &mut RffKpca,
) {
    let probes: Vec<Vec<f64>> =
        (0..4).map(|i| ds.x.row(i).to_vec()).chain([vec![0.25; ds.dim()]]).collect();
    for y in probes {
        let got = router.project(h, y.clone(), 8).unwrap();
        let want = replica.project(&y, 8);
        assert_eq!(got.len(), want.len(), "{}", h.id());
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() <= 1e-9,
                "{}: routed rff score {g} vs replica {w}",
                h.id()
            );
        }
    }
}

#[test]
fn rff_tier_matches_its_seeded_replica_exactly() {
    let ds = oracle::std_stream(40, 1201);
    let pool = ShardPool::spawn(pool_cfg(1));
    let router = pool.router();
    let tier = StreamTier::Rff { features: FEATURES, sketch_r: SKETCH_R };
    let h = router.open_stream("rffdet", ds.dim(), tier_cfg(tier, true, SIGMA)).unwrap();
    feed(&router, &h, &ds, 0..ds.n());

    let snap = router.snapshot(&h).unwrap();
    assert_eq!(snap.tier, "rff");
    assert_eq!(snap.kernel, "rbf");
    assert_eq!(snap.m, ds.n(), "the sketch counts absorbed points, seed included");

    let mut replica = rff_replica("rffdet", &ds, ds.n(), true, SIGMA);
    assert_matches_replica(&router, &h, &ds, &mut replica);

    // No Gram matrix → no drift audit: the verb errors cleanly instead
    // of lying with a zero.
    let err = router.measure_drift(&h).unwrap_err();
    assert!(err.contains("exact tier"), "unexpected drift error: {err}");
    pool.shutdown();
}

#[test]
fn rff_tier_tracks_the_batch_feature_pca_oracle() {
    // σ at the median-heuristic scale (E‖x−y‖² ≈ 2·dim on standardized
    // data) so the kernel has real structure — a near-identity Gram
    // would make any sketch comparison vacuous.
    let ds = oracle::std_stream(160, 1202);
    let sigma = 2.0 * ds.dim() as f64;
    let pool = ShardPool::spawn(pool_cfg(1));
    let router = pool.router();
    let tier = StreamTier::Rff { features: FEATURES, sketch_r: SKETCH_R };
    // mean_adjust off: the frequent-directions guarantee then applies
    // verbatim to the raw feature rows (streamed centering would
    // perturb the oracle by the mean-drift term).
    let h = router.open_stream("rffq", ds.dim(), tier_cfg(tier, false, sigma)).unwrap();
    feed(&router, &h, &ds, 0..ds.n());
    // Publish so the `&self` spectrum gauge behind `snapshot()` is
    // current (it refreshes at capture/project, not per push).
    router.sync(&h).unwrap();

    // Batch-recompute oracle: exact PCA of the full n×D feature matrix
    // under the SAME seeded map the engine derived from the stream id.
    let map = RffMap::new(ds.dim(), FEATURES, sigma, fnv1a("rffq")).unwrap();
    let mut z = vec![0.0; FEATURES];
    let mut fro2 = 0.0;
    let mut cov = Mat::zeros(FEATURES, FEATURES);
    let mut zrows = Vec::with_capacity(ds.n() * FEATURES);
    for i in 0..ds.n() {
        map.map_into(ds.x.row(i), &mut z);
        fro2 += z.iter().map(|v| v * v).sum::<f64>();
        cov.syr(1.0, &z);
        zrows.extend_from_slice(&z);
    }
    cov.symmetrize();
    let eg = eigh(&cov).unwrap();
    let lambda = |k: usize| eg.values[FEATURES - 1 - k].max(0.0);

    // The frequent-directions guarantee, verbatim: the sketch never
    // overshoots any oracle eigenvalue, and undershoots the top one by
    // at most ‖Z‖²F / sketch_r.
    let snap = router.snapshot(&h).unwrap();
    assert!(snap.top_values.len() >= 4, "sketch spectrum too short: {:?}", snap.top_values);
    for k in 0..4 {
        assert!(
            snap.top_values[k] <= lambda(k) + 1e-6 * (1.0 + lambda(k)),
            "sketch λ{k}={} overshoots oracle {}",
            snap.top_values[k],
            lambda(k)
        );
    }
    assert!(
        snap.top_values[0] >= lambda(0) - fro2 / SKETCH_R as f64 - 1e-6 * (1.0 + lambda(0)),
        "sketch λ0={} below the FD floor (oracle {}, ‖Z‖²F/r {})",
        snap.top_values[0],
        lambda(0),
        fro2 / SKETCH_R as f64
    );
    assert!(lambda(0) > 0.0, "degenerate oracle spectrum");

    // Projection energy over the top-4 subspace, aggregated across
    // in-distribution probes, within the documented sketch tolerance.
    let mut e_oracle = 0.0;
    let mut e_sketch = 0.0;
    for i in 0..16 {
        let zrow = &zrows[i * FEATURES..(i + 1) * FEATURES];
        for k in 0..4 {
            let idx = FEATURES - 1 - k;
            let mut s = 0.0;
            for f in 0..FEATURES {
                s += zrow[f] * eg.vectors.row(f)[idx];
            }
            e_oracle += s * s;
        }
        let scores = router.project(&h, ds.x.row(i).to_vec(), 4).unwrap();
        e_sketch += scores.iter().map(|s| s * s).sum::<f64>();
    }
    assert!(e_oracle > 0.0);
    let rel = (e_sketch - e_oracle).abs() / e_oracle;
    assert!(
        rel < SKETCH_REL_TOL,
        "top-subspace energy: sketch {e_sketch} vs batch oracle {e_oracle} (rel {rel})"
    );
    pool.shutdown();
}

#[test]
fn shadow_divergence_gauge_populates_and_resets_on_publish() {
    let ds = oracle::std_stream(SEED_POINTS + 12, 1203);
    let pool = ShardPool::spawn(pool_cfg(1));
    let router = pool.router();
    let tier = StreamTier::Shadow { sample: 2 };
    let h = router.open_stream("sh", ds.dim(), tier_cfg(tier, true, SIGMA)).unwrap();
    // An exact control stream on the same pool: its gauge must stay
    // `None` so the pool max attributes to the shadow stream alone.
    let hx = router
        .open_stream("ex", ds.dim(), tier_cfg(StreamTier::Exact, true, SIGMA))
        .unwrap();
    feed(&router, &hx, &ds, 0..ds.n());

    let gauge = |router: &StreamRouter, id: &str| -> Option<f64> {
        let snap = router.pool_snapshot().unwrap();
        snap.per_stream.iter().find(|g| g.stream == id).unwrap().divergence
    };

    // Probes land every 2nd post-seed point: after 4 points the gauge
    // holds the max gap of two probes …
    feed(&router, &h, &ds, 0..SEED_POINTS + 4);
    let d_mid = gauge(&router, "sh").expect("shadow stream must report divergence");
    assert!(d_mid > 0.0, "independent engines cannot agree exactly");
    // … and can only grow until the window closes.
    feed(&router, &h, &ds, SEED_POINTS + 4..SEED_POINTS + 8);
    let d_end = gauge(&router, "sh").expect("gauge stays populated");
    assert!(d_end >= d_mid, "divergence is a monotone max within a window: {d_end} < {d_mid}");
    assert_eq!(gauge(&router, "ex"), None, "the exact tier has no divergence gauge");
    let snap = router.pool_snapshot().unwrap();
    assert_eq!(
        snap.max_divergence,
        Some(d_end),
        "pool rollup takes the max over shadow streams"
    );

    // `sync` publishes → the window resets. The next non-probe point
    // refreshes the gauge to the fresh (empty) max.
    router.sync(&h).unwrap();
    feed(&router, &h, &ds, SEED_POINTS + 8..SEED_POINTS + 9);
    assert_eq!(
        gauge(&router, "sh"),
        Some(0.0),
        "publish must reset the divergence window"
    );
    // The next probe repopulates it.
    feed(&router, &h, &ds, SEED_POINTS + 9..SEED_POINTS + 10);
    let d2 = gauge(&router, "sh").expect("gauge repopulates after reset");
    assert!(d2 > 0.0);

    // Shadow serves from the exact engine: the eigensystem matches the
    // uninterrupted exact reference to the usual 1e-10 bar.
    let snap = router.snapshot(&h).unwrap();
    assert_eq!(snap.tier, "shadow");
    let reference = oracle::reference_run(&ds, SEED_POINTS + 10, SIGMA, SEED_POINTS);
    oracle::assert_matches_reference(&router, &h, &ds, &reference);
    pool.shutdown();
}

#[test]
fn tiers_roundtrip_through_checkpoint_and_restore() {
    let ds = oracle::std_stream(36, 1204);
    let dir = oracle::temp_dir("tiers");
    let (pool, router) = durable_pool(&dir);
    let rff_tier = StreamTier::Rff { features: FEATURES, sketch_r: SKETCH_R };
    let hr = router.open_stream("r", ds.dim(), tier_cfg(rff_tier, true, SIGMA)).unwrap();
    let hs = router
        .open_stream("s", ds.dim(), tier_cfg(StreamTier::Shadow { sample: 2 }, true, SIGMA))
        .unwrap();
    feed(&router, &hr, &ds, 0..24);
    feed(&router, &hs, &ds, 0..24);
    assert!(router.checkpoint_stream(&hr).unwrap() > 0);
    assert!(router.checkpoint_stream(&hs).unwrap() > 0);
    feed(&router, &hr, &ds, 24..ds.n());
    feed(&router, &hs, &ds, 24..ds.n());
    drop((hr, hs));
    pool.shutdown(); // crash: no close, checkpoints + WAL suffix on disk

    let (pool2, router2) = durable_pool(&dir);
    let report = router2.restore_pool().unwrap();
    assert_eq!(report.restored, 2, "both tiered checkpoints load");
    assert!(report.failed.is_empty(), "{:?}", report.failed);
    assert_eq!(report.replayed, 24, "12 post-checkpoint points per stream replay");
    let by_id = |id: &str| report.handles.iter().find(|h| h.id() == id).unwrap().clone();
    let hr = by_id("r");
    let hs = by_id("s");

    // The tier survived the codec round-trip …
    assert_eq!(router2.snapshot(&hr).unwrap().tier, "rff");
    assert_eq!(router2.snapshot(&hs).unwrap().tier, "shadow");
    // … and so did the state, exactly: checkpoint + WAL replay lands on
    // the same sketch an uninterrupted run produces.
    let mut replica = rff_replica("r", &ds, ds.n(), true, SIGMA);
    assert_matches_replica(&router2, &hr, &ds, &mut replica);
    let reference = oracle::reference_run(&ds, ds.n(), SIGMA, SEED_POINTS);
    oracle::assert_matches_reference(&router2, &hs, &ds, &reference);

    // Restored streams keep serving and absorbing.
    feed(&router2, &hr, &ds, 0..2);
    feed(&router2, &hs, &ds, 0..2);
    assert_eq!(router2.snapshot(&hr).unwrap().m, ds.n() + 2);
    assert_eq!(router2.snapshot(&hs).unwrap().m, ds.n() + 2);
    // The shadow probe cadence restarts post-restore and repopulates
    // the gauge (2 fresh points → one probe at the new sample=2 mark).
    let snap = router2.pool_snapshot().unwrap();
    let g = snap.per_stream.iter().find(|g| g.stream == "s").unwrap();
    assert!(g.divergence.is_some(), "restored shadow stream probes again");
    pool2.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tiers_survive_live_migration() {
    let ds = oracle::std_stream(32, 1205);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let rff_tier = StreamTier::Rff { features: FEATURES, sketch_r: SKETCH_R };
    let hr = router.open_stream("mr", ds.dim(), tier_cfg(rff_tier, true, SIGMA)).unwrap();
    let hs = router
        .open_stream("ms", ds.dim(), tier_cfg(StreamTier::Shadow { sample: 2 }, true, SIGMA))
        .unwrap();

    feed(&router, &hr, &ds, 0..ds.n() / 2);
    feed(&router, &hs, &ds, 0..ds.n() / 2);
    router.migrate_stream(&hr, (hr.shard() + 1) % 2).unwrap();
    router.migrate_stream(&hs, (hs.shard() + 1) % 2).unwrap();
    feed(&router, &hr, &ds, ds.n() / 2..ds.n());
    feed(&router, &hs, &ds, ds.n() / 2..ds.n());

    // Migration ships the boxed engine wholesale: tier intact, state
    // bit-identical to the unmigrated twin.
    assert_eq!(router.snapshot(&hr).unwrap().tier, "rff");
    assert_eq!(router.snapshot(&hs).unwrap().tier, "shadow");
    let mut replica = rff_replica("mr", &ds, ds.n(), true, SIGMA);
    assert_matches_replica(&router, &hr, &ds, &mut replica);
    let reference = oracle::reference_run(&ds, ds.n(), SIGMA, SEED_POINTS);
    oracle::assert_matches_reference(&router, &hs, &ds, &reference);

    let snap = router.pool_snapshot().unwrap();
    assert_eq!(snap.migrations, 2);
    assert!(
        snap.max_divergence.is_some(),
        "the migrated shadow stream still reports divergence"
    );
    pool.shutdown();
}

#[test]
fn sketched_tiers_require_an_rbf_kernel() {
    let ds = oracle::std_stream(4, 1206);
    let pool = ShardPool::spawn(pool_cfg(1));
    let router = pool.router();
    let cfg = StreamConfig {
        kernel: KernelConfig::Linear,
        mean_adjust: false,
        seed_points: 2,
        tier: StreamTier::Rff { features: FEATURES, sketch_r: SKETCH_R },
        ..StreamConfig::default()
    };
    let h = router.open_stream("lin", ds.dim(), cfg).unwrap();
    // Seeding buffers fine; the seed-completing point must surface the
    // tier/kernel mismatch instead of wedging the stream silently.
    router.ingest(&h, ds.x.row(0).to_vec()).unwrap();
    let err = router.ingest(&h, ds.x.row(1).to_vec()).unwrap_err();
    assert!(
        err.contains("require an RBF kernel"),
        "unexpected seed error: {err}"
    );
    pool.shutdown();
}
