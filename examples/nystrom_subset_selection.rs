//! The paper's §4 motivating use-case: grow the Nyström subset
//! incrementally and *stop when the approximation is good enough* —
//! something batch Nyström cannot do without recomputing from scratch
//! at every candidate size. Compares the eigen-update path against the
//! Rudi-2015-style incremental-Cholesky baseline.
//!
//!     cargo run --release --example nystrom_subset_selection

use inkpca::data::load;
use inkpca::kernels::{gram, median_heuristic, Rbf};
use inkpca::linalg::{frobenius, psd_norms};
use inkpca::nystrom::{CholeskyNystrom, IncrementalNystrom};

fn main() -> Result<(), String> {
    let mut ds = load("yeast", 400, 11)?;
    ds.standardize();
    let sigma = median_heuristic(&ds.x, 200);
    let kern = Rbf { sigma };
    let k_full = gram(&kern, &ds.x);
    let k_norm = frobenius(&k_full);
    // Target: relative Frobenius error below 1%.
    let target = 0.01;
    println!(
        "selecting Nyström subset for n={} (‖K‖_F = {k_norm:.3e}, target rel-err {target})",
        ds.n()
    );

    // ── eigen-update path (the paper's §4 algorithm) ──
    let mut inys = IncrementalNystrom::new(&kern, ds.x.clone())?;
    let mut chosen_m = None;
    for m in 0..ds.n() {
        inys.add_point(m)?;
        // Cheap evaluation at every step — the whole point of §4.
        let diff = k_full.sub(&inys.approx_gram());
        let rel = frobenius(&diff) / k_norm;
        if m % 25 == 24 {
            println!("  m={:>4}  rel-err {rel:.5}", m + 1);
        }
        if rel < target {
            chosen_m = Some(m + 1);
            println!("→ subset size {} reaches rel-err {rel:.5}", m + 1);
            break;
        }
    }
    let m_star = chosen_m.ok_or("target accuracy not reached — dataset too hard?")?;

    // Full norms at the chosen size.
    let norms = psd_norms(&k_full.sub(&inys.approx_gram()));
    println!(
        "at m={m_star}: ‖K−K̃‖_F {:.4e}  ‖·‖₂ {:.4e}  ‖·‖_tr {:.4e}",
        norms.frobenius, norms.spectral, norms.trace
    );

    // ── Cholesky baseline (Rudi et al. 2015 style) reaches the same
    //    subset with the same quality (it computes the same K̃). ──
    let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
    for m in 0..m_star {
        chol.add_point(m)?;
    }
    let chol_err = frobenius(&k_full.sub(&chol.approx_gram())) / k_norm;
    println!("cholesky baseline at m={m_star}: rel-err {chol_err:.5}");
    assert!((chol_err - norms.frobenius / k_norm).abs() < 1e-6);

    // The eigen path additionally gives approximate eigenpairs of K for
    // downstream kernel PCA — the Cholesky path does not.
    let (vals, _) = inys.approx_eigs();
    let top: Vec<f64> = vals.iter().rev().take(3).map(|v| (v * 10.0).round() / 10.0).collect();
    println!("approximate top eigenvalues of K from the subset: {top:?}");
    println!("nystrom_subset_selection OK");
    Ok(())
}
