//! Rudi, Camoriano & Rosasco (2015)-style incremental Nyström via
//! rank-one *Cholesky* updates — the prior work the paper generalizes
//! (§4). Maintains `K_{m,m} = L Lᵀ` through bordered expansion and
//! computes `K̃ = (L⁻¹K_{m,n})ᵀ(L⁻¹K_{m,n})` by triangular solves,
//! without ever forming an eigendecomposition. Serves as the comparison
//! baseline for the ablation bench (which decomposition to update).
//!
//! Streaming layout (mirroring `nystrom::incremental`): the factor
//! lives in a [`PackedCholesky`] (capacity-slack triangular store whose
//! bordered expansion is an amortized `Vec` append), the cross-Gram is
//! stored *transposed* (`kmn`, `m × n`) so adding a subset point is one
//! amortized-`O(n)` [`Mat::push_row`], and the subset's own rows are
//! kept flat so the per-add kernel column needs no subset-matrix
//! rebuild. Nothing re-layouts per added point.

use crate::kernels::{kernel_column_into, kernel_rows_into, Kernel, KernelBlockScratch};
use crate::kpca::EvictionPolicy;
use crate::linalg::{transpose_into, Mat, Norms, PackedCholesky};

/// Incrementally grown Cholesky-based Nyström approximation.
pub struct CholeskyNystrom<'k> {
    kernel: &'k dyn Kernel,
    x: Mat,
    /// Packed Cholesky factor of the subset Gram (plus jitter).
    chol: PackedCholesky,
    /// `m × n` *transposed* cross-Gram `K_{m,n}`: row `c` holds
    /// `k(x_{s_c}, x_j)` for all `j` — appended per subset point.
    pub kmn: Mat,
    pub subset: Vec<usize>,
    /// Flat row-major copy of the subset's points (`m × dim`),
    /// appended per accepted point.
    sub_x: Vec<f64>,
    /// Diagonal jitter guaranteeing positive-definite expansion.
    pub jitter: f64,
    /// Points rejected because expansion lost positive definiteness.
    pub rejected: usize,
    /// Reusable kernel-column buffer for the appends.
    col_buf: Vec<f64>,
    /// Reusable flat gather of a batch's accepted points (`b × dim`).
    batch_buf: Vec<f64>,
    /// Reusable `b × n` kernel-row block for the batched append.
    rows_buf: Vec<f64>,
    /// Row-norm scratch for the blocked kernel evaluation.
    kb: KernelBlockScratch,
    /// Bounded-memory cap on the subset (0 = unbounded).
    max_landmarks: usize,
    eviction: EvictionPolicy,
    protected: usize,
    /// Landmarks evicted so far (also the round-robin cursor).
    pub evicted: usize,
}

impl<'k> CholeskyNystrom<'k> {
    pub fn new(kernel: &'k dyn Kernel, x: Mat) -> Self {
        let n = x.rows();
        CholeskyNystrom {
            kernel,
            x,
            chol: PackedCholesky::new(),
            kmn: Mat::zeros(0, n),
            subset: Vec::new(),
            sub_x: Vec::new(),
            jitter: 1e-10,
            rejected: 0,
            col_buf: Vec::new(),
            batch_buf: Vec::new(),
            rows_buf: Vec::new(),
            kb: KernelBlockScratch::new(),
            max_landmarks: 0,
            eviction: EvictionPolicy::Off,
            protected: 0,
            evicted: 0,
        }
    }

    /// Cap the subset at `max_landmarks` points (0 = unbounded),
    /// never evicting the first `protected` entries. A Cholesky factor
    /// has no spectrum to score, so [`EvictionPolicy::LeverageScore`]
    /// degrades to the round-robin [`EvictionPolicy::Uniform`] here —
    /// this baseline exists for the ablation bench, and its honest
    /// removal cost (a full `O(m³)` refactorization, see
    /// [`CholeskyNystrom::remove_landmark`]) is part of what the bench
    /// measures against the eigen path's `O(m²)` down-date.
    pub fn set_bound(&mut self, max_landmarks: usize, policy: EvictionPolicy, protected: usize) {
        self.max_landmarks = max_landmarks;
        self.eviction = policy;
        self.protected = protected;
    }

    /// Evict subset position `c`: drop the point from every view, then
    /// rebuild the factor from scratch over the survivors — a bordered
    /// Cholesky expansion has no `O(m²)` inverse for interior rows, so
    /// removal is a full `O(m³)` refactorization (the eigen path's
    /// rank-one down-date is the contribution this baseline contrasts).
    pub fn remove_landmark(&mut self, c: usize) -> Result<(), String> {
        assert!(c < self.m(), "landmark position out of range");
        let dim = self.x.cols();
        self.subset.remove(c);
        self.sub_x.drain(c * dim..(c + 1) * dim);
        self.kmn.remove_row(c);
        self.chol = PackedCholesky::new();
        let mut col = std::mem::take(&mut self.col_buf);
        for i in 0..self.subset.len() {
            let xi = &self.sub_x[i * dim..(i + 1) * dim];
            kernel_column_into(self.kernel, &self.sub_x, dim, i, xi, &mut col);
            let kself = self.kernel.eval(xi, xi) + self.jitter;
            if self.chol.expand(&col, kself).is_err() {
                self.col_buf = col;
                return Err(format!(
                    "refactorization after eviction lost positive definiteness at row {i}"
                ));
            }
        }
        self.col_buf = col;
        self.evicted += 1;
        Ok(())
    }

    /// One bound-enforcement step; callers loop until `None`.
    fn enforce_bound_step(&mut self) -> Result<Option<usize>, String> {
        if self.max_landmarks == 0
            || self.eviction == EvictionPolicy::Off
            || self.m() <= self.max_landmarks
            || self.m() <= self.protected
        {
            return Ok(None);
        }
        let free = self.m() - self.protected;
        let c = self.protected + self.evicted % free;
        self.remove_landmark(c)?;
        Ok(Some(c))
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn m(&self) -> usize {
        self.subset.len()
    }

    /// The factor of the (jittered) subset Gram.
    pub fn factor(&self) -> &PackedCholesky {
        &self.chol
    }

    /// The `n × m` cross-Gram `K_{n,m}` (transposed copy — evaluation
    /// paths only; the stream maintains the `m × n` layout).
    pub fn knm(&self) -> Mat {
        let mut out = Mat::zeros(self.kmn.cols(), self.kmn.rows());
        let mut v = out.view_mut();
        transpose_into(self.kmn.view(), &mut v);
        out
    }

    /// Add evaluation point `idx` to the subset. Returns `false` when
    /// the bordered Cholesky expansion fails (rank-degenerate point).
    /// Amortized `O(n + m·dim)` storage traffic — no re-layout of the
    /// factor or the cross-Gram.
    pub fn add_point(&mut self, idx: usize) -> Result<bool, String> {
        assert!(idx < self.x.rows(), "subset index out of range");
        let dim = self.x.cols();
        let m = self.subset.len();
        let xi = self.x.row(idx);
        // Kernel column against the current subset (flat rows — no
        // subset-matrix rebuild) + jittered self-similarity.
        let mut col = std::mem::take(&mut self.col_buf);
        kernel_column_into(self.kernel, &self.sub_x, dim, m, xi, &mut col);
        let kself = self.kernel.eval(xi, xi) + self.jitter;
        if self.chol.expand(&col, kself).is_err() {
            self.rejected += 1;
            self.col_buf = col;
            return Ok(false);
        }
        // Append the K_{m,n} row k(x_idx, x_j) for all j.
        let n = self.x.rows();
        kernel_column_into(self.kernel, self.x.as_slice(), dim, n, xi, &mut col);
        self.kmn.push_row(&col);
        self.col_buf = col;
        self.sub_x.extend_from_slice(xi);
        self.subset.push(idx);
        while self.enforce_bound_step()?.is_some() {}
        Ok(true)
    }

    /// Add a batch of evaluation points. The bordered Cholesky
    /// expansions are inherently sequential (each point's column is
    /// taken against the subset *including* the batch points accepted
    /// before it) and — unlike the eigen path's blocked rank-b update —
    /// there is no spectrum here whose back-rotation could be fused:
    /// the factor row append *is* the whole per-point cost. The
    /// `K_{m,n}` rows of every accepted point are still
    /// computed afterwards as one `b × n` blocked kernel-row evaluation
    /// and appended in order — mirroring
    /// [`super::IncrementalNystrom::add_points`]. Returns the number of
    /// accepted points.
    pub fn add_points(&mut self, idxs: &[usize]) -> Result<usize, String> {
        let n = self.x.rows();
        let dim = self.x.cols();
        let mut acc = std::mem::take(&mut self.batch_buf);
        acc.clear();
        for &idx in idxs {
            assert!(idx < n, "subset index out of range");
            let m = self.subset.len();
            let xi = self.x.row(idx);
            let mut col = std::mem::take(&mut self.col_buf);
            kernel_column_into(self.kernel, &self.sub_x, dim, m, xi, &mut col);
            let kself = self.kernel.eval(xi, xi) + self.jitter;
            let expanded = self.chol.expand(&col, kself).is_ok();
            self.col_buf = col;
            if !expanded {
                self.rejected += 1;
                continue;
            }
            acc.extend_from_slice(xi);
            self.sub_x.extend_from_slice(xi);
            self.subset.push(idx);
        }
        let b = acc.len() / dim.max(1);
        if b > 0 {
            let mut rows = std::mem::take(&mut self.rows_buf);
            kernel_rows_into(
                self.kernel,
                self.x.as_slice(),
                dim,
                n,
                &acc,
                b,
                &mut rows,
                &mut self.kb,
            );
            for r in 0..b {
                self.kmn.push_row(&rows[r * n..(r + 1) * n]);
            }
            self.rows_buf = rows;
        }
        self.batch_buf = acc;
        // Enforce the bound after the cross-Gram appends so every view
        // shrinks in lockstep.
        while self.enforce_bound_step()?.is_some() {}
        Ok(b)
    }

    /// The approximation `K̃ = K_{n,m} (LLᵀ)⁻¹ K_{m,n}` via triangular
    /// solves: `B = L⁻¹ K_{m,n}` then `K̃ = Bᵀ B`.
    pub fn approx_gram(&self) -> Mat {
        let m = self.m();
        let n = self.n();
        if m == 0 {
            return Mat::zeros(n, n);
        }
        // Solve L b = K_{m,n} column-wise (columns of K_{m,n} are the
        // stored kmn columns).
        let mut b = Mat::zeros(m, n);
        let mut rhs = vec![0.0; m];
        let mut y = Vec::with_capacity(m);
        for j in 0..n {
            for i in 0..m {
                rhs[i] = self.kmn[(i, j)];
            }
            self.chol.solve_lower_into(&rhs, &mut y);
            for i in 0..m {
                b[(i, j)] = y[i];
            }
        }
        crate::linalg::matmul(&b.transpose(), &b)
    }

    /// Fig. 2-style error norms against the full Gram.
    pub fn error_norms(&self, k_full: &Mat) -> Norms {
        crate::linalg::sym_norms(&k_full.sub(&self.approx_gram()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::{gram, Rbf};
    use crate::nystrom::IncrementalNystrom;

    #[test]
    fn agrees_with_eigen_based_incremental() {
        let ds = yeast_like(20, 1);
        let kern = Rbf { sigma: 1.0 };
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        let mut eig = IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..8 {
            assert!(chol.add_point(m).unwrap());
            assert!(eig.add_point(m).unwrap());
        }
        let diff = chol.approx_gram().max_abs_diff(&eig.approx_gram());
        assert!(diff < 1e-5, "cholesky vs eigen Nyström diff {diff}");
    }

    #[test]
    fn duplicate_point_rejected() {
        let ds = yeast_like(10, 2);
        let kern = Rbf { sigma: 1.0 };
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        chol.jitter = 0.0; // make degeneracy exact
        assert!(chol.add_point(3).unwrap());
        assert!(!chol.add_point(3).unwrap());
        assert_eq!(chol.rejected, 1);
        assert_eq!(chol.m(), 1);
        // The failed expansion left the factor and cross-Gram intact.
        assert_eq!(chol.factor().order(), 1);
        assert_eq!(chol.kmn.rows(), 1);
        assert!(chol.add_point(4).unwrap());
        assert_eq!(chol.m(), 2);
    }

    #[test]
    fn batched_add_points_matches_sequential_cholesky() {
        let ds = yeast_like(18, 6);
        let kern = Rbf { sigma: 1.0 };
        let mut seq = CholeskyNystrom::new(&kern, ds.x.clone());
        for m in 0..8 {
            assert!(seq.add_point(m).unwrap());
        }
        let mut bat = CholeskyNystrom::new(&kern, ds.x.clone());
        assert_eq!(bat.add_points(&[0, 1, 2]).unwrap(), 3);
        assert_eq!(bat.add_points(&[3, 4, 5, 6, 7]).unwrap(), 5);
        assert_eq!(bat.subset, seq.subset);
        assert_eq!(bat.kmn.rows(), 8);
        assert!(bat.knm().max_abs_diff(&seq.knm()) < 1e-12);
        let diff = bat.approx_gram().max_abs_diff(&seq.approx_gram());
        assert!(diff < 1e-10, "batched vs sequential diff {diff}");
    }

    #[test]
    fn batched_add_points_rejects_duplicates_mid_batch() {
        let ds = yeast_like(10, 7);
        let kern = Rbf { sigma: 1.0 };
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        chol.jitter = 0.0; // make degeneracy exact
        let accepted = chol.add_points(&[3, 3, 4]).unwrap();
        assert_eq!(accepted, 2);
        assert_eq!(chol.rejected, 1);
        assert_eq!(chol.subset, vec![3, 4]);
        assert_eq!(chol.kmn.rows(), 2);
        assert_eq!(chol.factor().order(), 2);
    }

    #[test]
    fn remove_landmark_refactorizes_exactly() {
        // Eviction + refactorization must equal a fresh build over the
        // surviving subset — bit-for-bit on the factor's approximation.
        let ds = yeast_like(16, 8);
        let kern = Rbf { sigma: 1.0 };
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        for m in 0..7 {
            assert!(chol.add_point(m).unwrap());
        }
        chol.remove_landmark(2).unwrap();
        assert_eq!(chol.m(), 6);
        assert_eq!(chol.subset, vec![0, 1, 3, 4, 5, 6]);
        assert_eq!(chol.kmn.rows(), 6);
        assert_eq!(chol.factor().order(), 6);
        let mut fresh = CholeskyNystrom::new(&kern, ds.x.clone());
        for &idx in &[0usize, 1, 3, 4, 5, 6] {
            assert!(fresh.add_point(idx).unwrap());
        }
        let diff = chol.approx_gram().max_abs_diff(&fresh.approx_gram());
        assert!(diff < 1e-12, "refactorized vs fresh diff {diff}");
    }

    #[test]
    fn bounded_cholesky_subset_holds_cap() {
        let ds = yeast_like(20, 9);
        let kern = Rbf { sigma: 1.0 };
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        chol.set_bound(5, EvictionPolicy::Uniform, 2);
        for m in 0..12 {
            assert!(chol.add_point(m).unwrap());
        }
        assert_eq!(chol.m(), 5, "cap must hold");
        assert_eq!(chol.evicted, 12 - 5);
        assert_eq!(&chol.subset[..2], &[0, 1], "protected prefix evicted");
        assert_eq!(chol.kmn.rows(), 5);
        assert_eq!(chol.factor().order(), 5);
    }

    #[test]
    fn empty_subset_zero_approximation() {
        let ds = yeast_like(6, 3);
        let kern = Rbf { sigma: 1.0 };
        let chol = CholeskyNystrom::new(&kern, ds.x.clone());
        assert_eq!(chol.approx_gram().max_abs(), 0.0);
        let k = gram(&kern, &ds.x);
        let norms = chol.error_norms(&k);
        assert!((norms.frobenius - crate::linalg::frobenius(&k)).abs() < 1e-12);
    }

    #[test]
    fn transposed_layout_and_amortized_growth() {
        // The cross-Gram is kept m × n and appended per point; the
        // packed factor grows by Vec append — reallocations stay far
        // below the number of added points.
        let ds = yeast_like(40, 4);
        let kern = Rbf { sigma: 1.0 };
        let k_full = gram(&kern, &ds.x);
        let mut chol = CholeskyNystrom::new(&kern, ds.x.clone());
        for m in 0..32 {
            assert!(chol.add_point(m).unwrap());
        }
        assert_eq!(chol.kmn.rows(), 32);
        assert_eq!(chol.kmn.cols(), 40);
        assert!(chol.factor().reallocs() < 12, "reallocs {}", chol.factor().reallocs());
        // kmn rows are true kernel columns.
        for c in [0usize, 13, 31] {
            for j in 0..40 {
                let expect = k_full[(chol.subset[c], j)];
                assert!((chol.kmn[(c, j)] - expect).abs() < 1e-12);
            }
        }
        // knm() is the batch-layout transpose.
        let knm = chol.knm();
        assert_eq!(knm.rows(), 40);
        assert_eq!(knm.cols(), 32);
        assert!((knm[(7, 3)] - chol.kmn[(3, 7)]).abs() == 0.0);
    }
}
