//! The padding contract between the bucket-laddered AOT artifacts and
//! live problem sizes:
//!
//! * **data rows** pad with zeros — RBF distances to a zero-padded
//!   *feature* dimension are unchanged, and zero *rows* produce garbage
//!   entries the caller slices away;
//! * **z weights** pad with `0` — padded coordinates contribute nothing
//!   to the rotation (kernel multiplies by `z`);
//! * **eigenvalues** pad with ascending sentinels far above any real
//!   spectrum (`SENTINEL + j`), keeping denominators `λⱼ − λ̃ᵢ` huge so
//!   padded columns stay finite and bounded before being sliced away.

use crate::linalg::Mat;

/// Base value for sentinel eigenvalues. Real kernel eigenvalues in this
/// system are ≤ `n·max k(x,x)` ≲ 1e6; 1e12 keeps sentinel gaps dominant.
pub const SENTINEL: f64 = 1e12;

/// Zero-pad a matrix to `rows × cols`.
pub fn pad_mat(a: &Mat, rows: usize, cols: usize) -> Mat {
    assert!(rows >= a.rows() && cols >= a.cols());
    let mut p = Mat::zeros(rows, cols);
    for i in 0..a.rows() {
        for j in 0..a.cols() {
            p[(i, j)] = a[(i, j)];
        }
    }
    p
}

/// Zero-pad a vector to `len`.
pub fn pad_zeros(v: &[f64], len: usize) -> Vec<f64> {
    assert!(len >= v.len());
    let mut p = v.to_vec();
    p.resize(len, 0.0);
    p
}

/// Pad eigenvalues with ascending sentinels (`offset` shifts the
/// sentinel series so poles and roots never collide with each other).
pub fn pad_sentinels(v: &[f64], len: usize, offset: f64) -> Vec<f64> {
    assert!(len >= v.len());
    let mut p = v.to_vec();
    for j in p.len()..len {
        p.push(SENTINEL + j as f64 + offset);
    }
    p
}

/// Slice the leading `rows × cols` block out of a padded result.
pub fn unpad_mat(a: &Mat, rows: usize, cols: usize) -> Mat {
    a.submatrix(rows, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_roundtrip() {
        let a = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f64);
        let p = pad_mat(&a, 8, 8);
        assert_eq!(p[(2, 1)], 5.0);
        assert_eq!(p[(3, 0)], 0.0);
        assert!(unpad_mat(&p, 3, 2).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn sentinels_ascend_and_dont_collide() {
        let poles = pad_sentinels(&[1.0, 2.0], 6, 0.0);
        let roots = pad_sentinels(&[1.5, 2.5], 6, 0.5);
        for w in poles.windows(2) {
            assert!(w[0] < w[1]);
        }
        for (p, r) in poles.iter().zip(roots.iter()).skip(2) {
            assert!((p - r).abs() > 0.4);
        }
    }

    #[test]
    fn pad_zeros_length() {
        assert_eq!(pad_zeros(&[1.0], 3), vec![1.0, 0.0, 0.0]);
    }
}
