//! The blocked rank-b eigen-update: fold a batch's per-update
//! back-rotations into one pending product, apply it as a single engine
//! GEMM.
//!
//! # Why this is sound
//!
//! A clean (no-deflation) rank-one update factors as `U ← U·W` with `W`
//! built purely from the *eigenvalues* and the projected weight vector
//! `z = Uᵀv` — never from `U`'s entries themselves. So a run of `j`
//! clean updates is `U ← U·(W₁·…·W_j)`, and the product can be
//! accumulated in `r × r` scratch while `U` stays untouched:
//!
//! - the eigenvalues after each update are the secular roots, available
//!   without rotating anything;
//! - the next update's weight vector is recovered through the pending
//!   product, `z = Qᵀ(Uᵀv)` — two GEMVs instead of a rotated basis;
//! - an expansion embeds as `Q ← diag(Q, 1)` followed by the sorted
//!   insertion's *column permutation applied to `Q`* (the basis only
//!   gains its untouched identity row/column).
//!
//! The two situations that do reach into `U` — a deflation Givens
//! rotation for (near-)repeated eigenvalues, and the deflated-index
//! scatter/sort — are exactly what [`crate::secular::is_clean`]
//! screens for; a dirty update flushes the pending product and runs the
//! ordinary sequential path, then accumulation resumes.
//!
//! # What it buys
//!
//! Sequential, a batch of `b` points costs `2b`–`4b` engine
//! back-rotation GEMMs against the `m × r` basis; fused it costs the
//! same number of *native* `r × r` accumulation products plus **one**
//! engine GEMM at the flush. The flop count is comparable for a square
//! basis (`r ≈ m`) — the win is engine dispatches (PJRT launch/padding
//! overhead, one double-buffer commit instead of `b`) and it grows to a
//! real flop win whenever `U` is taller than wide (top-`r` trackers,
//! `m > r`). `UpdateWorkspace::engine_gemms` measures the difference.

use crate::linalg::{MatView, MatViewMut};
use crate::secular::is_clean;

use super::workspace::ensure_f64;
use super::{EigenBasis, Rotate, UpdateStats, UpdateWorkspace, DEFAULT_DEFLATE_TOL};

/// [`rank_one_update_fused_tol_ws`] at the default deflation tolerance.
pub fn rank_one_update_fused_ws(
    vals: &mut Vec<f64>,
    vecs: &mut EigenBasis,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
    ws: &mut UpdateWorkspace,
) -> Result<UpdateStats, String> {
    rank_one_update_fused_tol_ws(vals, vecs, sigma, v, engine, DEFAULT_DEFLATE_TOL, ws)
}

/// Deferred form of [`super::rank_one_update_tol_ws`]: when the update
/// is clean (nothing would deflate), its rotation is folded into the
/// workspace's pending product instead of being applied to `vecs` — no
/// engine GEMM, no basis write. When deflation makes deferral unsound,
/// the pending product is flushed and the update runs sequentially.
///
/// Until [`flush_rotation_ws`] is called, `vecs` holds a *stale* basis:
/// the true eigenvectors are `vecs · Q`. Callers must flush before any
/// read of the basis (projection, reconstruction, cloning) and before
/// handing the eigensystem to code unaware of the pending state.
pub fn rank_one_update_fused_tol_ws(
    vals: &mut Vec<f64>,
    vecs: &mut EigenBasis,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
    tol: f64,
    ws: &mut UpdateWorkspace,
) -> Result<UpdateStats, String> {
    let n = vals.len();
    // While a product is pending the stale basis may be *wider* than
    // the eigenvalue count (deferred removals drop columns from Q, not
    // from U); the effective basis U·Q always has one column per value.
    if ws.q_dim == 0 {
        assert_eq!(vecs.cols(), n, "one eigenvector column per eigenvalue");
    } else {
        assert_eq!(ws.q_dim, n, "pending rotation order mismatch");
        assert_eq!(ws.q_rows, vecs.cols(), "pending rotation rows must match the stale basis");
    }
    assert_eq!(vecs.rows(), v.len(), "v must live in the row space of vecs");
    if n == 0 || sigma == 0.0 {
        return Ok(UpdateStats::default());
    }
    debug_assert!(
        vals.windows(2).all(|w| w[0] <= w[1]),
        "eigenvalues must be ascending"
    );

    // z = Qᵀ(Uᵀv) — the perturbation projected into the *effective*
    // basis U·Q; with nothing pending this is the ordinary Uᵀv. After a
    // deferred removal Q is rectangular (`q_rows × n`, `q_rows > n`),
    // so the intermediate Uᵀv lives in the stale basis's column space.
    let qr = vecs.cols();
    ensure_f64(&mut ws.zq, qr, &mut ws.reallocs);
    crate::linalg::gemv_t_into(vecs.view(), v, &mut ws.zq);
    ensure_f64(&mut ws.z, n, &mut ws.reallocs);
    if ws.q_dim > 0 {
        crate::linalg::gemv_t_into(MatView::new(&ws.q, qr, n, n), &ws.zq, &mut ws.z);
    } else {
        ws.z.copy_from_slice(&ws.zq);
    }

    // Deflation screen: tiny weights or (near-)repeated eigenvalues
    // need Givens rotations / index scatters on U itself — flush the
    // pending product and run the exact sequential update instead.
    if !is_clean(vals, &ws.z, tol) {
        ws.fused_fallbacks += 1;
        flush_rotation_ws(vecs, engine, ws);
        return super::rank_one_update_tol_ws(vals, vecs, sigma, v, engine, tol, ws);
    }

    // Clean path: secular solve over the full active set, Gu–Eisenstat
    // stabilized weights, and the W factor — all against the current
    // spectrum, no basis access.
    crate::secular::solve_all_into(vals, &ws.z, sigma, &mut ws.roots, &mut ws.reallocs)?;
    ensure_f64(&mut ws.zhat, n, &mut ws.reallocs);
    super::stabilized_weights_into(vals, &ws.z, sigma, &ws.roots, &mut ws.zhat);
    super::assemble_w_into(&ws.zhat, vals, &ws.roots, &mut ws.w, &mut ws.col, &mut ws.reallocs)?;

    // Fold: Q ← Q·W (native q_rows×n product into the double buffer),
    // or seed the product with W when nothing is pending yet.
    if ws.q_dim == 0 {
        ensure_f64(&mut ws.q, n * n, &mut ws.reallocs);
        ws.q.copy_from_slice(&ws.w[..n * n]);
        ws.q_dim = n;
        ws.q_rows = n;
    } else {
        ensure_f64(&mut ws.q_next, qr * n, &mut ws.reallocs);
        let q_view = MatView::new(&ws.q, qr, n, n);
        let w_view = MatView::new(&ws.w, n, n, n);
        let mut out = MatViewMut::new(&mut ws.q_next, qr, n, n);
        crate::linalg::matmul_into_buf(q_view, w_view, &mut out, &mut ws.pack);
        std::mem::swap(&mut ws.q, &mut ws.q_next);
        ws.accum_gemms += 1;
    }
    // The secular roots are ascending and cover every position — the
    // eigenvalues update without any sort.
    for (c, root) in ws.roots.iter().enumerate() {
        vals[c] = root.value;
    }
    ws.fused_updates += 1;
    Ok(UpdateStats { deflated: 0, rotations: 0, solved: n })
}

/// Materialize a pending rotation product: `U ← U·Q` as one engine GEMM
/// into the workspace double buffer, committed by an `O(1)` swap.
/// Returns `true` if a product was pending (and one engine GEMM was
/// dispatched), `false` as a no-op. Idempotent; cheap when clean.
///
/// After deferred eigenpair removals the product is rectangular
/// (`q_rows × q_dim`, `q_rows > q_dim`): the GEMM then also *shrinks*
/// the basis window to `q_dim` columns — the columns the removals
/// logically dropped never materialize.
pub fn flush_rotation_ws(
    vecs: &mut EigenBasis,
    engine: &dyn Rotate,
    ws: &mut UpdateWorkspace,
) -> bool {
    let n = ws.q_dim;
    if n == 0 {
        return false;
    }
    let qr = ws.q_rows;
    debug_assert_eq!(vecs.cols(), qr, "pending rotation rows must match the basis");
    let m = vecs.rows();
    let stride = vecs.stride();
    let out_len = vecs.data_len();
    ensure_f64(&mut ws.rotated, out_len, &mut ws.reallocs);
    {
        let q_view = MatView::new(&ws.q, qr, n, n);
        let out_view = MatViewMut::new(&mut ws.rotated, m, n, stride);
        engine.rotate_into_buf(vecs.view(), q_view, out_view, &mut ws.pack);
    }
    vecs.swap_data(&mut ws.rotated);
    if n < qr {
        vecs.shrink_cols(n);
    }
    ws.q_dim = 0;
    ws.q_rows = 0;
    ws.engine_gemms += 1;
    ws.flushes += 1;
    true
}

/// Expansion step while a rotation is pending (called from
/// [`super::expand_eigensystem_ws`] *after* the basis gained its
/// identity row/column and `vals` its trailing entry): extend the
/// product to `diag(Q, 1)` and apply the sorted-insertion column
/// permutation to `Q` and `vals` — `U` is left untouched.
pub(super) fn expand_pending_rotation(vals: &mut [f64], ws: &mut UpdateWorkspace) {
    let n = ws.q_dim;
    let qr = ws.q_rows;
    let n1 = n + 1;
    let r1 = qr + 1;
    debug_assert_eq!(vals.len(), n1);
    // diag(Q, 1) re-layout into the double buffer (row stride changes
    // from n to n+1, so this cannot be done in place front-to-back).
    // The new basis column (identity row/column in `U`) couples only to
    // the new product row, so the embed stays exact for rectangular Q.
    ensure_f64(&mut ws.q_next, r1 * n1, &mut ws.reallocs);
    for i in 0..qr {
        ws.q_next[i * n1..i * n1 + n].copy_from_slice(&ws.q[i * n..(i + 1) * n]);
        ws.q_next[i * n1 + n] = 0.0;
    }
    ws.q_next[qr * n1..r1 * n1].fill(0.0);
    ws.q_next[qr * n1 + n] = 1.0;
    std::mem::swap(&mut ws.q, &mut ws.q_next);
    ws.q_dim = n1;
    ws.q_rows = r1;
    // Restore ascending order: the new eigenvalue sits at the end; move
    // it (and Q's last column) to its sorted slot by a right-rotation.
    let new_val = vals[n];
    let p = vals[..n].partition_point(|&x| x <= new_val);
    if p < n {
        vals[p..].rotate_right(1);
        for i in 0..r1 {
            let row = &mut ws.q[i * n1..(i + 1) * n1];
            row[p..].rotate_right(1);
        }
    }
}

/// Drop column `c` of the pending product (the deferred form of
/// [`EigenBasis::remove_col`]): re-layout `q_rows × q_dim` →
/// `q_rows × (q_dim − 1)` through the double buffer. `Q` keeps its row
/// count — the stale basis is untouched, so `U·Q` simply loses the
/// removed eigenvector — and the rectangle collapses at the next
/// [`flush_rotation_ws`].
pub(super) fn remove_pending_col(ws: &mut UpdateWorkspace, c: usize) {
    let n = ws.q_dim;
    let qr = ws.q_rows;
    debug_assert!(n > 0 && c < n, "remove_pending_col without a pending product");
    let n1 = n - 1;
    ensure_f64(&mut ws.q_next, qr * n1.max(1), &mut ws.reallocs);
    for i in 0..qr {
        let src = &ws.q[i * n..(i + 1) * n];
        let dst = &mut ws.q_next[i * n1..(i + 1) * n1];
        dst[..c].copy_from_slice(&src[..c]);
        dst[c..].copy_from_slice(&src[c + 1..]);
    }
    std::mem::swap(&mut ws.q, &mut ws.q_next);
    ws.q_dim = n1;
}

/// Remove eigenpair `c` (its eigenvalue and effective eigenvector
/// column) and basis row `row` — the structural half of a rank-one
/// *down-date*, run after the decoupling updates have isolated the
/// eigenpair. Deferred-aware: while a blocked-batch product is pending
/// the column is dropped from `Q` (no flush, no engine GEMM — row
/// removal commutes with the right-rotation); otherwise it is dropped
/// from the basis directly.
pub fn remove_eigenpair_ws(
    vals: &mut Vec<f64>,
    vecs: &mut EigenBasis,
    c: usize,
    row: usize,
    ws: &mut UpdateWorkspace,
) {
    assert!(c < vals.len(), "eigenpair index out of range");
    if ws.q_dim > 0 {
        debug_assert_eq!(ws.q_dim, vals.len());
        remove_pending_col(ws, c);
    } else {
        vecs.remove_col(c);
    }
    vecs.remove_row(row);
    vals.remove(c);
}

/// Row `i` of the *effective* basis — `U·Q` while a product is pending,
/// `U` itself otherwise — written into `out` (resized to the eigenpair
/// count). The down-date uses this to locate a decoupled eigenpair
/// without forcing a flush; `O(q_rows · q_dim)` worst case.
pub fn effective_row_into(
    vecs: &EigenBasis,
    ws: &UpdateWorkspace,
    i: usize,
    out: &mut Vec<f64>,
) {
    let u_row = vecs.row(i);
    if ws.q_dim == 0 {
        out.clear();
        out.extend_from_slice(u_row);
        return;
    }
    let (qr, n) = (ws.q_rows, ws.q_dim);
    debug_assert_eq!(u_row.len(), qr);
    out.clear();
    out.resize(n, 0.0);
    for (k, &u) in u_row.iter().enumerate() {
        if u != 0.0 {
            let qrow = &ws.q[k * n..(k + 1) * n];
            for (o, &q) in out.iter_mut().zip(qrow) {
                *o += u * q;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigh, orthogonality_defect, Mat};
    use crate::rankone::{expand_eigensystem_ws, rank_one_update_ws, NativeRotate};
    use crate::util::Rng;

    fn rand_sym(n: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.range(-1.0, 1.0);
                m[(i, j)] = v;
                m[(j, i)] = v;
            }
        }
        m
    }

    /// A run of clean updates, accumulated then flushed, must match the
    /// same updates applied sequentially — and dispatch one engine GEMM
    /// instead of one per update.
    #[test]
    fn fused_run_matches_sequential_with_one_gemm() {
        let n = 12;
        let mut rng = Rng::new(41);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();

        let mut vals_s = eg.values.clone();
        let mut basis_s = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws_s = UpdateWorkspace::new();
        let mut vals_f = eg.values.clone();
        let mut basis_f = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws_f = UpdateWorkspace::new();

        let updates: Vec<(f64, Vec<f64>)> = (0..6)
            .map(|_| {
                let sigma = rng.range(0.3, 1.5);
                let v: Vec<f64> = (0..n).map(|_| rng.range(-0.8, 0.8)).collect();
                (sigma, v)
            })
            .collect();
        for (sigma, v) in &updates {
            rank_one_update_ws(&mut vals_s, &mut basis_s, *sigma, v, &NativeRotate, &mut ws_s)
                .unwrap();
            rank_one_update_fused_ws(
                &mut vals_f,
                &mut basis_f,
                *sigma,
                v,
                &NativeRotate,
                &mut ws_f,
            )
            .unwrap();
        }
        assert!(ws_f.pending_rotation());
        assert!(flush_rotation_ws(&mut basis_f, &NativeRotate, &mut ws_f));
        assert!(!flush_rotation_ws(&mut basis_f, &NativeRotate, &mut ws_f), "idempotent");

        assert_eq!(ws_s.engine_gemms(), 6);
        assert_eq!(ws_f.engine_gemms(), 1, "fused run must dispatch exactly one GEMM");
        assert_eq!(ws_f.fused_updates(), 6);
        for (a, b) in vals_s.iter().zip(&vals_f) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        assert!(basis_f.max_abs_diff(&basis_s.to_mat()) < 1e-10);
        assert!(orthogonality_defect(&basis_f) < 1e-9);
    }

    /// Expansions mid-run defer into the product (diag-embed + column
    /// permutation) and still match the sequential result.
    #[test]
    fn deferred_expansion_matches_sequential() {
        let n = 8;
        let mut rng = Rng::new(43);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();

        let mut vals_s = eg.values.clone();
        let mut basis_s = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws_s = UpdateWorkspace::new();
        let mut vals_f = eg.values.clone();
        let mut basis_f = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws_f = UpdateWorkspace::new();

        // Interleave updates and expansions the way a batch of points
        // does: (update, update, expand) × 3 — the expansion value is
        // chosen interior so the sorted insertion actually permutes.
        for step in 0..3 {
            for _ in 0..2 {
                let sigma = rng.range(0.3, 1.2);
                let k = vals_s.len();
                let v: Vec<f64> = (0..k).map(|_| rng.range(-0.8, 0.8)).collect();
                rank_one_update_ws(&mut vals_s, &mut basis_s, sigma, &v, &NativeRotate, &mut ws_s)
                    .unwrap();
                rank_one_update_fused_ws(
                    &mut vals_f,
                    &mut basis_f,
                    sigma,
                    &v,
                    &NativeRotate,
                    &mut ws_f,
                )
                .unwrap();
            }
            let mid = 0.5 * (vals_s[0] + vals_s[vals_s.len() - 1]) + 0.01 * step as f64;
            expand_eigensystem_ws(&mut vals_s, &mut basis_s, mid, &mut ws_s);
            expand_eigensystem_ws(&mut vals_f, &mut basis_f, mid, &mut ws_f);
        }
        flush_rotation_ws(&mut basis_f, &NativeRotate, &mut ws_f);
        assert_eq!(vals_s.len(), n + 3);
        for (a, b) in vals_s.iter().zip(&vals_f) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        assert!(basis_f.max_abs_diff(&basis_s.to_mat()) < 1e-10);
        assert!(ws_f.engine_gemms() < ws_s.engine_gemms());
    }

    /// An update that must deflate (exactly repeated eigenvalues from a
    /// duplicated expansion value — the duplicate-point scenario)
    /// flushes the pending product, falls back, and stays exact against
    /// a sequential twin.
    #[test]
    fn deflating_update_falls_back_and_stays_exact() {
        let n = 6;
        let mut rng = Rng::new(47);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values.clone();
        let mut basis = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();
        let mut vals_s = eg.values.clone();
        let mut basis_s = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws_s = UpdateWorkspace::new();

        // One clean update to get a pending product…
        let v: Vec<f64> = (0..n).map(|_| rng.range(-0.8, 0.8)).collect();
        rank_one_update_fused_ws(&mut vals, &mut basis, 0.9, &v, &NativeRotate, &mut ws).unwrap();
        rank_one_update_ws(&mut vals_s, &mut basis_s, 0.9, &v, &NativeRotate, &mut ws_s)
            .unwrap();
        assert!(ws.pending_rotation());
        // …then expand with an eigenvalue that already exists: the next
        // update sees an exactly repeated pole — a deflation Givens
        // must fire, which cannot fold into the pending product.
        let dup = vals[3];
        expand_eigensystem_ws(&mut vals, &mut basis, dup, &mut ws);
        expand_eigensystem_ws(&mut vals_s, &mut basis_s, dup, &mut ws_s);
        assert!(ws.pending_rotation(), "expansion alone must not force a flush");
        let v2: Vec<f64> = (0..n + 1).map(|_| rng.range(-0.8, 0.8)).collect();
        let stats = rank_one_update_fused_ws(
            &mut vals,
            &mut basis,
            0.5,
            &v2,
            &NativeRotate,
            &mut ws,
        )
        .unwrap();
        let stats_s =
            rank_one_update_ws(&mut vals_s, &mut basis_s, 0.5, &v2, &NativeRotate, &mut ws_s)
                .unwrap();
        assert!(!ws.pending_rotation(), "fallback must flush the pending product");
        assert_eq!(ws.fused_fallbacks(), 1);
        assert!(stats.rotations > 0, "repeated pole must trigger a deflation Givens");
        assert!(stats_s.rotations > 0);
        for (a, b) in vals.iter().zip(&vals_s) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        // Within the formerly degenerate pair the individual
        // eigenvectors are only unique up to a rotation — compare the
        // reconstruction, which is invariant.
        let rec = |vals: &[f64], basis: &EigenBasis| {
            let mut vl = basis.to_mat();
            for i in 0..vl.rows() {
                for j in 0..vl.cols() {
                    vl[(i, j)] *= vals[j];
                }
            }
            crate::linalg::matmul_nt(&vl, basis)
        };
        let diff = rec(&vals, &basis).max_abs_diff(&rec(&vals_s, &basis_s));
        assert!(diff < 1e-10, "reconstruction diff {diff}");
        assert!(orthogonality_defect(&basis) < 1e-9);
        for w in vals.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    /// Against the dense ground truth: accumulate a run over a growing
    /// eigensystem, flush, and compare the reconstruction.
    #[test]
    fn fused_reconstruction_matches_dense() {
        let n = 10;
        let mut rng = Rng::new(53);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values.clone();
        let mut basis = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();
        let mut dense = a.clone();
        for _ in 0..5 {
            let sigma = rng.range(0.2, 1.0);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-0.7, 0.7)).collect();
            dense.syr(sigma, &v);
            rank_one_update_fused_ws(&mut vals, &mut basis, sigma, &v, &NativeRotate, &mut ws)
                .unwrap();
        }
        flush_rotation_ws(&mut basis, &NativeRotate, &mut ws);
        let expect = eigh(&dense).unwrap();
        for (u, w) in vals.iter().zip(expect.values.iter()) {
            assert!((u - w).abs() < 1e-8, "{u} vs {w}");
        }
        let rec = {
            let mut vl = basis.to_mat();
            for i in 0..n {
                for j in 0..n {
                    vl[(i, j)] *= vals[j];
                }
            }
            crate::linalg::matmul_nt(&vl, &basis)
        };
        assert!(rec.max_abs_diff(&dense) < 1e-8);
    }

    /// The sequential entry point must transparently flush a pending
    /// product left by the fused path.
    #[test]
    fn sequential_update_flushes_pending_product() {
        let n = 7;
        let mut rng = Rng::new(59);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values.clone();
        let mut basis = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();
        let v: Vec<f64> = (0..n).map(|_| rng.range(-0.8, 0.8)).collect();
        rank_one_update_fused_ws(&mut vals, &mut basis, 0.8, &v, &NativeRotate, &mut ws).unwrap();
        assert!(ws.pending_rotation());
        let v2: Vec<f64> = (0..n).map(|_| rng.range(-0.8, 0.8)).collect();
        rank_one_update_ws(&mut vals, &mut basis, 0.6, &v2, &NativeRotate, &mut ws).unwrap();
        assert!(!ws.pending_rotation());
        assert!(orthogonality_defect(&basis) < 1e-10);
    }

    /// Removing an eigenpair while a product is pending (column dropped
    /// from `Q`, row from `U`) must land on the same eigensystem as
    /// flushing first and removing from the basis directly — including
    /// a further fused update applied across the removal.
    #[test]
    fn deferred_removal_matches_flushed_removal() {
        let n = 9;
        let mut rng = Rng::new(67);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();

        let mut vals_d = eg.values.clone();
        let mut basis_d = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws_d = UpdateWorkspace::new();
        let mut vals_f = eg.values.clone();
        let mut basis_f = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws_f = UpdateWorkspace::new();

        // Two clean updates to build a pending product on both twins.
        for _ in 0..2 {
            let sigma = rng.range(0.3, 1.2);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-0.8, 0.8)).collect();
            rank_one_update_fused_ws(&mut vals_d, &mut basis_d, sigma, &v, &NativeRotate, &mut ws_d)
                .unwrap();
            rank_one_update_fused_ws(&mut vals_f, &mut basis_f, sigma, &v, &NativeRotate, &mut ws_f)
                .unwrap();
        }
        let (c, row) = (3, 5);
        // Twin F: flush, then remove from the materialized basis.
        assert!(flush_rotation_ws(&mut basis_f, &NativeRotate, &mut ws_f));
        remove_eigenpair_ws(&mut vals_f, &mut basis_f, c, row, &mut ws_f);
        // Twin D: remove while pending — Q goes rectangular.
        remove_eigenpair_ws(&mut vals_d, &mut basis_d, c, row, &mut ws_d);
        assert!(ws_d.pending_rotation(), "deferred removal must not flush");
        assert_eq!(ws_d.q_rows, n, "product keeps its row count");
        assert_eq!(ws_d.q_dim, n - 1, "product loses the removed column");

        // One more update across the removal on both twins (same data),
        // then materialize and compare.
        let v: Vec<f64> = (0..n - 1).map(|_| rng.range(-0.6, 0.6)).collect();
        rank_one_update_fused_ws(&mut vals_d, &mut basis_d, 0.7, &v, &NativeRotate, &mut ws_d)
            .unwrap();
        rank_one_update_fused_ws(&mut vals_f, &mut basis_f, 0.7, &v, &NativeRotate, &mut ws_f)
            .unwrap();
        flush_rotation_ws(&mut basis_d, &NativeRotate, &mut ws_d);
        flush_rotation_ws(&mut basis_f, &NativeRotate, &mut ws_f);
        assert_eq!(basis_d.rows(), n - 1);
        assert_eq!(basis_d.cols(), n - 1);
        for (a, b) in vals_d.iter().zip(&vals_f) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        assert!(basis_d.max_abs_diff(&basis_f.to_mat()) < 1e-10);
    }

    /// `effective_row_into` reads through the pending product: it must
    /// agree with the same row after a flush.
    #[test]
    fn effective_row_reads_through_pending_product() {
        let n = 7;
        let mut rng = Rng::new(71);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values.clone();
        let mut basis = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();
        for _ in 0..3 {
            let sigma = rng.range(0.3, 1.0);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-0.8, 0.8)).collect();
            rank_one_update_fused_ws(&mut vals, &mut basis, sigma, &v, &NativeRotate, &mut ws)
                .unwrap();
        }
        assert!(ws.pending_rotation());
        let mut through = Vec::new();
        effective_row_into(&basis, &ws, 4, &mut through);
        flush_rotation_ws(&mut basis, &NativeRotate, &mut ws);
        let mut direct = Vec::new();
        effective_row_into(&basis, &ws, 4, &mut direct);
        assert_eq!(through.len(), direct.len());
        for (a, b) in through.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    /// reserve() pre-sizes the blocked-path scratch too: a warm fused
    /// run is allocation-silent.
    #[test]
    fn fused_path_is_zero_realloc_after_reserve() {
        let n = 10;
        let mut rng = Rng::new(61);
        let a = rand_sym(n, &mut rng);
        let eg = eigh(&a).unwrap();
        let mut vals = eg.values.clone();
        let mut basis = EigenBasis::from_mat(eg.vectors.clone());
        let mut ws = UpdateWorkspace::new();
        ws.reserve(n, n);
        ws.reserve_blocked(n);
        basis.reserve(n, n);
        let r0 = ws.reallocs();
        for _ in 0..8 {
            let sigma = rng.range(0.3, 1.0);
            let v: Vec<f64> = (0..n).map(|_| rng.range(-0.7, 0.7)).collect();
            rank_one_update_fused_ws(&mut vals, &mut basis, sigma, &v, &NativeRotate, &mut ws)
                .unwrap();
        }
        flush_rotation_ws(&mut basis, &NativeRotate, &mut ws);
        assert_eq!(ws.reallocs(), r0, "fused steady state must not allocate");
    }
}
