//! Householder reduction of a real symmetric matrix to tridiagonal form,
//! with accumulation of the orthogonal transformation:  `A = Q T Qᵀ`.
//!
//! This is the classic `tred2` procedure (Householder 1958; Martin,
//! Reinsch & Wilkinson 1968), the first phase of the batch symmetric
//! eigensolver that `kpca::batch` and the Chin–Suter baseline rest on.

use super::matrix::Mat;

/// Output of the tridiagonalization.
pub struct Tridiagonal {
    /// Orthogonal accumulation matrix `Q` with `A = Q T Qᵀ`.
    pub q: Mat,
    /// Diagonal of `T`.
    pub d: Vec<f64>,
    /// Sub-diagonal of `T` (`e[0]` is unused / zero; `e[i]` couples
    /// `i-1` and `i`).
    pub e: Vec<f64>,
}

/// Reduce symmetric `a` to tridiagonal form. Only the lower triangle of
/// `a` is referenced.
pub fn tridiagonalize(a: &Mat) -> Tridiagonal {
    assert!(a.is_square(), "tridiagonalize needs a square matrix");
    let n = a.rows();
    let mut z = a.clone();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n];
    if n == 0 {
        return Tridiagonal { q: z, d, e };
    }

    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| z[(i, k)].abs()).sum();
            if scale == 0.0 {
                e[i] = z[(i, l)];
            } else {
                for k in 0..=l {
                    z[(i, k)] /= scale;
                    h += z[(i, k)] * z[(i, k)];
                }
                let f = z[(i, l)];
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                z[(i, l)] = f - g;
                let mut fsum = 0.0;
                for j in 0..=l {
                    // Store u/H in column i for later accumulation.
                    z[(j, i)] = z[(i, j)] / h;
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += z[(j, k)] * z[(i, k)];
                    }
                    for k in (j + 1)..=l {
                        g += z[(k, j)] * z[(i, k)];
                    }
                    e[j] = g / h;
                    fsum += e[j] * z[(i, j)];
                }
                let hh = fsum / (h + h);
                for j in 0..=l {
                    let f = z[(i, j)];
                    let gj = e[j] - hh * f;
                    e[j] = gj;
                    for k in 0..=j {
                        let delta = f * e[k] + gj * z[(i, k)];
                        z[(j, k)] -= delta;
                    }
                }
            }
        } else {
            e[i] = z[(i, l)];
        }
        d[i] = h;
    }

    // Accumulate transformation matrices.
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += z[(i, k)] * z[(k, j)];
                }
                for k in 0..i {
                    let delta = g * z[(k, i)];
                    z[(k, j)] -= delta;
                }
            }
        }
        d[i] = z[(i, i)];
        z[(i, i)] = 1.0;
        for j in 0..i {
            z[(j, i)] = 0.0;
            z[(i, j)] = 0.0;
        }
    }

    Tridiagonal { q: z, d, e }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;

    fn sym(n: usize, f: impl Fn(usize, usize) -> f64) -> Mat {
        let mut m = Mat::from_fn(n, n, |i, j| f(i.min(j), i.max(j)));
        m.symmetrize();
        m
    }

    fn reconstruct(t: &Tridiagonal) -> Mat {
        let n = t.d.len();
        let mut tri = Mat::zeros(n, n);
        for i in 0..n {
            tri[(i, i)] = t.d[i];
            if i > 0 {
                tri[(i, i - 1)] = t.e[i];
                tri[(i - 1, i)] = t.e[i];
            }
        }
        matmul(&matmul(&t.q, &tri), &t.q.transpose())
    }

    #[test]
    fn q_is_orthogonal() {
        let a = sym(8, |i, j| 1.0 / (1.0 + i as f64 + j as f64));
        let t = tridiagonalize(&a);
        let qtq = matmul(&t.q.transpose(), &t.q);
        assert!(qtq.max_abs_diff(&Mat::eye(8)) < 1e-12);
    }

    #[test]
    fn reconstruction_matches() {
        let a = sym(10, |i, j| ((i * 3 + j * 7) % 11) as f64 - 5.0);
        let t = tridiagonalize(&a);
        assert!(reconstruct(&t).max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn already_tridiagonal_passthrough() {
        let n = 6;
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = i as f64 + 1.0;
            if i > 0 {
                a[(i, i - 1)] = 0.5;
                a[(i - 1, i)] = 0.5;
            }
        }
        let t = tridiagonalize(&a);
        assert!(reconstruct(&t).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn tiny_sizes() {
        for n in 0..3 {
            let a = sym(n, |i, j| (i + j) as f64 + 1.0);
            let t = tridiagonalize(&a);
            if n > 0 {
                assert!(reconstruct(&t).max_abs_diff(&a) < 1e-12);
            }
        }
    }
}
