//! Micro-benchmarks of the substrate hot paths: blocked GEMM, the
//! symmetric eigensolver, the secular root finder and one full rank-one
//! update — the quantities the §Perf optimization loop tracks.

use inkpca::linalg::{eigh, matmul, Mat};
use inkpca::rankone::{rank_one_update, NativeRotate};
use inkpca::secular::solve_all;
use inkpca::util::bench::Bench;
use inkpca::util::Rng;

fn rand_mat(n: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(n, n, |_, _| rng.range(-1.0, 1.0))
}

fn rand_sym(n: usize, seed: u64) -> Mat {
    let mut m = rand_mat(n, seed);
    m.symmetrize();
    m
}

fn main() {
    let mut b = Bench::new();
    for n in [128usize, 256, 512] {
        let a = rand_mat(n, 1);
        let c = rand_mat(n, 2);
        b.case(&format!("linalg/gemm/n{n}"), || matmul(&a, &c).max_abs());
    }
    for n in [64usize, 128, 256] {
        let s = rand_sym(n, 3);
        b.case(&format!("linalg/eigh/n{n}"), || eigh(&s).unwrap().values[0]);
    }
    for n in [64usize, 256, 1024] {
        let mut rng = Rng::new(4);
        let mut d: Vec<f64> = (0..n).map(|_| rng.range(0.0, 10.0)).collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let z: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        b.case(&format!("secular/solve_all/n{n}"), || {
            solve_all(&d, &z, 1.5).unwrap().len()
        });
    }
    for n in [64usize, 128, 256] {
        let s = rand_sym(n, 5);
        let eg = eigh(&s).unwrap();
        let mut rng = Rng::new(6);
        let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        b.case(&format!("rankone/update/n{n}"), || {
            let mut vals = eg.values.clone();
            let mut vecs = eg.vectors.clone();
            rank_one_update(&mut vals, &mut vecs, 1.0, &v, &NativeRotate).unwrap().solved
        });
    }
    b.finish();
}
