//! `inkpca` — CLI for the incremental kernel PCA / Nyström system.
//!
//! Subcommands:
//!   fig1  [--full]        regenerate Figure 1 (drift curves)
//!   fig2  [--full]        regenerate Figure 2 (Nyström error curves)
//!   flops [--full]        regenerate the §3 cost table (T1)
//!   serve [opts]          run the streaming coordinator on a dataset feed
//!   quickstart            tiny end-to-end sanity run
//!
//! `serve` options: `--dataset magic|yeast`  `--n <pts>`  `--engine native|pjrt`
//!                  `--no-adjust`  `--drift-every <k>`  `--seed-points <k>`
//!                  `--shards <k>`  `--streams <k>`   (multi-stream pool mode)
//!                  `--batch <b>`   (ship points in b-sized `ingest_many`
//!                                  batches instead of per-point rendezvous)
//!                  `--grow <k>` / `--shrink <k>`  (elastic topology: halfway
//!                                  through the feed, add k shards / retire k
//!                                  shards live — streams migrate, handles
//!                                  keep working, nothing restarts)
//!                  `--publish-every <k>`  (snapshot publication cadence on
//!                                  the sequential ingest path; reads are
//!                                  served lock-free from published snapshots)
//!                  `--publish-after-ms <t>`  (wall-clock staleness bound: the
//!                                  next accept publishes once t ms have
//!                                  passed since the last publication)
//!                  `--snapshot-dir <dir>`  (durability: restore from the
//!                                  directory's checkpoints + WAL on start,
//!                                  write-ahead every accepted ingest, and
//!                                  checkpoint on clean exit)
//!                  `--fsync off|every=N|interval_ms=M`  (WAL fsync policy;
//!                                  default off — see `FsyncPolicy`)
//!                  `--max-landmarks <m>`  (bounded memory: cap the retained
//!                                  landmark set at m; every accept past the
//!                                  cap evicts one landmark, so the stream
//!                                  runs in fixed memory forever)
//!                  `--eviction off|uniform|leverage`  (victim policy at the
//!                                  cap; defaults to leverage when a cap is
//!                                  set)
//!                  `--tier exact|rff[:features[:sketch_r]]|shadow[:sample]`
//!                                  (stream engine: the paper-exact
//!                                  eigensystem, the fixed-memory RFF +
//!                                  frequent-directions sketch, or both in
//!                                  shadow with a live divergence gauge)

use inkpca::coordinator::{
    Config, Coordinator, EngineConfig, EnginePolicy, FsyncPolicy, KernelConfig, PersistConfig,
    ShardPool, StreamTier,
};
use inkpca::data::{load, Dataset, SliceSource};
use inkpca::experiments::{self, RunMode};
use inkpca::kpca::EvictionPolicy;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = args.iter().skip(1).cloned().collect();
    let result = match cmd {
        "fig1" => run_fig1(&rest),
        "fig2" => run_fig2(&rest),
        "flops" => run_flops(&rest),
        "serve" => serve(&rest),
        "quickstart" => quickstart(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(format!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "inkpca — incremental kernel PCA and the Nyström method\n\
         usage: inkpca <fig1|fig2|flops|serve|quickstart> [--full] [opts]"
    );
}

fn run_fig1(args: &[String]) -> Result<(), String> {
    let cfg = experiments::Fig1Config::new(RunMode::from_args(args));
    experiments::run_fig1(&cfg)?;
    // S1: the orthogonality column is part of the same CSV; also run the
    // unadjusted variant for the drift comparison the paper describes.
    let mut un = experiments::Fig1Config::new(RunMode::from_args(args));
    un.mean_adjust = false;
    experiments::run_fig1(&un)?;
    Ok(())
}

fn run_fig2(args: &[String]) -> Result<(), String> {
    let cfg = experiments::Fig2Config::new(RunMode::from_args(args));
    experiments::run_fig2(&cfg)?;
    Ok(())
}

fn run_flops(args: &[String]) -> Result<(), String> {
    let cfg = experiments::FlopsConfig::new(RunMode::from_args(args));
    experiments::run_flops(&cfg)?;
    Ok(())
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn serve(args: &[String]) -> Result<(), String> {
    let dataset = flag_value(args, "--dataset").unwrap_or_else(|| "yeast".into());
    let n: usize = flag_value(args, "--n").and_then(|v| v.parse().ok()).unwrap_or(500);
    let engine = match flag_value(args, "--engine").as_deref() {
        Some("pjrt") => EngineConfig::Pjrt {
            dir: "artifacts".into(),
            policy: EnginePolicy::Auto { pjrt_min: 64 },
        },
        _ => EngineConfig::Native,
    };
    let max_landmarks: usize =
        flag_value(args, "--max-landmarks").and_then(|v| v.parse().ok()).unwrap_or(0);
    // A cap without an explicit policy evicts by leverage score; an
    // explicit `--eviction off` turns the cap into a no-op on purpose.
    let eviction = match flag_value(args, "--eviction") {
        Some(name) => EvictionPolicy::from_name(&name)
            .ok_or_else(|| format!("unknown eviction policy '{name}' (off|uniform|leverage)"))?,
        None if max_landmarks > 0 => EvictionPolicy::LeverageScore,
        None => EvictionPolicy::Off,
    };
    let persist = match flag_value(args, "--snapshot-dir") {
        Some(dir) => {
            let mut p = PersistConfig::new(dir);
            if let Some(policy) = flag_value(args, "--fsync") {
                p.fsync = FsyncPolicy::parse(&policy)?;
            }
            Some(p)
        }
        None => None,
    };
    let tier = match flag_value(args, "--tier") {
        Some(spec) => StreamTier::parse(&spec)?,
        None => StreamTier::Exact,
    };
    let cfg = Config {
        kernel: KernelConfig::RbfMedian,
        mean_adjust: !args.iter().any(|a| a == "--no-adjust"),
        engine,
        queue: 64,
        seed_points: flag_value(args, "--seed-points")
            .and_then(|v| v.parse().ok())
            .unwrap_or(20),
        drift_every: flag_value(args, "--drift-every")
            .and_then(|v| v.parse().ok())
            .unwrap_or(100),
        publish_every: flag_value(args, "--publish-every")
            .and_then(|v| v.parse().ok())
            .unwrap_or(64),
        publish_after: flag_value(args, "--publish-after-ms")
            .and_then(|v| v.parse().ok())
            .map(std::time::Duration::from_millis),
        persist,
        max_landmarks,
        eviction,
        tier,
    };
    let mut ds = load(&dataset, n, 42)?;
    ds.standardize();
    let dim = ds.dim();
    let shards: usize =
        flag_value(args, "--shards").and_then(|v| v.parse().ok()).unwrap_or(1);
    let streams: usize =
        flag_value(args, "--streams").and_then(|v| v.parse().ok()).unwrap_or(1);
    let batch: usize =
        flag_value(args, "--batch").and_then(|v| v.parse().ok()).unwrap_or(1).max(1);
    let grow: usize = flag_value(args, "--grow").and_then(|v| v.parse().ok()).unwrap_or(0);
    let shrink: usize =
        flag_value(args, "--shrink").and_then(|v| v.parse().ok()).unwrap_or(0);
    if shards > 1 || streams > 1 || grow > 0 || shrink > 0 {
        return serve_pool(cfg, ds, shards.max(1), streams.max(1), batch, grow, shrink);
    }
    println!("serving {} points of {dataset} (dim {dim}, batch {batch})…", ds.n());
    let probe: Vec<f64> = ds.x.row(0).to_vec();
    let durable = cfg.persist.is_some();
    let coord = if durable {
        let (coord, report) = Coordinator::restore(cfg, dim)?;
        if report.restored + report.from_wal_only > 0 {
            println!(
                "restored {} stream(s) ({} WAL-only), replayed {} record(s), {} torn log(s), {} quarantined checkpoint(s)",
                report.restored + report.from_wal_only,
                report.from_wal_only,
                report.replayed,
                report.torn_logs,
                report.quarantined.len()
            );
        }
        for e in &report.failed {
            eprintln!("restore: {e}");
        }
        coord
    } else {
        Coordinator::spawn(cfg, dim)
    };
    let accepted = if batch > 1 {
        let reply = coord.ingest_all(ds.x.as_slice(), dim, batch)?;
        reply.seeded + reply.accepted
    } else {
        let mut src = SliceSource::new(ds);
        coord.ingest_stream(&mut src)?
    };
    let snap = coord.snapshot()?;
    let metrics = coord.metrics()?;
    println!("ingested: {accepted} accepted, eigensystem m={}", snap.m);
    println!(
        "top eigenvalues: {:?}",
        snap.top_values.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    if let Some(d) = snap.drift {
        println!(
            "last drift @ m={}: fro {:.3e} spec {:.3e} trace {:.3e} ‖UUᵀ−I‖ {:.3e}",
            d.m, d.norms.frobenius, d.norms.spectral, d.norms.trace, d.orthogonality
        );
    }
    println!("engine calls (native, pjrt): {:?}", snap.engine_calls);
    println!("{metrics}");
    // Lock-free read demo: sync publishes the latest snapshot
    // (read-your-writes), then the projection is served without
    // touching the worker queue.
    coord.sync()?;
    let scores = coord.project_snapshot(&probe, 3)?;
    println!(
        "snapshot read (lock-free): top-{} scores {:?}",
        scores.len(),
        scores.iter().map(|v| (v * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    if durable {
        let n = coord.checkpoint_all()?;
        println!("checkpointed {n} stream(s); WAL rotated");
    }
    coord.shutdown();
    Ok(())
}

/// Multi-stream mode: split the feed round-robin over `streams`
/// concurrent streams on a `shards`-shard pool, one producer thread per
/// stream (shipping `batch`-sized `ingest_many` commands when
/// `batch > 1`), then print the pool rollup, per-stream gauges and
/// per-shard occupancy. With `--grow`/`--shrink`, the producers pause
/// at a half-feed barrier while the topology changes live (streams
/// migrate between workers; the producers keep their original handles,
/// which re-route through the router's redirect table), then finish
/// the feed on the new topology.
fn serve_pool(
    cfg: Config,
    ds: Dataset,
    shards: usize,
    streams: usize,
    batch: usize,
    grow: usize,
    shrink: usize,
) -> Result<(), String> {
    let dim = ds.dim();
    let (mut pool_cfg, mut stream_cfg) = cfg.split();
    pool_cfg.shards = shards;
    // Per-stream reserve through the coordinator: each stream's share
    // and batch size are known up front, so the workers pre-size every
    // hot-path buffer at initialization instead of growing across the
    // first batches.
    stream_cfg.expected_m = ds.n().div_ceil(streams);
    stream_cfg.expected_batch = batch;
    if ds.n() / streams <= stream_cfg.seed_points {
        return Err(format!(
            "{} points over {streams} streams leaves ≤ {} per stream — not enough to seed",
            ds.n(),
            stream_cfg.seed_points
        ));
    }
    println!(
        "serving {} points of {} over {streams} streams on {shards} shards (batch {batch})…",
        ds.n(),
        ds.name
    );
    let pool = ShardPool::spawn(pool_cfg);
    let router = pool.router();
    // Handles are opened up front (they are cheap clones) so the
    // snapshot-read demo below can reuse them after the producers join.
    let handles: Vec<_> = (0..streams)
        .map(|s| {
            router
                .open_stream(&format!("stream-{s}"), dim, stream_cfg.clone())
                .expect("open stream")
        })
        .collect();
    let reshape = grow + shrink > 0;
    // Producers + (when resharding) the topology driver rendezvous at
    // the half-feed point.
    let barrier = std::sync::Barrier::new(streams + usize::from(reshape));
    std::thread::scope(|scope| {
        for s in 0..streams {
            let r = router.clone();
            let ds = &ds;
            let h = &handles[s];
            let barrier = &barrier;
            scope.spawn(move || {
                if reshape {
                    // Gather this stream's round-robin share, feed the
                    // first half, hold while the topology changes, then
                    // finish through the SAME handle — migrated streams
                    // re-route via the redirect table.
                    let mine: Vec<f64> = (s..ds.n())
                        .step_by(streams)
                        .flat_map(|i| ds.x.row(i).iter().copied())
                        .collect();
                    let half = (mine.len() / dim / 2) * dim;
                    r.ingest_all(h, &mine[..half], dim, batch).expect("ingest_all");
                    barrier.wait();
                    barrier.wait();
                    r.ingest_all(h, &mine[half..], dim, batch).expect("ingest_all");
                } else if batch > 1 {
                    // Gather this stream's round-robin share once, then
                    // ship it through the shared chunking loop.
                    let mine: Vec<f64> = (s..ds.n())
                        .step_by(streams)
                        .flat_map(|i| ds.x.row(i).iter().copied())
                        .collect();
                    r.ingest_all(h, &mine, dim, batch).expect("ingest_all");
                } else {
                    let mut i = s;
                    while i < ds.n() {
                        r.ingest(h, ds.x.row(i).to_vec()).expect("ingest");
                        i += streams;
                    }
                }
            });
        }
        if reshape {
            barrier.wait();
            for _ in 0..grow {
                let s = router.add_shard().expect("add_shard");
                println!("grew: shard {s} joined the ring");
            }
            for _ in 0..shrink {
                let victim = *router.active_shard_ids().last().expect("non-empty ring");
                match router.remove_shard(victim) {
                    Ok(moved) => println!(
                        "shrunk: shard {victim} retired ({moved} streams migrated off)"
                    ),
                    Err(e) => eprintln!("shrink failed: {e}"),
                }
            }
            barrier.wait();
        }
    });
    // Lock-free read demo: every stream serves a projection straight
    // from its published snapshot (sync first: read-your-writes). These
    // reads never enqueue a shard command — they show up in the rollup
    // as `snapshot_reads` while `worker_reads` stays flat.
    let probe: Vec<f64> = ds.x.row(0).to_vec();
    for h in &handles {
        router.sync(h)?;
        router.project_many(h, &probe, 3)?;
    }
    let snap = router.pool_snapshot()?;
    println!("{snap}");
    for o in &snap.per_shard {
        println!(
            "  shard {}{}: {} streams, ws={}B, migrated in/out {}/{}",
            o.shard,
            if o.active { "" } else { " (retired)" },
            o.streams,
            o.ws_bytes_resident,
            o.migrated_in,
            o.migrated_out
        );
    }
    for g in &snap.per_stream {
        println!(
            "  {} @ shard {}: m={} ws={}B reallocs/update={:.4} rotation_gemms={} drift={} snapshot(epoch={} reads={}/{} lag={})",
            g.stream,
            g.shard,
            g.m,
            g.ws_bytes_resident,
            g.reallocs_per_update,
            g.engine_gemms,
            g.drift_frobenius.map(|d| format!("{d:.3e}")).unwrap_or_else(|| "–".into()),
            g.snapshot_epoch,
            g.snapshot_reads,
            g.worker_reads,
            g.points_since_publish
        );
    }
    if cfg.persist.is_some() {
        let n = router.checkpoint_all()?;
        println!("checkpointed {n} stream(s); WAL rotated");
    }
    pool.shutdown();
    Ok(())
}

fn quickstart() -> Result<(), String> {
    use inkpca::kernels::{median_heuristic, Rbf};
    use inkpca::kpca::IncrementalKpca;
    let mut ds = load("yeast", 60, 1)?;
    ds.standardize();
    let kern = Rbf { sigma: median_heuristic(&ds.x, 100) };
    let seed = ds.x.submatrix(20, ds.dim());
    let mut inc = IncrementalKpca::from_batch(&kern, &seed, true)?;
    for i in 20..ds.n() {
        inc.push(ds.x.row(i))?;
    }
    let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
    println!("quickstart: m={} drift={drift:.3e}", inc.len());
    println!("top-3 eigenvalues: {:?}", inc.vals.iter().rev().take(3).collect::<Vec<_>>());
    if drift < 1e-7 {
        println!("OK — incremental reproduces batch");
        Ok(())
    } else {
        Err(format!("drift too large: {drift}"))
    }
}
