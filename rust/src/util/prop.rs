//! In-tree property-testing driver (proptest is unavailable offline):
//! seeded random case generation with shrinking-by-halving for sized
//! inputs. Used by the algorithm and coordinator invariant suites.

use super::rng::Rng;

/// Number of random cases per property; `INKPCA_PROP_CASES` overrides.
pub fn default_cases() -> usize {
    std::env::var("INKPCA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(32)
}

/// Run `prop(rng)` over `cases` random cases; on failure, re-run with
/// the failing seed to produce a deterministic panic message containing
/// the seed for reproduction.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert two floats are close, with a helpful error.
pub fn close(label: &str, a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{label}: {a} vs {b} (tol {tol})"))
    }
}

/// Assert a predicate with a message.
pub fn ensure(cond: bool, msg: impl Fn() -> String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-ok", 10, |_| {
            // Interior mutability not needed; the closure is Fn, so use
            // a cell via raw counting through rng draws instead.
            Ok(())
        });
        count += 10;
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerance_scales() {
        assert!(close("x", 1e6, 1e6 + 1.0, 1e-5).is_ok());
        assert!(close("x", 1.0, 2.0, 1e-5).is_err());
    }
}
