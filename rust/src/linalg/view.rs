//! Borrowed matrix views: shape + row stride over a flat `&[f64]`.
//!
//! The streaming hot path must not allocate once warm, so every kernel
//! in [`super::gemm`] has an `*_into` variant operating on these views.
//! A view never owns storage; the stride lets callers expose a
//! `rows × cols` window of a larger capacity buffer (the device
//! `rankone::EigenBasis` uses to grow in place) without copying.

use std::ops::{Index, IndexMut};

use super::matrix::Mat;

/// Immutable `rows × cols` window over `data`, with `stride` elements
/// between row starts (`stride >= cols`; `stride == cols` means
/// contiguous row-major).
#[derive(Clone, Copy)]
pub struct MatView<'a> {
    data: &'a [f64],
    rows: usize,
    cols: usize,
    stride: usize,
}

impl<'a> MatView<'a> {
    /// Wrap `data` as a `rows × cols` view with the given row stride.
    pub fn new(data: &'a [f64], rows: usize, cols: usize, stride: usize) -> Self {
        assert!(stride >= cols, "stride must cover a full row");
        assert!(
            rows == 0 || data.len() >= (rows - 1) * stride + cols,
            "view exceeds backing slice"
        );
        MatView { data, rows, cols, stride }
    }

    /// Contiguous `rows × cols` view over the leading `rows·cols`
    /// elements of a flat row-major buffer — the shape the streaming
    /// states keep their retained examples in (and batched ingest its
    /// incoming points), so the blocked kernels can consume them
    /// without a `Mat` copy.
    pub fn of_rows(data: &'a [f64], rows: usize, cols: usize) -> Self {
        MatView::new(&data[..rows * cols], rows, cols, cols)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Row `i` as a `cols`-long slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// The full backing slice (rows at `stride` spacing).
    pub fn raw(&self) -> &'a [f64] {
        self.data
    }

    /// Copy the viewed window out into an owned matrix.
    pub fn to_mat(&self) -> Mat {
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for i in 0..self.rows {
            data.extend_from_slice(self.row(i));
        }
        Mat::from_vec(self.rows, self.cols, data)
    }
}

impl Index<(usize, usize)> for MatView<'_> {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.stride + j]
    }
}

impl<'a> From<&'a Mat> for MatView<'a> {
    fn from(m: &'a Mat) -> MatView<'a> {
        MatView::new(m.as_slice(), m.rows(), m.cols(), m.cols())
    }
}

/// Mutable counterpart of [`MatView`].
pub struct MatViewMut<'a> {
    data: &'a mut [f64],
    rows: usize,
    cols: usize,
    stride: usize,
}

impl<'a> MatViewMut<'a> {
    pub fn new(data: &'a mut [f64], rows: usize, cols: usize, stride: usize) -> Self {
        assert!(stride >= cols, "stride must cover a full row");
        assert!(
            rows == 0 || data.len() >= (rows - 1) * stride + cols,
            "view exceeds backing slice"
        );
        MatViewMut { data, rows, cols, stride }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.stride..i * self.stride + self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> MatView<'_> {
        MatView { data: &*self.data, rows: self.rows, cols: self.cols, stride: self.stride }
    }

    /// The full backing slice (rows at `stride` spacing).
    pub fn raw_mut(&mut self) -> &mut [f64] {
        &mut *self.data
    }

    /// Zero the viewed `rows × cols` window (stride gaps untouched).
    pub fn fill_zero(&mut self) {
        for i in 0..self.rows {
            self.row_mut(i).fill(0.0);
        }
    }

    /// Copy `src` (same shape) into the viewed window.
    pub fn copy_from(&mut self, src: MatView<'_>) {
        assert_eq!(self.rows, src.rows(), "copy_from row mismatch");
        assert_eq!(self.cols, src.cols(), "copy_from col mismatch");
        for i in 0..self.rows {
            self.row_mut(i).copy_from_slice(src.row(i));
        }
    }
}

impl Index<(usize, usize)> for MatViewMut<'_> {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.stride + j]
    }
}

impl IndexMut<(usize, usize)> for MatViewMut<'_> {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.stride + j]
    }
}

impl<'a> From<&'a mut Mat> for MatViewMut<'a> {
    fn from(m: &'a mut Mat) -> MatViewMut<'a> {
        let (rows, cols) = (m.rows(), m.cols());
        MatViewMut::new(m.as_mut_slice(), rows, cols, cols)
    }
}

impl Mat {
    /// Contiguous view of the whole matrix.
    pub fn view(&self) -> MatView<'_> {
        MatView::from(self)
    }

    /// Contiguous mutable view of the whole matrix.
    pub fn view_mut(&mut self) -> MatViewMut<'_> {
        MatViewMut::from(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_over_mat_matches_indexing() {
        let m = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let v = m.view();
        assert_eq!(v.rows(), 3);
        assert_eq!(v.cols(), 4);
        assert_eq!(v.stride(), 4);
        for i in 0..3 {
            for j in 0..4 {
                assert_eq!(v[(i, j)], m[(i, j)]);
            }
        }
        assert_eq!(v.row(1), m.row(1));
    }

    #[test]
    fn strided_view_selects_window() {
        // 3 rows of a 2-wide window inside a stride-5 buffer.
        let data: Vec<f64> = (0..15).map(|x| x as f64).collect();
        let v = MatView::new(&data, 3, 2, 5);
        assert_eq!(v[(0, 0)], 0.0);
        assert_eq!(v[(1, 1)], 6.0);
        assert_eq!(v[(2, 0)], 10.0);
        let m = v.to_mat();
        assert_eq!(m.rows(), 3);
        assert_eq!(m[(2, 1)], 11.0);
    }

    #[test]
    fn mut_view_writes_through() {
        let mut m = Mat::zeros(2, 3);
        {
            let mut v = m.view_mut();
            v[(1, 2)] = 7.0;
            v.row_mut(0)[1] = 3.0;
        }
        assert_eq!(m[(1, 2)], 7.0);
        assert_eq!(m[(0, 1)], 3.0);
    }

    #[test]
    fn fill_zero_respects_stride_gaps() {
        let mut data = vec![1.0; 10];
        {
            let mut v = MatViewMut::new(&mut data, 2, 2, 5);
            v.fill_zero();
        }
        // Window rows zeroed, gap elements untouched.
        assert_eq!(data[0], 0.0);
        assert_eq!(data[1], 0.0);
        assert_eq!(data[2], 1.0);
        assert_eq!(data[5], 0.0);
        assert_eq!(data[6], 0.0);
        assert_eq!(data[7], 1.0);
    }

    #[test]
    fn of_rows_views_leading_window() {
        // A 10-long buffer holding 3 rows of width 3 plus one slack slot.
        let data: Vec<f64> = (0..10).map(|x| x as f64).collect();
        let v = MatView::of_rows(&data, 3, 3);
        assert_eq!(v.rows(), 3);
        assert_eq!(v.stride(), 3);
        assert_eq!(v[(2, 2)], 8.0);
    }

    #[test]
    fn copy_from_strided_source() {
        let src_data: Vec<f64> = (0..12).map(|x| x as f64).collect();
        let src = MatView::new(&src_data, 2, 3, 6);
        let mut dst = Mat::zeros(2, 3);
        dst.view_mut().copy_from(src);
        assert_eq!(dst[(1, 2)], 8.0);
    }
}
