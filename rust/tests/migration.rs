//! Elastic-topology integration tests: live stream migration between
//! shard workers (consistent-hash resharding, manual placement), the
//! slot/generation safety of the migration protocol, survival of
//! queued fire-and-forget traffic across a move, and the monotonicity
//! of pool counters while streams change shards.
//!
//! The exactness bar mirrors the shard-pool suite: a migrated stream's
//! eigensystem must match an unmigrated single-shard reference to
//! ≤ 1e-10 — migration ships state, it never recomputes it.

mod common;

use common::oracle;
use inkpca::coordinator::{EngineConfig, KernelConfig, PoolConfig, ShardPool, StreamConfig};
use inkpca::data::Dataset;
use inkpca::kpca::IncrementalKpca;

const SEED_POINTS: usize = 6;
const SIGMA: f64 = 1.5;

fn stream_cfg() -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma: SIGMA },
        mean_adjust: true,
        seed_points: SEED_POINTS,
        ..StreamConfig::default()
    }
}

fn pool_cfg(shards: usize) -> PoolConfig {
    PoolConfig { shards, queue: 64, engine: EngineConfig::Native, ..PoolConfig::default() }
}

/// Reference: the same stream driven directly, single-threaded, through
/// the identical engine type the shard workers use.
fn reference_run(ds: &Dataset) -> IncrementalKpca<'static> {
    oracle::reference_run(ds, ds.n(), SIGMA, SEED_POINTS)
}

/// The migration bar: exact eigensystem match AND tiny drift against
/// the batch-recomputed ground truth — migration ships state, it never
/// recomputes it.
fn assert_matches_reference(
    router: &inkpca::coordinator::StreamRouter,
    h: &inkpca::coordinator::StreamHandle,
    ds: &Dataset,
    reference: &IncrementalKpca<'static>,
) {
    oracle::assert_matches_reference(router, h, ds, reference);
    oracle::assert_drift_tiny(router, h);
}

#[test]
fn migrated_stream_matches_unmigrated_reference() {
    let ds = oracle::std_stream(32, 901);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("mig", ds.dim(), stream_cfg()).unwrap();
    let home = h.shard();
    let away = (home + 1) % 2;

    // First half on the home shard …
    for i in 0..ds.n() / 2 {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    // … migrate mid-stream …
    router.migrate_stream(&h, away).unwrap();
    // … second half through the SAME (now stale) handle — every verb
    // must re-route via the redirect table.
    for i in ds.n() / 2..ds.n() {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }

    let reference = reference_run(&ds);
    assert_matches_reference(&router, &h, &ds, &reference);

    // The pool attributes the stream to its new shard and counted the
    // move.
    let snap = router.pool_snapshot().unwrap();
    assert_eq!(snap.migrations, 1);
    let g = snap.per_stream.iter().find(|g| g.stream == "mig").unwrap();
    assert_eq!(g.shard, away);
    assert_eq!(snap.per_shard[away].migrated_in, 1);
    assert_eq!(snap.per_shard[home].migrated_out, 1);
    pool.shutdown();
}

#[test]
fn migration_mid_seeding_carries_the_seed_buffer() {
    let ds = oracle::std_stream(20, 902);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("migseed", ds.dim(), stream_cfg()).unwrap();
    // Two of six seed points, then move the half-seeded entry.
    for i in 0..2 {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    router.migrate_stream(&h, (h.shard() + 1) % 2).unwrap();
    for i in 2..ds.n() {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    let reference = reference_run(&ds);
    assert_matches_reference(&router, &h, &ds, &reference);
    pool.shutdown();
}

#[test]
fn queued_async_ingest_survives_migration() {
    let ds = oracle::std_stream(28, 903);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("amove", ds.dim(), stream_cfg()).unwrap();
    // Seed synchronously, then queue a burst of fire-and-forget points
    // and migrate while they sit in the source shard's queue: the
    // Migrate command serializes behind them, so the queue itself is
    // the drain barrier — none may be lost.
    for i in 0..SEED_POINTS {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    for i in SEED_POINTS..20 {
        router.ingest_async(&h, ds.x.row(i).to_vec()).unwrap();
    }
    router.migrate_stream(&h, (h.shard() + 1) % 2).unwrap();
    // More async traffic through the stale handle after the move.
    for i in 20..ds.n() {
        router.ingest_async(&h, ds.x.row(i).to_vec()).unwrap();
    }
    // The sync barrier resolves through the redirect table too.
    assert_eq!(router.sync(&h).unwrap(), 0, "no async ingest may be lost or fail");

    let reference = reference_run(&ds);
    assert_matches_reference(&router, &h, &ds, &reference);

    let m = router.metrics(&h).unwrap();
    assert_eq!(m.accepted, (ds.n() - SEED_POINTS) as u64);
    assert_eq!(m.errors, 0);
    let snap = router.pool_snapshot().unwrap();
    assert_eq!(snap.errors, 0, "a migrated stream's traffic must not orphan");
    pool.shutdown();
}

#[test]
fn generation_safety_outlives_migration_and_close() {
    let ds = oracle::std_stream(16, 904);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("gsafe", ds.dim(), stream_cfg()).unwrap();
    for i in 0..ds.n() {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    router.migrate_stream(&h, (h.shard() + 1) % 2).unwrap();
    // The pre-migration handle closes the stream at its new home.
    let stats = router.close_stream(&h).unwrap();
    assert_eq!(stats.accepted, ds.n() as u64);

    // Re-open the same id: a FRESH stream. The old handle's redirect
    // still points at the (now closed) migrated slot, whose generation
    // is retired — it must never alias the successor.
    let h2 = router.open_stream("gsafe", ds.dim(), stream_cfg()).unwrap();
    assert!(router.ingest(&h, ds.x.row(0).to_vec()).is_err());
    assert!(router.snapshot(&h).is_err());
    assert!(router.close_stream(&h).is_err());
    let reply = router.ingest(&h2, ds.x.row(0).to_vec()).unwrap();
    assert_eq!(reply.m, 1, "successor stream starts fresh");

    // Invalid migration targets fail cleanly.
    assert!(router.migrate_stream(&h2, 99).is_err());
    pool.shutdown();
}

#[test]
fn stream_ids_stay_unique_across_migration() {
    let ds = oracle::std_stream(16, 907);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let h = router.open_stream("uniq", ds.dim(), stream_cfg()).unwrap();
    for i in 0..ds.n() {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    // Move the stream off its ring shard: its name no longer lives in
    // the worker a duplicate open would hash to, so uniqueness must be
    // enforced at the router, not per worker.
    router.migrate_stream(&h, (h.shard() + 1) % 2).unwrap();
    assert!(router.open_stream("uniq", ds.dim(), stream_cfg()).is_err());
    // The rebalance sweep converges (one stream back home) without
    // tripping over itself, and the id frees only on a real close.
    assert_eq!(router.rebalance().unwrap(), 1);
    assert!(router.open_stream("uniq", ds.dim(), stream_cfg()).is_err());
    let stats = router.close_stream(&h).unwrap();
    assert_eq!(stats.accepted, ds.n() as u64);
    let h2 = router.open_stream("uniq", ds.dim(), stream_cfg()).unwrap();
    assert_eq!(router.snapshot(&h2).unwrap().m, 0);
    pool.shutdown();
}

#[test]
fn pool_counters_monotonic_across_moves() {
    let ds = oracle::std_stream(24, 905);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let handles: Vec<_> = ["m0", "m1", "m2"]
        .iter()
        .map(|id| {
            let h = router.open_stream(id, ds.dim(), stream_cfg()).unwrap();
            for i in 0..ds.n() {
                router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
            }
            h
        })
        .collect();
    let before = router.pool_snapshot().unwrap();
    assert_eq!(before.accepted, 3 * (ds.n() - SEED_POINTS) as u64);
    assert_eq!(before.ingest_count, 3 * ds.n() as u64);

    // A move must change NO pool counter: the stream's counters and
    // latency histograms travel inside the entry.
    router.migrate_stream(&handles[1], (handles[1].shard() + 1) % 2).unwrap();
    let during = router.pool_snapshot().unwrap();
    assert_eq!(during.accepted, before.accepted);
    assert_eq!(during.excluded, before.excluded);
    assert_eq!(during.errors, before.errors);
    assert_eq!(during.ingest_count, before.ingest_count);
    assert_eq!(during.ws_engine_gemms, before.ws_engine_gemms);
    assert_eq!(during.streams, 3);
    assert_eq!(during.migrations, 1);

    // More traffic through every handle (one of them stale) only grows
    // the counters.
    for h in &handles {
        for i in 0..4 {
            router.ingest(h, ds.x.row(i).to_vec()).unwrap();
        }
    }
    let after = router.pool_snapshot().unwrap();
    assert_eq!(after.accepted + after.excluded, during.accepted + during.excluded + 12);
    assert_eq!(after.ingest_count, during.ingest_count + 12);
    assert!(after.ws_engine_gemms >= during.ws_engine_gemms);
    // Occupancy stays consistent with the per-stream attribution.
    let by_shard = |snap: &inkpca::coordinator::PoolSnapshot| {
        snap.per_shard.iter().map(|o| o.streams).sum::<usize>()
    };
    assert_eq!(by_shard(&after), 3);
    for g in &after.per_stream {
        assert!(after.per_shard[g.shard].active);
    }
    pool.shutdown();
}

#[test]
fn grow_and_shrink_rebalance_to_ring_placement() {
    let ds = oracle::std_stream(20, 906);
    let pool = ShardPool::spawn(pool_cfg(2));
    let router = pool.router();
    let handles: Vec<_> = (0..6)
        .map(|i| {
            let id = format!("p{i}");
            let h = router.open_stream(&id, ds.dim(), stream_cfg()).unwrap();
            for r in 0..ds.n() {
                router.ingest(&h, ds.x.row(r).to_vec()).unwrap();
            }
            h
        })
        .collect();
    let before = router.pool_snapshot().unwrap();
    let reference = reference_run(&ds);

    // Grow 2 → 3: a new worker spawns, joins the ring, and exactly the
    // streams whose arc it took over migrate onto it.
    let new_shard = router.add_shard().unwrap();
    assert_eq!(new_shard, 2);
    assert_eq!(router.active_shards(), 3);
    assert_eq!(router.shards(), 3);
    let grown = router.pool_snapshot().unwrap();
    assert_eq!(grown.streams, 6);
    assert_eq!(grown.accepted, before.accepted, "a grow loses no counters");
    assert_eq!(grown.ingest_count, before.ingest_count);
    for g in &grown.per_stream {
        assert_eq!(
            g.shard,
            router.shard_of(&g.stream),
            "{} must sit on its ring shard after rebalance",
            g.stream
        );
    }
    // A rebalance right after a grow is a no-op.
    assert_eq!(router.rebalance().unwrap(), 0);

    // Every stream still serves, exactly.
    for h in &handles {
        assert_matches_reference(&router, h, &ds, &reference);
    }

    // Shrink back: the retired worker's streams move off; the worker
    // itself stays parked (handles must remain serviceable).
    let was_on_new = grown.per_stream.iter().filter(|g| g.shard == new_shard).count();
    assert!(was_on_new > 0, "the grow must have populated the new shard");
    let moved_off = router.remove_shard(new_shard).unwrap();
    assert_eq!(moved_off, was_on_new, "a shrink moves exactly the retired shard's streams");
    let shrunk = router.pool_snapshot().unwrap();
    assert_eq!(router.active_shards(), 2);
    assert_eq!(router.shards(), 3, "retired worker stays behind the router");
    assert_eq!(shrunk.streams, 6);
    assert_eq!(shrunk.accepted, before.accepted, "a shrink loses no counters");
    assert!(!shrunk.per_shard[new_shard].active);
    assert_eq!(shrunk.per_shard[new_shard].streams, 0);
    for g in &shrunk.per_stream {
        assert_eq!(g.shard, router.shard_of(&g.stream));
        assert_ne!(g.shard, new_shard);
    }
    for h in &handles {
        assert_matches_reference(&router, h, &ds, &reference);
    }

    // Growing again revives the parked worker instead of spawning.
    let revived = router.add_shard().unwrap();
    assert_eq!(revived, new_shard);
    assert_eq!(router.shards(), 3, "no extra worker thread");
    assert_eq!(router.active_shards(), 3);
    // Placement is a pure function of the member set, so the revived
    // topology reproduces the pre-shrink placement exactly.
    for (g_new, g_old) in router
        .pool_snapshot()
        .unwrap()
        .per_stream
        .iter()
        .zip(&grown.per_stream)
    {
        assert_eq!(g_new.stream, g_old.stream);
        assert_eq!(g_new.shard, g_old.shard);
    }

    // The last-shard guard: shrinking to zero is refused.
    router.remove_shard(revived).unwrap();
    router.remove_shard(router.active_shard_ids()[1]).unwrap();
    assert_eq!(router.active_shards(), 1);
    let last = router.active_shard_ids()[0];
    assert!(router.remove_shard(last).is_err());
    assert!(router.remove_shard(revived).is_err(), "already retired");
    pool.shutdown();
}

#[test]
fn coordinator_ingest_all_rejects_malformed_feed() {
    // The single-stream wrapper surfaces the router-side Err (it used
    // to assert! and take the caller thread down).
    let coord = inkpca::coordinator::Coordinator::spawn(
        inkpca::coordinator::Config { seed_points: 4, ..Default::default() },
        3,
    );
    assert!(coord.ingest_all(&[0.0; 7], 3, 2).is_err());
    assert!(coord.ingest_all(&[0.0; 6], 0, 2).is_err());
    let reply = coord.ingest_all(&[0.1; 6], 3, 2).unwrap();
    assert_eq!(reply.seeded, 2);
    coord.shutdown();
}
