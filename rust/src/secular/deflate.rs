//! Deflation for the rank-one updated eigenproblem (§5.1 of the paper;
//! Bunch–Nielsen–Sorensen 1978 §4). Two situations let an eigenpair
//! pass through the update unchanged:
//!
//! 1. **tiny weight** — `|zᵢ| ≈ 0`: the perturbation does not move
//!    eigenvalue `λᵢ` and its eigenvector is untouched;
//! 2. **repeated eigenvalues** — `λᵢ ≈ λⱼ`: a Givens rotation in the
//!    `(i, j)` plane (applied to the eigenvector basis too) zeroes one of
//!    the two weights, reducing to case 1.
//!
//! The paper handles near-rank-deficiency by *excluding* the offending
//! data example; deflation is strictly better (nothing is dropped) and
//! we count deflations so experiments can report them (§5.1).
//!
//! [`deflate_into`] is the zero-allocation form: the partition vectors
//! live in a caller-owned [`Deflation`] (inside
//! `rankone::UpdateWorkspace` on the streaming hot path) whose
//! capacities survive across updates.

use crate::linalg::{Mat, MatViewMut};

/// Result of deflating `(d, z)` prior to the secular solve. Reused
/// across updates by [`deflate_into`]; capacities are retained.
#[derive(Clone, Debug, Default)]
pub struct Deflation {
    /// Indices participating in the secular solve.
    pub active: Vec<usize>,
    /// Indices whose eigenpairs pass through unchanged.
    pub deflated: Vec<usize>,
    /// Weights (possibly rotated) for the active indices.
    pub z_active: Vec<f64>,
    /// Poles for the active indices (ascending).
    pub d_active: Vec<f64>,
    /// Number of Givens rotations applied for repeated eigenvalues.
    pub rotations: usize,
}

/// Non-mutating deflation probe for the blocked rank-b path: `true` iff
/// [`deflate_into`] on `(d, z)` would be a no-op — every weight clears
/// the tiny-weight threshold and no adjacent eigenvalue pair is within
/// the repeated-eigenvalue tolerance, so the whole problem is active,
/// no Givens rotation would touch `U`, and the update's rotation can be
/// folded into a pending product without materializing the basis.
/// `O(n)`, reads only; thresholds are formed exactly as in
/// [`deflate_into`] so the two can never disagree on a clean problem.
pub fn is_clean(d: &[f64], z: &[f64], tol: f64) -> bool {
    let n = d.len();
    debug_assert_eq!(z.len(), n);
    let znorm = z.iter().map(|x| x * x).sum::<f64>().sqrt();
    let dscale = d.iter().fold(0.0_f64, |m, v| m.max(v.abs())).max(1e-300);
    let ztol = tol * znorm.max(1e-300);
    let dtol = tol * dscale;
    z.iter().all(|zk| zk.abs() > ztol) && d.windows(2).all(|w| (w[1] - w[0]).abs() > dtol)
}

/// Allocating convenience wrapper over [`deflate_into`].
pub fn deflate(d: &[f64], z: &mut [f64], u: Option<&mut Mat>, tol: f64) -> Deflation {
    let mut out = Deflation::default();
    let mut reallocs = 0u64;
    deflate_into(d, z, u.map(MatViewMut::from), tol, &mut out, &mut reallocs);
    out
}

/// Deflate the problem `Λ + σ z zᵀ` given ascending `d` and weights `z`,
/// writing the partition into the reusable `out`. `u` is a view of the
/// current eigenvector matrix whose columns are rotated whenever a
/// repeated-eigenvalue Givens rotation fires (pass `None` when the
/// caller only needs eigenvalues). `reallocs` is bumped once per call
/// in which any of `out`'s buffers had to grow — zero in steady state.
pub fn deflate_into(
    d: &[f64],
    z: &mut [f64],
    mut u: Option<MatViewMut<'_>>,
    tol: f64,
    out: &mut Deflation,
    reallocs: &mut u64,
) {
    let n = d.len();
    assert_eq!(z.len(), n);
    let znorm = z.iter().map(|x| x * x).sum::<f64>().sqrt();
    let dscale = d.iter().fold(0.0_f64, |m, v| m.max(v.abs())).max(1e-300);
    let ztol = tol * znorm.max(1e-300);
    let dtol = tol * dscale;

    let mut rotations = 0;
    // Pass 1: rotate away weights on (near-)repeated eigenvalues. Scan
    // adjacent pairs (d sorted): for |dᵢ − dⱼ| ≤ dtol, zero zⱼ into zᵢ.
    let mut i = 0;
    while i + 1 < n {
        let mut j = i + 1;
        while j < n && (d[j] - d[i]).abs() <= dtol {
            if z[j].abs() > 0.0 {
                let r = (z[i] * z[i] + z[j] * z[j]).sqrt();
                if r > 0.0 {
                    let c = z[i] / r;
                    let s = z[j] / r;
                    z[i] = r;
                    z[j] = 0.0;
                    if let Some(uu) = u.as_mut() {
                        // Rotate columns i and j of U: the diagonal block
                        // is (near-)scalar, so it commutes with the
                        // rotation to within tol.
                        for row in 0..uu.rows() {
                            let a = uu[(row, i)];
                            let b = uu[(row, j)];
                            uu[(row, i)] = c * a + s * b;
                            uu[(row, j)] = -s * a + c * b;
                        }
                    }
                    rotations += 1;
                }
            }
            j += 1;
        }
        i = j.max(i + 1);
    }

    // Pass 2: partition by weight magnitude into the reusable buffers.
    if out.active.capacity() < n
        || out.deflated.capacity() < n
        || out.d_active.capacity() < n
        || out.z_active.capacity() < n
    {
        *reallocs += 1;
        out.active.reserve(n);
        out.deflated.reserve(n);
        out.d_active.reserve(n);
        out.z_active.reserve(n);
    }
    out.active.clear();
    out.deflated.clear();
    out.d_active.clear();
    out.z_active.clear();
    for k in 0..n {
        if z[k].abs() <= ztol {
            out.deflated.push(k);
        } else {
            out.active.push(k);
        }
    }
    out.d_active.extend(out.active.iter().map(|&k| d[k]));
    out.z_active.extend(out.active.iter().map(|&k| z[k]));
    out.rotations = rotations;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_clean_agrees_with_deflate() {
        // Clean problem: well-separated poles, solid weights.
        let d = vec![1.0, 2.0, 3.0];
        assert!(is_clean(&d, &[0.5, 0.6, 0.7], 1e-12));
        // Tiny weight → not clean, and deflate_into indeed deflates.
        let mut z = vec![0.5, 1e-18, 0.5];
        assert!(!is_clean(&d, &z, 1e-12));
        let def = deflate(&d, &mut z, None, 1e-12);
        assert!(!def.deflated.is_empty());
        // Repeated eigenvalues → not clean (a Givens would fire).
        let dr = vec![1.0, 1.0, 2.0];
        let mut zr = vec![3.0, 4.0, 1.0];
        assert!(!is_clean(&dr, &zr, 1e-12));
        let defr = deflate(&dr, &mut zr, None, 1e-12);
        assert!(defr.rotations > 0 || !defr.deflated.is_empty());
        // Conversely: when is_clean says yes, deflate_into is a no-op.
        let dc = vec![0.2, 1.1, 2.7, 4.0];
        let zc0 = vec![0.4, -0.3, 0.2, 0.6];
        assert!(is_clean(&dc, &zc0, 1e-12));
        let mut zc = zc0.clone();
        let defc = deflate(&dc, &mut zc, None, 1e-12);
        assert_eq!(defc.active.len(), 4);
        assert!(defc.deflated.is_empty());
        assert_eq!(defc.rotations, 0);
        assert_eq!(zc, zc0, "clean deflation must not touch z");
    }

    #[test]
    fn tiny_weights_deflate() {
        let d = vec![1.0, 2.0, 3.0];
        let mut z = vec![0.5, 1e-18, 0.5];
        let def = deflate(&d, &mut z, None, 1e-12);
        assert_eq!(def.deflated, vec![1]);
        assert_eq!(def.active, vec![0, 2]);
        assert_eq!(def.d_active, vec![1.0, 3.0]);
    }

    #[test]
    fn repeated_eigenvalues_rotated() {
        let d = vec![1.0, 1.0, 2.0];
        let mut z = vec![3.0, 4.0, 1.0];
        let mut u = Mat::eye(3);
        let def = deflate(&d, &mut z, Some(&mut u), 1e-12);
        assert_eq!(def.rotations, 1);
        // Combined weight magnitude preserved: √(3²+4²) = 5.
        assert!((z[0] - 5.0).abs() < 1e-14);
        assert_eq!(z[1], 0.0);
        assert_eq!(def.deflated, vec![1]);
        // U columns stay orthonormal after the rotation.
        assert!(crate::linalg::orthogonality_defect(&u) < 1e-14);
    }

    #[test]
    fn rotation_preserves_matrix() {
        // U diag(d) Uᵀ + σ zzᵀ must be unchanged by the deflation
        // rotation (U, z rotated together).
        let d = vec![1.0, 1.0, 2.5];
        let sigma = 0.7;
        let mut z = vec![0.6, -0.8, 0.3];
        let mut u = Mat::from_fn(3, 3, |i, j| ((i * 3 + j) as f64 * 0.9).sin());
        // Orthonormalize u via eigh trick not needed; the identity we
        // check is algebraic and holds for any U.
        let before = {
            let mut m = crate::linalg::matmul(
                &crate::linalg::matmul(&u, &Mat::from_diag(&d)),
                &u.transpose(),
            );
            let uz = crate::linalg::gemv(&u, &z);
            m.syr(sigma, &uz);
            m
        };
        let _ = deflate(&d, &mut z, Some(&mut u), 1e-12);
        let after = {
            let mut m = crate::linalg::matmul(
                &crate::linalg::matmul(&u, &Mat::from_diag(&d)),
                &u.transpose(),
            );
            let uz = crate::linalg::gemv(&u, &z);
            m.syr(sigma, &uz);
            m
        };
        assert!(before.max_abs_diff(&after) < 1e-12);
    }

    #[test]
    fn no_deflation_when_well_separated() {
        let d = vec![1.0, 2.0, 3.0];
        let mut z = vec![0.5, 0.6, 0.7];
        let def = deflate(&d, &mut z, None, 1e-12);
        assert!(def.deflated.is_empty());
        assert_eq!(def.active.len(), 3);
        assert_eq!(def.rotations, 0);
    }

    #[test]
    fn reused_deflation_buffers_stop_reallocating() {
        let d = vec![0.5, 1.5, 2.5, 3.5];
        let mut out = Deflation::default();
        let mut reallocs = 0u64;
        let mut z = vec![0.4, -0.2, 0.3, 0.6];
        deflate_into(&d, &mut z, None, 1e-12, &mut out, &mut reallocs);
        let after_warm = reallocs;
        for _ in 0..10 {
            let mut z = vec![0.4, -0.2, 0.3, 0.6];
            deflate_into(&d, &mut z, None, 1e-12, &mut out, &mut reallocs);
        }
        assert_eq!(reallocs, after_warm, "warm deflation buffers must not grow");
        assert_eq!(out.active.len(), 4);
    }
}
