//! The paper's §6 extension, implemented: "it could be straightforward
//! to adapt the proposed algorithm for incremental kernel PCA to only
//! maintain a subset of the eigenvectors and eigenvalues." This tracker
//! runs Algorithm 2's four rank-one updates against a rectangular
//! `m × r` eigenvector matrix — the perturbations are projected onto
//! the tracked dominant subspace — and truncates back to `r` after each
//! expansion. Unlike the Hoegaerts baseline it carries the *mean
//! adjustment*, which their tracker does not support. Shares the full
//! algorithm's workspace/eigenbasis storage for the rank-one updates
//! (truncation is an in-place column shift, expansion an in-place
//! capacity-slack grow); the per-step vectors here still allocate —
//! this is a comparison tracker, not the production hot path
//! (`kpca::IncrementalKpca` carries the step scratch).

use crate::kernels::{kernel_column_into, Kernel};
use crate::linalg::Mat;
use crate::rankone::{
    rank_one_update_ws, sort_pairs_ws, EigenBasis, NativeRotate, Rotate, UpdateWorkspace,
};

/// Top-`r` mean-adjusted incremental kernel PCA.
#[derive(Clone)]
pub struct TopKKpca<'k> {
    kernel: &'k dyn Kernel,
    x: Vec<f64>,
    dim: usize,
    m: usize,
    /// Dominant eigenpairs retained.
    pub r: usize,
    /// Tracked eigenvalues (ascending, length ≤ r).
    pub vals: Vec<f64>,
    /// Tracked eigenvectors (`m × len(vals)`).
    pub vecs: EigenBasis,
    /// Running sums of the *unadjusted* kernel matrix (as Algorithm 2).
    s: f64,
    k1: Vec<f64>,
    /// Per-stream rank-one scratch.
    ws: UpdateWorkspace,
}

impl<'k> TopKKpca<'k> {
    /// Seed from a batch fit of the first points, keeping the top `r`.
    pub fn from_batch(kernel: &'k dyn Kernel, x0: &Mat, r: usize) -> Result<Self, String> {
        let m = x0.rows();
        if m < 2 || r == 0 {
            return Err("topk needs ≥ 2 seed points and r ≥ 1".into());
        }
        let k = crate::kernels::gram(kernel, x0);
        let fit = super::batch::BatchKpca::fit_gram(k.clone(), true)?;
        let keep = r.min(m);
        let first = m - keep;
        let mut vecs = Mat::zeros(m, keep);
        let mut vals = Vec::with_capacity(keep);
        for (c, j) in (first..m).enumerate() {
            vals.push(fit.values[j]);
            for i in 0..m {
                vecs[(i, c)] = fit.vectors[(i, j)];
            }
        }
        let k1: Vec<f64> = (0..m).map(|i| k.row(i).iter().sum()).collect();
        let s = k1.iter().sum();
        Ok(TopKKpca {
            kernel,
            x: x0.as_slice().to_vec(),
            dim: x0.cols(),
            m,
            r,
            vals,
            vecs: EigenBasis::from_mat(vecs),
            s,
            k1,
            ws: UpdateWorkspace::new(),
        })
    }

    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Ingest one example (Algorithm 2 steps projected on the tracked
    /// subspace, then truncation).
    pub fn push(&mut self, xnew: &[f64]) -> Result<(), String> {
        self.push_with(xnew, &NativeRotate)
    }

    pub fn push_with(&mut self, xnew: &[f64], engine: &dyn Rotate) -> Result<(), String> {
        assert_eq!(xnew.len(), self.dim);
        let m = self.m;
        let mf = m as f64;
        // Kernel column over the flat retained data — no matrix clone.
        let mut a = Vec::with_capacity(m);
        kernel_column_into(self.kernel, &self.x, self.dim, m, xnew, &mut a);
        let knew = self.kernel.eval(xnew, xnew);
        let asum: f64 = a.iter().sum();

        // Algorithm 2 lines 2–4 (running sums, mean-shift vector).
        let s2 = self.s + 2.0 * asum + knew;
        let c = -self.s / (mf * mf) + s2 / ((mf + 1.0) * (mf + 1.0));
        let u: Vec<f64> = (0..m)
            .map(|i| self.k1[i] / (mf * (mf + 1.0)) - a[i] / (mf + 1.0) + 0.5 * c)
            .collect();
        let unorm = crate::linalg::norm2(&u);
        if unorm > 0.0 {
            let gamma = (unorm / mf.sqrt()).sqrt();
            let vp: Vec<f64> = u.iter().map(|ui| gamma + ui / gamma).collect();
            let vm: Vec<f64> = u.iter().map(|ui| gamma - ui / gamma).collect();
            rank_one_update_ws(&mut self.vals, &mut self.vecs, 0.5, &vp, engine, &mut self.ws)?;
            rank_one_update_ws(&mut self.vals, &mut self.vecs, -0.5, &vm, engine, &mut self.ws)?;
        }

        // Centered new row/column over m+1 points (lines 7–12).
        let mut k1n = self.k1.clone();
        for (k1i, ai) in k1n.iter_mut().zip(&a) {
            *k1i += ai;
        }
        k1n.push(asum + knew);
        let m1f = mf + 1.0;
        let ksum = asum + knew;
        let mut kvec = a.clone();
        kvec.push(knew);
        let v: Vec<f64> = (0..m + 1)
            .map(|i| kvec[i] - (ksum + k1n[i] - s2 / m1f) / m1f)
            .collect();
        let v0 = v[m];
        if v0 <= 1e-12 {
            // Rank-deficient example — excluded (§5.1); running sums are
            // not committed either.
            return Ok(());
        }

        // Expansion on the rectangular system + the two final updates.
        let (rows, cols) = (self.vecs.rows(), self.vecs.cols());
        self.vecs.expand();
        self.vecs[(rows, cols)] = 1.0;
        self.vals.push(0.25 * v0);
        sort_pairs_ws(&mut self.vals, &mut self.vecs, &mut self.ws);
        let sigma = 4.0 / v0;
        let mut v1 = v[..m].to_vec();
        v1.push(0.5 * v0);
        let mut v2 = v[..m].to_vec();
        v2.push(0.25 * v0);
        rank_one_update_ws(&mut self.vals, &mut self.vecs, sigma, &v1, engine, &mut self.ws)?;
        rank_one_update_ws(&mut self.vals, &mut self.vecs, -sigma, &v2, engine, &mut self.ws)?;

        // Truncate to the dominant r (ascending order: drop the front) —
        // an in-place column shift, no reallocation.
        while self.vals.len() > self.r {
            self.vals.remove(0);
            self.vecs.remove_col(0);
        }

        self.s = s2;
        self.k1 = k1n;
        self.x.extend_from_slice(xnew);
        self.m += 1;
        Ok(())
    }

    /// Low-rank reconstruction of the centered kernel matrix.
    pub fn reconstruct(&self) -> Mat {
        let (m, c) = (self.vecs.rows(), self.vecs.cols());
        let mut ul = self.vecs.to_mat();
        for i in 0..m {
            for j in 0..c {
                ul[(i, j)] *= self.vals[j];
            }
        }
        crate::linalg::matmul_nt(&ul, &self.vecs)
    }

    /// Optimal rank-r approximation of the batch-centered kernel matrix
    /// (quality reference).
    pub fn batch_rank_r(&self) -> Result<Mat, String> {
        let xmat = Mat::from_vec(self.m, self.dim, self.x.clone());
        let k = crate::kernels::gram(self.kernel, &xmat);
        let kc = super::centering::center_gram(&k);
        let eg = crate::linalg::eigh(&kc)?;
        let keep = self.r.min(self.m);
        let first = self.m - keep;
        let mut ul = Mat::zeros(self.m, keep);
        let mut u = Mat::zeros(self.m, keep);
        for (c, j) in (first..self.m).enumerate() {
            for i in 0..self.m {
                ul[(i, c)] = eg.vectors[(i, j)] * eg.values[j];
                u[(i, c)] = eg.vectors[(i, j)];
            }
        }
        Ok(crate::linalg::matmul_nt(&ul, &u))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;
    use crate::linalg::frobenius;

    #[test]
    fn exact_while_untruncated() {
        let ds = yeast_like(14, 1);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(5, ds.dim());
        let mut tk = TopKKpca::from_batch(&kern, &seed, 64).unwrap();
        let mut full = crate::kpca::IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 5..ds.n() {
            tk.push(ds.x.row(i)).unwrap();
            full.push(ds.x.row(i)).unwrap();
        }
        // With r ≥ m the tracker equals the full adjusted algorithm.
        assert!(tk.reconstruct().max_abs_diff(&full.reconstruct()) < 1e-8);
    }

    #[test]
    fn truncated_stays_near_optimal_rank_r() {
        let ds = yeast_like(36, 2);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(12, ds.dim());
        let r = 6;
        let mut tk = TopKKpca::from_batch(&kern, &seed, r).unwrap();
        for i in 12..ds.n() {
            tk.push(ds.x.row(i)).unwrap();
        }
        let best = tk.batch_rank_r().unwrap();
        let kc = {
            let k = crate::kernels::gram(&kern, &ds.x);
            crate::kpca::center_gram(&k)
        };
        let e_best = frobenius(&kc.sub(&best));
        let e_tk = frobenius(&kc.sub(&tk.reconstruct()));
        assert!(e_tk >= e_best - 1e-9);
        assert!(e_tk < 5.0 * e_best + 1e-6, "tracker {e_tk} vs optimal {e_best}");
    }

    #[test]
    fn memory_is_m_by_r() {
        let ds = yeast_like(20, 3);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut tk = TopKKpca::from_batch(&kern, &seed, 4).unwrap();
        for i in 6..ds.n() {
            tk.push(ds.x.row(i)).unwrap();
            assert!(tk.vals.len() <= 4);
            assert_eq!(tk.vecs.rows(), tk.len());
        }
    }
}
