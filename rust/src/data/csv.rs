//! Minimal CSV/whitespace loader for the real UCI files (no csv crate
//! offline). Drops non-numeric columns (the categorical targets the
//! paper removes, §5) and tolerates both comma- and whitespace-separated
//! layouts (`magic04.data` is comma-separated, `yeast.data` is
//! whitespace-separated with a leading sequence-name column).

use super::Dataset;
use crate::linalg::Mat;

/// Load a numeric dataset from `path`. If `expect_dim` is given, rows
/// whose numeric field count differs are rejected, guarding against
/// header/format drift.
pub fn load_csv(path: &str, name: &str, expect_dim: Option<usize>) -> Result<Dataset, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    parse_numeric(&text, name, expect_dim)
}

/// Parse numeric rows out of CSV-ish text (used directly by tests).
pub fn parse_numeric(text: &str, name: &str, expect_dim: Option<usize>) -> Result<Dataset, String> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = if line.contains(',') {
            line.split(',').collect()
        } else {
            line.split_whitespace().collect()
        };
        // Keep only fields that parse as numbers (drops the categorical
        // class column and any id column).
        let nums: Vec<f64> = fields.iter().filter_map(|f| f.trim().parse::<f64>().ok()).collect();
        if nums.is_empty() {
            continue;
        }
        if let Some(d) = expect_dim {
            if nums.len() != d {
                return Err(format!(
                    "{name}:{} expected {d} numeric fields, found {}",
                    lineno + 1,
                    nums.len()
                ));
            }
        }
        if let Some(first) = rows.first() {
            if first.len() != nums.len() {
                return Err(format!("{name}:{} ragged row", lineno + 1));
            }
        }
        rows.push(nums);
    }
    if rows.is_empty() {
        return Err(format!("{name}: no numeric rows"));
    }
    let (n, d) = (rows.len(), rows[0].len());
    let x = Mat::from_fn(n, d, |i, j| rows[i][j]);
    Ok(Dataset { name: name.into(), x })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comma_separated_with_class_column() {
        let text = "1.5,2.5,g\n3.0,4.0,h\n";
        let ds = parse_numeric(text, "t", Some(2)).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.x[(1, 1)], 4.0);
    }

    #[test]
    fn parses_whitespace_with_name_column() {
        let text = "SEQ_A  0.5 0.6 0.1\nSEQ_B  0.2 0.3 0.9\n";
        let ds = parse_numeric(text, "t", Some(3)).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.dim(), 3);
    }

    #[test]
    fn rejects_wrong_dimension() {
        assert!(parse_numeric("1,2,3\n", "t", Some(2)).is_err());
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_numeric("1,2\n1,2,3\n", "t", None).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let ds = parse_numeric("# header\n\n1.0,2.0\n", "t", None).unwrap();
        assert_eq!(ds.n(), 1);
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_csv("/nonexistent/file.csv", "t", None).is_err());
    }
}
