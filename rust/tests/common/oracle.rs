//! The oracle test layer: batch-recompute references and seeded stream
//! generators shared by every integration suite.
//!
//! The incremental system's correctness story is always the same
//! comparison — a stream of rank-one updates (and now down-dates)
//! against the thing the paper defines it to equal: the *batch*
//! eigendecomposition of the full centered Gram over exactly the
//! retained points (and its Nyström counterpart over the landmark
//! subset). This module holds that comparison once, instead of one
//! slightly-different copy per test file.

use std::path::PathBuf;
use std::sync::Arc;

use inkpca::coordinator::{RoutedEngine, StreamHandle, StreamRouter};
use inkpca::data::synthetic::yeast_like;
use inkpca::data::Dataset;
use inkpca::kernels::{Kernel, Rbf};
use inkpca::kpca::IncrementalKpca;
use inkpca::linalg::Mat;
use inkpca::nystrom::BatchNystrom;

/// Unique scratch directory for durability tests (unique per process ×
/// call, so parallel test binaries never collide).
pub fn temp_dir(tag: &str) -> PathBuf {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let dir =
        std::env::temp_dir().join(format!("inkpca_test_{tag}_{}_{n}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Seeded deterministic stream: `n` standardized yeast-like points.
/// Same seed → bit-identical dataset, on every platform.
pub fn std_stream(n: usize, seed: u64) -> Dataset {
    let mut ds = yeast_like(n, seed);
    ds.standardize();
    ds
}

/// Uninterrupted single-threaded reference: the first `n` points of
/// `ds` driven directly through the same native engine type the shard
/// workers use (RBF at `sigma`, mean-adjusted, `seed_points` batch
/// initialization).
pub fn reference_run(
    ds: &Dataset,
    n: usize,
    sigma: f64,
    seed_points: usize,
) -> IncrementalKpca<'static> {
    let kernel: Arc<dyn Kernel> = Arc::new(Rbf { sigma });
    let seed = ds.x.submatrix(seed_points, ds.dim());
    let engine = RoutedEngine::native_only();
    let mut inc = IncrementalKpca::from_batch_shared(kernel, &seed, true).unwrap();
    for i in seed_points..n {
        inc.push_with(ds.x.row(i), &engine).unwrap();
    }
    inc
}

/// A routed stream must match the reference eigensystem ≤ 1e-10 on
/// eigenvalues and projection magnitudes (eigenvector sign is
/// arbitrary). Projections exercise eigenvectors, retained data and
/// centering sums together.
pub fn assert_matches_reference(
    router: &StreamRouter,
    h: &StreamHandle,
    ds: &Dataset,
    reference: &IncrementalKpca<'static>,
) {
    let snap = router.snapshot(h).unwrap();
    assert_eq!(snap.m, reference.len(), "{}", h.id());
    let top_ref: Vec<f64> = reference.vals.iter().rev().take(10).copied().collect();
    for (got, want) in snap.top_values.iter().zip(&top_ref) {
        assert!(
            (got - want).abs() <= 1e-10,
            "{}: eigenvalue {got} vs reference {want}",
            h.id()
        );
    }
    let probe = vec![0.25; ds.dim()];
    let got = router.project(h, probe.clone(), 4).unwrap();
    let want = reference.project(&probe, 4);
    for (g, w) in got.iter().zip(&want) {
        assert!(
            (g.abs() - w.abs()).abs() <= 1e-10,
            "{}: projection {g} vs reference {w}",
            h.id()
        );
    }
}

/// The stream's own drift gauge against its batch-recomputed ground
/// truth must be tiny — the paper's Figure 1 invariant.
pub fn assert_drift_tiny(router: &StreamRouter, h: &StreamHandle) {
    let drift = router.measure_drift(h).unwrap();
    assert!(drift.norms.frobenius < 1e-7, "{}: drift {:?}", h.id(), drift.norms);
}

/// Full-Gram batch oracle: eigendecompose the (optionally centered)
/// Gram of `rows` from scratch and return the reconstructed tracked
/// matrix `U Λ Uᵀ`. This is the ground truth every incremental state
/// over the same retained rows must reproduce — including one that got
/// there through evictions and re-adds.
pub fn kpca_oracle(kern: &dyn Kernel, rows: &Mat, mean_adjust: bool) -> Mat {
    IncrementalKpca::from_batch(kern, rows, mean_adjust)
        .expect("oracle batch build")
        .reconstruct()
}

/// The same oracle applied to an incremental state's *own* retained
/// rows: the max-abs gap between what the stream tracks and what a
/// from-scratch batch recompute over exactly those rows yields.
pub fn kpca_oracle_gap(kern: &dyn Kernel, inc: &IncrementalKpca<'_>) -> f64 {
    let rows = Mat::from_vec(inc.len(), inc.dim(), inc.data_flat().to_vec());
    inc.reconstruct().max_abs_diff(&kpca_oracle(kern, &rows, inc.mean_adjust))
}

/// Nyström batch oracle: the rank-m approximate Gram
/// `K_nm K_mm⁻¹ K_mn` rebuilt from scratch over landmark `subset` —
/// the reference an incremental Nyström state with the same subset
/// must match.
pub fn nystrom_oracle(kern: &dyn Kernel, x: &Mat, subset: &[usize]) -> Mat {
    BatchNystrom::fit(kern, x, subset).expect("oracle Nyström fit").approx_gram()
}
