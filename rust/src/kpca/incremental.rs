//! The paper's main contribution: incremental kernel PCA through rank
//! one updates to the eigendecomposition of the kernel matrix
//! (Algorithm 1, §3.1.1 — zero-mean) or the mean-adjusted kernel matrix
//! (Algorithm 2, §3.1.2 — four rank-one updates per example, with the
//! running sums `Σₘ` and `Kₘ𝟙ₘ` maintained incrementally).
//!
//! The streaming hot path is allocation-free once warm: the eigenvectors
//! live in a capacity-doubling [`EigenBasis`], all rank-one scratch in a
//! per-stream [`UpdateWorkspace`] shared by every update an example
//! triggers (2 unadjusted / 4 adjusted), and the per-step vectors
//! (kernel column, mean-shift, centered column, update vectors) in a
//! private scratch block of reusable buffers.
//!
//! Batched ingest ([`IncrementalKpca::push_batch_with`]) is blocked end
//! to end: the batch's kernel rows are one GEMM, and under the
//! [`BatchRotation::Fused`] strategy the batch's rank-one
//! back-rotations accumulate into one pending product applied as a
//! single engine GEMM at the end of the batch (the blocked rank-b
//! eigen-update — see [`crate::rankone`] and `ARCHITECTURE.md`).
//!
//! Two pseudocode typos in the paper are corrected here (both confirmed
//! against the derivation in the surrounding text and by the exactness
//! tests below):
//!   * Algorithm 1 line 2 / Algorithm 2 line 14 write the new
//!     eigenvector diagonal entry as `k/4`; the expansion of eq. (2)
//!     requires the unit entry `1` (the *eigenvalue* is `k/4`).
//!   * Algorithm 2 line 4 writes `K1/(m(m+1))²`; the derivation defines
//!     `u = Kₘ𝟙ₘ/(m(m+1)) − a/(m+1) + ½C𝟙ₘ`.

use std::sync::Arc;

use crate::kernels::{kernel_column_into, kernel_rows_into, Kernel, KernelBlockScratch};
use crate::linalg::Mat;
use crate::rankone::{
    effective_row_into, expand_eigensystem_ws, flush_rotation_ws, rank_one_update_fused_ws,
    rank_one_update_ws, remove_eigenpair_ws, EigenBasis, NativeRotate, Rotate, UpdateStats,
    UpdateWorkspace,
};

/// How a batched ingest applies its rank-one back-rotations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchRotation {
    /// Blocked rank-b: fold every clean update's rotation into one
    /// pending `Q₁·…·Q_j` product (workspace scratch) and apply a
    /// single engine GEMM `U ← U·Q` when the batch flushes. Falls back
    /// to [`BatchRotation::Sequential`] per update whenever deflation
    /// makes folding unsound — blocked and sequential runs agree to
    /// rounding (`tests/batching.rs` pins ≤ 1e-10).
    Fused,
    /// Apply every update's back-rotation eagerly (one engine GEMM per
    /// rank-one update — the pre-blocked behaviour, and what single
    /// point pushes always do).
    Sequential,
}

/// How a bounded-memory stream picks its eviction victim once
/// [`IncrementalKpca::set_bound`] caps the retained set. Eviction is a
/// *down-date*: two rank-one updates decouple the victim's eigenpair
/// from the tracked matrix (the exact reverse of the eq. 2 expansion),
/// then the pair and the victim's basis row are dropped — deferred into
/// the pending blocked product when one is accumulating, so a mid-batch
/// eviction costs no extra engine GEMM.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Never evict — the bound is ignored and the stream grows
    /// unboundedly (the pre-bounded behaviour).
    #[default]
    Off,
    /// Deterministic round-robin over the unprotected landmarks:
    /// victim = `protected + evictions mod (m − protected)`. No RNG, so
    /// a WAL replay (which restores the eviction counter) reproduces
    /// the exact victim sequence.
    Uniform,
    /// Evict the landmark with the smallest ridge leverage score
    /// `ℓᵢ = Σ_c U[i,c]² λ_c/(λ_c + μ)`, `μ = trace⁺/m` — the point the
    /// current eigensystem can best afford to lose (Nyström column
    /// sampling literature). The full `O(m²)` rescore is batched to
    /// every [`LEV_REFRESH_EVERY`]th eviction; in between, cached
    /// scores are maintained incrementally (see
    /// [`IncrementalKpca::leverage_score_row`]).
    LeverageScore,
}

/// Full-rescore cadence of [`EvictionPolicy::LeverageScore`]: the
/// `O(m²)` score vector is recomputed every this-many evictions (keyed
/// off the persisted eviction counter — WAL replay from a checkpoint
/// lands on the same refresh schedule). Between refreshes a victim
/// costs one `O(m·n)` row score for the newly accepted landmark.
pub const LEV_REFRESH_EVERY: usize = 8;

impl EvictionPolicy {
    /// Stable name for CLI flags and config display.
    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::Off => "off",
            EvictionPolicy::Uniform => "uniform",
            EvictionPolicy::LeverageScore => "leverage",
        }
    }

    /// Parse the [`EvictionPolicy::name`] form (CLI `--eviction`).
    pub fn from_name(s: &str) -> Option<EvictionPolicy> {
        match s {
            "off" => Some(EvictionPolicy::Off),
            "uniform" => Some(EvictionPolicy::Uniform),
            "leverage" => Some(EvictionPolicy::LeverageScore),
            _ => None,
        }
    }
}

/// How a state holds its kernel: borrowed from the caller (library use,
/// lifetimes managed by the embedder) or shared ownership (long-lived
/// stream entries in the coordinator shard pool — each stream owns its
/// kernel through the `Arc`, nothing is leaked and no `'static` bound
/// plumbing is needed).
#[derive(Clone)]
enum KernelHandle<'k> {
    Borrowed(&'k dyn Kernel),
    Shared(Arc<dyn Kernel>),
}

impl<'k> KernelHandle<'k> {
    #[inline]
    fn get(&self) -> &dyn Kernel {
        match self {
            KernelHandle::Borrowed(k) => *k,
            KernelHandle::Shared(k) => k.as_ref(),
        }
    }
}

/// Aggregated per-stream statistics (reported by §5.1 experiments and
/// the coordinator metrics endpoint).
#[derive(Clone, Copy, Debug, Default)]
pub struct KpcaStats {
    /// Data examples accepted into the eigensystem.
    pub accepted: usize,
    /// Examples excluded due to near rank-deficiency (§5.1).
    pub excluded: usize,
    /// Total deflated eigenpairs across all rank-one updates.
    pub deflated: usize,
    /// Total deflation Givens rotations.
    pub rotations: usize,
    /// Rank-one updates performed (2 per step unadjusted, 4 adjusted).
    pub updates: usize,
    /// Landmarks evicted by the bounded-memory down-date path. Also the
    /// round-robin cursor of [`EvictionPolicy::Uniform`], which is why
    /// it persists in checkpoints: a replayed stream re-picks the same
    /// victims.
    pub evictions: usize,
}

impl KpcaStats {
    fn absorb(&mut self, s: UpdateStats) {
        self.deflated += s.deflated;
        self.rotations += s.rotations;
        self.updates += 1;
    }
}

/// Serialized essence of an [`IncrementalKpca`] state, as written and
/// read by the coordinator's checkpoint codec: the retained examples,
/// the eigensystem, the Algorithm 2 running sums, and the knobs/stats
/// that must survive a restart. The kernel travels separately (as its
/// `describe()` string — see [`crate::kernels::kernel_from_describe`]).
#[derive(Clone, Debug)]
pub struct KpcaParts {
    pub mean_adjust: bool,
    pub dim: usize,
    /// Retained examples, flat row-major `m × dim`.
    pub x: Vec<f64>,
    /// Eigenvalues, ascending (`m` of them — defines `m`).
    pub vals: Vec<f64>,
    /// Eigenvector window, dense row-major `m × m`.
    pub vecs: Vec<f64>,
    /// `Σₘ = 𝟙ᵀKₘ𝟙`.
    pub s: f64,
    /// `Kₘ𝟙` row sums (`m` of them).
    pub k1: Vec<f64>,
    pub exclude_tol: f64,
    pub naive_recenter_split: bool,
    pub batch_rotation: Option<BatchRotation>,
    pub stats: KpcaStats,
    /// Lifetime engine back-rotation GEMM count (monotonic gauge).
    pub engine_gemms: u64,
}

/// Result of a batched ingest ([`IncrementalKpca::push_batch_with`]):
/// how the batch's points split between accepted and §5.1-excluded.
/// Per-point flags are available from
/// [`IncrementalKpca::last_batch_mask`] until the next batch.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchOutcome {
    pub accepted: usize,
    pub excluded: usize,
}

/// One rank-one update through either rotation strategy: deferred into
/// the workspace's pending product (`fused`) or applied eagerly. Free
/// function so the call sites can borrow `vals`/`vecs`/`ws` and the
/// step scratch disjointly.
#[allow(clippy::too_many_arguments)]
fn apply_rank_one(
    vals: &mut Vec<f64>,
    vecs: &mut EigenBasis,
    sigma: f64,
    v: &[f64],
    engine: &dyn Rotate,
    ws: &mut UpdateWorkspace,
    fused: bool,
) -> Result<UpdateStats, String> {
    if fused {
        rank_one_update_fused_ws(vals, vecs, sigma, v, engine, ws)
    } else {
        rank_one_update_ws(vals, vecs, sigma, v, engine, ws)
    }
}

/// Reusable per-step vectors (capacities retained across pushes).
#[derive(Clone, Debug, Default)]
struct StepScratch {
    /// Kernel column `a` against the retained examples.
    a: Vec<f64>,
    /// Mean-shift vector `u` (Algorithm 2 line 4).
    u: Vec<f64>,
    /// Norm-balanced re-centering vectors `γ𝟙 ± u/γ`.
    vp: Vec<f64>,
    vm: Vec<f64>,
    /// Next-step running row sums `Kₘ₊₁𝟙`.
    k1_next: Vec<f64>,
    /// Centered new row/column `v` over the m+1 points.
    v: Vec<f64>,
    /// Expansion update vectors (eq. 2 / eq. 3).
    v1: Vec<f64>,
    v2: Vec<f64>,
    /// Batched-ingest scratch: the `b × m₀` kernel rows of the batch
    /// against the retained set (one blocked GEMM for GEMM-able
    /// kernels) …
    block: Vec<f64>,
    /// … the `b × b` kernel block among the batch's own points …
    intra: Vec<f64>,
    /// … per-point accept flags of the last batch …
    mask: Vec<bool>,
    /// … and the provenance of each *currently retained* landmark
    /// relative to the batch-start precomputation: `src < m₀` indexes a
    /// `block` column, `src ≥ m₀` indexes batch point `src − m₀` in
    /// `intra`. Seeded `0..m₀`, appended on accept, shifted on
    /// mid-batch eviction — what keeps the precomputed kernel rows
    /// addressable after the retained set mutates under the batch.
    prov: Vec<usize>,
    /// Effective basis row (read through any pending rotation) while
    /// locating a down-date's decoupled eigenpair.
    erow: Vec<f64>,
    /// Ridge leverage scores for [`EvictionPolicy::LeverageScore`].
    lev: Vec<f64>,
    /// Row-norm scratch for the blocked kernel evaluation.
    kb: KernelBlockScratch,
    /// Capacity-growth events across the batch scratch buffers (zero
    /// once warm — asserted by the batching test suite).
    reallocs: u64,
}

/// Incremental kernel PCA state: the eigendecomposition of the
/// (adjusted) kernel matrix over all points seen so far, plus the
/// running sums Algorithm 2 needs. Memory is `O(m²)` — the kernel
/// matrix itself is never stored (paper §3.1.2).
#[derive(Clone)]
pub struct IncrementalKpca<'k> {
    kernel: KernelHandle<'k>,
    /// Whether to maintain the eigensystem of `K'` (Algorithm 2) rather
    /// than `K` (Algorithm 1).
    pub mean_adjust: bool,
    /// Retained data examples, row-major (`m × dim`).
    x: Vec<f64>,
    dim: usize,
    m: usize,
    /// Eigenvalues, ascending.
    pub vals: Vec<f64>,
    /// Eigenvectors, one column per eigenvalue (capacity-doubling
    /// storage; grows in place as examples arrive).
    pub vecs: EigenBasis,
    /// `Σₘ = 𝟙ᵀ Kₘ 𝟙` — running total of the *unadjusted* kernel matrix.
    s: f64,
    /// `K1 = Kₘ 𝟙ₘ` — running row sums of the unadjusted kernel matrix.
    k1: Vec<f64>,
    /// Threshold on the new centered diagonal `v₀` below which an
    /// example is excluded as rank-deficient (§5.1).
    pub exclude_tol: f64,
    /// Ablation: use the paper's literal re-centering split
    /// `½(𝟙±u)(𝟙±u)ᵀ` instead of the norm-balanced one (see
    /// `push_adjusted`) — reproduces the paper's §5.1 drift behaviour.
    pub naive_recenter_split: bool,
    /// Back-rotation strategy for batched ingest. `None` (default)
    /// auto-selects: [`BatchRotation::Fused`] for batches of ≥ 2 points
    /// (there is a product to amortize), [`BatchRotation::Sequential`]
    /// otherwise. Single-point [`IncrementalKpca::push`] is always
    /// sequential.
    pub batch_rotation: Option<BatchRotation>,
    pub stats: KpcaStats,
    /// Bounded-memory cap on the retained set (0 = unbounded). Enforced
    /// after every accepted example by evicting one
    /// [`EvictionPolicy`]-chosen landmark per excess point.
    max_landmarks: usize,
    /// Victim selection when the cap binds.
    eviction: EvictionPolicy,
    /// Leading landmarks never evicted (the seed prefix — what anchors
    /// the Nyström subset a downstream consumer was built against).
    protected: usize,
    /// Per-stream rank-one scratch, shared by all updates of a push.
    ws: UpdateWorkspace,
    /// Per-step vector scratch.
    scratch: StepScratch,
}

impl<'k> IncrementalKpca<'k> {
    /// Start from a batch eigendecomposition of the first
    /// `x0.rows()` examples (the paper's experiments start at m₀ = 20).
    /// `x0` may have zero rows for Algorithm 1 (cold start); Algorithm 2
    /// requires at least 2 initial points (the 1-point centered matrix
    /// is identically zero).
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::kernels::Rbf;
    /// use inkpca::kpca::IncrementalKpca;
    /// use inkpca::linalg::Mat;
    ///
    /// let kern = Rbf { sigma: 1.0 };
    /// // Two seed points in ℝ², then stream one more (Algorithm 2).
    /// let seed = Mat::from_vec(2, 2, vec![0.0, 0.0, 1.0, 0.5]);
    /// let mut kpca = IncrementalKpca::from_batch(&kern, &seed, true)?;
    /// assert_eq!(kpca.len(), 2);
    /// kpca.push(&[0.3, -0.2])?;
    /// assert_eq!(kpca.len(), 3);
    /// # Ok::<(), String>(())
    /// ```
    pub fn from_batch(
        kernel: &'k dyn Kernel,
        x0: &Mat,
        mean_adjust: bool,
    ) -> Result<Self, String> {
        Self::from_handle(KernelHandle::Borrowed(kernel), x0, mean_adjust)
    }

    /// [`IncrementalKpca::from_batch`] with shared kernel ownership: the
    /// state co-owns the kernel through the `Arc`, so it carries no
    /// borrow and the result is `'static` (and `Send`) — the form the
    /// coordinator's per-stream entries use.
    pub fn from_batch_shared(
        kernel: Arc<dyn Kernel>,
        x0: &Mat,
        mean_adjust: bool,
    ) -> Result<IncrementalKpca<'static>, String> {
        IncrementalKpca::from_handle(KernelHandle::Shared(kernel), x0, mean_adjust)
    }

    fn from_handle(
        kernel: KernelHandle<'k>,
        x0: &Mat,
        mean_adjust: bool,
    ) -> Result<Self, String> {
        let m = x0.rows();
        if mean_adjust && m < 2 {
            return Err("mean-adjusted incremental KPCA needs ≥ 2 seed points".into());
        }
        let dim = x0.cols();
        let mut state = IncrementalKpca {
            kernel,
            mean_adjust,
            x: x0.as_slice().to_vec(),
            dim,
            m,
            vals: Vec::new(),
            vecs: EigenBasis::new(),
            s: 0.0,
            k1: Vec::new(),
            exclude_tol: 1e-12,
            naive_recenter_split: false,
            batch_rotation: None,
            stats: KpcaStats::default(),
            max_landmarks: 0,
            eviction: EvictionPolicy::Off,
            protected: 0,
            ws: UpdateWorkspace::new(),
            scratch: StepScratch::default(),
        };
        if m > 0 {
            let k = crate::kernels::gram(state.kernel.get(), x0);
            let fit = super::batch::BatchKpca::fit_gram(k.clone(), mean_adjust)?;
            state.vals = fit.values;
            state.vecs = EigenBasis::from_mat(fit.vectors);
            state.s = k.as_slice().iter().sum();
            state.k1 = (0..m).map(|i| k.row(i).iter().sum()).collect();
            // Warm the workspace for the seeded size up front so the
            // first streamed example already runs allocation-free.
            state.ws.reserve(m, m);
        }
        state.stats.accepted = m;
        Ok(state)
    }

    /// Rebuild a state from checkpointed parts — the restore inverse of
    /// the accessors the durability codec reads
    /// ([`IncrementalKpca::data_flat`], `vals`, `vecs`,
    /// [`IncrementalKpca::centering_sums`], `stats`). The parts are
    /// taken at face value (they were produced by a live state and
    /// framed under a CRC); only structural consistency is checked.
    /// Scratch buffers start cold and re-warm on the first pushes.
    pub fn from_parts(
        kernel: Arc<dyn Kernel>,
        parts: KpcaParts,
    ) -> Result<IncrementalKpca<'static>, String> {
        let m = parts.vals.len();
        if parts.x.len() != m * parts.dim {
            return Err(format!(
                "restore: retained data is {} floats, want {m}×{}",
                parts.x.len(),
                parts.dim
            ));
        }
        if parts.vecs.len() != m * m {
            return Err(format!("restore: basis is {} floats, want {m}×{m}", parts.vecs.len()));
        }
        if parts.k1.len() != m {
            return Err(format!("restore: row sums are {} floats, want {m}", parts.k1.len()));
        }
        let mut state = IncrementalKpca {
            kernel: KernelHandle::Shared(kernel),
            mean_adjust: parts.mean_adjust,
            x: parts.x,
            dim: parts.dim,
            m,
            vals: parts.vals,
            vecs: EigenBasis::from_mat(Mat::from_vec(m, m, parts.vecs)),
            s: parts.s,
            k1: parts.k1,
            exclude_tol: parts.exclude_tol,
            naive_recenter_split: parts.naive_recenter_split,
            batch_rotation: parts.batch_rotation,
            stats: parts.stats,
            // The bound is stream *configuration*, not state — restore
            // callers re-apply it via set_bound (the coordinator does so
            // from the checkpointed StreamConfig).
            max_landmarks: 0,
            eviction: EvictionPolicy::Off,
            protected: 0,
            ws: UpdateWorkspace::new(),
            scratch: StepScratch::default(),
        };
        state.ws.reserve(m, m);
        // The engine-GEMM gauge is monotonic across the stream's life;
        // carry it over so pool counters survive a restart.
        state.ws.engine_gemms = parts.engine_gemms;
        Ok(state)
    }

    /// The kernel this state evaluates.
    pub fn kernel_ref(&self) -> &dyn Kernel {
        self.kernel.get()
    }

    /// The incrementally maintained centering sums: `Σₘ = 𝟙ᵀKₘ𝟙` and
    /// the row sums `Kₘ𝟙` of the *unadjusted* kernel matrix. These are
    /// what make mean-adjusted projection `O(m·r)` — no per-query Gram
    /// recomputation (see [`IncrementalKpca::project`]).
    pub fn centering_sums(&self) -> (f64, &[f64]) {
        (self.s, &self.k1)
    }

    /// Number of examples currently in the eigensystem.
    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Copy of the retained data as a matrix (evaluation paths).
    pub fn data(&self) -> Mat {
        Mat::from_vec(self.m, self.dim, self.x.clone())
    }

    /// The retained data as a borrowed flat `m × dim` row-major slice —
    /// the no-copy form the projection-snapshot capture and the blocked
    /// kernel helpers consume.
    pub fn data_flat(&self) -> &[f64] {
        &self.x[..self.m * self.dim]
    }

    /// The shared kernel handle, when this state owns its kernel
    /// through an `Arc` (`from_batch_shared` — every coordinator
    /// stream). Borrowed-kernel states return `None`: a snapshot cannot
    /// outlive a borrow.
    pub fn kernel_arc(&self) -> Option<Arc<dyn Kernel>> {
        match &self.kernel {
            KernelHandle::Shared(k) => Some(k.clone()),
            KernelHandle::Borrowed(_) => None,
        }
    }

    /// Row `i` of the retained data.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Buffer-growth events on the streaming hot path (rank-one
    /// workspace + eigenvector storage). Amortized O(1) per accepted
    /// example; constant at fixed eigensystem size.
    pub fn hot_path_reallocs(&self) -> u64 {
        self.ws.reallocs() + self.vecs.reallocs()
    }

    /// Bytes resident in the hot-path buffers (workspace + basis).
    pub fn hot_path_bytes(&self) -> usize {
        self.ws.bytes_resident() + self.vecs.bytes_resident()
    }

    /// The per-stream update workspace (diagnostics).
    pub fn workspace(&self) -> &UpdateWorkspace {
        &self.ws
    }

    /// Cap the retained set at `max_landmarks` points (0 = unbounded),
    /// choosing eviction victims by `policy` and never evicting the
    /// first `protected` landmarks (the seed prefix). Takes effect on
    /// the next accepted example; an already-over-cap state sheds one
    /// landmark per subsequent accept until it fits.
    pub fn set_bound(&mut self, max_landmarks: usize, policy: EvictionPolicy, protected: usize) {
        self.max_landmarks = max_landmarks;
        self.eviction = policy;
        self.protected = protected;
    }

    /// The bounded-memory configuration `(max_landmarks, policy,
    /// protected)` last set by [`IncrementalKpca::set_bound`].
    pub fn bound(&self) -> (usize, EvictionPolicy, usize) {
        (self.max_landmarks, self.eviction, self.protected)
    }

    /// Landmarks evicted so far (shorthand for `stats.evictions`).
    pub fn evictions(&self) -> usize {
        self.stats.evictions
    }

    /// Sufficiency signal of the current landmark set: the share of the
    /// retained spectrum carried by its *smallest* positive eigenvalue,
    /// `λ⁺_min / Σλ⁺`. When this gap is small the weakest retained
    /// direction contributes almost nothing — the landmark set is
    /// sufficient and a bounded stream loses little by evicting. The
    /// `n/m` Nyström rescaling cancels in the ratio, so the gauge reads
    /// the same from an [`IncrementalKpca`] and the Nyström layer above
    /// it. Returns 0 on an empty or fully collapsed spectrum.
    pub fn sufficiency_gap(&self) -> f64 {
        let mut total = 0.0;
        let mut min_pos = f64::INFINITY;
        for &l in &self.vals {
            if l > 0.0 {
                total += l;
                if l < min_pos {
                    min_pos = l;
                }
            }
        }
        if total > 0.0 && min_pos.is_finite() {
            min_pos / total
        } else {
            0.0
        }
    }

    /// Ridge leverage scores of the retained landmarks,
    /// `ℓᵢ = Σ_c U[i,c]² λ⁺_c/(λ⁺_c + μ)` with ridge `μ = trace⁺/m`.
    /// Flushes any pending rotation first (scores read the materialized
    /// basis). By orthonormality `Σᵢ ℓᵢ = Σ_c λ⁺_c/(λ⁺_c + μ)` — the
    /// effective rank of the tracked matrix at ridge `μ` (pinned by the
    /// leverage property test).
    pub fn leverage_scores(&mut self, engine: &dyn Rotate, out: &mut Vec<f64>) {
        flush_rotation_ws(&mut self.vecs, engine, &mut self.ws);
        self.leverage_scores_flushed(out);
    }

    /// Ridge leverage score of the single retained landmark `i` — the
    /// same `ℓᵢ = Σ_c U[i,c]² λ⁺_c/(λ⁺_c + μ)` as
    /// [`IncrementalKpca::leverage_scores`], but `O(m·n)` for one row
    /// and read *through* any pending blocked rotation (no flush). The
    /// eviction path appends the newly accepted landmark's score with
    /// this between full rescores.
    pub fn leverage_score_row(&mut self, i: usize) -> f64 {
        assert!(i < self.m, "leverage_score_row index out of range");
        let mut erow = std::mem::take(&mut self.scratch.erow);
        effective_row_into(&self.vecs, &self.ws, i, &mut erow);
        let trace_pos: f64 = self.vals.iter().map(|l| l.max(0.0)).sum();
        let score = if trace_pos <= 0.0 {
            0.0
        } else {
            let mu = trace_pos / self.m as f64;
            erow.iter()
                .zip(&self.vals)
                .map(|(e, &lam)| {
                    let lam = lam.max(0.0);
                    e * e * lam / (lam + mu)
                })
                .sum()
        };
        self.scratch.erow = erow;
        score
    }

    /// [`IncrementalKpca::leverage_scores`] on an already-flushed basis.
    fn leverage_scores_flushed(&self, out: &mut Vec<f64>) {
        debug_assert!(!self.ws.pending_rotation(), "leverage scores on a stale basis");
        let n = self.vals.len();
        let trace_pos: f64 = self.vals.iter().map(|l| l.max(0.0)).sum();
        out.clear();
        if trace_pos <= 0.0 {
            out.resize(self.m, 0.0);
            return;
        }
        let mu = trace_pos / self.m as f64;
        for i in 0..self.m {
            let row = self.vecs.row(i);
            let mut l = 0.0;
            for c in 0..n {
                let lam = self.vals[c].max(0.0);
                l += row[c] * row[c] * lam / (lam + mu);
            }
            out.push(l);
        }
    }

    /// Down-date: remove retained landmark `j` from the eigensystem —
    /// the exact reverse of the eq. 2/3 expansion. Two rank-one updates
    /// zero the victim's row/column in the tracked matrix, decoupling
    /// its eigenpair onto the coordinate axis `e_j`; the pair and the
    /// basis row are then dropped (through the pending blocked product
    /// when one is accumulating — a mid-batch eviction defers like any
    /// other update), the running sums shed the victim's kernel
    /// column, and mean-adjusted streams re-center over the survivors
    /// with the same norm-balanced symmetric pair as ingest.
    ///
    /// `O(m²)` per call (two rank-one updates + two re-centering ones
    /// when adjusted) against `O(m³)` for a recompute; the eviction
    /// oracle suite pins evict + re-add ≡ batch recompute to ≤ 1e-10.
    pub fn remove_point(&mut self, j: usize, engine: &dyn Rotate) -> Result<(), String> {
        let fused = self.ws.pending_rotation();
        self.remove_point_inner(j, engine, fused)
    }

    fn remove_point_inner(
        &mut self,
        j: usize,
        engine: &dyn Rotate,
        fused: bool,
    ) -> Result<(), String> {
        assert!(j < self.m, "remove_point index out of range");
        if self.mean_adjust && self.m < 3 {
            return Err("mean-adjusted down-date needs ≥ 3 retained points".into());
        }
        let m = self.m;
        let mf = m as f64;
        // Kernel column of the victim against the whole retained set
        // (its own diagonal included) — the row being zeroed out.
        let mut a = std::mem::take(&mut self.scratch.a);
        {
            let xj = &self.x[j * self.dim..(j + 1) * self.dim];
            kernel_column_into(self.kernel.get(), &self.x, self.dim, m, xj, &mut a);
        }
        let d = a[j];
        // Row/column j of the *tracked* matrix: centered entries when
        // mean-adjusted, raw kernel values otherwise.
        let dt = if self.mean_adjust {
            d - 2.0 * self.k1[j] / mf + self.s / (mf * mf)
        } else {
            d
        };
        // Decouple: K ← K − σ v₁v₁ᵀ + σ v₂v₂ᵀ with σ = 4/d̃ zeroes row
        // and column j and pins the diagonal at d̃/4, leaving the exact
        // eigenpair (d̃/4, e_j) — the reverse of the expansion identity.
        // A (near-)zero tracked diagonal means the row is already ≈ 0
        // (SPSD: |K'ᵢⱼ| ≤ √(K'ᵢᵢK'ⱼⱼ)) — skip the updates, the pair is
        // as decoupled as the spectrum allows.
        if dt.abs() > self.exclude_tol {
            self.scratch.v1.clear();
            for i in 0..m {
                let e = if self.mean_adjust {
                    a[i] - (self.k1[i] + self.k1[j]) / mf + self.s / (mf * mf)
                } else {
                    a[i]
                };
                self.scratch.v1.push(e);
            }
            self.scratch.v2.clear();
            self.scratch.v2.extend_from_slice(&self.scratch.v1);
            self.scratch.v1[j] = 0.5 * dt;
            self.scratch.v2[j] = 0.25 * dt;
            let sigma = 4.0 / dt;
            let st = apply_rank_one(
                &mut self.vals,
                &mut self.vecs,
                -sigma,
                &self.scratch.v1,
                engine,
                &mut self.ws,
                fused,
            )?;
            self.stats.absorb(st);
            let st = apply_rank_one(
                &mut self.vals,
                &mut self.vecs,
                sigma,
                &self.scratch.v2,
                engine,
                &mut self.ws,
                fused,
            )?;
            self.stats.absorb(st);
        }
        // Locate the decoupled pair: the eigenvector living on e_j is
        // the effective-basis column with the dominant row-j entry
        // (±1; all others are 0 to rounding). Read through the pending
        // product — no flush required.
        let mut erow = std::mem::take(&mut self.scratch.erow);
        effective_row_into(&self.vecs, &self.ws, j, &mut erow);
        let mut c = 0;
        for (k, e) in erow.iter().enumerate() {
            if e.abs() > erow[c].abs() {
                c = k;
            }
        }
        self.scratch.erow = erow;
        // Drop the pair and the victim's basis row (deferred-aware).
        remove_eigenpair_ws(&mut self.vals, &mut self.vecs, c, j, &mut self.ws);
        // Shed the victim from the raw running sums and the data.
        let mut asum_excl = 0.0;
        for (i, ai) in a.iter().enumerate() {
            if i != j {
                asum_excl += ai;
            }
        }
        let s_old = self.s;
        self.s -= 2.0 * asum_excl + d;
        for (i, k1i) in self.k1.iter_mut().enumerate() {
            if i != j {
                *k1i -= a[i];
            }
        }
        self.k1.remove(j);
        self.x.drain(j * self.dim..(j + 1) * self.dim);
        self.m -= 1;
        self.stats.evictions += 1;
        // Mean-adjusted: the survivors' mean moved, so re-center the
        // tracked matrix over m′ = m − 1 points: K″ = K′ + w𝟙ᵀ + 𝟙wᵀ
        // with wᵢ = −K₁ᵢ/(m·m′) + aᵢ/m′ + ½c, c = Σ′/m′² − Σ/m² (K₁ the
        // pre-removal row sums of the survivors) — applied as the same
        // norm-balanced ±½(γ𝟙 ± w/γ) pair as ingest.
        if self.mean_adjust {
            let mpf = self.m as f64;
            let cshift = self.s / (mpf * mpf) - s_old / (mf * mf);
            self.scratch.u.clear();
            for i in 0..self.m {
                let o = if i < j { i } else { i + 1 };
                let w = -(self.k1[i] + a[o]) / (mf * mpf) + a[o] / mpf;
                self.scratch.u.push(w + 0.5 * cshift);
            }
            let wnorm = crate::linalg::norm2(&self.scratch.u);
            if wnorm > 0.0 {
                let gamma = if self.naive_recenter_split {
                    1.0
                } else {
                    (wnorm / mpf.sqrt()).sqrt()
                };
                self.scratch.vp.clear();
                self.scratch.vm.clear();
                for &wi in &self.scratch.u {
                    self.scratch.vp.push(gamma + wi / gamma);
                    self.scratch.vm.push(gamma - wi / gamma);
                }
                let st = apply_rank_one(
                    &mut self.vals,
                    &mut self.vecs,
                    0.5,
                    &self.scratch.vp,
                    engine,
                    &mut self.ws,
                    fused,
                )?;
                self.stats.absorb(st);
                let st = apply_rank_one(
                    &mut self.vals,
                    &mut self.vecs,
                    -0.5,
                    &self.scratch.vm,
                    engine,
                    &mut self.ws,
                    fused,
                )?;
                self.stats.absorb(st);
            }
        }
        self.scratch.a = a;
        Ok(())
    }

    /// One step of bound enforcement: when the cap binds (`max > 0`,
    /// policy active, `m > max`) evict the policy's victim and return
    /// its (pre-removal) position; `Ok(None)` when the state fits.
    /// Callers loop until `None` — an over-cap restored state converges
    /// one landmark per accept.
    ///
    /// Leverage scoring is batched: the full `O(m²)` rescore runs only
    /// every [`LEV_REFRESH_EVERY`] evictions (keyed off the persisted
    /// eviction counter, so WAL replay hits the same refresh points);
    /// between refreshes the cached scores survive — victims are
    /// removed from the cache in lockstep — and only the newly accepted
    /// landmark's `O(m·n)` row score is appended. Scores between
    /// refreshes are therefore up to [`LEV_REFRESH_EVERY`] down-dates
    /// stale; the eviction oracle suite bounds the resulting drift.
    fn enforce_bound_step(
        &mut self,
        engine: &dyn Rotate,
        fused: bool,
    ) -> Result<Option<usize>, String> {
        if self.max_landmarks == 0
            || self.eviction == EvictionPolicy::Off
            || self.m <= self.max_landmarks
            || self.m <= self.protected
        {
            return Ok(None);
        }
        let free = self.m - self.protected;
        let j = match self.eviction {
            EvictionPolicy::Off => unreachable!("checked above"),
            EvictionPolicy::Uniform => self.protected + self.stats.evictions % free,
            EvictionPolicy::LeverageScore => {
                let mut lev = std::mem::take(&mut self.scratch.lev);
                // The cache is valid when it covers exactly the
                // pre-accept landmark set (one short of m); anything
                // else — cold start, restored state, multi-step
                // convergence — forces a full rescore.
                if self.stats.evictions % LEV_REFRESH_EVERY == 0 || lev.len() + 1 != self.m {
                    self.leverage_scores(engine, &mut lev);
                } else {
                    lev.push(self.leverage_score_row(self.m - 1));
                }
                let mut j = self.protected;
                for i in self.protected + 1..self.m {
                    if lev[i] < lev[j] {
                        j = i;
                    }
                }
                // Keep the cache in lockstep with the survivors.
                lev.remove(j);
                self.scratch.lev = lev;
                j
            }
        };
        self.remove_point_inner(j, engine, fused)?;
        Ok(Some(j))
    }

    /// Ingest one example with the default native rotation engine.
    pub fn push(&mut self, xnew: &[f64]) -> Result<bool, String> {
        self.push_with(xnew, &NativeRotate)
    }

    /// Ingest one example, routing the `2m³` back-rotations through
    /// `engine`. Returns `Ok(false)` when the example was excluded as
    /// rank-deficient rather than accepted.
    pub fn push_with(&mut self, xnew: &[f64], engine: &dyn Rotate) -> Result<bool, String> {
        assert_eq!(xnew.len(), self.dim, "dimension mismatch");
        if self.m == 0 {
            return self.bootstrap_first(xnew);
        }
        // Kernel column a = [k(x₁,x) … k(xₘ,x)]ᵀ into reusable scratch —
        // no per-push clone of the retained data.
        let mut a = std::mem::take(&mut self.scratch.a);
        kernel_column_into(self.kernel.get(), &self.x, self.dim, self.m, xnew, &mut a);
        self.scratch.a = a;
        let knew = self.kernel.get().eval(xnew, xnew);
        let accepted = if self.mean_adjust {
            self.push_adjusted(xnew, knew, engine, false)?
        } else {
            self.push_unadjusted(xnew, knew, engine, false)?
        };
        if accepted {
            while self.enforce_bound_step(engine, false)?.is_some() {}
        }
        Ok(accepted)
    }

    /// First point of a cold-started (unadjusted) stream: the 1×1
    /// eigensystem is immediate. Grows the existing (possibly
    /// pre-[`IncrementalKpca::reserve`]d) buffers in place rather than
    /// replacing them, so reserved capacity survives the cold start.
    fn bootstrap_first(&mut self, xnew: &[f64]) -> Result<bool, String> {
        if self.mean_adjust {
            return Err("mean-adjusted stream cannot cold-start from m=0".into());
        }
        debug_assert_eq!(self.vecs.cols(), 0, "bootstrap on a non-empty basis");
        let knew = self.kernel.get().eval(xnew, xnew);
        self.x.extend_from_slice(xnew);
        self.m = 1;
        self.vals.clear();
        self.vals.push(knew);
        self.vecs.expand(); // 0×0 → zeroed 1×1, within reserved capacity
        self.vecs[(0, 0)] = 1.0;
        self.s = knew;
        self.k1.clear();
        self.k1.push(knew);
        self.stats.accepted += 1;
        Ok(true)
    }

    /// Ingest a whole batch with the default native rotation engine
    /// (see [`IncrementalKpca::push_batch_with`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::kernels::Linear;
    /// use inkpca::kpca::IncrementalKpca;
    /// use inkpca::linalg::Mat;
    ///
    /// let kern = Linear;
    /// let mut kpca = IncrementalKpca::from_batch(&kern, &Mat::zeros(0, 2), false)?;
    /// // Four 2-d points, flat row-major: one blocked kernel
    /// // evaluation, one fused back-rotation GEMM for the batch.
    /// let out = kpca.push_batch(&[1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.5, -0.5])?;
    /// assert_eq!(out.accepted, 4);
    /// assert_eq!(kpca.last_batch_mask(), &[true, true, true, true]);
    /// # Ok::<(), String>(())
    /// ```
    pub fn push_batch(&mut self, xs: &[f64]) -> Result<BatchOutcome, String> {
        self.push_batch_with(xs, &NativeRotate)
    }

    /// Ingest `b = xs.len() / dim` examples (flat row-major) in one
    /// call. The kernel rows of all `b` points against the `m` retained
    /// points — and the `b × b` block among the new points themselves —
    /// are computed up front as blocked GEMMs
    /// ([`kernel_rows_into`]: one `matmul_nt_into` plus an entry map
    /// for dot-product-family kernels, the row-norm trick for RBF, a
    /// scalar fallback otherwise); the `b` rank-one update sequences
    /// then run back to back with no kernel evaluation in between.
    ///
    /// Under the default [`BatchRotation::Fused`] strategy (auto-picked
    /// for `b ≥ 2`, overridable via
    /// [`IncrementalKpca::batch_rotation`]) the per-update
    /// back-rotations are folded into one pending product and applied
    /// as a single engine GEMM when the batch completes — the blocked
    /// rank-b update ([`rank_one_update_fused_ws`]). Updates that would
    /// deflate fall back to the sequential rotation mid-batch, so
    /// either strategy reaches the same eigensystem to rounding
    /// (≤ 1e-10, pinned by `tests/batching.rs`).
    ///
    /// Points are applied in order; a point excluded as rank-deficient
    /// (§5.1) simply does not join the retained set, exactly as in the
    /// sequential path. On `Err`, points before the failing one remain
    /// applied (and any pending rotation is flushed before returning).
    pub fn push_batch_with(
        &mut self,
        xs: &[f64],
        engine: &dyn Rotate,
    ) -> Result<BatchOutcome, String> {
        assert!(self.dim > 0, "push_batch on a zero-dimensional stream");
        assert_eq!(xs.len() % self.dim, 0, "batch length not a multiple of dim");
        let b = xs.len() / self.dim;
        let cap_mask = self.scratch.mask.capacity();
        let cap_prov = self.scratch.prov.capacity();
        self.scratch.mask.clear();
        self.scratch.prov.clear();
        if b == 0 {
            return Ok(BatchOutcome::default());
        }
        let fused = self.rotation_for(b) == BatchRotation::Fused;
        let m0 = self.m;
        // Provenance of the retained set against the precomputed kernel
        // blocks: batch-start landmarks map to `block` columns, points
        // accepted during the batch to `intra` entries. Mid-batch
        // evictions shift this in lockstep with the retained set.
        self.scratch.prov.extend(0..m0);
        // Stage 1: blocked kernel rows — batch × retained, batch × batch.
        {
            let mut block = std::mem::take(&mut self.scratch.block);
            let mut kb = std::mem::take(&mut self.scratch.kb);
            kernel_rows_into(self.kernel.get(), &self.x, self.dim, m0, xs, b, &mut block, &mut kb);
            self.scratch.block = block;
            let mut intra = std::mem::take(&mut self.scratch.intra);
            kernel_rows_into(self.kernel.get(), xs, self.dim, b, xs, b, &mut intra, &mut kb);
            self.scratch.intra = intra;
            self.scratch.kb = kb;
        }
        // Stage 2: the b rank-one update sequences, in order. The kernel
        // column of point i is the precomputed row against the original
        // retained set plus the intra-batch entries of the points
        // accepted before it. Under the fused strategy the sequences
        // accumulate one rotation product across the whole batch.
        let mut outcome = BatchOutcome::default();
        let mut failure: Option<String> = None;
        for i in 0..b {
            let xi = &xs[i * self.dim..(i + 1) * self.dim];
            let step = if self.m == 0 {
                self.bootstrap_first(xi)
            } else {
                let mut a = std::mem::take(&mut self.scratch.a);
                let cap_a = a.capacity();
                a.clear();
                for &src in &self.scratch.prov {
                    a.push(if src < m0 {
                        self.scratch.block[i * m0 + src]
                    } else {
                        self.scratch.intra[i * b + (src - m0)]
                    });
                }
                if a.capacity() > cap_a {
                    self.scratch.reallocs += 1;
                }
                self.scratch.a = a;
                let knew = self.scratch.intra[i * b + i];
                if self.mean_adjust {
                    self.push_adjusted(xi, knew, engine, fused)
                } else {
                    self.push_unadjusted(xi, knew, engine, fused)
                }
            };
            match step {
                Ok(accepted) => {
                    self.scratch.mask.push(accepted);
                    if accepted {
                        self.scratch.prov.push(m0 + i);
                        outcome.accepted += 1;
                        // Bound enforcement may evict mid-batch; keep
                        // the provenance aligned with the retained set
                        // (later columns read through the shift).
                        loop {
                            match self.enforce_bound_step(engine, fused) {
                                Ok(Some(p)) => {
                                    self.scratch.prov.remove(p);
                                }
                                Ok(None) => break,
                                Err(e) => {
                                    failure = Some(e);
                                    break;
                                }
                            }
                        }
                        if failure.is_some() {
                            break;
                        }
                    } else {
                        outcome.excluded += 1;
                    }
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        // Materialize the batch's pending rotation — even on failure,
        // so the applied prefix is directly readable (projection,
        // reconstruction, snapshots) the moment this returns.
        flush_rotation_ws(&mut self.vecs, engine, &mut self.ws);
        if self.scratch.mask.capacity() > cap_mask {
            self.scratch.reallocs += 1;
        }
        if self.scratch.prov.capacity() > cap_prov {
            self.scratch.reallocs += 1;
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(outcome),
        }
    }

    /// The back-rotation strategy a batch of `b` points will use:
    /// the explicit [`IncrementalKpca::batch_rotation`] override, or
    /// the auto rule — fused as soon as more than one point shares the
    /// flush.
    pub fn rotation_for(&self, b: usize) -> BatchRotation {
        self.batch_rotation.unwrap_or(if b >= 2 {
            BatchRotation::Fused
        } else {
            BatchRotation::Sequential
        })
    }

    /// Per-point accept flags of the most recent
    /// [`IncrementalKpca::push_batch_with`] call (empty before the
    /// first batch). Entry `i` is `true` iff batch point `i` joined the
    /// retained set.
    pub fn last_batch_mask(&self) -> &[bool] {
        &self.scratch.mask
    }

    /// Capacity-growth events in the batched-ingest scratch (kernel
    /// blocks, row norms, assembly buffers) — the batch-path companion
    /// of [`IncrementalKpca::hot_path_reallocs`], zero once warm.
    pub fn batch_reallocs(&self) -> u64 {
        self.scratch.reallocs + self.scratch.kb.reallocs()
    }

    /// Bytes resident in the batched-ingest scratch (kernel blocks,
    /// intra-batch block, accept mask/indices, row norms) — the
    /// batch-path companion of [`IncrementalKpca::hot_path_bytes`]. A
    /// stream that never batches holds none of this.
    pub fn batch_bytes_resident(&self) -> usize {
        let f = std::mem::size_of::<f64>();
        f * (self.scratch.block.capacity() + self.scratch.intra.capacity())
            + std::mem::size_of::<bool>() * self.scratch.mask.capacity()
            + std::mem::size_of::<usize>() * self.scratch.prov.capacity()
            + self.scratch.kb.bytes_resident()
    }

    /// `U`-sized back-rotation GEMMs dispatched to the rotation engine
    /// (one per sequential rank-one update, one per blocked-batch
    /// flush) — the quantity the [`BatchRotation::Fused`] path
    /// amortizes. Shorthand for `self.workspace().engine_gemms()`.
    pub fn engine_gemms(&self) -> u64 {
        self.ws.engine_gemms()
    }

    /// Pre-size every hot-path buffer for eigensystems up to `m` rows
    /// and ingest batches up to `b` points, without counting toward the
    /// realloc counters — after this, streaming (single or batched) up
    /// to that size touches the allocator only for the retained-data
    /// and running-sum appends.
    ///
    /// # Examples
    ///
    /// ```
    /// use inkpca::kernels::Rbf;
    /// use inkpca::kpca::IncrementalKpca;
    /// use inkpca::linalg::Mat;
    ///
    /// let kern = Rbf { sigma: 1.0 };
    /// let mut kpca = IncrementalKpca::from_batch(&kern, &Mat::zeros(0, 3), false)?;
    /// kpca.reserve(64, 16); // eigensystems up to 64 points, batches up to 16
    /// let before = kpca.hot_path_reallocs();
    /// let pts: Vec<f64> = (0..8 * 3).map(|i| (i as f64 * 0.37).sin()).collect();
    /// kpca.push_batch(&pts)?; // 8 points, well inside the reservation
    /// assert_eq!(kpca.hot_path_reallocs(), before, "warm path must not allocate");
    /// # Ok::<(), String>(())
    /// ```
    pub fn reserve(&mut self, m: usize, b: usize) {
        self.ws.reserve(m, m);
        // The pending-product scratch is another 2m² floats — skip it
        // only when this stream is *forced* sequential and provably
        // never fuses. Auto streams keep it even when the declared
        // batch is small: a later larger batch would otherwise grow
        // the buffers mid-stream, breaking the allocation-silent
        // promise this method exists for.
        if self.batch_rotation != Some(BatchRotation::Sequential) {
            self.ws.reserve_blocked(m);
        }
        self.vecs.reserve(m, m);
        self.x.reserve((m * self.dim).saturating_sub(self.x.len()));
        self.k1.reserve(m.saturating_sub(self.k1.len()));
        let s = &mut self.scratch;
        for buf in [
            &mut s.a, &mut s.u, &mut s.vp, &mut s.vm, &mut s.k1_next, &mut s.v, &mut s.v1,
            &mut s.v2, &mut s.erow, &mut s.lev,
        ] {
            if buf.capacity() < m + 1 {
                buf.reserve(m + 1 - buf.len());
            }
        }
        if s.block.capacity() < b * m {
            s.block.reserve(b * m - s.block.len());
        }
        if s.intra.capacity() < b * b {
            s.intra.reserve(b * b - s.intra.len());
        }
        if s.mask.capacity() < b {
            s.mask.reserve(b - s.mask.len());
        }
        // Provenance spans the retained set plus the whole batch.
        if s.prov.capacity() < m + b {
            s.prov.reserve(m + b - s.prov.len());
        }
        s.kb.reserve(m, b, self.dim);
    }

    /// Algorithm 1: expansion + two rank-one updates (eq. 2). Reads the
    /// kernel column from `self.scratch.a`. With `fused` set the two
    /// updates accumulate into the workspace's pending rotation product
    /// instead of rotating the basis eagerly.
    fn push_unadjusted(
        &mut self,
        xnew: &[f64],
        knew: f64,
        engine: &dyn Rotate,
        fused: bool,
    ) -> Result<bool, String> {
        if knew.abs() <= self.exclude_tol {
            self.stats.excluded += 1;
            return Ok(false);
        }
        // L ← [L  k/4];  U ← diag(U, 1)   [Algorithm 1, lines 1–2]
        expand_eigensystem_ws(&mut self.vals, &mut self.vecs, 0.25 * knew, &mut self.ws);
        let sigma = 4.0 / knew; // line 3
        self.scratch.v1.clear();
        self.scratch.v1.extend_from_slice(&self.scratch.a);
        self.scratch.v1.push(0.5 * knew); // line 4
        self.scratch.v2.clear();
        self.scratch.v2.extend_from_slice(&self.scratch.a);
        self.scratch.v2.push(0.25 * knew); // line 5
        let s1 = apply_rank_one(
            &mut self.vals,
            &mut self.vecs,
            sigma,
            &self.scratch.v1,
            engine,
            &mut self.ws,
            fused,
        )?;
        self.stats.absorb(s1); // line 6
        let s2 = apply_rank_one(
            &mut self.vals,
            &mut self.vecs,
            -sigma,
            &self.scratch.v2,
            engine,
            &mut self.ws,
            fused,
        )?;
        self.stats.absorb(s2); // line 7

        // Maintain running sums so a later switch to Nyström rescaling
        // (or to the adjusted algorithm's bookkeeping) stays cheap.
        let asum: f64 = self.scratch.a.iter().sum();
        self.s += 2.0 * asum + knew;
        for (k1i, ai) in self.k1.iter_mut().zip(&self.scratch.a) {
            *k1i += ai;
        }
        self.k1.push(asum + knew);
        self.x.extend_from_slice(xnew);
        self.m += 1;
        self.stats.accepted += 1;
        Ok(true)
    }

    /// Algorithm 2: two re-centering updates, then expansion + two more
    /// rank-one updates (eq. 3). Reads the kernel column from
    /// `self.scratch.a`. With `fused` set, all four updates (and the
    /// expansion) defer into the workspace's pending rotation product.
    fn push_adjusted(
        &mut self,
        xnew: &[f64],
        knew: f64,
        engine: &dyn Rotate,
        fused: bool,
    ) -> Result<bool, String> {
        let m = self.m;
        let mf = m as f64;
        let asum: f64 = self.scratch.a.iter().sum();

        // Lines 2–4: running sums and the mean-shift vector u.
        let s2 = self.s + 2.0 * asum + knew;
        let c = -self.s / (mf * mf) + s2 / ((mf + 1.0) * (mf + 1.0));
        self.scratch.u.clear();
        for i in 0..m {
            self.scratch.u.push(
                self.k1[i] / (mf * (mf + 1.0)) - self.scratch.a[i] / (mf + 1.0) + 0.5 * c,
            );
        }

        // Lines 7–10 (hoisted): the centered new row/column over the
        // m+1 points, v = k − (𝟙𝟙ᵀk + K𝟙 − Σ/(m+1)·𝟙)/(m+1). Computed
        // *before* any eigensystem mutation so the §5.1 exclusion below
        // can reject the example without corrupting state.
        self.scratch.k1_next.clear();
        self.scratch.k1_next.extend_from_slice(&self.k1);
        for (k1i, ai) in self.scratch.k1_next.iter_mut().zip(&self.scratch.a) {
            *k1i += ai;
        }
        self.scratch.k1_next.push(asum + knew);
        let m1f = mf + 1.0;
        let ksum = asum + knew; // 𝟙ᵀ[a; k]
        self.scratch.v.clear();
        for i in 0..m + 1 {
            let ki = if i < m { self.scratch.a[i] } else { knew };
            self.scratch.v.push(ki - (ksum + self.scratch.k1_next[i] - s2 / m1f) / m1f);
        }
        let v0 = self.scratch.v[m];

        // §5.1: a non-positive centered diagonal signals (near-)rank
        // deficiency — the expanded matrix cannot stay SPSD. Exclude.
        if v0 <= self.exclude_tol {
            self.stats.excluded += 1;
            return Ok(false);
        }

        // Lines 5–6: K'' = K' + 𝟙uᵀ + u𝟙ᵀ as two symmetric rank-one
        // updates. The paper splits as ½(𝟙+u)(·)ᵀ − ½(𝟙−u)(·)ᵀ, whose
        // terms have norm² ≈ m and nearly cancel — each update is only
        // accurate relative to its own O(m) scale, so the small net
        // change loses ~ε·m absolute accuracy per step. We use the
        // norm-balanced equivalent (γ𝟙 ± u/γ) with γ² = ‖u‖/‖𝟙‖, which
        // shrinks the cancelling mass to O(‖u‖√m) — same identity
        // ((a+b)(a+b)ᵀ − (a−b)(a−b)ᵀ = 2(abᵀ+baᵀ)), ~100× less drift on
        // fast-decaying spectra. (The paper explicitly invites swapping
        // the rank-one update "for potentially improved accuracy".)
        let unorm = crate::linalg::norm2(&self.scratch.u);
        if unorm > 0.0 {
            let gamma = if self.naive_recenter_split {
                1.0 // the paper's literal (𝟙±u) split
            } else {
                (unorm / mf.sqrt()).sqrt()
            };
            self.scratch.vp.clear();
            self.scratch.vm.clear();
            for &ui in &self.scratch.u {
                self.scratch.vp.push(gamma + ui / gamma);
                self.scratch.vm.push(gamma - ui / gamma);
            }
            let st = apply_rank_one(
                &mut self.vals,
                &mut self.vecs,
                0.5,
                &self.scratch.vp,
                engine,
                &mut self.ws,
                fused,
            )?;
            self.stats.absorb(st);
            let st = apply_rank_one(
                &mut self.vals,
                &mut self.vecs,
                -0.5,
                &self.scratch.vm,
                engine,
                &mut self.ws,
                fused,
            )?;
            self.stats.absorb(st);
        }

        // Lines 13–17: expansion and the two final updates (eq. 3).
        expand_eigensystem_ws(&mut self.vals, &mut self.vecs, 0.25 * v0, &mut self.ws);
        let sigma = 4.0 / v0;
        self.scratch.v1.clear();
        self.scratch.v1.extend_from_slice(&self.scratch.v[..m]);
        self.scratch.v1.push(0.5 * v0);
        self.scratch.v2.clear();
        self.scratch.v2.extend_from_slice(&self.scratch.v[..m]);
        self.scratch.v2.push(0.25 * v0);
        let st = apply_rank_one(
            &mut self.vals,
            &mut self.vecs,
            sigma,
            &self.scratch.v1,
            engine,
            &mut self.ws,
            fused,
        )?;
        self.stats.absorb(st);
        let st = apply_rank_one(
            &mut self.vals,
            &mut self.vecs,
            -sigma,
            &self.scratch.v2,
            engine,
            &mut self.ws,
            fused,
        )?;
        self.stats.absorb(st);

        // Commit state only after all updates succeeded (k1 swaps with
        // the scratch-built next-step sums — no allocation).
        self.s = s2;
        std::mem::swap(&mut self.k1, &mut self.scratch.k1_next);
        self.x.extend_from_slice(xnew);
        self.m += 1;
        self.stats.accepted += 1;
        Ok(true)
    }

    /// Reconstruction `U Λ Uᵀ` of the tracked (adjusted) kernel matrix —
    /// the quantity compared against the batch matrix in Fig. 1.
    pub fn reconstruct(&self) -> Mat {
        let n = self.vals.len();
        let mut vl = self.vecs.to_mat();
        for i in 0..vl.rows() {
            for j in 0..n {
                vl[(i, j)] *= self.vals[j];
            }
        }
        crate::linalg::matmul_nt(&vl, &self.vecs)
    }

    /// Batch-recomputed ground truth of the tracked matrix (drift
    /// reference; `O(m³)` — for experiments, not the hot path).
    pub fn batch_reference(&self) -> Mat {
        let xmat = self.data();
        let k = crate::kernels::gram(self.kernel.get(), &xmat);
        if self.mean_adjust {
            super::centering::center_gram(&k)
        } else {
            k
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{magic_like, yeast_like};
    use crate::kernels::{Linear, Rbf};
    use crate::linalg::orthogonality_defect;

    #[test]
    fn unadjusted_matches_batch_exactly() {
        let ds = yeast_like(24, 1);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, false).unwrap();
        for i in 4..ds.n() {
            assert!(inc.push(ds.x.row(i)).unwrap());
        }
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
        assert!(orthogonality_defect(&inc.vecs) < 1e-9);
    }

    #[test]
    fn adjusted_matches_batch_exactly() {
        let ds = yeast_like(20, 2);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(5, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 5..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        assert_eq!(inc.len(), 20);
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
        assert!(orthogonality_defect(&inc.vecs) < 1e-9);
    }

    #[test]
    fn adjusted_heavy_tailed_data() {
        let mut ds = magic_like(18, 3);
        ds.standardize();
        let kern = Rbf { sigma: crate::kernels::median_heuristic(&ds.x, 100) };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 6..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-7, "drift {drift}");
    }

    #[test]
    fn cold_start_unadjusted_from_zero() {
        let ds = yeast_like(10, 4);
        let kern = Rbf { sigma: 1.0 };
        let empty = Mat::zeros(0, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &empty, false).unwrap();
        for i in 0..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        assert_eq!(inc.len(), 10);
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-9, "drift {drift}");
    }

    #[test]
    fn adjusted_requires_two_seed_points() {
        let kern = Rbf { sigma: 1.0 };
        let one = Mat::zeros(1, 3);
        assert!(IncrementalKpca::from_batch(&kern, &one, true).is_err());
    }

    #[test]
    fn duplicate_point_survives_via_deflation() {
        // A repeated example makes K' singular (two identical rows); the
        // deflation path must absorb it without error and stay exact.
        let ds = yeast_like(6, 5);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(5, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        let dup = ds.x.row(2).to_vec();
        assert!(inc.push(&dup).unwrap());
        assert!(inc.push(ds.x.row(5)).unwrap());
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-7, "drift {drift}");
    }

    #[test]
    fn mean_point_excluded_when_adjusted() {
        // With the linear kernel the feature mean is the data mean, so a
        // new point AT the mean has centered diagonal v₀ = 0 → the §5.1
        // exclusion path must fire rather than dividing by v₀.
        let ds = yeast_like(8, 9);
        let kern = Linear;
        let seed = ds.x.submatrix(8, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        let mean: Vec<f64> =
            (0..ds.dim()).map(|j| (0..8).map(|i| ds.x[(i, j)]).sum::<f64>() / 8.0).collect();
        let accepted = inc.push(&mean).unwrap();
        assert!(!accepted);
        assert_eq!(inc.stats.excluded, 1);
        assert_eq!(inc.len(), 8);
        // State is untouched and still exact.
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-9);
    }

    #[test]
    fn eigenvalues_stay_sorted_and_nonnegative() {
        let ds = yeast_like(16, 6);
        let kern = Rbf { sigma: 2.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 4..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
            for w in inc.vals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
            // Centered PSD matrix: eigenvalues ≥ −tol.
            assert!(inc.vals[0] > -1e-8);
        }
    }

    #[test]
    fn linear_kernel_nonconstant_diagonal() {
        // Exercises Algorithm 1 without the k(x,x)=1 simplification.
        let ds = magic_like(12, 7);
        let kern = Linear;
        let mut dstd = ds.clone();
        dstd.standardize();
        let seed = dstd.x.submatrix(3, dstd.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, false).unwrap();
        for i in 3..dstd.n() {
            inc.push(dstd.x.row(i)).unwrap();
        }
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
    }

    #[test]
    fn stats_count_updates() {
        let ds = yeast_like(8, 8);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 4..8 {
            inc.push(ds.x.row(i)).unwrap();
        }
        // 4 rank-one updates per accepted adjusted step.
        assert_eq!(inc.stats.updates, 16);
        assert_eq!(inc.stats.accepted, 8);
    }

    #[test]
    fn hot_path_reallocs_are_amortized() {
        // Streaming growth reallocates only on capacity doublings — far
        // fewer growth events than pushes.
        let ds = yeast_like(40, 12);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 4..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        let pushes = (ds.n() - 4) as u64;
        // 4 rank-one updates per push; a copy-per-step design would pay
        // ≥ 1 fresh allocation per update. Amortized growth stays far
        // below that.
        assert!(
            inc.hot_path_reallocs() < pushes,
            "reallocs {} vs pushes {pushes}",
            inc.hot_path_reallocs()
        );
        assert!(inc.hot_path_bytes() > 0);
    }

    #[test]
    fn shared_kernel_state_is_owned_and_sendable() {
        // `from_batch_shared` co-owns the kernel: no borrow, no leak —
        // the whole state moves into another thread (what a shard
        // worker's stream entry does) and stays exact.
        let ds = yeast_like(14, 10);
        let kernel: std::sync::Arc<dyn crate::kernels::Kernel> =
            std::sync::Arc::new(Rbf { sigma: 1.0 });
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch_shared(kernel, &seed, true).unwrap();
        let handle = std::thread::spawn(move || {
            for i in 4..ds.n() {
                inc.push(ds.x.row(i)).unwrap();
            }
            inc.reconstruct().max_abs_diff(&inc.batch_reference())
        });
        let drift = handle.join().unwrap();
        assert!(drift < 1e-8, "drift {drift}");
    }

    #[test]
    fn batched_push_matches_sequential_pushes() {
        // Same stream driven point-by-point and in batches of 5: the
        // rank-one update sequences are identical, so the eigensystems
        // must agree to rounding of the blocked kernel evaluation.
        let ds = yeast_like(26, 31);
        let kern = Rbf { sigma: 1.3 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        let mut bat = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 6..ds.n() {
            seq.push(ds.x.row(i)).unwrap();
        }
        let dim = ds.dim();
        let flat = ds.x.as_slice();
        let mut i = 6;
        while i < ds.n() {
            let end = (i + 5).min(ds.n());
            let out = bat.push_batch(&flat[i * dim..end * dim]).unwrap();
            assert_eq!(out.accepted, end - i);
            assert_eq!(bat.last_batch_mask().len(), end - i);
            i = end;
        }
        assert_eq!(seq.len(), bat.len());
        for (a, b) in seq.vals.iter().zip(&bat.vals) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        let diff = bat.reconstruct().max_abs_diff(&seq.reconstruct());
        assert!(diff < 1e-10, "batched vs sequential reconstruction diff {diff}");
    }

    #[test]
    fn batched_push_cold_start_unadjusted() {
        // Whole stream in one batch from an empty unadjusted state: the
        // first point bootstraps, the rest run off the intra-batch block.
        let ds = yeast_like(12, 32);
        let kern = Linear;
        let empty = Mat::zeros(0, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &empty, false).unwrap();
        let out = inc.push_batch(ds.x.as_slice()).unwrap();
        assert_eq!(out.accepted, 12);
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
    }

    #[test]
    fn batched_push_excludes_mid_batch_like_sequential() {
        // A batch whose middle point sits at the data mean (linear
        // kernel, adjusted): the §5.1 exclusion must fire inside the
        // batch and later points must still match the sequential run.
        let ds = yeast_like(10, 33);
        let kern = Linear;
        let seed = ds.x.submatrix(6, ds.dim());
        let dim = ds.dim();
        let mean: Vec<f64> =
            (0..dim).map(|j| (0..6).map(|i| ds.x[(i, j)]).sum::<f64>() / 6.0).collect();
        // The mean goes FIRST so it is evaluated against exactly the
        // seed set it is the mean of (v₀ = 0 → excluded); the accepted
        // points behind it must then match the sequential run.
        let mut batch = Vec::new();
        batch.extend_from_slice(&mean);
        batch.extend_from_slice(ds.x.row(6));
        batch.extend_from_slice(ds.x.row(7));
        batch.extend_from_slice(ds.x.row(8));

        let mut bat = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        let out = bat.push_batch(&batch).unwrap();
        assert_eq!(out.excluded, 1);
        assert_eq!(out.accepted, 3);
        assert_eq!(bat.last_batch_mask(), &[false, true, true, true]);

        let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        assert!(!seq.push(&mean).unwrap());
        assert!(seq.push(ds.x.row(6)).unwrap());
        assert!(seq.push(ds.x.row(7)).unwrap());
        assert!(seq.push(ds.x.row(8)).unwrap());
        let diff = bat.reconstruct().max_abs_diff(&seq.reconstruct());
        assert!(diff < 1e-10, "diff {diff}");
    }

    #[test]
    fn reserved_cold_start_is_allocation_silent() {
        // bootstrap_first must grow the reserved buffers in place —
        // reserve() capacity survives the cold start, so the whole
        // stream (bootstrap included) leaves the tracked counters flat.
        let ds = yeast_like(20, 35);
        let kern = Rbf { sigma: 1.0 };
        let empty = Mat::zeros(0, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &empty, false).unwrap();
        inc.reserve(24, 8);
        let ws0 = inc.hot_path_reallocs();
        let bat0 = inc.batch_reallocs();
        let dim = ds.dim();
        let flat = ds.x.as_slice();
        let mut i = 0;
        while i < ds.n() {
            let end = (i + 8).min(ds.n());
            inc.push_batch(&flat[i * dim..end * dim]).unwrap();
            i = end;
        }
        assert_eq!(inc.len(), 20);
        assert_eq!(inc.hot_path_reallocs(), ws0, "cold start discarded reserved capacity");
        assert_eq!(inc.batch_reallocs(), bat0);
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
    }

    #[test]
    fn reserved_batched_stream_is_allocation_silent() {
        // Pre-size for the final eigensystem and batch, then assert the
        // tracked hot-path counters never move across the batched run.
        let ds = yeast_like(36, 34);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        inc.reserve(40, 10);
        let ws0 = inc.hot_path_reallocs();
        let bat0 = inc.batch_reallocs();
        let dim = ds.dim();
        let flat = ds.x.as_slice();
        let mut i = 6;
        while i < ds.n() {
            let end = (i + 10).min(ds.n());
            inc.push_batch(&flat[i * dim..end * dim]).unwrap();
            i = end;
        }
        assert_eq!(inc.len(), 36);
        assert_eq!(inc.hot_path_reallocs(), ws0, "workspace/basis grew after reserve");
        assert_eq!(inc.batch_reallocs(), bat0, "batch scratch grew after reserve");
    }

    #[test]
    fn fused_strategy_matches_sequential_strategy() {
        // Same batches under both explicit strategies: identical
        // eigensystems to rounding, and the fused run dispatches far
        // fewer engine back-rotation GEMMs (that's its whole point).
        let mut ds = yeast_like(30, 36);
        ds.standardize();
        let kern = Rbf { sigma: 1.1 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut fus = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        fus.batch_rotation = Some(BatchRotation::Fused);
        let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        seq.batch_rotation = Some(BatchRotation::Sequential);
        let dim = ds.dim();
        let flat = ds.x.as_slice();
        let mut i = 6;
        while i < ds.n() {
            let end = (i + 8).min(ds.n());
            fus.push_batch(&flat[i * dim..end * dim]).unwrap();
            seq.push_batch(&flat[i * dim..end * dim]).unwrap();
            i = end;
        }
        assert_eq!(fus.len(), seq.len());
        for (a, b) in fus.vals.iter().zip(&seq.vals) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        let diff = fus.reconstruct().max_abs_diff(&seq.reconstruct());
        assert!(diff < 1e-10, "fused vs sequential reconstruction diff {diff}");
        assert!(
            fus.engine_gemms() < seq.engine_gemms(),
            "fused {} vs sequential {} engine GEMMs",
            fus.engine_gemms(),
            seq.engine_gemms()
        );
        // No pending rotation may survive a batch boundary.
        assert!(!fus.workspace().pending_rotation());
        // Adjusted mode: the sequential strategy pays up to 4 engine
        // GEMMs per post-seed accepted point (at least the 2 final
        // updates; the re-centering pair skips only in degenerate
        // cases); the fused one replaced them with per-batch flushes
        // (plus any deflation fallbacks).
        let accepted = (seq.stats.accepted - 6) as u64;
        let gemms = seq.workspace().engine_gemms();
        assert!(
            gemms >= 2 * accepted && gemms <= 4 * accepted,
            "sequential GEMM count {gemms} outside [2, 4]x accepted {accepted}"
        );
    }

    #[test]
    fn auto_rotation_rule_fuses_only_real_batches() {
        let ds = yeast_like(10, 37);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        assert_eq!(inc.rotation_for(1), BatchRotation::Sequential);
        assert_eq!(inc.rotation_for(2), BatchRotation::Fused);
        let mut forced = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        forced.batch_rotation = Some(BatchRotation::Sequential);
        assert_eq!(forced.rotation_for(64), BatchRotation::Sequential);
    }

    #[test]
    fn from_parts_roundtrip_continues_identically() {
        // Serialize a mid-stream state through the accessor surface the
        // checkpoint codec uses, rebuild via from_parts, and require
        // the restored state to evolve bit-for-bit like the original.
        let ds = yeast_like(24, 5);
        let kern: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.3 });
        let seed = ds.x.submatrix(6, ds.dim());
        let mut live = IncrementalKpca::from_batch_shared(kern.clone(), &seed, true).unwrap();
        for i in 6..16 {
            live.push(ds.x.row(i)).unwrap();
        }
        let m = live.len();
        let (s, k1) = live.centering_sums();
        let mut vecs = Vec::with_capacity(m * m);
        for i in 0..m {
            vecs.extend_from_slice(live.vecs.row(i));
        }
        let parts = KpcaParts {
            mean_adjust: live.mean_adjust,
            dim: live.dim(),
            x: live.data_flat().to_vec(),
            vals: live.vals.clone(),
            vecs,
            s,
            k1: k1.to_vec(),
            exclude_tol: live.exclude_tol,
            naive_recenter_split: live.naive_recenter_split,
            batch_rotation: live.batch_rotation,
            stats: live.stats,
            engine_gemms: live.engine_gemms(),
        };
        let mut back = IncrementalKpca::from_parts(kern, parts).unwrap();
        assert_eq!(back.len(), live.len());
        assert_eq!(back.engine_gemms(), live.engine_gemms());
        for i in 16..24 {
            live.push(ds.x.row(i)).unwrap();
            back.push(ds.x.row(i)).unwrap();
        }
        assert_eq!(back.len(), live.len());
        for (a, b) in live.vals.iter().zip(&back.vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "eigenvalues diverged after restore");
        }
        for i in 0..live.len() {
            for (a, b) in live.vecs.row(i).iter().zip(back.vecs.row(i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "basis diverged after restore");
            }
        }
    }

    #[test]
    fn from_parts_rejects_inconsistent_shapes() {
        let kern: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.0 });
        let parts = KpcaParts {
            mean_adjust: false,
            dim: 2,
            x: vec![0.0; 4],
            vals: vec![1.0, 2.0],
            vecs: vec![0.0; 3], // not 2×2
            s: 0.0,
            k1: vec![0.0; 2],
            exclude_tol: 1e-12,
            naive_recenter_split: false,
            batch_rotation: None,
            stats: KpcaStats::default(),
            engine_gemms: 0,
        };
        assert!(IncrementalKpca::from_parts(kern, parts).is_err());
    }

    #[test]
    fn remove_point_matches_batch_recompute() {
        // Down-dating landmark j must leave exactly the eigensystem of
        // the kernel matrix over the survivors — both algorithms.
        for adjust in [false, true] {
            let ds = yeast_like(14, 41);
            let kern = Rbf { sigma: 1.2 };
            let seed = ds.x.submatrix(5, ds.dim());
            let mut inc = IncrementalKpca::from_batch(&kern, &seed, adjust).unwrap();
            for i in 5..ds.n() {
                inc.push(ds.x.row(i)).unwrap();
            }
            inc.remove_point(3, &NativeRotate).unwrap();
            inc.remove_point(7, &NativeRotate).unwrap();
            assert_eq!(inc.len(), 12);
            assert_eq!(inc.evictions(), 2);
            let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
            assert!(drift < 1e-8, "adjust={adjust} drift {drift}");
            assert!(orthogonality_defect(&inc.vecs) < 1e-9);
        }
    }

    #[test]
    fn remove_then_readd_recovers_original_state() {
        // Evict + re-add the same point: the eigensystem must match a
        // fresh batch recompute of the full set (the oracle suite pins
        // the same invariant across kernels at 1e-10).
        let ds = yeast_like(12, 42);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 4..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        let victim = inc.row(6).to_vec();
        inc.remove_point(6, &NativeRotate).unwrap();
        assert!(inc.push(&victim).unwrap());
        assert_eq!(inc.len(), 12);
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-10, "drift {drift}");
    }

    #[test]
    fn bounded_stream_enforces_cap_and_stays_exact() {
        let ds = yeast_like(30, 43);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        inc.set_bound(10, EvictionPolicy::Uniform, 4);
        for i in 4..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        assert_eq!(inc.len(), 10, "cap must hold");
        assert_eq!(inc.evictions(), 30 - 10);
        assert_eq!(inc.stats.accepted, 30);
        // The seed prefix is never evicted.
        for i in 0..4 {
            for (a, b) in inc.row(i).iter().zip(ds.x.row(i)) {
                assert_eq!(a, b, "protected landmark {i} was evicted");
            }
        }
        // The tracked eigensystem is the batch answer over whatever
        // survived — eviction is exact, not approximate.
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
        assert!(inc.sufficiency_gap() >= 0.0);
    }

    #[test]
    fn bounded_batched_matches_bounded_sequential() {
        // Mid-batch eviction (through the provenance remap and the
        // fused pending product) must pick the same victims and reach
        // the same eigensystem as the single-push bounded stream.
        let ds = yeast_like(28, 44);
        let kern = Rbf { sigma: 1.1 };
        let seed = ds.x.submatrix(5, ds.dim());
        let mut seq = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        let mut bat = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        seq.set_bound(9, EvictionPolicy::Uniform, 3);
        bat.set_bound(9, EvictionPolicy::Uniform, 3);
        for i in 5..ds.n() {
            seq.push(ds.x.row(i)).unwrap();
        }
        let dim = ds.dim();
        let flat = ds.x.as_slice();
        let mut i = 5;
        while i < ds.n() {
            let end = (i + 6).min(ds.n());
            bat.push_batch(&flat[i * dim..end * dim]).unwrap();
            i = end;
        }
        assert_eq!(seq.len(), 9);
        assert_eq!(bat.len(), 9);
        assert_eq!(seq.evictions(), bat.evictions());
        assert_eq!(seq.data_flat(), bat.data_flat(), "victim sequences diverged");
        let diff = bat.reconstruct().max_abs_diff(&seq.reconstruct());
        assert!(diff < 1e-9, "bounded batched vs sequential diff {diff}");
    }

    #[test]
    fn leverage_scores_sum_to_effective_rank() {
        let ds = yeast_like(16, 45);
        let kern = Rbf { sigma: 1.4 };
        let seed = ds.x.submatrix(4, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 4..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        let mut lev = Vec::new();
        inc.leverage_scores(&NativeRotate, &mut lev);
        assert_eq!(lev.len(), inc.len());
        let trace_pos: f64 = inc.vals.iter().map(|l| l.max(0.0)).sum();
        let mu = trace_pos / inc.len() as f64;
        let erank: f64 =
            inc.vals.iter().map(|&l| l.max(0.0)).map(|l| l / (l + mu)).sum();
        let total: f64 = lev.iter().sum();
        assert!((total - erank).abs() < 1e-8, "Σℓ {total} vs effective rank {erank}");
        for &l in &lev {
            assert!(l >= -1e-12, "leverage score {l} negative");
        }
    }

    #[test]
    fn leverage_eviction_respects_protected_prefix() {
        let ds = yeast_like(24, 46);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut inc = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        inc.set_bound(8, EvictionPolicy::LeverageScore, 6);
        for i in 6..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        assert_eq!(inc.len(), 8);
        for i in 0..6 {
            for (a, b) in inc.row(i).iter().zip(ds.x.row(i)) {
                assert_eq!(a, b, "protected landmark {i} was evicted");
            }
        }
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-8, "drift {drift}");
    }

    #[test]
    fn property_incremental_equals_batch() {
        crate::util::prop::check("incremental-equals-batch", 8, |rng| {
            let n = 8 + rng.below(10);
            let seed_n = 3 + rng.below(3);
            let ds = yeast_like(n, rng.next_u64());
            let sigma = rng.range(0.5, 3.0);
            let kern = Rbf { sigma };
            let adjust = rng.uniform() < 0.5;
            let seed = ds.x.submatrix(seed_n, ds.dim());
            let mut inc = IncrementalKpca::from_batch(&kern, &seed, adjust)
                .map_err(|e| e.to_string())?;
            for i in seed_n..n {
                inc.push(ds.x.row(i)).map_err(|e| e.to_string())?;
            }
            let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
            crate::util::prop::ensure(drift < 1e-7, || format!("drift {drift}"))
        });
    }
}
