//! Kernel functions (§2.1) and the Gram-matrix helpers the algorithms
//! consume. The paper's experiments use the RBF kernel with the median
//! heuristic (§5); linear, polynomial, Laplacian and sigmoid kernels are
//! provided so the incremental machinery is exercised beyond the
//! constant-diagonal case (`k(x,x) = 1`) the paper's Algorithm 1 note
//! discusses.

use crate::linalg::{matmul_nt_into_buf, Mat, MatView, MatViewMut};
use crate::util::par;

/// How a kernel's Gram blocks decompose over a dot-product GEMM — the
/// dispatch key for [`kernel_rows_into`], which turns the `b·m` scalar
/// `eval` calls of a batched ingest into one blocked `A·Bᵀ` product.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockForm {
    /// `k(x, y) = f(⟨x, y⟩)` — one GEMM, then map every entry
    /// (linear, polynomial, sigmoid).
    DotProduct,
    /// `k(x, y) = f(‖x − y‖²)` with `‖x − y‖² = ‖x‖² − 2⟨x, y⟩ + ‖y‖²`
    /// — one GEMM plus row norms (RBF).
    SquaredDistance,
    /// No GEMM form (e.g. the L1-distance Laplacian) — fall back to
    /// per-point scalar evaluation.
    General,
}

/// A symmetric positive (semi-)definite kernel over ℝᵈ rows.
pub trait Kernel: Sync + Send {
    /// Evaluate `k(x, y)`.
    fn eval(&self, x: &[f64], y: &[f64]) -> f64;

    /// Kernel family label for logs, metrics and snapshots. Static —
    /// the metrics/snapshot paths call this per report and must not
    /// allocate.
    fn name(&self) -> &'static str;

    /// Human-readable description including parameters (allocates;
    /// experiment reports only, never the hot path).
    fn describe(&self) -> String {
        self.name().to_string()
    }

    /// Whether `k(x, x)` is the same for every `x` (true for RBF and
    /// Laplacian) — enables the simplification noted after Algorithm 1.
    fn constant_diagonal(&self) -> bool {
        false
    }

    /// How blocks of this kernel reduce to a GEMM (see [`BlockForm`]).
    fn block_form(&self) -> BlockForm {
        BlockForm::General
    }

    /// Finish a blocked evaluation: map the raw GEMM quantity — the dot
    /// product (`DotProduct`) or the squared distance
    /// (`SquaredDistance`) — to the kernel value. Must compute the same
    /// function of that quantity as `eval` does, so blocked and scalar
    /// paths agree to rounding.
    fn map_block(&self, raw: f64) -> f64 {
        raw
    }
}

/// Radial basis function kernel `exp(−‖x−y‖² / σ)` — note the paper
/// parameterizes with `σ` directly dividing the squared distance.
#[derive(Clone, Copy, Debug)]
pub struct Rbf {
    pub sigma: f64,
}

impl Kernel for Rbf {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        (-sqdist(x, y) / self.sigma).exp()
    }
    fn name(&self) -> &'static str {
        "rbf"
    }
    fn describe(&self) -> String {
        // `{}` on f64 prints the shortest representation that parses
        // back to the same bits — `describe` is the checkpoint codec's
        // kernel serialization, so it must be exact, not pretty.
        format!("rbf(sigma={})", self.sigma)
    }
    fn constant_diagonal(&self) -> bool {
        true
    }
    fn block_form(&self) -> BlockForm {
        BlockForm::SquaredDistance
    }
    fn map_block(&self, raw: f64) -> f64 {
        (-raw / self.sigma).exp()
    }
}

/// Linear kernel `⟨x, y⟩`.
#[derive(Clone, Copy, Debug)]
pub struct Linear;

impl Kernel for Linear {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        crate::linalg::dot(x, y)
    }
    fn name(&self) -> &'static str {
        "linear"
    }
    fn block_form(&self) -> BlockForm {
        BlockForm::DotProduct
    }
}

/// Polynomial kernel `(⟨x, y⟩ + c)^p`.
#[derive(Clone, Copy, Debug)]
pub struct Polynomial {
    pub degree: u32,
    pub offset: f64,
}

impl Kernel for Polynomial {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        (crate::linalg::dot(x, y) + self.offset).powi(self.degree as i32)
    }
    fn name(&self) -> &'static str {
        "poly"
    }
    fn describe(&self) -> String {
        format!("poly(d={}, c={})", self.degree, self.offset)
    }
    fn block_form(&self) -> BlockForm {
        BlockForm::DotProduct
    }
    fn map_block(&self, raw: f64) -> f64 {
        (raw + self.offset).powi(self.degree as i32)
    }
}

/// Laplacian kernel `exp(−‖x−y‖₁ / σ)`.
#[derive(Clone, Copy, Debug)]
pub struct Laplacian {
    pub sigma: f64,
}

impl Kernel for Laplacian {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        let l1: f64 = x.iter().zip(y).map(|(a, b)| (a - b).abs()).sum();
        (-l1 / self.sigma).exp()
    }
    fn name(&self) -> &'static str {
        "laplacian"
    }
    fn describe(&self) -> String {
        format!("laplacian(sigma={})", self.sigma)
    }
    fn constant_diagonal(&self) -> bool {
        true
    }
}

/// Sigmoid (tanh) kernel `tanh(a⟨x,y⟩ + b)` — not PSD in general; kept
/// for robustness testing of the deflation path.
#[derive(Clone, Copy, Debug)]
pub struct Sigmoid {
    pub alpha: f64,
    pub beta: f64,
}

impl Kernel for Sigmoid {
    fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        (self.alpha * crate::linalg::dot(x, y) + self.beta).tanh()
    }
    fn name(&self) -> &'static str {
        "sigmoid"
    }
    fn describe(&self) -> String {
        format!("sigmoid(a={}, b={})", self.alpha, self.beta)
    }
    fn block_form(&self) -> BlockForm {
        BlockForm::DotProduct
    }
    fn map_block(&self, raw: f64) -> f64 {
        (self.alpha * raw + self.beta).tanh()
    }
}

/// Rebuild a kernel from its [`Kernel::describe`] string — the inverse
/// the checkpoint codec needs: a serialized stream stores only the
/// describe line (which for an `RbfMedian` config already carries the
/// *resolved* seed-time bandwidth), and recovery turns it back into a
/// live kernel. Round-trip is exact because every parameterized
/// `describe` prints floats with `{}` (shortest-exact `Display`).
pub fn kernel_from_describe(desc: &str) -> Result<std::sync::Arc<dyn Kernel>, String> {
    let (name, params) = split_describe(desc)?;
    let get = |key: &str| -> Result<f64, String> {
        params
            .iter()
            .find(|(k, _)| *k == key)
            .ok_or_else(|| format!("kernel '{desc}': missing parameter '{key}'"))
            .and_then(|(_, v)| {
                v.parse::<f64>()
                    .map_err(|_| format!("kernel '{desc}': bad value for '{key}'"))
            })
    };
    match name {
        "rbf" => Ok(std::sync::Arc::new(Rbf { sigma: get("sigma")? })),
        "linear" => Ok(std::sync::Arc::new(Linear)),
        "poly" => {
            let d = get("d")?;
            if d < 0.0 || d.fract() != 0.0 || d > u32::MAX as f64 {
                return Err(format!("kernel '{desc}': degree must be a non-negative integer"));
            }
            Ok(std::sync::Arc::new(Polynomial { degree: d as u32, offset: get("c")? }))
        }
        "laplacian" => Ok(std::sync::Arc::new(Laplacian { sigma: get("sigma")? })),
        "sigmoid" => Ok(std::sync::Arc::new(Sigmoid { alpha: get("a")?, beta: get("b")? })),
        other => Err(format!("unknown kernel family '{other}' in '{desc}'")),
    }
}

/// Split `name(k1=v1, k2=v2)` (or bare `name`) into the family label
/// and its key/value parameters.
fn split_describe(desc: &str) -> Result<(&str, Vec<(&str, &str)>), String> {
    let Some(open) = desc.find('(') else {
        return Ok((desc, Vec::new()));
    };
    let name = &desc[..open];
    let body = desc[open + 1..]
        .strip_suffix(')')
        .ok_or_else(|| format!("kernel '{desc}': unterminated parameter list"))?;
    let mut params = Vec::new();
    for part in body.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (k, v) = part
            .split_once('=')
            .ok_or_else(|| format!("kernel '{desc}': bad parameter '{part}'"))?;
        params.push((k.trim(), v.trim()));
    }
    Ok((name, params))
}

/// Squared Euclidean distance.
#[inline]
pub fn sqdist(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| (a - b) * (a - b)).sum()
}

/// The paper's bandwidth heuristic (§5): the median of pairwise squared
/// distances over (a subset of) the data. Uses at most `max_points`
/// rows to bound the O(n²) scan.
pub fn median_heuristic(x: &Mat, max_points: usize) -> f64 {
    let n = x.rows().min(max_points);
    if n < 2 {
        return 1.0;
    }
    let mut dists = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            let d = sqdist(x.row(i), x.row(j));
            // One non-finite feature row (a bad CSV record) must not
            // poison bandwidth selection for the whole stream: drop
            // NaN/∞ distances instead of letting them reach the sort.
            if d.is_finite() {
                dists.push(d);
            }
        }
    }
    if dists.is_empty() {
        return 1.0;
    }
    dists.sort_by(f64::total_cmp);
    let m = dists.len();
    let med = if m % 2 == 1 { dists[m / 2] } else { 0.5 * (dists[m / 2 - 1] + dists[m / 2]) };
    if med > 0.0 {
        med
    } else {
        1.0
    }
}

/// Full Gram matrix `K[i,j] = k(xᵢ, xⱼ)` over the rows of `x`: only the
/// upper triangle is evaluated (kernel evals dominate the cold-start
/// cost and the matrix is symmetric) and mirrored into place. The
/// parallel split pairs row `t` with row `n−1−t`, so every task carries
/// the same `n+1` evaluations — the bare upper-triangle row split would
/// front-load long rows onto the first workers.
pub fn gram(kernel: &dyn Kernel, x: &Mat) -> Mat {
    let n = x.rows();
    let mut k = Mat::zeros(n, n);
    if n == 0 {
        return k;
    }
    let half = n - n / 2; // ceil(n/2) row pairs
    let pairs: Vec<(Vec<f64>, Vec<f64>)> = par::par_map(half, 4, |t| {
        let i = t;
        let j = n - 1 - t;
        let row_i: Vec<f64> = (i..n).map(|c| kernel.eval(x.row(i), x.row(c))).collect();
        let row_j: Vec<f64> = if j > i {
            (j..n).map(|c| kernel.eval(x.row(j), x.row(c))).collect()
        } else {
            Vec::new()
        };
        (row_i, row_j)
    });
    for (t, (row_i, row_j)) in pairs.into_iter().enumerate() {
        let i = t;
        for (off, v) in row_i.into_iter().enumerate() {
            k[(i, i + off)] = v;
            k[(i + off, i)] = v;
        }
        let j = n - 1 - t;
        for (off, v) in row_j.into_iter().enumerate() {
            k[(j, j + off)] = v;
            k[(j + off, j)] = v;
        }
    }
    k
}

/// Kernel column `a = [k(x₁, y) … k(xₘ, y)]ᵀ` against the first `m` rows
/// of `x` — the per-step quantity of Algorithms 1–2 (allocating form of
/// [`kernel_column_into`]).
pub fn kernel_column(kernel: &dyn Kernel, x: &Mat, m: usize, y: &[f64]) -> Vec<f64> {
    assert!(m <= x.rows());
    let mut out = Vec::new();
    kernel_column_into(kernel, x.as_slice(), x.cols(), m, y, &mut out);
    out
}

/// [`kernel_column`] over flat row-major data into a caller-owned,
/// capacity-retaining buffer — the zero-allocation streaming form (the
/// incremental states keep their retained examples as a flat `Vec`, so
/// no per-push matrix clone is needed either).
pub fn kernel_column_into(
    kernel: &dyn Kernel,
    x: &[f64],
    dim: usize,
    m: usize,
    y: &[f64],
    out: &mut Vec<f64>,
) {
    assert!(x.len() >= m * dim, "kernel_column_into: data shorter than m rows");
    assert_eq!(y.len(), dim, "kernel_column_into: query dimension mismatch");
    out.clear();
    out.resize(m, 0.0);
    let row = |i: usize| &x[i * dim..(i + 1) * dim];
    if m >= 64 {
        const CHUNK: usize = 16;
        par::par_chunks_mut(out, CHUNK, |ci, chunk| {
            let base = ci * CHUNK;
            for (off, slot) in chunk.iter_mut().enumerate() {
                *slot = kernel.eval(row(base + off), y);
            }
        });
    } else {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = kernel.eval(row(i), y);
        }
    }
}

/// Reusable scratch for [`kernel_rows_into`]: the row-norm vectors the
/// squared-distance trick needs plus the GEMM packing panels of the
/// `Y·Xᵀ` block product, with a realloc counter so the batched ingest
/// path can assert steady-state allocation silence.
#[derive(Clone, Debug, Default)]
pub struct KernelBlockScratch {
    /// `‖xⱼ‖²` over the retained rows.
    xnorms: Vec<f64>,
    /// `‖yᵢ‖²` over the batch rows.
    ynorms: Vec<f64>,
    /// Packing panels of the blocked `Y·Xᵀ` kernel-rows GEMM.
    pack: crate::linalg::PackBuffers,
    reallocs: u64,
}

impl KernelBlockScratch {
    pub fn new() -> Self {
        KernelBlockScratch::default()
    }

    /// Capacity-growth events since construction, including pack-panel
    /// growth (zero once warm).
    pub fn reallocs(&self) -> u64 {
        self.reallocs + self.pack.reallocs()
    }

    /// Bytes currently held by the row-norm and packing buffers.
    pub fn bytes_resident(&self) -> usize {
        std::mem::size_of::<f64>() * (self.xnorms.capacity() + self.ynorms.capacity())
            + self.pack.bytes_resident()
    }

    /// Pre-size for blocks of up to `m` retained × `b` batch rows of
    /// `dim`-dimensional points, without counting toward the realloc
    /// counter. `dim` sizes the packing panels of the `b×dim · dim×m`
    /// block GEMM (callers that only ever take the scalar path may pass
    /// 0).
    pub fn reserve(&mut self, m: usize, b: usize, dim: usize) {
        if self.xnorms.capacity() < m {
            self.xnorms.reserve(m - self.xnorms.len());
        }
        if self.ynorms.capacity() < b {
            self.ynorms.reserve(b - self.ynorms.len());
        }
        // The batch block is b×m; seeding paths also evaluate the m×m
        // self-block through the same scratch.
        self.pack.reserve(m.max(b), dim, m.max(b));
    }
}

// Capacity-growth-counting resize shared with the rank-one workspace —
// one definition, so batch-path and update-path realloc accounting can
// never diverge.
use crate::rankone::ensure_f64;

/// Kernel rows of a *batch*: fills `out` (`b × m`, row-major) with
/// `out[i·m + j] = k(yᵢ, xⱼ)` for the `b` rows of `ys` against the
/// first `m` rows of `x` — the batched form of [`kernel_column_into`].
///
/// For dot-product-family kernels ([`BlockForm::DotProduct`]) the whole
/// block is one blocked `Y·Xᵀ` GEMM ([`matmul_nt_into_buf`]) followed by an
/// entry-wise map; the RBF family ([`BlockForm::SquaredDistance`])
/// additionally forms the two row-norm vectors and evaluates
/// `‖y‖² − 2⟨y,x⟩ + ‖x‖²` per entry (clamped at zero against rounding).
/// Kernels without a GEMM form fall back to per-point scalar `eval`,
/// bitwise identical to the sequential path.
#[allow(clippy::too_many_arguments)]
pub fn kernel_rows_into(
    kernel: &dyn Kernel,
    x: &[f64],
    dim: usize,
    m: usize,
    ys: &[f64],
    b: usize,
    out: &mut Vec<f64>,
    scratch: &mut KernelBlockScratch,
) {
    assert!(x.len() >= m * dim, "kernel_rows_into: data shorter than m rows");
    assert!(ys.len() >= b * dim, "kernel_rows_into: batch shorter than b rows");
    ensure_f64(out, b * m, &mut scratch.reallocs);
    if b == 0 || m == 0 {
        return;
    }
    let form = kernel.block_form();
    if form == BlockForm::General || dim == 0 {
        // Scalar fallback — same evaluation order as kernel_column_into,
        // parallel over batch rows when the block is large enough.
        let row_x = |j: usize| &x[j * dim..(j + 1) * dim];
        if b * m >= 256 {
            par::par_chunks_mut(out, m, |i, row| {
                let yi = &ys[i * dim..(i + 1) * dim];
                for (j, slot) in row.iter_mut().enumerate() {
                    *slot = kernel.eval(row_x(j), yi);
                }
            });
        } else {
            for i in 0..b {
                let yi = &ys[i * dim..(i + 1) * dim];
                for (j, slot) in out[i * m..(i + 1) * m].iter_mut().enumerate() {
                    *slot = kernel.eval(row_x(j), yi);
                }
            }
        }
        return;
    }
    // One blocked GEMM: out[i,j] = ⟨yᵢ, xⱼ⟩, packed into the scratch's
    // reusable panels.
    {
        let yv = MatView::of_rows(ys, b, dim);
        let xv = MatView::of_rows(x, m, dim);
        let mut ov = MatViewMut::new(out, b, m, m);
        matmul_nt_into_buf(yv, xv, &mut ov, &mut scratch.pack);
    }
    match form {
        BlockForm::DotProduct => {
            for v in out.iter_mut() {
                *v = kernel.map_block(*v);
            }
        }
        BlockForm::SquaredDistance => {
            ensure_f64(&mut scratch.xnorms, m, &mut scratch.reallocs);
            ensure_f64(&mut scratch.ynorms, b, &mut scratch.reallocs);
            for (j, nj) in scratch.xnorms.iter_mut().enumerate() {
                let r = &x[j * dim..(j + 1) * dim];
                *nj = crate::linalg::dot(r, r);
            }
            for (i, ni) in scratch.ynorms.iter_mut().enumerate() {
                let r = &ys[i * dim..(i + 1) * dim];
                *ni = crate::linalg::dot(r, r);
            }
            for i in 0..b {
                let yn = scratch.ynorms[i];
                let row = &mut out[i * m..(i + 1) * m];
                for (j, v) in row.iter_mut().enumerate() {
                    let d2 = (yn - 2.0 * *v + scratch.xnorms[j]).max(0.0);
                    *v = kernel.map_block(d2);
                }
            }
        }
        BlockForm::General => unreachable!(),
    }
}

/// Rectangular cross-Gram `K[i,j] = k(aᵢ, bⱼ)` between row sets.
pub fn cross_gram(kernel: &dyn Kernel, a: &Mat, b: &Mat) -> Mat {
    let (na, nb) = (a.rows(), b.rows());
    let rows: Vec<Vec<f64>> = par::par_map(na, 4, |i| {
        (0..nb).map(|j| kernel.eval(a.row(i), b.row(j))).collect()
    });
    let mut k = Mat::zeros(na, nb);
    for (i, vals) in rows.into_iter().enumerate() {
        k.row_mut(i).copy_from_slice(&vals);
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigvalsh;

    fn toy_data() -> Mat {
        Mat::from_fn(8, 3, |i, j| ((i * 3 + j) as f64 * 0.37).sin())
    }

    #[test]
    fn rbf_unit_diagonal_and_symmetry() {
        let k = Rbf { sigma: 2.0 };
        let x = toy_data();
        let g = gram(&k, &x);
        for i in 0..8 {
            assert!((g[(i, i)] - 1.0).abs() < 1e-15);
            for j in 0..8 {
                assert_eq!(g[(i, j)], g[(j, i)]);
                assert!(g[(i, j)] > 0.0 && g[(i, j)] <= 1.0);
            }
        }
    }

    #[test]
    fn rbf_gram_is_psd() {
        let k = Rbf { sigma: 1.0 };
        let g = gram(&k, &toy_data());
        let vals = eigvalsh(&g).unwrap();
        assert!(vals[0] > -1e-10);
    }

    #[test]
    fn linear_kernel_matches_dot() {
        let x = [1.0, 2.0];
        let y = [3.0, -1.0];
        assert_eq!(Linear.eval(&x, &y), 1.0);
    }

    #[test]
    fn polynomial_kernel_closed_form() {
        let k = Polynomial { degree: 2, offset: 1.0 };
        assert_eq!(k.eval(&[1.0], &[2.0]), 9.0);
    }

    #[test]
    fn laplacian_constant_diagonal() {
        let k = Laplacian { sigma: 1.5 };
        assert!((k.eval(&[0.3, 0.4], &[0.3, 0.4]) - 1.0).abs() < 1e-15);
        assert!(k.constant_diagonal());
    }

    #[test]
    fn median_heuristic_positive_and_scale_covariant() {
        let x = toy_data();
        let s1 = median_heuristic(&x, 100);
        assert!(s1 > 0.0);
        // Doubling the data scale quadruples squared distances.
        let mut x2 = x.clone();
        x2.scale(2.0);
        let s2 = median_heuristic(&x2, 100);
        assert!((s2 / s1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn median_heuristic_survives_non_finite_data() {
        // A single NaN cell used to panic the partial_cmp sort — in a
        // serving context that takes the whole ingest thread down.
        let mut x = toy_data();
        let clean = median_heuristic(&x, 100);
        x[(3, 1)] = f64::NAN;
        let s = median_heuristic(&x, 100);
        assert!(s.is_finite() && s > 0.0, "sigma from NaN-bearing data: {s}");
        // The finite pairs still dominate, so the estimate stays in the
        // same ballpark as the clean one.
        assert!(s / clean < 10.0 && clean / s < 10.0, "{s} vs {clean}");
        // All-NaN data falls back to the unit bandwidth, no panic.
        let bad = Mat::from_fn(4, 2, |_, _| f64::NAN);
        assert_eq!(median_heuristic(&bad, 100), 1.0);
        // Degenerate row counts (0 or 1 rows) fall back too.
        assert_eq!(median_heuristic(&Mat::zeros(0, 3), 100), 1.0);
        assert_eq!(median_heuristic(&Mat::zeros(1, 3), 100), 1.0);
    }

    #[test]
    fn kernel_column_matches_gram_column() {
        let k = Rbf { sigma: 0.7 };
        let x = toy_data();
        let g = gram(&k, &x);
        let col = kernel_column(&k, &x, 8, x.row(5));
        for i in 0..8 {
            assert!((col[i] - g[(i, 5)]).abs() < 1e-15);
        }
    }

    #[test]
    fn gram_matches_brute_force_odd_and_even() {
        // The paired-row upper-triangle fill must cover every entry for
        // both parities of n (middle row is unpaired when n is odd).
        let k = Rbf { sigma: 1.3 };
        for n in [1usize, 2, 5, 8, 9] {
            let x = Mat::from_fn(n, 3, |i, j| ((i * 3 + j) as f64 * 0.29).cos());
            let g = gram(&k, &x);
            for i in 0..n {
                for j in 0..n {
                    let expect = k.eval(x.row(i), x.row(j));
                    assert!((g[(i, j)] - expect).abs() < 1e-15, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn kernel_column_into_reuses_buffer() {
        let k = Rbf { sigma: 0.9 };
        let x = toy_data();
        let mut buf = Vec::new();
        kernel_column_into(&k, x.as_slice(), x.cols(), 8, x.row(2), &mut buf);
        assert_eq!(buf.len(), 8);
        let cap = buf.capacity();
        kernel_column_into(&k, x.as_slice(), x.cols(), 5, x.row(1), &mut buf);
        assert_eq!(buf.len(), 5);
        assert_eq!(buf.capacity(), cap, "buffer must be reused, not reallocated");
        assert!((buf[1] - k.eval(x.row(1), x.row(1))).abs() < 1e-15);
    }

    #[test]
    fn cross_gram_consistent_with_gram() {
        let k = Rbf { sigma: 0.7 };
        let x = toy_data();
        let c = cross_gram(&k, &x, &x);
        assert!(c.max_abs_diff(&gram(&k, &x)) < 1e-15);
    }

    #[test]
    fn kernel_rows_match_scalar_eval_across_forms() {
        // Every block form (GEMM+map, GEMM+norms, scalar fallback) must
        // agree with per-entry eval to rounding.
        let x = toy_data(); // 8 × 3 retained
        let ys = Mat::from_fn(5, 3, |i, j| ((i * 7 + j) as f64 * 0.23).cos());
        let kernels: Vec<Box<dyn Kernel>> = vec![
            Box::new(Rbf { sigma: 0.8 }),
            Box::new(Linear),
            Box::new(Polynomial { degree: 3, offset: 0.5 }),
            Box::new(Sigmoid { alpha: 0.7, beta: 0.1 }),
            Box::new(Laplacian { sigma: 1.2 }),
        ];
        let mut scratch = KernelBlockScratch::new();
        let mut out = Vec::new();
        for k in &kernels {
            let (xs, yy) = (x.as_slice(), ys.as_slice());
            kernel_rows_into(k.as_ref(), xs, 3, 8, yy, 5, &mut out, &mut scratch);
            assert_eq!(out.len(), 5 * 8);
            for i in 0..5 {
                for j in 0..8 {
                    let expect = k.eval(ys.row(i), x.row(j));
                    assert!(
                        (out[i * 8 + j] - expect).abs() < 1e-12,
                        "{} ({i},{j}): {} vs {expect}",
                        k.name(),
                        out[i * 8 + j]
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_rows_scratch_reuse_is_allocation_silent() {
        let k = Rbf { sigma: 1.1 };
        let x = toy_data();
        let mut scratch = KernelBlockScratch::new();
        let mut out = Vec::new();
        kernel_rows_into(&k, x.as_slice(), 3, 8, x.as_slice(), 8, &mut out, &mut scratch);
        let warm = scratch.reallocs();
        let cap = out.capacity();
        for _ in 0..5 {
            kernel_rows_into(&k, x.as_slice(), 3, 8, x.as_slice(), 6, &mut out, &mut scratch);
        }
        assert_eq!(scratch.reallocs(), warm, "warm blocked path must not grow buffers");
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn kernel_rows_empty_edges() {
        let k = Linear;
        let x = toy_data();
        let mut scratch = KernelBlockScratch::new();
        let mut out = vec![7.0; 3];
        kernel_rows_into(&k, x.as_slice(), 3, 0, x.as_slice(), 4, &mut out, &mut scratch);
        assert!(out.is_empty());
        kernel_rows_into(&k, x.as_slice(), 3, 5, x.as_slice(), 0, &mut out, &mut scratch);
        assert!(out.is_empty());
    }

    #[test]
    fn names_are_static_and_describe_carries_params() {
        let k = Rbf { sigma: 0.5 };
        let n: &'static str = k.name();
        assert_eq!(n, "rbf");
        assert!(k.describe().contains("0.5"));
        assert_eq!(Linear.name(), "linear");
        assert_eq!(Linear.describe(), "linear");
    }

    #[test]
    fn describe_roundtrip_is_bit_exact() {
        // Awkward parameters that a fixed-precision format would
        // truncate: the describe → parse cycle must recover the exact
        // bits, or a restored stream would silently use a different
        // kernel than the one it checkpointed.
        let sigmas = [0.1 + 0.2, 1.0 / 3.0, 1e-17, 12345.678901234567, f64::MIN_POSITIVE];
        for &sigma in &sigmas {
            let k = Rbf { sigma };
            let back = kernel_from_describe(&k.describe()).unwrap();
            assert_eq!(back.name(), "rbf");
            assert_eq!(back.describe(), k.describe(), "sigma {sigma:e}");
            let (x, y) = ([0.3, -0.7], [0.1, 0.4]);
            assert_eq!(back.eval(&x, &y).to_bits(), k.eval(&x, &y).to_bits());
        }
        let k = Laplacian { sigma: 2.0 / 7.0 };
        let back = kernel_from_describe(&k.describe()).unwrap();
        assert_eq!(back.describe(), k.describe());
        let k = Polynomial { degree: 4, offset: 0.1 + 0.7 };
        let back = kernel_from_describe(&k.describe()).unwrap();
        assert_eq!(back.describe(), k.describe());
        let k = Sigmoid { alpha: 1.0 / 9.0, beta: -0.25 };
        let back = kernel_from_describe(&k.describe()).unwrap();
        assert_eq!(back.describe(), k.describe());
        let back = kernel_from_describe("linear").unwrap();
        assert_eq!(back.describe(), "linear");
    }

    #[test]
    fn kernel_from_describe_rejects_malformed() {
        assert!(kernel_from_describe("rbf(sigma=").is_err());
        assert!(kernel_from_describe("rbf()").is_err());
        assert!(kernel_from_describe("rbf(sigma=abc)").is_err());
        assert!(kernel_from_describe("warp(q=1)").is_err());
        assert!(kernel_from_describe("poly(d=2.5, c=0)").is_err());
        assert!(kernel_from_describe("poly(d=-1, c=0)").is_err());
        assert!(kernel_from_describe("sigmoid(a=1)").is_err());
    }
}
