//! Durability benches: what the write-ahead log costs on the ingest
//! path, what a checkpoint costs to cut, and how fast a crashed pool is
//! back to serving.
//!
//! Series 1 (`recovery/ingest_{off,wal,wal_fsync8}/1stream`): per-point
//! rendezvous ingest with durability off, with the WAL appending every
//! accepted command (fsync off — the page cache absorbs the write), and
//! with an fsync every 8 appends. The off→wal gap prices the framing +
//! one `write(2)` per point; wal→fsync8 prices the flush policy. The
//! run asserts the logging happened (`wal_appends` = open + n ingests)
//! and that the happy path never errors.
//!
//! Series 2 (`recovery/checkpoint/mN`): one `checkpoint_stream` cut of
//! a live N-point stream — serialize + CRC + atomic rename, through the
//! same queue the ingests use.
//!
//! Series 3 (`recovery/restore_checkpoint/mN` vs
//! `recovery/restore_replay/mN`): time-to-serving after a crash, end to
//! end (pool spawn + `restore_pool` + shutdown), from a fresh
//! checkpoint (rotated WAL — install, no replay) vs from a bare WAL
//! (open + full replay through the ingest path). Each iteration resets
//! the snapshot directory from an in-memory template of the pristine
//! post-crash files, so every sample restores the identical state. The
//! replay/checkpoint gap is the argument for compaction-on-restore.
//!
//! Emits `BENCH_recovery.json` for the perf trajectory and the CI
//! regression gate.

use std::path::PathBuf;

use inkpca::coordinator::{
    EngineConfig, FsyncPolicy, KernelConfig, PersistConfig, PoolConfig, PoolSnapshot, ShardPool,
    StreamConfig, StreamRouter,
};
use inkpca::data::{load, Dataset};
use inkpca::util::bench::Bench;

const SEED_POINTS: usize = 4;

fn stream_cfg() -> StreamConfig {
    StreamConfig {
        kernel: KernelConfig::Rbf { sigma: 2.0 },
        mean_adjust: false,
        seed_points: SEED_POINTS,
        ..StreamConfig::default()
    }
}

fn spawn(persist: Option<PersistConfig>) -> (ShardPool, StreamRouter) {
    let pool = ShardPool::spawn(PoolConfig {
        shards: 1,
        queue: 64,
        engine: EngineConfig::Native,
        persist,
        ..PoolConfig::default()
    });
    let router = pool.router();
    (pool, router)
}

fn scratch_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("inkpca_bench_recovery_{tag}_{}", std::process::id()))
}

fn reset_dir(dir: &PathBuf) {
    std::fs::remove_dir_all(dir).ok();
    std::fs::create_dir_all(dir).unwrap();
}

/// Per-point feed of the whole dataset through one durable (or not)
/// stream; returns the pool snapshot taken while the stream is open.
fn run_feed(ds: &Dataset, persist: Option<PersistConfig>) -> PoolSnapshot {
    let (pool, router) = spawn(persist);
    let h = router.open_stream("bench", ds.dim(), stream_cfg()).unwrap();
    for i in 0..ds.n() {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    let snap = router.pool_snapshot().unwrap();
    pool.shutdown();
    snap
}

/// Snapshot the directory's files into memory (the pristine post-crash
/// state the restore series resets to before every sample).
fn template_of(dir: &PathBuf) -> Vec<(String, Vec<u8>)> {
    let mut files: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| {
            let p = e.ok()?.path();
            let name = p.file_name()?.to_str()?.to_string();
            Some((name, std::fs::read(&p).ok()?))
        })
        .collect();
    files.sort_by(|a, b| a.0.cmp(&b.0));
    files
}

fn restore_from(dir: &PathBuf, template: &[(String, Vec<u8>)]) -> (u64, usize) {
    reset_dir(dir);
    for (name, bytes) in template {
        std::fs::write(dir.join(name), bytes).unwrap();
    }
    let (pool, router) = spawn(Some(PersistConfig::new(dir.clone())));
    let report = router.restore_pool().unwrap();
    assert!(report.failed.is_empty(), "{:?}", report.failed);
    let m = router.snapshot(&report.handles[0]).unwrap().m;
    pool.shutdown();
    (report.replayed, m)
}

fn main() {
    let mut b = Bench::new();
    let fast = std::env::var("INKPCA_BENCH_FAST").is_ok();
    let n = if fast { 48 } else { 160 };
    let mut ds = load("yeast", n, 42).unwrap();
    ds.standardize();

    // Series 1: the WAL's ingest-path overhead ladder.
    let dir = scratch_dir("wal");
    let policies: [(&str, Option<FsyncPolicy>); 3] = [
        ("off", None),
        ("wal", Some(FsyncPolicy::Off)),
        ("wal_fsync8", Some(FsyncPolicy::EveryN(8))),
    ];
    for (label, fsync) in policies {
        b.case(&format!("recovery/ingest_{label}/1stream"), || {
            let persist = fsync.map(|f| {
                reset_dir(&dir);
                let mut p = PersistConfig::new(dir.clone());
                p.fsync = f;
                p
            });
            run_feed(&ds, persist).accepted
        });
        // Attribution guard (outside the timed region): durable runs
        // logged one record per open + one per point, error-free.
        if let Some(f) = fsync {
            reset_dir(&dir);
            let mut p = PersistConfig::new(dir.clone());
            p.fsync = f;
            let snap = run_feed(&ds, Some(p));
            assert_eq!(snap.wal_appends, ds.n() as u64 + 1, "{label}");
            assert_eq!(snap.wal_errors, 0, "{label}");
            assert!(snap.wal_bytes > 0, "{label}");
        }
    }

    // Series 2: checkpointing a live stream, by eigensystem size.
    for m in if fast { vec![n] } else { vec![n / 2, n] } {
        let dir = scratch_dir("ckpt");
        reset_dir(&dir);
        let (pool, router) = spawn(Some(PersistConfig::new(dir.clone())));
        let h = router.open_stream("bench", ds.dim(), stream_cfg()).unwrap();
        for i in 0..m {
            router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
        }
        b.case(&format!("recovery/checkpoint/m{m}"), || {
            // Overwrites the same file each time — the atomic
            // tmp+rename replace is part of what a cut costs.
            router.checkpoint_stream(&h).unwrap()
        });
        pool.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    // Series 3: crash-to-serving, checkpoint install vs full replay.
    // Pristine state A: checkpointed + rotated WAL (clean cut).
    let dir = scratch_dir("restore");
    reset_dir(&dir);
    let (pool, router) = spawn(Some(PersistConfig::new(dir.clone())));
    let h = router.open_stream("bench", ds.dim(), stream_cfg()).unwrap();
    for i in 0..ds.n() {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    router.checkpoint_all().unwrap();
    drop(h);
    pool.shutdown(); // crash right after the checkpoint
    let ckpt_template = template_of(&dir);

    // Pristine state B: the same stream, never checkpointed — the WAL
    // alone carries it.
    reset_dir(&dir);
    let (pool, router) = spawn(Some(PersistConfig::new(dir.clone())));
    let h = router.open_stream("bench", ds.dim(), stream_cfg()).unwrap();
    for i in 0..ds.n() {
        router.ingest(&h, ds.x.row(i).to_vec()).unwrap();
    }
    drop(h);
    pool.shutdown(); // crash with nothing but the log
    let wal_template = template_of(&dir);

    let stats_ckpt = b.case(&format!("recovery/restore_checkpoint/m{n}"), || {
        let (replayed, m) = restore_from(&dir, &ckpt_template);
        assert_eq!(replayed, 0, "a fresh checkpoint needs no replay");
        assert_eq!(m, n);
        m
    });
    let stats_replay = b.case(&format!("recovery/restore_replay/m{n}"), || {
        let (replayed, m) = restore_from(&dir, &wal_template);
        assert_eq!(replayed, n as u64, "the whole feed replays");
        assert_eq!(m, n);
        m
    });
    println!(
        "restore m={n}: checkpoint {:.3} ms vs replay {:.3} ms ({:.1}x) — what \
         compaction-on-restore buys the second crash",
        stats_ckpt.median_ns / 1e6,
        stats_replay.median_ns / 1e6,
        stats_replay.median_ns / stats_ckpt.median_ns.max(1.0)
    );
    std::fs::remove_dir_all(&dir).ok();

    b.finish();
    if let Err(e) = b.write_json("BENCH_recovery.json") {
        eprintln!("warning: could not write BENCH_recovery.json: {e}");
    } else {
        println!("wrote BENCH_recovery.json");
    }
}
