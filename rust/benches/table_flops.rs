//! T1 bench — per-step update cost: ours (adjusted/unadjusted) vs
//! Chin–Suter (faithful + lean) vs Hoegaerts vs batch re-eig, at the
//! paper-relevant sizes. Regenerates the §3 comparison; the acceptance
//! shape is ours-adj < chin-suter by ≳2× and all incremental methods
//! beating batch re-decomposition. Each sample clones a prepared state
//! (`O(m²)` memcpy) and pushes one point, so the measured cost is the
//! `O(m³)` step itself. `INKPCA_BENCH_FAST=1` shrinks budgets.

use inkpca::baselines::{ChinSuterKpca, HoegaertsTracker};
use inkpca::data::load;
use inkpca::kernels::{median_heuristic, Rbf};
use inkpca::kpca::{BatchKpca, IncrementalKpca};
use inkpca::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    let sizes: &[usize] =
        if std::env::var("INKPCA_BENCH_FAST").is_ok() { &[64, 128] } else { &[64, 128, 256] };
    let max_m = sizes.iter().max().unwrap() + 2;
    let mut ds = load("magic", max_m, 42).unwrap();
    ds.standardize();
    let sigma = median_heuristic(&ds.x, 200);
    let kern = Rbf { sigma };

    for &m in sizes {
        let seed = ds.x.submatrix(m, ds.dim());
        let next = ds.x.row(m).to_vec();

        let base_adj = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        b.case(&format!("t1/ours_adjusted/m{m}"), || {
            let mut inc = base_adj.clone();
            inc.push(&next).unwrap()
        });

        let base_un = IncrementalKpca::from_batch(&kern, &seed, false).unwrap();
        b.case(&format!("t1/ours_unadjusted/m{m}"), || {
            let mut inc = base_un.clone();
            inc.push(&next).unwrap()
        });

        let mut base_cs = ChinSuterKpca::from_batch(&kern, &seed).unwrap();
        base_cs.faithful_cost = true;
        b.case(&format!("t1/chin_suter_faithful/m{m}"), || {
            let mut cs = base_cs.clone();
            cs.push(&next).unwrap()
        });

        base_cs.faithful_cost = false;
        b.case(&format!("t1/chin_suter_lean/m{m}"), || {
            let mut cs = base_cs.clone();
            cs.push(&next).unwrap()
        });

        let base_hg = HoegaertsTracker::from_batch(&kern, &seed, m + 2).unwrap();
        b.case(&format!("t1/hoegaerts_full/m{m}"), || {
            let mut hg = base_hg.clone();
            hg.push(&next).unwrap()
        });

        let grown = ds.x.submatrix(m + 1, ds.dim());
        b.case(&format!("t1/batch_reeig/m{m}"), || {
            BatchKpca::fit(&kern, &grown, true).unwrap().values.len()
        });

        // Clone-only floor, for subtracting the per-sample state copy.
        b.case(&format!("t1/clone_floor/m{m}"), || base_adj.clone().len());
    }
    b.finish();
    if let Err(e) = b.write_json("BENCH_t1.json") {
        eprintln!("warning: could not write BENCH_t1.json: {e}");
    } else {
        println!("wrote BENCH_t1.json");
    }
}
