//! End-to-end system driver (EXPERIMENTS.md §E2E): stream 1000 points
//! through the full three-layer stack — the L3 coordinator with bounded
//! backpressure, the engine router dispatching the 2m³ back-rotations
//! (AOT Pallas/PJRT executable above the size threshold, native GEMM
//! below), live drift monitoring, and latency/throughput metrics — then
//! report the incremental-Nyström error the eigensystem supports.
//!
//!     make artifacts && cargo run --release --example streaming_kpca
//!     (runs with the native engine if artifacts/ is absent)

use std::time::Instant;

use inkpca::coordinator::{Config, Coordinator, EngineConfig, EnginePolicy, KernelConfig};
use inkpca::data::{load, SliceSource};
use inkpca::kernels::{gram, median_heuristic, Rbf};
use inkpca::nystrom::IncrementalNystrom;

fn main() -> Result<(), String> {
    let n = std::env::args()
        .skip_while(|a| a != "--n")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let mut ds = load("magic", n, 42)?;
    ds.standardize();
    let dim = ds.dim();
    println!("=== streaming KPCA end-to-end: {} points, dim {dim} ===", ds.n());

    let have_artifacts = std::path::Path::new("artifacts/manifest.tsv").exists();
    // Routed: the coordinator dispatches rotations ≥ 384 to the AOT
    // PJRT executable and the rest to the native GEMM. On this CPU-only
    // image the interpret-lowered Pallas kernel is slower than the
    // native f64 GEMM (EXPERIMENTS.md §Perf), so the threshold keeps the
    // PJRT path exercised without dominating wall-clock; on a real TPU
    // the same router would flip toward the accelerator.
    let engine = if have_artifacts {
        println!("engine: routed (pjrt ≥ 384, native below)");
        EngineConfig::Pjrt { dir: "artifacts".into(), policy: EnginePolicy::Auto { pjrt_min: 384 } }
    } else {
        println!("engine: native (no artifacts/ — run `make artifacts` for pjrt)");
        EngineConfig::Native
    };
    let cfg = Config {
        kernel: KernelConfig::RbfMedian,
        mean_adjust: true,
        engine,
        queue: 64,
        seed_points: 20,
        drift_every: 100,
    };

    // ── Phase 1: stream through the coordinator ──
    let coord = Coordinator::spawn(cfg, dim);
    let t0 = Instant::now();
    let mut src = SliceSource::new(ds.clone());
    let accepted = coord.ingest_stream(&mut src)?;
    let wall = t0.elapsed();
    let snap = coord.snapshot()?;
    let metrics = coord.metrics()?;
    println!("\n── ingest ──");
    println!("accepted {accepted}/{} in {:.2}s", ds.n(), wall.as_secs_f64());
    println!("{metrics}");
    println!("engine dispatch (native, pjrt): {:?}", snap.engine_calls);
    println!(
        "eigensystem: m={} | top eigenvalues {:?}",
        snap.m,
        snap.top_values.iter().take(5).map(|v| (v * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
    let d = coord.measure_drift()?;
    println!(
        "final drift @ m={}: fro {:.3e} spec {:.3e} trace {:.3e} | ‖UUᵀ−I‖ {:.3e}",
        d.m, d.norms.frobenius, d.norms.spectral, d.norms.trace, d.orthogonality
    );
    assert!(d.norms.frobenius.is_finite());
    let scores = coord.project(ds.x.row(0).to_vec(), 3)?;
    println!("projection of first point on top-3 PCs: {scores:?}");
    coord.shutdown();

    // ── Phase 2: incremental Nyström on the same feed (§4) ──
    println!("\n── incremental Nyström (subset → 128) ──");
    let sigma = median_heuristic(&ds.x, 200);
    let kern = Rbf { sigma };
    let eval_n = ds.n().min(512);
    let eval = ds.head(eval_n);
    let k_full = gram(&kern, &eval.x);
    let mut inys = IncrementalNystrom::new(&kern, eval.x.clone())?;
    let t1 = Instant::now();
    for m in 0..128.min(eval_n) {
        inys.add_point(m)?;
        if (m + 1) % 32 == 0 {
            let diff = k_full.sub(&inys.approx_gram());
            let norms = inkpca::linalg::psd_norms(&diff);
            println!(
                "m={:>4}  ‖K−K̃‖_F {:.4e}  ‖·‖₂ {:.4e}  ‖·‖_tr {:.4e}",
                m + 1,
                norms.frobenius,
                norms.spectral,
                norms.trace
            );
        }
    }
    println!("nyström phase: {:.2}s", t1.elapsed().as_secs_f64());
    println!("\nstreaming_kpca OK");
    Ok(())
}
