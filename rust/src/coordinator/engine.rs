//! The stream-engine seam: every per-stream verb the shard worker
//! calls, behind one object-safe trait.
//!
//! [`super::shard::StreamEntry`] owns a `Box<dyn StreamState + Send>`
//! instead of a concrete [`IncrementalKpca`]; which implementation
//! goes in the box is chosen by [`StreamTier`] on the stream's
//! [`super::shard::StreamConfig`]:
//!
//! | tier     | engine                          | memory | per-point |
//! |----------|---------------------------------|--------|-----------|
//! | `Exact`  | [`ExactState`] — paper eq. 2 rank-one eigenupdates | O(m²) | O(m·r) |
//! | `Rff`    | [`RffState`] — RFF + frequent-directions sketch ([`crate::rff`]) | O(D·r) | O(D·r) |
//! | `Shadow` | [`ShadowState`] — both engines on the same points | sum | sum |
//!
//! All tiers speak the same verbs — seed-from-batch, `push_batch_with`,
//! project, [`StreamState::capture`] into a [`ProjectionSnapshot`]
//! (so the lock-free `project_snapshot`/`project_many` read path works
//! unchanged), checkpoint [`StreamState::to_parts`] /
//! [`state_from_parts`], stats/gauges, reserve — while exact-only
//! verbs degrade gracefully: the sketch has no landmark set to bound
//! ([`StreamState::set_bound`] defaults to a no-op) and no Gram matrix
//! to drift-audit ([`StreamState::measure_drift`] errors cleanly).
//!
//! **Divergence contract.** The `Shadow` tier is the accuracy dial:
//! every `sample`-th absorbed point is projected through *both*
//! engines and the per-component gap — `min(|a−b|, |a+b|)`, sign-blind
//! because eigenvectors are — is folded into a max-since-publish
//! gauge. [`StreamState::divergence`] exposes it, the worker rolls it
//! through `Metrics` → `StreamGauges` → `PoolSnapshot`, and every
//! snapshot publish resets the window
//! ([`StreamState::reset_divergence`]). `Exact` and `Rff` report
//! `None` — the gauge is only meaningful when two engines disagree.

use std::sync::Arc;

use super::drift::{measure_point, DriftPoint};
use super::ring::fnv1a;
use super::shard::StreamConfig;
use super::snapshot::{ExactSnapshotParts, ProjectionSnapshot};
use crate::kernels::{kernel_from_describe, Kernel};
use crate::kpca::{BatchOutcome, EvictionPolicy, IncrementalKpca, KpcaParts, KpcaStats};
use crate::linalg::Mat;
use crate::rankone::Rotate;
use crate::rff::{RffKpca, RffParts};

/// Default feature count for `rff`/`shadow` when the config doesn't
/// pick one.
pub const DEFAULT_RFF_FEATURES: usize = 256;
/// Default sketch rank.
pub const DEFAULT_SKETCH_R: usize = 16;
/// Default shadow probe cadence (every N-th absorbed point).
pub const DEFAULT_SHADOW_SAMPLE: usize = 8;
/// Components compared per shadow probe.
const SHADOW_PROBE_R: usize = 4;

/// Which engine a stream runs. Carried on
/// [`super::shard::StreamConfig`], persisted in `IKCKPT03`
/// checkpoints (`IKCKPT02` files predate tiers and restore as
/// `Exact`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamTier {
    /// The paper's exact incremental eigensystem.
    Exact,
    /// Random-Fourier-features + frequent-directions sketch: fixed
    /// memory, per-update cost independent of m. RBF kernels only.
    Rff { features: usize, sketch_r: usize },
    /// Run both engines on the same points; serve from the exact one
    /// and report the max projection divergence every `sample`-th
    /// point.
    Shadow { sample: usize },
}

impl Default for StreamTier {
    fn default() -> Self {
        StreamTier::Exact
    }
}

impl StreamTier {
    pub fn name(&self) -> &'static str {
        match self {
            StreamTier::Exact => "exact",
            StreamTier::Rff { .. } => "rff",
            StreamTier::Shadow { .. } => "shadow",
        }
    }

    /// Parse a CLI spec: `exact` | `rff[:features[:sketch_r]]` |
    /// `shadow[:sample]`.
    pub fn parse(s: &str) -> Result<StreamTier, String> {
        let mut it = s.split(':');
        let head = it.next().unwrap_or("");
        let tier = match head {
            "exact" => {
                if it.next().is_some() {
                    return Err(format!("tier spec `{s}`: exact takes no parameters"));
                }
                StreamTier::Exact
            }
            "rff" => {
                let features = match it.next() {
                    None => DEFAULT_RFF_FEATURES,
                    Some(v) => v
                        .parse::<usize>()
                        .map_err(|_| format!("tier spec `{s}`: bad feature count `{v}`"))?,
                };
                let sketch_r = match it.next() {
                    None => DEFAULT_SKETCH_R.min(features / 2).max(1),
                    Some(v) => v
                        .parse::<usize>()
                        .map_err(|_| format!("tier spec `{s}`: bad sketch rank `{v}`"))?,
                };
                if it.next().is_some() {
                    return Err(format!("tier spec `{s}`: too many parameters"));
                }
                StreamTier::Rff { features, sketch_r }
            }
            "shadow" => {
                let sample = match it.next() {
                    None => DEFAULT_SHADOW_SAMPLE,
                    Some(v) => v
                        .parse::<usize>()
                        .map_err(|_| format!("tier spec `{s}`: bad sample cadence `{v}`"))?,
                };
                if it.next().is_some() {
                    return Err(format!("tier spec `{s}`: too many parameters"));
                }
                StreamTier::Shadow { sample }
            }
            other => {
                return Err(format!(
                    "unknown tier `{other}` (want exact, rff[:D[:r]] or shadow[:sample])"
                ))
            }
        };
        Ok(tier)
    }
}

/// Serialized engine state, tier-tagged — what the `IKCKPT03` codec
/// frames and [`state_from_parts`] revives. The kernel rides as its
/// `describe()` string (same contract as the v02 codec).
#[derive(Clone, Debug)]
pub enum TierParts {
    Exact {
        kernel: String,
        parts: KpcaParts,
    },
    Rff(RffParts),
    Shadow {
        kernel: String,
        exact: KpcaParts,
        rff: RffParts,
        sample: usize,
    },
}

/// Every verb the shard worker calls on a stream's engine. Object-safe
/// and `Send` (the boxed engine migrates between worker threads
/// through `Migrate`/`Install`).
///
/// Mutability note: gauges (`stats`, `top_values`, `sufficiency_gap`,
/// `divergence`, byte/realloc counters) take `&self` and may serve a
/// cached view; the verbs that advance or materialize state (`push_*`,
/// `project`, `capture`, `measure_drift`) take `&mut self`.
pub trait StreamState: Send {
    /// The tier this engine implements (drives checkpoint tagging and
    /// the `Snapshot` display).
    fn tier(&self) -> StreamTier;
    fn tier_name(&self) -> &'static str {
        self.tier().name()
    }

    /// Resident size: landmarks for the exact tier, absorbed points
    /// for the sketch (which holds directions, not rows).
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn dim(&self) -> usize;
    fn kernel_name(&self) -> &'static str;
    fn stats(&self) -> KpcaStats;
    /// Top eigenvalue estimates, descending. May serve the last
    /// materialized spectrum.
    fn top_values(&self, k: usize) -> Vec<f64>;
    fn sufficiency_gap(&self) -> f64;
    fn bytes_resident(&self) -> usize;
    fn reallocs(&self) -> u64;
    fn engine_gemms(&self) -> u64;

    /// Absorb one point. `Ok(false)` = excluded (near rank-deficient).
    fn push_with(&mut self, x: &[f64], engine: &dyn Rotate) -> Result<bool, String>;
    /// Absorb a flat row-major batch.
    fn push_batch_with(&mut self, xs: &[f64], engine: &dyn Rotate)
        -> Result<BatchOutcome, String>;
    /// Per-point accept/exclude mask of the last `push_batch_with`.
    fn last_batch_mask(&self) -> &[bool];

    /// Worker-path projection of one query onto the top `r` components.
    fn project(&mut self, y: &[f64], r: usize) -> Result<Vec<f64>, String>;
    /// Capture an immutable snapshot for the lock-free read path.
    /// `None` while the engine has nothing to serve.
    fn capture(&mut self, r_limit: usize) -> Option<ProjectionSnapshot>;
    /// Gram-reconstruction drift measurement; errors on tiers without
    /// a Gram matrix to reconstruct.
    fn measure_drift(&mut self) -> Result<DriftPoint, String>;

    /// Pre-size internal buffers for an expected landmark count /
    /// batch size.
    fn reserve(&mut self, m: usize, b: usize);
    /// Cap the landmark set (exact tier); the sketch is inherently
    /// bounded, so the default is a no-op.
    fn set_bound(&mut self, _max_landmarks: usize, _policy: EvictionPolicy, _protected: usize) {}

    /// Max projection divergence since the last snapshot publish —
    /// `Some` only on the shadow tier.
    fn divergence(&self) -> Option<f64> {
        None
    }
    /// Reset the divergence window (called at every snapshot publish).
    fn reset_divergence(&mut self) {}

    /// Serialize for the checkpoint codec.
    fn to_parts(&self) -> TierParts;
}

/// Capture an exact eigensystem into a [`ProjectionSnapshot`]: top-`r`
/// basis reordered descending, eigenvalues, the projected centering
/// sums `uᵀK𝟙`/`uᵀ𝟙`, retained data and the shared kernel. `None`
/// until the kernel is shareable (streams built `from_batch_shared`
/// always are).
pub fn capture_exact(
    state: &IncrementalKpca<'_>,
    r_limit: usize,
) -> Option<ProjectionSnapshot> {
    let kernel = state.kernel_arc()?;
    let m = state.len();
    let dim = state.dim();
    let n = state.vals.len();
    let r = if r_limit == 0 { n } else { r_limit.min(n) };
    let view = state.vecs.view();
    let mut vals = Vec::with_capacity(r);
    let mut basis = vec![0.0; m * r];
    for c in 0..r {
        // Live eigenpairs are ascending; the snapshot stores the top
        // component first so `r_eff` at query time is a prefix.
        let idx = n - 1 - c;
        vals.push(state.vals[idx]);
        for j in 0..m {
            basis[j * r + c] = view[(j, idx)];
        }
    }
    let (s, k1) = state.centering_sums();
    let (mut uk1, mut u1) = (Vec::new(), Vec::new());
    if state.mean_adjust {
        uk1 = vec![0.0; r];
        u1 = vec![0.0; r];
        for j in 0..m {
            let row = &basis[j * r..(j + 1) * r];
            let k1j = k1[j];
            for c in 0..r {
                uk1[c] += row[c] * k1j;
                u1[c] += row[c];
            }
        }
    }
    Some(ProjectionSnapshot::from_exact(ExactSnapshotParts {
        m,
        dim,
        mean_adjust: state.mean_adjust,
        r,
        vals,
        basis,
        uk1,
        u1,
        s,
        x: state.data_flat().to_vec(),
        kernel,
    }))
}

/// The exact tier: a thin newtype over the paper's incremental
/// eigensystem. Every trait verb forwards 1:1, so the exact tier's
/// behavior is pinned byte-identical to the pre-trait worker by the
/// existing suites.
pub struct ExactState {
    st: IncrementalKpca<'static>,
}

impl ExactState {
    pub fn seed(
        kernel: Arc<dyn Kernel>,
        seed: &Mat,
        mean_adjust: bool,
        batch_rotation: Option<crate::kpca::BatchRotation>,
    ) -> Result<ExactState, String> {
        let mut st = IncrementalKpca::from_batch_shared(kernel, seed, mean_adjust)?;
        st.batch_rotation = batch_rotation;
        Ok(ExactState { st })
    }

    pub fn from_parts(
        kernel: Arc<dyn Kernel>,
        parts: KpcaParts,
    ) -> Result<ExactState, String> {
        Ok(ExactState { st: IncrementalKpca::from_parts(kernel, parts)? })
    }

    fn parts(&self) -> (String, KpcaParts) {
        let st = &self.st;
        let m = st.len();
        let mut vecs = Vec::with_capacity(m * m);
        for i in 0..m {
            vecs.extend_from_slice(st.vecs.row(i));
        }
        let (s, k1) = st.centering_sums();
        (
            st.kernel_ref().describe(),
            KpcaParts {
                mean_adjust: st.mean_adjust,
                dim: st.dim(),
                x: st.data_flat().to_vec(),
                vals: st.vals.clone(),
                vecs,
                s,
                k1: k1.to_vec(),
                exclude_tol: st.exclude_tol,
                naive_recenter_split: st.naive_recenter_split,
                batch_rotation: st.batch_rotation,
                stats: st.stats,
                engine_gemms: st.engine_gemms(),
            },
        )
    }
}

impl StreamState for ExactState {
    fn tier(&self) -> StreamTier {
        StreamTier::Exact
    }

    fn len(&self) -> usize {
        self.st.len()
    }

    fn dim(&self) -> usize {
        self.st.dim()
    }

    fn kernel_name(&self) -> &'static str {
        self.st.kernel_ref().name()
    }

    fn stats(&self) -> KpcaStats {
        self.st.stats
    }

    fn top_values(&self, k: usize) -> Vec<f64> {
        self.st.vals.iter().rev().take(k).copied().collect()
    }

    fn sufficiency_gap(&self) -> f64 {
        self.st.sufficiency_gap()
    }

    fn bytes_resident(&self) -> usize {
        self.st.hot_path_bytes() + self.st.batch_bytes_resident()
    }

    fn reallocs(&self) -> u64 {
        self.st.hot_path_reallocs() + self.st.batch_reallocs()
    }

    fn engine_gemms(&self) -> u64 {
        self.st.engine_gemms()
    }

    fn push_with(&mut self, x: &[f64], engine: &dyn Rotate) -> Result<bool, String> {
        self.st.push_with(x, engine)
    }

    fn push_batch_with(
        &mut self,
        xs: &[f64],
        engine: &dyn Rotate,
    ) -> Result<BatchOutcome, String> {
        self.st.push_batch_with(xs, engine)
    }

    fn last_batch_mask(&self) -> &[bool] {
        self.st.last_batch_mask()
    }

    fn project(&mut self, y: &[f64], r: usize) -> Result<Vec<f64>, String> {
        Ok(self.st.project(y, r))
    }

    fn capture(&mut self, r_limit: usize) -> Option<ProjectionSnapshot> {
        capture_exact(&self.st, r_limit)
    }

    fn measure_drift(&mut self) -> Result<DriftPoint, String> {
        Ok(measure_point(&self.st))
    }

    fn reserve(&mut self, m: usize, b: usize) {
        self.st.reserve(m, b);
    }

    fn set_bound(&mut self, max_landmarks: usize, policy: EvictionPolicy, protected: usize) {
        self.st.set_bound(max_landmarks, policy, protected);
    }

    fn to_parts(&self) -> TierParts {
        let (kernel, parts) = self.parts();
        TierParts::Exact { kernel, parts }
    }
}

/// The sketched tier: fixed memory, O(D·r) per point, RBF kernels
/// only. Serves projections through the frequent-directions basis; has
/// no landmark set to bound or Gram matrix to drift-audit.
pub struct RffState {
    st: RffKpca,
    tier: StreamTier,
}

impl RffState {
    pub fn new(mut st: RffKpca) -> RffState {
        // Materialize the spectrum once so `&self` gauges read real
        // values before the first capture.
        st.refresh_basis();
        let tier = StreamTier::Rff { features: st.map().features(), sketch_r: st.sketch_r() };
        RffState { st, tier }
    }
}

impl StreamState for RffState {
    fn tier(&self) -> StreamTier {
        self.tier
    }

    fn len(&self) -> usize {
        self.st.len()
    }

    fn dim(&self) -> usize {
        self.st.dim()
    }

    fn kernel_name(&self) -> &'static str {
        "rbf"
    }

    fn stats(&self) -> KpcaStats {
        self.st.stats()
    }

    fn top_values(&self, k: usize) -> Vec<f64> {
        // Cached spectrum (refreshed at every capture/project) — a
        // `&self` gauge must not pay the eigensolve.
        let vals = self.st.cached_values();
        vals[..k.min(vals.len())].to_vec()
    }

    fn sufficiency_gap(&self) -> f64 {
        let mut total = 0.0;
        let mut min_pos = f64::INFINITY;
        for &l in self.st.cached_values() {
            if l > 0.0 {
                total += l;
                if l < min_pos {
                    min_pos = l;
                }
            }
        }
        if total > 0.0 && min_pos.is_finite() {
            min_pos / total
        } else {
            0.0
        }
    }

    fn bytes_resident(&self) -> usize {
        self.st.bytes_resident()
    }

    fn reallocs(&self) -> u64 {
        0
    }

    fn engine_gemms(&self) -> u64 {
        0
    }

    fn push_with(&mut self, x: &[f64], _engine: &dyn Rotate) -> Result<bool, String> {
        self.st.push(x)
    }

    fn push_batch_with(
        &mut self,
        xs: &[f64],
        _engine: &dyn Rotate,
    ) -> Result<BatchOutcome, String> {
        self.st.push_batch(xs)
    }

    fn last_batch_mask(&self) -> &[bool] {
        self.st.last_batch_mask()
    }

    fn project(&mut self, y: &[f64], r: usize) -> Result<Vec<f64>, String> {
        Ok(self.st.project(y, r))
    }

    fn capture(&mut self, r_limit: usize) -> Option<ProjectionSnapshot> {
        let m = self.st.len();
        let dim = self.st.dim();
        let mean_adjust = self.st.mean_adjust();
        let (map, mu, basis, vals) = self.st.snapshot_parts(r_limit)?;
        Some(ProjectionSnapshot::from_rff(map, mu, basis, vals, m, dim, mean_adjust))
    }

    fn measure_drift(&mut self) -> Result<DriftPoint, String> {
        Err("drift measurement needs the exact tier (the sketch keeps no Gram matrix)".into())
    }

    fn reserve(&mut self, _m: usize, _b: usize) {
        // Sketch buffers are fixed-size from construction.
    }

    fn to_parts(&self) -> TierParts {
        TierParts::Rff(self.st.to_parts())
    }
}

/// The accuracy dial: exact + sketch side by side on the same points.
/// All serving verbs (project, capture, stats, bound, drift) come from
/// the exact engine; the sketch runs behind it and every `sample`-th
/// point is projected through both, folding the sign-blind
/// per-component gap into a max-since-publish divergence gauge.
pub struct ShadowState {
    exact: ExactState,
    rff: RffKpca,
    sample: usize,
    seen: u64,
    divergence: f64,
    probed: bool,
}

impl ShadowState {
    pub fn new(exact: ExactState, mut rff: RffKpca, sample: usize) -> ShadowState {
        rff.refresh_basis();
        ShadowState { exact, rff, sample, seen: 0, divergence: 0.0, probed: false }
    }

    /// Feed the sketch and probe on cadence. The exact engine must
    /// already have absorbed the point.
    fn shadow_point(&mut self, x: &[f64]) -> Result<(), String> {
        self.rff.push(x)?;
        self.seen += 1;
        if self.sample > 0 && self.seen % self.sample as u64 == 0 {
            self.probe(x)?;
        }
        Ok(())
    }

    fn probe(&mut self, x: &[f64]) -> Result<(), String> {
        let a = self.exact.project(x, SHADOW_PROBE_R)?;
        let b = self.rff.project(x, SHADOW_PROBE_R);
        let mut gap: f64 = 0.0;
        for c in 0..a.len().min(b.len()) {
            // Eigenvectors are sign-ambiguous between two independent
            // eigensolves; compare up to sign per component.
            gap = gap.max((a[c] - b[c]).abs().min((a[c] + b[c]).abs()));
        }
        self.divergence = self.divergence.max(gap);
        self.probed = true;
        Ok(())
    }
}

impl StreamState for ShadowState {
    fn tier(&self) -> StreamTier {
        StreamTier::Shadow { sample: self.sample }
    }

    fn len(&self) -> usize {
        self.exact.len()
    }

    fn dim(&self) -> usize {
        self.exact.dim()
    }

    fn kernel_name(&self) -> &'static str {
        self.exact.kernel_name()
    }

    fn stats(&self) -> KpcaStats {
        self.exact.stats()
    }

    fn top_values(&self, k: usize) -> Vec<f64> {
        self.exact.top_values(k)
    }

    fn sufficiency_gap(&self) -> f64 {
        self.exact.sufficiency_gap()
    }

    fn bytes_resident(&self) -> usize {
        self.exact.bytes_resident() + self.rff.bytes_resident()
    }

    fn reallocs(&self) -> u64 {
        self.exact.reallocs()
    }

    fn engine_gemms(&self) -> u64 {
        self.exact.engine_gemms()
    }

    fn push_with(&mut self, x: &[f64], engine: &dyn Rotate) -> Result<bool, String> {
        let accepted = self.exact.push_with(x, engine)?;
        self.shadow_point(x)?;
        Ok(accepted)
    }

    fn push_batch_with(
        &mut self,
        xs: &[f64],
        engine: &dyn Rotate,
    ) -> Result<BatchOutcome, String> {
        let outcome = self.exact.push_batch_with(xs, engine)?;
        let dim = self.exact.dim();
        for p in 0..xs.len() / dim {
            self.shadow_point(&xs[p * dim..(p + 1) * dim])?;
        }
        Ok(outcome)
    }

    fn last_batch_mask(&self) -> &[bool] {
        self.exact.last_batch_mask()
    }

    fn project(&mut self, y: &[f64], r: usize) -> Result<Vec<f64>, String> {
        self.exact.project(y, r)
    }

    fn capture(&mut self, r_limit: usize) -> Option<ProjectionSnapshot> {
        self.exact.capture(r_limit)
    }

    fn measure_drift(&mut self) -> Result<DriftPoint, String> {
        self.exact.measure_drift()
    }

    fn reserve(&mut self, m: usize, b: usize) {
        self.exact.reserve(m, b);
    }

    fn set_bound(&mut self, max_landmarks: usize, policy: EvictionPolicy, protected: usize) {
        self.exact.set_bound(max_landmarks, policy, protected);
    }

    fn divergence(&self) -> Option<f64> {
        self.probed.then_some(self.divergence)
    }

    fn reset_divergence(&mut self) {
        self.divergence = 0.0;
    }

    fn to_parts(&self) -> TierParts {
        let (kernel, exact) = self.exact.parts();
        TierParts::Shadow {
            kernel,
            exact,
            rff: self.rff.to_parts(),
            sample: self.sample,
        }
    }
}

/// Extract σ from an RBF kernel's `describe()` string
/// (`rbf(sigma=…)`) — the sketched tiers need the spectral measure,
/// and by seed time `rbf_median` has already resolved to a concrete
/// σ.
fn rbf_sigma(kernel: &dyn Kernel) -> Result<f64, String> {
    let desc = kernel.describe();
    let inner = desc
        .strip_prefix("rbf(sigma=")
        .and_then(|rest| rest.strip_suffix(')'))
        .ok_or_else(|| {
            format!("the rff/shadow tiers require an RBF kernel, got `{desc}`")
        })?;
    inner
        .parse::<f64>()
        .map_err(|_| format!("unparseable sigma in kernel describe `{desc}`"))
}

/// The RFF map's seed: a deterministic function of the stream id, so
/// re-opening (or restoring) a stream regenerates the same map.
fn rff_map_seed(id: &str) -> u64 {
    fnv1a(id)
}

fn seed_rff(
    cfg: &StreamConfig,
    kernel: &dyn Kernel,
    seed: &Mat,
    id: &str,
    features: usize,
    sketch_r: usize,
) -> Result<RffKpca, String> {
    let sigma = rbf_sigma(kernel)?;
    let mut st = RffKpca::new(
        seed.cols(),
        features,
        sketch_r,
        sigma,
        rff_map_seed(id),
        cfg.mean_adjust,
    )?;
    for i in 0..seed.rows() {
        st.push(seed.row(i))?;
    }
    Ok(st)
}

/// Build a freshly seeded engine for `cfg.tier`. The exact arm is the
/// code the entry ran before the seam (kernel shared, batch-rotation
/// policy applied); the sketched arms derive their feature map from
/// the resolved RBF σ and the stream id.
pub fn seed_state(
    cfg: &StreamConfig,
    kernel: Arc<dyn Kernel>,
    seed: &Mat,
    id: &str,
) -> Result<Box<dyn StreamState>, String> {
    match cfg.tier {
        StreamTier::Exact => Ok(Box::new(ExactState::seed(
            kernel,
            seed,
            cfg.mean_adjust,
            cfg.batch_rotation,
        )?)),
        StreamTier::Rff { features, sketch_r } => {
            let st = seed_rff(cfg, kernel.as_ref(), seed, id, features, sketch_r)?;
            Ok(Box::new(RffState::new(st)))
        }
        StreamTier::Shadow { sample } => {
            let rff = seed_rff(
                cfg,
                kernel.as_ref(),
                seed,
                id,
                DEFAULT_RFF_FEATURES,
                DEFAULT_SKETCH_R,
            )?;
            let exact = ExactState::seed(kernel, seed, cfg.mean_adjust, cfg.batch_rotation)?;
            Ok(Box::new(ShadowState::new(exact, rff, sample)))
        }
    }
}

/// Revive an engine from checkpoint parts (the codec's inverse of
/// [`StreamState::to_parts`]). The caller re-applies stream
/// configuration — reserve and bound — through the trait afterwards.
pub fn state_from_parts(parts: TierParts) -> Result<Box<dyn StreamState>, String> {
    match parts {
        TierParts::Exact { kernel, parts } => {
            let kernel = kernel_from_describe(&kernel)?;
            Ok(Box::new(ExactState::from_parts(kernel, parts)?))
        }
        TierParts::Rff(p) => Ok(Box::new(RffState::new(RffKpca::from_parts(p)?))),
        TierParts::Shadow { kernel, exact, rff, sample } => {
            let kernel = kernel_from_describe(&kernel)?;
            let exact = ExactState::from_parts(kernel, exact)?;
            let rff = RffKpca::from_parts(rff)?;
            Ok(Box::new(ShadowState::new(exact, rff, sample)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_specs_parse_and_name() {
        assert_eq!(StreamTier::parse("exact").unwrap(), StreamTier::Exact);
        assert_eq!(
            StreamTier::parse("rff").unwrap(),
            StreamTier::Rff { features: DEFAULT_RFF_FEATURES, sketch_r: DEFAULT_SKETCH_R }
        );
        assert_eq!(
            StreamTier::parse("rff:128:8").unwrap(),
            StreamTier::Rff { features: 128, sketch_r: 8 }
        );
        assert_eq!(
            StreamTier::parse("shadow:5").unwrap(),
            StreamTier::Shadow { sample: 5 }
        );
        assert_eq!(StreamTier::parse("shadow").unwrap().name(), "shadow");
        assert!(StreamTier::parse("nope").is_err());
        assert!(StreamTier::parse("rff:x").is_err());
        assert!(StreamTier::parse("exact:3").is_err());
        assert!(StreamTier::parse("rff:128:8:9").is_err());
    }

    #[test]
    fn rbf_sigma_parses_describe_and_rejects_others() {
        use crate::kernels::{Linear, Rbf};
        assert_eq!(rbf_sigma(&Rbf { sigma: 1.5 }).unwrap(), 1.5);
        assert!(rbf_sigma(&Linear).is_err());
    }
}
