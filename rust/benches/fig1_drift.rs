//! Fig. 1 bench — the drift-experiment inner loops: per-step
//! incremental update at growing sizes on both datasets, the cost of
//! one drift measurement (reconstruct + batch reference + norms), and
//! the sketched tier's per-step cost at the same sizes — the exact
//! step grows with m, the RFF + frequent-directions step does not.

use inkpca::data::load;
use inkpca::kernels::{median_heuristic, Rbf};
use inkpca::kpca::IncrementalKpca;
use inkpca::linalg::sym_norms;
use inkpca::rff::RffKpca;
use inkpca::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    for name in ["magic", "yeast"] {
        let mut ds = load(name, 260, 42).unwrap();
        ds.standardize();
        let sigma = median_heuristic(&ds.x, 200);
        let kern = Rbf { sigma };
        for m in [20usize, 60, 120] {
            let seed = ds.x.submatrix(m, ds.dim());
            let next = ds.x.row(m).to_vec();
            let base = IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
            b.case(&format!("fig1/step/{name}/m{m}"), || {
                let mut inc = base.clone();
                inc.push(&next).unwrap()
            });
            // The sketched counterpart of the same step: absorb one
            // point into a sketch warmed with the same m-point prefix.
            // The sketch's memory is fixed, so pushing in place (no
            // per-sample clone) measures exactly the steady-state cost
            // — flat across this m ladder by construction.
            let mut rff = RffKpca::new(ds.dim(), 256, 16, sigma, 42, true).unwrap();
            for i in 0..m {
                rff.push(ds.x.row(i)).unwrap();
            }
            b.case(&format!("fig1/step_rff/{name}/m{m}"), || {
                rff.push(&next).unwrap()
            });
            b.case(&format!("fig1/drift_measure/{name}/m{m}"), || {
                let diff = base.reconstruct().sub(&base.batch_reference());
                sym_norms(&diff).frobenius
            });
        }
    }
    b.finish();
    if let Err(e) = b.write_json("BENCH_fig1.json") {
        eprintln!("warning: could not write BENCH_fig1.json: {e}");
    } else {
        println!("wrote BENCH_fig1.json");
    }
}
