"""L2 model-function tests: shapes, numerics vs numpy, and the AOT
lowering round-trip (HLO text parses and is non-trivial)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_kernel_column_shape_and_values():
    r = np.random.RandomState(0)
    x = r.randn(128, 16)
    y = r.randn(16)
    got = np.asarray(model.kernel_column(x, y, 2.0))
    want = np.exp(-np.sum((x - y) ** 2, axis=1) / 2.0)
    np.testing.assert_allclose(got, want, rtol=1e-12)


def test_gram_matches_numpy():
    r = np.random.RandomState(1)
    x = r.randn(128, 16)
    got = np.asarray(model.gram(x, 1.5))
    sq = np.sum(x * x, axis=1)
    want = np.exp(-(sq[:, None] + sq[None, :] - 2 * x @ x.T) / 1.5)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-12)


def test_eigvec_update_full_rank_one_step():
    """model.eigvec_update reproduces the dense eigendecomposition of a
    rank-one perturbed matrix when fed true secular roots."""
    k = 128
    r = np.random.RandomState(2)
    a = r.randn(k, k)
    a = 0.5 * (a + a.T)
    lam, u = np.linalg.eigh(a)
    v = r.randn(k)
    b = a + np.outer(v, v)
    lam_new = np.linalg.eigvalsh(b)
    z = u.T @ v
    got = np.asarray(model.eigvec_update(u, z, lam, lam_new))
    np.testing.assert_allclose(got @ np.diag(lam_new) @ got.T, b, atol=1e-6)


def test_nystrom_reconstruct_matches_direct():
    n, m = 64, 16
    r = np.random.RandomState(3)
    x = r.randn(n, 5)
    sq = np.sum(x * x, axis=1)
    k = np.exp(-(sq[:, None] + sq[None, :] - 2 * x @ x.T))
    kmm = k[:m, :m]
    knm = k[:, :m]
    lam, u = np.linalg.eigh(kmm)
    got = np.asarray(model.nystrom_reconstruct(knm, u, lam))
    want = knm @ np.linalg.pinv(kmm, rcond=1e-10) @ knm.T
    np.testing.assert_allclose(got, want, atol=1e-7)


def test_aot_lowering_roundtrip(tmp_path):
    """Every artifact kind lowers to parseable, non-trivial HLO text."""
    text = aot.to_hlo_text(
        model.kernel_column,
        aot.spec((64, aot.DIM)),
        aot.spec((aot.DIM,)),
        aot.spec(()),
    )
    assert "HloModule" in text
    assert len(text) > 200
    text = aot.to_hlo_text(
        model.eigvec_update,
        aot.spec((64, 64)),
        aot.spec((64,)),
        aot.spec((64,)),
        aot.spec((64,)),
    )
    assert "HloModule" in text
    # The rotation must have lowered to a real dot, not a custom-call.
    assert "custom-call" not in text.lower() or "dot" in text.lower()


def test_aot_main_writes_manifest(tmp_path):
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path), "--buckets", "64"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = (tmp_path / "manifest.tsv").read_text()
    lines = [l for l in manifest.splitlines() if l and not l.startswith("#")]
    assert len(lines) == 4  # 4 artifact kinds x 1 bucket
    for line in lines:
        name, kind, m, dim, path = line.split("\t")
        assert (tmp_path / path).exists()
        assert int(m) == 64
