"""L2 — the JAX compute graph the rust coordinator executes via PJRT.

Each public function here is AOT-lowered (aot.py) at every bucket size
into artifacts/*.hlo.txt. The hot inner products call the L1 Pallas
kernels; the O(k^2) scalar prep (inverse column norms) stays in jnp so
the whole step lowers into one fused HLO module.

f64 end to end: the rust native engine computes in f64, and the drift
experiments (Fig. 1) compare engines — a precision mismatch would
confound them. jax is switched to x64 in aot.py before lowering.
"""

import jax.numpy as jnp

from .kernels import eigvec, rbf
from .kernels.ref import eigvec_weights_ref


def kernel_column(x, y, sigma):
    """RBF kernel column against the rows of x (Algorithms 1-2, line 1)."""
    return rbf.rbf_column(x, y, sigma)


def gram(x, sigma):
    """Full RBF Gram matrix (batch baseline / Fig. 2 ground truth)."""
    return rbf.rbf_gram(x, sigma)


def eigvec_update(u, z, lam, lam_new):
    """BNS78 back-rotation (paper eq. 6): U @ normalize_cols(W).

    The O(k^2) norm pre-pass runs in plain jnp; the O(m k^2) rotation is
    the Pallas kernel. Padded columns (z == 0 rows / sentinel lam_new)
    produce finite garbage that callers slice away.
    """
    w = eigvec_weights_ref(z, lam, lam_new)
    norms = jnp.sqrt(jnp.sum(w * w, axis=0))
    inv = 1.0 / jnp.maximum(norms, jnp.asarray(1e-300, u.dtype))
    return eigvec.rotate(u, z, lam, lam_new, inv)


def nystrom_reconstruct(knm, u, lam, rcond=1e-12):
    """Nystrom approximation K~ = (Knm U L^+) L_nys (Knm U L^+)^T scaled
    per eq. (7); returned directly as the n x n matrix.

    Simplifies to K~ = B L^+ B^T with B = Knm @ U (the n/m factors
    cancel). Tiny eigenvalues are pseudo-inverted away.
    """
    lam_max = jnp.max(jnp.abs(lam))
    inv = jnp.where(jnp.abs(lam) > rcond * lam_max, 1.0 / lam, 0.0)
    b = knm @ u
    return (b * inv[None, :]) @ b.T
