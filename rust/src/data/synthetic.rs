//! Synthetic stand-ins for the paper's UCI datasets (substitution table
//! in DESIGN.md §3). The generators match the published summary shape of
//! each dataset — dimensionality, mixture structure, tail behaviour —
//! which is what the norm-vs-m curves of Figures 1–2 are sensitive to.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::Rng;

/// Magic-gamma-telescope-like data: 10 continuous features from a
/// two-component mixture (gamma vs hadron showers ≈ 65/35 split),
/// where the first features are heavy-tailed (shower sizes are
/// log-normal-ish) and the rest are correlated Gaussians.
pub fn magic_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x4D41_4749_43); // "MAGIC"
    let d = 10;
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        let gamma = rng.uniform() < 0.648; // class mix from the UCI docs
        let (mu_shift, spread) = if gamma { (0.0, 1.0) } else { (0.8, 1.4) };
        // Heavy-tailed "size" features (fLength, fWidth, fSize).
        let core = rng.normal();
        x[(i, 0)] = rng.lognormal(3.0 + mu_shift + 0.3 * core, 0.5 * spread);
        x[(i, 1)] = rng.lognormal(2.0 + mu_shift + 0.4 * core, 0.6 * spread);
        x[(i, 2)] = rng.lognormal(0.8 + 0.2 * core, 0.25);
        // Shape/concentration ratios in (0, 1).
        x[(i, 3)] = (0.5 + 0.2 * rng.normal() + 0.1 * core).clamp(0.0, 1.0);
        x[(i, 4)] = (0.3 + 0.15 * rng.normal()).clamp(0.0, 1.0);
        // Signed asymmetry features, roughly centred.
        x[(i, 5)] = 30.0 * spread * rng.normal() + 5.0 * core;
        x[(i, 6)] = 25.0 * spread * rng.normal();
        x[(i, 7)] = 15.0 * rng.normal() + if gamma { 0.0 } else { 10.0 };
        // Alpha angle and distance.
        x[(i, 8)] = (if gamma { 15.0 } else { 45.0 } + 20.0 * rng.normal()).abs() % 90.0;
        x[(i, 9)] = rng.lognormal(5.0, 0.4);
    }
    Dataset { name: "magic-like".into(), x }
}

/// Yeast-like data: 8 bounded features in `[0, 1]` with block
/// correlation and ~10 cluster centres (protein localization sites).
pub fn yeast_like(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5945_4153_54); // "YEAST"
    let d = 8;
    let n_clusters = 10;
    // Cluster centres in [0.2, 0.8]^d.
    let centres: Vec<Vec<f64>> = (0..n_clusters)
        .map(|_| (0..d).map(|_| rng.range(0.2, 0.8)).collect())
        .collect();
    // Skewed cluster weights (CYT dominates in the real data).
    let weights = [0.31, 0.29, 0.16, 0.11, 0.035, 0.03, 0.025, 0.02, 0.013, 0.007];
    let mut x = Mat::zeros(n, d);
    for i in 0..n {
        let u = rng.uniform();
        let mut acc = 0.0;
        let mut c = 0;
        for (ci, w) in weights.iter().enumerate() {
            acc += w;
            if u < acc {
                c = ci;
                break;
            }
        }
        // Two correlated blocks (mcg/gvh and alm/mit are correlated in
        // the real measurements), plus two near-discrete features
        // (erl/pox are almost binary in the real data).
        let b1 = 0.08 * rng.normal();
        let b2 = 0.08 * rng.normal();
        for j in 0..d {
            let noise = 0.06 * rng.normal();
            let block = match j {
                0 | 1 => b1,
                2 | 3 => b2,
                _ => 0.0,
            };
            let v = if j == 6 {
                if rng.uniform() < 0.98 { 0.5 } else { 1.0 } // erl-like
            } else if j == 7 {
                if rng.uniform() < 0.95 { 0.0 } else { rng.range(0.5, 0.85) } // pox-like
            } else {
                centres[c][j] + block + noise
            };
            x[(i, j)] = v.clamp(0.0, 1.0);
        }
    }
    Dataset { name: "yeast-like".into(), x }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn magic_shapes_and_determinism() {
        let a = magic_like(100, 7);
        let b = magic_like(100, 7);
        assert_eq!(a.n(), 100);
        assert_eq!(a.dim(), 10);
        assert_eq!(a.x.max_abs_diff(&b.x), 0.0);
        let c = magic_like(100, 8);
        assert!(c.x.max_abs_diff(&a.x) > 0.0);
    }

    #[test]
    fn magic_heavy_tail_positive() {
        let ds = magic_like(500, 1);
        // Log-normal features are strictly positive with occasional
        // large values.
        let col0: Vec<f64> = (0..500).map(|i| ds.x[(i, 0)]).collect();
        assert!(col0.iter().all(|&v| v > 0.0));
        let mean = col0.iter().sum::<f64>() / 500.0;
        let max = col0.iter().fold(0.0_f64, |m, &v| m.max(v));
        assert!(max > 3.0 * mean, "expected heavy tail, max={max} mean={mean}");
    }

    #[test]
    fn yeast_bounded_unit_interval() {
        let ds = yeast_like(300, 2);
        assert_eq!(ds.dim(), 8);
        for i in 0..300 {
            for j in 0..8 {
                let v = ds.x[(i, j)];
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn yeast_has_cluster_structure() {
        // Mean pairwise distance within the data should be clearly
        // smaller than for uniform noise (clusters concentrate mass).
        let ds = yeast_like(200, 3);
        let mut rng = Rng::new(999);
        let unif = Mat::from_fn(200, 8, |_, _| rng.uniform());
        let mean_d = |x: &Mat| {
            let mut s = 0.0;
            let mut c = 0;
            for i in 0..50 {
                for j in (i + 1)..50 {
                    s += crate::kernels::sqdist(x.row(i), x.row(j)).sqrt();
                    c += 1;
                }
            }
            s / c as f64
        };
        assert!(mean_d(&ds.x) < mean_d(&unif));
    }
}
