//! # inkpca — Incremental kernel PCA and the Nyström method
//!
//! A three-layer Rust + JAX + Pallas reproduction of Hallgren &
//! Northrop, *"Incremental kernel PCA and the Nyström method"*
//! (stat.ML 2018).
//!
//! - **Layer 3** ([`coordinator`]) — streaming orchestrator in Rust:
//!   ingestion with backpressure, eigenstate management, engine routing,
//!   drift monitoring, metrics.
//! - **Layer 2/1** — JAX model + Pallas kernels (build-time Python),
//!   AOT-lowered to HLO text and executed from Rust via PJRT
//!   ([`runtime`]).
//! - The paper's algorithms live in [`kpca`] (Algorithms 1 & 2),
//!   [`rankone`]/[`secular`] (the Golub-73 / Bunch–Nielsen–Sorensen-78
//!   rank-one eigen update) and [`nystrom`] (§4 incremental Nyström),
//!   with baselines in [`baselines`] and all dense linear algebra built
//!   from scratch in [`linalg`].

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kernels;
pub mod kpca;
pub mod linalg;
pub mod nystrom;
pub mod rankone;
pub mod runtime;
pub mod secular;
pub mod util;
