//! Projection of new points onto kernel principal components (§2.2):
//! the feature-space eigenvector is `vᵢ = Φᵀuᵢ/√λᵢ`, so the score of a
//! point `y` on component `i` is `⟨φ(y), vᵢ⟩ = (uᵢᵀ k_y)/√λᵢ` with
//! `k_y[j] = k(xⱼ, y)` (centered consistently when the model is
//! mean-adjusted).

use crate::kernels::{kernel_column, Kernel};
use crate::linalg::{Mat, MatView};

use super::centering::center_column;
use super::incremental::IncrementalKpca;

/// Project `y` onto the top `r` principal components of a fitted
/// eigensystem over training data `x` with (adjusted) eigenpairs
/// `(vals ascending, vecs)` — `vecs` is anything viewable as a matrix
/// (`&Mat`, a batch model's vectors, or an incremental state's
/// `EigenBasis`). `k` is the *uncentered* training Gram matrix, needed
/// for centering the new column; pass `None` when the model is
/// unadjusted.
pub fn project_point<'v>(
    kernel: &dyn Kernel,
    x: &Mat,
    vals: &[f64],
    vecs: impl Into<MatView<'v>>,
    k_uncentered: Option<&Mat>,
    y: &[f64],
    r: usize,
) -> Vec<f64> {
    let vecs = vecs.into();
    let m = x.rows();
    let ky = kernel_column(kernel, x, m, y);
    let col = match k_uncentered {
        Some(k) => center_column(k, &ky),
        None => ky,
    };
    // Top components are at the END of the ascending eigenvalue order.
    let n = vals.len();
    let r = r.min(n);
    let mut scores = Vec::with_capacity(r);
    for c in 0..r {
        let idx = n - 1 - c;
        let lam = vals[idx];
        if lam <= 1e-12 {
            scores.push(0.0);
            continue;
        }
        let mut dot = 0.0;
        for j in 0..m {
            dot += vecs[(j, idx)] * col[j];
        }
        scores.push(dot / lam.sqrt());
    }
    scores
}

impl<'k> IncrementalKpca<'k> {
    /// Project a new point onto the current top-`r` components.
    /// For mean-adjusted models this recomputes the uncentered Gram
    /// (`O(m²)` kernel evaluations) — acceptable for scoring paths;
    /// the coordinator caches it per snapshot.
    pub fn project(&self, kernel: &dyn Kernel, y: &[f64], r: usize) -> Vec<f64> {
        let x = self.data();
        let k = if self.mean_adjust {
            Some(crate::kernels::gram(kernel, &x))
        } else {
            None
        };
        project_point(kernel, &x, &self.vals, &self.vecs, k.as_ref(), y, r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::{gram, Rbf};
    use crate::kpca::batch::BatchKpca;

    /// Projections of training points must reproduce the eigen-scores:
    /// projecting xⱼ on component i gives √λᵢ · uᵢⱼ.
    #[test]
    fn training_point_projection_consistency() {
        let ds = yeast_like(12, 1);
        let kern = Rbf { sigma: 1.0 };
        let model = BatchKpca::fit(&kern, &ds.x, false).unwrap();
        let n = ds.n();
        let y = ds.x.row(4);
        let scores = project_point(&kern, &ds.x, &model.values, &model.vectors, None, y, 3);
        for c in 0..3 {
            let idx = n - 1 - c;
            let expect = model.values[idx].sqrt() * model.vectors[(4, idx)];
            assert!(
                (scores[c] - expect).abs() < 1e-9,
                "component {c}: {} vs {expect}",
                scores[c]
            );
        }
    }

    #[test]
    fn centered_projection_consistency() {
        let ds = yeast_like(10, 2);
        let kern = Rbf { sigma: 1.0 };
        let model = BatchKpca::fit(&kern, &ds.x, true).unwrap();
        let k = gram(&kern, &ds.x);
        let y = ds.x.row(7);
        let scores =
            project_point(&kern, &ds.x, &model.values, &model.vectors, Some(&k), y, 2);
        let n = ds.n();
        for c in 0..2 {
            let idx = n - 1 - c;
            let expect = model.values[idx].sqrt() * model.vectors[(7, idx)];
            assert!((scores[c] - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn incremental_projection_matches_batch() {
        let ds = yeast_like(14, 3);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut inc =
            crate::kpca::IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 6..ds.n() {
            inc.push(ds.x.row(i)).unwrap();
        }
        let batch = BatchKpca::fit(&kern, &ds.x, true).unwrap();
        let k = gram(&kern, &ds.x);
        let probe = vec![0.4; ds.dim()];
        let si = inc.project(&kern, &probe, 3);
        let sb =
            project_point(&kern, &ds.x, &batch.values, &batch.vectors, Some(&k), &probe, 3);
        for (a, b) in si.iter().zip(sb.iter()) {
            // Eigenvector sign is arbitrary — compare magnitudes.
            assert!((a.abs() - b.abs()).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn zero_eigenvalue_components_score_zero() {
        let ds = yeast_like(6, 4);
        let kern = Rbf { sigma: 1.0 };
        let model = BatchKpca::fit(&kern, &ds.x, true).unwrap();
        let k = gram(&kern, &ds.x);
        let scores = project_point(
            &kern,
            &ds.x,
            &model.values,
            &model.vectors,
            Some(&k),
            ds.x.row(0),
            6,
        );
        // The centered Gram has rank ≤ n−1: the last component is null.
        assert_eq!(scores.len(), 6);
        assert_eq!(scores[5], 0.0);
    }
}
