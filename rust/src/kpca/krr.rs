//! Incremental kernel ridge regression through the eigendecomposition —
//! the paper's §3 claim made concrete: "any incremental algorithm for
//! the eigendecomposition of the kernel matrix can be applied where the
//! explicit or implicit inverse of the same is required, such as kernel
//! regression". With `K = UΛUᵀ` maintained by Algorithm 1, the KRR
//! coefficients are `α = U (Λ + λI)⁻¹ Uᵀ y` — an `O(m²)` refresh per
//! ridge value, with the eigensystem update doing the `O(m³)` work once
//! per example regardless of how many ridges are evaluated (the standard
//! reason to prefer the eigendecomposition over one Cholesky per λ).

use crate::kernels::{kernel_column, Kernel};
use crate::linalg::{gemv_t, Mat};
use crate::rankone::Rotate;

use super::incremental::IncrementalKpca;

/// Incremental KRR model: an (unadjusted) incremental eigensystem plus
/// the stored targets.
pub struct IncrementalKrr<'k> {
    pub kpca: IncrementalKpca<'k>,
    y: Vec<f64>,
    /// Ridge (regularization) parameter λ.
    pub ridge: f64,
}

impl<'k> IncrementalKrr<'k> {
    /// Seed from a batch fit over `(x0, y0)`.
    pub fn from_batch(
        kernel: &'k dyn Kernel,
        x0: &Mat,
        y0: &[f64],
        ridge: f64,
    ) -> Result<Self, String> {
        assert_eq!(x0.rows(), y0.len());
        assert!(ridge > 0.0, "ridge must be positive");
        let kpca = IncrementalKpca::from_batch(kernel, x0, false)?;
        Ok(IncrementalKrr { kpca, y: y0.to_vec(), ridge })
    }

    pub fn len(&self) -> usize {
        self.kpca.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kpca.is_empty()
    }

    /// Ingest one labelled example.
    pub fn push(&mut self, x: &[f64], y: f64) -> Result<bool, String> {
        self.push_with(x, y, &crate::rankone::NativeRotate)
    }

    pub fn push_with(&mut self, x: &[f64], y: f64, engine: &dyn Rotate) -> Result<bool, String> {
        let accepted = self.kpca.push_with(x, engine)?;
        if accepted {
            self.y.push(y);
        }
        Ok(accepted)
    }

    /// Dual coefficients `α = U (Λ + λI)⁻¹ Uᵀ y` for the current ridge.
    pub fn coefficients(&self) -> Vec<f64> {
        self.coefficients_for(self.ridge)
    }

    /// Coefficients for an arbitrary ridge — `O(m²)`, no refactorization
    /// (the eigensystem amortizes across the whole regularization path).
    pub fn coefficients_for(&self, ridge: f64) -> Vec<f64> {
        let uty = gemv_t(&self.kpca.vecs, &self.y);
        let scaled: Vec<f64> = uty
            .iter()
            .zip(&self.kpca.vals)
            .map(|(c, l)| c / (l + ridge))
            .collect();
        crate::linalg::gemv(&self.kpca.vecs, &scaled)
    }

    /// Predict at a query point.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let data = self.kpca.data();
        let kq = kernel_column(self.kpca.kernel_ref(), &data, self.len(), x);
        crate::linalg::dot(&self.coefficients(), &kq)
    }

    /// In-sample predictions (smoother matrix applied to `y`).
    pub fn fitted(&self) -> Vec<f64> {
        let data = self.kpca.data();
        let k = crate::kernels::gram(self.kpca.kernel_ref(), &data);
        crate::linalg::gemv(&k, &self.coefficients())
    }

    /// Effective degrees of freedom `Σ λᵢ/(λᵢ+ridge)` — free given the
    /// eigenvalues, used for regularization-path selection.
    pub fn effective_dof(&self, ridge: f64) -> f64 {
        self.kpca.vals.iter().map(|l| l / (l + ridge)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::Rbf;
    use crate::linalg::Cholesky;

    fn toy_problem(n: usize) -> (Mat, Vec<f64>) {
        let ds = yeast_like(n, 9);
        let y: Vec<f64> =
            (0..n).map(|i| ds.x[(i, 0)] * 2.0 - ds.x[(i, 1)] + 0.1 * (i as f64).sin()).collect();
        (ds.x, y)
    }

    #[test]
    fn matches_direct_solve() {
        let (x, y) = toy_problem(18);
        let kern = Rbf { sigma: 1.0 };
        let ridge = 0.1;
        let seed_n = 6;
        let mut krr =
            IncrementalKrr::from_batch(&kern, &x.submatrix(seed_n, x.cols()), &y[..seed_n], ridge)
                .unwrap();
        for i in seed_n..18 {
            krr.push(x.row(i), y[i]).unwrap();
        }
        // Direct: α = (K + λI)⁻¹ y via Cholesky.
        let mut k = crate::kernels::gram(&kern, &x);
        for i in 0..18 {
            k[(i, i)] += ridge;
        }
        let direct = Cholesky::new(&k).unwrap().solve(&y);
        let ours = krr.coefficients();
        for (a, b) in ours.iter().zip(direct.iter()) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn prediction_interpolates_with_tiny_ridge() {
        let (x, y) = toy_problem(12);
        let kern = Rbf { sigma: 1.0 };
        let mut krr =
            IncrementalKrr::from_batch(&kern, &x.submatrix(4, x.cols()), &y[..4], 1e-8).unwrap();
        for i in 4..12 {
            krr.push(x.row(i), y[i]).unwrap();
        }
        // Near-zero ridge: training predictions ≈ targets.
        for i in 0..12 {
            let p = krr.predict(x.row(i));
            assert!((p - y[i]).abs() < 1e-3, "{p} vs {}", y[i]);
        }
    }

    #[test]
    fn ridge_path_without_refactorization() {
        let (x, y) = toy_problem(14);
        let kern = Rbf { sigma: 1.0 };
        let mut krr =
            IncrementalKrr::from_batch(&kern, &x.submatrix(5, x.cols()), &y[..5], 0.5).unwrap();
        for i in 5..14 {
            krr.push(x.row(i), y[i]).unwrap();
        }
        // dof decreases monotonically with ridge — the path is coherent.
        let d1 = krr.effective_dof(0.01);
        let d2 = krr.effective_dof(0.1);
        let d3 = krr.effective_dof(1.0);
        assert!(d1 > d2 && d2 > d3);
        // Coefficients for each ridge match the direct solve.
        for ridge in [0.01, 0.1, 1.0] {
            let mut k = crate::kernels::gram(&kern, &x);
            for i in 0..14 {
                k[(i, i)] += ridge;
            }
            let direct = Cholesky::new(&k).unwrap().solve(&y);
            for (a, b) in krr.coefficients_for(ridge).iter().zip(direct.iter()) {
                assert!((a - b).abs() < 1e-8);
            }
        }
    }
}
