//! Real PJRT execution (`--cfg pjrt_runtime` + vendored `xla` crate):
//! compiles the HLO-text artifacts once per (kind, bucket) on the CPU
//! PJRT client and exposes typed, padded execution wrappers. This is
//! the only module that touches the `xla` crate — Python never runs at
//! request time.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use super::pad;
use crate::linalg::{Mat, MatView, MatViewMut};
use crate::rankone::{NativeRotate, Rotate};
use crate::secular::SecularRoot;

/// Compiled-executable cache keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: super::Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Reusable padding buffers: operands are staged into these before
    /// literal construction, so warm-bucket dispatch re-pads without
    /// growing the allocator (the device literal copy is unavoidable).
    staging: Mutex<pad::Staging>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest from
    /// `dir` (normally `artifacts/`).
    pub fn new(dir: &Path) -> Result<Self, String> {
        let manifest = super::Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            staging: Mutex::new(pad::Staging::new()),
        })
    }

    pub fn manifest(&self) -> &super::Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for `(kind, bucket)`.
    fn exe(
        &self,
        kind: &str,
        bucket: usize,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, String> {
        let meta = self
            .manifest
            .entry(kind, bucket)
            .ok_or_else(|| format!("no artifact for {kind}@{bucket}"))?;
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(&meta.name) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            meta.path.to_str().ok_or("non-utf8 artifact path")?,
        )
        .map_err(|e| format!("parse {}: {e}", meta.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| format!("compile {kind}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        cache.insert(meta.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Warm the executable cache for every artifact (start-up path of
    /// the coordinator, so first requests don't pay compile latency).
    pub fn warmup(&self) -> Result<usize, String> {
        let mut n = 0;
        for kind in self.manifest.kinds() {
            for &b in self.manifest.buckets(kind) {
                self.exe(kind, b)?;
                n += 1;
            }
        }
        Ok(n)
    }

    fn run(
        &self,
        kind: &str,
        bucket: usize,
        inputs: &[xla::Literal],
    ) -> Result<Vec<f64>, String> {
        let exe = self.exe(kind, bucket)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| format!("execute {kind}@{bucket}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {kind}: {e}"))?;
        let out = result.to_tuple1().map_err(|e| format!("untuple {kind}: {e}"))?;
        out.to_vec::<f64>().map_err(|e| format!("to_vec {kind}: {e}"))
    }

    /// Build a `rows × cols` device literal from a staged padded buffer.
    fn lit_mat(buf: &[f64], rows: usize, cols: usize) -> Result<xla::Literal, String> {
        xla::Literal::vec1(buf)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| format!("reshape literal: {e}"))
    }

    /// RBF kernel column `k(xᵢ, y)` for the leading `m` rows of `x`.
    pub fn kernel_column(&self, x: &Mat, y: &[f64], sigma: f64) -> Result<Vec<f64>, String> {
        let m = x.rows();
        let d = self.manifest.dim;
        assert!(x.cols() <= d, "feature dim exceeds artifact pad target");
        let bucket = self
            .manifest
            .bucket_for("kernel_column", m)
            .ok_or_else(|| format!("kernel_column: no bucket ≥ {m}"))?;
        let (xl, yl) = {
            let mut st = self.staging.lock().unwrap();
            pad::pad_mat_into(x.view(), bucket, d, &mut st.mat_a);
            pad::pad_zeros_into(y, d, &mut st.vec_a);
            (Self::lit_mat(&st.mat_a, bucket, d)?, xla::Literal::vec1(&st.vec_a))
        };
        let out = self.run("kernel_column", bucket, &[xl, yl, xla::Literal::from(sigma)])?;
        Ok(out[..m].to_vec())
    }

    /// Full RBF Gram matrix over the rows of `x`.
    pub fn gram(&self, x: &Mat, sigma: f64) -> Result<Mat, String> {
        let n = x.rows();
        let d = self.manifest.dim;
        let bucket = self
            .manifest
            .bucket_for("gram", n)
            .ok_or_else(|| format!("gram: no bucket ≥ {n}"))?;
        let xl = {
            let mut st = self.staging.lock().unwrap();
            pad::pad_mat_into(x.view(), bucket, d, &mut st.mat_a);
            Self::lit_mat(&st.mat_a, bucket, d)?
        };
        let out = self.run("gram", bucket, &[xl, xla::Literal::from(sigma)])?;
        let full = Mat::from_vec(bucket, bucket, out);
        Ok(pad::unpad_mat(&full, n, n))
    }

    /// BNS78 back-rotation via the AOT Pallas kernel: `u` is `m × k`
    /// (rows = eigenvector length, cols = active eigenpairs).
    pub fn eigvec_update(
        &self,
        u: &Mat,
        z: &[f64],
        lam: &[f64],
        lam_new: &[f64],
    ) -> Result<Mat, String> {
        let (m, k) = (u.rows(), u.cols());
        assert!(z.len() == k && lam.len() == k && lam_new.len() == k);
        let size = m.max(k);
        let bucket = self
            .manifest
            .bucket_for("eigvec_update", size)
            .ok_or_else(|| format!("eigvec_update: no bucket ≥ {size}"))?;
        let lits = {
            let mut st = self.staging.lock().unwrap();
            pad::pad_mat_into(u.view(), bucket, bucket, &mut st.mat_a);
            pad::pad_zeros_into(z, bucket, &mut st.vec_a);
            pad::pad_sentinels_into(lam, bucket, 0.0, &mut st.vec_b);
            pad::pad_sentinels_into(lam_new, bucket, 0.5, &mut st.vec_c);
            [
                Self::lit_mat(&st.mat_a, bucket, bucket)?,
                xla::Literal::vec1(&st.vec_a),
                xla::Literal::vec1(&st.vec_b),
                xla::Literal::vec1(&st.vec_c),
            ]
        };
        let out = self.run("eigvec_update", bucket, &lits)?;
        let full = Mat::from_vec(bucket, bucket, out);
        Ok(pad::unpad_mat(&full, m, k))
    }

    /// Nyström reconstruction `K̃` from `K_{n,m}`, `U`, `Λ` (eq. 7).
    pub fn nystrom_reconstruct(&self, knm: &Mat, u: &Mat, lam: &[f64]) -> Result<Mat, String> {
        let (n, m) = (knm.rows(), knm.cols());
        assert_eq!(u.rows(), m);
        assert_eq!(lam.len(), m);
        let bucket_m = self
            .manifest
            .bucket_for("nystrom_reconstruct", m)
            .ok_or_else(|| format!("nystrom_reconstruct: no bucket ≥ {m}"))?;
        // The artifact fixes n at the top of the ladder.
        let bucket_n = *self
            .manifest
            .buckets("gram")
            .last()
            .ok_or("nystrom_reconstruct: no gram buckets")?;
        if n > bucket_n {
            return Err(format!("nystrom_reconstruct: n={n} exceeds max bucket {bucket_n}"));
        }
        let lits = {
            let mut st = self.staging.lock().unwrap();
            pad::pad_mat_into(knm.view(), bucket_n, bucket_m, &mut st.mat_a);
            pad::pad_mat_into(u.view(), bucket_m, bucket_m, &mut st.mat_b);
            // Padded eigenvalues are ZEROS here, not sentinels: the
            // artifact computes its pseudo-inverse cutoff from max|λ|,
            // which sentinel values would corrupt; zeros fail the cutoff
            // test and invert to exactly 0 (and the padded U columns are
            // zero anyway).
            pad::pad_zeros_into(lam, bucket_m, &mut st.vec_a);
            [
                Self::lit_mat(&st.mat_a, bucket_n, bucket_m)?,
                Self::lit_mat(&st.mat_b, bucket_m, bucket_m)?,
                xla::Literal::vec1(&st.vec_a),
            ]
        };
        let out = self.run("nystrom_reconstruct", bucket_m, &lits)?;
        let full = Mat::from_vec(bucket_n, bucket_n, out);
        Ok(pad::unpad_mat(&full, n, n))
    }
}

/// [`Rotate`] engine backed by the AOT Pallas `eigvec_update` artifact.
/// Problems smaller than `min_size` (or without a fitting bucket) fall
/// back to the native engine — padding waste dominates below ~64. The
/// PJRT round-trip allocates by nature (padding + device literals); the
/// zero-allocation guarantee applies to the native path only.
pub struct PjrtRotate {
    pub runtime: std::sync::Arc<Runtime>,
    pub min_size: usize,
    fallback: NativeRotate,
}

impl PjrtRotate {
    pub fn new(runtime: std::sync::Arc<Runtime>) -> Self {
        PjrtRotate { runtime, min_size: 0, fallback: NativeRotate }
    }
}

impl Rotate for PjrtRotate {
    fn rotate_into(&self, u: MatView<'_>, w: MatView<'_>, out: MatViewMut<'_>) {
        // The W-form product has no dedicated artifact; only the fused
        // path runs on PJRT.
        self.fallback.rotate_into(u, w, out);
    }

    fn rotate_fused_into(
        &self,
        u: MatView<'_>,
        z: &[f64],
        d: &[f64],
        roots: &[SecularRoot],
        mut out: MatViewMut<'_>,
    ) -> bool {
        if u.rows().max(u.cols()) < self.min_size {
            return false;
        }
        let lam_new: Vec<f64> = roots.iter().map(|r| r.value).collect();
        let um = u.to_mat();
        match self.runtime.eigvec_update(&um, z, d, &lam_new) {
            Ok(rotated) => {
                out.copy_from(rotated.view());
                true
            }
            Err(_) => false,
        }
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{gram as native_gram, kernel_column as native_col, Rbf};
    use crate::linalg::eigh;
    use crate::util::Rng;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.tsv").exists() {
            Some(Runtime::new(dir).expect("runtime init"))
        } else {
            None
        }
    }

    #[test]
    fn pjrt_kernel_column_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(50, 10, |_, _| rng.range(-1.0, 1.0));
        let y: Vec<f64> = (0..10).map(|_| rng.range(-1.0, 1.0)).collect();
        let sigma = 1.3;
        let got = rt.kernel_column(&x, &y, sigma).unwrap();
        let want = native_col(&Rbf { sigma }, &x, 50, &y);
        for (g, w) in got.iter().zip(want.iter()) {
            assert!((g - w).abs() < 1e-12, "{g} vs {w}");
        }
    }

    #[test]
    fn pjrt_gram_matches_native() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(70, 8, |_, _| rng.range(-1.0, 1.0));
        let sigma = 0.9;
        let got = rt.gram(&x, sigma).unwrap();
        let want = native_gram(&Rbf { sigma }, &x);
        assert!(got.max_abs_diff(&want) < 1e-11);
    }

    #[test]
    fn pjrt_eigvec_update_matches_dense() {
        let Some(rt) = runtime() else { return };
        let mut rng = Rng::new(3);
        let n = 40;
        let mut a = Mat::from_fn(n, n, |_, _| rng.range(-1.0, 1.0));
        a.symmetrize();
        let eg = eigh(&a).unwrap();
        let v: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 1.0)).collect();
        let mut b = a.clone();
        b.syr(1.0, &v);
        let expect = eigh(&b).unwrap();
        let z = crate::linalg::gemv_t(&eg.vectors, &v);
        let got = rt
            .eigvec_update(&eg.vectors, &z, &eg.values, &expect.values)
            .unwrap();
        // got should reconstruct b: got Λ̃ gotᵀ == b.
        let mut gl = got.clone();
        for i in 0..n {
            for j in 0..n {
                gl[(i, j)] *= expect.values[j];
            }
        }
        let rec = crate::linalg::matmul_nt(&gl, &got);
        assert!(rec.max_abs_diff(&b) < 1e-7, "diff {}", rec.max_abs_diff(&b));
    }

    #[test]
    fn pjrt_rotate_engine_drives_incremental_kpca() {
        let Some(rt) = runtime() else { return };
        let engine = PjrtRotate::new(std::sync::Arc::new(rt));
        let ds = crate::data::synthetic::yeast_like(14, 4);
        let kern = Rbf { sigma: 1.0 };
        let seed = ds.x.submatrix(6, ds.dim());
        let mut inc = crate::kpca::IncrementalKpca::from_batch(&kern, &seed, true).unwrap();
        for i in 6..ds.n() {
            inc.push_with(ds.x.row(i), &engine).unwrap();
        }
        let drift = inc.reconstruct().max_abs_diff(&inc.batch_reference());
        assert!(drift < 1e-6, "pjrt-engine drift {drift}");
    }

    #[test]
    fn pjrt_nystrom_reconstruct_matches_native() {
        let Some(rt) = runtime() else { return };
        let ds = crate::data::synthetic::yeast_like(60, 5);
        let kern = Rbf { sigma: 1.0 };
        let mut inys = crate::nystrom::IncrementalNystrom::new(&kern, ds.x.clone()).unwrap();
        for m in 0..12 {
            inys.add_point(m).unwrap();
        }
        let native = inys.approx_gram();
        let got = rt
            .nystrom_reconstruct(&inys.knm(), &inys.inc.vecs.to_mat(), &inys.inc.vals)
            .unwrap();
        assert!(got.max_abs_diff(&native) < 1e-7, "diff {}", got.max_abs_diff(&native));
    }
}
