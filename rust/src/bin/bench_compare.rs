//! Perf regression gate: compare the `BENCH_*.json` files of two runs
//! (the current workspace vs the previous CI run's uploaded artifacts)
//! and fail when any case regressed beyond the tolerance.
//!
//! A case only counts as regressed when **both** its median and its
//! minimum moved past the tolerance: scheduler noise on shared CI
//! runners (the e2e benches spawn 8+ threads on 2 vCPUs) routinely
//! inflates the median of a single run, but a genuine slowdown shifts
//! the whole distribution — including the best-case sample — so
//! requiring the min to agree keeps the gate meaningful without going
//! red on noisy-neighbor variance.
//!
//! The JSON is the hand-rolled array `util::bench::Bench::write_json`
//! emits — one object per line with `"name"`, `"median_ns"` and
//! `"min_ns"` fields — so the parser here is a line scanner, not a JSON
//! library (the image is offline; no serde).
//!
//! Usage: `bench_compare --old <dir> --new <dir> [--tolerance 0.20]`
//!
//! Exit codes: 0 = no regressions (or no previous run to compare
//! against — the first run of a fresh pipeline must pass), 1 = at least
//! one case regressed, 2 = usage error.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// `name → (median_ns, min_ns)` for one BENCH_*.json file.
type Cases = BTreeMap<String, (f64, f64)>;

/// Extract the string value following `"key": "` on a line.
fn str_field(line: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\": \"");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

/// Extract the numeric value following `"key": ` on a line.
fn num_field(line: &str, key: &str) -> Option<f64> {
    let tag = format!("\"{key}\": ");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == '+'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn parse_bench_json(path: &Path) -> std::io::Result<Cases> {
    let text = std::fs::read_to_string(path)?;
    let mut cases = Cases::new();
    for line in text.lines() {
        if let (Some(name), Some(median), Some(min)) = (
            str_field(line, "name"),
            num_field(line, "median_ns"),
            num_field(line, "min_ns"),
        ) {
            cases.insert(name, (median, min));
        }
    }
    Ok(cases)
}

/// All BENCH_*.json files directly inside `dir`, keyed by file name.
fn bench_files(dir: &Path) -> Vec<(String, PathBuf)> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return out,
    };
    for entry in entries.flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            out.push((name, entry.path()));
        }
    }
    out.sort();
    out
}

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn fmt_ms(ns: f64) -> String {
    format!("{:.3}", ns / 1e6)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (old_dir, new_dir) = match (flag_value(&args, "--old"), flag_value(&args, "--new")) {
        (Some(o), Some(n)) => (PathBuf::from(o), PathBuf::from(n)),
        _ => {
            eprintln!("usage: bench_compare --old <dir> --new <dir> [--tolerance 0.20]");
            std::process::exit(2);
        }
    };
    let tolerance: f64 = flag_value(&args, "--tolerance")
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.20);

    let old_files: BTreeMap<String, PathBuf> = bench_files(&old_dir).into_iter().collect();
    if old_files.is_empty() {
        println!(
            "bench_compare: no previous BENCH_*.json under {} — nothing to gate (first run?)",
            old_dir.display()
        );
        return;
    }

    let mut regressions = Vec::new();
    let mut compared = 0usize;
    // Coverage deltas are reported, not silently skipped: a case
    // present only in the new run has no baseline yet (it joins the
    // gate on the next comparison), and a case that vanished from the
    // new run is a bench that was renamed or deleted — either way the
    // operator should see it in the log, or the gate quietly narrows.
    let mut new_only = 0usize;
    let mut vanished = 0usize;
    let new_files: BTreeMap<String, PathBuf> = bench_files(&new_dir).into_iter().collect();
    for file in old_files.keys() {
        if !new_files.contains_key(file) {
            vanished += 1;
            println!("bench_compare: {file}: baseline file absent from current run");
        }
    }
    for (file, new_path) in &new_files {
        let Some(old_path) = old_files.get(file) else {
            new_only += 1;
            println!("bench_compare: {file}: new bench file (no baseline yet)");
            continue;
        };
        let old = match parse_bench_json(old_path) {
            Ok(c) => c,
            Err(e) => {
                println!("bench_compare: {file}: unreadable baseline ({e}) — skipped");
                continue;
            }
        };
        let new = match parse_bench_json(new_path) {
            Ok(c) => c,
            Err(e) => {
                println!("bench_compare: {file}: unreadable current run ({e}) — skipped");
                continue;
            }
        };
        for case in old.keys() {
            if !new.contains_key(case) {
                vanished += 1;
                println!(
                    "bench_compare: {file} :: {case}: baseline case absent from current run \
                     (renamed or deleted bench?)"
                );
            }
        }
        for (case, (new_median, new_min)) in &new {
            let Some((old_median, old_min)) = old.get(case) else {
                new_only += 1;
                println!("bench_compare: {file} :: {case}: new case (no baseline yet)");
                continue;
            };
            compared += 1;
            let ratio = |new: f64, old: f64| if old > 0.0 { new / old } else { 1.0 };
            let med_ratio = ratio(*new_median, *old_median);
            let min_ratio = ratio(*new_min, *old_min);
            // Both the median and the best-case sample must move past
            // the tolerance — single-run medians of threaded benches on
            // shared runners are too noisy to gate on alone.
            let verdict = if med_ratio > 1.0 + tolerance && min_ratio > 1.0 + tolerance {
                regressions.push(format!(
                    "{file} :: {case}: median {} ms → {} ms ({:+.1}%), min {:+.1}%",
                    fmt_ms(*old_median),
                    fmt_ms(*new_median),
                    (med_ratio - 1.0) * 100.0,
                    (min_ratio - 1.0) * 100.0
                ));
                "REGRESSED"
            } else if med_ratio < 1.0 - tolerance && min_ratio < 1.0 - tolerance {
                "improved"
            } else {
                "ok"
            };
            println!(
                "bench_compare: {file} :: {case}: median {} ms → {} ms  [{verdict}]",
                fmt_ms(*old_median),
                fmt_ms(*new_median)
            );
        }
    }

    println!(
        "bench_compare: {compared} case(s) compared, {} regression(s) beyond {:.0}%, \
         {} new (ungated this run), {} vanished from baseline",
        regressions.len(),
        tolerance * 100.0,
        new_only,
        vanished
    );
    if !regressions.is_empty() {
        for r in &regressions {
            eprintln!("REGRESSION: {r}");
        }
        std::process::exit(1);
    }
}
