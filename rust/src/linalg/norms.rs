//! The three matrix norms the paper's experiments report (§5, Figures
//! 1–2): Frobenius, spectral (operator 2-norm) and trace (nuclear) norm.
//! For *symmetric* arguments — which is all the experiments need, since
//! both `K' − UΛUᵀ` and `K − K̃` are symmetric — spectral and trace
//! norms reduce to `max|λᵢ|` and `Σ|λᵢ|`.

use super::eigh::eigvalsh;
use super::gemm::gemv;
use super::matrix::{norm2, Mat};

/// Bundle of the three norms reported in Figures 1 and 2.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Norms {
    pub frobenius: f64,
    pub spectral: f64,
    pub trace: f64,
}

/// Frobenius norm of any matrix.
pub fn frobenius(a: &Mat) -> f64 {
    a.as_slice().iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Spectral norm of a *symmetric* matrix via power iteration with a
/// deterministic start; falls back to the exact eigenvalue computation
/// when convergence stalls (near-degenerate leading pair).
pub fn spectral_sym(a: &Mat) -> f64 {
    assert!(a.is_square());
    let n = a.rows();
    if n == 0 {
        return 0.0;
    }
    // Power iteration on A² (so the sign of the extreme eigenvalue does
    // not matter) is implicit: we track |λ| through consecutive applies.
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
    let nv = norm2(&v);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut lambda = 0.0;
    for it in 0..200 {
        let w = gemv(a, &v);
        let nw = norm2(&w);
        if nw == 0.0 {
            return 0.0;
        }
        let new_lambda = nw;
        v = w.iter().map(|x| x / nw).collect();
        if it > 4 && (new_lambda - lambda).abs() <= 1e-12 * new_lambda.max(1e-300) {
            return new_lambda;
        }
        lambda = new_lambda;
    }
    // Slow convergence — do it exactly.
    match eigvalsh(a) {
        Ok(vals) => vals.iter().fold(0.0_f64, |m, v| m.max(v.abs())),
        Err(_) => lambda,
    }
}

/// Trace (nuclear) norm of a *symmetric* matrix: `Σ|λᵢ|`.
pub fn trace_sym(a: &Mat) -> f64 {
    match eigvalsh(a) {
        Ok(vals) => vals.iter().map(|v| v.abs()).sum(),
        Err(_) => f64::NAN,
    }
}

/// All three norms of a symmetric matrix, sharing one eigenvalue sweep
/// for spectral + trace.
pub fn sym_norms(a: &Mat) -> Norms {
    let fro = frobenius(a);
    match eigvalsh(a) {
        Ok(vals) => Norms {
            frobenius: fro,
            spectral: vals.iter().fold(0.0_f64, |m, v| m.max(v.abs())),
            trace: vals.iter().map(|v| v.abs()).sum(),
        },
        Err(_) => Norms { frobenius: fro, spectral: f64::NAN, trace: f64::NAN },
    }
}

/// Norms of a *positive semi-definite* symmetric matrix in `O(n²)`:
/// trace norm = trace (all eigenvalues ≥ 0), spectral via pure power
/// iteration (no `O(n³)` fallback — for PSD the iterate estimate is a
/// valid lower bound that converges from below). Used for the Nyström
/// residual `K − K̃`, which is the Schur complement of `K_{m,m}` in `K`
/// and hence PSD.
pub fn psd_norms(a: &Mat) -> Norms {
    assert!(a.is_square());
    let n = a.rows();
    let fro = frobenius(a);
    let trace: f64 = (0..n).map(|i| a[(i, i)]).sum();
    // Power iteration (deterministic start), no exact fallback.
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
    let nv = norm2(&v);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut lambda = 0.0;
    for it in 0..500 {
        let w = gemv(a, &v);
        let nw = norm2(&w);
        if nw == 0.0 {
            lambda = 0.0;
            break;
        }
        v = w.iter().map(|x| x / nw).collect();
        if it > 8 && (nw - lambda).abs() <= 1e-10 * nw.max(1e-300) {
            lambda = nw;
            break;
        }
        lambda = nw;
    }
    Norms { frobenius: fro, spectral: lambda, trace }
}

/// `‖UUᵀ − I‖_F` — the orthogonality-loss diagnostic from §5.1.
/// Accepts anything viewable as a matrix (`&Mat`, `MatView`,
/// `&rankone::EigenBasis`).
pub fn orthogonality_defect<'a>(u: impl Into<super::view::MatView<'a>>) -> f64 {
    let u = u.into();
    let uut = super::gemm::matmul_nt(u, u);
    let n = uut.rows();
    let mut s = 0.0;
    for i in 0..n {
        for j in 0..n {
            let d = uut[(i, j)] - if i == j { 1.0 } else { 0.0 };
            s += d * d;
        }
    }
    s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frobenius_known() {
        let a = Mat::from_vec(2, 2, vec![3.0, 0.0, 4.0, 0.0]);
        assert!((frobenius(&a) - 5.0).abs() < 1e-14);
    }

    #[test]
    fn norms_of_diagonal() {
        let a = Mat::from_diag(&[3.0, -4.0, 1.0]);
        let n = sym_norms(&a);
        assert!((n.spectral - 4.0).abs() < 1e-12);
        assert!((n.trace - 8.0).abs() < 1e-12);
        assert!((n.frobenius - (9.0f64 + 16.0 + 1.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn spectral_power_matches_exact() {
        let mut a = Mat::from_fn(8, 8, |i, j| ((i * 5 + j * 3) % 7) as f64 - 3.0);
        a.symmetrize();
        let exact = {
            let vals = eigvalsh(&a).unwrap();
            vals.iter().fold(0.0_f64, |m, v| m.max(v.abs()))
        };
        assert!((spectral_sym(&a) - exact).abs() < 1e-8 * exact.max(1.0));
    }

    #[test]
    fn norm_inequalities_hold() {
        // spectral ≤ frobenius ≤ trace for symmetric matrices.
        let mut a = Mat::from_fn(10, 10, |i, j| ((i as f64) - (j as f64) * 0.5).sin());
        a.symmetrize();
        let n = sym_norms(&a);
        assert!(n.spectral <= n.frobenius + 1e-10);
        assert!(n.frobenius <= n.trace + 1e-10);
    }

    #[test]
    fn orthogonality_defect_zero_for_orthogonal() {
        assert!(orthogonality_defect(&Mat::eye(5)) < 1e-15);
        // Rotation matrix.
        let th = 0.3_f64;
        let r = Mat::from_vec(2, 2, vec![th.cos(), -th.sin(), th.sin(), th.cos()]);
        assert!(orthogonality_defect(&r) < 1e-14);
    }

    #[test]
    fn psd_norms_match_exact_on_psd() {
        // Gram matrix is PSD; psd_norms must agree with sym_norms.
        let x = Mat::from_fn(12, 5, |i, j| ((i * 3 + j) as f64 * 0.7).sin());
        let g = crate::linalg::gemm::syrk(&x);
        let fast = psd_norms(&g);
        let exact = sym_norms(&g);
        assert!((fast.frobenius - exact.frobenius).abs() < 1e-10);
        assert!((fast.trace - exact.trace).abs() < 1e-9 * exact.trace.max(1.0));
        assert!((fast.spectral - exact.spectral).abs() < 1e-6 * exact.spectral.max(1.0));
    }

    #[test]
    fn zero_matrix_norms() {
        let z = Mat::zeros(4, 4);
        let n = sym_norms(&z);
        assert_eq!(n.frobenius, 0.0);
        assert!(n.spectral.abs() < 1e-14);
        assert!(n.trace.abs() < 1e-14);
    }
}
