//! Implicit-shift QL eigensolver for symmetric tridiagonal matrices
//! (`tqli`; Bowdler, Martin, Reinsch & Wilkinson 1968), the second phase
//! of the batch symmetric eigensolver. Rotations are accumulated into a
//! caller-supplied matrix so the same routine serves both
//! eigenvalues-only and full-decomposition uses.

use super::matrix::Mat;

/// Maximum QL iterations per eigenvalue before declaring failure.
const MAX_ITER: usize = 64;

/// `hypot`-style stable `sqrt(a² + b²)`.
#[inline]
fn pythag(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

/// Diagonalize the symmetric tridiagonal matrix with diagonal `d` and
/// sub-diagonal `e` (`e[i]` couples rows `i-1`, `i`; `e[0]` ignored).
///
/// On return `d` holds the (unsorted) eigenvalues and `z`'s columns have
/// been rotated: if `z` entered as `Q` from `tridiagonalize`, its columns
/// exit as the eigenvectors of the original full matrix; pass
/// `Mat::eye(n)` to get the tridiagonal's own eigenvectors.
pub fn tridiag_eig(d: &mut [f64], e: &mut [f64], z: &mut Mat) -> Result<(), String> {
    let n = d.len();
    assert_eq!(e.len(), n);
    // A 0-row `z` requests eigenvalues only (no rotation accumulation).
    assert!(z.rows() == n || z.rows() == 0);
    if n == 0 {
        return Ok(());
    }
    // Shift the sub-diagonal down for convenient indexing: e[i] now
    // couples i and i+1.
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Look for a negligible off-diagonal to split the problem.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > MAX_ITER {
                return Err(format!("tridiag_eig: no convergence at index {l}"));
            }
            // Wilkinson-style shift from the leading 2x2.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = pythag(g, 1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = pythag(f, g);
                e[i + 1] = r;
                if r == 0.0 {
                    // Recover from underflow: annihilate the small
                    // element and restart this eigenvalue.
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate the rotation into the eigenvector matrix.
                for k in 0..z.rows() {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

/// Sort eigenpairs ascending by eigenvalue, permuting columns of `z`
/// accordingly.
pub fn sort_eigenpairs(d: &mut [f64], z: &mut Mat) {
    let n = d.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let d_old = d.to_vec();
    let z_old = z.clone();
    for (newj, &oldj) in idx.iter().enumerate() {
        d[newj] = d_old[oldj];
        for i in 0..z.rows() {
            z[(i, newj)] = z_old[(i, oldj)];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_is_fixed_point() {
        let mut d = vec![3.0, 1.0, 2.0];
        let mut e = vec![0.0; 3];
        let mut z = Mat::eye(3);
        tridiag_eig(&mut d, &mut e, &mut z).unwrap();
        sort_eigenpairs(&mut d, &mut z);
        assert!((d[0] - 1.0).abs() < 1e-14);
        assert!((d[2] - 3.0).abs() < 1e-14);
    }

    #[test]
    fn two_by_two_closed_form() {
        // [[2, 1], [1, 2]] has eigenvalues 1 and 3.
        let mut d = vec![2.0, 2.0];
        let mut e = vec![0.0, 1.0];
        let mut z = Mat::eye(2);
        tridiag_eig(&mut d, &mut e, &mut z).unwrap();
        sort_eigenpairs(&mut d, &mut z);
        assert!((d[0] - 1.0).abs() < 1e-13);
        assert!((d[1] - 3.0).abs() < 1e-13);
    }

    #[test]
    fn laplacian_chain_known_spectrum() {
        // 1-D discrete Laplacian: eigenvalues 2 - 2 cos(kπ/(n+1)).
        let n = 12;
        let mut d = vec![2.0; n];
        let mut e = vec![-1.0; n];
        e[0] = 0.0;
        let mut z = Mat::eye(n);
        tridiag_eig(&mut d, &mut e, &mut z).unwrap();
        sort_eigenpairs(&mut d, &mut z);
        for k in 1..=n {
            let expect = 2.0 - 2.0 * (k as f64 * std::f64::consts::PI / (n as f64 + 1.0)).cos();
            assert!((d[k - 1] - expect).abs() < 1e-12, "k={k}");
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let n = 9;
        let mut d: Vec<f64> = (0..n).map(|i| (i as f64).sin() + 2.0).collect();
        let mut e: Vec<f64> = (0..n).map(|i| 0.3 * (i as f64 + 1.0).cos()).collect();
        e[0] = 0.0;
        let mut z = Mat::eye(n);
        tridiag_eig(&mut d, &mut e, &mut z).unwrap();
        let ztz = crate::linalg::gemm::matmul(&z.transpose(), &z);
        assert!(ztz.max_abs_diff(&Mat::eye(n)) < 1e-12);
    }
}
