//! Lock-free read path: epoch-published projection snapshots.
//!
//! Every `project` verb used to be an RPC through the owning shard
//! worker's FIFO — reads serialized against ingests, so read throughput
//! scaled with *shard count*, not cores. This module decouples them:
//! the worker periodically captures an immutable [`ProjectionSnapshot`]
//! of the stream's engine and publishes it through a
//! [`SnapshotCell`] — a hand-rolled arc-swap: an `AtomicU64` epoch next
//! to a rarely-written `RwLock<Arc<ProjectionSnapshot>>`. Readers that
//! keep a [`ProjectScratch`] cache the `Arc` keyed by (cell, epoch), so
//! the steady-state read is one atomic epoch load + an `Arc` clone —
//! no lock, no queue, no worker involvement at all.
//!
//! Since the engine-tier seam ([`super::engine`]) a snapshot is
//! tier-shaped: the **exact** kind carries the top-r basis copy,
//! eigenvalues, cached centering sums and retained landmark data (the
//! O(m·r) kernel-space projection); the **rff** kind carries the
//! (cheaply cloned) random-feature map, the running feature mean and
//! the sketch basis (the O(D·r) feature-space projection). Both kinds
//! serve the same `project`/`project_many_into` surface, so the router
//! read path is tier-blind. Construction goes through
//! [`super::engine::StreamState::capture`] — this module knows no
//! concrete engine type.
//!
//! # Freshness contract
//!
//! Snapshot reads may lag the eigensystem by up to
//! [`super::StreamConfig::publish_every`] accepted points (the worker
//! also publishes on every `sync`, every `ingest_many` flush, and at
//! seed completion). `sync` + read gives read-your-writes: the sync
//! barrier publishes before replying, so a snapshot read issued after
//! a successful `sync` observes at least everything enqueued before it.
//! The staleness is observable: `StreamGauges::points_since_publish`
//! counts accepted points not yet captured, and `snapshot_epoch` is
//! monotonic (it survives migration — the cell travels with the stream
//! entry, and publishes serialize through the single owning worker).
//!
//! # Batched projection
//!
//! [`ProjectionSnapshot::project_many_into`] scores `b` queries in one
//! pass. Exact kind: the b×m kernel block via
//! [`crate::kernels::kernel_rows_into`] (one GEMM + entry map for
//! dot-product/distance kernels), then ONE (b×m)·(m×r) GEMM against the
//! captured basis; mean-adjusted centering folds into a per-entry
//! correction using the captured per-component sums `uᵀK𝟙` and `uᵀ𝟙` —
//! algebraically identical to the worker path without ever
//! materializing a centered column. Rff kind: the b×D feature block
//! (one `Y·Ωᵀ` GEMM + cosine map), mean-centered, then ONE (b×D)·(D×r)
//! GEMM against the sketch basis — no 1/√λ rescaling (see
//! [`crate::rff`] for the Gram/covariance bridge).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::kernels::{kernel_rows_into, Kernel, KernelBlockScratch};
use crate::linalg::{matmul_into_buf, MatView, MatViewMut};
use crate::rankone::ensure_f64;
use crate::rff::RffMap;

/// Tier-specific payload of a snapshot. Private: readers only see the
/// uniform projection surface.
enum SnapKind {
    Exact {
        /// Basis copy, `m × r` row-major: `basis[j·r + c]` is component
        /// `c`'s weight on retained example `j` (columns reordered so
        /// the top component is column 0, unlike the ascending live
        /// basis).
        basis: Vec<f64>,
        /// Per-component `uᵀ(K𝟙)` over the captured row sums (empty
        /// when unadjusted).
        uk1: Vec<f64>,
        /// Per-component `uᵀ𝟙` (empty when unadjusted).
        u1: Vec<f64>,
        /// `Σₘ = 𝟙ᵀKₘ𝟙` at capture.
        s: f64,
        /// Retained landmark data, `m × dim` row-major.
        x: Vec<f64>,
        kernel: Arc<dyn Kernel>,
    },
    Rff {
        /// The seeded feature map (ω/b tables behind `Arc`s — cloning
        /// into the snapshot is O(1)).
        map: RffMap,
        /// Running feature mean at capture (`features` long; zeros
        /// when unadjusted).
        mu: Vec<f64>,
        /// Sketch basis copy, `features × r` row-major (columns = unit
        /// right singular vectors, top first).
        basis: Vec<f64>,
    },
}

/// Everything [`super::engine::capture_exact`] hands over to build an
/// exact-kind snapshot. Crate-internal: the capture loop lives at the
/// engine seam, the memory layout lives here.
pub(crate) struct ExactSnapshotParts {
    pub m: usize,
    pub dim: usize,
    pub mean_adjust: bool,
    pub r: usize,
    /// Eigenvalues, DESCENDING, length `r`.
    pub vals: Vec<f64>,
    /// `m × r` row-major basis, top component first.
    pub basis: Vec<f64>,
    pub uk1: Vec<f64>,
    pub u1: Vec<f64>,
    pub s: f64,
    pub x: Vec<f64>,
    pub kernel: Arc<dyn Kernel>,
}

/// Immutable point-in-time copy of everything a projection needs,
/// published by the owning shard worker, shared read-only by any number
/// of reader threads. The captured fields are mutually consistent —
/// they were captured atomically (the worker owns the engine
/// exclusively between commands).
pub struct ProjectionSnapshot {
    /// Publication counter (1-based; assigned by [`SnapshotCell`]).
    epoch: u64,
    /// Points in the engine at capture (landmarks for the exact tier,
    /// absorbed points for the sketch).
    m: usize,
    dim: usize,
    mean_adjust: bool,
    /// Components captured (`min(snapshot_r, available)`; everything
    /// available when the config leaves `snapshot_r` at 0).
    r: usize,
    /// Eigenvalue estimates, DESCENDING (index 0 = top component),
    /// length `r`.
    vals: Vec<f64>,
    kind: SnapKind,
}

impl ProjectionSnapshot {
    /// Assemble an exact-kind snapshot (see
    /// [`super::engine::capture_exact`] for the capture loop).
    pub(crate) fn from_exact(p: ExactSnapshotParts) -> ProjectionSnapshot {
        ProjectionSnapshot {
            epoch: 0, // assigned by SnapshotCell::publish
            m: p.m,
            dim: p.dim,
            mean_adjust: p.mean_adjust,
            r: p.r,
            vals: p.vals,
            kind: SnapKind::Exact {
                basis: p.basis,
                uk1: p.uk1,
                u1: p.u1,
                s: p.s,
                x: p.x,
                kernel: p.kernel,
            },
        }
    }

    /// Assemble an rff-kind snapshot from the sketch's
    /// [`crate::rff::RffKpca::snapshot_parts`]: `basis` is
    /// `features × r` row-major with `r = vals.len()`.
    pub(crate) fn from_rff(
        map: RffMap,
        mu: Vec<f64>,
        basis: Vec<f64>,
        vals: Vec<f64>,
        m: usize,
        dim: usize,
        mean_adjust: bool,
    ) -> ProjectionSnapshot {
        let r = vals.len();
        debug_assert_eq!(basis.len(), map.features() * r);
        ProjectionSnapshot {
            epoch: 0,
            m,
            dim,
            mean_adjust,
            r,
            vals,
            kind: SnapKind::Rff { map, mu, basis },
        }
    }

    /// Publication epoch (1-based, monotonic per stream).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Engine size at capture.
    pub fn m(&self) -> usize {
        self.m
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Components available (`project*` clamps `r` to this).
    pub fn components(&self) -> usize {
        self.r
    }

    /// Which tier captured this snapshot (`"exact"` or `"rff"`; a
    /// shadow stream serves exact-kind snapshots).
    pub fn tier_name(&self) -> &'static str {
        match &self.kind {
            SnapKind::Exact { .. } => "exact",
            SnapKind::Rff { .. } => "rff",
        }
    }

    /// Bytes resident in the snapshot's owned buffers.
    pub fn bytes_resident(&self) -> usize {
        let f64s = self.vals.len()
            + match &self.kind {
                SnapKind::Exact { basis, uk1, u1, x, .. } => {
                    basis.len() + uk1.len() + u1.len() + x.len()
                }
                SnapKind::Rff { mu, basis, .. } => mu.len() + basis.len(),
            };
        std::mem::size_of::<f64>() * f64s
    }

    /// Score `b` queries (`ys` is `b × dim` row-major) on the top
    /// `min(r, components)` captured components into `out` (`b × r_eff`
    /// row-major), reusing `scratch` so the warm path never allocates.
    /// Returns the number of query rows scored.
    ///
    /// Exact-kind scores match the worker-side projection to ≤1e-12:
    /// same centering, same `λ ≤ 1e-12 → 0` guard, only the
    /// floating-point summation order differs (blocked GEMM vs scalar
    /// loop). Rff-kind scores likewise match the sketch engine's
    /// worker-path projection.
    pub fn project_many_into(
        &self,
        ys: &[f64],
        r: usize,
        scratch: &mut ProjectScratch,
        out: &mut Vec<f64>,
    ) -> Result<usize, String> {
        if self.dim == 0 || ys.len() % self.dim != 0 {
            return Err(format!(
                "query length {} is not a multiple of dim {}",
                ys.len(),
                self.dim
            ));
        }
        let b = ys.len() / self.dim;
        let r_eff = r.min(self.r);
        ensure_f64(out, b * r_eff, &mut scratch.out_reallocs);
        if b == 0 || r_eff == 0 {
            return Ok(b);
        }
        match &self.kind {
            SnapKind::Exact { basis, uk1, u1, s, x, kernel } => {
                // b×m kernel block (blocked GEMM form for
                // dot-product/distance kernels, scalar fallback
                // otherwise).
                kernel_rows_into(
                    kernel.as_ref(),
                    x,
                    self.dim,
                    self.m,
                    ys,
                    b,
                    &mut scratch.block,
                    &mut scratch.kernel,
                );
                // One GEMM against the leading r_eff basis columns
                // (stride r exposes the prefix without a copy).
                let block = MatView::of_rows(&scratch.block, b, self.m);
                let basis_v = MatView::new(basis, self.m, r_eff, self.r);
                let mut out_view = MatViewMut::new(out, b, r_eff, r_eff);
                matmul_into_buf(block, basis_v, &mut out_view, &mut scratch.pack);
                // Fold centering + 1/√λ scaling into one per-entry
                // pass. The centered column is
                // k_y + (Σ/m² − mean(k_y))·𝟙 − K𝟙/m, so its dot with u
                // is the raw GEMM entry plus the captured
                // per-component corrections.
                let mf = self.m as f64;
                let total_mean = if self.mean_adjust { s / (mf * mf) } else { 0.0 };
                for i in 0..b {
                    let adjust = if self.mean_adjust {
                        let row = &scratch.block[i * self.m..(i + 1) * self.m];
                        let ky_mean = row.iter().sum::<f64>() / mf;
                        total_mean - ky_mean
                    } else {
                        0.0
                    };
                    let o = &mut out[i * r_eff..(i + 1) * r_eff];
                    for c in 0..r_eff {
                        let lam = self.vals[c];
                        if lam <= 1e-12 {
                            o[c] = 0.0;
                            continue;
                        }
                        let mut dot = o[c];
                        if self.mean_adjust {
                            dot += adjust * u1[c] - uk1[c] / mf;
                        }
                        o[c] = dot / lam.sqrt();
                    }
                }
            }
            SnapKind::Rff { map, mu, basis } => {
                // b×D feature block: one Y·Ωᵀ GEMM + the cosine map.
                map.map_block_into(ys, b, &mut scratch.feat, &mut scratch.pack);
                let d = map.features();
                if self.mean_adjust {
                    for i in 0..b {
                        let row = &mut scratch.feat[i * d..(i + 1) * d];
                        for (v, m) in row.iter_mut().zip(mu) {
                            *v -= m;
                        }
                    }
                }
                // One GEMM against the sketch basis; scores are
                // vₖᵀ(z(y)−μ) directly — no 1/√λ (see crate::rff).
                let block = MatView::of_rows(&scratch.feat, b, d);
                let basis_v = MatView::new(basis, d, r_eff, self.r);
                let mut out_view = MatViewMut::new(out, b, r_eff, r_eff);
                matmul_into_buf(block, basis_v, &mut out_view, &mut scratch.pack);
                // Collapsed components read as 0, same guard as exact.
                for i in 0..b {
                    let o = &mut out[i * r_eff..(i + 1) * r_eff];
                    for c in 0..r_eff {
                        if self.vals[c] <= 1e-12 {
                            o[c] = 0.0;
                        }
                    }
                }
            }
        }
        Ok(b)
    }

    /// Score one query (allocating convenience wrapper).
    pub fn project(&self, y: &[f64], r: usize) -> Result<Vec<f64>, String> {
        if y.len() != self.dim {
            return Err(format!(
                "dimension mismatch: got {}, want {}",
                y.len(),
                self.dim
            ));
        }
        let mut scratch = ProjectScratch::new();
        let mut out = Vec::new();
        self.project_many_into(y, r, &mut scratch, &mut out)?;
        Ok(out)
    }
}

/// The per-stream publication cell: the hand-rolled arc-swap. One lives
/// in every [`super::StreamHandle`] *and* inside the owning worker's
/// stream entry (it migrates with the entry), so readers and the writer
/// share it without going through the router.
///
/// ```text
///            writer (owning shard worker, serialized)
///                    │ publish: write-lock, store Arc, bump epoch
///                    ▼
///   epoch: AtomicU64 ─ slot: RwLock<Option<Arc<ProjectionSnapshot>>>
///                    ▲
///                    │ readers: epoch load (Acquire); on match reuse
///                    │ the Arc cached in their ProjectScratch (no
///                    │ lock), else read-lock + clone + re-cache
/// ```
///
/// The write lock is held only for the two pointer stores; readers take
/// the read lock only on the first read after a publish. Epoch 0 means
/// "never published" (stream still seeding).
pub struct SnapshotCell {
    epoch: AtomicU64,
    slot: RwLock<Option<Arc<ProjectionSnapshot>>>,
    /// Snapshot-path reads served (lock-free counter; surfaces in
    /// `StreamGauges`/`PoolSnapshot` next to `worker_reads`).
    reads: AtomicU64,
    /// Set on close: late readers get an error instead of a stale
    /// snapshot of a stream that no longer exists.
    closed: AtomicBool,
}

impl Default for SnapshotCell {
    fn default() -> Self {
        SnapshotCell::new()
    }
}

impl SnapshotCell {
    pub fn new() -> SnapshotCell {
        SnapshotCell {
            epoch: AtomicU64::new(0),
            slot: RwLock::new(None),
            reads: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        }
    }

    /// Current publication epoch (0 = nothing published yet).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Snapshot-path reads served through this cell.
    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Publish a fresh snapshot under the next epoch. Writer side only
    /// — publishes serialize through the single owning worker (the cell
    /// migrates with the stream entry, so ownership transfer itself
    /// serializes through the shard channels). Returns the epoch.
    pub fn publish(&self, mut snap: ProjectionSnapshot) -> u64 {
        let mut guard = self.slot.write().unwrap_or_else(|e| e.into_inner());
        let epoch = self.epoch.load(Ordering::Relaxed) + 1;
        snap.epoch = epoch;
        *guard = Some(Arc::new(snap));
        // Released before the guard: a reader that sees the new epoch
        // and misses its scratch cache read-locks and finds the new
        // Arc already in place.
        self.epoch.store(epoch, Ordering::Release);
        epoch
    }

    /// Mark the stream closed; subsequent loads error.
    pub fn mark_closed(&self) {
        self.closed.store(true, Ordering::Release);
    }

    /// Load the latest snapshot (read-lock + clone — the scratch-less
    /// path; use [`SnapshotCell::load_cached`] from a read loop).
    pub fn load(&self) -> Result<Arc<ProjectionSnapshot>, String> {
        if self.is_closed() {
            return Err("unknown or closed stream".to_string());
        }
        let guard = self.slot.read().unwrap_or_else(|e| e.into_inner());
        match &*guard {
            Some(snap) => {
                self.reads.fetch_add(1, Ordering::Relaxed);
                Ok(snap.clone())
            }
            None => Err("no snapshot published yet (stream still seeding?)".to_string()),
        }
    }

    /// Load through a per-reader scratch cache: when the epoch matches
    /// the cached `Arc`, the read is one atomic load + one `Arc` clone
    /// — no lock. The cache is keyed by cell identity (`Arc::ptr_eq`),
    /// so one scratch can serve reads against many streams.
    pub fn load_cached(
        self: &Arc<Self>,
        scratch: &mut ProjectScratch,
    ) -> Result<Arc<ProjectionSnapshot>, String> {
        if self.is_closed() {
            return Err("unknown or closed stream".to_string());
        }
        let epoch = self.epoch.load(Ordering::Acquire);
        if epoch != 0 && scratch.cached_epoch == epoch {
            if let (Some(cell), Some(snap)) = (&scratch.cached_cell, &scratch.cached) {
                if Arc::ptr_eq(cell, self) {
                    self.reads.fetch_add(1, Ordering::Relaxed);
                    return Ok(snap.clone());
                }
            }
        }
        let snap = {
            let guard = self.slot.read().unwrap_or_else(|e| e.into_inner());
            match &*guard {
                Some(snap) => snap.clone(),
                None => {
                    return Err(
                        "no snapshot published yet (stream still seeding?)".to_string()
                    )
                }
            }
        };
        scratch.cached_epoch = snap.epoch;
        scratch.cached = Some(snap.clone());
        scratch.cached_cell = Some(self.clone());
        self.reads.fetch_add(1, Ordering::Relaxed);
        Ok(snap)
    }
}

impl std::fmt::Debug for SnapshotCell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SnapshotCell")
            .field("epoch", &self.epoch())
            .field("reads", &self.reads())
            .field("closed", &self.is_closed())
            .finish()
    }
}

/// Per-reader reusable state: the epoch-keyed snapshot cache plus every
/// buffer the batched projection needs. Keep one per reader thread and
/// the steady-state read path performs zero allocations (asserted by
/// [`ProjectScratch::reallocs`] staying flat once warm).
#[derive(Default)]
pub struct ProjectScratch {
    cached_epoch: u64,
    cached_cell: Option<Arc<SnapshotCell>>,
    cached: Option<Arc<ProjectionSnapshot>>,
    /// b×m kernel block (exact-kind snapshots).
    block: Vec<f64>,
    /// b×D feature block (rff-kind snapshots).
    feat: Vec<f64>,
    /// Row-norm scratch of the blocked kernel evaluation.
    kernel: KernelBlockScratch,
    /// Packing panels of the projection GEMMs.
    pack: crate::linalg::PackBuffers,
    /// Growth events on the caller-owned `out` buffer.
    out_reallocs: u64,
}

impl ProjectScratch {
    pub fn new() -> ProjectScratch {
        ProjectScratch::default()
    }

    /// Pre-size for batches of up to `b` queries of `dim`-dimensional
    /// points against an `m`-point snapshot (growths here don't count
    /// toward [`Self::reallocs`]). `dim` sizes the packing panels of
    /// the kernel-block GEMM.
    pub fn reserve(&mut self, m: usize, b: usize, dim: usize) {
        if self.block.capacity() < m * b {
            self.block.reserve(m * b - self.block.len());
        }
        self.kernel.reserve(m, b, dim);
        // Projection GEMM: the b×m kernel block against the m×r basis
        // prefix (r ≤ m).
        self.pack.reserve(b, m, m);
    }

    /// Buffer-growth events since construction across the kernel block,
    /// the row-norm scratch, the GEMM packing panels and the caller's
    /// `out` buffers — zero once warm (the zero-alloc gauge of the read
    /// path).
    pub fn reallocs(&self) -> u64 {
        self.kernel.reallocs() + self.pack.reallocs() + self.out_reallocs
    }

    /// Bytes resident in the scratch buffers (cached snapshot excluded
    /// — it is shared, not per-reader).
    pub fn bytes_resident(&self) -> usize {
        std::mem::size_of::<f64>() * (self.block.capacity() + self.feat.capacity())
            + self.kernel.bytes_resident()
            + self.pack.bytes_resident()
    }

    /// Epoch of the cached snapshot (0 = nothing cached).
    pub fn cached_epoch(&self) -> u64 {
        self.cached_epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::capture_exact;
    use crate::data::synthetic::yeast_like;
    use crate::kernels::{Linear, Polynomial, Rbf};
    use crate::kpca::IncrementalKpca;
    use crate::linalg::Mat;
    use crate::rff::RffKpca;

    fn streamed_state(
        kernel: Arc<dyn Kernel>,
        n: usize,
        seed: usize,
        adjust: bool,
    ) -> (IncrementalKpca<'static>, Mat) {
        let ds = yeast_like(n, 7);
        let seed_m = ds.x.submatrix(seed, ds.dim());
        let mut st = IncrementalKpca::from_batch_shared(kernel, &seed_m, adjust).unwrap();
        for i in seed..n {
            st.push(ds.x.row(i)).unwrap();
        }
        (st, ds.x)
    }

    #[test]
    fn snapshot_matches_worker_projection() {
        let kernels: Vec<Arc<dyn Kernel>> = vec![
            Arc::new(Rbf { sigma: 1.3 }),
            Arc::new(Linear),
            Arc::new(Polynomial { degree: 3, offset: 1.0 }),
        ];
        for kernel in kernels {
            for adjust in [true, false] {
                let (st, x) = streamed_state(kernel.clone(), 20, 8, adjust);
                let cell = Arc::new(SnapshotCell::new());
                cell.publish(capture_exact(&st, 0).unwrap());
                let snap = cell.load().unwrap();
                assert_eq!(snap.m(), st.len());
                assert_eq!(snap.tier_name(), "exact");
                for probe_row in [0usize, 5, 19] {
                    let y = x.row(probe_row);
                    let want = st.project(y, 6);
                    let got = snap.project(y, 6).unwrap();
                    assert_eq!(want.len(), got.len());
                    for (a, b) in want.iter().zip(&got) {
                        assert!(
                            (a - b).abs() < 1e-12,
                            "{} adjust={adjust}: worker {a} vs snapshot {b}",
                            kernel.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn batched_projection_matches_per_point() {
        let kernel: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.1 });
        let (st, x) = streamed_state(kernel, 18, 6, true);
        let snap_raw = capture_exact(&st, 0).unwrap();
        let cell = Arc::new(SnapshotCell::new());
        cell.publish(snap_raw);
        let snap = cell.load().unwrap();
        let b = 7;
        let ys: Vec<f64> =
            (0..b).flat_map(|i| x.row(i).iter().copied().collect::<Vec<_>>()).collect();
        let mut scratch = ProjectScratch::new();
        let mut out = Vec::new();
        let rows = snap.project_many_into(&ys, 4, &mut scratch, &mut out).unwrap();
        assert_eq!(rows, b);
        assert_eq!(out.len(), b * 4);
        for i in 0..b {
            let single = snap.project(x.row(i), 4).unwrap();
            for c in 0..4 {
                assert!(
                    (out[i * 4 + c] - single[c]).abs() < 1e-13,
                    "row {i} comp {c}: batch {} vs single {}",
                    out[i * 4 + c],
                    single[c]
                );
            }
        }
    }

    #[test]
    fn rff_snapshot_matches_engine_projection() {
        // The sketched tier's snapshot must serve the same scores as
        // its worker-path projection — the rff analogue of
        // `snapshot_matches_worker_projection`.
        let ds = yeast_like(60, 7);
        let dim = ds.dim();
        let mut st = RffKpca::new(dim, 64, 6, 1.5, 99, true).unwrap();
        for i in 0..60 {
            st.push(ds.x.row(i)).unwrap();
        }
        let (map, mu, basis, vals) = st.snapshot_parts(0).unwrap();
        let snap = ProjectionSnapshot::from_rff(map, mu, basis, vals, st.len(), dim, true);
        assert_eq!(snap.tier_name(), "rff");
        assert_eq!(snap.m(), 60);
        let cell = Arc::new(SnapshotCell::new());
        cell.publish(snap);
        let snap = cell.load().unwrap();
        let mut scratch = ProjectScratch::new();
        let mut out = Vec::new();
        let b = 5;
        let ys: Vec<f64> =
            (0..b).flat_map(|i| ds.x.row(i).iter().copied().collect::<Vec<_>>()).collect();
        snap.project_many_into(&ys, 4, &mut scratch, &mut out).unwrap();
        for i in 0..b {
            let want = st.project(ds.x.row(i), 4);
            for c in 0..want.len() {
                assert!(
                    (out[i * 4 + c] - want[c]).abs() < 1e-12,
                    "row {i} comp {c}: snapshot {} vs engine {}",
                    out[i * 4 + c],
                    want[c]
                );
            }
        }
    }

    #[test]
    fn top_r_capture_is_a_prefix_of_full_capture() {
        let kernel: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.0 });
        let (st, x) = streamed_state(kernel, 16, 6, true);
        let full = capture_exact(&st, 0).unwrap();
        let top3 = capture_exact(&st, 3).unwrap();
        assert_eq!(top3.components(), 3);
        let y = x.row(2);
        let a = full.project(y, 3).unwrap();
        let b = top3.project(y, 10).unwrap(); // clamped to 3
        assert_eq!(b.len(), 3);
        for (p, q) in a.iter().zip(&b) {
            assert!((p - q).abs() < 1e-13);
        }
    }

    #[test]
    fn steady_state_reads_are_zero_realloc() {
        let kernel: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.2 });
        let (st, x) = streamed_state(kernel, 20, 8, true);
        let cell = Arc::new(SnapshotCell::new());
        cell.publish(capture_exact(&st, 0).unwrap());
        let mut scratch = ProjectScratch::new();
        let mut out = Vec::new();
        let ys: Vec<f64> =
            (0..5).flat_map(|i| x.row(i).iter().copied().collect::<Vec<_>>()).collect();
        // Warm-up pass allocates; every pass after must not.
        let snap = cell.load_cached(&mut scratch).unwrap();
        snap.project_many_into(&ys, 5, &mut scratch, &mut out).unwrap();
        let warm = scratch.reallocs();
        for _ in 0..50 {
            let snap = cell.load_cached(&mut scratch).unwrap();
            snap.project_many_into(&ys, 5, &mut scratch, &mut out).unwrap();
        }
        assert_eq!(scratch.reallocs(), warm, "warm read path must not grow buffers");
    }

    #[test]
    fn cell_epoch_read_counters_and_close() {
        let kernel: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.0 });
        let (st, x) = streamed_state(kernel, 14, 6, false);
        let cell = Arc::new(SnapshotCell::new());
        assert_eq!(cell.epoch(), 0);
        assert!(cell.load().is_err(), "unpublished cell must error, not panic");
        assert_eq!(cell.publish(capture_exact(&st, 0).unwrap()), 1);
        assert_eq!(cell.publish(capture_exact(&st, 0).unwrap()), 2);
        assert_eq!(cell.epoch(), 2);
        let mut scratch = ProjectScratch::new();
        let before = cell.reads();
        cell.load_cached(&mut scratch).unwrap();
        cell.load_cached(&mut scratch).unwrap(); // cached hit
        assert_eq!(cell.reads(), before + 2);
        assert_eq!(scratch.cached_epoch(), 2);
        let snap = cell.load().unwrap();
        assert_eq!(snap.epoch(), 2);
        assert!(snap.project(x.row(0), 3).is_ok());
        cell.mark_closed();
        assert!(cell.load().is_err());
        assert!(cell.load_cached(&mut scratch).is_err());
    }

    #[test]
    fn scratch_cache_is_keyed_by_cell_identity() {
        // Two streams whose cells happen to share an epoch: a scratch
        // bouncing between them must never serve one stream's snapshot
        // for the other.
        let kernel: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.0 });
        let (st_a, _) = streamed_state(kernel.clone(), 12, 6, false);
        let (st_b, _) = streamed_state(kernel, 16, 6, false);
        let cell_a = Arc::new(SnapshotCell::new());
        let cell_b = Arc::new(SnapshotCell::new());
        cell_a.publish(capture_exact(&st_a, 0).unwrap());
        cell_b.publish(capture_exact(&st_b, 0).unwrap());
        assert_eq!(cell_a.epoch(), cell_b.epoch());
        let mut scratch = ProjectScratch::new();
        assert_eq!(cell_a.load_cached(&mut scratch).unwrap().m(), 12);
        assert_eq!(cell_b.load_cached(&mut scratch).unwrap().m(), 16);
        assert_eq!(cell_a.load_cached(&mut scratch).unwrap().m(), 12);
    }

    #[test]
    fn malformed_queries_error_without_panicking() {
        let kernel: Arc<dyn Kernel> = Arc::new(Rbf { sigma: 1.0 });
        let (st, _) = streamed_state(kernel, 12, 6, true);
        let snap_raw = capture_exact(&st, 0).unwrap();
        let cell = Arc::new(SnapshotCell::new());
        cell.publish(snap_raw);
        let snap = cell.load().unwrap();
        assert!(snap.project(&vec![0.0; st.dim() + 1], 3).is_err());
        let mut scratch = ProjectScratch::new();
        let mut out = Vec::new();
        assert!(snap
            .project_many_into(&vec![0.0; st.dim() * 2 + 1], 3, &mut scratch, &mut out)
            .is_err());
        // Empty batch is fine: zero rows, empty output.
        assert_eq!(snap.project_many_into(&[], 3, &mut scratch, &mut out).unwrap(), 0);
        assert!(out.is_empty());
    }
}
