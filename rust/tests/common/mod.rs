//! Shared helpers for the integration suites. Each test crate pulls
//! this in with `mod common;` — not every crate uses every helper, so
//! dead-code lints are off for the module.
#![allow(dead_code)]

pub mod oracle;
