//! Stub PJRT runtime, compiled when `--cfg pjrt_runtime` is off (the
//! offline image carries no `xla` crate). [`Runtime::new`] fails with a
//! clear message — `coordinator::server::build_engine` catches it and
//! falls back to the native engine — and [`PjrtRotate`] satisfies the
//! [`Rotate`] trait by delegating every rotation to the native blocked
//! GEMM, so code paths and tests that *route through* a PJRT engine
//! still compile and run.

use std::path::Path;

use crate::linalg::{Mat, MatView, MatViewMut};
use crate::rankone::{NativeRotate, Rotate};
use crate::secular::SecularRoot;

const UNAVAILABLE: &str =
    "pjrt runtime not compiled in (build with RUSTFLAGS=\"--cfg pjrt_runtime\" and a vendored `xla` crate)";

/// Placeholder for the compiled-executable cache. Never constructible
/// in stub builds.
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always fails in stub builds.
    pub fn new(_dir: &Path) -> Result<Self, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn warmup(&self) -> Result<usize, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn kernel_column(&self, _x: &Mat, _y: &[f64], _sigma: f64) -> Result<Vec<f64>, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn gram(&self, _x: &Mat, _sigma: f64) -> Result<Mat, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn eigvec_update(
        &self,
        _u: &Mat,
        _z: &[f64],
        _lam: &[f64],
        _lam_new: &[f64],
    ) -> Result<Mat, String> {
        Err(UNAVAILABLE.into())
    }

    pub fn nystrom_reconstruct(&self, _knm: &Mat, _u: &Mat, _lam: &[f64]) -> Result<Mat, String> {
        Err(UNAVAILABLE.into())
    }
}

/// [`Rotate`] engine surface matching the real PJRT engine; in stub
/// builds it is a pass-through to [`NativeRotate`].
pub struct PjrtRotate {
    pub runtime: std::sync::Arc<Runtime>,
    pub min_size: usize,
    fallback: NativeRotate,
}

impl PjrtRotate {
    pub fn new(runtime: std::sync::Arc<Runtime>) -> Self {
        PjrtRotate { runtime, min_size: 0, fallback: NativeRotate }
    }
}

impl Rotate for PjrtRotate {
    fn rotate_into(&self, u: MatView<'_>, w: MatView<'_>, out: MatViewMut<'_>) {
        self.fallback.rotate_into(u, w, out);
    }

    fn rotate_fused_into(
        &self,
        _u: MatView<'_>,
        _z: &[f64],
        _d: &[f64],
        _roots: &[SecularRoot],
        _out: MatViewMut<'_>,
    ) -> bool {
        false
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_runtime_fails_cleanly() {
        let err = Runtime::new(Path::new("artifacts")).err().unwrap();
        assert!(err.contains("pjrt runtime not compiled"), "{err}");
    }
}
