//! Consistent-hash ring for stream→shard placement.
//!
//! PR 2 pinned every stream to `fnv1a(id) % shards` — deterministic,
//! but any change of the shard count remaps almost every stream, which
//! makes growing or shrinking the pool equivalent to restarting it.
//! The ring keeps the determinism (everything is a pure function of
//! the shard-id set and the vnode count — no per-process seed, so two
//! processes always agree) while making topology changes *minimally
//! disruptive*: adding a shard steals arcs only for the new shard
//! (≈ `1/(k+1)` of the keyspace), removing one re-distributes only the
//! removed shard's arcs.
//!
//! Each shard contributes `vnodes` points, placed at
//! `mix64(fnv1a(shard ‖ v))` on the `u64` circle; a key lands on the
//! first point clockwise of `mix64(fnv1a(key))`. FNV-1a alone has weak
//! high-bit avalanche on short inputs, so every hash is finished with
//! the splitmix64 finalizer before it touches the circle — with ≥ 128
//! vnodes per shard the arc shares concentrate well enough that stream
//! counts stay within ~1.6× of each other (pinned by the property
//! tests below at a 2× bound).

/// FNV-1a over a byte slice — deterministic within and across processes
/// (the std hasher is randomly seeded per process, which would break
/// cross-run attribution in logs and tests, and would make two router
/// processes disagree about placement).
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over a stream id.
pub fn fnv1a(s: &str) -> u64 {
    fnv1a_bytes(s.as_bytes())
}

/// splitmix64 finalizer: full-avalanche mix of an FNV hash before it is
/// used as a ring position.
fn mix64(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    h
}

/// Ring position of vnode `v` of shard `shard`: FNV-1a over the
/// 16-byte little-endian encoding of the pair, finalized.
fn vnode_hash(shard: usize, v: usize) -> u64 {
    let mut bytes = [0u8; 16];
    bytes[..8].copy_from_slice(&(shard as u64).to_le_bytes());
    bytes[8..].copy_from_slice(&(v as u64).to_le_bytes());
    mix64(fnv1a_bytes(&bytes))
}

/// Consistent-hash ring over shard ids. Placement depends only on the
/// *set* of member shards and the vnode count — not on the order they
/// were added — so any two processes (or a process and its restart)
/// that agree on the membership agree on every key.
#[derive(Clone, Debug)]
pub struct HashRing {
    vnodes: usize,
    /// Sorted `(position, shard)` points. Ties (astronomically
    /// unlikely) break deterministically on the shard id.
    points: Vec<(u64, usize)>,
}

impl HashRing {
    /// Empty ring with `vnodes` points per future shard (≥ 1 enforced).
    pub fn new(vnodes: usize) -> HashRing {
        HashRing { vnodes: vnodes.max(1), points: Vec::new() }
    }

    /// Ring with shards `0..shards` (the spawn-time topology).
    pub fn with_shards(shards: usize, vnodes: usize) -> HashRing {
        let mut ring = HashRing::new(vnodes);
        for s in 0..shards {
            ring.add_shard(s);
        }
        ring
    }

    /// Vnodes contributed per shard.
    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    /// Number of member shards.
    pub fn len(&self) -> usize {
        self.points.len() / self.vnodes
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Whether `shard` is a member.
    pub fn contains(&self, shard: usize) -> bool {
        self.points.iter().any(|&(_, s)| s == shard)
    }

    /// Member shard ids, ascending.
    pub fn shards(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self.points.iter().map(|&(_, s)| s).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Add a member (no-op if already present). O(points) — topology
    /// changes are rare and never on the ingest path.
    pub fn add_shard(&mut self, shard: usize) {
        if self.contains(shard) {
            return;
        }
        for v in 0..self.vnodes {
            self.points.push((vnode_hash(shard, v), shard));
        }
        self.points.sort_unstable();
    }

    /// Remove a member (no-op if absent).
    pub fn remove_shard(&mut self, shard: usize) {
        self.points.retain(|&(_, s)| s != shard);
    }

    /// The shard a key is placed on: the first vnode clockwise of the
    /// key's ring position (wrapping). Panics on an empty ring — the
    /// pool always keeps ≥ 1 member.
    pub fn shard_of(&self, key: &str) -> usize {
        assert!(!self.points.is_empty(), "shard_of on an empty ring");
        let h = mix64(fnv1a(key));
        let i = self.points.partition_point(|&(p, _)| p < h);
        if i == self.points.len() {
            self.points[0].1
        } else {
            self.points[i].1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    const N_KEYS: usize = 4096;

    fn keys() -> Vec<String> {
        (0..N_KEYS).map(|i| format!("s{i}")).collect()
    }

    fn counts(ring: &HashRing, keys: &[String]) -> HashMap<usize, usize> {
        let mut c = HashMap::new();
        for k in keys {
            *c.entry(ring.shard_of(k)).or_insert(0) += 1;
        }
        c
    }

    #[test]
    fn deterministic_across_instances_and_insertion_order() {
        // Two independently built rings — and one built in a different
        // membership order — agree on every key. Placement is a pure
        // function of the member set, which is what makes it stable
        // across processes (no per-process hasher seed anywhere).
        let a = HashRing::with_shards(4, 128);
        let b = HashRing::with_shards(4, 128);
        let mut c = HashRing::new(128);
        for s in [3, 1, 0, 2] {
            c.add_shard(s);
        }
        for k in keys() {
            let want = a.shard_of(&k);
            assert_eq!(b.shard_of(&k), want, "{k}");
            assert_eq!(c.shard_of(&k), want, "{k} (insertion order)");
        }
    }

    #[test]
    fn balanced_within_2x_at_128_vnodes() {
        let keys = keys();
        for k in [2usize, 3, 4, 6, 8] {
            let ring = HashRing::with_shards(k, 128);
            let c = counts(&ring, &keys);
            assert_eq!(c.len(), k, "every shard must own keys at k={k}");
            let max = *c.values().max().unwrap() as f64;
            let min = *c.values().min().unwrap() as f64;
            assert!(
                max / min <= 2.0,
                "k={k}: stream spread {max}/{min} exceeds 2x: {c:?}"
            );
        }
    }

    #[test]
    fn growing_remaps_at_most_its_share_and_only_to_the_new_shard() {
        let keys = keys();
        for k in [1usize, 2, 3, 4, 7] {
            let before = HashRing::with_shards(k, 128);
            let mut after = before.clone();
            after.add_shard(k);
            let mut moved = 0usize;
            for key in &keys {
                let (a, b) = (before.shard_of(key), after.shard_of(key));
                if a != b {
                    moved += 1;
                    // The defining consistent-hashing property: a grow
                    // only ever moves keys ONTO the new shard.
                    assert_eq!(b, k, "{key} moved {a}->{b}, not to the new shard");
                }
            }
            // Expected share is 1/(k+1); allow 1.5x slack for arc-share
            // concentration at 128 vnodes.
            let bound = 1.5 * N_KEYS as f64 / (k + 1) as f64;
            assert!(
                (moved as f64) <= bound,
                "k={k}->{}: {moved} of {N_KEYS} keys moved (bound {bound:.0})",
                k + 1
            );
        }
    }

    #[test]
    fn removal_redistributes_only_the_removed_shards_keys() {
        let keys = keys();
        let before = HashRing::with_shards(4, 128);
        let mut after = before.clone();
        after.remove_shard(2);
        assert!(!after.contains(2));
        assert_eq!(after.len(), 3);
        for key in &keys {
            let (a, b) = (before.shard_of(key), after.shard_of(key));
            assert_ne!(b, 2, "{key} placed on a removed shard");
            if a != 2 {
                assert_eq!(a, b, "{key} moved although its shard stayed");
            }
        }
    }

    #[test]
    fn membership_bookkeeping() {
        let mut ring = HashRing::new(16);
        assert!(ring.is_empty());
        ring.add_shard(5);
        ring.add_shard(5); // idempotent
        ring.add_shard(9);
        assert_eq!(ring.len(), 2);
        assert_eq!(ring.shards(), vec![5, 9]);
        assert!(ring.contains(5) && ring.contains(9) && !ring.contains(0));
        ring.remove_shard(5);
        assert_eq!(ring.shards(), vec![9]);
        // With one member every key lands there.
        for k in keys().iter().take(64) {
            assert_eq!(ring.shard_of(k), 9);
        }
    }
}
