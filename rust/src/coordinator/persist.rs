//! Checkpoint codec: the snapshot half of the durability story (the
//! replay half is [`super::wal`]).
//!
//! A checkpoint file captures everything a stream entry needs to
//! come back after a crash: the stream configuration, the seed buffer
//! (for streams that died mid-seed), the serialized *engine* state —
//! tier-tagged [`TierParts`]: the exact eigensystem essence
//! ([`crate::kpca::KpcaParts`] plus the kernel's `describe()` string —
//! see [`crate::kernels::kernel_from_describe`]), the RFF sketch
//! ([`crate::rff::RffParts`]), or both for the shadow tier — the drift
//! monitor, the persistent counters, and the stream's WAL sequence
//! cursor (`ingest_seq`) so recovery replays exactly the logged suffix
//! the checkpoint does not already contain.
//!
//! File format (all integers little-endian):
//!
//! ```text
//! file  := MAGIC(8)  len:u32  crc:u32  payload[len]
//! ```
//!
//! with `crc = CRC32(payload)` — one frame per file, same framing
//! discipline as the WAL. Writes are atomic: encode, write to a
//! sibling temp file, fsync, rename over the target (and fsync the
//! directory), so a crash mid-checkpoint leaves either the old file or
//! the new one, never a hybrid. Reads that fail the magic/CRC/decode
//! checks are *quarantined* — the file is renamed to `<name>.corrupt`
//! and recovery proceeds with the remaining streams instead of
//! aborting the pool (the quarantined stream may still recover from
//! its WAL `Open` record).
//!
//! Deliberately not persisted: latency histograms (process-lifetime
//! observability, meaningless across a restart) and snapshot-cell
//! epochs (readers re-subscribe against a fresh cell after recovery).

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::kpca::{BatchRotation, EvictionPolicy, KpcaParts, KpcaStats};
use crate::linalg::Norms;
use crate::rff::RffParts;

use super::drift::DriftPoint;
use super::engine::{StreamTier, TierParts};
use super::ring::fnv1a;
use super::server::KernelConfig;
use super::shard::StreamConfig;
use super::wal::{
    crc32, put_f64, put_f64s, put_str, put_u32, put_u64, put_u8, read_wal, Cur, FsyncPolicy,
    WalRecord,
};

/// Leading bytes of every checkpoint file (name + format version).
/// `03` added the engine-tier tag: the stream config carries its
/// [`StreamTier`] and the state block is tier-tagged [`TierParts`].
/// `02` (bounded-memory fields) files are still decoded — their state
/// block restores as the `Exact` tier, which is the only engine that
/// existed when they were written. `01` files predate any release and
/// are not migrated — they quarantine like any other unreadable file.
pub const CKPT_MAGIC: &[u8; 8] = b"IKCKPT03";
/// Previous format version, decoded read-only (see [`CKPT_MAGIC`]).
pub const CKPT_MAGIC_V2: &[u8; 8] = b"IKCKPT02";

/// Where and how the pool persists: the snapshot directory (checkpoint
/// files + per-shard WALs) and the WAL fsync policy.
#[derive(Clone, Debug)]
pub struct PersistConfig {
    /// Directory holding `ckpt-*.ckpt` files and `wal-<shard>.log`s.
    /// Created on pool spawn if missing.
    pub dir: PathBuf,
    /// When WAL appends reach stable storage (see [`FsyncPolicy`]).
    pub fsync: FsyncPolicy,
}

impl PersistConfig {
    pub fn new(dir: impl Into<PathBuf>) -> PersistConfig {
        PersistConfig { dir: dir.into(), fsync: FsyncPolicy::default() }
    }

    /// The WAL file owned by shard `shard`'s worker.
    pub(crate) fn wal_path(&self, shard: usize) -> PathBuf {
        self.dir.join(format!("wal-{shard}.log"))
    }
}

/// Counters that survive a restart (everything in
/// [`super::metrics::Metrics`] except the latency histograms).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub(crate) struct PersistedCounters {
    pub(crate) accepted: u64,
    pub(crate) excluded: u64,
    pub(crate) errors: u64,
    pub(crate) async_errors: u64,
    pub(crate) worker_reads: u64,
    pub(crate) checkpoints: u64,
    pub(crate) wal_appends: u64,
    pub(crate) wal_bytes: u64,
    pub(crate) wal_errors: u64,
}

/// Everything one stream persists — the unit of
/// [`write_checkpoint`]/[`load_checkpoints`].
#[derive(Clone, Debug)]
pub(crate) struct CheckpointData {
    pub(crate) id: String,
    pub(crate) dim: usize,
    pub(crate) cfg: StreamConfig,
    pub(crate) seeded: usize,
    pub(crate) seed_buf: Vec<f64>,
    /// Tier-tagged engine state — `None` for streams that died
    /// mid-seed. Kernels ride as their exact `describe()` string
    /// (RBF-median streams persist the *resolved* sigma, so recovery
    /// never re-runs the heuristic on different data).
    pub(crate) state: Option<TierParts>,
    pub(crate) drift_every: usize,
    pub(crate) drift_accepted_since: usize,
    pub(crate) drift_history: Vec<DriftPoint>,
    pub(crate) counters: PersistedCounters,
    pub(crate) since_publish: u64,
    /// Next WAL sequence number the stream will assign — recovery
    /// replays exactly the records with `seq >= ingest_seq`.
    pub(crate) ingest_seq: u64,
}

// ---------------------------------------------------------------------
// Kernel / stream-config codec (shared with WAL `Open` records)
// ---------------------------------------------------------------------

const KERN_RBF: u8 = 1;
const KERN_RBF_MEDIAN: u8 = 2;
const KERN_LINEAR: u8 = 3;
const KERN_POLY: u8 = 4;
const KERN_LAPLACIAN: u8 = 5;

fn put_kernel_config(buf: &mut Vec<u8>, k: &KernelConfig) {
    match k {
        KernelConfig::Rbf { sigma } => {
            put_u8(buf, KERN_RBF);
            put_f64(buf, *sigma);
        }
        KernelConfig::RbfMedian => put_u8(buf, KERN_RBF_MEDIAN),
        KernelConfig::Linear => put_u8(buf, KERN_LINEAR),
        KernelConfig::Polynomial { degree, offset } => {
            put_u8(buf, KERN_POLY);
            put_u32(buf, *degree);
            put_f64(buf, *offset);
        }
        KernelConfig::Laplacian { sigma } => {
            put_u8(buf, KERN_LAPLACIAN);
            put_f64(buf, *sigma);
        }
    }
}

fn take_kernel_config(c: &mut Cur<'_>) -> Result<KernelConfig, String> {
    Ok(match c.take_u8()? {
        KERN_RBF => KernelConfig::Rbf { sigma: c.take_f64()? },
        KERN_RBF_MEDIAN => KernelConfig::RbfMedian,
        KERN_LINEAR => KernelConfig::Linear,
        KERN_POLY => KernelConfig::Polynomial { degree: c.take_u32()?, offset: c.take_f64()? },
        KERN_LAPLACIAN => KernelConfig::Laplacian { sigma: c.take_f64()? },
        k => return Err(format!("unknown kernel tag {k}")),
    })
}

fn put_rotation(buf: &mut Vec<u8>, r: Option<BatchRotation>) {
    put_u8(
        buf,
        match r {
            None => 0,
            Some(BatchRotation::Fused) => 1,
            Some(BatchRotation::Sequential) => 2,
        },
    );
}

fn take_rotation(c: &mut Cur<'_>) -> Result<Option<BatchRotation>, String> {
    Ok(match c.take_u8()? {
        0 => None,
        1 => Some(BatchRotation::Fused),
        2 => Some(BatchRotation::Sequential),
        t => return Err(format!("unknown rotation tag {t}")),
    })
}

fn put_eviction(buf: &mut Vec<u8>, e: EvictionPolicy) {
    put_u8(
        buf,
        match e {
            EvictionPolicy::Off => 0,
            EvictionPolicy::Uniform => 1,
            EvictionPolicy::LeverageScore => 2,
        },
    );
}

fn take_eviction(c: &mut Cur<'_>) -> Result<EvictionPolicy, String> {
    Ok(match c.take_u8()? {
        0 => EvictionPolicy::Off,
        1 => EvictionPolicy::Uniform,
        2 => EvictionPolicy::LeverageScore,
        t => return Err(format!("unknown eviction tag {t}")),
    })
}

fn put_tier(buf: &mut Vec<u8>, t: StreamTier) {
    match t {
        StreamTier::Exact => put_u8(buf, 0),
        StreamTier::Rff { features, sketch_r } => {
            put_u8(buf, 1);
            put_u64(buf, features as u64);
            put_u64(buf, sketch_r as u64);
        }
        StreamTier::Shadow { sample } => {
            put_u8(buf, 2);
            put_u64(buf, sample as u64);
        }
    }
}

fn take_tier(c: &mut Cur<'_>) -> Result<StreamTier, String> {
    Ok(match c.take_u8()? {
        0 => StreamTier::Exact,
        1 => StreamTier::Rff {
            features: c.take_u64()? as usize,
            sketch_r: c.take_u64()? as usize,
        },
        2 => StreamTier::Shadow { sample: c.take_u64()? as usize },
        t => return Err(format!("unknown tier tag {t}")),
    })
}

/// Encode a [`StreamConfig`] — also the opaque `cfg` bytes of a WAL
/// `Open` record, so mid-seed streams recover their full configuration
/// from the log alone.
pub(crate) fn encode_stream_config(buf: &mut Vec<u8>, cfg: &StreamConfig) {
    put_kernel_config(buf, &cfg.kernel);
    put_u8(buf, cfg.mean_adjust as u8);
    put_u64(buf, cfg.seed_points as u64);
    put_u64(buf, cfg.drift_every as u64);
    put_u64(buf, cfg.expected_m as u64);
    put_u64(buf, cfg.expected_batch as u64);
    put_rotation(buf, cfg.batch_rotation);
    put_u64(buf, cfg.publish_every as u64);
    put_u64(buf, cfg.snapshot_r as u64);
    match cfg.publish_after {
        None => put_u8(buf, 0),
        Some(d) => {
            put_u8(buf, 1);
            put_u64(buf, d.as_nanos() as u64);
        }
    }
    put_u64(buf, cfg.max_landmarks as u64);
    put_eviction(buf, cfg.eviction);
    // The tier rides at the end of the config block, so pre-tier
    // encodings (v02 checkpoints, old WAL `Open` blobs) are a strict
    // prefix of the current one.
    put_tier(buf, cfg.tier);
}

/// Decode the pre-tier (v02) prefix of a stream config; the tier
/// defaults to `Exact` — the only engine that existed then.
fn decode_stream_config_base(c: &mut Cur<'_>) -> Result<StreamConfig, String> {
    Ok(StreamConfig {
        kernel: take_kernel_config(c)?,
        mean_adjust: c.take_u8()? != 0,
        seed_points: c.take_u64()? as usize,
        drift_every: c.take_u64()? as usize,
        expected_m: c.take_u64()? as usize,
        expected_batch: c.take_u64()? as usize,
        batch_rotation: take_rotation(c)?,
        publish_every: c.take_u64()? as usize,
        snapshot_r: c.take_u64()? as usize,
        publish_after: match c.take_u8()? {
            0 => None,
            _ => Some(Duration::from_nanos(c.take_u64()?)),
        },
        max_landmarks: c.take_u64()? as usize,
        eviction: take_eviction(c)?,
        tier: StreamTier::Exact,
    })
}

pub(crate) fn decode_stream_config(c: &mut Cur<'_>) -> Result<StreamConfig, String> {
    let mut cfg = decode_stream_config_base(c)?;
    cfg.tier = take_tier(c)?;
    Ok(cfg)
}

/// Decode a standalone config blob — the `cfg` bytes of a WAL `Open`
/// record. A blob that ends right after the eviction policy is a
/// pre-tier record (logged before the engine seam) and restores as the
/// `Exact` tier; otherwise the tier tail must parse and the blob must
/// end exactly there — trailing bytes are rejected like everywhere
/// else in the codec (a longer blob is a different format, not this
/// one).
pub(crate) fn decode_stream_config_bytes(bytes: &[u8]) -> Result<StreamConfig, String> {
    let mut c = Cur::new(bytes);
    let mut cfg = decode_stream_config_base(&mut c)?;
    if c.remaining() != 0 {
        cfg.tier = take_tier(&mut c)?;
        if c.remaining() != 0 {
            return Err(format!("{} trailing bytes after stream config", c.remaining()));
        }
    }
    Ok(cfg)
}

// ---------------------------------------------------------------------
// Checkpoint payload codec
// ---------------------------------------------------------------------

fn put_stats(buf: &mut Vec<u8>, s: &KpcaStats) {
    put_u64(buf, s.accepted as u64);
    put_u64(buf, s.excluded as u64);
    put_u64(buf, s.deflated as u64);
    put_u64(buf, s.rotations as u64);
    put_u64(buf, s.updates as u64);
    put_u64(buf, s.evictions as u64);
}

fn take_stats(c: &mut Cur<'_>) -> Result<KpcaStats, String> {
    Ok(KpcaStats {
        accepted: c.take_u64()? as usize,
        excluded: c.take_u64()? as usize,
        deflated: c.take_u64()? as usize,
        rotations: c.take_u64()? as usize,
        updates: c.take_u64()? as usize,
        evictions: c.take_u64()? as usize,
    })
}

// State-block tier tags. `STATE_EXACT`'s body is byte-identical to the
// v02 state block (minus its 0/1 presence byte), so the v2 decode
// branch reuses `take_kpca_parts` unchanged.
const STATE_NONE: u8 = 0;
const STATE_EXACT: u8 = 1;
const STATE_RFF: u8 = 2;
const STATE_SHADOW: u8 = 3;

fn put_kpca_parts(buf: &mut Vec<u8>, kernel: &str, p: &KpcaParts) {
    put_str(buf, kernel);
    put_u8(buf, p.mean_adjust as u8);
    put_f64s(buf, &p.x);
    put_f64s(buf, &p.vals);
    put_f64s(buf, &p.vecs);
    put_f64(buf, p.s);
    put_f64s(buf, &p.k1);
    put_f64(buf, p.exclude_tol);
    put_u8(buf, p.naive_recenter_split as u8);
    put_rotation(buf, p.batch_rotation);
    put_stats(buf, &p.stats);
    put_u64(buf, p.engine_gemms);
}

/// `dim` is not on the wire inside the state block — it rides once at
/// the top of the payload and is injected here.
fn take_kpca_parts(c: &mut Cur<'_>, dim: usize) -> Result<(String, KpcaParts), String> {
    let kernel = c.take_str()?;
    let mean_adjust = c.take_u8()? != 0;
    let x = c.take_f64s()?;
    let vals = c.take_f64s()?;
    let vecs = c.take_f64s()?;
    let s = c.take_f64()?;
    let k1 = c.take_f64s()?;
    let exclude_tol = c.take_f64()?;
    let naive_recenter_split = c.take_u8()? != 0;
    let batch_rotation = take_rotation(c)?;
    let stats = take_stats(c)?;
    let engine_gemms = c.take_u64()?;
    Ok((
        kernel,
        KpcaParts {
            mean_adjust,
            dim,
            x,
            vals,
            vecs,
            s,
            k1,
            exclude_tol,
            naive_recenter_split,
            batch_rotation,
            stats,
            engine_gemms,
        },
    ))
}

fn put_rff_parts(buf: &mut Vec<u8>, p: &RffParts) {
    put_u64(buf, p.seed);
    put_f64(buf, p.sigma);
    put_u64(buf, p.features as u64);
    put_u64(buf, p.sketch_r as u64);
    put_u8(buf, p.mean_adjust as u8);
    put_u64(buf, p.count);
    put_f64s(buf, &p.mu);
    put_u64(buf, p.brows as u64);
    put_f64s(buf, &p.b);
    put_stats(buf, &p.stats);
}

fn take_rff_parts(c: &mut Cur<'_>, dim: usize) -> Result<RffParts, String> {
    let seed = c.take_u64()?;
    let sigma = c.take_f64()?;
    let features = c.take_u64()? as usize;
    let sketch_r = c.take_u64()? as usize;
    let mean_adjust = c.take_u8()? != 0;
    let count = c.take_u64()?;
    let mu = c.take_f64s()?;
    let brows = c.take_u64()? as usize;
    let b = c.take_f64s()?;
    let stats = take_stats(c)?;
    Ok(RffParts {
        seed,
        sigma,
        dim,
        features,
        sketch_r,
        mean_adjust,
        count,
        mu,
        b,
        brows,
        stats,
    })
}

fn encode_payload(buf: &mut Vec<u8>, d: &CheckpointData) {
    put_str(buf, &d.id);
    put_u64(buf, d.dim as u64);
    encode_stream_config(buf, &d.cfg);
    put_u64(buf, d.seeded as u64);
    put_f64s(buf, &d.seed_buf);
    match &d.state {
        None => put_u8(buf, STATE_NONE),
        Some(TierParts::Exact { kernel, parts }) => {
            put_u8(buf, STATE_EXACT);
            put_kpca_parts(buf, kernel, parts);
        }
        Some(TierParts::Rff(p)) => {
            put_u8(buf, STATE_RFF);
            put_rff_parts(buf, p);
        }
        Some(TierParts::Shadow { kernel, exact, rff, sample }) => {
            put_u8(buf, STATE_SHADOW);
            put_kpca_parts(buf, kernel, exact);
            put_rff_parts(buf, rff);
            put_u64(buf, *sample as u64);
        }
    }
    put_u64(buf, d.drift_every as u64);
    put_u64(buf, d.drift_accepted_since as u64);
    put_u64(buf, d.drift_history.len() as u64);
    for p in &d.drift_history {
        put_u64(buf, p.m as u64);
        put_f64(buf, p.norms.frobenius);
        put_f64(buf, p.norms.spectral);
        put_f64(buf, p.norms.trace);
        put_f64(buf, p.orthogonality);
    }
    let c = &d.counters;
    for v in [
        c.accepted,
        c.excluded,
        c.errors,
        c.async_errors,
        c.worker_reads,
        c.checkpoints,
        c.wal_appends,
        c.wal_bytes,
        c.wal_errors,
    ] {
        put_u64(buf, v);
    }
    put_u64(buf, d.since_publish);
    put_u64(buf, d.ingest_seq);
}

/// Decode a checkpoint payload. `v2` selects the `IKCKPT02`
/// compatibility branch: no tier in the config block, and the state
/// block is a 0/1-tagged exact eigensystem — restored as the `Exact`
/// tier, the only engine that existed when those files were written.
fn decode_payload(payload: &[u8], v2: bool) -> Result<CheckpointData, String> {
    let mut c = Cur::new(payload);
    let id = c.take_str()?;
    let dim = c.take_u64()? as usize;
    let cfg = if v2 {
        decode_stream_config_base(&mut c)?
    } else {
        decode_stream_config(&mut c)?
    };
    let seeded = c.take_u64()? as usize;
    let seed_buf = c.take_f64s()?;
    let state = match (v2, c.take_u8()?) {
        (_, STATE_NONE) => None,
        (true, _) | (false, STATE_EXACT) => {
            let (kernel, parts) = take_kpca_parts(&mut c, dim)?;
            Some(TierParts::Exact { kernel, parts })
        }
        (false, STATE_RFF) => Some(TierParts::Rff(take_rff_parts(&mut c, dim)?)),
        (false, STATE_SHADOW) => {
            let (kernel, exact) = take_kpca_parts(&mut c, dim)?;
            let rff = take_rff_parts(&mut c, dim)?;
            let sample = c.take_u64()? as usize;
            Some(TierParts::Shadow { kernel, exact, rff, sample })
        }
        (false, t) => return Err(format!("unknown state tag {t}")),
    };
    let drift_every = c.take_u64()? as usize;
    let drift_accepted_since = c.take_u64()? as usize;
    let n_drift = c.take_u64()? as usize;
    if c.remaining() < n_drift.saturating_mul(40) {
        return Err(format!("short drift history: {n_drift} points claimed"));
    }
    let mut drift_history = Vec::with_capacity(n_drift);
    for _ in 0..n_drift {
        drift_history.push(DriftPoint {
            m: c.take_u64()? as usize,
            norms: Norms {
                frobenius: c.take_f64()?,
                spectral: c.take_f64()?,
                trace: c.take_f64()?,
            },
            orthogonality: c.take_f64()?,
        });
    }
    let counters = PersistedCounters {
        accepted: c.take_u64()?,
        excluded: c.take_u64()?,
        errors: c.take_u64()?,
        async_errors: c.take_u64()?,
        worker_reads: c.take_u64()?,
        checkpoints: c.take_u64()?,
        wal_appends: c.take_u64()?,
        wal_bytes: c.take_u64()?,
        wal_errors: c.take_u64()?,
    };
    let since_publish = c.take_u64()?;
    let ingest_seq = c.take_u64()?;
    if c.remaining() != 0 {
        return Err(format!("{} trailing bytes after checkpoint", c.remaining()));
    }
    Ok(CheckpointData {
        id,
        dim,
        cfg,
        seeded,
        seed_buf,
        state,
        drift_every,
        drift_accepted_since,
        drift_history,
        counters,
        since_publish,
        ingest_seq,
    })
}

/// Encode a full checkpoint file (magic + one CRC frame).
pub(crate) fn encode_checkpoint(d: &CheckpointData) -> Vec<u8> {
    let mut payload = Vec::new();
    encode_payload(&mut payload, d);
    let mut bytes = CKPT_MAGIC.to_vec();
    bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
    bytes.extend_from_slice(&payload);
    bytes
}

/// Decode checkpoint file bytes (current `IKCKPT03` or the previous
/// `IKCKPT02`). Never panics on malformed input — every failure is an
/// `Err` the loader turns into a quarantine.
pub(crate) fn decode_checkpoint(bytes: &[u8]) -> Result<CheckpointData, String> {
    if bytes.len() < CKPT_MAGIC.len() + 8 {
        return Err("bad checkpoint magic".into());
    }
    let magic = &bytes[..CKPT_MAGIC.len()];
    let v2 = magic == CKPT_MAGIC_V2;
    if !v2 && magic != CKPT_MAGIC {
        return Err("bad checkpoint magic".into());
    }
    let p = CKPT_MAGIC.len();
    let len = u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap()) as usize;
    let crc = u32::from_le_bytes(bytes[p + 4..p + 8].try_into().unwrap());
    let payload = bytes
        .get(p + 8..p + 8 + len)
        .ok_or_else(|| "truncated checkpoint frame".to_string())?;
    if bytes.len() != p + 8 + len {
        return Err("trailing bytes after checkpoint frame".into());
    }
    if crc32(payload) != crc {
        return Err("checkpoint CRC mismatch".into());
    }
    decode_payload(payload, v2)
}

// ---------------------------------------------------------------------
// Files
// ---------------------------------------------------------------------

/// Checkpoint filename for a stream id: a sanitized prefix for human
/// legibility plus the FNV-1a hash of the *full* id for uniqueness
/// (the true id lives inside the file; the name is only an address).
pub(crate) fn checkpoint_filename(id: &str) -> String {
    let sanitized: String = id
        .chars()
        .take(40)
        .map(|ch| if ch.is_ascii_alphanumeric() || ch == '-' || ch == '_' { ch } else { '_' })
        .collect();
    format!("ckpt-{sanitized}-{:016x}.ckpt", fnv1a(id))
}

pub(crate) fn checkpoint_path(dir: &Path, id: &str) -> PathBuf {
    dir.join(checkpoint_filename(id))
}

/// Atomically (write-temp → fsync → rename) persist one checkpoint.
/// Returns the encoded byte count.
pub(crate) fn write_checkpoint(dir: &Path, d: &CheckpointData) -> std::io::Result<u64> {
    let bytes = encode_checkpoint(d);
    let target = checkpoint_path(dir, &d.id);
    let tmp = target.with_extension("ckpt.tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &target)?;
    // Make the rename itself durable. Directory fsync is best-effort:
    // not every filesystem supports opening a directory for sync.
    if let Ok(dirf) = std::fs::File::open(dir) {
        let _ = dirf.sync_all();
    }
    Ok(bytes.len() as u64)
}

/// Best-effort removal of a closed stream's checkpoint (the WAL `Close`
/// record covers the window until the next rotation).
pub(crate) fn remove_checkpoint(dir: &Path, id: &str) {
    let _ = std::fs::remove_file(checkpoint_path(dir, id));
}

/// Result of sweeping a snapshot directory for checkpoints.
#[derive(Debug, Default)]
pub(crate) struct LoadedCheckpoints {
    pub(crate) checkpoints: Vec<CheckpointData>,
    /// Files that failed the magic/CRC/decode checks, renamed to
    /// `<name>.corrupt` and skipped.
    pub(crate) quarantined: Vec<PathBuf>,
}

/// Load every `ckpt-*.ckpt` under `dir`, quarantining corrupt files
/// instead of failing the sweep. A missing directory loads as empty.
pub(crate) fn load_checkpoints(dir: &Path) -> std::io::Result<LoadedCheckpoints> {
    let mut out = LoadedCheckpoints::default();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e),
    };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.extension().is_some_and(|x| x == "ckpt")
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("ckpt-"))
        })
        .collect();
    paths.sort(); // deterministic restore order
    for path in paths {
        let decoded = std::fs::read(&path)
            .map_err(|e| e.to_string())
            .and_then(|bytes| decode_checkpoint(&bytes));
        match decoded {
            Ok(d) => out.checkpoints.push(d),
            Err(_) => {
                let mut corrupt = path.clone().into_os_string();
                corrupt.push(".corrupt");
                let _ = std::fs::rename(&path, PathBuf::from(corrupt));
                out.quarantined.push(path);
            }
        }
    }
    Ok(out)
}

/// Result of sweeping a snapshot directory for WAL files.
#[derive(Debug, Default)]
pub(crate) struct LoadedWals {
    /// All records across every shard log, in per-file append order
    /// (cross-file order is irrelevant: ingest replay sorts by the
    /// per-stream sequence number).
    pub(crate) records: Vec<WalRecord>,
    /// Shard logs that ended in a torn tail (tolerated — the valid
    /// prefix is in `records`).
    pub(crate) torn_logs: usize,
}

/// Read every `wal-*.log` under `dir`, tolerating torn tails. A missing
/// directory loads as empty.
pub(crate) fn load_wals(dir: &Path) -> std::io::Result<LoadedWals> {
    let mut out = LoadedWals::default();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => return Err(e),
    };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.extension().is_some_and(|x| x == "log")
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("wal-"))
        })
        .collect();
    paths.sort();
    for path in paths {
        let read = read_wal(&path)?;
        out.torn_logs += read.torn as usize;
        out.records.extend(read.records);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!(
            "inkpca_persist_{tag}_{}_{n}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_config() -> StreamConfig {
        StreamConfig {
            kernel: KernelConfig::Polynomial { degree: 3, offset: 0.25 },
            mean_adjust: true,
            seed_points: 7,
            drift_every: 5,
            expected_m: 128,
            expected_batch: 16,
            batch_rotation: Some(BatchRotation::Sequential),
            publish_every: 32,
            snapshot_r: 4,
            publish_after: Some(Duration::from_millis(250)),
            max_landmarks: 96,
            eviction: EvictionPolicy::LeverageScore,
            tier: StreamTier::Rff { features: 64, sketch_r: 8 },
        }
    }

    fn sample_kpca_parts() -> (String, KpcaParts) {
        (
            "rbf(sigma=0.30000000000000004)".to_string(),
            KpcaParts {
                mean_adjust: true,
                dim: 3,
                x: (0..12).map(|i| i as f64 * 0.125).collect(),
                vals: vec![0.1, 0.7, 1.0 / 3.0, 2.5],
                vecs: (0..16).map(|i| (i as f64).sin()).collect(),
                s: 17.25,
                k1: vec![1.0, 2.0, 3.0, 4.0],
                exclude_tol: 1e-10,
                naive_recenter_split: false,
                batch_rotation: Some(BatchRotation::Fused),
                stats: KpcaStats {
                    accepted: 20,
                    excluded: 2,
                    deflated: 1,
                    rotations: 3,
                    updates: 80,
                    evictions: 6,
                },
                engine_gemms: 44,
            },
        )
    }

    fn sample_rff_parts() -> RffParts {
        RffParts {
            seed: 0xDEAD_BEEF,
            sigma: 0.75,
            dim: 3,
            features: 64,
            sketch_r: 8,
            mean_adjust: true,
            count: 40,
            mu: (0..64).map(|i| (i as f64).cos() * 0.01).collect(),
            b: (0..5 * 64).map(|i| (i as f64 * 0.37).sin()).collect(),
            brows: 5,
            stats: KpcaStats { accepted: 40, updates: 40, deflated: 2, ..KpcaStats::default() },
        }
    }

    fn sample_checkpoint(id: &str) -> CheckpointData {
        let (kernel, parts) = sample_kpca_parts();
        CheckpointData {
            id: id.to_string(),
            dim: 3,
            cfg: sample_config(),
            seeded: 4,
            seed_buf: vec![0.5; 12],
            state: Some(TierParts::Exact { kernel, parts }),
            drift_every: 5,
            drift_accepted_since: 2,
            drift_history: vec![DriftPoint {
                m: 10,
                norms: Norms { frobenius: 1e-12, spectral: 5e-13, trace: -2e-13 },
                orthogonality: 3e-14,
            }],
            counters: PersistedCounters {
                accepted: 20,
                excluded: 2,
                errors: 1,
                async_errors: 1,
                worker_reads: 9,
                checkpoints: 2,
                wal_appends: 22,
                wal_bytes: 4096,
                wal_errors: 0,
            },
            since_publish: 3,
            ingest_seq: 22,
        }
    }

    #[test]
    fn stream_config_roundtrip_all_kernels() {
        let kernels = [
            KernelConfig::Rbf { sigma: 0.1 + 0.2 },
            KernelConfig::RbfMedian,
            KernelConfig::Linear,
            KernelConfig::Polynomial { degree: 2, offset: 1.0 },
            KernelConfig::Laplacian { sigma: 1.0 / 3.0 },
        ];
        let tiers = [
            StreamTier::Exact,
            StreamTier::Rff { features: 256, sketch_r: 16 },
            StreamTier::Shadow { sample: 8 },
        ];
        for kernel in kernels {
            for publish_after in [None, Some(Duration::from_micros(1500))] {
                for ((batch_rotation, eviction), tier) in [
                    (None, EvictionPolicy::Off),
                    (Some(BatchRotation::Fused), EvictionPolicy::Uniform),
                    (Some(BatchRotation::Sequential), EvictionPolicy::LeverageScore),
                ]
                .into_iter()
                .zip(tiers)
                {
                    let cfg = StreamConfig {
                        kernel: kernel.clone(),
                        batch_rotation,
                        publish_after,
                        eviction,
                        tier,
                        ..sample_config()
                    };
                    let mut buf = Vec::new();
                    encode_stream_config(&mut buf, &cfg);
                    let back = decode_stream_config(&mut Cur::new(&buf)).unwrap();
                    // `Debug` prints f64 fields with shortest exact
                    // round-trip precision, so string equality is value
                    // equality.
                    assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
                }
            }
        }
    }

    #[test]
    fn checkpoint_roundtrip_is_exact() {
        let d = sample_checkpoint("stream/with:odd id");
        let bytes = encode_checkpoint(&d);
        let back = decode_checkpoint(&bytes).unwrap();
        assert_eq!(format!("{d:?}"), format!("{back:?}"));
        // Seeding-only checkpoint (no eigensystem yet) round-trips too.
        let d2 = CheckpointData { state: None, ..sample_checkpoint("mid-seed") };
        let back2 = decode_checkpoint(&encode_checkpoint(&d2)).unwrap();
        assert_eq!(format!("{d2:?}"), format!("{back2:?}"));
    }

    #[test]
    fn rff_and_shadow_states_roundtrip() {
        let mut d = sample_checkpoint("rff-stream");
        d.state = Some(TierParts::Rff(sample_rff_parts()));
        let back = decode_checkpoint(&encode_checkpoint(&d)).unwrap();
        assert_eq!(format!("{d:?}"), format!("{back:?}"));

        let (kernel, exact) = sample_kpca_parts();
        d.cfg.tier = StreamTier::Shadow { sample: 5 };
        d.state =
            Some(TierParts::Shadow { kernel, exact, rff: sample_rff_parts(), sample: 5 });
        let back = decode_checkpoint(&encode_checkpoint(&d)).unwrap();
        assert_eq!(format!("{d:?}"), format!("{back:?}"));
    }

    /// Encode the pre-tier `IKCKPT02` layout byte-for-byte — the
    /// compatibility pin: files written by the previous release must
    /// keep decoding, with the engine restored as the `Exact` tier.
    fn encode_checkpoint_v2(d: &CheckpointData) -> Vec<u8> {
        let mut payload = Vec::new();
        put_str(&mut payload, &d.id);
        put_u64(&mut payload, d.dim as u64);
        // v02 stream config: everything up to (and including) the
        // eviction policy; no tier byte.
        put_kernel_config(&mut payload, &d.cfg.kernel);
        put_u8(&mut payload, d.cfg.mean_adjust as u8);
        put_u64(&mut payload, d.cfg.seed_points as u64);
        put_u64(&mut payload, d.cfg.drift_every as u64);
        put_u64(&mut payload, d.cfg.expected_m as u64);
        put_u64(&mut payload, d.cfg.expected_batch as u64);
        put_rotation(&mut payload, d.cfg.batch_rotation);
        put_u64(&mut payload, d.cfg.publish_every as u64);
        put_u64(&mut payload, d.cfg.snapshot_r as u64);
        match d.cfg.publish_after {
            None => put_u8(&mut payload, 0),
            Some(dur) => {
                put_u8(&mut payload, 1);
                put_u64(&mut payload, dur.as_nanos() as u64);
            }
        }
        put_u64(&mut payload, d.cfg.max_landmarks as u64);
        put_eviction(&mut payload, d.cfg.eviction);
        put_u64(&mut payload, d.seeded as u64);
        put_f64s(&mut payload, &d.seed_buf);
        match &d.state {
            None => put_u8(&mut payload, 0),
            Some(TierParts::Exact { kernel, parts }) => {
                put_u8(&mut payload, 1);
                put_kpca_parts(&mut payload, kernel, parts);
            }
            other => panic!("v02 had no tier {other:?}"),
        }
        put_u64(&mut payload, d.drift_every as u64);
        put_u64(&mut payload, d.drift_accepted_since as u64);
        put_u64(&mut payload, d.drift_history.len() as u64);
        for p in &d.drift_history {
            put_u64(&mut payload, p.m as u64);
            put_f64(&mut payload, p.norms.frobenius);
            put_f64(&mut payload, p.norms.spectral);
            put_f64(&mut payload, p.norms.trace);
            put_f64(&mut payload, p.orthogonality);
        }
        let c = &d.counters;
        for v in [
            c.accepted,
            c.excluded,
            c.errors,
            c.async_errors,
            c.worker_reads,
            c.checkpoints,
            c.wal_appends,
            c.wal_bytes,
            c.wal_errors,
        ] {
            put_u64(&mut payload, v);
        }
        put_u64(&mut payload, d.since_publish);
        put_u64(&mut payload, d.ingest_seq);
        let mut bytes = CKPT_MAGIC_V2.to_vec();
        bytes.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes
    }

    #[test]
    fn v2_checkpoint_decodes_with_exact_tier() {
        let mut d = sample_checkpoint("legacy");
        d.cfg.tier = StreamTier::Exact; // v02 knew no other engine
        let bytes = encode_checkpoint_v2(&d);
        let back = decode_checkpoint(&bytes).unwrap();
        // Everything round-trips; the tier comes back `Exact`.
        assert_eq!(format!("{d:?}"), format!("{back:?}"));
        assert_eq!(back.cfg.tier, StreamTier::Exact);
        assert!(matches!(back.state, Some(TierParts::Exact { .. })));
        // Corrupting a v2 frame still quarantines cleanly.
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(decode_checkpoint(&bad).is_err());
    }

    #[test]
    fn pre_tier_config_blob_decodes_as_exact() {
        // A WAL `Open` record logged before the engine seam: the blob
        // ends at the eviction policy. Strip the tier tail off a fresh
        // encoding (Exact's tag is exactly one byte) to reproduce it.
        let cfg = StreamConfig { tier: StreamTier::Exact, ..sample_config() };
        let mut blob = Vec::new();
        encode_stream_config(&mut blob, &cfg);
        blob.pop(); // drop the tier byte -> pre-tier layout
        let back = decode_stream_config_bytes(&blob).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
        // Current blobs (tier included) still round-trip, including
        // parameterized tiers.
        let cfg = sample_config();
        let mut blob = Vec::new();
        encode_stream_config(&mut blob, &cfg);
        let back = decode_stream_config_bytes(&blob).unwrap();
        assert_eq!(format!("{cfg:?}"), format!("{back:?}"));
        // Trailing garbage after the tier is still rejected.
        blob.push(7);
        assert!(decode_stream_config_bytes(&blob).is_err());
    }

    #[test]
    fn decode_rejects_corruption_without_panicking() {
        let d = sample_checkpoint("c");
        let good = encode_checkpoint(&d);
        assert!(decode_checkpoint(b"not a checkpoint").is_err());
        // Flip one bit everywhere: every mutant must decode to Err or
        // to the original (a flip in ignored padding does not exist in
        // this format, but the contract is only "never panic, never
        // accept a corrupt payload").
        for byte in 0..good.len() {
            let mut bad = good.clone();
            bad[byte] ^= 0x40;
            if let Ok(back) = decode_checkpoint(&bad) {
                assert_eq!(format!("{back:?}"), format!("{d:?}"), "byte {byte}");
            }
        }
        // Truncations never panic.
        for cut in 0..good.len() {
            let _ = decode_checkpoint(&good[..cut]);
        }
    }

    #[test]
    fn write_then_load_roundtrips_and_overwrites() {
        let dir = temp_dir("roundtrip");
        let d = sample_checkpoint("s1");
        write_checkpoint(&dir, &d).unwrap();
        // Second write of the same stream replaces, not duplicates.
        let mut d2 = sample_checkpoint("s1");
        d2.ingest_seq = 99;
        write_checkpoint(&dir, &d2).unwrap();
        let loaded = load_checkpoints(&dir).unwrap();
        assert_eq!(loaded.checkpoints.len(), 1);
        assert!(loaded.quarantined.is_empty());
        assert_eq!(loaded.checkpoints[0].ingest_seq, 99);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_file_is_quarantined_not_fatal() {
        let dir = temp_dir("quarantine");
        write_checkpoint(&dir, &sample_checkpoint("good")).unwrap();
        let bad_path = dir.join("ckpt-bad-0000000000000000.ckpt");
        let mut bytes = encode_checkpoint(&sample_checkpoint("bad"));
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&bad_path, &bytes).unwrap();
        let loaded = load_checkpoints(&dir).unwrap();
        assert_eq!(loaded.checkpoints.len(), 1);
        assert_eq!(loaded.checkpoints[0].id, "good");
        assert_eq!(loaded.quarantined, vec![bad_path.clone()]);
        assert!(!bad_path.exists(), "corrupt file renamed away");
        let corrupt = PathBuf::from(format!("{}.corrupt", bad_path.display()));
        assert!(corrupt.exists(), "renamed to .corrupt for post-mortem");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_loads_empty() {
        let dir = std::env::temp_dir().join("inkpca_persist_never_created");
        assert!(load_checkpoints(&dir).unwrap().checkpoints.is_empty());
        assert!(load_wals(&dir).unwrap().records.is_empty());
    }

    #[test]
    fn filenames_are_sanitized_and_collision_safe() {
        let a = checkpoint_filename("sensor/7:rack#2");
        assert!(a.starts_with("ckpt-sensor_7_rack_2-"));
        assert!(a.ends_with(".ckpt"));
        // Ids that sanitize identically still get distinct names.
        let b = checkpoint_filename("sensor_7_rack_2");
        assert_ne!(a, b);
        // Long ids truncate the legible prefix, not the hash.
        let long = checkpoint_filename(&"x".repeat(200));
        assert!(long.len() < 80);
    }
}
