//! Figure 2 — incremental Nyström accuracy: the three norms of
//! `K − K̃` as the subset grows, over the first `n` observations of each
//! dataset, for one run and the mean of `runs` random-subset-order runs
//! (§5.2). The residual `K − K̃` is PSD (Schur complement), so the
//! norms are computed in `O(n²)` via [`crate::linalg::psd_norms`].

use std::io::Write;

use crate::data::{load, Dataset};
use crate::kernels::{gram, median_heuristic, Rbf};
use crate::linalg::Norms;
use crate::nystrom::IncrementalNystrom;
use crate::util::{par, Rng};

use super::RunMode;

#[derive(Clone, Debug)]
pub struct Fig2Config {
    pub datasets: Vec<String>,
    /// Evaluation set size (paper: first 1000 observations).
    pub n: usize,
    /// Largest subset size to grow to.
    pub m_max: usize,
    /// Random-order repetitions for the mean curve (paper: 50).
    pub runs: usize,
    /// Measure error every this many added subset points.
    pub measure_every: usize,
    pub seed: u64,
}

impl Fig2Config {
    pub fn new(mode: RunMode) -> Self {
        match mode {
            RunMode::Quick => Fig2Config {
                datasets: vec!["magic".into(), "yeast".into()],
                n: 300,
                m_max: 100,
                runs: 5,
                measure_every: 10,
                seed: 42,
            },
            // Paper: n = 1000, 50 runs. We keep n = 1000 and use 20
            // random-order runs for the mean curve (single-core budget);
            // the averaged error-vs-m shape stabilizes well before 20.
            RunMode::Full => Fig2Config {
                datasets: vec!["magic".into(), "yeast".into()],
                n: 1000,
                m_max: 320,
                runs: 20,
                measure_every: 10,
                seed: 42,
            },
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct NystromSample {
    pub m: usize,
    pub norms: Norms,
}

/// Error curve for one subset order.
pub fn nystrom_curve(
    ds: &Dataset,
    cfg: &Fig2Config,
    k_full: &crate::linalg::Mat,
    sigma: f64,
    order: &[usize],
) -> Result<Vec<NystromSample>, String> {
    let kern = Rbf { sigma };
    let mut inys = IncrementalNystrom::new(&kern, ds.x.clone())?;
    let mut samples = Vec::new();
    for (step, &idx) in order.iter().take(cfg.m_max).enumerate() {
        inys.add_point(idx)?;
        if (step + 1) % cfg.measure_every == 0 || step + 1 == cfg.m_max {
            let diff = k_full.sub(&inys.approx_gram());
            samples.push(NystromSample { m: inys.m(), norms: crate::linalg::psd_norms(&diff) });
        }
    }
    Ok(samples)
}

/// Run the full Figure-2 harness; returns (dataset, mean curve).
pub fn run_fig2(cfg: &Fig2Config) -> Result<Vec<(String, Vec<NystromSample>)>, String> {
    let (mut csv, path) = super::csv_writer(
        "fig2_nystrom.csv",
        "dataset,run,m,frobenius,spectral,trace",
    )
    .map_err(|e| e.to_string())?;
    let mut out = Vec::new();
    for name in &cfg.datasets {
        let ds = load(name, cfg.n, cfg.seed)?;
        let mut std_ds = ds.clone();
        std_ds.standardize();
        let sigma = median_heuristic(&std_ds.x, 200);
        let k_full = gram(&Rbf { sigma }, &std_ds.x);
        let orders: Vec<Vec<usize>> = (0..=cfg.runs)
            .map(|r| {
                if r == 0 {
                    (0..std_ds.n()).collect()
                } else {
                    Rng::new(cfg.seed ^ (r as u64) << 20).permutation(std_ds.n())
                }
            })
            .collect();
        let curves: Vec<Result<Vec<NystromSample>, String>> = par::par_map(
            orders.len(),
            1,
            |r| nystrom_curve(&std_ds, cfg, &k_full, sigma, &orders[r]),
        );
        let mut all = Vec::new();
        for c in curves {
            let samples = c?;
            all.push(samples);
        }
        for (r, samples) in all.iter().enumerate() {
            for s in samples {
                writeln!(
                    csv,
                    "{name},{r},{},{:.6e},{:.6e},{:.6e}",
                    s.m, s.norms.frobenius, s.norms.spectral, s.norms.trace
                )
                .map_err(|e| e.to_string())?;
            }
        }
        let mean = mean_curve(&all[1..]);
        print_summary(name, cfg.n, &mean);
        out.push((name.clone(), mean));
    }
    println!("fig2: wrote {}", path.display());
    Ok(out)
}

fn mean_curve(runs: &[Vec<NystromSample>]) -> Vec<NystromSample> {
    if runs.is_empty() || runs[0].is_empty() {
        return Vec::new();
    }
    let npts = runs.iter().map(|r| r.len()).min().unwrap();
    (0..npts)
        .map(|i| {
            let k = runs.len() as f64;
            NystromSample {
                m: runs[0][i].m,
                norms: Norms {
                    frobenius: runs.iter().map(|r| r[i].norms.frobenius).sum::<f64>() / k,
                    spectral: runs.iter().map(|r| r[i].norms.spectral).sum::<f64>() / k,
                    trace: runs.iter().map(|r| r[i].norms.trace).sum::<f64>() / k,
                },
            }
        })
        .collect()
}

fn print_summary(name: &str, n: usize, mean: &[NystromSample]) {
    println!("── Fig. 2 Nyström error (n={n}): {name} ──");
    println!("{:>6} {:>12} {:>12} {:>12}", "m", "frobenius", "spectral", "trace");
    for s in mean {
        println!(
            "{:>6} {:>12.4e} {:>12.4e} {:>12.4e}",
            s.m, s.norms.frobenius, s.norms.spectral, s.norms.trace
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_fig2_error_decreases() {
        let cfg = Fig2Config {
            datasets: vec!["yeast".into()],
            n: 60,
            m_max: 40,
            runs: 2,
            measure_every: 10,
            seed: 5,
        };
        let out = run_fig2(&cfg).unwrap();
        let (_, mean) = &out[0];
        assert_eq!(mean.len(), 4);
        // Error decreases monotonically in the mean curve.
        for w in mean.windows(2) {
            assert!(
                w[1].norms.frobenius <= w[0].norms.frobenius + 1e-9,
                "error rose: {} → {}",
                w[0].norms.frobenius,
                w[1].norms.frobenius
            );
        }
        // Norm ordering holds.
        for s in mean {
            assert!(s.norms.spectral <= s.norms.frobenius + 1e-9);
            assert!(s.norms.frobenius <= s.norms.trace + 1e-9);
        }
    }
}
