//! Cholesky factorization `A = L Lᵀ` with triangular solves and rank-one
//! up/downdates ([`Cholesky`], dense storage), plus a *packed*
//! capacity-slack variant ([`PackedCholesky`]) whose bordered expansion
//! is an amortized `Vec` append — the streaming form the incremental
//! Nyström-Cholesky baseline grows one point at a time. Substrate for
//! the batch Nyström inverse and for the Rudi et al. (2015)
//! incremental-Cholesky Nyström baseline (§4).

use super::matrix::{dot, Mat};

/// Lower-triangular Cholesky factor.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor symmetric positive-definite `a`. Fails (returns `Err`)
    /// on a non-positive pivot.
    pub fn new(a: &Mat) -> Result<Self, String> {
        assert!(a.is_square());
        let n = a.rows();
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(format!("cholesky: non-positive pivot {s:e} at {i}"));
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    pub fn order(&self) -> usize {
        self.l.rows()
    }

    /// The factor `L`.
    pub fn factor(&self) -> &Mat {
        &self.l
    }

    /// Solve `L y = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.order();
        assert_eq!(b.len(), n);
        let mut y = b.to_vec();
        for i in 0..n {
            for k in 0..i {
                y[i] -= self.l[(i, k)] * y[k];
            }
            y[i] /= self.l[(i, i)];
        }
        y
    }

    /// Solve `Lᵀ x = y` (back substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.order();
        assert_eq!(y.len(), n);
        let mut x = y.to_vec();
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                x[i] -= self.l[(k, i)] * x[k];
            }
            x[i] /= self.l[(i, i)];
        }
        x
    }

    /// Solve `A x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// Solve `A X = B` column-wise.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let mut x = Mat::zeros(self.order(), b.cols());
        for j in 0..b.cols() {
            let col = b.col(j);
            x.set_col(j, &self.solve(&col));
        }
        x
    }

    /// Explicit inverse `A⁻¹` (used by the batch Nyström path).
    pub fn inverse(&self) -> Mat {
        let n = self.order();
        let mut inv = Mat::zeros(n, n);
        for j in 0..n {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            inv.set_col(j, &self.solve(&e));
        }
        inv
    }

    /// Rank-one *update*: factor of `A + v vᵀ` in `O(n²)` via Givens-style
    /// hyperbolic sweeps (Golub & Van Loan §6.5.4).
    pub fn rank_one_update(&mut self, v: &[f64]) {
        let n = self.order();
        assert_eq!(v.len(), n);
        let mut w = v.to_vec();
        for k in 0..n {
            let lkk = self.l[(k, k)];
            let r = (lkk * lkk + w[k] * w[k]).sqrt();
            let c = r / lkk;
            let s = w[k] / lkk;
            self.l[(k, k)] = r;
            for i in (k + 1)..n {
                let lik = (self.l[(i, k)] + s * w[i]) / c;
                w[i] = c * w[i] - s * lik;
                self.l[(i, k)] = lik;
            }
        }
    }

    /// Rank-one *downdate*: factor of `A − v vᵀ`. Fails if the result is
    /// not positive definite.
    pub fn rank_one_downdate(&mut self, v: &[f64]) -> Result<(), String> {
        let n = self.order();
        assert_eq!(v.len(), n);
        let mut w = v.to_vec();
        for k in 0..n {
            let lkk = self.l[(k, k)];
            let d = lkk * lkk - w[k] * w[k];
            if d <= 0.0 {
                return Err("cholesky downdate: loss of positive definiteness".into());
            }
            let r = d.sqrt();
            let c = r / lkk;
            let s = w[k] / lkk;
            self.l[(k, k)] = r;
            for i in (k + 1)..n {
                let lik = (self.l[(i, k)] - s * w[i]) / c;
                w[i] = c * w[i] - s * lik;
                self.l[(i, k)] = lik;
            }
        }
        Ok(())
    }

    /// Expand the factor for `A` bordered by a new row/column
    /// `[A a; aᵀ alpha]` in `O(n²)` — the Rudi-15 incremental step.
    pub fn expand(&mut self, a_col: &[f64], alpha: f64) -> Result<(), String> {
        let n = self.order();
        assert_eq!(a_col.len(), n);
        let y = self.solve_lower(a_col);
        let d = alpha - super::matrix::dot(&y, &y);
        if d <= 0.0 {
            return Err("cholesky expand: new pivot non-positive".into());
        }
        let mut l = Mat::zeros(n + 1, n + 1);
        for i in 0..n {
            for j in 0..=i {
                l[(i, j)] = self.l[(i, j)];
            }
        }
        for j in 0..n {
            l[(n, j)] = y[j];
        }
        l[(n, n)] = d.sqrt();
        self.l = l;
        Ok(())
    }
}

/// Lower-triangular Cholesky factor in packed row-major storage: row
/// `i` holds its `i+1` entries at offset `i(i+1)/2`. The bordered
/// expansion (`[A a; aᵀ α]`) appends one row to the backing `Vec` —
/// amortized `O(n)` with capacity-doubling slack, where the dense
/// [`Cholesky::expand`] re-layouts the whole `O(n²)` factor per added
/// point. A realloc counter proves the amortization (mirroring
/// `EigenBasis`/`UpdateWorkspace` on the eigen path).
#[derive(Clone, Debug, Default)]
pub struct PackedCholesky {
    /// Packed rows, `n(n+1)/2` elements.
    data: Vec<f64>,
    n: usize,
    /// Reusable forward-substitution scratch for `expand`.
    scratch: Vec<f64>,
    reallocs: u64,
}

impl PackedCholesky {
    /// Empty factor of order 0 (grows via [`PackedCholesky::expand`]).
    pub fn new() -> Self {
        PackedCholesky::default()
    }

    pub fn order(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Buffer-growth events since construction (amortized `O(log n)`
    /// over `n` expansions).
    pub fn reallocs(&self) -> u64 {
        self.reallocs
    }

    #[inline]
    fn row(&self, i: usize) -> &[f64] {
        let off = i * (i + 1) / 2;
        &self.data[off..off + i + 1]
    }

    /// `L[i][j]` for `j ≤ i`.
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(j <= i && i < self.n);
        self.data[i * (i + 1) / 2 + j]
    }

    /// Solve `L y = b` by forward substitution into a caller-owned,
    /// capacity-retaining buffer.
    pub fn solve_lower_into(&self, b: &[f64], y: &mut Vec<f64>) {
        assert_eq!(b.len(), self.n);
        y.clear();
        y.extend_from_slice(b);
        for i in 0..self.n {
            let row = self.row(i);
            let mut s = y[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
    }

    /// Allocating form of [`PackedCholesky::solve_lower_into`].
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.solve_lower_into(b, &mut y);
        y
    }

    /// Expand the factor for `A` bordered by a new row/column
    /// `[A a; aᵀ alpha]`. `O(n²)` flops for the solve but only an
    /// amortized `O(n)` append to storage. Fails — without mutating the
    /// factor — when the new pivot is non-positive.
    pub fn expand(&mut self, a_col: &[f64], alpha: f64) -> Result<(), String> {
        assert_eq!(a_col.len(), self.n);
        let mut y = std::mem::take(&mut self.scratch);
        self.solve_lower_into(a_col, &mut y);
        let d = alpha - dot(&y, &y);
        if d <= 0.0 {
            self.scratch = y;
            return Err("cholesky expand: new pivot non-positive".into());
        }
        let cap = self.data.capacity();
        self.data.extend_from_slice(&y);
        self.data.push(d.sqrt());
        if self.data.capacity() != cap {
            self.reallocs += 1;
        }
        self.n += 1;
        self.scratch = y;
        Ok(())
    }

    /// Dense copy of the factor (evaluation/diagnostic paths).
    pub fn to_mat(&self) -> Mat {
        let mut l = Mat::zeros(self.n, self.n);
        for i in 0..self.n {
            let off = i * (i + 1) / 2;
            l.row_mut(i)[..i + 1].copy_from_slice(&self.data[off..off + i + 1]);
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul_nt, syrk};

    fn spd(n: usize, seed: u64) -> Mat {
        let x = Mat::from_fn(n, n + 2, |i, j| {
            (((i as u64 + 1) * (j as u64 + 3) * seed) % 97) as f64 / 97.0 - 0.3
        });
        let mut g = syrk(&x);
        for i in 0..n {
            g[(i, i)] += 1e-3;
        }
        g
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(8, 5);
        let ch = Cholesky::new(&a).unwrap();
        let rec = matmul_nt(ch.factor(), ch.factor());
        assert!(rec.max_abs_diff(&a) < 1e-11);
    }

    #[test]
    fn solve_matches_direct() {
        let a = spd(6, 9);
        let ch = Cholesky::new(&a).unwrap();
        let b: Vec<f64> = (0..6).map(|i| (i as f64).cos()).collect();
        let x = ch.solve(&b);
        let ax = crate::linalg::gemm::gemv(&a, &x);
        for (u, v) in ax.iter().zip(b.iter()) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_identity() {
        let a = spd(5, 13);
        let ch = Cholesky::new(&a).unwrap();
        let ainv = ch.inverse();
        let prod = crate::linalg::gemm::matmul(&a, &ainv);
        assert!(prod.max_abs_diff(&Mat::eye(5)) < 1e-9);
    }

    #[test]
    fn update_then_downdate_roundtrip() {
        let a = spd(7, 17);
        let mut ch = Cholesky::new(&a).unwrap();
        let v: Vec<f64> = (0..7).map(|i| 0.2 * (i as f64 + 1.0).sin()).collect();
        ch.rank_one_update(&v);
        // A + vvᵀ reconstructed
        let mut avv = a.clone();
        avv.syr(1.0, &v);
        assert!(matmul_nt(ch.factor(), ch.factor()).max_abs_diff(&avv) < 1e-10);
        ch.rank_one_downdate(&v).unwrap();
        assert!(matmul_nt(ch.factor(), ch.factor()).max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn expand_matches_batch() {
        let a = spd(6, 23);
        let mut ch = Cholesky::new(&a.submatrix(5, 5)).unwrap();
        let col: Vec<f64> = (0..5).map(|i| a[(i, 5)]).collect();
        ch.expand(&col, a[(5, 5)]).unwrap();
        let full = Cholesky::new(&a).unwrap();
        assert!(ch.factor().max_abs_diff(full.factor()) < 1e-10);
    }

    #[test]
    fn non_spd_rejected() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn packed_grown_factor_matches_dense() {
        // Grow a packed factor point-by-point; it must equal the dense
        // batch factor at every order, and solves must agree.
        let a = spd(9, 31);
        let mut packed = PackedCholesky::new();
        for m in 0..9 {
            let col: Vec<f64> = (0..m).map(|i| a[(i, m)]).collect();
            packed.expand(&col, a[(m, m)]).unwrap();
            let dense = Cholesky::new(&a.submatrix(m + 1, m + 1)).unwrap();
            assert!(
                packed.to_mat().max_abs_diff(dense.factor()) < 1e-11,
                "factor mismatch at order {}",
                m + 1
            );
        }
        let b: Vec<f64> = (0..9).map(|i| (i as f64 * 0.7).sin()).collect();
        let dense = Cholesky::new(&a).unwrap();
        let yp = packed.solve_lower(&b);
        let yd = dense.solve_lower(&b);
        for (p, d) in yp.iter().zip(yd.iter()) {
            assert!((p - d).abs() < 1e-11);
        }
    }

    #[test]
    fn packed_expand_is_amortized_and_fails_clean() {
        let n = 64;
        let a = spd(n, 7);
        let mut packed = PackedCholesky::new();
        for m in 0..n {
            let col: Vec<f64> = (0..m).map(|i| a[(i, m)]).collect();
            packed.expand(&col, a[(m, m)]).unwrap();
        }
        // Vec-doubling growth: far fewer reallocations than expansions.
        assert!(packed.reallocs() < 16, "reallocs {}", packed.reallocs());
        // A decisively non-positive pivot (repeat of the last column
        // with a deflated diagonal) must fail without corrupting the
        // factor.
        let col: Vec<f64> = (0..n).map(|i| a[(i, n - 1)]).collect();
        let alpha = a[(n - 1, n - 1)] - 1.0;
        assert!(packed.expand(&col, alpha).is_err());
        assert_eq!(packed.order(), n);
        let dense = Cholesky::new(&a).unwrap();
        assert!(packed.to_mat().max_abs_diff(dense.factor()) < 1e-10);
    }
}
